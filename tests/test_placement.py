"""Cost-driven layer replication + scale-out serving (DESIGN.md §13):
planner determinism, honest plan pricing, pspec overrides, the
precision-vs-replication co-decision, draft-bit autotuning, and
bit-exact replicated execution against a single device."""
import os
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.apsim import metrics as apm
from repro.core import policy as pol
from repro.dist import placement as dpl
from repro.dist import sharding as shd
from repro.dist.api import logical_to_mesh
from repro.models import lm
from repro.serve import accounting as acct
from repro.serve.engine import ServeEngine

KEY = jax.random.PRNGKey(4)

INTERP = os.environ.get("REPRO_PALLAS", "").lower() == "interpret"
heavy = pytest.mark.skipif(INTERP, reason="full-LM engine under interpret "
                                          "Pallas; pure planner tests cover "
                                          "the plan math")
multidev = pytest.mark.skipif(len(jax.devices()) < 2,
                              reason="needs >= 2 devices "
                                     "(XLA_FLAGS=--xla_force_host_platform"
                                     "_device_count=8)")

# synthetic priced entries: slot 2 dominates both latency and weights
GEMMS = ([(64, 64)], [(64, 64), (64, 32)], [(256, 256)])
HEAD = (64, 128)
REP8 = [8, 8, 8]


class FakeMesh:
    def __init__(self, shape_map):
        self.shape = shape_map
        self.axis_names = tuple(shape_map)


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

def test_full_budget_fully_replicates():
    plan = dpl.plan_placement(GEMMS, REP8, REP8, n_devices=4, head=HEAD)
    assert plan.replicas == (4, 4, 4, 4)
    assert plan.fully_replicated and plan.has_head
    assert plan.mean_replicas == 4.0
    assert plan.dp == 4 and plan.n_devices == 4
    assert plan.summary()["fully_replicated"] is True


def test_planner_deterministic_and_budgeted():
    kw = dict(n_devices=4, head=HEAD, memory_budget=1.5)
    a = dpl.plan_placement(GEMMS, REP8, REP8, **kw)
    b = dpl.plan_placement(GEMMS, REP8, REP8, **kw)
    assert a == b                               # frozen dataclass equality
    assert not a.fully_replicated
    # the memory budget is respected: extra copies cost at most half a
    # model's weights
    weights = dpl._entry_weights(GEMMS, HEAD)
    extra = sum((r - 1) * w for r, w in zip(a.replicas, weights))
    assert extra <= 0.5 * sum(weights) * (1 + 1e-9)
    # the greedy loop spent SOMETHING (half a model copy funds at least
    # one extra copy of a non-dominant entry) and stayed within [1, D]
    assert a.replicated_entries
    assert 1.0 < a.mean_replicas < 4.0


def test_planner_validation():
    with pytest.raises(ValueError):
        dpl.plan_placement(GEMMS, REP8, REP8, n_devices=0)
    with pytest.raises(ValueError):
        dpl.plan_placement(GEMMS, REP8, REP8, n_devices=2,
                           memory_budget=0.5)
    with pytest.raises(ValueError):
        dpl.PlacementPlan(n_devices=2, dp=2, replicas=(3,), shares=(1.0,))
    with pytest.raises(ValueError):
        dpl.PlacementPlan(n_devices=2, dp=2, replicas=(2, 2), shares=(1, 0),
                          names=("a",))         # 2 entries, 1 name, no head


def test_mesh_device_count():
    assert dpl.mesh_device_count(None) == 1
    assert dpl.mesh_device_count(FakeMesh({"data": 2, "model": 4})) == 8


# ---------------------------------------------------------------------------
# honest pricing
# ---------------------------------------------------------------------------

def test_price_amortizes_latency_not_energy():
    cost = apm.price_bit_vector(GEMMS, REP8, REP8, head=HEAD)
    plan = dpl.plan_placement(GEMMS, REP8, REP8, n_devices=4, head=HEAD)
    priced = plan.price(cost)
    for c, p in zip(cost.per_layer_cycles, priced.per_layer_cycles):
        assert p == c / 4
    assert priced.per_layer_energy_j == cost.per_layer_energy_j
    assert priced.freq_hz == cost.freq_hz
    assert priced.latency_s == pytest.approx(cost.latency_s / 4, rel=1e-12)
    assert priced.energy_j == cost.energy_j
    # a cost with MORE entries than the plan covers is a caller bug
    short = dpl.plan_placement(GEMMS, REP8, REP8, n_devices=4)  # no head
    with pytest.raises(ValueError):
        short.price(cost)


# ---------------------------------------------------------------------------
# pspec overrides
# ---------------------------------------------------------------------------

def test_replicates_lm_keys():
    full = dpl.plan_placement(GEMMS, REP8, REP8, n_devices=4, head=HEAD)
    assert full.replicates(("layers", "attn", "wq", "q"))
    assert full.replicates(("emb",)) and full.replicates(("head",))
    part = dpl.plan_placement(GEMMS, REP8, REP8, n_devices=4, head=HEAD,
                              memory_budget=1.5)
    # a partial stack cannot replicate (one leading L dim, no per-layer
    # pspecs) and unknown keys never match
    assert not part.replicates(("layers", "attn", "wq", "q"))
    assert not full.replicates(("opt_state", "mu"))
    assert not full.replicates(())


def test_replicates_cnn_names():
    plan = dpl.PlacementPlan(n_devices=4, dp=4, replicas=(4, 1),
                             shares=(0.7, 0.3), names=("conv1", "fc"))
    assert plan.replicates(("conv1", "w"))
    assert not plan.replicates(("fc", "w"))     # single copy: base rules
    assert not plan.replicates(("bn1", "scale"))


def test_logical_spec_plan_override():
    full = dpl.plan_placement(GEMMS, REP8, REP8, n_devices=4, head=HEAD)
    part = dpl.plan_placement(GEMMS, REP8, REP8, n_devices=4, head=HEAD,
                              memory_budget=1.5)
    k = ("layers", "attn", "wq", "q")
    assert shd._logical_spec(k, 3, plan=full) == (None,) * 3
    # partial plans keep the base Megatron/FSDP rule bit for bit
    assert shd._logical_spec(k, 3, plan=part) == shd._logical_spec(k, 3)


def test_logical_to_mesh_fallback_warns_once():
    mesh = FakeMesh({"data": 2})
    with pytest.warns(RuntimeWarning, match=r"7919"):
        assert logical_to_mesh(mesh, ("dp",), (7919,)) == P(None)
    with warnings.catch_warnings():             # second resolve: silent
        warnings.simplefilter("error")
        assert logical_to_mesh(mesh, ("dp",), (7919,)) == P(None)


# ---------------------------------------------------------------------------
# co-decision + draft autotuning (pure controller)
# ---------------------------------------------------------------------------

def test_adopt_plan_co_decision():
    """Replication makes configs honestly cheaper, so the same budget
    resolves HIGHER bits after adopt_plan."""
    ctrl = pol.BudgetController(
        {"int4": pol.fixed(4), "int8": pol.fixed(8)},
        {"int4": 0.5, "int8": 1.0}, 3, budget_axis="latency")
    before = int(np.asarray(ctrl.resolve(jnp.float32(0.6))[0])[0])
    assert before == 4                          # int8 (1.0) does not fit
    pricer = acct.BitVectorPricer(GEMMS, head=HEAD)
    plan = dpl.plan_placement(GEMMS, REP8, REP8, n_devices=4, head=HEAD)
    ctrl.adopt_plan(plan, pricer)
    assert ctrl.plan_gain == {"int4": pytest.approx(0.25),
                              "int8": pytest.approx(0.25)}
    assert ctrl.predicted_latency_s["int8"] == pytest.approx(0.25)
    after = int(np.asarray(ctrl.resolve(jnp.float32(0.6))[0])[0])
    assert after == 8                           # 0.25 fits the same budget
    ctrl.adopt_plan(plan, pricer)               # idempotent re-adoption
    assert ctrl.predicted_latency_s["int8"] == pytest.approx(0.25)
    other = dpl.plan_placement(GEMMS, REP8, REP8, n_devices=2, head=HEAD)
    with pytest.raises(ValueError):
        ctrl.adopt_plan(other, pricer)          # re-planning needs a fresh
                                                # controller


def test_draft_autotune_shifts_with_accept_rate():
    ctrl = pol.FluidController(
        {"int4": pol.fixed(4), "int8": pol.fixed(8)},
        {"int4": 0.5, "int8": 1.0}, 2, draft_autotune=True)
    for _ in range(3):
        ctrl.observe_accept(0.0)        # rejected drafts -> raise bits
    assert ctrl.draft_shift == 3
    assert ctrl.draft_accept_ema == -1.0        # reset after each shift
    ctrl.observe_accept(1.0)            # perfect drafts -> lower bits
    assert ctrl.draft_shift == 2
    for _ in range(20):
        ctrl.observe_accept(0.0)
    assert ctrl.draft_shift == 8                # loose clamp
    off = pol.FluidController(
        {"int4": pol.fixed(4)}, {"int4": 0.5}, 2)
    off.observe_accept(0.0)
    assert off.draft_shift == 0                 # off by default


# ---------------------------------------------------------------------------
# plan-priced ledger (engine, no mesh needed)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    cfg = configs.get_smoke("qwen3_4b")
    params = lm.init_params(cfg, KEY)
    return cfg, lm.quantize_params(params, cfg), lm.n_bit_slots(cfg)


def _ctrl(n):
    return pol.BudgetController(
        {"int4": pol.fixed(4), "int8": pol.fixed(8)},
        {"int4": 1.0, "int8": 2.0}, n)


def _engine(served, **kw):
    cfg, qparams, n = served
    kw.setdefault("controller", _ctrl(n))
    return ServeEngine(cfg, qparams, max_len=64, n_slots=4, prefill_len=8,
                       decode_block=4, seed=0, **kw)


PROMPTS = ([3, 1, 4, 1, 5], [2, 7, 1], [6, 2, 8, 1, 8, 2], [9, 9])
BUDGETS = (10.0, 0.5, 10.0, 0.5)                # int8 / int4 mix


def _serve(eng):
    rids = [eng.submit(p, max_new_tokens=5, budget_s=b)
            for p, b in zip(PROMPTS, BUDGETS)]
    eng.run()
    return rids


@heavy
def test_plan_priced_records_match_aggregate(served):
    """An explicit plan (no mesh) amortizes every record's EDP by exactly
    1/D (latency /D, energy unchanged) and flows into aggregate()."""
    cfg, _, n = served
    plan = dpl.plan_for_controller(
        _ctrl(n), lm.layer_gemm_dims(cfg), n_devices=4,
        head=lm.head_gemm_dims(cfg), axis="edp")
    base_eng = _engine(served)
    plan_eng = _engine(served, plan=plan)
    base_rids = _serve(base_eng)
    plan_rids = _serve(plan_eng)
    for rb, rp in zip(base_rids, plan_rids):
        b, p = base_eng.requests[rb], plan_eng.requests[rp]
        assert p.tokens == b.tokens             # pricing never touches math
        assert p.ap_latency_s == pytest.approx(b.ap_latency_s / 4,
                                               rel=1e-12)
        assert p.ap_energy_j == b.ap_energy_j
        assert p.edp == pytest.approx(b.edp / 4, rel=1e-12)
        assert p.plan_replicas == 4.0 and b.plan_replicas == 0.0
    agg = acct.aggregate(plan_eng.requests.values())
    assert agg["plan_requests"] == len(plan_rids)
    assert agg["plan_mean_replicas"] == 4.0
    base_agg = acct.aggregate(base_eng.requests.values())
    assert base_agg["plan_requests"] == 0
    assert agg["edp_per_unit_js"] == pytest.approx(
        base_agg["edp_per_unit_js"] / 4, rel=1e-9)


@heavy
def test_draft_autotune_closed_loop_engine(served):
    """Autotuned draft bits keep the greedy stream exact while the
    ledger reports the drafted precision."""
    cfg, qparams, n = served
    vanilla = _engine(served)
    v_rids = _serve(vanilla)
    fluid = pol.FluidController(
        {"int4": pol.fixed(4), "int8": pol.fixed(8)},
        {"int4": 1.0, "int8": 2.0}, n, draft_autotune=True)
    eng = _engine(served, controller=fluid, spec_k=4, draft_budget_s=1.0)
    rids = _serve(eng)
    for rv, rs in zip(v_rids, rids):
        assert eng.requests[rs].tokens == vanilla.requests[rv].tokens
    spec = [r for r in eng.requests.values() if r.spec_rounds > 0]
    assert spec                                 # speculation actually ran
    assert all(r.draft_wbits > 0 for r in spec)
    agg = acct.aggregate(eng.requests.values())
    assert agg["spec_draft_mean_wbits"] > 0
    # the engine clamps the controller's shift into its config range
    fluid.draft_shift = 99
    assert eng._draft_index() == len(fluid.order()) - 1
    fluid.draft_shift = -99
    assert eng._draft_index() == 0


# ---------------------------------------------------------------------------
# replicated execution: bit-exact vs single device
# ---------------------------------------------------------------------------

@heavy
@multidev
def test_lm_replicated_rows_bit_exact(served):
    """A fully-replicated auto plan on a data mesh serves the exact
    greedy streams of the single-device engine — per-row budgets and
    all — with zero retraces."""
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:2]), ("data",))
    eng_m = _engine(served, mesh=mesh, plan="auto")
    assert eng_m.plan is not None and eng_m.plan.fully_replicated
    assert eng_m.plan.dp == 2
    assert eng_m._dp_exec is not None           # shard_map path engaged
    eng_s = _engine(served)
    rids_m = _serve(eng_m)
    rids_s = _serve(eng_s)
    for rm, rs in zip(rids_m, rids_s):
        assert eng_m.requests[rm].tokens == eng_s.requests[rs].tokens
        assert eng_m.requests[rm].plan_replicas == 2.0
    assert eng_m.stats.prefill_traces == 1
    assert eng_m.stats.decode_traces == 1
    agg = acct.aggregate(eng_m.requests.values())
    assert agg["plan_requests"] == len(rids_m)
    assert agg["plan_mean_replicas"] == 2.0


@heavy
@multidev
def test_cnn_replicated_batch_matches_single_device():
    from jax.sharding import Mesh

    from repro.models import cnn
    from repro.serve.cnn import CNNServeEngine

    params, layers = cnn.init_cnn("resnet18", KEY, image=8)
    images = np.asarray(jax.random.normal(KEY, (4, 8, 8, 3), jnp.float32))
    mesh = Mesh(np.asarray(jax.devices()[:2]), ("data",))
    eng_m = CNNServeEngine(params, layers, max_batch=4, mesh=mesh,
                           plan="auto")
    assert eng_m.plan is not None and eng_m.plan.fully_replicated
    assert eng_m.plan.names                     # per-layer CNN entries
    assert eng_m._dp_exec is not None
    eng_s = CNNServeEngine(params, layers, max_batch=4)
    got_m, stats_m = eng_m.serve(images)
    got_s, stats_s = eng_s.serve(images)
    np.testing.assert_allclose(got_m, got_s, rtol=1e-5, atol=1e-5)
    assert np.argmax(got_m, -1).tolist() == np.argmax(got_s, -1).tolist()
    for sm, ss in zip(stats_m, stats_s):
        assert sm.plan_replicas == 2.0 and ss.plan_replicas == 0.0
        assert sm.ap_cost.latency_s == pytest.approx(
            ss.ap_cost.latency_s / 2, rel=1e-12)
    assert eng_m.stats.forward_traces == 1
