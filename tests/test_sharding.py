"""Sharding rules: divisibility fallback, spec shapes, logical mapping.

Pure-spec tests — they build meshes abstractly via jax.sharding.Mesh over
a numpy device grid trick?  No: Mesh requires real devices, so rules are
tested through logical_to_mesh with a fake mesh-like object."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.dist import sharding as shd
from repro.dist.api import logical_to_mesh
from repro.launch import specs as sp


class FakeMesh:
    def __init__(self, shape_map):
        self.shape = shape_map
        self.axis_names = tuple(shape_map)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_divisibility_fallback():
    spec = logical_to_mesh(MESH, ("dp", "tp"), (100, 96))
    assert spec == P(None, "model")          # 100 % 16 != 0 -> replicate
    spec = logical_to_mesh(MESH, ("dp", "tp"), (128, 96))
    assert spec == P("data", "model")


def test_combined_dp_axes():
    spec = logical_to_mesh(MESH3, ("dp", None), (64, 7))
    assert spec == P(("pod", "data"), None)
    spec = logical_to_mesh(MESH3, ("dp+tp", None), (512, 7))
    assert spec == P(("pod", "data", "model"), None)
    # 100 doesn't divide 512 -> drop
    assert logical_to_mesh(MESH3, ("dp+tp",), (100,)) == P(None)


@pytest.mark.parametrize("arch", ["qwen1_5_110b", "kimi_k2_1t_a32b",
                                  "mamba2_1_3b", "zamba2_2_7b"])
def test_param_specs_cover_all_leaves(arch):
    cfg = configs.get(arch)
    params = sp.abstract_params(cfg)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        spec = shd.param_pspec(path, leaf)
        assert len(spec) == leaf.ndim, (path, spec, leaf.shape)


def test_expert_stack_sharded_both_axes():
    cfg = configs.get("kimi_k2_1t_a32b")
    params = sp.abstract_params(cfg)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    found = False
    for path, leaf in flat:
        keys = tuple(str(getattr(p, "key", p)) for p in path)
        if "experts" in keys and keys[-1] == "wg":
            spec = shd.param_pspec(path, leaf)      # (L, E, d, f)
            assert spec == (None, "tp", "dp", None)
            found = True
    assert found


def test_kv_cache_spec_long_context():
    """B=1 long decode shards the SEQUENCE over dp instead of batch."""
    spec = shd._kv_cache_spec(MESH, (48, 1, 524288, 8, 128))
    assert spec == P(None, None, "data", None, "model")
    spec = shd._kv_cache_spec(MESH, (48, 128, 32768, 16, 128))
    assert spec == P(None, "data", None, "model", None)


def test_opt_int8_codec_mirrors_params():
    from repro.launch.specs import optimizer_for
    from repro.optim.adamw import adamw_init
    cfg = configs.get("kimi_k2_1t_a32b")
    params = sp.abstract_params(cfg)
    ocfg = optimizer_for(cfg)
    assert ocfg.m_dtype == "int8" and ocfg.v_mode == "factored"
    opt = jax.eval_shape(lambda p: adamw_init(p, ocfg), params)
    # every m.q leaf has EXACTLY its parameter's shape (shape-preserving
    # codec — the 7.8 TB/device lesson of §Perf iteration 2)
    p_flat = {tuple(str(getattr(q, "key", q)) for q in path): leaf
              for path, leaf in
              jax.tree_util.tree_flatten_with_path(params)[0]}
    for path, leaf in jax.tree_util.tree_flatten_with_path(opt["m"])[0]:
        keys = tuple(str(getattr(q, "key", q)) for q in path)
        if keys[-1] == "q":
            assert p_flat[keys[:-1]].shape == leaf.shape


def test_bits_specs_and_off_mesh_identity():
    """Per-request (B, L) bit matrices shard batch over dp; (L,) tables
    replicate; without a mesh shard_bits is the identity."""
    spec = logical_to_mesh(MESH, shd.bits_pspec(np.zeros((32, 4))), (32, 4))
    assert spec == P("data", None)
    spec = logical_to_mesh(MESH, shd.bits_pspec(np.zeros((30, 4))), (30, 4))
    assert spec == P(None, None)                  # non-dividing B replicates
    spec = logical_to_mesh(MESH, shd.bits_pspec(np.zeros((4,))), (4,))
    assert spec == P(None)
    bits = np.zeros((4,), np.int32)
    assert shd.shard_bits(bits) is bits


def test_budgets_spec_and_off_mesh_identity():
    """Per-request (B,) budget vectors — the runtime's batched admission
    state — shard over dp like the rows they gate (replication fallback
    for non-dividing B; identity off-mesh)."""
    spec = logical_to_mesh(MESH, shd.budgets_pspec(np.zeros((32,))), (32,))
    assert spec == P("data")
    spec = logical_to_mesh(MESH, shd.budgets_pspec(np.zeros((30,))), (30,))
    assert spec == P(None)
    spec = logical_to_mesh(MESH3, shd.budgets_pspec(np.zeros((32,))), (32,))
    assert spec == P(("pod", "data"))
    budgets = np.zeros((8,), np.float32)
    assert shd.shard_budgets(budgets) is budgets
