"""Speculative decoding (DESIGN.md §11): greedy bit-identity against
vanilla decode, post-rejection cache exactness vs a never-drafted run,
zero-retrace program counters, and the fluid controller's draft ledger
(planned-charge / actual-reconcile, early-eos refund)."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.apsim import metrics as apm
from repro.core import policy as pol
from repro.models import lm
from repro.models.transformer import EMPTY_POS
from repro.serve import accounting as acct
from repro.serve.engine import SPEC_K_MAX, ServeEngine
from repro.serve.prefix_cache import PrefixCache

KEY = jax.random.PRNGKey(7)

# full-LM spec rounds are too slow through interpret-mode Pallas; the
# rollback/ledger semantics are covered there by the pure tests below
INTERP = os.environ.get("REPRO_PALLAS", "").lower() == "interpret"
heavy = pytest.mark.skipif(INTERP, reason="pure rollback/ledger tests cover "
                                          "spec decode under interpret "
                                          "Pallas")

PROMPTS = ([3, 1, 4, 1], [3, 1, 4, 1], [2, 7, 1])   # repeat -> cache hit
MAX_NEW = 10


@pytest.fixture(scope="module")
def served():
    cfg = configs.get_smoke("qwen3_4b")
    params = lm.init_params(cfg, KEY)
    return cfg, lm.quantize_params(params, cfg), lm.n_bit_slots(cfg)


def _ctrl(n):
    return pol.BudgetController(
        {"int4": pol.fixed(4), "int8": pol.fixed(8)},
        {"int4": 1.0, "int8": 2.0}, n)


def _engine(served, **kw):
    cfg, qparams, n = served
    return ServeEngine(cfg, qparams, max_len=64,
                       controller=kw.pop("controller", _ctrl(n)),
                       n_slots=2, prefill_len=4, decode_block=4,
                       seed=0, **kw)


def _serve(eng, *, draft_ks=None, max_new=MAX_NEW):
    rids = [eng.submit(p, max_new_tokens=max_new,
                       draft_k=None if draft_ks is None else draft_ks[i])
            for i, p in enumerate(PROMPTS)]
    eng.run()
    return {r: eng.requests[r].tokens for r in rids}


@pytest.fixture(scope="module")
def vanilla_tokens(served):
    """Greedy reference stream from a never-drafting engine."""
    if INTERP:
        pytest.skip("full-LM engine under interpret Pallas")
    return _serve(_engine(served))


# ---------------------------------------------------------------------------
# greedy bit-identity + zero retrace
# ---------------------------------------------------------------------------

@heavy
@pytest.mark.parametrize("hit_policy", [None, "exact", "at_least"])
@pytest.mark.parametrize("draft_ks", [None, [0, 2, SPEC_K_MAX]])
def test_greedy_spec_matches_vanilla(served, vanilla_tokens, hit_policy,
                                     draft_ks):
    """Every (k, per-request override, prefix-cache policy) combination
    emits the exact vanilla greedy stream: each token is a verify-bits
    argmax, rejected drafts roll back invisibly."""
    cache = (None if hit_policy is None
             else PrefixCache(chunk=2, capacity=8, hit_policy=hit_policy))
    eng = _engine(served, spec_k=4, draft_budget_s=1.0,   # int4 drafts
                  prefix_cache=cache)
    got = _serve(eng, draft_ks=draft_ks)
    assert list(got.values()) == list(vanilla_tokens.values())
    if cache is not None:
        assert cache.ledger.hits >= 1       # the repeat prompt actually hit


@heavy
def test_zero_retrace_and_counters(served, vanilla_tokens):
    """Mixed depths across slot churn compile ONE draft and ONE verify
    program, and the per-request spec counters obey the round algebra."""
    eng = _engine(served, spec_k=0, draft_budget_s=1.0)
    got = _serve(eng, draft_ks=[SPEC_K_MAX, 2, 4])
    assert got == vanilla_tokens
    assert eng.stats.traces["draft"] == 1
    assert eng.stats.traces["verify"] == 1
    for rec in eng.requests.values():
        if rec.spec_k == 0:
            assert rec.spec_rounds == rec.draft_units == 0
            continue
        assert rec.draft_units == rec.spec_k * rec.spec_rounds
        assert rec.verify_units == (rec.spec_k + 1) * rec.spec_rounds
        assert 0 <= rec.accepted_units <= rec.draft_units
        # every round delivers accepted drafts + one verified token
        assert rec.spec_tokens == rec.accepted_units + rec.spec_rounds
        assert rec.spec_tokens <= len(rec.tokens)
    agg = acct.aggregate(eng.requests.values())
    assert agg["spec_rounds"] == sum(r.spec_rounds
                                     for r in eng.requests.values())
    assert 0.0 <= agg["spec_accept_rate"] <= 1.0


@heavy
def test_submit_guards(served):
    eng = _engine(served, spec_k=4, draft_budget_s=1.0)
    with pytest.raises(ValueError):
        eng.submit([1, 2], max_new_tokens=4, draft_k=SPEC_K_MAX + 1)
    with pytest.raises(ValueError):
        # 4 + 52 + SPEC_K_MAX > max_len=64: the draft scan could overrun
        eng.submit([1, 2, 3, 4], max_new_tokens=53)
    # the same request is admissible with drafting off
    vane = _engine(served)
    vane.submit([1, 2, 3, 4], max_new_tokens=53)


# ---------------------------------------------------------------------------
# post-rejection cache state: bit-exact vs never drafted
# ---------------------------------------------------------------------------

@heavy
def test_post_rejection_cache_bitexact(served):
    """Draft wrong tokens at low bits, roll back, decode the true
    continuation: the pool is bit-exact vs a run that never drafted —
    kpos identical everywhere, K/V identical at every visible entry."""
    cfg, qparams, n = served
    wv, av = pol.fixed(8).vectors(n)
    dwv, dav = pol.fixed(4).vectors(n)
    prompt = jnp.asarray([[3, 1, 4, 1]], jnp.int32)
    S, max_len = prompt.shape[1], 16

    def prefilled():
        pool = lm.CachePool(cfg, 1, max_len)
        slot = pool.alloc()
        logits, row = lm.prefill(qparams, {"tokens": prompt}, cfg, wv, av,
                                 lm.empty_cache(cfg, 1, max_len))
        pool.write_row(row, slot, S)
        return pool, int(jnp.argmax(logits[0, -1]))

    pool_a, tok = prefilled()
    pool_b, tok_b = prefilled()
    assert tok == tok_b

    # A: three junk drafts at draft bits into positions S..S+2, rejected
    cache = pool_a.cache
    for i, junk in enumerate((7, 9, 11)):
        _, cache = lm.decode_step(qparams, jnp.asarray([[junk]], jnp.int32),
                                  S + i, cache, cfg, dwv, dav)
    pool_a.cache = cache
    pool_a.rollback(np.asarray([S - 1]))    # keep only the prompt

    # both pools now decode the true greedy continuation at target bits
    def continue_greedy(pool, tok, steps=3):
        cache, out = pool.cache, []
        for i in range(steps):
            logits, cache = lm.decode_step(
                qparams, jnp.asarray([[tok]], jnp.int32), S + i, cache,
                cfg, wv, av)
            tok = int(jnp.argmax(logits[0, -1]))
            out.append(tok)
        pool.cache = cache
        return out

    assert continue_greedy(pool_a, tok) == continue_greedy(pool_b, tok)
    kpos_a = np.asarray(pool_a.cache["kpos"])
    kpos_b = np.asarray(pool_b.cache["kpos"])
    np.testing.assert_array_equal(kpos_a, kpos_b)   # rollback left no trace
    visible = kpos_a != EMPTY_POS                   # (L, 1, Sc)
    for leaf in ("k", "v"):
        a = np.asarray(pool_a.cache[leaf])
        b = np.asarray(pool_b.cache[leaf])
        np.testing.assert_array_equal(a[visible], b[visible])


def test_rollback_masks_only_past_keep(served):
    """Pure pool semantics: kpos > keep goes EMPTY for that slot only;
    a slot passing keep >= EMPTY_POS is untouched (the non-spec rows)."""
    cfg, _, _ = served
    pool = lm.CachePool(cfg, 2, 16)
    kp = np.full_like(np.asarray(pool.cache["kpos"]), EMPTY_POS)
    kp[:, :, :6] = np.arange(6)
    pool.cache = dict(pool.cache, kpos=jnp.asarray(kp))
    pool.rollback(np.asarray([3, EMPTY_POS]))
    out = np.asarray(pool.cache["kpos"])
    assert (out[:, 0, :4] == np.arange(4)).all()
    assert (out[:, 0, 4:] == EMPTY_POS).all()
    np.testing.assert_array_equal(out[:, 1], kp[:, 1])


# ---------------------------------------------------------------------------
# fluid-controller draft ledger
# ---------------------------------------------------------------------------

def test_draft_depth_from_headroom():
    ctrl = pol.FluidController({"int8": pol.fixed(8)}, {"int8": 1.0}, 1,
                               budget_axis="edp", slo=100.0, window=64)
    for spent, k in ((0.0, 8), (60.0, 4), (85.0, 2), (95.0, 0)):
        ctrl.spent = spent
        assert ctrl.draft_depth() == k
    assert pol.FluidController({"int8": pol.fixed(8)}, {"int8": 1.0},
                               1).draft_depth() == 8      # slo=inf


def test_spec_ledger_plan_vs_actual():
    """axis_planned swaps planned spec tokens for draft+verify pricing;
    axis_actual re-prices what ran; charge + reconcile leaves the
    controller holding exactly the actual spend."""
    rec = acct.RequestStats(
        rid=0, budget_s=None, prompt_len=4,
        ap_cost=apm.BitVectorCost((10.0,), (2.0,)),
        draft_cost=apm.BitVectorCost((4.0,), (0.5,)),
        verify_cost=apm.BitVectorCost((12.0,), (2.5,)),
        spec_k=4, planned_units=13, planned_spec_rounds=2,
        planned_spec_tokens=8)
    planned = rec.axis_planned("energy")
    # 5 non-spec units at 2.0 J + 8 drafts at 0.5 J + 2 verifies at 2.5 J
    assert planned == pytest.approx(5 * 2.0 + 8 * 0.5 + 2 * 2.5)
    # the request finished early: one round, 3 of 4 drafts accepted
    rec.tokens = [1] * 5                   # prompt 4 + 5 emitted = 9 units
    rec.spec_rounds, rec.draft_units = 1, 4
    rec.accepted_units, rec.spec_tokens = 3, 4
    actual = rec.axis_actual("energy")
    assert actual == pytest.approx(5 * 2.0 + 4 * 0.5 + 1 * 2.5)
    ctrl = pol.FluidController({"int8": pol.fixed(8)}, {"int8": 1.0}, 1,
                               budget_axis="energy", slo=1e9, window=64)
    ctrl.charge(planned)
    ctrl.reconcile(actual - planned)
    assert ctrl.spent == pytest.approx(actual)


@heavy
def test_fluid_eos_ledger_reconciliation(served, vanilla_tokens):
    """Admissions charge their PLAN; finishes reconcile to what ran.
    An eos-truncated vanilla request refunds its unused decode units;
    a drafting sibling whose acceptance diverged from the full-accept
    plan settles the difference (either direction); the controller ends
    the stream holding exactly the sum of actual spends."""
    cfg, qparams, n = served
    ref = list(vanilla_tokens.values())[-1]        # PROMPTS[-1]'s stream
    eos = ref[len(ref) // 2]
    cfgs = {"int4": pol.fixed(4), "int8": pol.fixed(8)}
    ctrl = pol.FluidController(cfgs, {"int4": 1.0, "int8": 2.0}, n,
                               budget_axis="edp", slo=1e6, window=64)
    eng = ServeEngine(cfg, qparams, max_len=64, controller=ctrl,
                      n_slots=2, prefill_len=4, decode_block=4, seed=0,
                      eos_id=eos, spec_k=0, draft_budget_s=1.0)
    rid0 = eng.submit(PROMPTS[-1], max_new_tokens=MAX_NEW, draft_k=0)
    rid8 = eng.submit(PROMPTS[-1], max_new_tokens=MAX_NEW,
                      draft_k=SPEC_K_MAX)
    eng.run()
    rec0, rec8 = eng.requests[rid0], eng.requests[rid8]
    for rec in (rec0, rec8):                       # greedy: same stream
        assert rec.tokens[-1] == eos and len(rec.tokens) < MAX_NEW
    # the never-drafting request was charged max_new planned units and
    # used fewer: a pure refund
    assert rec0.axis_actual("edp") < rec0.axis_planned("edp")
    assert rec8.spec_rounds >= 1
    spent = rec0.axis_actual("edp") + rec8.axis_actual("edp")
    assert ctrl.spent == pytest.approx(spent)      # plan fully reconciled
