"""Serve-form CNN dispatch: conv-as-GEMM through kernels/ops.py must be
BIT-EXACT against the retained inline serve math (the fake-quant-era
per-layer/per-group-loop oracle below, held verbatim in the
test_kernel_dispatch.py style), run every HAWQ-V3 configuration in ONE
compiled program (zero retrace), and the batched serving engine must
return per-request EDP priced over the network's conv/fc GEMM dims."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.apsim import metrics as apm
from repro.apsim.workloads import (HAWQV3_RESNET18, conv, fc, gemm_layers,
                                   per_layer_bits, pool, add)
from repro.core import bitfluid as bf
from repro.core import policy as pol
from repro.kernels import ops
from repro.models import cnn
from repro.models import common as cm
from repro.serve.cnn import CNNServeEngine

KEY = jax.random.PRNGKey(0)

# full-network forwards are too slow through interpret-mode Pallas; the
# dispatch numerics are covered by the tiny-net tests, which do run there
INTERP = os.environ.get("REPRO_PALLAS", "").lower() == "interpret"
heavy = pytest.mark.skipif(INTERP, reason="tiny-net tests cover dispatch "
                                          "under interpret-mode Pallas")


def _tiny_layers():
    """conv -> maxpool -> grouped conv -> residual add -> fc (3 GEMMs)."""
    return [
        conv("c1", 8, 4, 3, 8),
        pool("p1", "maxpool", 8, 8, 2, 2),
        conv("c2", 4, 8, 3, 8, groups=2),
        add("a1", 4, 8),
        fc("fc", 8 * 4 * 4, 10, relu=False),
    ]


def _tiny(int4_names=()):
    layers = _tiny_layers()
    params = {}
    keys = jax.random.split(KEY, len(layers))
    for i, l in enumerate(layers):
        if l.kind == "conv":
            fk = l.hk * l.wk * (l.cin // l.groups)
            params[l.name] = cm.dense_init(keys[i], fk, l.cout, bias=True)
        elif l.kind == "fc":
            params[l.name] = cm.dense_init(keys[i], l.cin, l.cout, bias=True)
    qp = cnn.quantize_cnn_params(params, layers, int4_names=int4_names)
    return params, qp, layers


def _f32(x):
    return np.asarray(x, np.float32)


# ---------------------------------------------------------------------------
# Oracle: the inline serve math, verbatim (per-group Python loop form).
# ---------------------------------------------------------------------------

def _oracle_linear(p, x, wbits, abits):
    if "q4" in p:
        qw, from_bits = bf.unpack_int4_halves(p["q4"]), 4
    else:
        qw, from_bits = p["q"], 8
    w_q = bf.requant_shift(qw, wbits, from_bits=from_bits)
    w_s = bf.effective_scale(p["s"], wbits, from_bits=from_bits)
    x2 = x.astype(jnp.float32)
    x_scale = bf.symmetric_scale(x2, abits)
    x_q = bf.quantize(x2, x_scale, abits)
    acc = jax.lax.dot_general(
        x_q, w_q, dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * x_scale * w_s
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(cm.DTYPE)


def _oracle_conv(p, x, layer, wbits, abits):
    g = layer.groups
    cols = cnn.im2col(x, layer.hk, layer.wk, layer.stride, layer.pad)
    if g == 1:
        y = _oracle_linear(p, cols, wbits, abits)
    else:
        N, Ho, Wo, _ = cols.shape
        cg = cnn.grouped_cols(cols, g, layer.hk * layer.wk)
        ys = [_oracle_linear({"q": p["q"][i], "s": p["s"][i]},
                             cg[:, :, :, i], wbits, abits)
              for i in range(g)]
        y = jnp.concatenate(ys, axis=-1)
        if "b" in p:
            y = y.astype(jnp.float32) + p["b"].astype(jnp.float32)
        y = y.astype(cm.DTYPE)
    if layer.relu:
        y = jax.nn.relu(y.astype(jnp.float32)).astype(cm.DTYPE)
    return y


def _oracle_forward(qp, x, layers, wvec, avec):
    gi = 0
    residual = block_in = None
    x = x.astype(cm.DTYPE)
    for l in layers:
        wb = int(wvec[gi]) if wvec is not None else 8
        ab = int(avec[gi]) if avec is not None else 8
        if l.kind == "conv":
            if block_in is None:
                block_in = x
            if l.name.endswith("_down"):
                residual = _oracle_conv(qp[l.name], block_in, l, wb, ab)
                gi += 1
                continue
            x = _oracle_conv(qp[l.name], x, l, wb, ab)
            gi += 1
        elif l.kind in ("maxpool", "avgpool"):
            x = cnn.pool2d(x, l)
            block_in = None
        elif l.kind == "add":
            skip = residual if residual is not None else block_in
            x = x + skip
            x = jax.nn.relu(x.astype(jnp.float32)).astype(cm.DTYPE)
            residual, block_in = None, None
        elif l.kind == "fc":
            if x.ndim == 4:
                x = x.reshape(x.shape[0], -1)
            x = _oracle_linear(qp[l.name], x, wb, ab)
            if l.relu:
                x = jax.nn.relu(x.astype(jnp.float32)).astype(cm.DTYPE)
            gi += 1
    return x.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Dispatch parity (runs under interpret-mode Pallas too)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("int4_names", [(), ("c1", "fc")],
                         ids=["int8", "int4-mixed"])
@pytest.mark.parametrize("wbits", [2, 4, 8])
def test_serve_forward_bit_exact_vs_oracle(rng, int4_names, wbits):
    _, qp, layers = _tiny(int4_names)
    n = len(gemm_layers(layers))
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 4)).astype(np.float32))
    wv = jnp.full((n,), wbits, jnp.int32)
    got = cnn.cnn_forward(qp, x, layers, wv, wv)
    want = _oracle_forward(qp, x, layers, [wbits] * n, [wbits] * n)
    np.testing.assert_array_equal(_f32(got), _f32(want))


def test_grouped_single_gemm_matches_per_group_loop(rng):
    _, qp, layers = _tiny()
    l = layers[2]
    assert l.groups == 2
    x = jnp.asarray(rng.normal(size=(2, l.hin, l.hin, l.cin))
                    .astype(np.float32)).astype(cm.DTYPE)
    for wb in (2, 4, 8):
        got = cnn.conv_gemm(qp[l.name], x, l, wb, 8)
        want = _oracle_conv(qp[l.name], x, l, wb, 8)
        np.testing.assert_array_equal(_f32(got), _f32(want))


def test_serve_linear_stacked_matches_loop(rng):
    w = jnp.asarray(rng.normal(size=(3, 32, 16)).astype(np.float32) * 0.1)
    qs = cm.quantize_linear({"w": w})
    x = jnp.asarray(rng.normal(size=(3, 5, 32)).astype(np.float32))
    got = ops.serve_linear_stacked({"q": qs["q"], "s": qs["s"]}, x, 4, 8)
    want = jnp.stack([
        ops.serve_linear({"q": qs["q"][i], "s": qs["s"][i]}, x[i], 4, 8)
        for i in range(3)])
    np.testing.assert_array_equal(_f32(got), _f32(want))
    # stack_bits: one width per stacked slice (the MoE per-expert axis)
    wb = jnp.asarray([2, 4, 8], jnp.int32)
    got = ops.serve_linear_stacked({"q": qs["q"], "s": qs["s"]}, x, wb, 8,
                                   stack_bits=True)
    want = jnp.stack([
        ops.serve_linear({"q": qs["q"][i], "s": qs["s"][i]}, x[i],
                         int(wb[i]), 8)
        for i in range(3)])
    np.testing.assert_array_equal(_f32(got), _f32(want))


def test_per_row_bit_matrix_rows_match_solo_runs(rng):
    """(B, n_gemm) per-request rows are numerically independent: each row
    equals its own single-image run at that row's (n_gemm,) vector."""
    _, qp, layers = _tiny()
    n = len(gemm_layers(layers))
    x = jnp.asarray(rng.normal(size=(3, 8, 8, 4)).astype(np.float32))
    rows = jnp.asarray([[4] * n, [8] * n, [4, 8, 4]], jnp.int32)
    with ops.bit_families((4, 8)):
        batched = _f32(cnn.cnn_forward(qp, x, layers, rows, rows))
        for i in range(3):
            solo = _f32(cnn.cnn_forward(qp, x[i:i + 1], layers,
                                        rows[i], rows[i]))
            np.testing.assert_array_equal(batched[i:i + 1], solo)


def test_zero_retrace_across_bit_configs(rng):
    """Any per-layer configuration is data: one trace serves them all."""
    _, qp, layers = _tiny()
    n = len(gemm_layers(layers))
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 4)).astype(np.float32))
    traces = []

    @jax.jit
    def run(wv, av):
        traces.append(1)
        return cnn.cnn_forward(qp, x, layers, wv, av)

    for mix in ([8] * n, [4] * n, [2, 4, 8], [8, 2, 4]):
        run(jnp.asarray(mix, jnp.int32),
            jnp.asarray(mix, jnp.int32)).block_until_ready()
    assert len(traces) == 1


# ---------------------------------------------------------------------------
# ResNet18 / HAWQ-V3 (the Table VII acceptance path)
# ---------------------------------------------------------------------------

@heavy
def test_hawq_resnet18_one_compiled_program(rng):
    """All HAWQV3_RESNET18 constraints run ResNet18 through
    ops.serve_linear in ONE compiled program, bit-exact to the retained
    inline oracle."""
    params, layers = cnn.init_cnn("resnet18", KEY, image=32)
    qp = cnn.quantize_cnn_params(params, layers)
    x = jnp.asarray(rng.normal(size=(2, 32, 32, 3)).astype(np.float32))
    traces = []

    @jax.jit
    def run(wv, av):
        traces.append(1)
        return cnn.cnn_forward(qp, x, layers, wv, av)

    outs = {}
    for name, vec in HAWQV3_RESNET18.items():
        bits = jnp.asarray(per_layer_bits(layers, vec), jnp.int32)
        outs[name] = np.asarray(run(bits, bits))
    assert len(traces) == 1
    assert not np.allclose(outs["int4"], outs["int8"])
    bits = per_layer_bits(layers, HAWQV3_RESNET18["medium"])
    want = _oracle_forward(qp, x, layers, bits, bits)
    np.testing.assert_array_equal(outs["medium"], _f32(want))


@heavy
def test_engine_per_request_edp_monotone(rng):
    """Mixed budgets in one batch: tighter budgets resolve to fewer bits
    and strictly lower modeled EDP; batch churn never retraces."""
    params, layers = cnn.init_cnn("resnet18", KEY, image=32)
    ctrl = pol.cnn_budget_controller("resnet18", layers=layers)
    eng = CNNServeEngine(params, layers, controller=ctrl, max_batch=4)
    preds = sorted(ctrl.predicted_latency_s.values())
    lo, hi = preds[0] * 1.01, preds[-1] * 1.01
    x = jnp.asarray(rng.normal(size=(4, 32, 32, 3)).astype(np.float32))
    logits, stats = eng.serve(x, [lo, hi, lo, hi])
    assert logits.shape == (4, 1000)
    assert np.isfinite(logits).all()
    assert stats[0].mean_wbits < stats[1].mean_wbits
    assert stats[0].edp < stats[1].edp                  # int4 < int8 rows
    assert stats[0].ap_energy_j < stats[1].ap_energy_j
    assert stats[2].edp == stats[0].edp                 # same config, cached
    # shorter batch, different mix: same compiled program
    logits2, stats2 = eng.serve(x[:2], hi)
    assert logits2.shape == (2, 1000)
    assert stats2[0].edp == stats[1].edp
    assert eng.stats.forward_traces == 1
    assert eng.stats.images == 6


def test_engine_int4_container_plan(rng):
    """A controller whose every configuration runs <= 4 bits makes
    ungrouped, even-width layers packed-int4 eligible; grouped layers
    stay int8 stacks."""
    params, _, layers = _tiny()
    ctrl = pol.BudgetController(
        {"int4": pol.fixed(4), "int2": pol.fixed(2)},
        {"int4": 2.0, "int2": 1.0}, len(gemm_layers(layers)))
    eng = CNNServeEngine(params, layers, controller=ctrl, max_batch=2)
    assert set(eng.int4_names) == {"c1", "fc"}          # c2 is grouped
    assert "q4" in eng.qparams["c1"] and "q4" in eng.qparams["fc"]
    assert "q" in eng.qparams["c2"] and eng.qparams["c2"]["q"].ndim == 3
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 4)).astype(np.float32))
    logits, stats = eng.serve(x, [0.5, 3.0])
    assert np.isfinite(logits).all()
    assert stats[0].mean_wbits == 2 and stats[1].mean_wbits == 4
    # with an 8-bit config registered, nothing is int4-eligible
    ctrl8 = pol.BudgetController(
        {"int4": pol.fixed(4), "int8": pol.fixed(8)},
        {"int4": 1.0, "int8": 2.0}, len(gemm_layers(layers)))
    assert CNNServeEngine(params, layers, controller=ctrl8).int4_names == ()


def test_engine_validates_controller_slots():
    params, _, layers = _tiny()
    ctrl = pol.BudgetController({"int8": pol.fixed(8)}, {"int8": 0.0}, 7)
    with pytest.raises(ValueError, match="GEMM"):
        CNNServeEngine(params, layers, controller=ctrl)


def test_engine_rejects_oversized_batch(rng):
    params, _, layers = _tiny()
    eng = CNNServeEngine(params, layers, max_batch=2)
    x = jnp.asarray(rng.normal(size=(3, 8, 8, 4)).astype(np.float32))
    with pytest.raises(ValueError, match="max_batch"):
        eng.serve(x)


# ---------------------------------------------------------------------------
# EDP pricing over conv/fc GEMM dims
# ---------------------------------------------------------------------------

def test_price_bit_vector_layers_match_simulator():
    """Pricing a CNN bit vector over network_gemms must equal the GEMM
    subtotal of the paper simulator on the same bits (same _gemm_layer
    mapping), and scale monotonically with precision."""
    from repro.apsim.energy import SRAM
    from repro.apsim.mapper import LR_CONFIG, simulate_network

    layers = _tiny_layers()
    gemms = apm.network_gemms(layers)
    n = len(gemms)
    c4 = apm.price_bit_vector(gemms, [4] * n, [4] * n)
    c8 = apm.price_bit_vector(gemms, [8] * n, [8] * n)
    assert 0 < c4.energy_j < c8.energy_j
    assert 0 < c4.edp < c8.edp
    rep = simulate_network(layers, LR_CONFIG, SRAM, bits=8)
    want_cyc = sum(r.cycles for r in rep.layers if r.kind in ("conv", "fc"))
    want_en = sum(r.energy_j for r in rep.layers if r.kind in ("conv", "fc"))
    np.testing.assert_allclose(c8.cycles, want_cyc, rtol=1e-12)
    np.testing.assert_allclose(c8.energy_j, want_en, rtol=1e-12)


def test_cnn_budget_controller_resolves_by_edp():
    ctrl = pol.cnn_budget_controller("resnet18")
    assert ctrl.budget_axis == "edp"
    assert ctrl.order() == ["hawqv3-int4", "hawqv3-low", "hawqv3-medium",
                            "hawqv3-high", "hawqv3-int8"]
    preds = [ctrl.predicted_latency_s[k] for k in ctrl.order()]
    assert preds == sorted(preds)
    wv, _ = ctrl.resolve(jnp.asarray(preds[0] * 1.01, jnp.float32))
    assert float(jnp.mean(wv.astype(jnp.float32))) == 4.0
    wv, _ = ctrl.resolve(jnp.asarray(preds[-1] * 1.01, jnp.float32))
    assert float(jnp.mean(wv.astype(jnp.float32))) == 8.0
    with pytest.raises(ValueError, match="metric"):
        pol.cnn_budget_controller("resnet18", metric="flops")


def test_cnn_budget_controller_other_networks():
    """The HAWQ-V3 defaults are ResNet18 vectors: on AlexNet they must
    raise with a clear error, and explicit per-network configs work."""
    with pytest.raises(ValueError, match="explicit"):
        pol.cnn_budget_controller("alexnet")
    ctrl = pol.cnn_budget_controller(
        "alexnet",
        configs={"int4": pol.fixed(4), "int8": pol.fixed(8)},
        metric="energy")
    assert ctrl.budget_axis == "energy"
    assert ctrl.n_layers == 8
    assert (ctrl.predicted_latency_s["int4"]
            < ctrl.predicted_latency_s["int8"])


def test_engine_rejects_unhonorable_int4_container():
    """An explicit int4 container under a controller that can resolve
    8-bit configs would bill requests at a precision the container
    cannot honor — the engine must refuse it."""
    params, _, layers = _tiny()
    ctrl = pol.BudgetController(
        {"int4": pol.fixed(4), "int8": pol.fixed(8)},
        {"int4": 1.0, "int8": 2.0}, len(gemm_layers(layers)))
    with pytest.raises(ValueError, match="cannot honor"):
        CNNServeEngine(params, layers, controller=ctrl, container="int4")


# ---------------------------------------------------------------------------
# Bit-vector validation (no silent clamping)
# ---------------------------------------------------------------------------

def test_bit_vector_length_validated(rng):
    params, layers = cnn.init_cnn("resnet18", KEY, image=32)
    x = jnp.asarray(rng.normal(size=(1, 32, 32, 3)).astype(np.float32))
    short = jnp.asarray(HAWQV3_RESNET18["medium"], jnp.int32)   # 18 < 21
    with pytest.raises(ValueError, match="21 GEMM"):
        cnn.cnn_forward(params, x, layers, short, short)
    n = len(gemm_layers(layers))
    good = jnp.full((n,), 8, jnp.int32)
    with pytest.raises(ValueError, match="21 GEMM"):
        cnn.cnn_forward(params, x, layers, good, good[:-1])
    bad_rows = jnp.full((2, n + 1), 8, jnp.int32)
    with pytest.raises(ValueError, match="21 GEMM"):
        cnn.cnn_forward(params, x, layers, bad_rows, bad_rows)


def test_per_layer_bits_rejects_overlong():
    layers = _tiny_layers()
    assert per_layer_bits(layers, [8]) == [8, 8, 8]
    with pytest.raises(ValueError, match="exceeds"):
        per_layer_bits(layers, [8, 8, 8, 8])
