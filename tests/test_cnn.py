"""Paper CNNs in JAX: im2col-GEMM forward, bit-fluid vectors, shapes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.apsim.workloads import add, conv, fc, pool
from repro.models import cnn
from repro.models import common as cm

KEY = jax.random.PRNGKey(0)


def test_im2col_matches_conv():
    """conv-as-GEMM (the paper's §II.C mapping) == lax.conv."""
    x = jax.random.normal(KEY, (2, 8, 8, 3), jnp.float32)
    w = jax.random.normal(KEY, (3, 3, 3, 16), jnp.float32) * 0.1
    ref = jax.lax.conv_general_dilated(
        x, w, (1, 1), [(1, 1), (1, 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    cols = cnn.im2col(x, 3, 3, 1, 1)                 # (N,Ho,Wo,hk*wk*C)
    wm = jnp.moveaxis(w, 2, 0).reshape(3 * 3 * 3, 16)  # (hk*wk... match order
    # im2col emits (hk*wk, C) ordering; rebuild W accordingly
    wm = w.transpose(0, 1, 2, 3).reshape(9, 3, 16).transpose(0, 1, 2)
    wm = w.reshape(9, 3, 16).reshape(27, 16)
    got = jnp.einsum("nhwf,fo->nhwo", cols, wm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("net", ["alexnet", "resnet18"])
def test_cnn_forward_shapes(net):
    params, layers = cnn.init_cnn(net, KEY, image=32)
    x = jax.random.normal(KEY, (2, 32, 32, 3), jnp.float32)
    out = cnn.cnn_forward(params, x, layers)
    assert out.shape == (2, 1000)
    assert np.isfinite(np.asarray(out)).all()


def test_cnn_bits_change_output_monotonically():
    params, layers = cnn.init_cnn("resnet18", KEY, image=32)
    x = jax.random.normal(KEY, (2, 32, 32, 3), jnp.float32)
    ref = cnn.cnn_forward(params, x, layers)              # fp
    errs = []
    n = sum(1 for l in layers if l.kind in ("conv", "fc"))
    for b in (2, 4, 8):
        wv = jnp.full((n,), b, jnp.int32)
        out = cnn.cnn_forward(params, x, layers, wv, wv)
        errs.append(float(jnp.abs(out - ref).mean()))
    assert errs[0] > errs[1] > errs[2]


def test_grouped_conv_matches_lax_conv():
    """conv_gemm with groups > 1 implements TRUE grouped-conv semantics
    (channel-sliced groups == lax.conv with feature_group_count)."""
    l = conv("g", 8, 8, 3, 12, groups=4, relu=False)
    rng = np.random.default_rng(0)
    fk = l.hk * l.wk * (l.cin // l.groups)
    w = jnp.asarray(rng.normal(size=(fk, l.cout)).astype(np.float32) * 0.1)
    b = jnp.asarray(rng.normal(size=(l.cout,)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 8)).astype(np.float32))
    got = cnn.conv_gemm({"w": w.astype(cm.DTYPE), "b": b.astype(cm.DTYPE)},
                        x, l, 16, 16)
    w_hwio = w.reshape(l.hk, l.wk, l.cin // l.groups, l.cout)
    ref = jax.lax.conv_general_dilated(
        x, w_hwio, (1, 1), [(1, 1), (1, 1)], feature_group_count=l.groups,
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + b
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(ref),
                               rtol=0.1, atol=0.1)        # bf16 GEMM


@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int32])
def test_pool2d_integer_dtypes(dtype):
    """Serve-form int activations: maxpool must use iinfo, not finfo."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-128, 128, (2, 8, 8, 4)), dtype)
    mp = pool("p", "maxpool", 8, 4, 2, 2)
    got = cnn.pool2d(x, mp)
    want = cnn.pool2d(x.astype(jnp.float32), mp)
    assert got.dtype == dtype
    np.testing.assert_array_equal(np.asarray(got, np.int64),
                                  np.asarray(want, np.int64))
    ap = pool("p", "avgpool", 8, 4, 2, 2)
    assert cnn.pool2d(x, ap).dtype == dtype               # no crash


def test_residual_shape_mismatch_raises():
    """A broken block (no downsample projection across a stride-2 conv)
    must raise with the offending layer/shapes, not silently skip."""
    layers = [
        conv("c1", 8, 4, 3, 8),
        conv("c2", 8, 8, 3, 8, stride=2),
        add("a1", 4, 8),
        fc("fc", 8 * 4 * 4, 10, relu=False),
    ]
    params = {}
    keys = jax.random.split(KEY, len(layers))
    for i, l in enumerate(layers):
        if l.kind in ("conv", "fc"):
            k = l.hk * l.wk * l.cin if l.kind == "conv" else l.cin
            params[l.name] = cm.dense_init(keys[i], k, l.cout, bias=True)
    x = jax.random.normal(KEY, (2, 8, 8, 4), jnp.float32)
    with pytest.raises(ValueError, match="a1"):
        cnn.cnn_forward(params, x, layers)


def test_rescaled_resnets_keep_block_wiring():
    """_rescale must keep every residual add shape-consistent (shrunken
    kernels stay odd; pools end blocks) — the add path now raises on any
    wiring break, so a clean forward IS the assertion."""
    for net in ("resnet18", "resnet50"):
        for image in (24, 32):
            params, layers = cnn.init_cnn(net, KEY, image=image)
            x = jax.random.normal(KEY, (1, image, image, 3), jnp.float32)
            out = cnn.cnn_forward(params, x, layers)
            assert out.shape == (1, 1000)
            assert np.isfinite(np.asarray(out)).all()
