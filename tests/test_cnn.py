"""Paper CNNs in JAX: im2col-GEMM forward, bit-fluid vectors, shapes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import cnn

KEY = jax.random.PRNGKey(0)


def test_im2col_matches_conv():
    """conv-as-GEMM (the paper's §II.C mapping) == lax.conv."""
    x = jax.random.normal(KEY, (2, 8, 8, 3), jnp.float32)
    w = jax.random.normal(KEY, (3, 3, 3, 16), jnp.float32) * 0.1
    ref = jax.lax.conv_general_dilated(
        x, w, (1, 1), [(1, 1), (1, 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    cols = cnn.im2col(x, 3, 3, 1, 1)                 # (N,Ho,Wo,hk*wk*C)
    wm = jnp.moveaxis(w, 2, 0).reshape(3 * 3 * 3, 16)  # (hk*wk... match order
    # im2col emits (hk*wk, C) ordering; rebuild W accordingly
    wm = w.transpose(0, 1, 2, 3).reshape(9, 3, 16).transpose(0, 1, 2)
    wm = w.reshape(9, 3, 16).reshape(27, 16)
    got = jnp.einsum("nhwf,fo->nhwo", cols, wm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("net", ["alexnet", "resnet18"])
def test_cnn_forward_shapes(net):
    params, layers = cnn.init_cnn(net, KEY, image=32)
    x = jax.random.normal(KEY, (2, 32, 32, 3), jnp.float32)
    out = cnn.cnn_forward(params, x, layers)
    assert out.shape == (2, 1000)
    assert np.isfinite(np.asarray(out)).all()


def test_cnn_bits_change_output_monotonically():
    params, layers = cnn.init_cnn("resnet18", KEY, image=32)
    x = jax.random.normal(KEY, (2, 32, 32, 3), jnp.float32)
    ref = cnn.cnn_forward(params, x, layers)              # fp
    errs = []
    n = sum(1 for l in layers if l.kind in ("conv", "fc"))
    for b in (2, 4, 8):
        wv = jnp.full((n,), b, jnp.int32)
        out = cnn.cnn_forward(params, x, layers, wv, wv)
        errs.append(float(jnp.abs(out - ref).mean()))
    assert errs[0] > errs[1] > errs[2]
