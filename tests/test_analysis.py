"""Static-analysis suite tests (DESIGN.md §12).

Every lint rule is pinned twice: a fixture snippet that MUST fire (true
positive) and a near-miss that must NOT (documented false-positive
guard — e.g. ``float()`` on a host-side numpy value is fine).  The
retrace auditor gets signature snapshot tests plus a deliberate
host-conversion bug it must catch; the sharding checker and ledger
auditor get synthetic violations; and the repo itself must audit clean
— the same gate CI blocks on.
"""
import ast
import json
import textwrap

import numpy as np
import pytest

from repro.analysis import (ALL_PASSES, Baseline, common, ledger, lint,
                            registry, retrace, run_suite, sharding)

# ---------------------------------------------------------------------------
# lint fixtures
# ---------------------------------------------------------------------------

KERNEL = "src/repro/kernels/fixture.py"      # whole-module hot ("*")
ENGINE = "src/repro/serve/engine.py"         # hot only in registered scopes


def _mod(src: str, relpath: str = KERNEL) -> common.ParsedModule:
    src = textwrap.dedent(src)
    return common.ParsedModule(relpath=relpath, source=src,
                               tree=ast.parse(src),
                               lines=src.splitlines())


def _rules(src: str, relpath: str = KERNEL):
    return [f.rule for f in lint.lint_modules([_mod(src, relpath)])]


def test_hs101_item_on_device_fires():
    src = """
    import jax.numpy as jnp
    def f():
        x = jnp.zeros(3)
        return x.item()
    """
    assert "HS101" in _rules(src)


def test_hs101_item_on_host_numpy_does_not_fire():
    src = """
    import numpy as np
    def f():
        a = np.zeros(3)
        return a.item()
    """
    assert _rules(src) == []


def test_hs102_float_on_device_fires():
    src = """
    import jax.numpy as jnp
    def f(x):
        return float(jnp.sum(x))
    """
    assert "HS102" in _rules(src)


def test_hs102_float_on_host_numpy_does_not_fire():
    # the documented false-positive guard: host-side numpy math is free
    src = """
    import numpy as np
    def f():
        a = np.arange(4)
        return float(np.mean(a))
    """
    assert _rules(src) == []


def test_hs102_asarray_on_device_fires():
    src = """
    import numpy as np
    import jax.numpy as jnp
    def f(x):
        y = jnp.exp(x)
        return np.asarray(y)
    """
    assert "HS102" in _rules(src)


def test_hs102_device_get_clears_taint():
    # the sanctioned coalesced transfer: everything downstream is host
    src = """
    import jax
    import jax.numpy as jnp
    def f(x):
        y = jnp.exp(x)
        h = jax.device_get(y)
        return float(h[0])
    """
    assert _rules(src) == []


def test_hs102_pricer_on_device_args_fires():
    src = """
    import jax.numpy as jnp
    class ServeEngine:
        def _decode_tick(self, budgets):
            wv, av = self.controller.resolve(budgets)
            return self.price_bits(wv, av)
    """
    assert "HS102" in _rules(src, ENGINE)


def test_hs102_pricer_on_host_bits_does_not_fire():
    src = """
    class ServeEngine:
        def _decode_tick(self, budget):
            wv, av = self.host_bits(budget)
            return self.price_bits(wv, av)
    """
    assert _rules(src, ENGINE) == []


def test_hs102_only_fires_in_hot_scopes():
    # same sync, but in an unregistered method: setup-time syncs are fine
    src = """
    import jax.numpy as jnp
    class ServeEngine:
        def build_tables(self, budgets):
            wv, av = self.controller.resolve(budgets)
            return self.price_bits(wv, av)
    """
    assert _rules(src, ENGINE) == []


def test_hs103_branch_on_device_fires():
    src = """
    import jax.numpy as jnp
    def f(x):
        if jnp.any(x > 0):
            return 1
        return 0
    """
    assert "HS103" in _rules(src)


def test_hs103_branch_on_host_flag_does_not_fire():
    src = """
    def f(flag):
        if flag:
            return 1
        return 0
    """
    assert _rules(src) == []


def test_nd201_set_iteration_fires():
    src = """
    def f():
        out = []
        for k in {2, 1, 3}:
            out.append(k)
        return out
    """
    assert "ND201" in _rules(src)


def test_nd201_sorted_set_does_not_fire():
    src = """
    def f(vals):
        return [k for k in sorted({v for v in vals})]
    """
    assert _rules(src) == []


def test_rng301_unseeded_rng_fires():
    src = """
    import numpy as np
    def f():
        return np.random.default_rng().normal()
    """
    assert "RNG301" in _rules(src)


def test_rng301_seeded_rng_does_not_fire():
    src = """
    import numpy as np
    def f(seed):
        return np.random.default_rng(seed).normal()
    """
    assert _rules(src) == []


def test_stat401_static_bit_argnames_fires():
    src = """
    import jax
    def build():
        def fwd(x, wbits):
            return x * wbits
        return jax.jit(fwd, static_argnames=("wbits",))
    """
    assert "STAT401" in _rules(src)


def test_stat401_captured_bit_local_fires():
    src = """
    import jax
    def build(wv):
        def fwd(x):
            return x * wv
        return jax.jit(fwd)
    """
    assert "STAT401" in _rules(src)


def test_stat401_static_tiling_params_do_not_fire():
    # tiling/block-shape statics are the sanctioned use of static_argnames
    src = """
    import jax
    def build():
        def fwd(x, bm, bn):
            return x
        return jax.jit(fwd, static_argnames=("bm", "bn"))
    """
    assert _rules(src) == []


# ---------------------------------------------------------------------------
# baseline mechanics
# ---------------------------------------------------------------------------

def test_baseline_suppresses_and_goes_stale():
    f = common.Finding(rule="HS102", file="src/x.py", line=3, scope="f",
                       message="sync", snippet="float(y)")
    bl = Baseline([{"rule": "HS102", "file": "src/x.py",
                    "match": "float(y)", "why": "justified"}])
    fresh, suppressed = common.apply_baseline([f], bl)
    assert fresh == [] and len(suppressed) == 1 and bl.stale() == []
    unused = Baseline([{"rule": "HS101", "file": "gone.py",
                        "match": "x.item()", "why": "old"}])
    assert len(unused.stale()) == 1


def test_baseline_entry_requires_why():
    with pytest.raises(ValueError):
        Baseline([{"rule": "HS102", "file": "x.py", "match": "y"}])


def test_checked_in_baseline_is_small_and_justified():
    with open(common.BASELINE_PATH) as f:
        entries = json.load(f)["entries"]
    assert len(entries) <= 5
    assert all(e.get("why") for e in entries)


# ---------------------------------------------------------------------------
# retrace auditor
# ---------------------------------------------------------------------------

def test_signature_is_deterministic_and_shape_sensitive():
    import jax.numpy as jnp

    def fn(x):
        return jnp.sum(x * 2)

    a = jnp.zeros((4,))
    assert retrace.signature(fn, a) == retrace.signature(fn, a)
    assert retrace.signature(fn, a) != retrace.signature(
        fn, jnp.zeros((8,)))


def test_audit_entrypoint_flags_host_conversion_rt502():
    import jax.numpy as jnp

    def buggy(x):
        return jnp.asarray(int(np.asarray(x).max()))   # host round-trip

    rep = retrace.audit_entrypoint(
        "fix", "buggy", [("v0", lambda: (jnp.zeros((2,)),))], buggy)
    assert not rep.ok
    assert [f.rule for f in rep.findings()] == ["RT502"]


def test_audit_entrypoint_flags_multi_signature_rt501():
    import jax.numpy as jnp

    def fn(x):
        return x + 1

    rep = retrace.audit_entrypoint(
        "fix", "drift",
        [("v0", lambda: (jnp.zeros((2,)),)),
         ("v1", lambda: (jnp.zeros((3,)),))],    # shape leaks into jaxpr
        fn)
    assert len(rep.signatures) == 2
    assert [f.rule for f in rep.findings()] == ["RT501"]


def test_retrace_one_config_single_signature_snapshot():
    # the full ten-config × CNN sweep runs in the CI analysis job; one
    # dense config here pins the auditor end to end (6 entrypoints:
    # prefill_row, decode_scan, sample_first, extend_row, draft_scan,
    # verify_chunk)
    reports = retrace.audit_config("qwen3_4b")
    assert {r.entrypoint for r in reports} >= {
        "prefill_row", "decode_scan", "sample_first", "extend_row"}
    for r in reports:
        assert r.ok, (r.entrypoint, r.signatures, r.errors)
        assert len(r.signatures) == 1


# ---------------------------------------------------------------------------
# sharding checker
# ---------------------------------------------------------------------------

def test_check_resolved_catches_bad_arithmetic():
    from jax.sharding import PartitionSpec as P
    mesh = sharding.FakeMesh((("data", 2), ("model", 2)))
    # non-dividing dim
    bad = sharding.check_resolved(P("model"), (5,), mesh, "w")
    assert [f.rule for f in bad] == ["SH601"]
    # axis consumed twice
    dup = sharding.check_resolved(P("data", "data"), (4, 4), mesh, "w")
    assert any("two dims" in f.message for f in dup)
    # unknown axis
    unk = sharding.check_resolved(P("pod"), (4,), mesh, "w")
    assert any("not in mesh" in f.message for f in unk)
    # clean spec
    assert sharding.check_resolved(P("data", "model"), (4, 6), mesh,
                                   "w") == []


def test_dropped_axes_reports_fallback_but_not_singletons():
    mesh = sharding.FakeMesh((("data", 2), ("model", 2)))
    # 5 % 2 != 0: requested 'tp' placement silently replicated
    assert sharding.dropped_axes(mesh, ("tp", "dp"), (5, 4)) == [
        (0, "tp", 2)]
    # singleton dims replicate by design — no report
    assert sharding.dropped_axes(mesh, ("tp", "dp"), (1, 4)) == []


def test_sharding_one_config_clean():
    meshes = [sharding.FakeMesh((("data", 2), ("model", 2)))]
    findings, stats = sharding.audit_config_sharding("qwen3_4b", meshes)
    assert findings == [], [f.render() for f in findings]
    assert stats["sharded"] > 0


# ---------------------------------------------------------------------------
# ledger auditor
# ---------------------------------------------------------------------------

FAKE_ACCT = """
import dataclasses

@dataclasses.dataclass
class CostRecord:
    rid: int
    used: float = 0.0
    orphan: float = 0.0
    base: float = 0.0

    @property
    def derived(self):
        return self.base * 2

def aggregate(records):
    return {"used": sum(r.used for r in records),
            "derived": sum(r.derived for r in records)}
"""

FAKE_SERVE = """
def admit(record, CostRecord):
    record.used = 1.0
    record.orphan = 2.0
    r = CostRecord(rid=0, base=3.0)
    return r
"""


def test_ledger_transitive_consumption_and_orphan():
    acct = _mod(FAKE_ACCT, "src/repro/serve/accounting.py")
    fields, members = ledger.record_schema(acct)
    assert fields == {"rid", "used", "orphan", "base"}
    consumed = ledger.consumed_fields(acct, fields, members)
    assert consumed == {"used", "base"}       # base via derived property
    writes = ledger.written_fields(
        [_mod(FAKE_SERVE, "src/repro/serve/fake.py")], fields)
    assert set(writes) == {"used", "orphan", "rid", "base"}


def test_ledger_repo_is_clean():
    findings, detail = ledger.run_ledger()
    assert findings == [], [f.render() for f in findings]
    # every written field is consumed or deliberately waived
    waived = set(registry.LEDGER_WAIVED)
    assert detail["written"] <= (detail["consumed"] | waived)


# ---------------------------------------------------------------------------
# suite + CLI
# ---------------------------------------------------------------------------

def test_repo_lint_is_clean():
    assert lint.run_lint(common.repo_root()) == []


def test_run_suite_fast_passes_ok():
    res = run_suite(passes=("lint", "ledger"))
    assert res.ok
    d = res.to_dict()
    assert d["ok"] and set(d["passes"]) == {"lint", "ledger"}


def test_compare_refuses_baseline_update_on_analysis_failure(tmp_path):
    import importlib.util
    import os
    spec = importlib.util.spec_from_file_location(
        "bench_compare", os.path.join(common.repo_root(), "benchmarks",
                                      "compare.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    bench = tmp_path / "bench.json"
    bench.write_text(json.dumps({"suite": "smoke", "modules": {}}))
    base = tmp_path / "baseline.json"
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"ok": False, "passes": {"lint": {}}}))
    rc = mod.main(["--update-baseline", "--baseline", str(base),
                   "--current", str(bench),
                   "--analysis-status", str(bad)])
    assert rc == 2 and not base.exists()
    good = tmp_path / "good.json"
    good.write_text(json.dumps({"ok": True, "passes": {}}))
    rc = mod.main(["--update-baseline", "--baseline", str(base),
                   "--current", str(bench),
                   "--analysis-status", str(good)])
    assert rc == 0 and base.exists()


def test_cli_exit_codes(tmp_path):
    from repro.launch import analyze
    out = tmp_path / "status.json"
    assert analyze.main(["--lint", "--ledger",
                         "--json", str(out)]) == 0
    payload = json.loads(out.read_text())
    assert payload["ok"] is True
    assert set(ALL_PASSES) == {"lint", "retrace", "sharding", "ledger"}
