"""The workload-agnostic serving runtime (DESIGN.md §8): closed-loop
SLO convergence (deterministic, seed-stable), EDP-aware admission that
never starves, unified LM+CNN accounting, and one-pass matrix pricing
— zero-retrace across closed-loop config switches throughout."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.apsim import metrics as apm
from repro.apsim.workloads import conv, fc, pool
from repro.core import policy as pol
from repro.models import common as cm
from repro.models import lm
from repro.serve import accounting as acct
from repro.serve.cnn import CNNServeEngine
from repro.serve.engine import ServeEngine
from repro.serve.runtime import SlotTable, UNCONSTRAINED_BUDGET

KEY = jax.random.PRNGKey(7)

# full-LM engines are too slow through interpret-mode Pallas; the
# control-loop/scheduler/accounting logic is covered there by the pure
# and tiny-CNN tests below
INTERP = os.environ.get("REPRO_PALLAS", "").lower() == "interpret"
heavy = pytest.mark.skipif(INTERP, reason="pure + tiny-CNN tests cover the "
                                          "runtime under interpret Pallas")

PROMPT = [3, 1, 4, 1]
MAX_NEW = 4
UNITS = len(PROMPT) + MAX_NEW           # planned AP units per request


@pytest.fixture(scope="module")
def served():
    cfg = configs.get_smoke("qwen3_4b")
    params = lm.init_params(cfg, KEY)
    qparams = lm.quantize_params(params, cfg)
    return cfg, qparams, lm.n_bit_slots(cfg)


def _ctrl(n, preds=None):
    return pol.BudgetController(
        {"int4": pol.fixed(4), "int8": pol.fixed(8)},
        preds or {"int4": 1.0, "int8": 2.0}, n)


def _request_costs(served):
    """Actual modeled per-request AP energy of each config (J).

    Energy, not latency: AP latency is nearly flat across precisions
    (Table VII — bit-serial columns), so the energy family is the axis a
    system-level SLO can meaningfully constrain; the LM closed-loop
    tests run their FluidController there."""
    cfg, qparams, n = served
    eng = ServeEngine(cfg, qparams, max_len=64, controller=_ctrl(n))
    return (UNITS * eng.price_budget(1.0).energy_j,
            UNITS * eng.price_budget(10.0).energy_j)


# ---------------------------------------------------------------------------
# FluidController math (pure, runs everywhere)
# ---------------------------------------------------------------------------

def test_fluid_controller_headroom_charge_and_rollover():
    c = pol.FluidController({"int8": pol.fixed(8)}, {"int8": 1.0}, 4,
                            slo=8.0, window=4)
    assert c.headroom() == pytest.approx(2.0)
    assert c.admission_budget() == pytest.approx(2.0)
    assert c.admission_budget(1.5) == pytest.approx(1.5)    # request caps
    c.charge(5.0)                       # overspend: remaining 3.0 over 3
    assert c.headroom() == pytest.approx(1.0)
    c.charge(3.0)
    assert c.headroom() == pytest.approx(0.0)               # budget gone
    c.charge(1.0)
    c.charge(1.0)                       # 4th admission rolls the window
    assert c.served == 0
    assert c.spent == pytest.approx(2.0)                    # debt carries
    c2 = pol.FluidController.from_open_loop(_ctrl(4), slo=4.0, window=2)
    assert c2.budget_axis == "latency" and c2.n_layers == 4
    c2.charge(1.0)
    c2.charge(1.0)                      # underspend: credit expires
    assert c2.spent == 0.0 and c2.served == 0


def test_budget_controller_caches_tables():
    c = _ctrl(4)
    w1, a1 = c.stacked_tables()
    w2, a2 = c.stacked_tables()
    assert w1 is w2 and a1 is a2        # built once, reused per admission
    assert c.latency_array() is c.latency_array()
    assert c.order() == ["int4", "int8"]
    np.testing.assert_array_equal(np.asarray(c.latency_array()), [1.0, 2.0])


def test_slot_table_lifecycle():
    t = SlotTable(3, budget=(np.float64, 0.0), remaining=(np.int64, 0))
    assert not t.active.any()
    t.occupy(1, rid=7, budget=2.5, remaining=4)
    assert t.rid[1] == 7 and t["budget"][1] == 2.5
    assert t.active.tolist() == [False, True, False]
    t.release(1)
    assert not t.active.any() and t["budget"][1] == 0.0 and t.rid[1] == -1


# ---------------------------------------------------------------------------
# One-pass matrix pricing
# ---------------------------------------------------------------------------

def _tiny_layers():
    return [conv("c1", 8, 4, 3, 8), pool("p1", "maxpool", 8, 8, 2, 2),
            conv("c2", 4, 8, 3, 8), fc("fc", 8 * 4 * 4, 10, relu=False)]


def test_price_bit_matrix_matches_per_vector():
    gemms = apm.network_gemms(_tiny_layers())
    n = len(gemms)
    rng = np.random.default_rng(3)
    wmat = rng.choice([2, 4, 8, 16], size=(6, n))
    amat = rng.choice([4, 8], size=(6, n))
    wmat[3] = wmat[0]                   # duplicate row -> shared object
    amat[3] = amat[0]
    costs = apm.price_bit_matrix(gemms, wmat, amat)
    assert len(costs) == 6
    assert costs[3] is costs[0]
    for i, c in enumerate(costs):
        want = apm.price_bit_vector(gemms, wmat[i].tolist(),
                                    amat[i].tolist())
        assert c.per_layer_cycles == want.per_layer_cycles
        assert c.per_layer_energy_j == want.per_layer_energy_j


def test_price_bit_matrix_head_and_validation():
    gemms = ((64, 32),), ((32, 16),)
    wmat = np.asarray([[4, 8], [8, 2]])
    costs = apm.price_bit_matrix(gemms, wmat, wmat, head=(16, 100))
    for i, c in enumerate(costs):
        want = apm.price_bit_vector(gemms, wmat[i].tolist(),
                                    wmat[i].tolist(), head=(16, 100))
        assert c.per_layer_cycles == want.per_layer_cycles   # incl. head
        assert len(c.per_layer_cycles) == 3
    with pytest.raises(ValueError, match="bit slots"):
        apm.price_bit_matrix(gemms, wmat[:, :1], wmat[:, :1])
    with pytest.raises(ValueError, match="shape"):
        apm.price_bit_matrix(gemms, wmat, wmat[:1])


def test_pricer_cache_identity_across_vector_and_matrix():
    gemms = apm.network_gemms(_tiny_layers())
    n = len(gemms)
    p = acct.BitVectorPricer(gemms)
    v = np.full((n,), 8, np.int64)
    one = p.price(v, v)
    rows = p.price_matrix(np.stack([v, v // 2, v]), np.stack([v, v, v]))
    assert rows[0] is one and rows[2] is one
    assert rows[1] is p.price(v // 2, v)


# ---------------------------------------------------------------------------
# Closed-loop SLO convergence (the §V.B control loop)
# ---------------------------------------------------------------------------

def _run_stream(served, controller, n_req, budget_s=None, seed=0):
    cfg, qparams, _ = served
    eng = ServeEngine(cfg, qparams, max_len=64, controller=controller,
                      n_slots=2, prefill_len=8, decode_block=4, seed=seed)
    rids = [eng.submit(np.asarray(PROMPT), max_new_tokens=MAX_NEW,
                       budget_s=budget_s) for _ in range(n_req)]
    res = eng.run()
    return eng, [res[r] for r in rids]


def _energy_fluid(n, preds, *, slo, window):
    """A FluidController running an ENERGY SLO loop (see _request_costs);
    with slo=inf it degrades to open-loop behavior on the same axis —
    the apples-to-apples baseline."""
    return pol.FluidController(
        {"int4": pol.fixed(4), "int8": pol.fixed(8)}, dict(preds), n,
        budget_axis="energy", slo=slo, window=window)


@heavy
def test_closed_loop_converges_to_slo_and_undercuts_open_loop(served):
    """A stream of identical requests under a tight SLO: the closed loop
    ends within one request of the budget and serves strictly lower-bit
    configs than the open-loop controller under the same load — while
    both compile exactly once (config switches are pure data)."""
    _, _, n = served
    req4, req8 = _request_costs(served)
    assert req4 < 0.6 * req8            # energy discriminates precisions
    # optimistic predictions (half the actual cost): the open loop takes
    # them at face value and overshoots; the closed loop sees the charges
    preds = {"int4": req4 / 2, "int8": req8 / 2}
    n_req = 8
    slo = n_req * preds["int8"] * 1.2           # tight system budget

    open_ctrl = _energy_fluid(n, preds, slo=float("inf"), window=n_req)
    open_eng, open_recs = _run_stream(served, open_ctrl, n_req,
                                      budget_s=slo / n_req)
    fluid = _energy_fluid(n, preds, slo=slo, window=n_req)
    closed_eng, closed_recs = _run_stream(served, fluid, n_req)

    open_total = sum(r.ap_energy_j for r in open_recs)
    closed_total = sum(r.ap_energy_j for r in closed_recs)
    assert open_total > slo * 1.5               # open loop blows the SLO
    assert abs(closed_total - slo) <= req8      # converges within one req
    assert closed_total < open_total
    open_bits = [r.mean_wbits for r in open_recs]
    closed_bits = [r.mean_wbits for r in closed_recs]
    assert open_bits == [8.0] * n_req
    assert np.mean(closed_bits) < np.mean(open_bits)        # strictly lower
    assert closed_bits[0] == 8.0 and 4.0 in closed_bits     # adapted down
    # the ledger agrees with the per-request records (window rolled once)
    assert fluid.spent == pytest.approx(max(closed_total - slo, 0.0))
    # zero-retrace across every closed-loop switch
    for eng in (open_eng, closed_eng):
        assert eng.stats.prefill_traces == 1
        assert eng.stats.decode_traces == 1


@heavy
def test_closed_loop_refunds_early_termination(served):
    """Admissions are charged their PLANNED token count so headroom
    reacts immediately; a request that hits eos early must refund the
    unused share — the window ledger tracks real spend, not plans."""
    cfg, qparams, n = served
    req4, req8 = _request_costs(served)
    preds = {"int4": req4 / 2, "int8": req8 / 2}
    slo = 40 * req8                     # generous: config stays int8

    def engine(eos_id=None):
        fluid = _energy_fluid(n, preds, slo=slo, window=16)
        return ServeEngine(cfg, qparams, max_len=64, controller=fluid,
                           n_slots=1, prefill_len=8, decode_block=4,
                           eos_id=eos_id), fluid

    eng, fluid = engine()
    rid = eng.submit(np.asarray(PROMPT), max_new_tokens=12)
    rec = eng.run()[rid]
    # full-length request: planned == actual, nothing to reconcile
    assert fluid.spent == pytest.approx(rec.ap_energy_j)

    eng2, fluid2 = engine(eos_id=rec.tokens[1])     # stop within 2 tokens
    rid2 = eng2.submit(np.asarray(PROMPT), max_new_tokens=12)
    rec2 = eng2.run()[rid2]
    assert rec2.n_tokens < 12
    assert rec2.ap_units < rec2.planned_units
    assert fluid2.spent == pytest.approx(rec2.ap_energy_j)  # refunded


@heavy
def test_closed_loop_is_deterministic_and_seed_stable(served):
    _, _, n = served
    req4, req8 = _request_costs(served)
    preds = {"int4": req4 / 2, "int8": req8 / 2}
    slo = 6 * preds["int8"] * 1.2

    def trajectory(seed):
        fluid = _energy_fluid(n, preds, slo=slo, window=6)
        eng, recs = _run_stream(served, fluid, 6, seed=seed)
        return [r.mean_wbits for r in recs], [tuple(r.tokens) for r in recs]

    bits_a, toks_a = trajectory(0)
    bits_b, toks_b = trajectory(0)
    assert bits_a == bits_b and toks_a == toks_b    # deterministic replay
    bits_c, _ = trajectory(99)
    assert bits_a == bits_c                          # config path is
    assert len(set(bits_a)) > 1                      # seed-independent


# ---------------------------------------------------------------------------
# EDP-aware admission + anti-starvation
# ---------------------------------------------------------------------------

@heavy
def test_admission_prefers_cheapest_edp(served):
    """With one slot, queued requests admit cheapest-modeled-EDP first
    (int4 before int8), regardless of submission order."""
    cfg, qparams, n = served
    eng = ServeEngine(cfg, qparams, max_len=64, controller=_ctrl(n),
                      n_slots=1, prefill_len=8, decode_block=4)
    exp = eng.submit(np.asarray(PROMPT), max_new_tokens=4, budget_s=10.0)
    cheap = [eng.submit(np.asarray(PROMPT), max_new_tokens=4, budget_s=0.5)
             for _ in range(2)]
    done = []
    while len(done) < 3:
        done.extend(eng.step())
    assert done == cheap + [exp]
    assert eng.stats.prefill_traces == eng.stats.decode_traces == 1


@heavy
def test_scheduler_never_starves(served):
    """A continuous stream of cheaper arrivals cannot starve an expensive
    queued request: after `starvation_ticks` scheduler ticks it jumps
    the EDP ordering and is admitted FIFO."""
    cfg, qparams, n = served
    eng = ServeEngine(cfg, qparams, max_len=64, controller=_ctrl(n),
                      n_slots=1, prefill_len=8, decode_block=4)
    exp = eng.submit(np.asarray(PROMPT), max_new_tokens=4, budget_s=10.0)
    eng.submit(np.asarray(PROMPT), max_new_tokens=4, budget_s=0.5)
    finished_before = 0
    for tick in range(3 * eng.starvation_ticks):
        # keep the pressure on: one new cheap request every tick
        eng.submit(np.asarray(PROMPT), max_new_tokens=4, budget_s=0.5)
        done = eng.step()
        if exp in done:
            break
        finished_before += len(done)
    else:
        pytest.fail("expensive request starved by cheap arrivals")
    assert finished_before >= 1             # cheap traffic did cut ahead
    assert tick <= 2 * eng.starvation_ticks
    assert eng.requests[exp].mean_wbits == 8.0


# ---------------------------------------------------------------------------
# Unified accounting across LM + CNN workloads
# ---------------------------------------------------------------------------

def _tiny_cnn():
    layers = _tiny_layers()
    params = {}
    keys = jax.random.split(KEY, len(layers))
    for i, l in enumerate(layers):
        if l.kind == "conv":
            fk = l.hk * l.wk * (l.cin // l.groups)
            params[l.name] = cm.dense_init(keys[i], fk, l.cout, bias=True)
        elif l.kind == "fc":
            params[l.name] = cm.dense_init(keys[i], l.cin, l.cout, bias=True)
    return params, layers


def _cnn_edp_ctrl(layers, *, optimistic=1.0):
    gemms = apm.network_gemms(layers)
    n = len(gemms)
    edp4 = apm.price_bit_vector(gemms, [4] * n, [4] * n).edp
    edp8 = apm.price_bit_vector(gemms, [8] * n, [8] * n).edp
    return pol.BudgetController(
        {"int4": pol.fixed(4), "int8": pol.fixed(8)},
        {"int4": edp4 * optimistic, "int8": edp8 * optimistic},
        n, budget_axis="edp"), edp4, edp8


def test_cnn_closed_loop_adapts_within_batch(rng):
    """The CNN batch lifecycle charges the fluid controller image by
    image: under a tight EDP SLO the leading images serve at 8 bits and
    the tail degrades to 4 — in one compiled forward."""
    params, layers = _tiny_cnn()
    ctrl, edp4, edp8 = _cnn_edp_ctrl(layers, optimistic=0.5)
    B = 6
    slo = B * edp8 * 0.5 * 1.2
    fluid = pol.FluidController.from_open_loop(ctrl, slo=slo, window=B)
    eng = CNNServeEngine(params, layers, controller=fluid, max_batch=B)
    x = jnp.asarray(rng.normal(size=(B, 8, 8, 4)).astype(np.float32))
    logits, stats = eng.serve(x)                 # no per-image budgets: SLO
    assert np.isfinite(logits).all()
    bits = [s.mean_wbits for s in stats]
    assert bits[0] == 8.0 and bits[-1] == 4.0
    assert eng.stats.forward_traces == 1
    # open loop under the same per-image share never downgrades
    ctrl2, _, _ = _cnn_edp_ctrl(layers, optimistic=0.5)
    eng2 = CNNServeEngine(params, layers, controller=ctrl2, max_batch=B)
    _, stats2 = eng2.serve(x, slo / B)
    assert [s.mean_wbits for s in stats2] == [8.0] * B
    assert np.mean(bits) < 8.0


@heavy
def test_mixed_lm_cnn_accounting_sums(served):
    """One ledger for both workloads: engine-level stats totals equal
    the sums over per-request records, and records from an LM engine and
    a CNN engine aggregate together."""
    cfg, qparams, n = served
    lm_eng = ServeEngine(cfg, qparams, max_len=64, controller=_ctrl(n),
                         n_slots=2, prefill_len=8, decode_block=4)
    for b in (10.0, 0.5, 10.0):
        lm_eng.submit(np.asarray(PROMPT), max_new_tokens=3, budget_s=b)
    lm_recs = list(lm_eng.run().values())

    params, layers = _tiny_cnn()
    ctrl, _, _ = _cnn_edp_ctrl(layers)
    cnn_eng = CNNServeEngine(params, layers, controller=ctrl, max_batch=4)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(3, 8, 8, 4)).astype(np.float32))
    _, cnn_recs = cnn_eng.serve(x, [0.0, 1e30, 1e30])

    # engine totals == per-record sums, per workload
    assert lm_eng.stats.tokens == sum(r.n_tokens for r in lm_recs)
    assert lm_eng.stats.admitted == lm_eng.stats.completed == len(lm_recs)
    assert cnn_eng.stats.images == cnn_eng.stats.admitted == len(cnn_recs)
    assert cnn_eng.requests == {r.rid: r for r in cnn_recs}

    # and the two ledgers merge: aggregate is a plain sum over records
    agg = acct.aggregate(lm_recs + cnn_recs)
    assert agg["requests"] == agg["completed"] == 6
    assert agg["ap_units"] == sum(r.processed_tokens for r in lm_recs) + 3
    for key, sel in (("ap_latency_s", "ap_latency_s"),
                     ("ap_energy_j", "ap_energy_j"), ("edp", "edp")):
        want = (sum(getattr(r, sel) for r in lm_recs)
                + sum(getattr(r, sel) for r in cnn_recs))
        assert agg[key] == pytest.approx(want, rel=1e-12)
        assert agg[key] > 0
    a_lm = acct.aggregate(lm_recs)
    a_cnn = acct.aggregate(cnn_recs)
    assert agg["ap_energy_j"] == pytest.approx(
        a_lm["ap_energy_j"] + a_cnn["ap_energy_j"], rel=1e-12)


def test_serve_engine_rejects_non_latency_controller(served):
    cfg, qparams, n = served
    ctrl = pol.BudgetController(
        {"int8": pol.fixed(8)}, {"int8": 1.0}, n, budget_axis="edp")
    with pytest.raises(ValueError, match="latency"):
        ServeEngine(cfg, qparams, max_len=64, controller=ctrl)


def test_whole_batch_api_rejects_fluid_controller(served):
    """generate() has no admissions to charge — running a FluidController
    through it would silently be open-loop, so it must refuse."""
    cfg, qparams, n = served
    fluid = pol.FluidController({"int8": pol.fixed(8)}, {"int8": 1.0}, n,
                                slo=1.0, window=4)
    eng = ServeEngine(cfg, qparams, max_len=64, controller=fluid)
    with pytest.raises(ValueError, match="open-loop"):
        eng.generate({"tokens": np.zeros((1, 4), np.int32)}, 2)


def test_unconstrained_budget_fits_everything():
    c = _ctrl(4)
    w, _ = c.resolve(jnp.asarray(UNCONSTRAINED_BUDGET, jnp.float32))
    assert int(w[0]) == 8
