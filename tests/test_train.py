"""Training substrate: loop, optimizer variants, checkpoint round-trip
(+ resharding restore = elastic scaling), watchdog, data determinism."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.data.pipeline import SyntheticLM, make_batch
from repro.models import lm
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.loop import TrainConfig, make_train_step
from repro.train.watchdog import StragglerWatchdog

KEY = jax.random.PRNGKey(0)
CFG = configs.get_smoke("qwen3_4b")


def _setup(ocfg=None, n_accum=1):
    params = lm.init_params(CFG, KEY)
    tcfg = TrainConfig(optimizer=ocfg or AdamWConfig(lr=1e-2),
                       n_accum=n_accum)
    step_fn, _ = make_train_step(tcfg, CFG)
    opt = adamw_init(params, tcfg.optimizer)
    return params, opt, jax.jit(step_fn)


def test_loss_decreases():
    params, opt, step = _setup()
    losses = []
    for i in range(8):
        batch = make_batch(0, i, 4, 33, CFG.vocab_size)
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses


def test_grad_accum_matches_single_batch():
    """n_accum microbatches == one big batch (same grads, fp32 accum)."""
    params = lm.init_params(CFG, KEY)
    batch = make_batch(0, 0, 4, 33, CFG.vocab_size)
    outs = {}
    for n in (1, 4):
        tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-2), n_accum=n)
        step_fn, _ = make_train_step(tcfg, CFG)
        opt = adamw_init(params, tcfg.optimizer)
        p2, _, m = jax.jit(step_fn)(params, opt, batch)
        outs[n] = (p2, float(m["loss"]))
    assert abs(outs[1][1] - outs[4][1]) < 2e-2
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.1, atol=2e-2)


@pytest.mark.parametrize("m_dtype,v_mode", [("bfloat16", "full"),
                                            ("int8", "factored")])
def test_optimizer_memory_variants_converge(m_dtype, v_mode):
    ocfg = AdamWConfig(lr=1e-2, m_dtype=m_dtype, v_mode=v_mode)
    params, opt, step = _setup(ocfg)
    losses = []
    for i in range(8):
        batch = make_batch(0, i, 4, 33, CFG.vocab_size)
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_int8_moment_state_is_small():
    params = lm.init_params(CFG, KEY)
    full = adamw_init(params, AdamWConfig(m_dtype="float32", v_mode="full"))
    small = adamw_init(params, AdamWConfig(m_dtype="int8", v_mode="factored"))

    def nbytes(t):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))

    assert nbytes(small["m"]) < 0.30 * nbytes(full["m"])
    assert nbytes(small["v"]) < 0.10 * nbytes(full["v"])


def test_checkpoint_roundtrip(tmp_path):
    params, opt, step = _setup()
    batch = make_batch(0, 0, 4, 33, CFG.vocab_size)
    params, opt, _ = step(params, opt, batch)
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"params": params, "opt": opt})
    assert latest_step(d) == 1
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          {"params": params, "opt": opt})
    restored, step_no = restore_checkpoint(d, target)
    assert step_no == 1
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomic_overwrite(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"x": jnp.ones((4,))})
    save_checkpoint(d, 2, {"x": jnp.ones((4,)) * 2})
    restored, s = restore_checkpoint(
        d, {"x": jax.ShapeDtypeStruct((4,), jnp.float32)})
    assert s == 2 and float(restored["x"][0]) == 2.0
    # stale temp dirs never linger
    assert not [p for p in os.listdir(d) if p.startswith(".tmp_")]


def test_data_pipeline_pure_and_deterministic():
    b1 = make_batch(7, 42, 4, 64, 1000)
    b2 = make_batch(7, 42, 4, 64, 1000)
    b3 = make_batch(7, 43, 4, 64, 1000)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_data_prefetch_iterator():
    it = SyntheticLM(seed=1, batch=2, seq_len=16, vocab=100, start_step=5)
    s1, b1 = next(it)
    s2, b2 = next(it)
    assert (s1, s2) == (5, 6)
    np.testing.assert_array_equal(
        np.asarray(b1["tokens"]),
        np.asarray(make_batch(1, 5, 2, 16, 100)["tokens"]))
    it.close()


def test_watchdog_flags_straggler():
    events = []
    wd = StragglerWatchdog(z_threshold=2.0, warmup=3,
                           on_straggler=lambda s, dt: events.append(s))
    import time as _t
    for step in range(12):
        wd.start()
        if step == 10:
            _t.sleep(0.05)
        wd.stop(step)
    assert any(e["step"] == 10 for e in wd.events)
    assert events == [10]
