"""Per-arch smoke tests: reduced config, one fwd/train step on CPU,
shape + no-NaN asserts (the brief's required per-arch smoke)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import lm

B, S = 2, 32
KEY = jax.random.PRNGKey(0)


def _batch(cfg):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["prefix"] = jax.random.normal(
            KEY, (B, cfg.n_prefix_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (B, S // cfg.frames_ratio, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def smoke_state():
    return {}


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = configs.get_smoke(arch)
    params = lm.init_params(cfg, KEY)
    n = lm.n_bit_slots(cfg)
    wvec = avec = jnp.full((n,), 8, jnp.int32)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.jit(jax.value_and_grad(
        lambda p: lm.train_loss(p, batch, cfg, wvec, avec),
        has_aux=True))(params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_serve_smoke(arch):
    cfg = configs.get_smoke(arch)
    params = lm.init_params(cfg, KEY)
    qparams = lm.quantize_params(params, cfg)
    n = lm.n_bit_slots(cfg)
    wvec = avec = jnp.full((n,), 8, jnp.int32)
    batch = _batch(cfg)
    cache = lm.empty_cache(cfg, B, 64)
    logits, cache = jax.jit(
        lambda q, b, c: lm.prefill(q, b, cfg, wvec, avec, c))(
        qparams, batch, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    t0 = S + (cfg.n_prefix_tokens if cfg.family == "vlm" else 0)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits2, _ = jax.jit(
        lambda q, tk, t, c: lm.decode_step(q, tk, t, c, cfg, wvec, avec))(
        qparams, tok, jnp.asarray(t0), cache)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["qwen3_4b", "kimi_k2_1t_a32b",
                                  "mamba2_1_3b"])
def test_bit_vector_is_runtime_data(arch):
    """One jitted program serves different precision configs (bit fluidity:
    no recompilation when the per-layer bit vector changes)."""
    cfg = configs.get_smoke(arch)
    params = lm.init_params(cfg, KEY)
    n = lm.n_bit_slots(cfg)
    batch = _batch(cfg)

    calls = {"n": 0}

    def loss(p, wv, av):
        calls["n"] += 1
        return lm.train_loss(p, batch, cfg, wv, av)[0]

    jitted = jax.jit(loss)
    l8 = jitted(params, jnp.full((n,), 8, jnp.int32),
                jnp.full((n,), 8, jnp.int32))
    l4 = jitted(params, jnp.full((n,), 4, jnp.int32),
                jnp.full((n,), 8, jnp.int32))
    lmix = jitted(params,
                  jnp.where(jnp.arange(n) % 2 == 0, 4, 8).astype(jnp.int32),
                  jnp.full((n,), 8, jnp.int32))
    assert calls["n"] == 1                      # traced exactly once
    assert len({float(l8), float(l4), float(lmix)}) == 3  # bits matter


def test_decode_matches_prefill_qwen3():
    """Teacher-forced prefill logits == step-by-step decode logits."""
    cfg = configs.get_smoke("qwen3_4b")
    params = lm.init_params(cfg, KEY)
    n = lm.n_bit_slots(cfg)
    wvec = avec = jnp.full((n,), 8, jnp.int32)
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)

    cache = lm.empty_cache(cfg, 1, 16)
    logits_p, cache_p = lm.prefill(params, {"tokens": toks}, cfg, wvec, avec,
                                   cache)

    cache = lm.empty_cache(cfg, 1, 16)
    for t in range(8):
        logits_d, cache = lm.decode_step(params, toks[:, t:t + 1],
                                         jnp.asarray(t), cache, cfg,
                                         wvec, avec)
    # per-tensor dynamic activation scales differ between the batched
    # prefill and single-token decode, so compare distributions, not raw
    # logits: total variation of the next-token softmax
    pp = jax.nn.softmax(logits_p[:, -1].astype(jnp.float32), -1)
    pd = jax.nn.softmax(logits_d[:, -1].astype(jnp.float32), -1)
    tv = float(jnp.abs(pp - pd).sum(-1).max()) * 0.5
    assert tv < 0.12, tv


def test_sliding_window_ring_buffer():
    """starcoder2 smoke: decode beyond the window keeps a bounded cache and
    still produces finite logits (ring-buffer slot reuse)."""
    cfg = configs.get_smoke("starcoder2_15b")     # window = 8
    params = lm.init_params(cfg, KEY)
    n = lm.n_bit_slots(cfg)
    wvec = avec = jnp.full((n,), 8, jnp.int32)
    cache = lm.empty_cache(cfg, 1, 64)
    assert cache["k"].shape[2] == cfg.sliding_window   # bounded!
    tok = jnp.zeros((1, 1), jnp.int32)
    for t in range(20):                                # > 2x window
        logits, cache = lm.decode_step(params, tok, jnp.asarray(t), cache,
                                       cfg, wvec, avec)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
