"""Trace-driven traffic harness (DESIGN.md §9): seeded generator
determinism, pattern shapes, the repetition mix, tick-windowed
FluidController rollover, timestamped arrivals through the runtime
(``submit_at``/``run``), honest unserved accounting, lock-step replay
through real engines, and the closed-vs-open spike claim at test size."""
import dataclasses
import os

import numpy as np
import jax
import pytest

from repro import configs
from repro.apsim import metrics as apm
from repro.apsim.workloads import conv, fc, pool
from repro.core import policy as pol
from repro.models import common as cm
from repro.models import lm
from repro.serve import traffic as tf
from repro.serve.accounting import CostRecord
from repro.serve.cnn import CNNServeEngine
from repro.serve.engine import ServeEngine
from repro.serve.runtime import ServeRuntime

KEY = jax.random.PRNGKey(7)

# full-LM engines are too slow through interpret-mode Pallas; generator,
# controller, stub-runtime, and tiny-CNN replay tests cover the harness
# there (same split as tests/test_serve_runtime.py)
INTERP = os.environ.get("REPRO_PALLAS", "").lower() == "interpret"
heavy = pytest.mark.skipif(INTERP, reason="pure + tiny-CNN tests cover the "
                                          "harness under interpret Pallas")


# ---------------------------------------------------------------------------
# Generator: patterns, seeding, repetition, payloads (pure)
# ---------------------------------------------------------------------------

def test_pattern_rate_shapes():
    flat = tf.pattern_rates("poisson", 16, 2.0)
    assert flat.shape == (16,) and (flat == 2.0).all()
    spike = tf.pattern_rates("spike", 30, 1.0, burst_mag=10.0, burst_at=10,
                             burst_len=4)
    assert (spike[10:14] == 10.0).all()
    assert (np.delete(spike, np.s_[10:14]) == 1.0).all()
    di = tf.pattern_rates("diurnal", 64, 2.0, depth=0.5)
    assert di.argmax() == 16 and di.argmin() == 48      # period/4, 3/4
    assert di.max() == pytest.approx(3.0)
    assert di.min() == pytest.approx(1.0)               # rate*(1-depth)
    assert di[0] == pytest.approx(2.0)
    with pytest.raises(ValueError, match="pattern"):
        tf.pattern_rates("sawtooth", 8, 1.0)


def test_synth_trace_is_seed_deterministic():
    kw = dict(ticks=32, rate=1.5, repetition=0.3, cnn_frac=0.4,
              budget=[1.0, 2.0], slo_edp=0.5)
    a = tf.synth_trace("spike", seed=5, **kw)
    b = tf.synth_trace("spike", seed=5, **kw)
    assert a == b                       # bit-for-bit identical schedule
    c = tf.synth_trace("spike", seed=6, **kw)
    assert a.requests != c.requests
    assert a.n_requests > 0
    assert all(0 <= r.t < 32 for r in a.requests)
    assert a.counts().sum() == a.n_requests
    assert sorted(sum(a.arrivals_by_tick().values(), []),
                  key=lambda r: (r.t, r.key)) \
        == sorted(a.requests, key=lambda r: (r.t, r.key))
    # budget cycles over arrivals; slo metadata rides on every request
    assert {r.budget for r in a.requests} == {1.0, 2.0}
    assert all(r.slo_edp == 0.5 for r in a.requests)


def test_realized_arrivals_follow_the_pattern():
    """Arrival counts per window track the configured pattern: the
    burst window of a spike trace and the peak phase of a diurnal trace
    dominate their quiet counterparts (deterministic given the seed)."""
    sp = tf.synth_trace("spike", ticks=40, rate=1.0, seed=7,
                        burst_mag=10.0, burst_at=10, burst_len=5)
    c = sp.counts()
    assert c[10:15].mean() > 4 * max(c[:10].mean(), c[15:].mean())
    di = tf.synth_trace("diurnal", ticks=80, rate=2.0, seed=7, depth=0.9)
    cd = di.counts()
    phase = [cd[i * 20:(i + 1) * 20].sum() for i in range(4)]
    assert phase[1] > phase[3]          # peak quarter >> trough quarter


def test_replay_metrics_are_deterministic_across_runs():
    """Same seed → identical schedule AND identical collector metrics
    across two independent replays (fresh engines each time) — the
    property the regression gate's tight tolerances stand on."""
    def run_once():
        trace = tf.synth_trace("spike", ticks=10, rate=1.0, seed=6,
                               cnn_frac=1.0, cnn_archs=("tiny",),
                               burst_mag=6.0, burst_len=2)
        eng, _, _ = _cnn_engine(max_batch=4, fluid_slo_x8=0.6 *
                                trace.n_requests, window_ticks=0)
        res = tf.TraceReplayer(trace, None, cnn_engines={"tiny": eng},
                               use_budgets=False, image_hw=8).replay()
        return res.report(window=4)

    assert run_once() == run_once()


def test_repetition_mix_controls_unique_vs_repeated():
    fresh = tf.synth_trace("poisson", ticks=64, rate=2.0, seed=1,
                           repetition=0.0)
    keys = [r.key for r in fresh.requests]
    assert len(set(keys)) == len(keys)  # 0.0 -> every key unique
    hot = tf.synth_trace("poisson", ticks=64, rate=2.0, seed=1,
                         repetition=0.8)
    hot_keys = [r.key for r in hot.requests]
    assert len(set(hot_keys)) < 0.5 * len(hot_keys)     # heavy reuse
    counts = np.unique(hot_keys, return_counts=True)[1]
    assert counts.max() >= 3            # rich-get-richer skew
    with pytest.raises(ValueError, match="repetition"):
        tf.synth_trace("poisson", repetition=1.0)


def test_workload_mix_and_payload_determinism():
    mixed = tf.synth_trace("poisson", ticks=48, rate=2.0, seed=9,
                           cnn_frac=0.5, prompt_len=8, max_new_tokens=4)
    kinds = {r.workload for r in mixed.requests}
    assert kinds == {"lm", "cnn"}
    lm_req = next(r for r in mixed.requests if r.workload == "lm")
    cnn_req = next(r for r in mixed.requests if r.workload == "cnn")
    assert lm_req.prompt_len == 8 and cnn_req.prompt_len == 0
    toks = tf.payload_tokens(mixed, lm_req, vocab_size=128)
    assert (toks == tf.payload_tokens(mixed, lm_req, 128)).all()
    assert 4 <= len(toks) <= 8 and toks.dtype == np.int32
    assert (toks < 128).all() and (toks >= 0).all()
    img = tf.payload_image(mixed, cnn_req, (4, 4, 3))
    assert (img == tf.payload_image(mixed, cnn_req, (4, 4, 3))).all()
    assert img.shape == (4, 4, 3) and img.dtype == np.float32
    # repeated keys replay byte-identical payloads across requests
    twin = tf.TraceRequest(t=99, workload="lm", arch=lm_req.arch,
                           key=lm_req.key, prompt_len=8, max_new_tokens=4)
    assert (tf.payload_tokens(mixed, twin, 128) == toks).all()


# ---------------------------------------------------------------------------
# Tick-windowed FluidController rollover (pure)
# ---------------------------------------------------------------------------

def _tick_fluid(slo, window_ticks):
    return pol.FluidController({"int8": pol.fixed(8)}, {"int8": 1.0}, 4,
                               slo=slo, window_ticks=window_ticks)


def test_fluid_tick_window_headroom_splits_over_queue_depth():
    c = _tick_fluid(slo=6.0, window_ticks=3)
    assert c.headroom(pending=1) == pytest.approx(6.0)
    assert c.headroom(pending=3) == pytest.approx(2.0)  # burst: deep queue
    c.charge(4.0)
    assert c.headroom(pending=2) == pytest.approx(1.0)
    assert c.admission_budget(0.5, pending=2) == pytest.approx(0.5)


def test_fluid_tick_window_rolls_on_ticks_not_admissions():
    c = _tick_fluid(slo=6.0, window_ticks=3)
    for _ in range(10):                 # admissions never roll a tick window
        c.charge(0.4)
    assert c.served == 10 and c.spent == pytest.approx(4.0)
    c.tick()
    c.tick()
    assert c.spent == pytest.approx(4.0)
    c.tick()                            # 3rd tick rolls: credit expires
    assert c.spent == 0.0 and c.served == 0 and c.ticks == 0
    c.charge(10.0)                      # overspend: debt carries the roll
    for _ in range(3):
        c.tick()
    assert c.spent == pytest.approx(4.0)
    # tick() is a no-op on admission-count windows
    c2 = pol.FluidController({"int8": pol.fixed(8)}, {"int8": 1.0}, 4,
                             slo=6.0, window=2)
    c2.tick()
    assert c2.ticks == 0 and c2.spent == 0.0


# ---------------------------------------------------------------------------
# Timestamped arrivals + unserved accounting through the runtime (pure)
# ---------------------------------------------------------------------------

class _StubEngine(ServeRuntime):
    """Minimal workload adapter: one admission slot, each admitted
    request finishes ``service_ticks`` ticks later — just enough to
    exercise the shared queue/clock/arrival machinery."""

    def __init__(self, service_ticks=0, starvation_ticks=8):
        super().__init__(pol.BudgetController(
            {"int8": pol.fixed(8)}, {"int8": 1.0}, 2), 2,
            starvation_ticks=starvation_ticks)
        self.service_ticks = service_ticks
        self._active = {}               # rid -> ticks of service left

    def submit(self):
        rid = self.next_rid()
        return self.new_record(CostRecord(rid=rid, budget_s=0.0), rid, None)

    def _has_active(self):
        return bool(self._active)

    def _active_count(self):
        return len(self._active)

    def step(self):
        done = []
        for rid in list(self._active):
            if self._active[rid] <= 0:
                del self._active[rid]
                self.finish_record(rid)
                done.append(rid)
            else:
                self._active[rid] -= 1
        self.age_queue()
        if not self._active:
            rid = self.next_admission()
            if rid is not None:
                self.requests[rid].admitted_tick = self._tick
                self.stats.admitted += 1
                self._active[rid] = self.service_ticks
        return done


def test_submit_at_enqueues_by_timestamp():
    eng = _StubEngine()
    rids = []
    for t in (0, 2, 2, 5):
        eng.submit_at(t, lambda: rids.append(eng.submit()))
    res = eng.run()
    assert len(res) == 4 and all(r.done for r in res.values())
    assert [res[r].submitted_tick for r in rids] == [0, 2, 2, 5]
    assert all(r.finished_tick >= r.submitted_tick for r in res.values())
    assert all(r.latency_ticks >= 0 for r in res.values())
    assert eng.stats.unserved == 0
    assert eng.stats.ticks == len(eng.stats.queue_depth) > 5
    with pytest.raises(ValueError, match="past"):
        eng.submit_at(0, lambda: None)  # clock has moved on


def test_run_exhaustion_reports_unserved():
    eng = _StubEngine(service_ticks=3)  # 1 slot, slow service
    for _ in range(4):
        eng.submit()
    eng.submit_at(9, eng.submit)        # an arrival past the cutoff
    res = eng.run(max_ticks=5, on_exhaust="report")
    assert eng.stats.unserved == 4      # 3 pending/active + 1 never enqueued
    assert sum(1 for r in res.values() if not r.done) == 3
    eng2 = _StubEngine(service_ticks=3)
    for _ in range(4):
        eng2.submit()
    with pytest.raises(RuntimeError, match="unserved|pending"):
        eng2.run(max_ticks=5)
    with pytest.raises(ValueError, match="on_exhaust"):
        _StubEngine().run(on_exhaust="ignore")


# ---------------------------------------------------------------------------
# Lock-step replay through a real (tiny-CNN) engine — interpret-safe
# ---------------------------------------------------------------------------

def _tiny_cnn():
    layers = [conv("c1", 8, 4, 3, 8), pool("p1", "maxpool", 8, 8, 2, 2),
              fc("fc", 8 * 4 * 4, 10, relu=False)]
    params = {}
    keys = jax.random.split(KEY, len(layers))
    for i, l in enumerate(layers):
        if l.kind == "conv":
            fk = l.hk * l.wk * (l.cin // l.groups)
            params[l.name] = cm.dense_init(keys[i], fk, l.cout, bias=True)
        elif l.kind == "fc":
            params[l.name] = cm.dense_init(keys[i], l.cin, l.cout, bias=True)
    return params, layers


def _cnn_engine(max_batch=4, fluid_slo_x8=None, window_ticks=0):
    """Tiny-CNN engine; ``fluid_slo_x8`` (in int8-request units) makes
    the controller a closed tick-windowed loop."""
    params, layers = _tiny_cnn()
    gemms = apm.network_gemms(layers)
    n = len(gemms)
    edp4 = apm.price_bit_vector(gemms, [4] * n, [4] * n).edp
    edp8 = apm.price_bit_vector(gemms, [8] * n, [8] * n).edp
    preds = {"int4": edp4, "int8": edp8}
    cfgs = {"int4": pol.fixed(4), "int8": pol.fixed(8)}
    if fluid_slo_x8 is None:
        ctrl = pol.BudgetController(cfgs, preds, n, budget_axis="edp")
    else:
        ctrl = pol.FluidController(cfgs, preds, n, budget_axis="edp",
                                   slo=fluid_slo_x8 * edp8, window=64,
                                   window_ticks=window_ticks)
    return CNNServeEngine(params, layers, controller=ctrl,
                          max_batch=max_batch), edp4, edp8


def test_cnn_replay_serves_whole_trace_one_trace():
    trace = tf.synth_trace("poisson", ticks=8, rate=1.5, seed=4,
                           cnn_frac=1.0, cnn_archs=("tiny",))
    assert trace.n_requests > 0
    eng, _, edp8 = _cnn_engine(max_batch=4)
    res = tf.TraceReplayer(trace, None, cnn_engines={"tiny": eng},
                           image_hw=8, use_budgets=True).replay()
    rep = res.report(window=4)
    assert rep["requests"] == rep["completed"] == trace.n_requests
    assert rep["unserved"] == 0
    assert eng.stats.forward_traces == 1        # zero-retrace replay
    assert eng.stats.images == trace.n_requests
    assert rep["slo_attainment"] is None        # trace carried no SLO
    assert rep["mean_wbits"] == 8.0             # unconstrained -> int8
    assert rep["total_edp_js"] == pytest.approx(trace.n_requests * edp8)
    assert len(rep["queue_depth"]["series"]) == res.ticks
    assert len(rep["mean_wbits_per_window"]) == (res.ticks + 3) // 4
    assert sum(rep["arrivals_per_window"]) == trace.n_requests


def test_cnn_replay_spill_queues_to_next_tick_and_cutoff_reports():
    reqs = tuple(tf.TraceRequest(t=0, workload="cnn", arch="tiny", key=k)
                 for k in range(5))
    trace = tf.Trace(pattern="manual", seed=0, ticks=3,
                     rates=(5.0, 0.0, 0.0), requests=reqs)
    eng, _, _ = _cnn_engine(max_batch=2)
    res = tf.TraceReplayer(trace, None, cnn_engines={"tiny": eng},
                           image_hw=8).replay()
    assert res.unserved == 0
    by_rid = {e["rid"]: e for e in res.entries}
    # 2 at tick 0, 2 spill to tick 1, 1 to tick 2: latency == serve delay
    assert sorted(e["latency_ticks"] for e in by_rid.values()) \
        == [0, 0, 1, 1, 2]
    assert res.queue_depth[0] == 3              # spill after tick-0 batch
    # a cutoff mid-spill reports the leftovers instead of dropping them
    eng2, _, _ = _cnn_engine(max_batch=2)
    res2 = tf.TraceReplayer(trace, None, cnn_engines={"tiny": eng2},
                            image_hw=8, max_ticks=2).replay()
    assert res2.unserved == 1
    assert eng2.stats.unserved == 1
    assert sum(1 for e in res2.entries if not e["done"]) == 1
    assert len(res2.entries) == 5               # nothing silently dropped


def test_cnn_replay_tick_windowed_fluid_flexes_with_load():
    """Burst ticks resolve cheaper bits than idle ticks under a rate
    SLO: the tick-windowed loop reacts to queue depth, then relaxes."""
    reqs = tuple(tf.TraceRequest(t=t, workload="cnn", arch="tiny", key=i)
                 for i, t in enumerate([0] * 6 + [8]))
    trace = tf.Trace(pattern="manual", seed=0, ticks=9,
                     rates=(6.0,) + (0.0,) * 7 + (1.0,),
                     requests=reqs)
    eng, _, _ = _cnn_engine(max_batch=6, fluid_slo_x8=2.0, window_ticks=2)
    res = tf.TraceReplayer(trace, None, cnn_engines={"tiny": eng},
                           use_budgets=False, image_hw=8).replay()
    burst = [e["mean_wbits"] for e in res.entries if e["submitted_tick"] == 0]
    idle = [e["mean_wbits"] for e in res.entries if e["submitted_tick"] == 8]
    assert np.mean(burst) < 8.0                 # degraded under pressure
    assert idle == [8.0]                        # window rolled: relaxed
    assert eng.stats.forward_traces == 1


def test_replayer_validates_arch_coverage():
    trace = tf.synth_trace("poisson", ticks=8, rate=1.0, seed=0,
                           lm_archs=("qwen3_4b",))
    with pytest.raises(ValueError, match="LM archs"):
        tf.TraceReplayer(trace, {})
    cnn_trace = tf.synth_trace("poisson", ticks=8, rate=1.0, seed=0,
                               cnn_frac=1.0, cnn_archs=("resnet18",))
    with pytest.raises(ValueError, match="CNN"):
        tf.TraceReplayer(cnn_trace, None, cnn_engines={})


# ---------------------------------------------------------------------------
# LM replay: equivalence + the spike claim at test size (heavy)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    cfg = configs.get_smoke("qwen3_4b")
    params = lm.init_params(cfg, KEY)
    qparams = lm.quantize_params(params, cfg)
    return cfg, qparams, lm.n_bit_slots(cfg)


def _lm_engine(served, controller=None):
    cfg, qparams, n = served
    ctrl = controller or pol.BudgetController(
        {"int4": pol.fixed(4), "int8": pol.fixed(8)},
        {"int4": 1.0, "int8": 2.0}, n)
    return ServeEngine(cfg, qparams, max_len=64, controller=ctrl,
                       n_slots=2, prefill_len=8, decode_block=4)


@heavy
def test_replay_matches_upfront_submission_when_all_arrive_at_zero(served):
    """rate->inf degeneracy: a trace whose arrivals all land on tick 0
    must reproduce the classic submit-everything-then-run() results
    exactly — same bits, same tokens, same tick latencies."""
    cfg = served[0]
    reqs = tuple(tf.TraceRequest(t=0, workload="lm", arch="q", key=k,
                                 prompt_len=6, max_new_tokens=4)
                 for k in range(4))
    trace = tf.Trace(pattern="manual", seed=11, ticks=1, rates=(4.0,),
                     requests=reqs)
    eng_r = _lm_engine(served)
    res_r = tf.TraceReplayer(trace, {"q": eng_r}).replay()
    eng_u = _lm_engine(served)
    rids = [eng_u.submit(tf.payload_tokens(trace, r, cfg.vocab_size),
                         max_new_tokens=r.max_new_tokens)
            for r in reqs]
    recs_u = eng_u.run()
    want = [(recs_u[r].mean_wbits, tuple(recs_u[r].tokens),
             recs_u[r].latency_ticks) for r in rids]
    got = [(eng_r.requests[e["rid"]].mean_wbits,
            tuple(eng_r.requests[e["rid"]].tokens), e["latency_ticks"])
           for e in sorted(res_r.entries, key=lambda e: e["rid"])]
    assert got == want
    assert eng_r.stats.prefill_traces == eng_u.stats.prefill_traces == 1


@heavy
def test_spike_closed_loop_attains_at_least_open_loop(served):
    """The benchmark claim at test size: through a burst, the closed
    loop holds the whole-stream EDP SLO and attains per-request SLOs at
    least as often as the open loop that trusts its (optimistic)
    table."""
    cfg, qparams, n = served
    from repro.serve import predict_table
    cfgs = {"int4": pol.fixed(4), "int8": pol.fixed(8)}
    actual = predict_table(lm.layer_gemm_dims(cfg), cfgs, axis="edp",
                           units=10, head=lm.head_gemm_dims(cfg))
    preds = {k: v / 2 for k, v in actual.items()}
    reqs = tuple(tf.TraceRequest(t=t, workload="lm", arch="q", key=i,
                                 prompt_len=6, max_new_tokens=4)
                 for i, t in enumerate([0, 2, 4, 4, 4, 4, 6]))
    slo = len(reqs) * preds["int8"] * 1.2
    reqs = tuple(dataclasses.replace(r, slo_edp=slo / len(reqs),
                                     budget=preds["int8"] * 1.2)
                 for r in reqs)
    trace = tf.Trace(pattern="manual", seed=2, ticks=7,
                     rates=(1.0,) * 7, requests=reqs)

    def fluid(s):
        return pol.FluidController(cfgs, dict(preds), n, budget_axis="edp",
                                   slo=s, window=len(reqs))

    open_eng = _lm_engine(served, fluid(float("inf")))
    open_rep = tf.TraceReplayer(trace, {"q": open_eng}).replay().report()
    closed_eng = _lm_engine(served, fluid(slo))
    closed_rep = tf.TraceReplayer(trace, {"q": closed_eng},
                                  use_budgets=False).replay().report()
    assert closed_rep["total_edp_js"] <= 1.1 * slo
    assert open_rep["total_edp_js"] > closed_rep["total_edp_js"]
    assert closed_rep["slo_attainment"] >= open_rep["slo_attainment"]
    assert closed_rep["mean_wbits"] < open_rep["mean_wbits"]
    assert closed_rep["unserved"] == open_rep["unserved"] == 0
    for eng in (open_eng, closed_eng):
        assert eng.stats.prefill_traces == eng.stats.decode_traces == 1
