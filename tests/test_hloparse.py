"""hloparse: trip-count-aware walker vs cost_analysis ground truth."""
import jax
import jax.numpy as jnp

from repro.launch import hloparse

W = jax.ShapeDtypeStruct((256, 256), jnp.float32)
X = jax.ShapeDtypeStruct((64, 256), jnp.float32)
FLOPS_ONE = 2 * 64 * 256 * 256


def _scan(n):
    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), ()
        c, _ = jax.lax.scan(body, x, None, length=n)
        return c
    return f


def test_cost_analysis_undercounts_loops():
    """The reason the walker exists: XLA counts while bodies once."""
    c = jax.jit(_scan(10)).lower(W, X).compile()
    # body x1 (+ a couple of loop-counter flops), NOT x10
    assert hloparse.cost_analysis_dict(c)["flops"] < 1.01 * FLOPS_ONE


def test_walker_multiplies_trip_count():
    for n in (1, 4, 10):
        c = jax.jit(_scan(n)).lower(W, X).compile()
        s = hloparse.summarize(c.as_text())
        assert s["flops"] == n * FLOPS_ONE, (n, s["flops"])


def test_walker_matches_unrolled_reference():
    def unrolled(w, x):
        c = x
        for _ in range(6):
            c = jnp.tanh(c @ w)
        return c
    comp = jax.jit(unrolled).lower(W, X).compile()
    s = hloparse.summarize(comp.as_text())
    ca = hloparse.cost_analysis_dict(comp)
    assert s["flops"] == ca["flops"] == 6 * FLOPS_ONE
    assert abs(s["bytes"] - ca["bytes accessed"]) / ca["bytes accessed"] < 0.15


def test_nested_scan():
    def f(w, x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, ()
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, ()
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c
    comp = jax.jit(f).lower(W, X).compile()
    s = hloparse.summarize(comp.as_text())
    assert s["flops"] == 15 * FLOPS_ONE


def test_int8_dot_bucketed():
    def f(x, w):
        return jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.int32)
    xi = jax.ShapeDtypeStruct((128, 128), jnp.int8)
    wi = jax.ShapeDtypeStruct((128, 128), jnp.int8)
    comp = jax.jit(f).lower(xi, wi).compile()
    s = hloparse.summarize(comp.as_text())
    assert s["flops_int8"] == 2 * 128 * 128 * 128
    assert s["flops"] == 0
