"""Family-specific correctness: SSD chunked==stepwise parity, MoE routing."""
import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import lm, mamba2, moe

KEY = jax.random.PRNGKey(2)


def test_ssd_chunked_matches_stepwise():
    """The chunked dual form == the naive recurrence, to fp tolerance."""
    cfg = configs.get_smoke("mamba2_1_3b")
    B, S = 2, 32
    d_inner, H, N, P = mamba2.dims(cfg)
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    xh = jax.random.normal(k1, (B, S, H, P), jnp.float32)
    Bm = jax.random.normal(k2, (B, S, N), jnp.float32) * 0.5
    Cm = jax.random.normal(k3, (B, S, N), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(k4, (B, S, H), jnp.float32))
    a = -jnp.exp(jnp.zeros((H,)))
    h0 = jnp.zeros((B, H, P, N), jnp.float32)

    y_chunk, h_chunk = mamba2.ssd_chunked(xh, Bm, Cm, dt, a, h0,
                                          chunk=cfg.ssm_chunk)

    # naive stepwise recurrence
    h = h0
    ys = []
    for t in range(S):
        dA = jnp.exp(a[None, :] * dt[:, t])                      # (B,H)
        h = h * dA[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], xh[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t], h))
    y_step = jnp.stack(ys, axis=1)

    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h),
                               rtol=2e-3, atol=2e-3)


def test_mamba_prefill_then_decode_continuity():
    """Decode continuing from prefill state == full-sequence forward."""
    cfg = configs.get_smoke("mamba2_1_3b")
    params = lm.init_params(cfg, KEY)
    n = lm.n_bit_slots(cfg)
    w = jnp.full((n,), 16, jnp.int32)       # fp for exactness
    toks = jax.random.randint(KEY, (1, 17), 0, cfg.vocab_size)

    # full forward on 17 tokens: logits for last position
    cache = lm.empty_cache(cfg, 1, 32)
    lp, cache = lm.prefill(params, {"tokens": toks[:, :16]}, cfg, w, w, cache)
    ld, _ = lm.decode_step(params, toks[:, 16:17], jnp.asarray(16), cache,
                           cfg, w, w)
    cache2 = lm.empty_cache(cfg, 1, 32)
    lfull, _ = lm.prefill(params, {"tokens": toks}, cfg, w, w, cache2)
    np.testing.assert_allclose(np.asarray(ld[:, -1], np.float32),
                               np.asarray(lfull[:, -1], np.float32),
                               rtol=0.05, atol=0.08)


def test_moe_capacity_and_combination():
    cfg = configs.get_smoke("moonshot_v1_16b_a3b")
    p = moe.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.bfloat16)
    y, aux = moe.apply_moe(p, x, cfg, 8, 8)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert float(aux) > 0.0                  # load-balance loss defined


def test_moe_expert_selection_matters():
    """Routing is input-dependent: different tokens -> different outputs
    (catches dispatch/combine indexing bugs that average experts).
    Generous capacity so token parity isn't confounded by drops."""
    cfg = configs.get_smoke("moonshot_v1_16b_a3b").with_(capacity_factor=8.0)
    p = moe.moe_init(KEY, cfg)
    x1 = jax.random.normal(jax.random.PRNGKey(3), (1, 8, cfg.d_model))
    x2 = x1.at[:, 0].mul(-1.0)
    y1, _ = moe.apply_moe(p, x1.astype(jnp.bfloat16), cfg, 8, 8)
    y2, _ = moe.apply_moe(p, x2.astype(jnp.bfloat16), cfg, 8, 8)
    # token 0 changed -> its output changes materially; token 5 unchanged
    # -> near-identical (small drift allowed: the per-expert dynamic
    # activation scale covers the whole dispatch buffer, so a token
    # entering/leaving an expert nudges its neighbours' quantization)
    d0 = np.abs(np.asarray(y1[0, 0] - y2[0, 0], np.float32)).max()
    d5 = np.abs(np.asarray(y1[0, 5] - y2[0, 5], np.float32)).max()
    assert d0 > 0.5, d0
    assert d5 < 0.1 * d0, (d5, d0)


def test_moe_per_expert_bits():
    """Per-expert precision (paper's per-layer ≈ per-expert granularity)."""
    cfg = configs.get_smoke("moonshot_v1_16b_a3b")
    p = moe.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 16, cfg.d_model), jnp.bfloat16)
    bits_hi = jnp.full((cfg.n_experts,), 8, jnp.int32)
    bits_mix = jnp.where(jnp.arange(cfg.n_experts) % 2 == 0, 2, 8
                         ).astype(jnp.int32)
    y_hi, _ = moe.apply_moe(p, x, cfg, bits_hi, 8)
    y_mix, _ = moe.apply_moe(p, x, cfg, bits_mix, 8)
    assert np.abs(np.asarray(y_hi - y_mix, np.float32)).max() > 1e-4


def test_hybrid_shared_block_weight_sharing():
    """zamba2: the shared attention block is ONE weight set; LoRA gives
    per-site specialization."""
    cfg = configs.get_smoke("zamba2_2_7b")
    params = lm.init_params(cfg, KEY)
    shared = params["layers"]["shared"]["attn"]["wq"]["w"]
    lora = params["layers"]["lora"]
    assert shared.ndim == 2                          # not stacked per site
    assert lora["wq"]["a"].shape[0] == cfg.n_layers // cfg.attn_every
