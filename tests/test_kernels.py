"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bitfluid as bf
from repro.kernels import ops, ref


@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 384, 128),
                                   (64, 256, 512)])
def test_bitplane_matmul_sweep(rng, bits, shape):
    M, K, N = shape
    x = rng.integers(-127, 128, (M, K)).astype(np.int8)
    # b-bit two's complement range [-2^(b-1), 2^(b-1)-1] (1 bit = {-1, 0})
    w = rng.integers(-(2 ** (bits - 1)), 2 ** (bits - 1), (K, N)
                     ).astype(np.int8)
    exact = x.astype(np.int64) @ w.astype(np.int64)
    out = ops.bitplane_matmul(jnp.asarray(x), jnp.asarray(w),
                              n_planes=bits, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), exact)
    out_ref = ref.bitplane_matmul_ref(jnp.asarray(x), jnp.asarray(w), bits)
    np.testing.assert_array_equal(np.asarray(out_ref), exact)


def test_bitplane_matmul_nonaligned(rng):
    """ops.py pads non-128-multiples."""
    x = rng.integers(-10, 10, (100, 200)).astype(np.int8)
    w = rng.integers(-10, 10, (200, 72)).astype(np.int8)
    out = ops.bitplane_matmul(jnp.asarray(x), jnp.asarray(w),
                              n_planes=8, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), x.astype(np.int64) @ w.astype(np.int64))


@pytest.mark.parametrize("act", ["none", "relu", "silu", "gelu"])
@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_sweep(rng, act, out_dtype):
    M, K, N = 128, 256, 128
    x = rng.integers(-127, 128, (M, K)).astype(np.int8)
    w = rng.integers(-127, 128, (K, N)).astype(np.int8)
    s = rng.uniform(0.001, 0.05, (1, N)).astype(np.float32)
    b = rng.normal(size=(1, N)).astype(np.float32)
    got = ops.quant_matmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(s),
                           jnp.asarray(b), act=act, out_dtype=out_dtype,
                           interpret=True)
    want = ref.quant_matmul_ref(jnp.asarray(x), jnp.asarray(w),
                                jnp.asarray(s), jnp.asarray(b), act,
                                out_dtype)
    assert got.dtype == out_dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if out_dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2)


@pytest.mark.parametrize("shape", [(128, 128, 256), (128, 256, 512)])
def test_int4_matmul_sweep(rng, shape):
    M, K, N = shape
    x = rng.integers(-127, 128, (M, K)).astype(np.int8)
    q4 = rng.integers(-8, 8, (K, N)).astype(np.int8)
    packed = bf.pack_int4_halves(jnp.asarray(q4))
    s = rng.uniform(0.001, 0.05, (1, N)).astype(np.float32)
    got = ops.int4_matmul(jnp.asarray(x), packed, jnp.asarray(s),
                          interpret=True)
    want = (x.astype(np.int64) @ q4.astype(np.int64)).astype(np.float32) * s
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


def test_fluid_linear_precision_cost_scaling(rng):
    """The plane kernel's cost scales with wbits (probe: result exactness
    at stored precision, approximation below it)."""
    x = rng.normal(size=(32, 128)).astype(np.float32)
    w = (rng.normal(size=(128, 64)) * 0.05).astype(np.float32)
    ws = bf.symmetric_scale(jnp.asarray(w), 8, axis=0)
    qw = bf.quantize(jnp.asarray(w), ws, 8)
    y8 = ops.fluid_linear(jnp.asarray(x), qw, ws, wbits=8, interpret=True)
    exact = np.asarray(bf.dequantize(qw, ws))
    np.testing.assert_allclose(
        np.asarray(y8), np.asarray(
            bf.fake_quant(jnp.asarray(x), 8) @ jnp.asarray(exact)),
        rtol=5e-2, atol=5e-2)


@pytest.mark.skipif(ops.use_pallas(),
                    reason="dispatch forced to Pallas (REPRO_PALLAS)")
def test_dispatch_uses_ref_on_cpu(rng):
    """Off-TPU without interpret, ops route through XLA ref (same math)."""
    x = rng.integers(-10, 10, (64, 128)).astype(np.int8)
    w = rng.integers(-10, 10, (128, 64)).astype(np.int8)
    assert not ops.use_pallas()
    out = ops.bitplane_matmul(jnp.asarray(x), jnp.asarray(w), n_planes=8)
    np.testing.assert_array_equal(
        np.asarray(out), x.astype(np.int64) @ w.astype(np.int64))


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
@pytest.mark.parametrize("shape", [(4, 128, 64), (2, 256, 128), (3, 100, 48)])
def test_flash_attention_sweep(rng, causal, window, shape):
    BH, S, hd = shape
    q = jnp.asarray(rng.normal(size=(BH, S, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(BH, S, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(BH, S, hd)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_cross_lengths(rng):
    """Sq != Sk (cross-attention shape) with padded key masking."""
    q = jnp.asarray(rng.normal(size=(2, 64, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 200, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 200, 32)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
