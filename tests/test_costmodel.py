"""apsim cost model vs the paper's literal Table I expressions."""
import pytest

from repro.apsim import costmodel as cm
from repro.apsim.energy import RERAM, SRAM


@pytest.mark.parametrize("M", [2, 4, 8, 16])
@pytest.mark.parametrize("mode", ["1d", "2d", "2dseg"])
def test_add_matches_table1(M, mode):
    got = cm.rt_add(M, 64, mode).cycles(SRAM)
    assert got == cm.table1_cycles("add", mode, M=M)


@pytest.mark.parametrize("M", [2, 4, 8])
def test_multiply_matches_table1(M):
    got = cm.rt_multiply(M, M, 64, "2d").cycles(SRAM)
    assert got == cm.table1_cycles("multiply", "2d", M=M)


@pytest.mark.parametrize("mode", ["1d", "2d", "2dseg"])
@pytest.mark.parametrize("L", [16, 64, 256])
def test_reduce_matches_table1(mode, L):
    got = cm.rt_reduce(8, L, mode).cycles(SRAM)
    want = cm.table1_cycles("reduce", mode, M=8, L=L)
    assert abs(got - want) <= 1        # word-seq read rounding


@pytest.mark.parametrize("mode", ["1d", "2d", "2dseg"])
def test_matmat_matches_table1(mode):
    i, j, u, M = 4, 16, 8, 8
    got = cm.rt_matmat(i, j, u, M, M, mode).cycles(SRAM)
    want = cm.table1_cycles("matmat", mode, M=M, i=i, j=j, u=u)
    assert abs(got - want) / want < 0.02


@pytest.mark.parametrize("M", [4, 8])
def test_relu_matches_table1(M):
    got = cm.rt_relu(M, 64, "2d").cycles(SRAM)
    assert got == cm.table1_cycles("relu", "2d", M=M)


@pytest.mark.parametrize("mode", ["1d", "2d", "2dseg"])
def test_pools_match_table1(mode):
    M, S, K = 8, 4, 16
    got = cm.rt_maxpool(M, S, K, mode).cycles(SRAM)
    want = cm.table1_cycles("maxpool", mode, M=M, S=S, K=K)
    assert abs(got - want) / want < 0.25
    got = cm.rt_avgpool(M, S, K, mode).cycles(SRAM)
    want = cm.table1_cycles("avgpool", mode, M=M, S=S, K=K)
    assert abs(got - want) / want < 0.25


def test_mixed_precision_multiply_cost():
    """rt_multiply walks Mw x Ma bit pairs: 4b x 8b costs ~half of 8x8."""
    c88 = cm.rt_multiply(8, 8, 64, "2d").cycles(SRAM)
    c48 = cm.rt_multiply(4, 8, 64, "2d").cycles(SRAM)
    assert 0.4 < c48 / c88 < 0.62


def test_complexity_ordering():
    """2D-with-segmentation is the fastest flavour for reductions
    (Table II: O(log L) vs O(L) / O(M log L + L))."""
    for L in (64, 256):
        c1 = cm.rt_reduce(8, L, "1d").cycles(SRAM)
        c2 = cm.rt_reduce(8, L, "2d").cycles(SRAM)
        c3 = cm.rt_reduce(8, L, "2dseg").cycles(SRAM)
        assert c3 < c2 and c3 < c1


def test_reram_slower_and_hungrier():
    c = cm.rt_multiply(8, 8, 4096, "2d")
    assert c.cycles(RERAM) > c.cycles(SRAM)
    assert c.energy_j(RERAM) > c.energy_j(SRAM)


def test_extension_technologies():
    """Paper §V.A: the framework extends to PCM/FeFET cells trivially —
    energy ordering FeFET < SRAM-write-scale < ReRAM < PCM on writes,
    and every technology runs the full end-to-end simulator."""
    from repro.apsim.energy import FEFET, PCM, TECHNOLOGIES
    from repro.apsim.mapper import LR_CONFIG, simulate_network
    from repro.apsim.workloads import alexnet
    assert PCM.e_write_j > RERAM.e_write_j > FEFET.e_write_j
    layers = alexnet()
    es = {name: simulate_network(layers, LR_CONFIG, t, bits=8).energy_j
          for name, t in TECHNOLOGIES.items()}
    assert all(e > 0 for e in es.values())
    assert es["pcm"] > es["reram"] > es["fefet"]
