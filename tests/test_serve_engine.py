"""Continuous-batching serve stack: per-request bit fluidity, slot pool
reuse, scan-fused decode, per-request sampling — all zero-retrace."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core import policy as pol
from repro.models import lm
from repro.serve.engine import ServeEngine

KEY = jax.random.PRNGKey(2)


@pytest.fixture(scope="module")
def served():
    """One quantized smoke model + controller shared by the module."""
    cfg = configs.get_smoke("qwen3_4b")
    params = lm.init_params(cfg, KEY)
    qparams = lm.quantize_params(params, cfg)
    n = lm.n_bit_slots(cfg)
    ctrl = pol.BudgetController(
        {"int4": pol.fixed(4),
         "mixed": pol.per_layer([8, 4], name="mixed"),
         "int8": pol.fixed(8)},
        {"int4": 0.5, "mixed": 0.75, "int8": 1.0}, n)
    return cfg, qparams, ctrl


def _engine(served, **kw):
    cfg, qparams, ctrl = served
    kw.setdefault("max_len", 64)
    return ServeEngine(cfg, qparams, controller=ctrl, **kw)


def test_per_request_bits_are_row_exact(served):
    """A mixed-budget batch serves each row EXACTLY as a uniform-budget
    batch would serve it: per-request precision decouples rows."""
    eng = _engine(served)
    batch = {"tokens": jax.random.randint(KEY, (2, 8), 0,
                                          served[0].vocab_size)}
    eng.set_budget(jnp.asarray([10.0, 0.4]))        # int8 row, int4 row
    mixed = np.asarray(eng.generate(batch, steps=4))
    eng.set_budget(jnp.asarray([10.0, 10.0]))
    all8 = np.asarray(eng.generate(batch, steps=4))
    eng.set_budget(jnp.asarray([0.4, 0.4]))
    all4 = np.asarray(eng.generate(batch, steps=4))
    np.testing.assert_array_equal(mixed[0], all8[0])
    np.testing.assert_array_equal(mixed[1], all4[1])
    assert not (mixed[1] == all8[1]).all()          # bits really differ
    assert eng.stats.prefill_traces == 1
    assert eng.stats.decode_traces == 1


def test_continuous_batching_slot_reuse_zero_retrace(served):
    """More requests than slots: the scheduler streams them through freed
    slots; prefill/decode each compile exactly once for the whole run."""
    eng = _engine(served, n_slots=2, prefill_len=8, decode_block=4)
    rng = np.random.default_rng(0)
    budgets = [10.0, 0.4, 0.75, 10.0, 0.4]
    rids = [eng.submit(rng.integers(0, served[0].vocab_size, (4 + i % 4,)),
                       max_new_tokens=5, budget_s=b)
            for i, b in enumerate(budgets)]
    res = eng.run()
    assert sorted(res) == sorted(rids)
    slots = set()
    for rid, b in zip(rids, budgets):
        st = res[rid]
        assert st.done and st.n_tokens == 5
        assert all(0 <= t < served[0].vocab_size for t in st.tokens)
        slots.add(st.slot)
        wv, _ = eng.controller.resolve(jnp.asarray(b, jnp.float32))
        assert st.mean_wbits == pytest.approx(
            float(jnp.mean(wv.astype(jnp.float32))))
    assert slots == {0, 1}                          # both slots recycled
    assert eng.stats.admitted == eng.stats.completed == 5
    assert eng.stats.prefill_traces == 1            # (1, prefill_len) once
    assert eng.stats.decode_traces == 1             # one fused block once
    assert eng.pool.free_slots == 2                 # pool fully reclaimed


def test_continuous_matches_whole_batch_greedy(served):
    """A request served through the slot pool produces the same greedy
    continuation as the standalone ragged prefill + decode path."""
    cfg, qparams, ctrl = served
    prompt = np.asarray([5, 9, 2, 7, 3], np.int64)
    eng = _engine(served, n_slots=2, prefill_len=8, decode_block=4)
    rid = eng.submit(prompt, max_new_tokens=4, budget_s=10.0)
    got = eng.run()[rid].tokens

    n = lm.n_bit_slots(cfg)
    wv = jnp.full((n,), 8, jnp.int32)
    toks = np.zeros((1, 8), np.int32)
    toks[0, :5] = prompt
    cache = lm.empty_cache(cfg, 1, 64)
    logits, cache = lm.prefill(qparams, {"tokens": jnp.asarray(toks)}, cfg,
                               wv, wv, cache, lengths=jnp.asarray([5]))
    want = [int(jnp.argmax(logits[0, -1]))]
    t = 5
    for _ in range(3):
        tok = jnp.asarray([[want[-1]]], jnp.int32)
        logits, cache = lm.decode_step(qparams, tok, jnp.asarray([t]),
                                       cache, cfg, wv, wv)
        want.append(int(jnp.argmax(logits[0, -1])))
        t += 1
    assert got == want


def test_per_request_sampling_params(served):
    """Greedy rows are deterministic; temperature/top-k rows sample within
    the top-k support — in the same fused decode program."""
    cfg = served[0]
    eng = _engine(served, n_slots=3, prefill_len=8, decode_block=4, seed=3)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, (6,))
    r_greedy = eng.submit(prompt, max_new_tokens=8, budget_s=10.0)
    r_hot = eng.submit(prompt, max_new_tokens=8, budget_s=10.0,
                       temperature=1.5, top_k=4)
    res = eng.run()

    eng2 = _engine(served, n_slots=3, prefill_len=8, decode_block=4, seed=99)
    r2 = eng2.submit(prompt, max_new_tokens=8, budget_s=10.0)
    res2 = eng2.run()
    # greedy is seed-independent
    assert res[r_greedy].tokens == res2[r2].tokens
    # sampled row differs from greedy (V=512, k=4, T=1.5: overwhelmingly)
    assert res[r_hot].tokens != res[r_greedy].tokens


def test_eos_stops_early(served):
    eng = _engine(served, n_slots=1, prefill_len=8, decode_block=4)
    prompt = np.asarray([1, 2, 3], np.int64)
    rid = eng.submit(prompt, max_new_tokens=16, budget_s=10.0)
    full = eng.run()[rid].tokens
    eos = full[2]                       # force an eos hit at position 2
    eng2 = _engine(served, n_slots=1, prefill_len=8, decode_block=4)
    eng2.eos_id = eos
    rid2 = eng2.submit(prompt, max_new_tokens=16, budget_s=10.0)
    got = eng2.run()[rid2].tokens
    assert got == full[:3]
    assert eng2.pool.free_slots == 1


def test_cache_pool_alloc_free_cycle(served):
    cfg = served[0]
    pool = lm.CachePool(cfg, n_slots=2, max_len=16)
    a, b = pool.alloc(), pool.alloc()
    assert {a, b} == {0, 1} and pool.alloc() is None
    row = lm.empty_cache(cfg, 1, 16)
    pool.write_row(row, a, 7)
    assert pool.lengths[a] == 7
    pool.free(a)
    assert pool.free_slots == 1 and pool.lengths[a] == 0
    with pytest.raises(ValueError):
        pool.free(a)
    assert pool.alloc() == a            # LIFO recycle


def test_sliding_window_ragged_prefill_keeps_real_tokens():
    """A short prompt padded past the ring capacity must keep its real
    tokens (per-row gather), not the uniform padding tail: the continuous
    path matches the exact-length whole-batch path token-for-token."""
    cfg = configs.get_smoke("starcoder2_15b")       # sliding_window == 8
    params = lm.init_params(cfg, KEY)
    qparams = lm.quantize_params(params, cfg)
    n = lm.n_bit_slots(cfg)
    ctrl = pol.BudgetController({"int8": pol.fixed(8)}, {"int8": 1.0}, n)
    prompt = np.asarray([3, 1, 4, 1], np.int64)

    # prefill_len=16 > ring capacity Sc=8: the padded buffer overflows
    eng = ServeEngine(cfg, qparams, max_len=64, controller=ctrl,
                      n_slots=1, prefill_len=16, decode_block=4)
    rid = eng.submit(prompt, max_new_tokens=8, budget_s=10.0)
    eng.step()                                      # still in flight
    kpos0 = np.asarray(eng.pool.cache["kpos"][0, 0])
    assert (kpos0 < 2 ** 30).sum() >= 4             # real tokens survived
    got = eng.run()[rid].tokens[:4]

    eng2 = ServeEngine(cfg, qparams, max_len=64, controller=ctrl)
    want = np.asarray(eng2.generate(
        {"tokens": jnp.asarray(prompt[None], jnp.int32)}, steps=4))[0]
    assert got == want.tolist()


def test_vlm_continuous_serving():
    """vlm requests stream through the pool with their prefix embeddings."""
    cfg = configs.get_smoke("internvl2_1b")
    params = lm.init_params(cfg, KEY)
    qparams = lm.quantize_params(params, cfg)
    n = lm.n_bit_slots(cfg)
    ctrl = pol.BudgetController(
        {"int4": pol.fixed(4), "int8": pol.fixed(8)},
        {"int4": 0.5, "int8": 1.0}, n)
    eng = ServeEngine(cfg, qparams, max_len=64, controller=ctrl,
                      n_slots=2, prefill_len=8, decode_block=4)
    with pytest.raises(ValueError):                 # prefix is required
        eng.submit(np.asarray([1, 2, 3]), budget_s=1.0)
    rng = np.random.default_rng(0)
    rids = [eng.submit(rng.integers(0, cfg.vocab_size, (5,)),
                       max_new_tokens=5, budget_s=b,
                       prefix=rng.standard_normal(
                           (cfg.n_prefix_tokens, cfg.d_model)))
            for b in (2.0, 0.4, 2.0)]
    res = eng.run()
    for rid in rids:
        assert res[rid].done and res[rid].n_tokens == 5
    assert eng.stats.prefill_traces == 1
    assert eng.stats.decode_traces == 1


def test_unsupported_family_and_topk_rejected(served):
    cfg = configs.get_smoke("mamba2_1_3b")          # ssm: no ragged prefill
    params = lm.init_params(cfg, KEY)
    qparams = lm.quantize_params(params, cfg)
    eng = ServeEngine(cfg, qparams, max_len=64)
    with pytest.raises(NotImplementedError):
        eng.submit(np.asarray([1, 2, 3]))
    eng2 = _engine(served, n_slots=1, prefill_len=8)
    with pytest.raises(ValueError):
        eng2.submit(np.asarray([1, 2, 3]), top_k=10_000)


def test_fused_equals_unfused_decode(served):
    """lax.scan fusion is a pure scheduling change: token-identical to the
    per-token Python loop baseline."""
    eng = _engine(served)
    batch = {"tokens": jax.random.randint(KEY, (2, 8), 0,
                                          served[0].vocab_size)}
    eng.set_budget(jnp.asarray([10.0, 0.4]))
    fused = np.asarray(eng.generate(batch, steps=6))
    loop = np.asarray(eng.generate(batch, steps=6, fused=False))
    np.testing.assert_array_equal(fused, loop)


def test_per_request_edp_accounting(served):
    """Every request's resolved bit vector is priced into AP cycles/energy
    (apsim.costmodel), so RequestStats reports per-request latency/EDP —
    the Table 7 accuracy-vs-EDP trade-off at request granularity."""
    eng = _engine(served, n_slots=2, prefill_len=8, decode_block=4)
    prompt = np.asarray([3, 1, 4, 1, 5], np.int64)
    r8 = eng.submit(prompt, max_new_tokens=4, budget_s=10.0)
    r4 = eng.submit(prompt, max_new_tokens=4, budget_s=0.4)
    res = eng.run()
    s8, s4 = res[r8], res[r4]
    assert s4.ap_energy_per_token_j < s8.ap_energy_per_token_j
    assert s4.ap_cycles_per_token < s8.ap_cycles_per_token
    assert 0 < s4.edp < s8.edp
    assert s8.latency_s > 0 and s4.latency_s > 0
    # per-layer breakdown: one entry per bit slot + the logits head
    assert len(s8.ap_cost.per_layer_cycles) == eng.n_layers + 1
    assert s8.ap_latency_s == pytest.approx(
        s8.processed_tokens * s8.ap_cycles_per_token / s8.ap_cost.freq_hz)
    assert s8.ap_energy_j == pytest.approx(
        s8.processed_tokens * s8.ap_energy_per_token_j)
    # identical bit vectors hit the pricing cache (one object, shared)
    r8b = eng.submit(prompt, max_new_tokens=2, budget_s=10.0)
    assert eng.run()[r8b].ap_cost is s8.ap_cost


def test_engine_families_follow_controller(served):
    """The grouped dispatch family set is derived from the controller's
    registered configurations (4- and 8-bit here)."""
    eng = _engine(served)
    assert eng._families == (4, 8)
