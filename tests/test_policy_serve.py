"""Precision policies + the bit-fluid serving engine (zero-retrace switch)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.core import policy as pol
from repro.models import lm
from repro.serve.engine import ServeEngine

KEY = jax.random.PRNGKey(1)


def test_policy_vectors_extend():
    p = pol.per_layer([8, 4], name="t")
    w, a = p.vectors(5)
    np.testing.assert_array_equal(np.asarray(w), [8, 4, 4, 4, 4])


def test_hawq_tables_match_paper_averages():
    """Table VII average bitwidths: high 7.16, medium 6.53, low 5.05."""
    n = 20
    for name, avg in (("high", 7.16), ("medium", 6.53), ("low", 5.05)):
        w, _ = pol.hawq_v3(name).vectors(n)
        got = float(np.mean(np.asarray(w)))
        assert abs(got - avg) < 0.45, (name, got)


def test_budget_controller_selection():
    cfgs = {k: pol.fixed(b, name=k)
            for k, b in (("int4", 4), ("mix", 6), ("int8", 8))}
    lat = {"int4": 1.0, "mix": 2.0, "int8": 3.0}
    c = pol.BudgetController(cfgs, lat, n_layers=4)
    # generous budget -> most accurate (slowest fitting) config
    w, _ = c.resolve(10.0)
    assert int(w[0]) == 8
    # tight budget -> fastest
    w, _ = c.resolve(0.5)
    assert int(w[0]) == 4
    # middle
    w, _ = c.resolve(2.5)
    assert int(w[0]) == 6


def _controller_348():
    cfgs = {k: pol.fixed(b, name=k)
            for k, b in (("int3", 3), ("int4", 4), ("int8", 8))}
    lat = {"int3": 1.0, "int4": 2.0, "int8": 3.0}
    return pol.BudgetController(cfgs, lat, n_layers=4)


def test_budget_select_boundaries():
    c = _controller_348()
    # exact-fit budget: a config whose predicted latency EQUALS the budget
    # fits (<=), and the slowest such config wins
    assert int(c.select(2.0)) == 1
    assert int(c.select(3.0)) == 2
    # budget below the fastest config: fall back to the fastest (index 0)
    assert int(c.select(0.25)) == 0
    w, a = c.resolve(0.25)
    assert int(w[0]) == 3 and int(a[0]) == 3
    # just under a boundary drops one config down
    assert int(c.select(2.0 - 1e-6)) == 0 or int(c.select(1.99)) == 0


def test_budget_controller_single_config():
    c = pol.BudgetController({"only": pol.fixed(8)}, {"only": 1.0}, 4)
    for budget in (0.0, 1.0, 100.0):
        w, _ = c.resolve(budget)
        assert w.shape == (4,) and int(w[0]) == 8


def test_budget_select_vectorized():
    """(B,) budget vector -> (B,) indices / (B, n_layers) bit matrices,
    elementwise-equal to the scalar path."""
    c = _controller_348()
    budgets = jnp.asarray([0.1, 1.0, 2.0, 2.5, 3.0, 99.0])
    idx = c.select(budgets)
    assert idx.shape == budgets.shape
    np.testing.assert_array_equal(np.asarray(idx), [0, 0, 1, 1, 2, 2])
    for i, b in enumerate(np.asarray(budgets)):
        assert int(idx[i]) == int(c.select(float(b)))
    w, a = c.resolve(budgets)
    assert w.shape == (6, 4) and a.shape == (6, 4)
    np.testing.assert_array_equal(np.asarray(w[:, 0]), [3, 3, 4, 4, 8, 8])


def test_serving_budget_switch_no_retrace():
    """Dynamic mixed-precision serving: changing the budget changes bits
    but never recompiles (the paper's zero-reconfiguration claim)."""
    cfg = configs.get_smoke("qwen3_4b")
    params = lm.init_params(cfg, KEY)
    qparams = lm.quantize_params(params, cfg)
    n = lm.n_bit_slots(cfg)
    ctrl = pol.BudgetController(
        {"int4": pol.fixed(4), "int8": pol.fixed(8)},
        {"int4": 1.0, "int8": 2.0}, n)
    eng = ServeEngine(cfg, qparams, max_len=64, controller=ctrl)
    batch = {"tokens": jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)}

    eng.set_budget(10.0)               # int8
    out8 = eng.generate(batch, steps=4)
    eng.set_budget(0.5)                # int4
    out4 = eng.generate(batch, steps=4)
    assert out8.shape == out4.shape == (2, 4)
    assert eng.stats.prefill_traces == 1
    assert eng.stats.decode_traces == 1


def test_per_request_budget_vector_no_retrace():
    """Rows of one batch carry DIFFERENT budgets (hence different per-layer
    bit vectors) inside one compiled prefill + one compiled decode; varying
    the budget vector across generate() calls never retraces."""
    cfg = configs.get_smoke("qwen3_4b")
    params = lm.init_params(cfg, KEY)
    qparams = lm.quantize_params(params, cfg)
    n = lm.n_bit_slots(cfg)
    ctrl = pol.BudgetController(
        {"int4": pol.fixed(4), "int8": pol.fixed(8)},
        {"int4": 1.0, "int8": 2.0}, n)
    eng = ServeEngine(cfg, qparams, max_len=64, controller=ctrl)
    batch = {"tokens": jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)}

    for budgets in ([10.0, 0.5], [0.5, 10.0], [0.5, 0.5], [10.0, 10.0]):
        eng.set_budget(jnp.asarray(budgets))
        out = eng.generate(batch, steps=4)
        assert out.shape == (2, 4)
    assert eng.stats.prefill_traces == 1
    assert eng.stats.decode_traces == 1


def test_quantized_serving_close_to_fp():
    cfg = configs.get_smoke("qwen3_4b")
    params = lm.init_params(cfg, KEY)
    qparams = lm.quantize_params(params, cfg)
    n = lm.n_bit_slots(cfg)
    w8 = jnp.full((n,), 8, jnp.int32)
    wfp = jnp.full((n,), 16, jnp.int32)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)}
    cache = lm.empty_cache(cfg, 2, 32)
    lq, _ = lm.prefill(qparams, batch, cfg, w8, w8, cache)
    cache = lm.empty_cache(cfg, 2, 32)
    lf, _ = lm.prefill(params, batch, cfg, wfp, wfp, cache)
    pq = np.asarray(jax.nn.softmax(lq[:, -1]), np.float32)
    pf = np.asarray(jax.nn.softmax(lf[:, -1]), np.float32)
    # int8 serving stays close to the fp teacher distribution
    assert np.abs(pq - pf).sum(-1).max() < 0.35


def test_int4_container_roundtrip():
    cfg = configs.get_smoke("qwen3_4b")
    params = lm.init_params(cfg, KEY)
    q4 = lm.quantize_params(params, cfg, container="int4")
    n = lm.n_bit_slots(cfg)
    w4 = jnp.full((n,), 4, jnp.int32)
    batch = {"tokens": jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)}
    cache = lm.empty_cache(cfg, 2, 16)
    logits, _ = lm.prefill(q4, batch, cfg, w4, w4, cache)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # container really is packed nibbles: bytes(q4) ~ half of bytes(int8)
    q8 = lm.quantize_params(params, cfg, container="int8")

    def gemm_bytes(t, key):
        return sum(x.size * x.dtype.itemsize
                   for p, x in jax.tree_util.tree_flatten_with_path(t)[0]
                   if any(key in str(k) for k in p))

    assert gemm_bytes(q4, "q4") < 0.55 * gemm_bytes(q8, "'q'")


def test_int8_kv_cache_matches_bf16():
    """int8 KV cache (+int8 QK/PV dots) tracks the bf16-cache decode."""
    cfg = configs.get_smoke("qwen3_4b")
    cfg8 = cfg.with_(kv_cache_bits=8)
    params = lm.init_params(cfg, KEY)
    n = lm.n_bit_slots(cfg)
    w = jnp.full((n,), 16, jnp.int32)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)

    outs = {}
    for c in (cfg, cfg8):
        cache = lm.empty_cache(c, 2, 32)
        if c.kv_cache_bits == 8:
            assert cache["k"].dtype == jnp.int8 and "ks" in cache
        _, cache = lm.prefill(params, {"tokens": toks}, c, w, w, cache)
        logits, _ = lm.decode_step(params, toks[:, :1], jnp.asarray(12),
                                   cache, c, w, w)
        outs[c.kv_cache_bits] = jax.nn.softmax(logits[:, -1], -1)
    tv = float(jnp.abs(outs[0] - outs[8]).sum(-1).max()) * 0.5
    assert tv < 0.15, tv        # total-variation distance of next-token dist
