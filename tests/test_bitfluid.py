"""core/bitfluid: quantization, bit planes, dyadic requant — property tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import bitfluid as bf


@pytest.mark.parametrize("bits", [2, 3, 4, 6, 8])
def test_quant_dequant_bounds(rng, bits):
    x = rng.normal(size=(64, 32)).astype(np.float32) * 10
    s = bf.symmetric_scale(jnp.asarray(x), bits)
    q = bf.quantize(jnp.asarray(x), s, bits)
    lim = 2 ** (bits - 1) - 1
    assert np.abs(np.asarray(q)).max() <= lim
    err = np.abs(np.asarray(bf.dequantize(q, s)) - x).max()
    assert err <= float(s) * 0.5 + 1e-6


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_bitplane_roundtrip_exhaustive(bits):
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    q = jnp.arange(lo, hi + 1, dtype=jnp.int8)
    planes = bf.bitplanes(q, bits)
    assert planes.shape == (bits,) + q.shape
    np.testing.assert_array_equal(np.asarray(bf.from_bitplanes(planes, bits)),
                                  np.asarray(q))


@given(st.integers(min_value=-127, max_value=127),
       st.integers(min_value=2, max_value=8))
@settings(max_examples=200, deadline=None)
def test_requant_shift_dyadic(v, to_bits):
    """round-half-away(q / 2^(8-b)), clipped — pure integer dyadic."""
    out = int(bf.requant_shift(jnp.asarray([v], jnp.int8), to_bits)[0])
    shift = 8 - to_bits
    expect = np.sign(v) * ((abs(v) + (1 << shift >> 1)) >> shift) if shift \
        else v
    lim = 2 ** (to_bits - 1) - 1
    assert out == int(np.clip(expect, -lim, lim))


def test_requant_traced_bits_matches_static(rng):
    q = jnp.asarray(rng.integers(-127, 128, (256,)), jnp.int8)
    for b in (2, 4, 6, 8):
        static = bf.requant_shift(q, b)
        traced = jax.jit(bf.requant_shift)(q, jnp.asarray(b))
        np.testing.assert_array_equal(np.asarray(static), np.asarray(traced))


def test_int4_pack_roundtrip(rng):
    q = rng.integers(-8, 8, (64, 128)).astype(np.int8)
    for pack, unpack in ((bf.pack_int4, bf.unpack_int4),
                         (bf.pack_int4_halves, bf.unpack_int4_halves)):
        p = pack(jnp.asarray(q))
        assert p.shape == (64, 64) and p.dtype == jnp.uint8
        np.testing.assert_array_equal(np.asarray(unpack(p)), q)


def test_fake_quant_ste_gradient(rng):
    x = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
    g = jax.grad(lambda v: jnp.sum(bf.fake_quant(v, 4) ** 2))(x)
    # STE: gradient flows as if identity (2x at quantized point)
    assert np.isfinite(np.asarray(g)).all()
    assert np.abs(np.asarray(g)).max() > 0


def test_fake_quant_fp_sentinel(rng):
    x = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
    np.testing.assert_array_equal(np.asarray(bf.fake_quant(x, 16)),
                                  np.asarray(x))


def test_fluid_matmul_bits_monotone_error(rng):
    """More bits -> lower quantization error (the accuracy/cost dial)."""
    x = rng.normal(size=(64, 128)).astype(np.float32)
    w = (rng.normal(size=(128, 64)) * 0.05).astype(np.float32)
    ws = bf.symmetric_scale(jnp.asarray(w), 8, axis=0)
    qw = bf.quantize(jnp.asarray(w), ws, 8)
    exact = x @ w
    errs = []
    for b in (2, 4, 8):
        y = bf.fluid_int8_matmul(jnp.asarray(x), qw, ws, wbits=b, abits=8)
        errs.append(float(np.abs(np.asarray(y) - exact).mean()))
    assert errs[0] > errs[1] > errs[2]


def test_bitplane_matmul_ref_identity(rng):
    """sum_j w_j (x @ plane_j) == x @ q exactly (int32)."""
    x = rng.integers(-127, 128, (32, 64)).astype(np.int8)
    for bits in (2, 4, 8):
        lim = 2 ** (bits - 1)
        w = rng.integers(-lim, lim, (64, 48)).astype(np.int8)
        got = bf.bitplane_matmul_ref(jnp.asarray(x), jnp.asarray(w), bits)
        np.testing.assert_array_equal(
            np.asarray(got), x.astype(np.int64) @ w.astype(np.int64))
