"""Dry-run integration: one real cell lowers+compiles on the 512-device
production mesh in a subprocess (the XLA device-count flag must be set
before jax init, so in-process is impossible).  Marked slow-ish (~1 min).

Also: elastic checkpoint restore across mesh shapes (8 fake devices)."""
import json
import os
import subprocess
import sys


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def test_dryrun_single_cell(tmp_path):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "seamless_m4t_medium", "--shape", "decode_32k", "--out",
         str(tmp_path)],
        env=ENV, capture_output=True, text=True, timeout=540, cwd=REPO)
    assert r.returncode == 0, r.stderr[-3000:]
    art = json.load(open(tmp_path / "seamless_m4t_medium.decode_32k.16x16.json"))
    assert art["chips"] == 256
    assert art["cost"]["flops_int8_per_device"] > 0     # quantized serving
    assert art["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert art["memory"]["fits_hbm_16g"]


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train.checkpoint import save_checkpoint, restore_checkpoint

d = sys.argv[1]
tree = {"w": jnp.arange(64 * 16, dtype=jnp.float32).reshape(64, 16),
        "b": jnp.ones((16,), jnp.bfloat16)}

# save on mesh A (4x2)
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
w_a = jax.device_put(tree["w"], NamedSharding(mesh_a, P("data", "model")))
save_checkpoint(d, 3, {"w": w_a, "b": tree["b"]})

# restore on mesh B (2x4) — elastic rescale
mesh_b = jax.make_mesh((2, 4), ("data", "model"))
target = {"w": jax.ShapeDtypeStruct((64, 16), jnp.float32),
          "b": jax.ShapeDtypeStruct((16,), jnp.bfloat16)}
shard = {"w": NamedSharding(mesh_b, P("data", "model")),
         "b": NamedSharding(mesh_b, P())}
restored, step = restore_checkpoint(d, target, shard)
assert step == 3
np.testing.assert_array_equal(np.asarray(restored["w"]),
                              np.arange(64 * 16, dtype=np.float32).reshape(64, 16))
assert restored["w"].sharding.mesh.shape["data"] == 2     # resharded!
print("ELASTIC_OK")
"""


def test_elastic_resharding_restore(tmp_path):
    r = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT,
                        str(tmp_path / "ck")],
                       env=ENV, capture_output=True, text=True, timeout=300,
                       cwd=REPO)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ELASTIC_OK" in r.stdout


COMPRESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim.compress import compress_psum
from repro.dist.api import shard_map_compat

mesh = jax.make_mesh((8,), ("pod",))
rng = np.random.default_rng(0)
g_all = jnp.asarray(rng.normal(size=(8, 64, 32)), jnp.float32)

def step(g, e):
    avg, new_e = compress_psum({"w": g}, {"w": e}, "pod")
    return avg["w"], new_e["w"]

f = shard_map_compat(step, mesh=mesh, in_specs=(P("pod"), P("pod")),
                     out_specs=(P("pod"), P("pod")), check=False)

e = jnp.zeros_like(g_all)
total_err = []
for it in range(4):
    avg, e = f(g_all, e)
    true_mean = jnp.mean(g_all, axis=0, keepdims=True)
    # every shard's averaged gradient approximates the true mean
    err = float(jnp.abs(avg - true_mean).max())
    total_err.append(err)
# int8 quantization error bounded by ~scale = max|g|/127
bound = float(jnp.abs(g_all).max()) / 127.0 * 3
assert total_err[0] < bound, (total_err, bound)
# error feedback: residual buffer is nonzero and bounded by one scale
assert 0 < float(jnp.abs(e).max()) < bound
print("COMPRESS_OK", total_err[0])
"""


def test_int8_gradient_compression(tmp_path):
    r = subprocess.run([sys.executable, "-c", COMPRESS_SCRIPT],
                       env=ENV, capture_output=True, text=True, timeout=300,
                       cwd=REPO)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "COMPRESS_OK" in r.stdout
