"""Repetition-aware prefix/KV-cache tier (DESIGN.md §10): content-keyed
hits are bit-exact, precision-gated, priced for eviction in AP-cost
terms, charged to the closed loop only for their miss fraction — all
zero-retrace."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.cache.policy import (CacheLedger, RepetitionAwarePolicy,
                                hit_allowed)
from repro.core import policy as pol
from repro.models import lm
from repro.serve.engine import ServeEngine
from repro.serve.prefix_cache import PrefixCache

KEY = jax.random.PRNGKey(4)


@pytest.fixture(scope="module")
def served():
    cfg = configs.get_smoke("qwen3_4b")
    params = lm.init_params(cfg, KEY)
    qparams = lm.quantize_params(params, cfg)
    return cfg, qparams


def _ctrl(cfg):
    n = lm.n_bit_slots(cfg)
    return pol.BudgetController(
        {"int4": pol.fixed(4), "int8": pol.fixed(8)},
        {"int4": 0.5, "int8": 1.0}, n)


def _engine(served, cache=None, controller=None, **kw):
    cfg, qparams = served
    kw.setdefault("max_len", 64)
    kw.setdefault("n_slots", 2)
    kw.setdefault("prefill_len", 8)
    kw.setdefault("decode_block", 4)
    return ServeEngine(cfg, qparams,
                       controller=controller or _ctrl(cfg),
                       prefix_cache=cache, **kw)


# ---------------------------------------------------------------------------
# cache/policy.py units
# ---------------------------------------------------------------------------

def test_hit_allowed_policies():
    w8 = np.full((3,), 8)
    w4 = np.full((3,), 4)
    # exact: identical vectors only
    assert hit_allowed("exact", w8, w8, w8, w8)
    assert not hit_allowed("exact", w8, w8, w4, w4)
    # at_least: cached precision must dominate elementwise
    assert hit_allowed("at_least", w8, w8, w4, w4)
    assert not hit_allowed("at_least", w4, w4, w8, w8)
    assert not hit_allowed("at_least", w8, w4, w8, w8)   # abits too low
    # repriced: anything goes (the record carries the cached cost)
    assert hit_allowed("repriced", w4, w4, w8, w8)
    with pytest.raises(ValueError, match="hit policy"):
        hit_allowed("sometimes", w8, w8, w8, w8)


def test_eviction_is_value_ordered_and_deterministic():
    """Lowest repetition-weighted recompute EDP evicts first; ties break
    by insertion order — the same sequence always evicts the same keys."""
    def run():
        cache = PrefixCache(chunk=4, capacity=2, hit_policy="at_least")
        w = np.full((2,), 8)
        cost = _FakeCost(1.0, 1.0)
        rows = {}
        for i, count in [(0, 3), (1, 1), (2, 2)]:
            toks = np.arange(i * 10, i * 10 + 6, dtype=np.int32)
            for _ in range(count):       # observed repetitions -> value
                cache.policy.observe(cache.content_key(toks))
            cache.store(toks, rows, None, w, w, cost)
        return sorted(int(e.tokens[0]) for e in cache.entries.values())

    # key 1 (count 1) is the cheapest resident when key 2 arrives
    assert run() == [0, 20]
    assert run() == run()
    # a low-value newcomer is rejected instead of evicting a hot entry
    cache = PrefixCache(chunk=4, capacity=1, hit_policy="at_least")
    w = np.full((2,), 8)
    hot = np.arange(6, dtype=np.int32)
    for _ in range(5):
        cache.policy.observe(cache.content_key(hot))
    cache.store(hot, {}, None, w, w, _FakeCost(1.0, 1.0))
    assert not cache.store(np.arange(50, 56, dtype=np.int32), {}, None,
                           w, w, _FakeCost(1.0, 1.0))
    assert cache.ledger.rejected == 1 and cache.ledger.evictions == 0


class _FakeCost:
    def __init__(self, energy_j, latency_s):
        self.energy_j = energy_j
        self.latency_s = latency_s


# ---------------------------------------------------------------------------
# CachePool row primitives (models/lm.py)
# ---------------------------------------------------------------------------

def test_pool_install_validation(served):
    cfg, qparams = served
    pool = lm.CachePool(cfg, 2, 16)
    n = lm.n_bit_slots(cfg)
    wv = np.full((n,), 8)
    toks = np.zeros((1, 8), np.int32)
    _, row = lm.prefill(qparams, {"tokens": toks}, cfg, wv, wv,
                        lm.empty_cache(cfg, 1, 16),
                        lengths=np.asarray([8]))
    slot = pool.alloc()
    with pytest.raises(ValueError, match="not in"):
        pool.write_row(row, slot, 17)                # length > max_len
    with pytest.raises(ValueError, match="out of range"):
        pool.write_row(row, 5, 8)
    free = pool._free[-1]
    with pytest.raises(ValueError, match="free"):
        pool.install_prefix(row, free, 8)            # unallocated slot
    with pytest.raises(ValueError, match="free"):
        pool.copy_row(free, slot)                    # free source
    pool.write_row(row, slot, 8)
    with pytest.raises(ValueError, match="free"):
        pool.copy_row(slot, free)                    # free destination


def test_install_prefix_row_exact_and_copy_row(served):
    """A full-length install_prefix lands the exact same device row as
    write_row; copy_row duplicates it bit for bit."""
    cfg, qparams = served
    pool_a = lm.CachePool(cfg, 2, 16)
    pool_b = lm.CachePool(cfg, 2, 16)
    n = lm.n_bit_slots(cfg)
    wv = np.full((n,), 8)
    toks = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (1, 8)).astype(np.int32)
    _, row = lm.prefill(qparams, {"tokens": toks}, cfg, wv, wv,
                        lm.empty_cache(cfg, 1, 16),
                        lengths=np.asarray([8]))
    sa, sb = pool_a.alloc(), pool_b.alloc()
    pool_a.write_row(row, sa, 8)
    pool_b.install_prefix(row, sb, 8)
    for pa, pb in zip(jax.tree.leaves(pool_a.cache),
                      jax.tree.leaves(pool_b.cache)):
        np.testing.assert_array_equal(np.asarray(pa[:, sa]),
                                      np.asarray(pb[:, sb]))
    dst = pool_a.alloc()
    pool_a.copy_row(sa, dst)
    assert pool_a.lengths[dst] == pool_a.lengths[sa] == 8
    for p in jax.tree.leaves(pool_a.cache):
        np.testing.assert_array_equal(np.asarray(p[:, sa]),
                                      np.asarray(p[:, dst]))


# ---------------------------------------------------------------------------
# Engine integration: bit-exact hits under every policy, zero-retrace
# ---------------------------------------------------------------------------

def _tokens(served, eng, prompt, budget):
    rid = eng.submit(prompt, max_new_tokens=4, budget_s=budget)
    return eng.run()[rid].tokens


def test_full_hit_bit_exact_and_zero_retrace(served):
    """miss -> full hit -> partial hit, every output identical to a
    fresh cache-less engine; prefill/decode/extend compile once each."""
    cfg, _ = served
    rng = np.random.default_rng(2)
    base = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
    ext = np.concatenate([base[:4],
                          rng.integers(0, cfg.vocab_size, (3,))]
                         ).astype(np.int32)
    cache = PrefixCache(chunk=4, capacity=8, hit_policy="at_least")
    eng = _engine(served, cache=cache)
    fresh = _engine(served)
    for prompt in (base, base, ext):     # miss, full hit, partial hit
        assert (_tokens(served, eng, prompt, 10.0)
                == _tokens(served, fresh, prompt, 10.0))
    led = cache.ledger
    assert (led.hits, led.partial_hits, led.misses) == (1, 1, 1)
    assert led.hit_tokens == 8 + 4
    assert eng.stats.prefill_traces == 1
    assert eng.stats.decode_traces == 1
    assert eng.stats.extend_traces == 1
    assert fresh.stats.extend_traces == 0


def test_hit_policy_exact_refreshes_on_precision_change(served):
    """exact: an int8 entry cannot serve an int4 request — the miss
    re-prefills and refreshes the entry at the new precision."""
    cfg, _ = served
    prompt = np.random.default_rng(3).integers(
        0, cfg.vocab_size, (6,)).astype(np.int32)
    cache = PrefixCache(chunk=4, capacity=8, hit_policy="exact")
    eng = _engine(served, cache=cache)
    _tokens(served, eng, prompt, 10.0)               # miss, stored @ int8
    _tokens(served, eng, prompt, 0.4)                # int4: exact miss
    assert cache.ledger.misses == 2
    assert cache.ledger.refreshes == 1               # entry now int4
    _tokens(served, eng, prompt, 0.4)                # int4 now hits
    assert cache.ledger.hits == 1
    [entry] = cache.entries.values()
    assert entry.wbits.max() == 4


def test_hit_policy_at_least_serves_lower_precision(served):
    """at_least: an int8 entry serves an int4 request (row carries MORE
    precision than asked); an int4 entry never serves int8."""
    cfg, _ = served
    rng = np.random.default_rng(5)
    p8 = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    p4 = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    cache = PrefixCache(chunk=4, capacity=8, hit_policy="at_least")
    eng = _engine(served, cache=cache)
    _tokens(served, eng, p8, 10.0)                   # stored @ int8
    _tokens(served, eng, p8, 0.4)                    # int4 request: HIT
    assert cache.ledger.hits == 1
    rec = max(eng.requests.values(), key=lambda r: r.rid)
    assert rec.cache_hit == "full"
    assert rec.cached_mean_wbits == 8.0              # served from int8 row
    _tokens(served, eng, p4, 0.4)                    # stored @ int4
    _tokens(served, eng, p4, 10.0)                   # int8 request: miss
    assert cache.ledger.misses == 3
    assert cache.ledger.refreshes == 1


def test_hit_policy_repriced_always_hits_and_records_cost(served):
    cfg, _ = served
    prompt = np.random.default_rng(7).integers(
        0, cfg.vocab_size, (6,)).astype(np.int32)
    cache = PrefixCache(chunk=4, capacity=8, hit_policy="repriced")
    eng = _engine(served, cache=cache)
    _tokens(served, eng, prompt, 0.4)                # stored @ int4
    toks = _tokens(served, eng, prompt, 10.0)        # int8 request: hit
    assert cache.ledger.hits == 1
    rec = max(eng.requests.values(), key=lambda r: r.rid)
    assert rec.cache_hit == "full" and rec.cached_mean_wbits == 4.0
    assert rec.cached_cost is not None
    assert rec.cached_cost.energy_j < rec.ap_cost.energy_j
    # repriced reuse serves the int4-prefilled row and its stored
    # logits: the FIRST token is the int4 serve's, whatever bits the
    # requester resolved (decode then continues at the requester's bits)
    assert toks[0] == _tokens(served, _engine(served), prompt, 0.4)[0]


def test_ledger_invariant_and_aggregate(served):
    """Every cacheable admission is exactly one of hit/partial/miss, and
    the runtime aggregate mirrors the tier's ledger."""
    from repro.serve.accounting import aggregate

    cfg, _ = served
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
               for _ in range(3)]
    cache = PrefixCache(chunk=4, capacity=8, hit_policy="at_least")
    eng = _engine(served, cache=cache)
    order = [0, 1, 0, 2, 1, 0]
    for i in order:
        eng.submit(prompts[i], max_new_tokens=4, rep_key=i)
    eng.run()
    led = cache.ledger
    assert led.lookups == led.hits + led.partial_hits + led.misses
    assert led.lookups == eng.stats.admitted == len(order)
    assert led.hits == 3                             # every repeat hits
    # repetition counts are keyed by the threaded rep_key
    assert [cache.policy.count(i) for i in range(3)] == [3, 2, 1]
    agg = aggregate(eng.requests.values())
    assert agg["prefix_hits"] == 3
    assert agg["prefix_hit_rate"] == 0.5
    assert agg["cached_units"] == 3 * 6 == led.hit_tokens
    assert agg["prefill_edp_saved_js"] == pytest.approx(
        led.prefill_edp_saved_js)
    assert agg["ap_units"] == sum(r.processed_tokens
                                  for r in eng.requests.values()) - 18


def test_admission_planner_prefers_predicted_hits(served):
    """submit() discounts a predicted hit's modeled EDP, so it outranks
    an identically-budgeted unknown prompt in the admission queue."""
    cfg, _ = served
    rng = np.random.default_rng(13)
    known = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    unknown = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    cache = PrefixCache(chunk=4, capacity=8, hit_policy="at_least")
    eng = _engine(served, cache=cache, n_slots=1)
    _tokens(served, eng, known, 10.0)                # stored
    r_unk = eng.submit(unknown, max_new_tokens=4, budget_s=10.0)
    r_known = eng.submit(known, max_new_tokens=4, budget_s=10.0)
    ests = {e.rid: e.est_edp for e in eng._pending}
    assert ests[r_known] < ests[r_unk]
    assert eng.next_admission().rid == r_known       # hit admits first


def test_fluid_controller_charges_only_miss_fraction(served):
    """A full hit charges (planned - cached) units against the SLO
    window; the avoided share lands on controller.saved, buying later
    admissions higher bits than a cache-less run at the same SLO."""
    from repro.serve.accounting import axis_cost

    cfg, _ = served
    n = lm.n_bit_slots(cfg)
    prompt = np.random.default_rng(17).integers(
        0, cfg.vocab_size, (8,)).astype(np.int32)
    cfgs = {"int4": pol.fixed(4), "int8": pol.fixed(8)}
    preds = {"int4": 1e-10, "int8": 1e-8}

    def fluid():
        return pol.FluidController(cfgs, dict(preds), n, budget_axis="edp",
                                   slo=1e30, window=64)

    cache = PrefixCache(chunk=4, capacity=8, hit_policy="at_least")
    eng = _engine(served, cache=cache, controller=fluid())
    plain = _engine(served, controller=fluid())
    for e in (eng, plain):
        e.submit(prompt, max_new_tokens=4)
        e.run()
        rid2 = e.submit(prompt, max_new_tokens=4)
        e.run()
    rec = eng.requests[rid2]
    assert rec.cache_hit == "full" and rec.cached_units == 8
    assert rec.planned_units == 4                    # miss fraction only
    assert plain.requests[rid2].planned_units == 12
    # spend differs by exactly the cached share, which is what saved says
    delta = plain.controller.spent - eng.controller.spent
    assert eng.controller.saved == pytest.approx(delta)
    assert eng.controller.saved == pytest.approx(
        axis_cost(rec.ap_cost, "edp", 12) - axis_cost(rec.ap_cost, "edp", 4))
    # the hit request's own books: no prefill spend, full counterfactual
    assert rec.ap_units == 4
    assert rec.prefill_edp_js == 0.0
    assert rec.prefill_edp_saved_js > 0.0


def test_summarize_reports_repetition_stats():
    from repro.serve import traffic as tf

    trace = tf.synth_trace("poisson", ticks=32, rate=1.5, seed=7,
                           repetition=0.7)
    keys = [r.key for r in trace.requests]
    distinct = len(set(keys))
    res = tf.TrafficResult(
        entries=[{"rid": i, "workload": "lm", "arch": "a", "key": k,
                  "done": True, "submitted_tick": 0, "latency_ticks": 1,
                  "edp": 0.0, "energy_j": 0.0, "mean_wbits": 8.0,
                  "slo_edp": None, "attained": False, "starved": False}
                 for i, k in enumerate(keys)],
        queue_depth=[0], active_depth=[0], ticks=1, unserved=0)
    rep = res.report()["repetition"]
    assert rep["arrivals"] == len(keys)
    assert rep["distinct_keys"] == distinct
    assert rep["max_hit_rate"] == round((len(keys) - distinct)
                                        / len(keys), 4)
    assert 0.0 < rep["top_key_share"] <= 1.0


def test_ledger_as_dict_roundtrip():
    led = CacheLedger(hits=3, partial_hits=1, misses=2, refreshes=1,
                      hit_tokens=20, computed_tokens=10)
    d = led.as_dict()
    assert d["lookups"] == 6
    assert d["hit_rate"] == round(4 / 6, 4)
    assert d["hit_tokens"] == 20


def test_eviction_unregisters_prefixes():
    """An evicted entry's chunk prefixes stop matching — no dangling
    partial hits into freed rows."""
    cache = PrefixCache(chunk=4, capacity=1, hit_policy="at_least")
    w = np.full((2,), 8)
    a = np.arange(8, dtype=np.int32)
    b = np.arange(100, 108, dtype=np.int32)
    cache.store(a, {}, None, w, w, _FakeCost(1.0, 1.0))
    assert cache.peek(a) == 8
    for _ in range(3):                   # make b clearly more valuable
        cache.policy.observe(cache.content_key(b))
    cache.store(b, {}, None, w, w, _FakeCost(1.0, 1.0))
    assert cache.ledger.evictions == 1
    assert cache.peek(a) == 0                        # fully unregistered
    assert cache.peek(np.concatenate([a[:4], a[:2]])) == 0
    assert cache.peek(b) == 8


def test_repetition_policy_capacity_bound():
    p = RepetitionAwarePolicy(capacity=2)
    for k in range(10):
        p.observe(bytes([k]))
    assert len(p.counts) == 10           # counts persist past capacity:
                                         # rejected keys keep earning value
    admit, victim = p.plan(5.0, {b"x": (1.0, 0), b"y": (2.0, 1)})
    assert admit and victim == b"x"      # lowest value evicts
    admit, victim = p.plan(0.5, {b"x": (1.0, 0), b"y": (2.0, 1)})
    assert not admit and victim is None  # newcomer too cheap
