"""dist.api: mesh-context stack, logical resolution on REAL meshes,
no-op behavior off-mesh, and jit compatibility (zero retraces).

Runs on however many CPU devices exist; CI sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the real-mesh
cases exercise nontrivial shardings.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import dist
from repro.dist import api, sharding as shd


def _padded(spec, n: int):
    """jax may drop trailing Nones from a committed sharding's spec."""
    return tuple(spec) + (None,) * (n - len(tuple(spec)))


def _host_mesh(model: int = 1):
    n = len(jax.devices())
    if n % max(model, 1) != 0:
        pytest.skip(f"{n} devices not divisible by model={model}")
    return jax.make_mesh((n // model, model), ("data", "model"))


# ---------------------------------------------------------------------------
# Mesh-context stack
# ---------------------------------------------------------------------------

def test_no_mesh_by_default():
    assert api.active_mesh() is None
    assert api.tp_size() == 1 and api.dp_size() == 1


def test_use_mesh_nesting():
    outer = _host_mesh()
    inner = _host_mesh(model=len(jax.devices()))
    with api.use_mesh(outer):
        assert api.active_mesh() is outer
        with api.use_mesh(inner):
            assert api.active_mesh() is inner
            assert api.tp_size() == inner.shape["model"]
        assert api.active_mesh() is outer
    assert api.active_mesh() is None


def test_jax_context_manager_is_seen():
    mesh = _host_mesh()
    with mesh:
        assert api.active_mesh() is not None
        assert api.dp_size() == mesh.shape["data"]
    assert api.active_mesh() is None


def test_stack_wins_over_jax_context():
    mesh = _host_mesh()
    explicit = _host_mesh()
    with mesh, api.use_mesh(explicit):
        assert api.active_mesh() is explicit


# ---------------------------------------------------------------------------
# logical_to_mesh on a real jax.sharding.Mesh
# ---------------------------------------------------------------------------

def test_divisibility_fallback_real_mesh():
    mesh = _host_mesh()
    dp = mesh.shape["data"]
    if dp == 1:
        pytest.skip("single device")
    spec = dist.logical_to_mesh(mesh, ("dp", None), (dp * 3, 5))
    assert spec == P("data", None)
    # odd leading dim -> replicated, not an error
    spec = dist.logical_to_mesh(mesh, ("dp", None), (dp * 3 + 1, 5))
    assert spec == P(None, None)


def test_unknown_logical_axis_rejected():
    mesh = _host_mesh()
    with pytest.raises(ValueError, match="unknown logical axis"):
        dist.logical_to_mesh(mesh, ("pp",), (8,))


def test_axis_used_once_per_spec():
    mesh = _host_mesh()
    dp = mesh.shape["data"]
    if dp == 1:
        pytest.skip("single device")
    spec = dist.logical_to_mesh(mesh, ("dp", "dp"), (dp, dp))
    assert spec == P("data", None)      # second dp dim falls back


# ---------------------------------------------------------------------------
# constrain: off-mesh no-op, on-mesh placement, jit + zero retraces
# ---------------------------------------------------------------------------

def test_constrain_noop_without_mesh():
    x = jnp.arange(8.0)
    y = dist.constrain(x, ("dp",))
    assert y is x


def test_constrain_heads_noop_without_mesh():
    x = jnp.zeros((2, 1, 4, 8))
    assert dist.constrain_heads(x, 2, 3, True) is x


def test_constrain_places_data_on_mesh():
    mesh = _host_mesh()
    dp = mesh.shape["data"]
    x = jnp.arange(dp * 4.0).reshape(dp, 4)
    with mesh:
        y = jax.jit(lambda t: dist.constrain(t, ("dp", None)))(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    if dp > 1:
        assert _padded(y.sharding.spec, 2) == ("data", None)


def test_constrain_jit_zero_retraces():
    mesh = _host_mesh()
    dp = mesh.shape["data"]
    traces = {"n": 0}

    def f(t):
        traces["n"] += 1
        return dist.constrain(t * 2.0, ("dp", None))

    jitted = jax.jit(f)
    with mesh:
        for i in range(3):
            x = jnp.full((dp * 2, 3), float(i))
            out = jitted(x)
    assert traces["n"] == 1
    np.testing.assert_array_equal(np.asarray(out), np.full((dp * 2, 3), 4.0))


def test_constrain_heads_picks_axis():
    mesh = _host_mesh(model=len(jax.devices()))
    tp = mesh.shape["model"]
    if tp == 1:
        pytest.skip("single device")
    x = jnp.zeros((2, 1, tp, 4 * tp))
    with mesh:
        y_head = jax.jit(lambda t: dist.constrain_heads(t, 2, 3, True))(x)
        y_alt = jax.jit(lambda t: dist.constrain_heads(t, 2, 3, False))(x)
    assert _padded(y_head.sharding.spec, 4) == (None, None, "model", None)
    assert _padded(y_alt.sharding.spec, 4) == (None, None, None, "model")


# ---------------------------------------------------------------------------
# Spec builders against a real mesh
# ---------------------------------------------------------------------------

def test_batch_and_param_shardings_real_mesh():
    from repro import configs
    from repro.launch import specs as sp

    mesh = _host_mesh()
    cfg = configs.get_smoke("qwen3_4b")
    params = sp.abstract_params(cfg)
    p_shd = shd.param_shardings(params, mesh)
    assert jax.tree.structure(p_shd) == jax.tree.structure(params)
    batch = {"tokens": jax.ShapeDtypeStruct((mesh.shape["data"] * 2, 9),
                                            jnp.int32)}
    b_shd = shd.batch_shardings(batch, mesh)
    if mesh.shape["data"] > 1:
        assert _padded(b_shd["tokens"].spec, 2) == ("data", None)


def test_shard_batch_noop_without_mesh():
    batch = {"tokens": jnp.zeros((4, 8), jnp.int32)}
    out = shd.shard_batch(batch)
    assert out["tokens"] is batch["tokens"]


def test_opt_shardings_cover_codec_leaves():
    from repro import configs
    from repro.launch import specs as sp
    from repro.launch.specs import optimizer_for

    mesh = _host_mesh()
    cfg = configs.get("kimi_k2_1t_a32b")        # int8 m + factored v
    opt = sp.abstract_opt(cfg, optimizer_for(cfg))
    o_shd = shd.opt_shardings(opt, mesh)
    assert jax.tree.structure(o_shd) == jax.tree.structure(opt)
    flat = jax.tree_util.tree_flatten_with_path(o_shd)[0]
    assert all(s.mesh is not None for _, s in flat)
