"""AP emulator: bit-exactness of LUT passes + Table I pass-count fidelity.

Property tests need hypothesis (pip install .[dev]) and skip without it;
the deterministic pass-count locks below always run."""
import numpy as np
import pytest

from repro.apsim import costmodel as cm
from repro.core import emulator as em

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*a, **k):          # make the decorated defs importable
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed (pip install .[dev])")(fn)

    settings = given

    class st:                                         # noqa: N801
        @staticmethod
        def lists(*a, **k):
            return None

        @staticmethod
        def integers(*a, **k):
            return None


@given(st.lists(st.integers(0, 255), min_size=2, max_size=32),
       st.lists(st.integers(0, 255), min_size=2, max_size=32))
@settings(max_examples=50, deadline=None)
def test_add_bit_exact(a, b):
    n = min(len(a), len(b))
    a, b = np.array(a[:n]), np.array(b[:n])
    out, _ = em.ap_add(a, b, 8)
    np.testing.assert_array_equal(out, a + b)


@given(st.lists(st.integers(0, 255), min_size=2, max_size=16),
       st.lists(st.integers(0, 255), min_size=2, max_size=16))
@settings(max_examples=30, deadline=None)
def test_multiply_bit_exact(a, b):
    n = min(len(a), len(b))
    a, b = np.array(a[:n]), np.array(b[:n])
    out, _ = em.ap_multiply(a, b, 8)
    np.testing.assert_array_equal(out, a * b)


@given(st.lists(st.integers(-128, 127), min_size=2, max_size=32))
@settings(max_examples=50, deadline=None)
def test_relu_bit_exact(v):
    v = np.array(v)
    out, _ = em.ap_relu(v, 8)
    # ReLU via sign-flag zeroing: negatives -> 0, positives unchanged
    np.testing.assert_array_equal(out, np.maximum(v, 0))


@given(st.lists(st.integers(0, 255), min_size=2, max_size=32),
       st.lists(st.integers(0, 255), min_size=2, max_size=32))
@settings(max_examples=50, deadline=None)
def test_max_bit_exact(a, b):
    n = min(len(a), len(b))
    a, b = np.array(a[:n]), np.array(b[:n])
    out, _ = em.ap_max(a, b, 8)
    np.testing.assert_array_equal(out, np.maximum(a, b))


@given(st.lists(st.integers(0, 255), min_size=2, max_size=64))
@settings(max_examples=30, deadline=None)
def test_reduce_bit_exact(a):
    a = np.array(a)
    out, _ = em.ap_reduce(a, 8)
    assert out == int(a.sum())


def test_matmul_bit_exact(rng):
    X = rng.integers(0, 16, (3, 5))
    W = rng.integers(0, 16, (5, 4))
    out, _ = em.ap_matmul(X, W, 4)
    np.testing.assert_array_equal(out, X @ W)


# ---------------------------------------------------------------------------
# Pass counts vs Table I (the paper's §IV microbenchmark validation)
# ---------------------------------------------------------------------------

def test_add_pass_count_matches_table1(rng):
    """Table I addition: 8M compare+write passes (excl. populate/read)."""
    a = rng.integers(0, 255, (16,))
    b = rng.integers(0, 255, (16,))
    _, c = em.ap_add(a, b, 8)
    # emulator runs 4 passes per bit over M+1 columns (carry-out column)
    assert c.compares == 4 * 9
    assert c.writes == 4 * 9
    # paper's Table I counts 8M total compare+write cycles for M-bit adds
    table = cm.table1_cycles("add", "2d", M=8) - (2 * 8 + 8 + 1)  # LUT part
    assert abs((c.compares + c.writes) - table) <= 8  # carry-out column


def test_multiply_pass_scaling(rng):
    """Bit-serial multiply cost scales ~M^2 (the bit-fluidity premise)."""
    a = rng.integers(0, 255, (8,))
    b = rng.integers(0, 255, (8,))
    cycles = {}
    for M in (2, 4, 8):
        _, c = em.ap_multiply(a % (1 << M), b % (1 << M), M)
        cycles[M] = c.cycles()
    r42 = cycles[4] / cycles[2]
    r84 = cycles[8] / cycles[4]
    assert 2.5 < r42 < 5.0 and 2.5 < r84 < 5.0   # ~4x per doubling


def test_mixed_precision_cost_drops(rng):
    """Fewer bits -> proportionally fewer passes on identical hardware:
    the emulator-level statement of bit fluidity."""
    a = rng.integers(0, 15, (16,))
    b = rng.integers(0, 15, (16,))
    _, c4 = em.ap_multiply(a, b, 4)
    _, c8 = em.ap_multiply(a, b, 8)
    assert c4.cycles() < 0.45 * c8.cycles()


def test_relu_pass_count_matches_table3(rng):
    """Table III ReLU per-op passes, locked exactly: 1 flag-stash read,
    M writes (MSB reset + M-1 conditional zeroings), M-1 compares — 2M
    passes; one write per pass (the flag-column stash must not be
    double-counted as read AND an extra write)."""
    for M in (4, 8):
        v = rng.integers(-(1 << (M - 1)), (1 << (M - 1)) - 1, (32,))
        out, c = em.ap_relu(v, M)
        np.testing.assert_array_equal(out, np.maximum(v, 0))
        assert c.reads == 1
        assert c.compares == M - 1
        assert c.writes == M
        assert c.cycles() == 2 * M
        # Table I's 4M+1 ReLU cycles = 2M populate + 2M LUT/flag + 1 read
        assert c.cycles() == cm.table1_cycles("relu", "2d", M=M) - (2 * M + 1)


def test_relu_pass_count_independent_of_data(rng):
    """Word-parallel AP: pass counts depend on M only, never on values."""
    counts = set()
    for _ in range(4):
        v = rng.integers(-128, 127, (16,))
        _, c = em.ap_relu(v, 8)
        counts.add((c.compares, c.writes, c.reads))
    assert len(counts) == 1


def test_add_max_pass_components(rng):
    """Lock add/max per-op pass composition (Table I / Table IV)."""
    a = rng.integers(0, 255, (8,))
    b = rng.integers(0, 255, (8,))
    _, c = em.ap_add(a, b, 8)
    assert (c.compares, c.writes, c.reads) == (4 * 9, 4 * 9, 0)
    _, c = em.ap_max(a, b, 8)
    assert c.compares == c.writes == 4 * 8      # 4 LUT passes per bit
