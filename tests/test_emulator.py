"""AP emulator: bit-exactness of LUT passes + Table I pass-count fidelity."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.apsim import costmodel as cm
from repro.core import emulator as em


@given(st.lists(st.integers(0, 255), min_size=2, max_size=32),
       st.lists(st.integers(0, 255), min_size=2, max_size=32))
@settings(max_examples=50, deadline=None)
def test_add_bit_exact(a, b):
    n = min(len(a), len(b))
    a, b = np.array(a[:n]), np.array(b[:n])
    out, _ = em.ap_add(a, b, 8)
    np.testing.assert_array_equal(out, a + b)


@given(st.lists(st.integers(0, 255), min_size=2, max_size=16),
       st.lists(st.integers(0, 255), min_size=2, max_size=16))
@settings(max_examples=30, deadline=None)
def test_multiply_bit_exact(a, b):
    n = min(len(a), len(b))
    a, b = np.array(a[:n]), np.array(b[:n])
    out, _ = em.ap_multiply(a, b, 8)
    np.testing.assert_array_equal(out, a * b)


@given(st.lists(st.integers(-128, 127), min_size=2, max_size=32))
@settings(max_examples=50, deadline=None)
def test_relu_bit_exact(v):
    v = np.array(v)
    out, _ = em.ap_relu(v, 8)
    # ReLU via sign-flag zeroing: negatives -> 0, positives unchanged
    np.testing.assert_array_equal(out, np.maximum(v, 0))


@given(st.lists(st.integers(0, 255), min_size=2, max_size=32),
       st.lists(st.integers(0, 255), min_size=2, max_size=32))
@settings(max_examples=50, deadline=None)
def test_max_bit_exact(a, b):
    n = min(len(a), len(b))
    a, b = np.array(a[:n]), np.array(b[:n])
    out, _ = em.ap_max(a, b, 8)
    np.testing.assert_array_equal(out, np.maximum(a, b))


@given(st.lists(st.integers(0, 255), min_size=2, max_size=64))
@settings(max_examples=30, deadline=None)
def test_reduce_bit_exact(a):
    a = np.array(a)
    out, _ = em.ap_reduce(a, 8)
    assert out == int(a.sum())


def test_matmul_bit_exact(rng):
    X = rng.integers(0, 16, (3, 5))
    W = rng.integers(0, 16, (5, 4))
    out, _ = em.ap_matmul(X, W, 4)
    np.testing.assert_array_equal(out, X @ W)


# ---------------------------------------------------------------------------
# Pass counts vs Table I (the paper's §IV microbenchmark validation)
# ---------------------------------------------------------------------------

def test_add_pass_count_matches_table1(rng):
    """Table I addition: 8M compare+write passes (excl. populate/read)."""
    a = rng.integers(0, 255, (16,))
    b = rng.integers(0, 255, (16,))
    _, c = em.ap_add(a, b, 8)
    # emulator runs 4 passes per bit over M+1 columns (carry-out column)
    assert c.compares == 4 * 9
    assert c.writes == 4 * 9
    # paper's Table I counts 8M total compare+write cycles for M-bit adds
    table = cm.table1_cycles("add", "2d", M=8) - (2 * 8 + 8 + 1)  # LUT part
    assert abs((c.compares + c.writes) - table) <= 8  # carry-out column


def test_multiply_pass_scaling(rng):
    """Bit-serial multiply cost scales ~M^2 (the bit-fluidity premise)."""
    a = rng.integers(0, 255, (8,))
    b = rng.integers(0, 255, (8,))
    cycles = {}
    for M in (2, 4, 8):
        _, c = em.ap_multiply(a % (1 << M), b % (1 << M), M)
        cycles[M] = c.cycles()
    r42 = cycles[4] / cycles[2]
    r84 = cycles[8] / cycles[4]
    assert 2.5 < r42 < 5.0 and 2.5 < r84 < 5.0   # ~4x per doubling


def test_mixed_precision_cost_drops(rng):
    """Fewer bits -> proportionally fewer passes on identical hardware:
    the emulator-level statement of bit fluidity."""
    a = rng.integers(0, 15, (16,))
    b = rng.integers(0, 15, (16,))
    _, c4 = em.ap_multiply(a, b, 4)
    _, c8 = em.ap_multiply(a, b, 8)
    assert c4.cycles() < 0.45 * c8.cycles()
