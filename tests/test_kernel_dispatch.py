"""Kernel-dispatch parity: the serve compute path now lives in
kernels/ops.py — it must be BIT-EXACT against the pre-refactor inline
math (the `_apply_linear1` serve branch, kept verbatim below as the
oracle), and grouped per-row dispatch must be bit-exact against the
per-row vmap baseline, for int8 and packed-int4 containers alike."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bitfluid as bf
from repro.kernels import ops, ref
from repro.models import common as cm


# ---------------------------------------------------------------------------
# Oracles: the pre-refactor inline serve math, verbatim.
# ---------------------------------------------------------------------------

def _inline_serve_linear(p, x, wbits, abits):
    if "q4" in p:
        qw = bf.unpack_int4_halves(p["q4"])
        from_bits = 4
    else:
        qw, from_bits = p["q"], 8
    w_q = bf.requant_shift(qw, wbits, from_bits=from_bits)
    w_s = bf.effective_scale(p["s"], wbits, from_bits=from_bits)
    x2 = x.astype(jnp.float32)
    x_scale = bf.symmetric_scale(x2, abits)
    x_q = bf.quantize(x2, x_scale, abits)
    acc = jax.lax.dot_general(
        x_q, w_q, dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * x_scale * w_s
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(cm.DTYPE)


def _inline_vmap(p, x, wbits, abits):
    B = x.shape[0]
    wb = jnp.broadcast_to(jnp.asarray(wbits, jnp.int32), (B,))
    ab = jnp.broadcast_to(jnp.asarray(abits, jnp.int32), (B,))
    return jax.vmap(lambda xr, w, a: _inline_serve_linear(p, xr, w, a))(
        x, wb, ab)


def _container(rng, container, K=64, N=48, bias=True):
    w = jnp.asarray((rng.normal(size=(K, N)) * 0.1).astype(np.float32))
    p = {"w": w}
    if bias:
        p["b"] = jnp.asarray(rng.normal(size=(N,)).astype(np.float32))
    return cm.quantize_linear(p, container)


def _f32(x):
    return np.asarray(x, np.float32)


# ---------------------------------------------------------------------------
# Scalar-bits parity (the container path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("container", ["int8", "int4"])
@pytest.mark.parametrize("wbits", [2, 4, 8])
def test_scalar_bits_parity(rng, container, wbits):
    p = _container(rng, container)
    x = jnp.asarray(rng.normal(size=(3, 5, 64)).astype(np.float32))
    got = cm.apply_linear(p, x, wbits, 8)
    want = _inline_serve_linear(p, x, wbits, 8)
    np.testing.assert_array_equal(_f32(got), _f32(want))


@pytest.mark.parametrize("container", ["int8", "int4"])
def test_traced_scalar_bits_parity(rng, container):
    """(L,)-vector bits arrive in models as traced scalars via scan; the
    dispatch must stay bit-exact when bits are runtime tensors."""
    p = _container(rng, container)
    x = jnp.asarray(rng.normal(size=(2, 4, 64)).astype(np.float32))

    @jax.jit
    def run(wb, ab):
        return cm.apply_linear(p, x, wb, ab)

    for wb in (2, 4, 8):
        got = run(jnp.asarray(wb, jnp.int32), jnp.asarray(8, jnp.int32))
        want = _inline_serve_linear(p, x, wb, 8)
        np.testing.assert_array_equal(_f32(got), _f32(want))


# ---------------------------------------------------------------------------
# Per-row bits: grouped dispatch vs the vmap baseline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("container", ["int8", "int4"])
@pytest.mark.parametrize("seq", [1, 4])
def test_grouped_dispatch_matches_vmap_oracle(rng, container, seq):
    p = _container(rng, container)
    x = jnp.asarray(rng.normal(size=(6, seq, 64)).astype(np.float32))
    wb = jnp.asarray([2, 4, 8, 8, 4, 2], jnp.int32)
    ab = jnp.asarray([8, 8, 4, 8, 2, 8], jnp.int32)
    got = cm.apply_linear(p, x, wb, ab)
    want = _inline_vmap(p, x, wb, ab)
    np.testing.assert_array_equal(_f32(got), _f32(want))
    # and through ops' own vmap baseline
    with ops.row_dispatch("vmap"):
        base = cm.apply_linear(p, x, wb, ab)
    np.testing.assert_array_equal(_f32(base), _f32(want))


def test_grouped_dispatch_scalar_abits_vector_wbits(rng):
    p = _container(rng, "int8")
    x = jnp.asarray(rng.normal(size=(4, 2, 64)).astype(np.float32))
    wb = jnp.asarray([8, 4, 4, 8], jnp.int32)
    got = cm.apply_linear(p, x, wb, 8)
    want = _inline_vmap(p, x, wb, 8)
    np.testing.assert_array_equal(_f32(got), _f32(want))


def test_narrowed_families_stay_exact_and_snap_up(rng):
    """An engine narrows the family set to its controller's bits: values
    in the set stay exact; out-of-set values snap UP to the next family."""
    p = _container(rng, "int8", bias=False)
    x = jnp.asarray(rng.normal(size=(4, 1, 64)).astype(np.float32))
    wb = jnp.asarray([4, 8, 4, 8], jnp.int32)
    with ops.bit_families((4, 8)):
        got = cm.apply_linear(p, x, wb, 8)
    np.testing.assert_array_equal(_f32(got), _f32(_inline_vmap(p, x, wb, 8)))
    with ops.bit_families((4, 8)):
        snapped = cm.apply_linear(p, x, jnp.asarray([3, 3, 3, 3], jnp.int32),
                                  8)
    np.testing.assert_array_equal(
        _f32(snapped),
        _f32(_inline_vmap(p, x, jnp.asarray([4, 4, 4, 4], jnp.int32), 8)))


def test_bit_families_context_restores():
    before = ops.get_bit_families()
    with ops.bit_families((4, 8)):
        assert ops.get_bit_families() == (4, 8)
    assert ops.get_bit_families() == before
    with pytest.raises(ValueError):
        ops.set_bit_families(())
    with pytest.raises(ValueError):
        ops.set_row_dispatch("loop")
    assert ops.get_row_dispatch() == "grouped"


def test_grouped_dispatch_zero_retrace(rng):
    """Family membership is data: changing the per-row bit mix never
    retraces the jitted caller."""
    p = _container(rng, "int8")
    x = jnp.asarray(rng.normal(size=(4, 1, 64)).astype(np.float32))
    traces = []

    @jax.jit
    def run(wb):
        traces.append(1)
        return cm.apply_linear(p, x, wb, 8)

    for mix in ([2, 4, 6, 8], [8, 8, 8, 8], [4, 2, 4, 2]):
        run(jnp.asarray(mix, jnp.int32)).block_until_ready()
    assert len(traces) == 1


# ---------------------------------------------------------------------------
# Satellite: _blocks_for + int4 alignment behavior
# ---------------------------------------------------------------------------

def test_blocks_for_shrinks_all_dims():
    assert ops._blocks_for(512, 512, 512) == (128, 128, 128)
    assert ops._blocks_for(64, 32, 16) == (64, 32, 16)
    assert ops._blocks_for(1, 2, 3) == (8, 8, 8)        # floor at 8
    assert ops._blocks_for(100, 72, 200) == (128, 128, 128)  # next pow2 >= 128


def test_int4_matmul_unaligned_falls_back(rng):
    """Packed-column padding would split nibble halves; the dispatcher
    must fall back to ref instead of crashing (the old assert)."""
    M, K, N = 16, 64, 72                    # N/2 = 36 does not tile
    x = rng.integers(-127, 128, (M, K)).astype(np.int8)
    q4 = rng.integers(-8, 8, (K, N)).astype(np.int8)
    packed = bf.pack_int4_halves(jnp.asarray(q4))
    s = rng.uniform(0.001, 0.05, (1, N)).astype(np.float32)
    got = ops.int4_matmul(jnp.asarray(x), packed, jnp.asarray(s),
                          interpret=True)
    want = (x.astype(np.int64) @ q4.astype(np.int64)).astype(np.float32) * s
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


def test_int4_matmul_bad_shapes_raise(rng):
    x = jnp.asarray(rng.integers(-10, 10, (8, 64)).astype(np.int8))
    packed = jnp.zeros((32, 16), jnp.uint8)             # K=32 != 64
    with pytest.raises(ValueError, match="K"):
        ops.int4_matmul(x, packed, jnp.ones((1, 32)))
    packed = jnp.zeros((64, 16), jnp.uint8)             # N = 32
    with pytest.raises(ValueError, match="scale"):
        ops.int4_matmul(x, packed, jnp.ones((1, 7)))


# ---------------------------------------------------------------------------
# Flash attention: chunked ref + model routing through the dispatcher
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 24)])
def test_flash_chunked_ref_matches_oracle(rng, causal, window):
    q = jnp.asarray(rng.normal(size=(2, 100, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 80, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 80, 32)), jnp.float32)
    got = ref.flash_attention_chunked_ref(q, k, v, causal=causal,
                                          window=window, chunk=32)
    want = ref.flash_attention_ref(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_long_seq_attention_routes_through_ops(rng, monkeypatch):
    """No flash math inline in models/: above FLASH_THRESHOLD the
    attention block must reach ops.flash_attention, and its output must
    match the short-path masked SDPA."""
    from repro import configs
    from repro.models import transformer as tf

    cfg = configs.get_smoke("qwen3_4b")
    p = tf.attn_init(jax.random.PRNGKey(0), cfg)
    S = 32
    x = jnp.asarray(rng.normal(size=(2, S, cfg.d_model)) * 0.1, cm.DTYPE)
    positions = jnp.arange(S, dtype=jnp.int32)[None]

    calls = []
    orig = ops.flash_attention

    def spy(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(tf.kops, "flash_attention", spy)
    monkeypatch.setattr(tf, "FLASH_THRESHOLD", 16)
    out_flash, _ = tf.attention(p, x, cfg, positions=positions)
    assert len(calls) == 1
    monkeypatch.setattr(tf, "FLASH_THRESHOLD", 2048)
    out_sdpa, _ = tf.attention(p, x, cfg, positions=positions)
    np.testing.assert_allclose(_f32(out_flash), _f32(out_sdpa),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# EDP pricing (apsim.metrics.price_bit_vector)
# ---------------------------------------------------------------------------

def test_price_bit_vector_scales_with_bits():
    from repro.apsim import metrics as apm

    gemms = (((64, 128), (128, 64)),) * 4
    c8 = apm.price_bit_vector(gemms, [8] * 4, [8] * 4)
    c4 = apm.price_bit_vector(gemms, [4] * 4, [4] * 4)
    assert len(c8.per_layer_cycles) == len(c8.per_layer_energy_j) == 4
    assert 0 < c4.energy_j < c8.energy_j
    assert 0 < c4.cycles < c8.cycles
    assert 0 < c4.edp < c8.edp
    mixed = apm.price_bit_vector(gemms, [4, 8, 4, 8], [8] * 4)
    assert c4.energy_j < mixed.energy_j < c8.energy_j
    with_head = apm.price_bit_vector(gemms, [8] * 4, [8] * 4,
                                     head=(64, 512))
    assert len(with_head.per_layer_cycles) == 5
    assert with_head.cycles > c8.cycles
    with pytest.raises(ValueError):
        apm.price_bit_vector(gemms, [8] * 3, [8] * 4)


def test_layer_gemm_dims_cover_bit_slots():
    from repro import configs
    from repro.models import lm

    for arch in ("qwen3_4b", "mamba2_1_3b", "zamba2_2_7b",
                 "seamless_m4t_medium", "kimi_k2_1t_a32b"):
        cfg = configs.get_smoke(arch)
        gemms = lm.layer_gemm_dims(cfg)
        assert len(gemms) == lm.n_bit_slots(cfg), arch
        assert all(K > 0 and N > 0 for per in gemms for K, N in per), arch
