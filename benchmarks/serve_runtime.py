"""Closed-loop vs open-loop bit fluidity at B=32 (the serving runtime's
control-loop claim, DESIGN.md §8).

32 identical requests stream through the continuous-batching engine
under a tight system-level EDP SLO.  The open-loop BudgetController
trusts its (deliberately optimistic, 0.5x) prediction table and serves
every request at int8 — blowing through the SLO; the closed-loop
FluidController charges every admission's PRICED AP cost against the
SLO window and resolves each new admission from the REMAINING budget,
degrading precision mid-stream (paper §V.B's dynamic switching as a
live control loop).  Both compile exactly once — closed-loop config
switches are pure data.

Claims checked (rc != 0 on failure):
  * the closed loop lands within one request's EDP of the SLO while the
    open loop overshoots by >= 1.5x;
  * the closed loop serves strictly lower mean weight bits;
  * prefill/decode trace counters stay at 1 for both engines.
"""
from __future__ import annotations

import numpy as np

N_REQ = 32
N_SLOTS = 8
PROMPT = 8
MAX_NEW = 8
LAST_RESULTS: dict = {}


def _stream(cfg, qparams, controller, budget=None):
    from repro.serve.engine import ServeEngine

    rng = np.random.default_rng(0)
    eng = ServeEngine(cfg, qparams, max_len=64, controller=controller,
                      n_slots=N_SLOTS, prefill_len=PROMPT, decode_block=8)
    rids = [eng.submit(rng.integers(0, cfg.vocab_size, (PROMPT,)),
                       max_new_tokens=MAX_NEW, budget_s=budget)
            for _ in range(N_REQ)]
    res = eng.run()
    recs = [res[r] for r in rids]
    return eng, recs


def main() -> int:
    import jax

    from repro import configs
    from repro.core import policy as pol
    from repro.models import lm
    from repro.serve import predict_table

    cfg = configs.get_smoke("qwen3_4b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    qparams = lm.quantize_params(params, cfg)
    n = lm.n_bit_slots(cfg)
    cfgs = {"int4": pol.fixed(4), "int8": pol.fixed(8)}

    # actual per-request EDP of each config, priced by the AP model —
    # the same axis_cost math the runtime charges at admission
    actual = predict_table(lm.layer_gemm_dims(cfg), cfgs, axis="edp",
                           units=PROMPT + MAX_NEW,
                           head=lm.head_gemm_dims(cfg))
    edp4, edp8 = actual["int4"], actual["int8"]
    preds = {k: v / 2 for k, v in actual.items()}   # optimistic table
    slo = N_REQ * preds["int8"] * 1.2               # tight EDP budget

    def fluid(slo_):
        return pol.FluidController(cfgs, dict(preds), n, budget_axis="edp",
                                   slo=slo_, window=N_REQ)

    open_eng, open_recs = _stream(cfg, qparams, fluid(float("inf")),
                                  budget=slo / N_REQ)
    closed_eng, closed_recs = _stream(cfg, qparams, fluid(slo))

    open_edp = sum(r.edp for r in open_recs)
    closed_edp = sum(r.edp for r in closed_recs)
    open_bits = float(np.mean([r.mean_wbits for r in open_recs]))
    closed_bits = float(np.mean([r.mean_wbits for r in closed_recs]))
    traces = [open_eng.stats.prefill_traces, open_eng.stats.decode_traces,
              closed_eng.stats.prefill_traces,
              closed_eng.stats.decode_traces]

    print(f"EDP SLO for {N_REQ} requests: {slo:.3e} J·s "
          f"(per-config request EDP: int4 {edp4:.3e} | int8 {edp8:.3e})")
    print(f"open loop  : {open_edp:.3e} J·s ({open_edp / slo:5.2f}x SLO) "
          f"mean_wbits={open_bits:.2f}")
    print(f"closed loop: {closed_edp:.3e} J·s ({closed_edp / slo:5.2f}x "
          f"SLO) mean_wbits={closed_bits:.2f}")
    print(f"traces (prefill/decode x2 engines): {traces}")

    ok = (open_edp > slo * 1.5
          and abs(closed_edp - slo) <= edp8
          and closed_bits < open_bits
          and traces == [1, 1, 1, 1])
    LAST_RESULTS.clear()
    LAST_RESULTS.update({
        "n_requests": N_REQ, "slots": N_SLOTS,
        "slo_edp_js": slo,
        "open_loop_edp_js": open_edp, "closed_loop_edp_js": closed_edp,
        "open_loop_vs_slo": round(open_edp / slo, 3),
        "closed_loop_vs_slo": round(closed_edp / slo, 3),
        "open_mean_wbits": round(open_bits, 2),
        "closed_mean_wbits": round(closed_bits, 2),
        "traces": traces,
    })
    print(f"claim (closed loop converges to SLO, lower bits, one program): "
          f"{'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
