"""§Roofline — aggregate dry-run artifacts into the per-(arch x shape x
mesh) roofline table: three terms, dominant bottleneck, model-FLOP ratio.

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun); emits the
table EXPERIMENTS.md §Roofline embeds.  Exit 0 iff every single-pod
baseline cell is present."""
from __future__ import annotations

import glob
import json
import os

ART = os.environ.get("DRYRUN_ART", "artifacts/dryrun")

MOVE_HINTS = {
    "compute": "raise MXU efficiency: larger fused GEMM tiles / int8 path",
    "memory": "cut HBM traffic: weight container bits (int8->int4), "
              "fewer microbatch re-gathers, bf16 scores",
    "collective": "cut FSDP regather volume (accum), overlap TP collectives"
                  " with compute, int8-compress pod all-reduce",
}


def load():
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


PEAK = 197e12


def mfu_projected(r: dict) -> float:
    """Projected MFU: useful model FLOPs at the bf16 peak over the step's
    binding roofline term — the roofline fraction this cell achieves.
    (= 1.0 iff the step is exactly compute-bound with zero overhead.)"""
    t = r["roofline"]
    bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
    useful_s = r["model_flops_global"] / r["chips"] / PEAK
    return useful_s / max(bound, 1e-12)


def main() -> int:
    rows = load()
    if not rows:
        print(f"roofline: no artifacts under {ART}; run "
              "PYTHONPATH=src python -m repro.launch.dryrun --all "
              "--both-meshes first")
        return 1
    print("roofline: per (arch x shape x mesh); terms in seconds/step")
    print("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
          "model_flops_ratio,mfu_projected,peak_GiB,fits_16G")
    n_single = 0
    for r in rows:
        t = r["roofline"]
        print(f"{r['arch']},{r['shape']},{r['mesh']},"
              f"{t['compute_s']:.4f},{t['memory_s']:.4f},"
              f"{t['collective_s']:.4f},{t['dominant']},"
              f"{t['model_flops_ratio']:.3f},"
              f"{mfu_projected(r):.3f},"
              f"{r['memory']['peak_bytes_per_device'] / 2**30:.2f},"
              f"{r['memory']['fits_hbm_16g']}")
        if r["mesh"] == "16x16":
            n_single += 1
    # expected single-pod cells: 10 archs x 4 shapes - 7 long_500k skips
    expected = 33
    print(f"check,single_pod_cells,{n_single}/{expected}")
    print("hints:")
    for k, v in MOVE_HINTS.items():
        print(f"hint,{k},{v}")
    return 0 if n_single >= expected else 1


if __name__ == "__main__":
    raise SystemExit(main())
