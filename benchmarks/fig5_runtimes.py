"""Fig. 5 — AP runtime of micro/macro/CNN functions vs precision M.

Emits the runtime (cycles) of each Table I function on 1D / 2D / 2D-seg
APs across M in {2..16}, the curves the paper plots in Fig. 5."""
from __future__ import annotations

from repro.apsim import costmodel as cm
from repro.apsim.energy import SRAM

FUNCS = ("add", "multiply", "reduce", "matmat", "relu", "maxpool", "avgpool")


def rows():
    L, S, K = 256, 4, 64
    i, j, u = 8, 16, 8
    for M in (2, 4, 8, 12, 16):
        for mode in ("1d", "2d", "2dseg"):
            yield dict(
                M=M, mode=mode,
                add=cm.rt_add(M, L, mode).cycles(SRAM),
                multiply=cm.rt_multiply(M, M, L, mode).cycles(SRAM),
                reduce=cm.rt_reduce(M, L, mode).cycles(SRAM),
                matmat=cm.rt_matmat(i, j, u, M, M, mode).cycles(SRAM),
                relu=cm.rt_relu(M, L, mode).cycles(SRAM),
                maxpool=cm.rt_maxpool(M, S, K, mode).cycles(SRAM),
                avgpool=cm.rt_avgpool(M, S, K, mode).cycles(SRAM),
            )


def main() -> int:
    print("fig5: AP runtimes (cycles), L=256 S=4 K=64 gemm=8x16x8")
    print("M,mode," + ",".join(FUNCS))
    for r in rows():
        print(f"{r['M']},{r['mode']}," +
              ",".join(f"{r[f]:.0f}" for f in FUNCS))
    # paper claim: multiplication dominates micro functions and scales ~M^2
    m2 = cm.rt_multiply(2, 2, 256, "2d").cycles(SRAM)
    m8 = cm.rt_multiply(8, 8, 256, "2d").cycles(SRAM)
    ratio = m8 / m2
    ok = 10 < ratio < 18          # ~(8/2)^2 = 16 with linear terms
    print(f"check,multiply_scaling_8b_vs_2b,{ratio:.1f},"
          f"{'OK' if ok else 'MISMATCH'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
