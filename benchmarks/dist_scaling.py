"""Multi-device scale-out serving: the placement-planner headline
experiment (DESIGN.md §13), persisted as ``BENCH_dist.json``.

The PR 6 spike trace is replayed against the SAME smoke model served at
1 / 2 / 4 / 8 (fake CPU) devices.  At each device count the engine gets
a ``"data"`` mesh, a cost-driven :class:`repro.dist.placement
.PlacementPlan` (``plan="auto"`` — the planner reads the controller's
priced bit families and, with every device able to hold a full copy,
fully replicates), and proportionally more serve slots (the capacity
replication actually buys: every device holds every weight, so request
ROWS shard across the data axis under ``shard_map`` and the admission
pool grows with the mesh).  A short, heavy arrival burst backlogs the
single-device engine; scale-out drains it in a few admission waves.

Claims checked (rc != 0 on failure; device counts above the host's fake
pool are skipped, and their claims with them):
  * admitted throughput (completed / makespan ticks) scales
    near-linearly: >= 3x at 8 devices vs 1, monotonic through 2 and 4;
  * p99 latency under the spike is no worse at 8 devices than at 1;
  * every plan at D > 1 is fully replicated (mean_replicas == D) and
    every request's ledger row carries it (``plan_requests`` ==
    completed, via ``accounting.aggregate``);
  * nothing goes unserved and prefill/decode trace counters stay at 1
    (scale-out must not break the zero-retrace property).

Deterministic end to end: seeded arrivals, tick-domain latency, analytic
AP pricing — the regression gate (benchmarks/compare.py) holds the
throughput ratios as HARD metrics.
"""
from __future__ import annotations

import json
import time

LAST_RESULTS: dict = {}

SEED = 11
PROMPT = 8
MAX_NEW = 8
ARCH = "qwen3_4b"
SLOTS_PER_DEV = 4
DEVICE_COUNTS = (1, 2, 4, 8)


def _controller(n, cfgs, preds):
    from repro.core import policy as pol

    return pol.FluidController(dict(cfgs), dict(preds), n,
                               budget_axis="edp", slo=float("inf"),
                               window=64)


def _engine(cfg, qparams, controller, n_devices):
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.serve.engine import ServeEngine

    mesh = (Mesh(np.asarray(jax.devices()[:n_devices]), ("data",))
            if n_devices > 1 else None)
    return ServeEngine(cfg, qparams, max_len=64, controller=controller,
                       n_slots=SLOTS_PER_DEV * n_devices,
                       prefill_len=PROMPT, decode_block=MAX_NEW,
                       mesh=mesh, plan="auto" if mesh is not None else None)


def scaling_sweep(cfg, qparams, n, cfgs, preds, *, full):
    """Replay one seeded spike trace per device count; measure the
    admitted-throughput curve."""
    import jax

    from repro.serve import accounting as acct
    from repro.serve import traffic as tf

    avail = len(jax.devices())
    counts = [d for d in DEVICE_COUNTS if d <= avail]
    # the trace ends right after the burst: arrivals stop, so the
    # makespan is DRAIN-dominated and throughput reflects capacity (a
    # long steady tail would floor every makespan at the trace length)
    ticks, rate, burst_mag, burst_len = ((10, 0.75, 60.0, 6) if full
                                         else (6, 0.5, 60.0, 4))
    burst_at = 2
    trace = tf.synth_trace("spike", ticks=ticks, rate=rate, seed=SEED,
                           burst_mag=burst_mag, burst_at=burst_at,
                           burst_len=burst_len, prompt_len=PROMPT,
                           max_new_tokens=MAX_NEW)
    n_req = trace.n_requests
    print(f"spike: {n_req} requests over {ticks} ticks, "
          f"{burst_mag:.0f}x burst @[{burst_at}, {burst_at + burst_len}), "
          f"devices available: {avail}")

    per_dev = {}
    for d in counts:
        eng = _engine(cfg, qparams, _controller(n, cfgs, preds), d)
        res = tf.TraceReplayer(trace, {ARCH: eng},
                               use_budgets=False).replay()
        rep = res.report(window=burst_len)
        agg = acct.aggregate(eng.requests.values())
        thr = rep["completed"] / rep["ticks"] if rep["ticks"] else 0.0
        per_dev[d] = {
            "engine": eng, "report": rep, "agg": agg,
            "throughput": thr,
            "plan": eng.plan.summary() if eng.plan is not None else None,
        }
        print(f"  D={d}: makespan {rep['ticks']:3d} ticks, throughput "
              f"{thr:5.2f} req/tick, p50/p99 latency "
              f"{rep['p50_latency_ticks']:.0f}/"
              f"{rep['p99_latency_ticks']:.0f} ticks, queue peak "
              f"{rep['queue_depth']['peak']}, mean EDP "
              f"{agg['edp_per_unit_js']:.3e} J*s/unit, plan="
              f"{per_dev[d]['plan']}")

    base = per_dev[counts[0]]
    ok = True
    for d in counts:
        pd = per_dev[d]
        rep, agg, eng = pd["report"], pd["agg"], pd["engine"]
        ok &= rep["unserved"] == 0
        ok &= (eng.stats.prefill_traces == eng.stats.decode_traces == 1)
        if d > 1:
            ok &= pd["plan"] is not None and pd["plan"]["fully_replicated"]
            ok &= pd["plan"]["mean_replicas"] == d
            ok &= agg["plan_requests"] == rep["completed"]
            ok &= agg["plan_mean_replicas"] == float(d)

    ratios = {d: per_dev[d]["throughput"] / base["throughput"]
              for d in counts if d > 1}
    floors = {2: 1.5, 4: 2.5, 8: 3.0}
    prev = 1.0
    for d, r in sorted(ratios.items()):
        print(f"  throughput ratio {d}dev/1dev: {r:.2f}x (floor "
              f"{floors[d]}x)")
        ok &= r >= floors[d] and r >= prev
        prev = r
    if 8 in ratios:
        ok &= (per_dev[8]["report"]["p99_latency_ticks"]
               <= base["report"]["p99_latency_ticks"])

    metrics = {
        "n_requests": n_req, "ticks": ticks, "burst_mag": burst_mag,
        "devices": counts,
    }
    for d in counts:
        rep = per_dev[d]["report"]
        metrics[f"admitted_throughput_{d}dev"] = round(
            per_dev[d]["throughput"], 4)
        metrics[f"p99_latency_ticks_{d}dev"] = rep["p99_latency_ticks"]
        metrics[f"makespan_ticks_{d}dev"] = rep["ticks"]
        metrics[f"edp_per_unit_js_{d}dev"] = per_dev[d]["agg"][
            "edp_per_unit_js"]
    for d, r in ratios.items():
        metrics[f"throughput_ratio_{d}dev"] = round(r, 4)
    detail = {"metrics": metrics,
              "plans": {str(d): per_dev[d]["plan"] for d in counts},
              "reports": {str(d): per_dev[d]["report"] for d in counts}}
    return ok, metrics, detail


def main(full: bool = False, out: str = "BENCH_dist.json") -> int:
    import jax

    from repro import configs
    from repro.core import policy as pol
    from repro.models import lm
    from repro.serve import predict_table

    t0 = time.time()
    cfg = configs.get_smoke(ARCH)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    qparams = lm.quantize_params(params, cfg)
    n = lm.n_bit_slots(cfg)
    cfgs = {"int4": pol.fixed(4), "int8": pol.fixed(8)}
    preds = predict_table(lm.layer_gemm_dims(cfg), cfgs, axis="edp",
                          units=PROMPT + MAX_NEW,
                          head=lm.head_gemm_dims(cfg))

    ok, m, d = scaling_sweep(cfg, qparams, n, cfgs, preds, full=full)

    record = {
        "suite": "dist" + ("-full" if full else "-smoke"),
        "total_seconds": round(time.time() - t0, 3),
        "modules": {"scaling_sweep": {"rc": 0 if ok else 1, **d}},
    }
    with open(out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"[dist] wrote {out}")

    LAST_RESULTS.clear()
    LAST_RESULTS.update({"scaling_sweep": m})
    print(f"claims (fully-replicated plans scale admitted throughput "
          f">= 3x at 8 devices, p99 no worse, zero retraces): "
          f"{'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="full-size trace (nightly); default smoke sizes")
    ap.add_argument("--out", default="BENCH_dist.json")
    args = ap.parse_args()
    raise SystemExit(main(full=args.full, out=args.out))
