"""Fig. 6 — ReRAM/SRAM energy and latency ratios, VGG16, precisions 2..8.

Paper targets: energy ratio falls 80.9x -> 63.1x as precision rises 2->8;
latency ratio ~1.85x flat.  Constants not in Table VI were CALIBRATED once
(energy.py); this benchmark reports predicted vs paper ratios."""
from __future__ import annotations

from repro.apsim.energy import RERAM, SRAM
from repro.apsim.mapper import LR_CONFIG, simulate_network
from repro.apsim.workloads import vgg16

PAPER_ENERGY_RATIOS = {2: 80.9, 3: 72.9, 4: 68.9, 5: 66.6, 6: 65.0,
                       7: 63.9, 8: 63.1}
PAPER_LATENCY_RATIO = 1.85


def main() -> int:
    layers = vgg16()
    print("fig6: ReRAM/SRAM ratios, VGG16, LR config")
    print("precision,energy_ratio,paper_energy_ratio,latency_ratio,"
          "paper_latency_ratio")
    worst = 0.0
    for M in range(2, 9):
        rs = simulate_network(layers, LR_CONFIG, SRAM, bits=M,
                              network="vgg16")
        rr = simulate_network(layers, LR_CONFIG, RERAM, bits=M,
                              network="vgg16")
        er = rr.energy_j / rs.energy_j
        lr = rr.latency_s / rs.latency_s
        pe = PAPER_ENERGY_RATIOS[M]
        worst = max(worst, abs(er - pe) / pe)
        print(f"{M},{er:.1f},{pe},{lr:.2f},{PAPER_LATENCY_RATIO}")
    trend_ok = True
    prev = None
    for M in range(2, 9):
        rs = simulate_network(layers, LR_CONFIG, SRAM, bits=M).energy_j
        rr = simulate_network(layers, LR_CONFIG, RERAM, bits=M).energy_j
        r = rr / rs
        if prev is not None and r > prev + 1e-6:
            trend_ok = False
        prev = r
    vs_ok = voltage_scaling_check()
    print(f"check,energy_ratio_max_rel_err,{worst:.3f}")
    print(f"check,ratio_monotone_decreasing,{trend_ok}")
    print(f"check,voltage_scaling_insignificant,{vs_ok}")
    return 0 if (worst < 0.30 and trend_ok and vs_ok) else 1


if __name__ == "__main__":
    raise SystemExit(main())


def voltage_scaling_check() -> bool:
    """Paper §V.A: scaling SRAM VDD 1.0 -> 0.5 V (write energy 0.24 ->
    0.06 fJ) saves < 0.1% end-to-end — compares dominate once writes are
    sub-fJ."""
    from repro.apsim.energy import voltage_scaled
    layers = vgg16()
    base = simulate_network(layers, LR_CONFIG, SRAM, bits=8).energy_j
    scaled_tech = voltage_scaled(SRAM, 0.5)
    scaled = simulate_network(layers, LR_CONFIG, scaled_tech, bits=8).energy_j
    saving = (base - scaled) / base
    print(f"voltage_scaling,energy_saving_frac,{saving:.5f},"
          f"err_prob,{scaled_tech.write_error_prob}")
    return 0.0 <= saving < 0.005
