"""Benchmark regression gate: diff BENCH records against the committed
baseline (``benchmarks/BENCH_baseline.json``) with per-metric tolerances.

CI runs this *blocking* after the (non-blocking) smoke suite, so a PR
that silently tanks throughput or SLO attainment fails even though the
smoke step itself only records.  Rules, by metric name (first match
wins), applied to each module's curated ``metrics`` dict plus its
``rc``:

* ``rc`` — HARD: a module that passed at baseline must still pass.
* ``*attainment*`` / ``*hit_rate*`` / ``*accept_rate*`` — HARD:
  must-not-drop floors (SLO attainment; the prefix-cache tier's
  deterministic hit rate; speculative decoding's draft accept rate).
* relative throughput (``*speedup*`` / ``*geomean*`` /
  ``*throughput*`` — machine-relative ratios) — HARD: may regress at
  most 15%.
* absolute rates (``*_img_s`` / ``*_tok_s``) — reported only: they
  scale with the runner's hardware, so only their machine-relative
  ratios (above) gate.
* ``*traces*`` — HARD: compiled-trace counts are the zero-retrace
  proof and must match the baseline exactly.
* ``closed_loop_vs_slo`` — HARD: the closed loop must stay within
  1.1x of its SLO (the headline acceptance bound), and within 5% of
  the deterministic baseline value.
* ``*seconds*`` — reported only (machine-dependent wall time).
* everything else numeric — WARN (reported, non-blocking) when it
  moves more than 10%; the traffic metrics are deterministic, so a
  warn there still deserves a look.

A metric present at baseline but missing now is HARD (the suite lost
coverage).  New metrics are listed as info.  The delta table is printed
and, when ``--summary`` (CI passes ``$GITHUB_STEP_SUMMARY``) is given,
appended there as markdown.

Refresh the baseline after an intentional perf/metric change::

    python -m benchmarks.run --smoke && \
    PYTHONPATH=src python -m benchmarks.traffic_elasticity && \
    python -m benchmarks.compare --update-baseline \
        --current BENCH_smoke.json BENCH_traffic.json

``--update-baseline`` merges the given suites into the committed
baseline IN PLACE (suites not re-run keep their baseline records);
``--write-baseline`` replaces the whole file with exactly the given
suites (dropping any others) — use it only for a from-scratch rebuild.

Both baseline writers first consult the static-analysis suite
(DESIGN.md §12) and REFUSE to touch the baseline while it fails: a
retrace regression must never be baselined into ``BENCH_baseline.json``.
They read ``--analysis-status`` (the JSON ``repro.launch.analyze
--json`` writes; CI hands it down) when present, else run the suite
in-process.  The step summary notes the analysis status alongside the
delta table.
"""
from __future__ import annotations

import argparse
import json
import math
from typing import Dict, List, Optional, Tuple

BASELINE = "benchmarks/BENCH_baseline.json"

HARD, WARN, INFO = "HARD", "WARN", "info"

THROUGHPUT_KEYS = ("speedup", "geomean", "throughput")   # relative ratios
RATE_KEYS = ("_img_s", "_tok_s")        # absolute, machine-dependent


def flatten(record: dict) -> Dict[str, float]:
    """Curated metric leaves of one BENCH record: each module's ``rc``
    plus every numeric leaf under its ``metrics`` dict (lists index as
    ``name[i]``); wall-time fields ride along for the report."""
    out: Dict[str, float] = {}

    def walk(path: str, v) -> None:
        if isinstance(v, bool):
            out[path] = float(v)
        elif isinstance(v, (int, float)):
            out[path] = float(v)
        elif isinstance(v, dict):
            for k, vv in v.items():
                walk(f"{path}.{k}", vv)
        elif isinstance(v, (list, tuple)):
            for i, vv in enumerate(v):
                walk(f"{path}[{i}]", vv)
        # None / strings carry no gateable value

    for name, mod in record.get("modules", {}).items():
        if "rc" in mod:
            out[f"{name}.rc"] = float(mod["rc"])
        if "seconds" in mod:
            out[f"{name}.seconds"] = float(mod["seconds"])
        walk(f"{name}.metrics", mod.get("metrics", {}))
    if "total_seconds" in record:
        out["total_seconds"] = float(record["total_seconds"])
    return out


def classify(path: str) -> str:
    """Tolerance class for one flattened metric path."""
    leaf = path.rsplit(".", 1)[-1].lower()
    if "seconds" in leaf:
        return "time"
    if leaf == "rc":
        return "rc"
    if ("attainment" in leaf or "hit_rate" in leaf
            or "accept_rate" in leaf):
        return "attainment"
    if any(leaf.endswith(k) for k in RATE_KEYS):
        return "rate"
    if any(k in leaf for k in THROUGHPUT_KEYS):
        return "throughput"
    if "traces" in leaf:
        return "traces"
    if leaf.startswith("closed_loop_vs_slo"):
        return "closed_vs_slo"
    return "other"


def judge(cls: str, base: float, cur: Optional[float]) -> Tuple[str, str]:
    """(status, note) for one metric; status HARD means the gate fails."""
    if cur is None:
        if cls == "time":
            return INFO, "missing"
        return HARD, "metric disappeared"
    if cls in ("time", "rate"):
        return INFO, ""
    if cls == "rc":
        if base == 0 and cur != 0:
            return HARD, "module now fails"
        return ("ok", "") if cur == base else (INFO, "rc changed")
    if cls == "attainment":
        if cur < base - 1e-9:
            return HARD, "must-not-drop metric fell"
        return "ok", ""
    if cls == "throughput":
        if base > 0 and cur < 0.85 * base:
            return HARD, f"regressed >15% ({cur / base - 1:+.1%})"
        return "ok", ""
    if cls == "traces":
        if cur != base:
            return HARD, "trace count changed (retrace?)"
        return "ok", ""
    if cls == "closed_vs_slo":
        if cur > 1.1:
            return HARD, "closed loop beyond 1.1x SLO"
        if base > 0 and abs(cur - base) > 0.05 * base:
            return HARD, "deterministic SLO ratio moved >5%"
        return "ok", ""
    # other: deterministic-ish numerics -> warn on drift
    denom = max(abs(base), 1e-12)
    if abs(cur - base) > 0.10 * denom:
        return WARN, f"moved {(cur - base) / denom:+.1%}"
    return "ok", ""


def fmt(v: Optional[float]) -> str:
    if v is None:
        return "-"
    if math.isfinite(v) and v == int(v) and abs(v) < 1e12:
        return str(int(v))
    return f"{v:.4g}"


def compare(baseline: dict, currents: List[dict]) -> Tuple[List[dict], int]:
    """Diff each current record against its suite's baseline record.

    Returns (rows, n_hard).  Every baseline metric produces a row;
    unflagged rows are summarized, flagged ones make the table.
    """
    rows: List[dict] = []
    n_hard = 0
    for rec in currents:
        suite = rec.get("suite", "?")
        base_rec = baseline.get(suite)
        if base_rec is None:
            rows.append({"suite": suite, "path": "(suite)", "base": None,
                         "cur": None, "status": HARD,
                         "note": f"suite {suite!r} not in baseline — "
                                 f"refresh with --write-baseline"})
            n_hard += 1
            continue
        base_flat, cur_flat = flatten(base_rec), flatten(rec)
        for path, bval in sorted(base_flat.items()):
            cval = cur_flat.get(path)
            status, note = judge(classify(path), bval, cval)
            if status == HARD:
                n_hard += 1
            rows.append({"suite": suite, "path": path, "base": bval,
                         "cur": cval, "status": status, "note": note})
        for path in sorted(set(cur_flat) - set(base_flat)):
            rows.append({"suite": suite, "path": path, "base": None,
                         "cur": cur_flat[path], "status": INFO,
                         "note": "new metric (not in baseline)"})
    return rows, n_hard


def render(rows: List[dict], n_hard: int) -> str:
    """Markdown delta table of flagged rows + a one-line verdict."""
    flagged = [r for r in rows if r["status"] in (HARD, WARN, INFO)
               and r["note"]]
    ok_n = sum(1 for r in rows if r["status"] == "ok")
    lines = ["## Benchmark regression gate", ""]
    verdict = ("**FAIL** — hard regression(s) vs baseline"
               if n_hard else "**PASS** — no hard regressions vs baseline")
    lines.append(f"{verdict}: {ok_n} metrics within tolerance, "
                 f"{len(flagged)} flagged.")
    if flagged:
        lines += ["", "| status | suite | metric | baseline | current | "
                      "note |", "|---|---|---|---|---|---|"]
        order = {HARD: 0, WARN: 1, INFO: 2}
        for r in sorted(flagged, key=lambda r: order[r["status"]]):
            lines.append(f"| {r['status']} | {r['suite']} | `{r['path']}` "
                         f"| {fmt(r['base'])} | {fmt(r['cur'])} "
                         f"| {r['note']} |")
    return "\n".join(lines) + "\n"


def analysis_status(path: Optional[str],
                    run_if_missing: bool) -> Tuple[Optional[bool], str]:
    """(ok, detail) from the static-analysis suite.

    Reads the status JSON when it exists; otherwise runs the full suite
    in-process when ``run_if_missing`` (the baseline-update gate), else
    reports unknown (the diff path never pays the suite's runtime).
    """
    if path:
        try:
            with open(path) as f:
                data = json.load(f)
            passes = ",".join(sorted(data.get("passes", {})))
            return bool(data.get("ok")), f"{path} [{passes}]"
        except FileNotFoundError:
            pass
    if not run_if_missing:
        return None, "not run (no status file)"
    try:
        from repro import analysis
    except ImportError:
        return False, "repro.analysis unavailable (need PYTHONPATH=src)"
    res = analysis.run_suite()
    n = sum(len(p.fresh) for p in res.passes)
    return res.ok, f"suite run in-process ({n} finding(s))"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--current", nargs="+",
                    default=["BENCH_smoke.json", "BENCH_traffic.json"],
                    help="BENCH record files produced by this run")
    ap.add_argument("--summary", default=None,
                    help="file to APPEND the markdown table to "
                         "(CI: $GITHUB_STEP_SUMMARY)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="REPLACE --baseline with exactly the --current "
                         "suites (drops suites not re-run)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="merge the --current suites into --baseline in "
                         "place (suites not re-run keep their baseline "
                         "records)")
    ap.add_argument("--analysis-status", default="analysis_status.json",
                    help="JSON written by `repro.launch.analyze --json`; "
                         "baseline updates refuse when the suite failed "
                         "(and run it in-process when the file is absent)")
    args = ap.parse_args(argv)

    currents = []
    for path in args.current:
        with open(path) as f:
            currents.append(json.load(f))

    if args.write_baseline or args.update_baseline:
        ok, detail = analysis_status(args.analysis_status,
                                     run_if_missing=True)
        if not ok:
            print(f"[compare] REFUSING baseline update: static analysis "
                  f"suite failed ({detail}). Fix the findings (or "
                  f"justify them in the analysis baseline) first — a "
                  f"retrace regression must not be baselined.")
            return 2
        merged = {}
        if args.update_baseline:
            try:
                with open(args.baseline) as f:
                    merged = json.load(f)
            except FileNotFoundError:
                pass                    # first refresh seeds the file
        fresh = {rec.get("suite", f"suite{i}"): rec
                 for i, rec in enumerate(currents)}
        merged.update(fresh)
        with open(args.baseline, "w") as f:
            json.dump(merged, f, indent=1, sort_keys=True)
        verb = "updated" if args.update_baseline else "wrote"
        print(f"[compare] {verb} baseline {args.baseline} "
              f"(refreshed: {', '.join(sorted(fresh))}; "
              f"suites now: {', '.join(sorted(merged))})")
        return 0

    with open(args.baseline) as f:
        baseline = json.load(f)
    rows, n_hard = compare(baseline, currents)
    md = render(rows, n_hard)
    ok, detail = analysis_status(args.analysis_status,
                                 run_if_missing=False)
    badge = {True: "PASS", False: "**FAIL**", None: "n/a"}[ok]
    md += f"\nStatic analysis: {badge} ({detail})\n"
    print(md)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(md + "\n")
    return 1 if n_hard else 0


if __name__ == "__main__":
    raise SystemExit(main())
