"""Bit-fluid speculative decoding: self-draft low, verify high, once.

The headline experiment of the speculative serving path (DESIGN.md
§11): every request drafts k tokens through the scan-fused decode at a
LOW draft bit vector (int4), then verifies the current token plus all k
drafts in ONE (k+1)-wide chunked pass at its own TARGET bits (int8) —
same weights, two precisions, zero extra programs.  Greedy speculative
output is bit-identical to vanilla greedy by construction (every emitted
token is a verify-bits argmax), so the speedup is pure accounting: the
modeled AP latency of k accepted tokens collapses from k serial decode
GEMVs at int8 into k int4 GEMVs plus one batched verify chunk.

Random smoke weights make a low-bit draft disagree with its high-bit
verify almost immediately (accept rate ~0.15 — drafting then *loses*),
so the open-loop measurement runs a margin-calibrated surrogate: +-1
embedding codes with a permutation head (argmax margin 1.0 before
noise), head noise eps/d and damped residual branches g tuned so int4
tracks int8 essentially exactly while int2 falls off a cliff — the
precision-fidelity regime the BF-IMNA bit-fluid story assumes, scaled
to smoke shapes.  The accept rate is therefore DETERMINISTIC and gates
must-not-drop; tokens/AP-second speedup gates like a throughput ratio.

Claims checked (rc != 0 on failure):
  * greedy speculative tokens == vanilla greedy tokens, every request
    (bit-identity, the correctness core);
  * draft and verify each compile exactly ONE program across mixed
    accept lengths and request churn (``traces``);
  * modeled tokens/AP-second >= 1.5x vanilla int8 decode at int4
    draft / int8 verify (the headline);
  * the closed-loop variant (FluidController picks k from SLO headroom)
    spends <= 1.05x its EDP SLO window while choosing k > 0 for at
    least half of admissions.
"""
from __future__ import annotations

import copy

import jax
import jax.numpy as jnp
import numpy as np

LAST_RESULTS: dict = {}

SPEC_K = 8                  # open-loop draft depth (deepest tier)
PROMPT = 4
G_DAMP = 0.30               # residual-branch damping (surrogate)
EPS = 1.0                   # head noise scale, in units of 1/d


def _surrogate(cfg, base, seed: int = 1):
    """Margin-calibrated weights: int4 ~= int8 decode, int2 diverges."""
    from repro.models import lm
    rng = np.random.default_rng(seed)
    p = jax.tree_util.tree_map(jnp.asarray,
                               copy.deepcopy(jax.device_get(base)))
    d, V, PV = cfg.d_model, cfg.vocab_size, cfg.padded_vocab
    E = rng.choice([-1.0, 1.0], size=(V, d)).astype(np.float32)
    perm = rng.permutation(V)
    W = np.zeros((d, PV), np.float32)
    W[:, perm] = E.T            # column perm[t] = e_t: clean argmax chains
    W = W / d                   # margin 1.0, bounded cross-talk
    W += EPS * rng.standard_normal((d, PV)).astype(np.float32) / d
    p["emb"] = jnp.asarray(E, jnp.bfloat16)
    p["head"]["w"] = jnp.asarray(W, jnp.bfloat16)
    p["layers"]["attn"]["wo"]["w"] = p["layers"]["attn"]["wo"]["w"] * G_DAMP
    p["layers"]["mlp"]["wd"]["w"] = p["layers"]["mlp"]["wd"]["w"] * G_DAMP
    return lm.quantize_params(p, cfg)


def _submit_all(eng, prompts, max_new, **kw):
    return [eng.submit(p, max_new_tokens=max_new, **kw) for p in prompts]


def main(full: bool = True) -> int:
    from repro import configs
    from repro.core import policy as pol
    from repro.models import lm
    from repro.serve import accounting as acc
    from repro.serve.engine import ServeEngine

    # untied head: the tied path scores logits through an unquantized
    # f32 einsum, which would exempt the head from the draft bits
    cfg = configs.get_smoke("qwen3_4b").with_(tie_embeddings=False)
    key = jax.random.PRNGKey(0)
    qparams = _surrogate(cfg, lm.init_params(cfg, key))
    n = lm.n_bit_slots(cfg)

    # max_new = 1 mod (k+1): every spec round runs full-width (the last
    # token ships via the vanilla tick), so no draft is clamped away
    n_req = 12 if full else 6
    max_new = 37 if full else 19
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, (PROMPT,), dtype=np.int32)
               for _ in range(n_req)]

    def controller():
        return pol.BudgetController(
            {"int2": pol.fixed(2), "int4": pol.fixed(4),
             "int8": pol.fixed(8)},
            {"int2": 0.5, "int4": 1.0, "int8": 2.0}, n)

    def engine(**kw):
        return ServeEngine(cfg, qparams, max_len=64,
                           controller=kw.pop("controller", controller()),
                           n_slots=4, prefill_len=PROMPT,
                           decode_block=4, seed=0, **kw)

    # ---- open loop: vanilla int8 vs int4-draft / int8-verify ----------
    van = engine()
    _submit_all(van, prompts, max_new)
    van.run()
    spec = engine(spec_k=SPEC_K, draft_budget_s=1.0)    # 1.0 -> int4 draft
    _submit_all(spec, prompts, max_new)
    spec.run()

    identical = all(van.requests[a].tokens == spec.requests[b].tokens
                    for a, b in zip(sorted(van.requests),
                                    sorted(spec.requests)))
    traces = {"draft": int(spec.stats.traces.get("draft", 0)),
              "verify": int(spec.stats.traces.get("verify", 0))}
    agg_v = acc.aggregate(van.requests.values())
    agg_s = acc.aggregate(spec.requests.values())
    rate_v = agg_v["ap_units"] / agg_v["ap_latency_s"]
    rate_s = agg_s["ap_units"] / agg_s["ap_latency_s"]
    speedup = rate_s / rate_v
    accept = agg_s["spec_accept_rate"]
    edp_ratio = agg_s["edp_per_unit_js"] / agg_v["edp_per_unit_js"]
    print(f"open loop (k={SPEC_K}, int4 draft / int8 verify, "
          f"{n_req} reqs x {max_new} tokens):")
    print(f"  bit-identical greedy outputs: {identical}")
    print(f"  accept rate {accept:.3f} over {agg_s['spec_rounds']} rounds "
          f"({agg_s['spec_draft_units']} drafts)")
    print(f"  modeled tokens/AP-second: {rate_s:,.0f} vs {rate_v:,.0f} "
          f"vanilla -> {speedup:.2f}x | net EDP/token {edp_ratio:.2f}x")
    print(f"  compiled programs: draft x{traces['draft']}, "
          f"verify x{traces['verify']}")

    # ---- closed loop: FluidController picks k from SLO headroom -------
    cfgs = {"int2": pol.fixed(2), "int4": pol.fixed(4),
            "int8": pol.fixed(8)}
    preds = acc.predict_table(lm.layer_gemm_dims(cfg), cfgs, axis="edp",
                              units=PROMPT + max_new,
                              head=lm.head_gemm_dims(cfg))
    slo = n_req * preds["int8"] * 1.2
    ctrl = pol.FluidController(cfgs, preds, n, budget_axis="edp",
                               slo=slo, window=n_req)
    # the draft budget resolves through the SAME controller, so it is
    # denominated in the controller's own prediction units (EDP here,
    # not the seconds-like table of the open-loop BudgetController):
    # anything in (pred_int4, pred_int8) selects int4 drafts
    draft_budget = (preds["int4"] + preds["int8"]) / 2
    fluid = engine(controller=ctrl, spec_k=0, draft_budget_s=draft_budget)
    _submit_all(fluid, prompts, max_new)
    fluid.run()
    recs = list(fluid.requests.values())
    frac_spec = sum(1 for r in recs if r.spec_k > 0) / len(recs)
    agg_f = acc.aggregate(recs)
    # whole-stream spend vs the window SLO (ctrl.spent zeroes at window
    # rollover, so the aggregate ledger is the honest ratio)
    slo_ratio = agg_f["edp"] / slo
    print(f"closed loop (EDP SLO window {slo:.3e} J*s): spent "
          f"{slo_ratio:.2f}x SLO, k>0 on {frac_spec:.0%} of admissions, "
          f"accept {agg_f['spec_accept_rate']:.3f}")

    ok_identity = identical
    ok_traces = traces == {"draft": 1, "verify": 1}
    ok_speed = speedup >= 1.5
    ok_fluid = slo_ratio <= 1.05 and frac_spec >= 0.5
    LAST_RESULTS.clear()
    LAST_RESULTS.update({
        "spec_k": SPEC_K,
        "requests": n_req, "max_new_tokens": max_new,
        "bit_identical": bool(identical),
        "accept_rate": accept,
        "speedup_tok_per_ap_s": round(speedup, 3),
        "net_edp_per_token_x": round(edp_ratio, 3),
        "traces": traces,
        "closed_loop": {
            "frac_spec_admissions": round(frac_spec, 3),
            "closed_loop_vs_slo": round(slo_ratio, 4),
            "accept_rate": agg_f["spec_accept_rate"],
        },
    })
    ok = ok_identity and ok_traces and ok_speed and ok_fluid
    print(f"claims: identity {'PASS' if ok_identity else 'FAIL'} | "
          f"one-program {'PASS' if ok_traces else 'FAIL'} | "
          f"speedup>=1.5x {'PASS' if ok_speed else 'FAIL'} "
          f"({speedup:.2f}x) | closed-loop "
          f"{'PASS' if ok_fluid else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
