"""Table VIII — peak GOPS / GOPS/W vs SOTA accelerators.

The peak-cycle polynomial (metrics.peak_cycles) reproduces the paper's
published BF-IMNA peaks exactly at 1/8/16 bits; GOPS/W is predicted from
the same cell-energy accounting as the end-to-end simulator.  The paper's
headline cross-accelerator claims are asserted."""
from __future__ import annotations

from repro.apsim.metrics import (PAPER_TABLE8, peak_gops, peak_gops_per_w)


def main() -> int:
    print("table8: BF-IMNA peaks vs paper")
    print("precision,GOPS_ours,GOPS_paper,GOPSW_ours,GOPSW_paper")
    ok = True
    paper_gops = {1: 2_808_686, 8: 140_434, 16: 41_654}
    paper_gopsw = {1: 22_879, 8: 641, 16: 170}
    for M in (1, 8, 16):
        g = peak_gops(M)
        gw = peak_gops_per_w(M)
        print(f"{M},{g:.0f},{paper_gops[M]},{gw:.0f},{paper_gopsw[M]}")
        ok &= abs(g - paper_gops[M]) / paper_gops[M] < 0.01
        ok &= abs(gw - paper_gopsw[M]) / paper_gopsw[M] < 0.35
    # headline comparisons (paper §V.C)
    isaac_gops, isaac_gopsw = 40_907, 622
    pipel_gops, pipel_gopsw = 122_706, 143
    g16, gw16 = peak_gops(16), peak_gops_per_w(16)
    g8, gw8 = peak_gops(8), peak_gops_per_w(8)
    checks = {
        "16b_throughput_~1.02x_ISAAC": 0.9 < g16 / isaac_gops < 1.15,
        "16b_energy_eff_~1.19x_PipeLayer": 0.8 < gw16 / pipel_gopsw < 1.6,
        # paper: 8b beats ISAAC on both axes (641 vs 622 GOPS/W — a 3%
        # margin inside our 6% peak-power prediction error, so we assert
        # throughput strictly and energy efficiency within tolerance)
        "8b_beats_ISAAC_throughput": g8 > isaac_gops,
        "8b_ISAAC_energy_eff_within_6pct": gw8 / isaac_gopsw > 0.94,
        "8b_beats_PipeLayer_both": g8 > pipel_gops and gw8 > pipel_gopsw,
    }
    for k, v in checks.items():
        print(f"check,{k},{bool(v)}")
        ok &= bool(v)
    print("table8_sota_reference (from paper):")
    for name, (node, freq, prec, gops, gopsw) in PAPER_TABLE8.items():
        print(f"ref,{name},{node},{prec},{gops},{gopsw}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
