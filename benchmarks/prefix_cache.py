"""Headline experiment for the repetition-aware prefix/KV-cache tier
(DESIGN.md §10): replay a high-repetition trace with and without the
tier and measure what cross-request reuse buys.

A ``synth_trace(repetition=0.7)`` stream replays byte-identical prompts
for repeated keys (``payload_tokens`` is a pure function of (seed, key)),
so the tier's content-keyed lookup should convert nearly every repeat
into a full hit.  Two experiment pairs, both fully deterministic
(analytic AP costs, greedy sampling, seeded traces):

(a) **Open loop, fixed int8** — the same trace through two identical
    engines, one with a :class:`~repro.serve.prefix_cache.PrefixCache`.
    Hits install cached KV rows instead of re-prefilling, so the cached
    run must show a large prefill-EDP reduction and a modeled
    tokens-per-AP-second win — at bit-identical outputs (every request's
    token stream matches the fresh run exactly).
(b) **Closed loop, same SLO** — a FluidController pair under one tight
    whole-stream EDP SLO.  The cached run charges only each hit's miss
    fraction against the window, so the freed budget must buy strictly
    higher mean bits at the same SLO (and still land inside 1.1x of it).

Claims checked (rc != 0 on failure):
  * every repeat hits: achieved hit rate >= the trace's theoretical
    ``max_hit_rate``; ledger splits exactly (hits + partial + misses ==
    lookups == arrivals).
  * prefill EDP drops >= 2x; modeled throughput speedup > 1.
  * cached outputs are bit-exact vs fresh prefill for every request.
  * zero-retrace: prefill/decode/extend each compile exactly once.
  * closed loop: cached mean bits strictly above uncached at the same
    SLO, within 1.1x of it, with the controller's ``saved`` ledger > 0.
"""
from __future__ import annotations

import time

import numpy as np

LAST_RESULTS: dict = {}

SEED = 7
PROMPT = 8
MAX_NEW = 8
ARCH = "qwen3_4b"
REPETITION = 0.7
CAPACITY = 128


def _engine(cfg, qparams, *, controller=None, policy=None, cache=None):
    from repro.serve.engine import ServeEngine

    return ServeEngine(cfg, qparams, max_len=64, controller=controller,
                       policy=policy, n_slots=8, prefill_len=PROMPT,
                       decode_block=MAX_NEW, prefix_cache=cache)


def _warm(eng, vocab):
    """Trigger every compiled program (prefill, decode, and — on cached
    engines — the partial-hit extend path) before anything is timed."""
    base = (np.arange(1, PROMPT + 1, dtype=np.int32)) % vocab
    eng.submit(base, max_new_tokens=2)
    eng.run()
    if eng.prefix_cache is not None:
        eng.submit(base, max_new_tokens=2)              # full hit
        part = np.concatenate(                          # partial -> extend
            [base[:4], np.zeros((2,), np.int32)])
        eng.submit(part, max_new_tokens=2)
        eng.run()


def _replay(trace, eng):
    from repro.serve import traffic as tf

    tok0 = eng.stats.tokens
    t0 = time.time()
    res = tf.TraceReplayer(trace, {ARCH: eng}, use_budgets=False).replay()
    wall = time.time() - t0
    return res, eng.stats.tokens - tok0, wall


def open_loop(cfg, qparams, trace, *, full):
    """(a): same int8 trace, with vs without the tier — cheaper AND
    bit-identical."""
    from repro.cache.policy import CacheLedger
    from repro.core import policy as pol
    from repro.serve.accounting import aggregate
    from repro.serve.prefix_cache import PrefixCache

    fresh = _engine(cfg, qparams, policy=pol.fixed(8))
    cache = PrefixCache(chunk=4, capacity=CAPACITY, hit_policy="at_least")
    cached = _engine(cfg, qparams, policy=pol.fixed(8), cache=cache)
    _warm(fresh, cfg.vocab_size)
    _warm(cached, cfg.vocab_size)
    cache.ledger = CacheLedger()        # warmup traffic doesn't count

    res_f, ntok_f, wall_f = _replay(trace, fresh)
    res_c, ntok_c, wall_c = _replay(trace, cached)
    rep_f, rep_c = res_f.report(), res_c.report()
    agg_f = aggregate(fresh.requests.values())
    agg_c = aggregate(cached.requests.values())

    led = cache.ledger
    kr = rep_c["repetition"]
    prefill_f = sum(r.prefill_edp_js for r in fresh.requests.values())
    prefill_c = sum(r.prefill_edp_js for r in cached.requests.values())
    reduction = prefill_f / prefill_c if prefill_c > 0 else float("inf")
    saved_ratio = 1.0 - prefill_c / prefill_f if prefill_f > 0 else 0.0
    # modeled serving throughput: same token stream, fewer AP-computed
    # units -> less modeled AP latency -> higher tokens per AP-second
    speedup = agg_f["ap_latency_s"] / agg_c["ap_latency_s"]
    # bit-exactness: cache-served requests replay the fresh engine's
    # exact token stream (warmup consumed rids, so match by order)
    f_rids = sorted(r for r, st in fresh.requests.items() if st.prompt_len
                    and st.submitted_tick >= 0)[-trace.n_requests:]
    c_rids = sorted(r for r, st in cached.requests.items() if st.prompt_len
                    and st.submitted_tick >= 0)[-trace.n_requests:]
    tokens_equal = all(
        fresh.requests[a].tokens == cached.requests[b].tokens
        for a, b in zip(f_rids, c_rids))
    traces = [fresh.stats.prefill_traces, fresh.stats.decode_traces,
              fresh.stats.extend_traces, cached.stats.prefill_traces,
              cached.stats.decode_traces, cached.stats.extend_traces]

    print(f"open loop: {trace.n_requests} arrivals, "
          f"{kr['distinct_keys']} distinct keys (theoretical max hit rate "
          f"{kr['max_hit_rate']:.2f})")
    print(f"  ledger   : {led.hits} full + {led.partial_hits} partial / "
          f"{led.lookups} lookups (rate {led.hit_rate:.2f}), "
          f"{led.misses} misses, {led.evictions} evictions, "
          f"{led.hit_tokens} tokens from cache")
    print(f"  prefill  : {prefill_f:.3e} -> {prefill_c:.3e} J*s "
          f"({reduction:.1f}x reduction, {saved_ratio:.0%} saved)")
    print(f"  modeled  : {speedup:.2f}x tokens/AP-second "
          f"({agg_f['ap_latency_s']:.3e}s -> {agg_c['ap_latency_s']:.3e}s "
          f"for {ntok_c} tokens)")
    print(f"  wall     : {ntok_f / wall_f:.1f} -> {ntok_c / wall_c:.1f} "
          f"tok/s (machine-dependent, reported only)")
    print(f"  outputs  : bit-exact={tokens_equal}, traces={traces}")

    ok = (led.hit_rate >= kr["max_hit_rate"] - 1e-9
          and led.hits + led.partial_hits + led.misses == led.lookups
          and led.lookups == trace.n_requests
          and reduction >= 2.0
          and speedup > 1.0
          and tokens_equal
          and traces == [1, 1, 0, 1, 1, 1]
          and rep_f["unserved"] == rep_c["unserved"] == 0)
    metrics = {
        "n_requests": trace.n_requests,
        "distinct_keys": kr["distinct_keys"],
        "max_hit_rate": kr["max_hit_rate"],
        "hit_rate": round(led.hit_rate, 4),
        "full_hits": led.hits, "partial_hits": led.partial_hits,
        "misses": led.misses, "evictions": led.evictions,
        "cached_units": agg_c["cached_units"],
        "prefill_edp_nocache_js": prefill_f,
        "prefill_edp_cache_js": prefill_c,
        "prefill_edp_saved_ratio": round(saved_ratio, 4),
        "prefill_edp_reduction_x": round(reduction, 4),
        "cached_vs_fresh_speedup": round(speedup, 4),
        "nocache_wall_tok_s": round(ntok_f / wall_f, 2),
        "cache_wall_tok_s": round(ntok_c / wall_c, 2),
        "tokens_equal": tokens_equal,
        "traces": traces,
    }
    detail = {"metrics": metrics, "ledger": led.as_dict(),
              "fresh": rep_f, "cached": rep_c}
    return ok, metrics, detail


def closed_loop(cfg, qparams, trace, *, full):
    """(b): one tight EDP SLO, with vs without the tier — hits free
    window budget, so the loop converges to strictly higher bits."""
    from repro.core import policy as pol
    from repro.models import lm
    from repro.serve import predict_table
    from repro.serve import traffic as tf
    from repro.serve.accounting import aggregate
    from repro.serve.prefix_cache import PrefixCache

    n = lm.n_bit_slots(cfg)
    cfgs = {"int4": pol.fixed(4), "int8": pol.fixed(8)}
    actual = predict_table(lm.layer_gemm_dims(cfg), cfgs, axis="edp",
                           units=PROMPT + MAX_NEW,
                           head=lm.head_gemm_dims(cfg))
    # whole-stream SLO priced on the trace's ACTUAL planned unit counts
    # (EDP scales with units^2), at 0.65x the all-int8 cost: too tight
    # to serve everything at int8 without the cache's subsidy
    units = np.asarray([
        len(tf.payload_tokens(trace, r, cfg.vocab_size)) + r.max_new_tokens
        for r in trace.requests], np.float64)
    scale = float(np.sum((units / (PROMPT + MAX_NEW)) ** 2))
    slo = actual["int8"] * 0.65 * scale

    def fluid():
        return pol.FluidController(cfgs, dict(actual), n, budget_axis="edp",
                                   slo=slo, window=trace.n_requests)

    plain = _engine(cfg, qparams, controller=fluid())
    cache = PrefixCache(chunk=4, capacity=CAPACITY, hit_policy="at_least")
    tier = _engine(cfg, qparams, controller=fluid(), cache=cache)
    res_p, _, _ = _replay(trace, plain)
    res_t, _, _ = _replay(trace, tier)
    rep_p, rep_t = res_p.report(), res_t.report()
    slo_p = aggregate(plain.requests.values())["edp"] / slo
    slo_t = aggregate(tier.requests.values())["edp"] / slo
    saved = tier.controller.saved

    print(f"closed loop: EDP SLO {slo:.3e} J*s over the whole stream "
          f"(0.65x all-int8)")
    print(f"  no cache : mean_wbits={rep_p['mean_wbits']:.2f}, "
          f"{slo_p:.2f}x SLO")
    print(f"  cached   : mean_wbits={rep_t['mean_wbits']:.2f}, "
          f"{slo_t:.2f}x SLO, window subsidy {saved:.3e} J*s, "
          f"hit rate {cache.ledger.hit_rate:.2f}")

    ok = (rep_t["mean_wbits"] > rep_p["mean_wbits"]
          and slo_t <= 1.1
          and saved > 0.0
          and rep_p["unserved"] == rep_t["unserved"] == 0)
    metrics = {
        "slo_edp_js": slo,
        "nocache_mean_wbits": rep_p["mean_wbits"],
        "cache_mean_wbits": rep_t["mean_wbits"],
        "nocache_slo_ratio": round(slo_p, 4),
        "closed_loop_vs_slo": round(slo_t, 4),
        "controller_saved_js": saved,
        "hit_rate": round(cache.ledger.hit_rate, 4),
    }
    return ok, metrics, {"metrics": metrics, "nocache": rep_p,
                         "cached": rep_t}


def main(full: bool = False) -> int:
    import jax

    from repro import configs
    from repro.models import lm
    from repro.serve import traffic as tf

    cfg = configs.get_smoke(ARCH)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    qparams = lm.quantize_params(params, cfg)
    ticks, rate = (64, 2.0) if full else (32, 1.5)
    trace = tf.synth_trace("poisson", ticks=ticks, rate=rate, seed=SEED,
                           repetition=REPETITION, prompt_len=PROMPT,
                           max_new_tokens=MAX_NEW)

    ok_a, m_a, _ = open_loop(cfg, qparams, trace, full=full)
    ok_b, m_b, _ = closed_loop(cfg, qparams, trace, full=full)

    LAST_RESULTS.clear()
    LAST_RESULTS.update({"open_loop": m_a, "closed_loop": m_b})
    ok = ok_a and ok_b
    print(f"claims (repeats hit, >=2x prefill-EDP cut at bit-exact "
          f"outputs; same SLO buys strictly higher bits): "
          f"{'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="full-size trace (nightly); default smoke size")
    args = ap.parse_args()
    raise SystemExit(main(full=args.full))
