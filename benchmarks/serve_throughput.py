"""Serving throughput: scan-fused decode vs the per-token Python loop,
fixed int8 vs mixed per-request budgets, at B in {1, 8, 32}.

The trajectory this records into BENCH_smoke.json is the serving-scale
claim of the refactored engine: (a) fusing ``decode_block`` tokens into
one ``lax.scan`` dispatch beats the per-token loop (dispatch overhead is
the CPU-CI bottleneck, exactly as per-step launch latency is on real
accelerators), and (b) per-request precision (a (B, n_layers) bit matrix
driving the vmapped row path) prices in at smoke scale while keeping one
compiled program.

Throughput is also split into prefill vs decode tok/s per batch size
(two-point timing: a steps=1 run isolates the prefill phase, the
marginal cost of the remaining steps is pure decode) — the phase rates
the speculative-decoding benchmark's tokens/AP-second model rides on.

Claim checked (rc != 0 on failure): fused decode beats the Python loop
by >= 1.1x in geometric mean across batch sizes (the per-B speedup is
dispatch-bound, so it is largest at small B and noisier at large B on
shared CI hosts — the geomean is the stable statistic).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

BATCHES = (1, 8, 32)
SMOKE_BATCHES = (1,)        # dispatch-bound claim is strongest at small B
STEPS = 16
SMOKE_STEPS = 8
PROMPT = 8
LAST_RESULTS: dict = {}


REPS = 3


def _bench_s(eng, batch, steps, *, fused, reps=REPS):
    """Best-of-N wall seconds for one generate() call (prefill + steps)."""
    out = eng.generate(batch, steps, fused=fused)     # warm the traces
    np.asarray(out)
    best = float("inf")
    for _ in range(reps):                             # best-of-N: CI hosts
        t0 = time.perf_counter()                      # are noisy neighbors
        np.asarray(eng.generate(batch, steps, fused=fused))
        best = min(best, time.perf_counter() - t0)
    return best


def _bench(eng, batch, steps, *, fused, reps=REPS):
    best = _bench_s(eng, batch, steps, fused=fused, reps=reps)
    return batch["tokens"].shape[0] * steps / best


def _phase_split(eng, batch, steps, *, fused, reps=REPS):
    """Split throughput into prefill vs decode tok/s by two-point
    timing: a steps=1 run is prefill + one sampled token (the prefill
    phase), and the marginal time for the remaining steps-1 tokens is
    pure decode.  Noisy hosts can invert the subtraction — fall back to
    the combined rate rather than report a negative."""
    B = batch["tokens"].shape[0]
    t1 = _bench_s(eng, batch, 1, fused=fused, reps=reps)
    tn = _bench_s(eng, batch, steps, fused=fused, reps=reps)
    prefill = B * batch["tokens"].shape[1] / t1
    decode = (B * (steps - 1) / (tn - t1) if tn > t1
              else B * steps / tn)
    return prefill, decode, B * steps / tn


def main(full: bool = True) -> int:
    from repro import configs
    from repro.core import policy as pol
    from repro.models import lm

    from repro.serve.engine import ServeEngine

    cfg = configs.get_smoke("qwen3_4b")
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    qparams = lm.quantize_params(params, cfg)
    n = lm.n_bit_slots(cfg)
    ctrl = pol.BudgetController(
        {"int4": pol.fixed(4), "int8": pol.fixed(8)},
        {"int4": 1.0, "int8": 2.0}, n)

    batches = BATCHES if full else SMOKE_BATCHES
    steps = STEPS if full else SMOKE_STEPS
    reps = REPS                 # reps are cheap next to compiles; keep
                                # best-of-3 for noisy-neighbor robustness
    results = {}
    for B in batches:
        eng = ServeEngine(cfg, qparams, max_len=64, controller=ctrl)
        batch = {"tokens": jax.random.randint(key, (B, PROMPT), 0,
                                              cfg.vocab_size)}
        eng.set_budget(10.0)                          # fixed int8, (L,) bits
        prefill_rate, decode_rate, fixed_fused = _phase_split(
            eng, batch, steps, fused=True, reps=reps)
        fixed_loop = _bench(eng, batch, steps, fused=False, reps=reps)
        results[B] = {
            "fixed_int8_fused_tok_s": round(fixed_fused, 1),
            "fixed_int8_loop_tok_s": round(fixed_loop, 1),
            "fused_speedup_vs_loop": round(fixed_fused / fixed_loop, 2),
            "prefill_tok_s": round(prefill_rate, 1),
            "decode_tok_s": round(decode_rate, 1),
        }
        line = (f"B={B:>2}: fused {fixed_fused:8.1f} tok/s (prefill "
                f"{prefill_rate:8.1f} / decode {decode_rate:8.1f}) | loop "
                f"{fixed_loop:8.1f} tok/s ({fixed_fused / fixed_loop:4.2f}x)")
        if full:
            # per-request budgets: alternate int8/int4 rows, (B, L) bit
            # matrix (smoke skips it — the grouped-dispatch benchmark
            # owns the mixed-precision overhead trend)
            eng.set_budget(jnp.where(jnp.arange(B) % 2 == 0, 10.0, 0.5))
            mixed_fused = _bench(eng, batch, steps, fused=True, reps=reps)
            results[B].update({
                "mixed_budgets_fused_tok_s": round(mixed_fused, 1),
                "mixed_precision_cost": round(fixed_fused / mixed_fused, 2),
            })
            line += f" | mixed-budget fused {mixed_fused:8.1f} tok/s"
        print(line)

    speedups = [results[B]["fused_speedup_vs_loop"] for B in batches]
    geomean = float(np.prod(speedups) ** (1.0 / len(speedups)))
    LAST_RESULTS.clear()
    LAST_RESULTS.update(
        {"steps": steps, "prompt_len": PROMPT,
         "fused_speedup_geomean": round(geomean, 2), "per_batch": results})
    ok = geomean >= 1.1
    print(f"claim (scan-fused vs per-token loop, geomean over "
          f"B={list(batches)}): {geomean:.2f}x -> "
          f"{'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
