"""Bit-grouped dispatch vs the per-row vmap baseline on mixed-budget
serving, with per-request EDP surfaced from RequestStats.

The per-row vmap path requantizes the shared weight container once per
batch ROW — O(B·K·N) weight work and B materialized weight copies per
linear.  The grouped path (kernels/ops.py, the default) requantizes once
per *distinct* bit family, runs one batch GEMM per family, and gathers
each row's result — O(G·K·N) weight work at G = |{4, 8}| here.  At
serving batch sizes that difference IS the engine's mixed-precision
overhead, so this benchmark is the serving-scale claim of the kernel
dispatch refactor.

Claims checked (rc != 0 on failure):
  * grouped >= vmap throughput at B=32 on the mixed-budget fused decode;
  * tighter budgets price to strictly lower per-request EDP (AP model).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

BATCHES = (8, 32)
SMOKE_BATCHES = (32,)       # the gated claim lives at B=32
STEPS = 12
SMOKE_STEPS = 8
PROMPT = 8
REPS = 3
LAST_RESULTS: dict = {}


def _bench(eng, batch, steps, reps=REPS):
    np.asarray(eng.generate(batch, steps))            # warm the traces
    best = float("inf")
    for _ in range(reps):                             # best-of-N: CI hosts
        t0 = time.perf_counter()                      # are noisy neighbors
        np.asarray(eng.generate(batch, steps))
        best = min(best, time.perf_counter() - t0)
    return batch["tokens"].shape[0] * steps / best


def main(full: bool = True) -> int:
    from repro import configs
    from repro.core import policy as pol
    from repro.kernels import ops
    from repro.models import lm
    from repro.serve.engine import ServeEngine

    import dataclasses
    # the tiny smoke model's 64x128 linears vanish under scheduler overhead;
    # scale the GEMMs to serving-representative dims (B=32 decode is weight
    # -requant bound on the vmap path at these sizes) while keeping the
    # harness CI-fast
    cfg = dataclasses.replace(
        configs.get_smoke("qwen3_4b"), name="qwen3_4b_bench",
        d_model=256, d_ff=1024, n_heads=8, n_kv_heads=4, head_dim=0)
    cfg = dataclasses.replace(cfg, head_dim=cfg.d_model // cfg.n_heads)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    qparams = lm.quantize_params(params, cfg)
    n = lm.n_bit_slots(cfg)
    ctrl = pol.BudgetController(
        {"int4": pol.fixed(4), "int8": pol.fixed(8)},
        {"int4": 1.0, "int8": 2.0}, n)

    batches = BATCHES if full else SMOKE_BATCHES
    steps = STEPS if full else SMOKE_STEPS
    reps = REPS if full else 2
    results = {}
    for B in batches:
        batch = {"tokens": jax.random.randint(key, (B, PROMPT), 0,
                                              cfg.vocab_size)}
        budgets = jnp.where(jnp.arange(B) % 2 == 0, 10.0, 0.5)
        eng = ServeEngine(cfg, qparams, max_len=64, controller=ctrl)
        eng.set_budget(budgets)
        grouped = _bench(eng, batch, steps, reps)
        with ops.row_dispatch("vmap"):                # baseline traces here
            eng_v = ServeEngine(cfg, qparams, max_len=64, controller=ctrl)
            eng_v.set_budget(budgets)
            vmapped = _bench(eng_v, batch, steps, reps)
        results[B] = {
            "grouped_tok_s": round(grouped, 1),
            "vmap_tok_s": round(vmapped, 1),
            "grouped_speedup_vs_vmap": round(grouped / vmapped, 2),
        }
        print(f"B={B:>2}: grouped {grouped:8.1f} tok/s | per-row vmap "
              f"{vmapped:8.1f} tok/s ({grouped / vmapped:4.2f}x)")

    speedup32 = results[32]["grouped_speedup_vs_vmap"]
    ok = speedup32 >= 1.0
    LAST_RESULTS.clear()
    LAST_RESULTS.update({
        "steps": steps, "prompt_len": PROMPT,
        "grouped_speedup_vs_vmap_b32": speedup32,
        "per_batch": results,
    })

    if full:
        # ---- per-request EDP through the continuous API ------------------
        # (smoke skips this: serve_runtime + cnn_serve gate the same
        # per-request EDP ordering on the CI path)
        eng = ServeEngine(cfg, qparams, max_len=64, controller=ctrl,
                          n_slots=32, prefill_len=PROMPT, decode_block=8)
        rng = np.random.default_rng(0)
        rids = [eng.submit(rng.integers(0, cfg.vocab_size, (PROMPT,)),
                           max_new_tokens=8,
                           budget_s=(10.0 if i % 2 == 0 else 0.5))
                for i in range(32)]
        res = eng.run()
        edp8 = float(np.mean([res[r].edp for i, r in enumerate(rids)
                              if i % 2 == 0]))
        edp4 = float(np.mean([res[r].edp for i, r in enumerate(rids)
                              if i % 2 == 1]))
        print(f"per-request EDP (32 requests, mixed budgets): int8 rows "
              f"{edp8:.3e} J·s | int4 rows {edp4:.3e} J·s "
              f"({edp8 / edp4:.1f}x) — traces: "
              f"prefill={eng.stats.prefill_traces} "
              f"decode={eng.stats.decode_traces}")
        ok = ok and 0 < edp4 < edp8
        LAST_RESULTS.update({"edp_int8_mean_js": edp8,
                             "edp_int4_mean_js": edp4})
    print(f"claim (grouped >= vmap at B=32, EDP ordered): "
          f"{speedup32:.2f}x -> {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
