"""Fig. 7 — design-space exploration: energy / latency / GOPS/W/mm^2 vs
average precision for AlexNet, VGG16, ResNet50 on IR and LR configs.

Paper trends asserted:
  (a) energy: VGG16 > ResNet50 > AlexNet; rises super-linearly with bits
      (ResNet50 LR 2b->8b is ~10.5x in the paper);
  (b) latency ~flat vs precision; LR >> IR (folding);
  (c) GOPS/W/mm^2: LR > IR (IR area is enormous); decreasing in bits."""
from __future__ import annotations

import numpy as np

from repro.apsim.energy import SRAM
from repro.apsim.mapper import IR_CONFIG, LR_CONFIG, simulate_network
from repro.apsim.workloads import NETWORKS


def sweep():
    rows = []
    rng = np.random.default_rng(0)
    for net in ("alexnet", "vgg16", "resnet50"):
        layers = NETWORKS[net]()
        n_gemm = sum(1 for l in layers if l.kind in ("conv", "fc"))
        for cfg in (LR_CONFIG, IR_CONFIG):
            for avg_bits in (2, 4, 6, 8):
                # several per-layer mixes with this average (paper: means
                # across combinations of similar average precision)
                metrics = []
                for trial in range(3):
                    if trial == 0:
                        bits = [avg_bits] * n_gemm
                    else:
                        lo = max(2, avg_bits - 2)
                        hi = min(8, avg_bits + 2)
                        bits = rng.integers(lo, hi + 1, n_gemm)
                        shift = avg_bits - float(np.mean(bits))
                        bits = np.clip(np.round(bits + shift), 2, 8
                                       ).astype(int).tolist()
                    r = simulate_network(layers, cfg, SRAM, bits=bits,
                                         network=net)
                    metrics.append((r.energy_j, r.latency_s,
                                    r.gops_per_w_per_mm2))
                e, l, g = (float(np.mean([m[i] for m in metrics]))
                           for i in range(3))
                rows.append(dict(net=net, cfg=cfg.name, bits=avg_bits,
                                 energy_j=e, latency_s=l, gopswmm2=g))
    return rows


def main() -> int:
    rows = sweep()
    print("fig7: DSE (SRAM), mean over per-layer mixes per avg precision")
    print("net,config,avg_bits,energy_J,latency_s,GOPS_per_W_per_mm2")
    for r in rows:
        print(f"{r['net']},{r['cfg']},{r['bits']},{r['energy_j']:.4g},"
              f"{r['latency_s']:.4g},{r['gopswmm2']:.4g}")

    def get(net, cfg, bits, key):
        return next(r[key] for r in rows
                    if r["net"] == net and r["cfg"] == cfg
                    and r["bits"] == bits)

    checks = {
        "energy_order_vgg_gt_rn50_gt_alex": (
            get("vgg16", "LR", 8, "energy_j")
            > get("resnet50", "LR", 8, "energy_j")
            > get("alexnet", "LR", 8, "energy_j")),
        "rn50_energy_scaling_2to8": 5.0 < (
            get("resnet50", "LR", 8, "energy_j")
            / get("resnet50", "LR", 2, "energy_j")) < 20.0,
        "latency_flat_vs_bits": (
            get("vgg16", "LR", 8, "latency_s")
            / get("vgg16", "LR", 2, "latency_s")) < 1.6,
        "lr_slower_than_ir": (
            get("resnet50", "LR", 8, "latency_s")
            > get("resnet50", "IR", 8, "latency_s")),
        "lr_more_area_efficient": (
            get("vgg16", "LR", 8, "gopswmm2")
            > get("vgg16", "IR", 8, "gopswmm2")),
    }
    ok = True
    for k, v in checks.items():
        print(f"check,{k},{bool(v)}")
        ok &= bool(v)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
