"""Benchmark harness: one module per paper table/figure + the roofline.

``python -m benchmarks.run [--skip-roofline]`` runs everything and exits
non-zero if any paper-claim check fails."""
from __future__ import annotations

import sys
import time


def main() -> int:
    from benchmarks import (calibrate, fig5_runtimes, fig6_technology,
                            fig7_dse, fig8_breakdown, roofline,
                            table7_bitfluid, table8_sota)
    mods = [
        ("calibrate", calibrate),
        ("fig5_runtimes", fig5_runtimes),
        ("fig6_technology", fig6_technology),
        ("fig7_dse", fig7_dse),
        ("fig8_breakdown", fig8_breakdown),
        ("table7_bitfluid", table7_bitfluid),
        ("table8_sota", table8_sota),
    ]
    if "--skip-roofline" not in sys.argv:
        mods.append(("roofline", roofline))
    failed = []
    for name, mod in mods:
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            rc = mod.main()
        except Exception as e:                      # noqa: BLE001
            print(f"ERROR in {name}: {e!r}")
            rc = 1
        print(f"[{name}] rc={rc} ({time.time() - t0:.1f}s)")
        if rc:
            failed.append(name)
    print(f"\n==== benchmarks summary: "
          f"{len(mods) - len(failed)}/{len(mods)} passed "
          f"{'FAILED: ' + ','.join(failed) if failed else ''} ====")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
