"""Benchmark harness: one module per paper table/figure + the roofline.

``python -m benchmarks.run [--skip-roofline]`` runs everything and exits
non-zero if any paper-claim check fails.

``--smoke`` is the headless CI mode: it runs the analytic modules (no
dry-run artifacts required, so the roofline is skipped) at REDUCED depth
(smaller batch/step grids — the CI smoke must stay well under the
tier-1 budget; add ``--full`` to keep full benchmark depth), records
per-module wall time and status into a ``BENCH_*.json`` file
(``--out``, default ``BENCH_smoke.json``), and still exits non-zero on
any paper-claim failure — CI marks the step non-blocking so the perf
trajectory accumulates without gating merges."""
from __future__ import annotations

import argparse
import inspect
import json
import platform
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="headless analytic subset + BENCH json record")
    ap.add_argument("--full", action="store_true",
                    help="keep full benchmark depth even under --smoke")
    ap.add_argument("--out", default="BENCH_smoke.json",
                    help="where --smoke writes its record")
    args = ap.parse_args(argv)
    full = args.full or not args.smoke

    from benchmarks import (calibrate, cnn_serve, dist_scaling,
                            fig5_runtimes, fig6_technology, fig7_dse,
                            fig8_breakdown, grouped_dispatch, prefix_cache,
                            roofline, serve_runtime, serve_throughput,
                            spec_decode, table7_bitfluid, table8_sota,
                            traffic_elasticity)
    mods = [
        ("calibrate", calibrate),
        ("fig5_runtimes", fig5_runtimes),
        ("fig6_technology", fig6_technology),
        ("fig7_dse", fig7_dse),
        ("fig8_breakdown", fig8_breakdown),
        ("table7_bitfluid", table7_bitfluid),
        ("table8_sota", table8_sota),
        ("serve_throughput", serve_throughput),
        ("grouped_dispatch", grouped_dispatch),
        ("cnn_serve", cnn_serve),
        ("serve_runtime", serve_runtime),
        ("traffic_elasticity", traffic_elasticity),
        ("prefix_cache", prefix_cache),
        ("spec_decode", spec_decode),
        ("dist_scaling", dist_scaling),
    ]
    if not (args.skip_roofline or args.smoke):
        mods.append(("roofline", roofline))
    failed = []
    record = {}
    t_all = time.time()
    for name, mod in mods:
        print(f"\n===== {name} =====")
        t0 = time.time()
        # depth-aware modules take full=; the rest keep a bare main()
        kw = ({"full": full}
              if "full" in inspect.signature(mod.main).parameters else {})
        try:
            rc = mod.main(**kw)
        except Exception as e:                      # noqa: BLE001
            print(f"ERROR in {name}: {e!r}")
            rc = 1
        dt = time.time() - t0
        print(f"[{name}] rc={rc} ({dt:.1f}s)")
        record[name] = {"rc": int(rc or 0), "seconds": round(dt, 3)}
        metrics = getattr(mod, "LAST_RESULTS", None)
        if metrics:                     # modules may export a metric dict
            record[name]["metrics"] = metrics
        if rc:
            failed.append(name)
    print(f"\n==== benchmarks summary: "
          f"{len(mods) - len(failed)}/{len(mods)} passed "
          f"{'FAILED: ' + ','.join(failed) if failed else ''} ====")
    if args.smoke:
        out = {
            "suite": "smoke",
            "python": platform.python_version(),
            "total_seconds": round(time.time() - t_all, 3),
            "passed": len(mods) - len(failed),
            "failed": failed,
            "modules": record,
        }
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1)
        print(f"[smoke] wrote {args.out}")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
