"""Table VII — bit fluidity: HAWQ-V3 ResNet18 mixed-precision configs on
BF-IMNA (LR), normalized energy/latency + EDP vs fixed INT4/INT8.

Two halves, one table: the analytic AP simulator prices each config's
energy/latency/EDP, and the serve-form CNN path runs every config through
the REAL kernel dispatch layer (ops.serve_linear, int8 containers) in one
compiled program — fidelity vs the fp reference supplies the accuracy
axis of the accuracy-vs-EDP trade-off functionally, with trace-count == 1
across all five configuration switches (the zero-retrace claim).

Accuracy and model size columns are adopted from HAWQ-V3 [53] (inputs to
the trade-off, not simulator outputs — same as the paper)."""
from __future__ import annotations

from repro.apsim.energy import SRAM
from repro.apsim.mapper import LR_CONFIG, simulate_network
from repro.apsim.workloads import (HAWQV3_METADATA, HAWQV3_RESNET18,
                                   gemm_layers, per_layer_bits, resnet18)

PAPER = {  # constraint: (norm_energy, norm_latency, edp)
    "int4": (3.29, 1.004, 0.58),
    "high": (1.13, 1.001, 1.69),
    "medium": (1.22, 1.002, 1.56),
    "low": (1.90, 1.004, 1.00),
    "int8": (1.0, 1.0, 1.91),
}

LAST_RESULTS: dict = {}


def run():
    layers = resnet18()
    reports = {}
    for name, vec in HAWQV3_RESNET18.items():
        bits = per_layer_bits(layers, vec)
        reports[name] = simulate_network(layers, LR_CONFIG, SRAM, bits=bits,
                                         network="resnet18")
    return reports


def main(full: bool = True) -> int:
    reports = run()
    base = reports["int8"]
    # paper normalizes energy so that INT4 consumes less absolute energy
    # but reports >1 normalized energy due to its fixed-latency basis; we
    # report our simulator's direct normalization and the paper's values.
    from repro.serve.cnn import hawq_fidelity_sweep

    # smoke shrinks the serve-fidelity image (same program structure,
    # same trace-count gate, lighter compile); --full keeps paper depth
    fid, n_traces = hawq_fidelity_sweep(image=32 if full else 16)
    print("table7: HAWQ-V3 ResNet18 on BF-IMNA (LR/SRAM) + serve kernels")
    print("constraint,avg_bits,norm_energy,norm_latency,edp_rel,"
          "paper_edp_ordering,serve_fidelity,size_mb,top1")
    edps = {}
    ok = True
    for name in ("int4", "low", "medium", "high", "int8"):
        r = reports[name]
        vec = HAWQV3_RESNET18[name]
        bits = per_layer_bits(resnet18(), vec)
        avg = sum(bits) / len(bits)
        ne = r.energy_j / base.energy_j
        nl = r.latency_s / base.latency_s
        edps[name] = r.edp
        meta = HAWQV3_METADATA[name]
        print(f"{name},{avg:.2f},{ne:.3f},{nl:.4f},"
              f"{r.edp / base.edp:.3f},{PAPER[name][2]},{fid[name]:.4f},"
              f"{meta['size_mb']},{meta['top1']}")
    # ordering claims of the paper's Table VII:
    #  * INT4 best EDP; among mixed configs low < medium < high EDP;
    #  * all mixed EDPs beat INT8;
    #  * latency ~constant (within 2%) across configs (bit-serial cols);
    #  * run FUNCTIONALLY: every config through one compiled serve-form
    #    program (zero retrace), higher-bit ends more faithful to fp.
    ok &= edps["int4"] < edps["low"] < edps["medium"] < edps["high"]
    ok &= edps["high"] < edps["int8"]
    lat_spread = (max(r.latency_s for r in reports.values())
                  / min(r.latency_s for r in reports.values()))
    ok &= lat_spread < 1.10
    ok &= n_traces == 1
    ok &= fid["int8"] > fid["int4"]
    print(f"check,edp_ordering_int4<low<med<high<int8,{ok}")
    print(f"check,latency_spread,{lat_spread:.3f}")
    print(f"check,serve_traces,{n_traces}")
    print(f"check,fidelity_int8>{fid['int8']:.4f}>int4>{fid['int4']:.4f}")
    LAST_RESULTS.clear()
    LAST_RESULTS.update({
        "serve_traces": n_traces,
        "serve_fidelity": {k: round(v, 4) for k, v in fid.items()},
        "edp_rel": {k: round(edps[k] / base.edp, 3) for k in edps},
    })
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
