"""Fig. 8 — breakdowns: (a) energy by op class, (b) GEMM latency by phase.

Paper claims asserted: GEMM (+pooling) dominates energy; within GEMM, the
*reduction* phase (sequential row-pair adds) dominates latency — which is
why end-to-end latency is nearly precision-independent (Fig. 7b)."""
from __future__ import annotations

from repro.apsim import costmodel as cm
from repro.apsim.energy import SRAM
from repro.apsim.mapper import LR_CONFIG, simulate_network
from repro.apsim.workloads import NETWORKS


def energy_breakdown(net: str, bits: int = 8):
    layers = NETWORKS[net]()
    rep = simulate_network(layers, LR_CONFIG, SRAM, bits=bits, network=net)
    out = rep.breakdown()
    total_e = sum(d["energy_j"] for d in out.values())
    return {k: d["energy_j"] / total_e for k, d in out.items()}, rep


def gemm_latency_breakdown(bits: int = 8):
    """Multiply phase vs reduction phase vs io for a representative GEMM."""
    i, j, u = 512, 4608, 196          # VGG16 conv-ish dims
    opc = 1
    mult = cm.Cost()
    passes = 4 * bits * bits
    mult.compares += passes
    mult.writes += passes
    red = cm.Cost()
    seq = opc * (min(j, LR_CONFIG.cap_rows - 1) - 1)
    red.compares += 4 * seq
    red.writes += 4 * seq
    io = cm.Cost()
    io.writes += 2 * bits
    io.reads += 2 * bits + 13
    c = {"multiply": mult.cycles(SRAM), "reduce": red.cycles(SRAM),
         "io": io.cycles(SRAM)}
    tot = sum(c.values())
    return {k: v / tot for k, v in c.items()}


def main() -> int:
    print("fig8a: energy fraction by op class (LR/SRAM/8b)")
    ok = True
    for net in ("alexnet", "vgg16", "resnet50"):
        frac, _ = energy_breakdown(net)
        gemm = frac.get("gemm", 0.0)
        pool = frac.get("maxpool", 0.0) + frac.get("avgpool", 0.0)
        line = ",".join(f"{k}:{v:.3f}" for k, v in sorted(frac.items()))
        print(f"{net},{line}")
        ok &= gemm + pool > 0.80          # paper: GEMM+pooling dominate
    print("fig8b: GEMM latency fraction by phase (8b)")
    lat = gemm_latency_breakdown()
    for k, v in lat.items():
        print(f"gemm_latency,{k},{v:.3f}")
    ok &= lat["reduce"] > lat["multiply"]  # paper: reduction dominates
    print(f"check,gemm_pool_dominate_and_reduce_bound,{ok}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
