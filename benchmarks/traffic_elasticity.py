"""The two headline elasticity experiments, run on the trace-driven
traffic harness (`repro.serve.traffic`, DESIGN.md §9) and persisted as
``BENCH_traffic.json``.

(a) **Spike response** — a seeded Poisson stream with a systematic 10x
    burst.  The closed-loop FluidController (deliberately optimistic
    0.5x predictions, like benchmarks/serve_runtime.py) must hold a
    tight whole-stream EDP SLO *through the burst* by degrading bits,
    while the open-loop baseline trusts its table and overshoots.
(b) **Hourly elasticity** — a diurnal (sinusoid) arrival pattern under a
    tick-windowed FluidController (a *rate* SLO: EDP per window of
    scheduler ticks).  Peak phases must serve at lower mean bits than
    trough phases, which relax back to full precision.

Claims checked (rc != 0 on failure):
  * spike: closed loop lands within 1.1x of the EDP SLO; open loop
    overshoots by >= 1.3x; closed-loop SLO attainment >= open loop;
    closed-loop mean bits strictly below open loop; queue depth peaks
    during the burst; prefill/decode trace counters stay at 1.
  * diurnal: peak-phase mean bits < trough-phase mean bits (the loop
    flexes with load); nothing goes unserved.

Both experiments are fully deterministic (seeded arrivals, tick-based
latency, analytic EDP), so the regression gate (benchmarks/compare.py)
can hold their metrics to tight tolerances.
"""
from __future__ import annotations

import json
import time

LAST_RESULTS: dict = {}

SEED = 3
PROMPT = 8
MAX_NEW = 8
ARCH = "qwen3_4b"


def _engine(cfg, qparams, controller, n_slots):
    from repro.serve.engine import ServeEngine

    return ServeEngine(cfg, qparams, max_len=64, controller=controller,
                       n_slots=n_slots, prefill_len=PROMPT,
                       decode_block=MAX_NEW)


def _replay(trace, eng, use_budgets):
    from repro.serve import traffic as tf

    res = tf.TraceReplayer(trace, {ARCH: eng},
                           use_budgets=use_budgets).replay()
    return res


def spike_response(cfg, qparams, n, cfgs, preds, actual, *, full):
    """(a): does the closed loop hold the EDP SLO through a 10x burst?"""
    from repro.core import policy as pol
    from repro.serve import traffic as tf

    import numpy as np

    ticks, rate = (72, 1.5) if full else (24, 0.8)
    burst_at, burst_len = ticks // 3, max(ticks // 8, 3)
    kw = dict(ticks=ticks, rate=rate, seed=SEED, burst_mag=10.0,
              burst_at=burst_at, burst_len=burst_len, prompt_len=PROMPT,
              max_new_tokens=MAX_NEW)
    probe = tf.synth_trace("spike", **kw)
    n_req = probe.n_requests
    # prompt lengths vary per request and EDP scales with units^2, so the
    # whole-stream SLO prices the trace's ACTUAL planned token counts
    units = np.asarray([
        len(tf.payload_tokens(probe, r, cfg.vocab_size)) + r.max_new_tokens
        for r in probe.requests], np.float64)
    scale = float(np.sum((units / (PROMPT + MAX_NEW)) ** 2))
    slo = preds["int8"] * 1.2 * scale           # tight whole-stream budget
    # per-request SLO metadata = the flat fair share (attainment
    # accounting); per-request BUDGET = what the optimistic table says an
    # int8 request costs, padded 1.2x — the open loop trusts it blindly
    trace = tf.synth_trace("spike", slo_edp=slo / n_req,
                           budget=[preds["int8"] * 1.2], **kw)

    def fluid(slo_):
        return pol.FluidController(cfgs, dict(preds), n, budget_axis="edp",
                                   slo=slo_, window=n_req)

    open_eng = _engine(cfg, qparams, fluid(float("inf")), n_slots=8)
    open_rep = _replay(trace, open_eng, use_budgets=True).report(window=6)
    closed_eng = _engine(cfg, qparams, fluid(slo), n_slots=8)
    closed_rep = _replay(trace, closed_eng, use_budgets=False).report(window=6)

    open_x = open_rep["total_edp_js"] / slo
    closed_x = closed_rep["total_edp_js"] / slo
    traces = [open_eng.stats.prefill_traces, open_eng.stats.decode_traces,
              closed_eng.stats.prefill_traces,
              closed_eng.stats.decode_traces]
    base_q = max(closed_rep["queue_depth"]["series"][:burst_at] or [0])
    burst_q = closed_rep["queue_depth"]["peak"]

    print(f"spike: {n_req} requests over {ticks} ticks, 10x burst "
          f"@[{burst_at}, {burst_at + burst_len}), EDP SLO {slo:.3e} J*s")
    print(f"  open loop  : {open_x:5.2f}x SLO, mean_wbits="
          f"{open_rep['mean_wbits']:.2f}, attainment="
          f"{open_rep['slo_attainment']}")
    print(f"  closed loop: {closed_x:5.2f}x SLO, mean_wbits="
          f"{closed_rep['mean_wbits']:.2f}, attainment="
          f"{closed_rep['slo_attainment']}, p50/p99 latency "
          f"{closed_rep['p50_latency_ticks']:.0f}/"
          f"{closed_rep['p99_latency_ticks']:.0f} ticks, queue peak "
          f"{burst_q} (pre-burst {base_q})")
    print(f"  bits/window: {closed_rep['mean_wbits_per_window']}")

    ok = (closed_x <= 1.1
          and open_x >= 1.3
          and closed_rep["slo_attainment"] >= open_rep["slo_attainment"]
          and closed_rep["mean_wbits"] < open_rep["mean_wbits"]
          and burst_q > base_q
          and closed_rep["unserved"] == 0
          and traces == [1, 1, 1, 1])
    metrics = {
        "n_requests": n_req, "ticks": ticks, "burst_mag": 10.0,
        "slo_edp_js": slo,
        "open_loop_vs_slo": round(open_x, 4),
        "closed_loop_vs_slo": round(closed_x, 4),
        "open_slo_attainment": open_rep["slo_attainment"],
        "closed_slo_attainment": closed_rep["slo_attainment"],
        "open_mean_wbits": open_rep["mean_wbits"],
        "closed_mean_wbits": closed_rep["mean_wbits"],
        "closed_p50_latency_ticks": closed_rep["p50_latency_ticks"],
        "closed_p99_latency_ticks": closed_rep["p99_latency_ticks"],
        "queue_peak": burst_q, "queue_prespike_peak": base_q,
        "traces": traces,
    }
    detail = {"metrics": metrics, "open": open_rep, "closed": closed_rep}
    return ok, metrics, detail


def hourly_elasticity(cfg, qparams, n, cfgs, actual, *, full):
    """(b): diurnal load under a rate SLO — bits flex with the phase."""
    from repro.core import policy as pol
    from repro.serve import traffic as tf

    ticks, rate, window_ticks = (96, 2.0, 12) if full else (48, 1.0, 6)
    phase = ticks // 4                          # rise / peak / fall / trough
    trace = tf.synth_trace("diurnal", ticks=ticks, rate=rate, seed=SEED + 2,
                           depth=0.9, prompt_len=PROMPT,
                           max_new_tokens=MAX_NEW)
    # rate SLO: 0.75x of what serving the MEAN arrival rate at int8 costs
    # per window — tight at peak (rate*1.9), loose at trough (rate*0.1)
    slo = window_ticks * rate * actual["int8"] * 0.75
    fluid = pol.FluidController(cfgs, dict(actual), n, budget_axis="edp",
                                slo=slo, window_ticks=window_ticks)
    eng = _engine(cfg, qparams, fluid, n_slots=8)
    rep = _replay(trace, eng, use_budgets=False).report(window=phase)

    bits = rep["mean_wbits_per_window"][:4]
    arrivals = rep["arrivals_per_window"][:4]
    peak_bits = bits[1] if bits[1] is not None else 8.0
    trough_bits = bits[3] if bits[3] is not None else 8.0
    print(f"diurnal: {trace.n_requests} requests over {ticks} ticks, rate "
          f"SLO {slo:.3e} J*s per {window_ticks} ticks")
    print(f"  arrivals/phase: {arrivals}")
    print(f"  bits/phase    : {bits} (peak {peak_bits} vs trough "
          f"{trough_bits})")
    print(f"  unserved={rep['unserved']}, queue peak "
          f"{rep['queue_depth']['peak']}, overall mean_wbits="
          f"{rep['mean_wbits']:.2f}")

    ok = (peak_bits < trough_bits
          and trough_bits == 8.0
          and rep["unserved"] == 0
          and arrivals[1] > arrivals[3]
          and eng.stats.prefill_traces == eng.stats.decode_traces == 1)
    metrics = {
        "n_requests": trace.n_requests, "ticks": ticks,
        "slo_edp_js_per_window": slo, "window_ticks": window_ticks,
        "arrivals_per_phase": arrivals,
        "mean_wbits_per_phase": bits,
        "peak_phase_wbits": peak_bits, "trough_phase_wbits": trough_bits,
        "mean_wbits": rep["mean_wbits"],
        "queue_peak": rep["queue_depth"]["peak"],
        "unserved": rep["unserved"],
    }
    return ok, metrics, {"metrics": metrics, "closed": rep}


def main(full: bool = False, out: str = "BENCH_traffic.json") -> int:
    import jax

    from repro import configs
    from repro.core import policy as pol
    from repro.models import lm
    from repro.serve import predict_table

    t0 = time.time()
    cfg = configs.get_smoke(ARCH)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    qparams = lm.quantize_params(params, cfg)
    n = lm.n_bit_slots(cfg)
    cfgs = {"int4": pol.fixed(4), "int8": pol.fixed(8)}
    actual = predict_table(lm.layer_gemm_dims(cfg), cfgs, axis="edp",
                           units=PROMPT + MAX_NEW,
                           head=lm.head_gemm_dims(cfg))
    preds = {k: v / 2 for k, v in actual.items()}   # optimistic table

    ok_a, m_a, d_a = spike_response(cfg, qparams, n, cfgs, preds, actual,
                                    full=full)
    ok_b, m_b, d_b = hourly_elasticity(cfg, qparams, n, cfgs, actual,
                                       full=full)

    record = {
        "suite": "traffic" + ("-full" if full else "-smoke"),
        "total_seconds": round(time.time() - t0, 3),
        "modules": {
            "spike_response": {"rc": 0 if ok_a else 1, **d_a},
            "hourly_elasticity": {"rc": 0 if ok_b else 1, **d_b},
        },
    }
    with open(out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"[traffic] wrote {out}")

    LAST_RESULTS.clear()
    LAST_RESULTS.update({"spike_response": m_a, "hourly_elasticity": m_b})
    ok = ok_a and ok_b
    print(f"claims (closed loop holds EDP SLO through 10x spike; bits flex "
          f"with diurnal phase): {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="full-size traces (nightly); default smoke sizes")
    ap.add_argument("--out", default="BENCH_traffic.json")
    args = ap.parse_args()
    raise SystemExit(main(full=args.full, out=args.out))
