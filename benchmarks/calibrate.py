"""The one-time calibration fit behind energy.py's CALIBRATED constants.

The paper's Table VI gives write energies but not per-cell compare/read
energy, the ReRAM sense-cycle slowdown, or the fraction of LUT-pass
writes that actually toggle a ReRAM cell.  This script fits those three
constants against the paper's own published numbers:

  targets (paper §V.A):
    * ReRAM/SRAM VGG16 energy ratios 80.9x @2b .. 63.1x @8b (Fig. 6)
    * ReRAM/SRAM latency ratio ~1.85x, flat in precision
    * absolute LR/SRAM ResNet50 energies 0.009 J @2b / 0.095 J @8b (Fig 7a)

Run it to regenerate the constants and their residuals; the values frozen
into `apsim/energy.py` come from exactly this fit (single fit — nothing
downstream re-tunes).  `python -m benchmarks.calibrate`
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.apsim import energy as en
from repro.apsim.mapper import LR_CONFIG, simulate_network
from repro.apsim.workloads import resnet50, vgg16

PAPER_RATIOS = {2: 80.9, 3: 72.9, 4: 68.9, 5: 66.6, 6: 65.0, 7: 63.9,
                8: 63.1}
PAPER_RN50 = {2: 0.009, 8: 0.095}


def loss_for(e_cmp: float, toggle: float, sense: float) -> float:
    sram = dataclasses.replace(en.SRAM, e_compare_j=e_cmp, e_read_j=e_cmp)
    reram = dataclasses.replace(en.RERAM, e_compare_j=e_cmp, e_read_j=e_cmp,
                                lut_toggle_frac=toggle,
                                compare_cycles=sense, read_cycles=sense)
    v = vgg16()
    loss = 0.0
    for M, target in PAPER_RATIOS.items():
        rs = simulate_network(v, LR_CONFIG, sram, bits=M).energy_j
        rr = simulate_network(v, LR_CONFIG, reram, bits=M).energy_j
        loss += ((rr / rs - target) / target) ** 2
    r = resnet50()
    for M, target in PAPER_RN50.items():
        e = simulate_network(r, LR_CONFIG, sram, bits=M).energy_j
        loss += 4.0 * ((e - target) / target) ** 2
    return loss


def main() -> int:
    grid_cmp = np.geomspace(1e-14, 2e-13, 9)
    grid_tog = np.linspace(0.2, 0.8, 7)
    grid_sense = (1.5, 1.7, 2.0)
    best = min(itertools.product(grid_cmp, grid_tog, grid_sense),
               key=lambda t: loss_for(*t))
    # local refine around the winner
    c0, t0, s0 = best
    fine = min(itertools.product(np.linspace(0.6 * c0, 1.6 * c0, 11),
                                 np.linspace(max(0.2, t0 - 0.1),
                                             min(0.8, t0 + 0.1), 9),
                                 (s0,)),
               key=lambda t: loss_for(*t))
    c, t, s = fine
    print("calibrate: fitted constants (frozen into apsim/energy.py)")
    print(f"E_COMPARE_J,{c:.3e},frozen={en.E_COMPARE_J:.3e}")
    print(f"LUT_TOGGLE_FRAC_RERAM,{t:.3f},frozen={en.LUT_TOGGLE_FRAC_RERAM}")
    print(f"reram_sense_cycles,{s},frozen={en.RERAM.compare_cycles}")
    drift_c = abs(c - en.E_COMPARE_J) / en.E_COMPARE_J
    drift_t = abs(t - en.LUT_TOGGLE_FRAC_RERAM)
    print(f"check,refit_within_15pct_of_frozen,{drift_c < 0.15 and drift_t < 0.1}")
    return 0 if (drift_c < 0.15 and drift_t < 0.1) else 1


if __name__ == "__main__":
    raise SystemExit(main())
