"""Serve-form CNN inference smoke: ResNet18 through the kernel dispatch
layer, fixed vs HAWQ-V3 budgets, fake-quant vs serve-form throughput.

What this guards (rc != 0 on failure):
  * ONE compiled program serves every budget mix — fixed budgets, all
    five HAWQ-V3 constraints, and per-request mixed batches — with
    trace-count == 1 (the zero-retrace claim of the CNN serve path);
  * per-request EDP ordering: rows resolved to int8 price strictly above
    rows resolved to int4 (the Table VII trade-off, live per image).

Throughput of the retained fake-quant path vs the serve-form kernel path
is recorded (not gated — on CPU the int8 emulation has no MXU to win on;
the number tracks the dispatch overhead trend in BENCH_smoke.json).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

IMAGE = 32
BATCH = 8
REPS = 3
LAST_RESULTS: dict = {}


def _bench(fn, *args):
    np.asarray(fn(*args))                             # warm the trace
    best = float("inf")
    for _ in range(REPS):                             # best-of-N: CI hosts
        t0 = time.perf_counter()                      # are noisy neighbors
        np.asarray(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return BATCH / best


def main(full: bool = True) -> int:
    from repro.apsim.workloads import gemm_layers
    from repro.core import policy as pol
    from repro.models import cnn
    from repro.serve.cnn import CNNServeEngine

    key = jax.random.PRNGKey(0)
    params, layers = cnn.init_cnn("resnet18", key, image=IMAGE)
    n = len(gemm_layers(layers))
    x = jax.random.normal(key, (BATCH, IMAGE, IMAGE, 3), jnp.float32)

    ctrl = pol.cnn_budget_controller("resnet18", layers=layers)
    eng = CNNServeEngine(params, layers, controller=ctrl, max_batch=BATCH)
    preds = {k: ctrl.predicted_latency_s[k] for k in ctrl.order()}
    lo = preds["hawqv3-int4"] * 1.01                  # fits int4 only
    hi = preds["hawqv3-int8"] * 1.01                  # fits everything

    # ---- every budget regime through ONE compiled program ----------------
    ok = True
    for name, budgets in [
        ("fixed-int4", lo), ("fixed-int8", hi),
        ("hawq-mixed", [lo if i % 2 else hi for i in range(BATCH)]),
        ("hawq-medium", preds["hawqv3-medium"] * 1.01),
    ]:
        logits, stats = eng.serve(x, budgets)
        ok &= bool(np.isfinite(logits).all())
        mean_b = sum(s.mean_wbits for s in stats) / len(stats)
        print(f"{name:12s} mean_wbits={mean_b:.2f} "
              f"edp[0]={stats[0].edp:.3e} J·s")
    traces = eng.stats.forward_traces
    ok &= traces == 1
    print(f"forward traces across all budget regimes: {traces} (want 1)")

    # ---- per-request EDP ordering on the mixed batch ---------------------
    _, stats = eng.serve(x, [lo if i % 2 else hi for i in range(BATCH)])
    edp8 = float(np.mean([s.edp for s in stats if s.mean_wbits == 8.0]))
    edp4 = float(np.mean([s.edp for s in stats if s.mean_wbits == 4.0]))
    ok &= 0 < edp4 < edp8
    print(f"per-request EDP: int8 rows {edp8:.3e} | int4 rows {edp4:.3e} "
          f"({edp8 / edp4:.1f}x)")

    LAST_RESULTS.clear()
    LAST_RESULTS.update({
        "image": IMAGE, "batch": BATCH,
        "forward_traces": traces,
        "edp_int8_mean_js": edp8, "edp_int4_mean_js": edp4,
    })

    if full:
        # ---- fake-quant vs serve-form throughput (recorded, not gated;
        # smoke skips it — the comparison needs its own fq compile) -------
        wv = jnp.full((n,), 8, jnp.int32)
        fq_fwd = jax.jit(lambda p, xx, v: cnn.cnn_forward(p, xx, layers,
                                                          v, v))
        fq_ips = _bench(fq_fwd, params, x, wv)
        serve_ips = _bench(lambda xx, b: eng.serve(xx, b)[0], x, hi)
        print(f"throughput @B={BATCH}: fake-quant {fq_ips:7.1f} img/s | "
              f"serve-form {serve_ips:7.1f} img/s "
              f"({serve_ips / fq_ips:4.2f}x)")
        LAST_RESULTS.update({
            "fakequant_img_s": round(fq_ips, 1),
            "serve_img_s": round(serve_ips, 1),
            "serve_vs_fakequant": round(serve_ips / fq_ips, 3),
        })
    print(f"claim (one program, EDP ordered): {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
