"""Flash attention Pallas kernel — the prefill compute hot spot.

Blockwise online-softmax attention with explicit VMEM tiling: the scores
tile (bq x bk) lives only in VMEM/registers, HBM traffic is O(S·hd) per
head instead of O(S²).  Grid (batch*heads, Sq/bq, Sk/bk), K innermost;
running max / normalizer / accumulator persist in VMEM scratch across the
K walk.  Causal + sliding-window masks derive from tile coordinates with
iota — nothing S² ever materializes.

The pure-JAX blockwise path (kernels/ref.flash_attention_chunked_ref) is
the off-TPU lowering ops.flash_attention dispatches to for long
sequences (XLA fuses it adequately and it composes with SPMD); this
kernel is the single-core TPU-optimal version for the (B·H, S, hd) hot
loop, validated against ref.flash_attention_ref in interpret mode.  MXU
alignment: bq/bk multiples of 128, hd padded by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, k_len: int, bq: int,
            bk: int, nk: int):
    i = pl.program_id(1)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (bq, hd)
    k = k_ref[0]                                   # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    vis = kpos < k_len                             # padded key slots
    if causal:
        vis &= kpos <= qpos
    if window:
        vis &= kpos > qpos - window
    s = jnp.where(vis, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(vis, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jax.lax.dot_general(
                        p.astype(v_ref.dtype), v_ref[0],
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(kk == nk - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "k_len",
                                             "scale", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0, k_len: int = 0,
                    scale: float = 0.0, bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """(BH, Sq, hd) x (BH, Sk, hd)^2 -> (BH, Sq, hd), softmax(qk^T/√hd)v.

    Sq/Sk must be multiples of bq/bk (ops.py pads; ``k_len`` masks padded
    key slots; ``scale`` defaults to padded-hd^-0.5 — pass the real one
    when hd was padded)."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    nq, nk = Sq // bq, Sk // bk
    scale = scale or hd ** -0.5
    k_len = k_len or Sk

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, k_len=k_len, bq=bq, bk=bk, nk=nk),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, kk: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, kk: (b, kk, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, kk: (b, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, kk: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
