"""Public kernel API: padding, dispatch (Pallas-TPU vs XLA ref), caching.

This module is the **single compute path** for serve-form math: every
quantized GEMM in ``models/`` reaches Pallas (TPU) or the jnp refs (CPU,
dry-run) only through the dispatchers here — ``serve_linear`` for int8 /
packed-int4 containers (scalar, traced, or per-row bits), and
``flash_attention`` for long-sequence attention.

``use_pallas()`` is True only on real TPU backends; elsewhere (this CPU
container, and inside the 512-device dry-run) the mathematically identical
ref path lowers through XLA, so compiled-artifact analysis reflects the
same algorithm.  Kernel *numerics* are validated against ref in
tests/test_kernels.py with interpret=True; setting ``REPRO_PALLAS=interpret``
in the environment routes every dispatcher through interpret-mode Pallas
(the CI kernel job).

Per-precision specializations are cached by (n_planes, block shape) via
jit's static-arg cache: switching a layer between 2/4/8 bits after warmup
costs no recompilation — the dispatch-cache realization of bit fluidity.

Bit-grouped batch execution
---------------------------
Per-request precision hands ``serve_linear`` a ``(B,)`` bit vector.  The
naive realization (one weight requantization per row) does O(B·K·N) weight
work for at most a handful of distinct bit-widths.  Instead, the grouped
path requantizes the container once per *family* in the static
``BIT_FAMILIES`` set, runs one batch GEMM per family (each at a static
plane count — the plane-serial kernel's cost ∝ bits), and gathers each
row's result from its family's accumulator: O(G·K·N) weight work,
zero-retrace (family membership is data).  ``set_bit_families`` /
``bit_families`` narrow the set to the precisions a serving policy can
actually emit; rows whose bits fall between families snap UP to the next
family, and rows ABOVE the largest family clamp down to it — so a family
set must always include its policy's widest bit-width (engines derive it
from the controller, which guarantees this; results are bit-exact
whenever the bits are in the set).  The historical
per-row vmap path is kept behind ``set_row_dispatch("vmap")`` as the
benchmark baseline.
"""
from __future__ import annotations

import contextlib
import os
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitfluid as bf
from repro.kernels import ref as kref
from repro.kernels.bitplane_matmul import bitplane_matmul as _bitplane_pallas
from repro.kernels.quant_matmul import quant_matmul as _quant_pallas
from repro.kernels.int4_matmul import int4_matmul as _int4_pallas

_FORCE: Optional[bool] = None  # tests set this to route through interpret
_INTERPRET = os.environ.get("REPRO_PALLAS", "").lower() == "interpret"
if _INTERPRET:
    _FORCE = True

# Distinct weight bit-widths the grouped per-row path specializes for.
BIT_FAMILIES = (2, 3, 4, 6, 8)
_families: Sequence[int] = BIT_FAMILIES
_row_dispatch = "grouped"


def set_force_pallas(v: Optional[bool], interpret: Optional[bool] = None
                     ) -> None:
    global _FORCE, _INTERPRET
    _FORCE = v
    if interpret is not None:
        _INTERPRET = interpret


def use_pallas() -> bool:
    if _FORCE is not None:
        return _FORCE
    return jax.default_backend() == "tpu"


def _interp(flag: bool) -> bool:
    return bool(flag or _INTERPRET)


def set_bit_families(fams: Sequence[int]) -> None:
    """Set the static family set for grouped per-row dispatch.

    Values clamp into [1, 8] (the int8 container width); serving engines
    derive this from their controller's registered configurations, so the
    grouped path runs exactly one GEMM per precision the policy can emit.
    The set MUST contain the widest bit-width rows can carry: bits between
    families snap up, but bits above the largest family clamp DOWN to it
    (there is no wider GEMM to snap up to).
    """
    global _families
    vals = tuple(sorted({min(max(int(f), 1), 8) for f in fams}))
    if not vals:
        raise ValueError("bit family set must be non-empty")
    _families = vals


def get_bit_families():
    return tuple(_families)


@contextlib.contextmanager
def bit_families(fams: Sequence[int]):
    """Scoped family set (trace-time property of the jitted caller)."""
    global _families
    prev = _families
    set_bit_families(fams)
    try:
        yield
    finally:
        _families = prev


_token_scales = False


@contextlib.contextmanager
def token_scale_mode():
    """Per-token activation scales in the per-row serve path (trace-time).

    The default per-row path reduces activation amax over every axis past
    the batch axis — one scale per request, exactly what a ``(B, 1, K)``
    single-token decode step computes.  A speculative verify chunk runs
    ``(B, U, K)`` token positions in one forward; sharing one scale across
    the U tokens would change numerics vs running them sequentially.
    Under this context the amax reduction keeps every leading axis and
    reduces only the feature axis, so each token row of the flattened
    ``(B*U, K)`` grouped GEMM carries the same scale sequential decode
    would give it — the chunked forward stays bit-identical to U
    single-token steps.
    """
    global _token_scales
    prev = _token_scales
    _token_scales = True
    try:
        yield
    finally:
        _token_scales = prev


def set_row_dispatch(mode: str) -> None:
    """'grouped' (default) or 'vmap' (the per-row baseline, kept for
    benchmarks/parity tests).  Read at trace time."""
    global _row_dispatch
    if mode not in ("grouped", "vmap"):
        raise ValueError(f"row dispatch must be 'grouped' or 'vmap', "
                         f"got {mode!r}")
    _row_dispatch = mode


def get_row_dispatch() -> str:
    return _row_dispatch


@contextlib.contextmanager
def row_dispatch(mode: str):
    global _row_dispatch
    prev = _row_dispatch
    set_row_dispatch(mode)
    try:
        yield
    finally:
        _row_dispatch = prev


def _pad_to(x: jnp.ndarray, mults) -> jnp.ndarray:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


def _block_dim(d: int) -> int:
    """128 for MXU-sized dims; small dims shrink to the next power of two
    (floor 8) so a (64, 32) tail GEMM doesn't pad every operand to 128."""
    if d >= 128:
        return 128
    return max(8, 1 << (max(d - 1, 1)).bit_length())


def _blocks_for(M: int, N: int, K: int):
    """MXU-aligned blocks; every small dim shrinks to avoid wasteful
    padding (M, N, and K alike — N/K were previously pinned at 128)."""
    return _block_dim(M), _block_dim(N), _block_dim(K)


# ---------------------------------------------------------------------------

def bitplane_matmul(x_q: jnp.ndarray, w_q: jnp.ndarray, *, n_planes: int = 8,
                    interpret: bool = False) -> jnp.ndarray:
    """int8 (M,K) @ int8-container (K,N) -> int32 (M,N), plane-serial."""
    interpret = _interp(interpret)
    if not (use_pallas() or interpret):
        return kref.bitplane_matmul_ref(x_q, w_q, n_planes)
    M, K = x_q.shape
    N = w_q.shape[1]
    bm, bn, bk = _blocks_for(M, N, K)
    xp = _pad_to(x_q, (bm, bk))
    wp = _pad_to(w_q, (bk, bn))
    out = _bitplane_pallas(xp, wp, n_planes=n_planes, bm=bm, bn=bn, bk=bk,
                           interpret=interpret)
    return out[:M, :N]


def quant_matmul(x_q: jnp.ndarray, w_q: jnp.ndarray, scale: jnp.ndarray,
                 bias: Optional[jnp.ndarray] = None, *, act: str = "none",
                 out_dtype=jnp.float32, interpret: bool = False) -> jnp.ndarray:
    """int8 (M,K) @ int8 (K,N) with fused per-channel dequant epilogue."""
    interpret = _interp(interpret)
    M, K = x_q.shape
    N = w_q.shape[1]
    scale = jnp.broadcast_to(jnp.asarray(scale, jnp.float32), (1, N))
    bias = (jnp.zeros((1, N), jnp.float32) if bias is None
            else jnp.broadcast_to(jnp.asarray(bias, jnp.float32), (1, N)))
    if not (use_pallas() or interpret):
        return kref.quant_matmul_ref(x_q, w_q, scale, bias, act, out_dtype)
    bm, bn, bk = _blocks_for(M, N, K)
    xp = _pad_to(x_q, (bm, bk))
    wp = _pad_to(w_q, (bk, bn))
    sp = _pad_to(scale, (1, bn))
    bp = _pad_to(bias, (1, bn))
    out = _quant_pallas(xp, wp, sp, bp, act=act, out_dtype=out_dtype,
                        bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:M, :N]


def int4_matmul(x_q: jnp.ndarray, w_packed: jnp.ndarray, scale: jnp.ndarray,
                *, out_dtype=jnp.float32, interpret: bool = False) -> jnp.ndarray:
    """int8 (M,K) @ halves-packed uint8 (K,N/2) with fused dequant.

    Invalid operand shapes raise ``ValueError``.  When K or the packed
    column count does not tile (padding packed columns would split the
    low/high nibble halves inconsistently), the call falls back to the
    XLA ref path instead of crashing — model dims are 128-multiples, so
    the Pallas path covers the hot shapes.
    """
    interpret = _interp(interpret)
    M, K = x_q.shape
    if w_packed.ndim != 2 or w_packed.shape[0] != K:
        raise ValueError(
            f"int4_matmul: packed weights {w_packed.shape} do not match "
            f"activations {x_q.shape} on K={K}")
    N = 2 * w_packed.shape[1]
    scale = jnp.asarray(scale, jnp.float32)
    if scale.size not in (1, N):
        raise ValueError(
            f"int4_matmul: scale {scale.shape} is not broadcastable to "
            f"(1, {N}) for packed weights {w_packed.shape}")
    scale = jnp.broadcast_to(scale.reshape(1, -1), (1, N))
    if not (use_pallas() or interpret):
        return kref.int4_matmul_ref(x_q, w_packed, scale, out_dtype)
    bm, bn, bk = _blocks_for(M, N, K)
    if K % bk or N % bn or (N // 2) % bn:
        return kref.int4_matmul_ref(x_q, w_packed, scale, out_dtype)
    xp = _pad_to(x_q, (bm, bk))
    out = _int4_pallas(xp, w_packed, scale, out_dtype=out_dtype,
                       bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:M, :N]


# ---------------------------------------------------------------------------
# Serve-form linears — the models' quantized compute path.
# ---------------------------------------------------------------------------

def _static_bits(b) -> Optional[int]:
    """Python int when ``b`` is a compile-time constant, else None."""
    if isinstance(b, (int, np.integer)) and not isinstance(b, bool):
        return int(b)
    return None


def int8_accum(x_q: jnp.ndarray, w_q: jnp.ndarray, *,
               planes: Optional[int] = None,
               interpret: bool = False) -> jnp.ndarray:
    """int8 (M,K) @ int8 (K,N) -> int32 through the kernel layer.

    Static ``planes`` runs the plane-serial kernel at exactly that many
    bit planes (TPU cost ∝ assigned bits); None means the bits were traced
    upstream, so the container-width path runs (the 8-plane walk lowers to
    one native int8 MXU matmul)."""
    n = 8 if planes is None else min(max(planes, 1), 8)
    return bitplane_matmul(x_q, w_q, n_planes=n, interpret=interpret)


def _epilogue(acc2, lead, x_scale, w_s, bias):
    """f32(acc) * x_scale * w_s (+ bias) — fixed multiply order, identical
    to the historical inline serve math (parity-tested bit-exact)."""
    y = acc2.astype(jnp.float32).reshape(*lead, -1) * x_scale * w_s
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y


def _container_linear(x, qw, s, bias, *, from_bits, wbits, abits, interpret):
    x2 = x.astype(jnp.float32)
    x_scale = bf.symmetric_scale(x2, abits)           # per-tensor scalar
    x_q = bf.quantize(x2, x_scale, abits)
    w_q = bf.requant_shift(qw, wbits, from_bits=from_bits)
    w_s = bf.effective_scale(s, wbits, from_bits=from_bits)
    acc = int8_accum(x_q.reshape(-1, x.shape[-1]), w_q,
                     planes=_static_bits(wbits), interpret=interpret)
    return _epilogue(acc, x.shape[:-1], x_scale, w_s, bias)


def quant_linear(x: jnp.ndarray, q: jnp.ndarray, s: jnp.ndarray,
                 bias: Optional[jnp.ndarray] = None, *, wbits=8, abits=8,
                 interpret: bool = False) -> jnp.ndarray:
    """float (..., K) @ int8-container {q (K,N), s (1,N)} -> f32 (..., N).

    Dyadic requantization to ``wbits`` + dynamic ``abits`` activation
    quantization; bits may be Python ints (static → plane-serial kernel)
    or traced scalars (zero-recompilation switch)."""
    return _container_linear(x, q, s, bias, from_bits=8, wbits=wbits,
                             abits=abits, interpret=_interp(interpret))


def int4_linear(x: jnp.ndarray, q4: jnp.ndarray, s: jnp.ndarray,
                bias: Optional[jnp.ndarray] = None, *, wbits=8, abits=8,
                interpret: bool = False) -> jnp.ndarray:
    """float (..., K) @ packed-int4 container {q4 (K,N/2), s (1,N)}.

    With static ``wbits >= 4`` on the Pallas path, requantization is the
    identity and the packed kernel streams nibbles straight from HBM (half
    the weight traffic); the dequant epilogue stays outside the kernel in
    canonical order, so results match the unpacked path exactly (the int4
    accumulator magnitude is < 2^24 for any practical K, hence f32-exact).
    Otherwise the container unpacks and takes the shared requant path.
    """
    interpret = _interp(interpret)
    wb = _static_bits(wbits)
    if wb is not None and wb >= 4 and (use_pallas() or interpret):
        N = 2 * q4.shape[-1]
        x2 = x.astype(jnp.float32)
        x_scale = bf.symmetric_scale(x2, abits)
        x_q = bf.quantize(x2, x_scale, abits)
        acc = int4_matmul(x_q.reshape(-1, x.shape[-1]), q4,
                          jnp.ones((1, N), jnp.float32),
                          out_dtype=jnp.float32, interpret=interpret)
        return _epilogue(acc, x.shape[:-1], x_scale,
                         jnp.asarray(s, jnp.float32), bias)
    return _container_linear(x, bf.unpack_int4_halves(q4), s, bias,
                             from_bits=4, wbits=wbits, abits=abits,
                             interpret=interpret)


def serve_linear(p: dict, x: jnp.ndarray, wbits=8, abits=8, *,
                 interpret: bool = False) -> jnp.ndarray:
    """Serve-form linear dispatch: {"q","s"[,"b"]} or {"q4","s"[,"b"]}.

    ``wbits``/``abits`` scalars (Python ints or traced) take the container
    path; ``(B,)`` vectors (per-request precision) take the bit-grouped
    batch path (or the vmap baseline under ``set_row_dispatch("vmap")``).
    Returns float32; callers cast to their activation dtype.
    """
    if getattr(wbits, "ndim", 0) >= 1 or getattr(abits, "ndim", 0) >= 1:
        return _serve_linear_rows(p, x, wbits, abits, _interp(interpret))
    bias = p.get("b")
    if "q4" in p:
        return int4_linear(x, p["q4"], p["s"], bias, wbits=wbits,
                           abits=abits, interpret=interpret)
    return quant_linear(x, p["q"], p["s"], bias, wbits=wbits, abits=abits,
                        interpret=interpret)


def serve_linear_stacked(p: dict, x: jnp.ndarray, wbits=8, abits=8, *,
                         stack_bits: bool = False,
                         interpret: bool = False) -> jnp.ndarray:
    """Stacked serve-form linears: containers carry a leading stack axis.

    ``p``: ``{"q": (G, K, N), "s": (G, 1, N)}`` — G independent weight
    matrices applied slice-wise to ``x`` ``(G, ..., K)`` in ONE batched
    GEMM (MoE expert stacks, grouped-conv group stacks) instead of a
    per-slice Python loop.  Each slice's weights differ, so the
    per-slice requant is NOT redundant (unlike per-row bits over shared
    weights); every slice still reaches the kernel layer through
    :func:`serve_linear` under vmap.

    ``stack_bits=False`` (default): ``wbits`` is shared by every stack —
    a scalar, or a per-row ``(B,)`` vector when ``x`` is ``(G, B, ...,
    K)`` (each slice then takes the bit-grouped batch path).
    ``stack_bits=True``: ``wbits`` is a ``(G,)`` vector, one width per
    stack (MoE per-expert precision).  Biases are not stacked — callers
    apply a full-width bias after recombining slices.
    """
    interpret = _interp(interpret)
    if stack_bits:
        wb = jnp.broadcast_to(jnp.asarray(wbits, jnp.int32), (x.shape[0],))
        return jax.vmap(
            lambda pp, xx, b: serve_linear(pp, xx, b, abits,
                                           interpret=interpret))(p, x, wb)
    return jax.vmap(
        lambda pp, xx: serve_linear(pp, xx, wbits, abits,
                                    interpret=interpret))(p, x)


def _family_index(wb: jnp.ndarray, fams) -> jnp.ndarray:
    """Index of the smallest family >= wb (clamped into the family range) —
    exact whenever wb is in the set, snap-up otherwise."""
    bounds = jnp.asarray(fams, jnp.int32)
    clipped = jnp.clip(jnp.asarray(wb, jnp.int32), bounds[0], bounds[-1])
    return jnp.searchsorted(bounds, clipped, side="left").astype(jnp.int32)


def _serve_linear_rows(p, x, wbits, abits, interpret):
    """Per-row precision: grouped (one GEMM per static bit family) or the
    vmap baseline (one weight requant per row)."""
    B = x.shape[0]
    wb = jnp.broadcast_to(jnp.asarray(wbits, jnp.int32), (B,))
    ab = jnp.broadcast_to(jnp.asarray(abits, jnp.int32), (B,))
    if _row_dispatch == "vmap":
        return jax.vmap(
            lambda xr, w, a: serve_linear(p, xr, w, a, interpret=interpret)
        )(x, wb, ab)

    if "q4" in p:
        qw, from_bits = bf.unpack_int4_halves(p["q4"]), 4
    else:
        qw, from_bits = p["q"], 8
    K = x.shape[-1]
    lead = x.shape[:-1]
    x2 = x.astype(jnp.float32)
    # per-row dynamic activation quantization at per-row abits (elementwise
    # — activations never need grouping); token_scale_mode keeps one scale
    # per token position instead of one per request (verify chunks)
    if _token_scales:
        axes = (x2.ndim - 1,)
    else:
        axes = tuple(range(1, x2.ndim))
    amax = jnp.max(jnp.abs(x2), axis=axes, keepdims=True)   # (B, 1, ..., 1)
    ab_b = ab.reshape((B,) + (1,) * (x2.ndim - 1))
    lim = bf.qmax(ab_b)
    x_scale = jnp.maximum(amax, 1e-8) / lim
    x_q = jnp.clip(jnp.round(x2 / x_scale), -lim, lim).astype(bf.INT_DTYPE)
    xq2 = x_q.reshape(-1, K)                                # (R, K)
    R = xq2.shape[0]

    # one requant + one grouped GEMM per distinct family — families below
    # the container width collapse (requant 4->6 == 4->4 for a q4 container)
    fams = tuple(_families)
    eff = [min(f, from_bits) for f in fams]
    uniq = sorted(set(eff))
    accs, scales = [], []
    for f in uniq:
        w_f = bf.requant_shift(qw, f, from_bits=from_bits)
        accs.append(int8_accum(xq2, w_f, planes=f, interpret=interpret))
        scales.append(jnp.broadcast_to(
            jnp.asarray(bf.effective_scale(p["s"], f, from_bits=from_bits),
                        jnp.float32).reshape(1, -1), (1, accs[-1].shape[-1])))
    acc_stack = jnp.stack(accs)                             # (G, R, N)
    ws_stack = jnp.concatenate(scales, axis=0)              # (G, N)

    # scatter rows back: gather each row's accumulator from its family
    remap = jnp.asarray([uniq.index(e) for e in eff], jnp.int32)
    fam_of_row = remap[_family_index(wb, fams)]              # (B,)
    rows_per_b = R // B
    idx_r = jnp.repeat(fam_of_row, rows_per_b)               # (R,)
    acc = acc_stack[idx_r, jnp.arange(R)]                    # (R, N)
    w_s = ws_stack[idx_r]                                    # (R, N)
    xs_flat = jnp.broadcast_to(x_scale, x2.shape[:-1] + (1,)).reshape(R, 1)
    y = acc.astype(jnp.float32) * xs_flat * w_s
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.reshape(lead + (y.shape[-1],))


# ---------------------------------------------------------------------------
# End-to-end fluid linear: quantize activations, walk planes, dequantize.
# ---------------------------------------------------------------------------

def fluid_linear(x: jnp.ndarray, w_q: jnp.ndarray, w_scale: jnp.ndarray,
                 *, wbits: int = 8, abits: int = 8,
                 interpret: bool = False) -> jnp.ndarray:
    """float (..., K) @ int8-container (K, N): the bit-fluid serving matmul.

    Static ``wbits`` routes through the plane-serial kernel (cost ∝ wbits),
    masking container MSBs directly (truncation semantics — serve_linear
    adds the dyadic-rounding requant the models use); use
    core.bitfluid.fluid_int8_matmul for traced (runtime-tensor) bits.
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    x_scale = bf.symmetric_scale(x2, abits)
    x_q = bf.quantize(x2, x_scale, abits)
    acc = bitplane_matmul(x_q, w_q, n_planes=wbits, interpret=interpret)
    y = acc.astype(jnp.float32) * x_scale * jnp.asarray(w_scale, jnp.float32)
    return y.reshape(*lead, -1)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    interpret: bool = False) -> jnp.ndarray:
    """Flat-head flash attention: (BH, Sq, hd). Pads Sq/Sk/hd to tiles.

    Off-TPU, sequences longer than one ref chunk take the blockwise
    online-softmax ref (O(S·chunk) memory — dry-run artifacts keep the
    flash memory posture); short ones take the exact oracle."""
    from repro.kernels.flash_attention import flash_attention as _fa
    interpret = _interp(interpret)
    if not (use_pallas() or interpret):
        if max(q.shape[1], k.shape[1]) > kref.FLASH_CHUNK:
            return kref.flash_attention_chunked_ref(q, k, v, causal=causal,
                                                    window=window)
        return kref.flash_attention_ref(q, k, v, causal, window)
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    bq = min(128, max(8, 1 << (Sq - 1).bit_length())) if Sq < 128 else 128
    bk = min(128, max(8, 1 << (Sk - 1).bit_length())) if Sk < 128 else 128
    qp = _pad_to(q, (1, bq, 128))
    kp = _pad_to(k, (1, bk, 128))
    vp = _pad_to(v, (1, bk, 128))
    out = _fa(qp, kp, vp, causal=causal, window=window, k_len=Sk,
              scale=hd ** -0.5, bq=bq, bk=bk, interpret=interpret)
    return out[:, :Sq, :hd]
