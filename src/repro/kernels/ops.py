"""Public kernel API: padding, dispatch (Pallas-TPU vs XLA ref), caching.

``use_pallas()`` is True only on real TPU backends; elsewhere (this CPU
container, and inside the 512-device dry-run) the mathematically identical
ref path lowers through XLA, so compiled-artifact analysis reflects the
same algorithm.  Kernel *numerics* are validated against ref in
tests/test_kernels.py with interpret=True.

Per-precision specializations are cached by (n_planes, block shape) via
jit's static-arg cache: switching a layer between 2/4/8 bits after warmup
costs no recompilation — the dispatch-cache realization of bit fluidity.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import bitfluid as bf
from repro.kernels import ref as kref
from repro.kernels.bitplane_matmul import bitplane_matmul as _bitplane_pallas
from repro.kernels.quant_matmul import quant_matmul as _quant_pallas
from repro.kernels.int4_matmul import int4_matmul as _int4_pallas

_FORCE: Optional[bool] = None  # tests set this to route through interpret


def set_force_pallas(v: Optional[bool]) -> None:
    global _FORCE
    _FORCE = v


def use_pallas() -> bool:
    if _FORCE is not None:
        return _FORCE
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, mults) -> jnp.ndarray:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


def _blocks_for(M: int, N: int, K: int):
    """MXU-aligned blocks; small dims shrink to avoid wasteful padding."""
    bm = 128 if M >= 128 else max(8, 1 << (max(M - 1, 1)).bit_length())
    return min(bm, 128), 128, 128


# ---------------------------------------------------------------------------

def bitplane_matmul(x_q: jnp.ndarray, w_q: jnp.ndarray, *, n_planes: int = 8,
                    interpret: bool = False) -> jnp.ndarray:
    """int8 (M,K) @ int8-container (K,N) -> int32 (M,N), plane-serial."""
    if not (use_pallas() or interpret):
        return kref.bitplane_matmul_ref(x_q, w_q, n_planes)
    M, K = x_q.shape
    N = w_q.shape[1]
    bm, bn, bk = _blocks_for(M, N, K)
    xp = _pad_to(x_q, (bm, bk))
    wp = _pad_to(w_q, (bk, bn))
    out = _bitplane_pallas(xp, wp, n_planes=n_planes, bm=bm, bn=bn, bk=bk,
                           interpret=interpret)
    return out[:M, :N]


def quant_matmul(x_q: jnp.ndarray, w_q: jnp.ndarray, scale: jnp.ndarray,
                 bias: Optional[jnp.ndarray] = None, *, act: str = "none",
                 out_dtype=jnp.float32, interpret: bool = False) -> jnp.ndarray:
    """int8 (M,K) @ int8 (K,N) with fused per-channel dequant epilogue."""
    M, K = x_q.shape
    N = w_q.shape[1]
    scale = jnp.broadcast_to(jnp.asarray(scale, jnp.float32), (1, N))
    bias = (jnp.zeros((1, N), jnp.float32) if bias is None
            else jnp.broadcast_to(jnp.asarray(bias, jnp.float32), (1, N)))
    if not (use_pallas() or interpret):
        return kref.quant_matmul_ref(x_q, w_q, scale, bias, act, out_dtype)
    bm, bn, bk = _blocks_for(M, N, K)
    xp = _pad_to(x_q, (bm, bk))
    wp = _pad_to(w_q, (bk, bn))
    sp = _pad_to(scale, (1, bn))
    bp = _pad_to(bias, (1, bn))
    out = _quant_pallas(xp, wp, sp, bp, act=act, out_dtype=out_dtype,
                        bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:M, :N]


def int4_matmul(x_q: jnp.ndarray, w_packed: jnp.ndarray, scale: jnp.ndarray,
                *, out_dtype=jnp.float32, interpret: bool = False) -> jnp.ndarray:
    """int8 (M,K) @ halves-packed uint8 (K,N/2) with fused dequant."""
    M, K = x_q.shape
    N = 2 * w_packed.shape[1]
    scale = jnp.broadcast_to(jnp.asarray(scale, jnp.float32), (1, N))
    if not (use_pallas() or interpret):
        return kref.int4_matmul_ref(x_q, w_packed, scale, out_dtype)
    bm, bn, bk = _blocks_for(M, N, K)
    # padding packed columns pads both halves consistently only when no pad
    # is needed; require alignment instead (model dims are 128-multiples).
    assert K % bk == 0 and (N // 2) % bn == 0, (K, N)
    xp = _pad_to(x_q, (bm, bk))
    out = _int4_pallas(xp, w_packed, scale, out_dtype=out_dtype,
                       bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:M, :N]


# ---------------------------------------------------------------------------
# End-to-end fluid linear: quantize activations, walk planes, dequantize.
# ---------------------------------------------------------------------------

def fluid_linear(x: jnp.ndarray, w_q: jnp.ndarray, w_scale: jnp.ndarray,
                 *, wbits: int = 8, abits: int = 8,
                 interpret: bool = False) -> jnp.ndarray:
    """float (..., K) @ int8-container (K, N): the bit-fluid serving matmul.

    Static ``wbits`` routes through the plane-serial kernel (cost ∝ wbits);
    use core.bitfluid.fluid_int8_matmul for traced (runtime-tensor) bits.
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    x_scale = bf.symmetric_scale(x2, abits)
    x_q = bf.quantize(x2, x_scale, abits)
    acc = bitplane_matmul(x_q, w_q, n_planes=wbits, interpret=interpret)
    y = acc.astype(jnp.float32) * x_scale * jnp.asarray(w_scale, jnp.float32)
    return y.reshape(*lead, -1)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    interpret: bool = False) -> jnp.ndarray:
    """Flat-head flash attention: (BH, Sq, hd). Pads Sq/Sk/hd to tiles."""
    from repro.kernels.flash_attention import flash_attention as _fa
    if not (use_pallas() or interpret):
        return kref.flash_attention_ref(q, k, v, causal, window)
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    bq = min(128, max(8, 1 << (Sq - 1).bit_length())) if Sq < 128 else 128
    bk = min(128, max(8, 1 << (Sk - 1).bit_length())) if Sk < 128 else 128
    qp = _pad_to(q, (1, bq, 128))
    kp = _pad_to(k, (1, bk, 128))
    vp = _pad_to(v, (1, bk, 128))
    out = _fa(qp, kp, vp, causal=causal, window=window, k_len=Sk,
              scale=hd ** -0.5, bq=bq, bk=bk, interpret=interpret)
    return out[:, :Sq, :hd]
