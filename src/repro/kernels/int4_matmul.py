"""Packed-int4 GEMM — weight bits as HBM bandwidth (the decode kernel).

On the *memory-bound* side of the roofline (autoregressive decode reads
every weight once per token), weight bits are bandwidth are latency — the
TPU equivalent of the AP's per-bit energy scaling.  This kernel streams
int4 weights packed two-per-byte (half the HBM traffic of int8, a quarter
of bf16) and unpacks in VMEM.

Packing is the *halves* layout (core/bitfluid.pack_int4_halves): output
columns [0, N/2) live in low nibbles, [N/2, N) in high nibbles, so a weight
tile unpacks with a single elementwise nibble-select — no interleave, no
layout change.  The grid's N dimension runs over *logical* columns; the
index map folds column block j onto packed block j % (N/2bn) and the kernel
selects the nibble from the block index.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, wp_ref, s_ref, o_ref, acc_ref, *, k_steps: int,
            n_half_blocks: int, out_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    j = pl.program_id(1)
    wp = wp_ref[...]                                   # (bk, bn) uint8 packed
    nib = jnp.where(j < n_half_blocks, wp & 0xF, (wp >> 4) & 0xF)
    w = nib.astype(jnp.int8)
    w = jnp.where(w >= 8, w - 16, w)                   # sign-extend int4

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * s_ref[...]).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype", "bm", "bn", "bk",
                                             "interpret"))
def int4_matmul(x_q: jnp.ndarray, w_packed: jnp.ndarray, scale: jnp.ndarray,
                *, out_dtype=jnp.float32, bm: int = 128, bn: int = 128,
                bk: int = 128, interpret: bool = False) -> jnp.ndarray:
    """(M,K) int8 @ packed (K,N/2) uint8 -> (M,N) out_dtype.

    scale: (1, N) fused per-channel dequant (activation scale folded in).
    N is the logical (unpacked) width; w_packed.shape == (K, N // 2).
    """
    M, K = x_q.shape
    K2, N_half = w_packed.shape
    N = 2 * N_half
    assert K == K2 and scale.shape == (1, N)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0 and N_half % bn == 0
    k_steps = K // bk
    n_half_blocks = N_half // bn

    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps,
                          n_half_blocks=n_half_blocks, out_dtype=out_dtype),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            # fold logical column block j onto its packed block
            pl.BlockSpec((bk, bn),
                         lambda i, j, k: (k, j % n_half_blocks)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_packed, scale)
