"""Pure-jnp oracles for every kernel — the correctness ground truth.

Each ``*_ref`` mirrors its kernel's contract exactly (same dtypes, same
rounding, same scale semantics) with no Pallas, so tests can
``assert_allclose`` across shape/dtype sweeps, and the dry-run lowers the
same math through XLA when Pallas-TPU is unavailable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitfluid as bf

_ACTS = {
    "none": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "silu": lambda x: x * jax.nn.sigmoid(x),
    "gelu": jax.nn.gelu,
}


def _int8_dot(x_q, w_q):
    return jax.lax.dot_general(
        x_q, w_q, dimension_numbers=(((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def bitplane_matmul_ref(x_q: jnp.ndarray, w_q: jnp.ndarray,
                        n_planes: int = 8) -> jnp.ndarray:
    """Plane-serial accumulate; identical numerics to the kernel (int32)."""
    field = w_q.astype(jnp.int32) & ((1 << n_planes) - 1)
    acc = jnp.zeros((x_q.shape[0], w_q.shape[1]), jnp.int32)
    for j in range(n_planes):
        plane = ((field >> j) & 1).astype(jnp.int8)
        weight = -(1 << (n_planes - 1)) if j == n_planes - 1 else (1 << j)
        acc = acc + weight * _int8_dot(x_q, plane)
    return acc


def quant_matmul_ref(x_q, w_q, scale, bias, act: str = "none",
                     out_dtype=jnp.float32):
    y = _int8_dot(x_q, w_q).astype(jnp.float32) * scale + bias
    return _ACTS[act](y).astype(out_dtype)


def int4_matmul_ref(x_q, w_packed, scale, out_dtype=jnp.float32):
    w = bf.unpack_int4_halves(w_packed)
    return (_int8_dot(x_q, w).astype(jnp.float32) * scale).astype(out_dtype)


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0):
    """(BH, Sq, hd) softmax attention oracle (f32 math)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    Sq, Sk = s.shape[1], s.shape[2]
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    vis = jnp.ones((Sq, Sk), bool)
    if causal:
        vis &= kpos <= qpos
    if window:
        vis &= kpos > qpos - window
    s = jnp.where(vis[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
