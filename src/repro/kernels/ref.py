"""Pure-jnp oracles for every kernel — the correctness ground truth.

Each ``*_ref`` mirrors its kernel's contract exactly (same dtypes, same
rounding, same scale semantics) with no Pallas, so tests can
``assert_allclose`` across shape/dtype sweeps, and the dry-run lowers the
same math through XLA when Pallas-TPU is unavailable.

Two flash references exist: :func:`flash_attention_ref` is the exact
O(Sq·Sk)-memory softmax oracle tests compare against, and
:func:`flash_attention_chunked_ref` is the blockwise online-softmax
lowering (O(S·chunk) memory) that ``ops.flash_attention`` uses off-TPU
for long sequences — formerly ``models/transformer._flash_sdpa``, now a
kernel-layer concern so dry-run HLO never materializes an S² scores
tensor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitfluid as bf

_ACTS = {
    "none": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "silu": lambda x: x * jax.nn.sigmoid(x),
    "gelu": jax.nn.gelu,
}


def _int8_dot(x_q, w_q):
    return jax.lax.dot_general(
        x_q, w_q, dimension_numbers=(((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def bitplane_matmul_ref(x_q: jnp.ndarray, w_q: jnp.ndarray,
                        n_planes: int = 8) -> jnp.ndarray:
    """Plane-serial contract, single-dot form (int32-exact).

    The kernel's plane walk computes  sum_j w_j * (x_q @ plane_j)  over the
    low ``n_planes`` two's-complement field of the container — which is
    identically  x_q @ sign_extend(w_q & (2^n - 1))  (the weighted planes
    reassemble the masked field; see core/bitfluid.bitplane_matmul_ref for
    the loop-form oracle).  One dot instead of ``n_planes`` keeps the XLA
    serving path at container cost — the plane-count cost model is a TPU
    (Pallas) property, not a CPU one.
    """
    field = w_q.astype(jnp.int32) & ((1 << n_planes) - 1)
    sign = (field >> (n_planes - 1)) & 1                 # two's-complement
    w = (field - sign * (1 << n_planes)).astype(jnp.int8)
    return _int8_dot(x_q, w)


def quant_matmul_ref(x_q, w_q, scale, bias, act: str = "none",
                     out_dtype=jnp.float32):
    y = _int8_dot(x_q, w_q).astype(jnp.float32) * scale + bias
    return _ACTS[act](y).astype(out_dtype)


def int4_matmul_ref(x_q, w_packed, scale, out_dtype=jnp.float32):
    w = bf.unpack_int4_halves(w_packed)
    return (_int8_dot(x_q, w).astype(jnp.float32) * scale).astype(out_dtype)


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0):
    """(BH, Sq, hd) softmax attention oracle (f32 math)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    Sq, Sk = s.shape[1], s.shape[2]
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    vis = jnp.ones((Sq, Sk), bool)
    if causal:
        vis &= kpos <= qpos
    if window:
        vis &= kpos > qpos - window
    s = jnp.where(vis[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


NEG_INF = -1e30
FLASH_CHUNK = 2048


def _pad_axis(x, axis: int, mult: int):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention_chunked_ref(q, k, v, causal: bool = True,
                                window: int = 0,
                                chunk: int = FLASH_CHUNK) -> jnp.ndarray:
    """Blockwise (flash) attention in pure JAX: O(S·chunk) memory.

    q: (BH, Sq, hd); k, v: (BH, Sk, hd); positions are 0..S-1 (lock-step
    sequences — ragged callers mask upstream).  Scores exist only as one
    (BH, Qc, Kc) tile per scan step, so XLA-lowered artifacts (dry runs,
    CPU CI) carry the same O(S) memory posture as the Pallas kernel.
    Accumulation is f32 with bf16 tiles when the inputs are bf16,
    matching the Pallas kernel's MXU dtype discipline.
    """
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    Qc, Kc = min(chunk, Sq), min(chunk, Sk)
    qp_full = _pad_axis(q, 1, Qc)
    kp_full = _pad_axis(k, 1, Kc)
    vp_full = _pad_axis(v, 1, Kc)
    nq, nk = qp_full.shape[1] // Qc, kp_full.shape[1] // Kc
    scale = hd ** -0.5

    q5 = jnp.moveaxis(qp_full.reshape(BH, nq, Qc, hd), 1, 0)
    k5 = jnp.moveaxis(kp_full.reshape(BH, nk, Kc, hd), 1, 0)
    v5 = jnp.moveaxis(vp_full.reshape(BH, nk, Kc, hd), 1, 0)
    qpos = jnp.arange(nq * Qc).reshape(nq, Qc)
    kpos = jnp.arange(nk * Kc).reshape(nk, Kc)

    def q_block(_, xs_q):
        qb, qpb = xs_q                            # (BH, Qc, hd), (Qc,)

        def kv_block(carry, xs_k):
            m, l, acc = carry
            kb, vb, kpb = xs_k                    # (BH, Kc, hd), (Kc,)
            s = jnp.einsum("bqd,bkd->bqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            vis = kpb[None, :] < Sk               # padded key slots
            if causal:
                vis &= kpb[None, :] <= qpb[:, None]
                if window:
                    vis &= kpb[None, :] > qpb[:, None] - window
            s = jnp.where(vis[None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(vis[None], p, 0.0)
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = (acc * corr[..., None]
                   + jnp.einsum("bqk,bkd->bqd", p.astype(vb.dtype), vb,
                                preferred_element_type=jnp.float32))
            return (m_new, l, acc), ()

        m0 = jnp.full((BH, Qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((BH, Qc), jnp.float32)
        a0 = jnp.zeros((BH, Qc, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), (k5, v5, kpos))
        return None, acc / jnp.maximum(l, 1e-30)[..., None]

    _, blocks = jax.lax.scan(q_block, None, (q5, qpos))   # (nq, BH, Qc, hd)
    out = jnp.moveaxis(blocks, 0, 1).reshape(BH, nq * Qc, hd)
    return out[:, :Sq].astype(q.dtype)
