"""Bit-plane GEMM — the AP's bit-serial multiply rebuilt for the MXU.

BF-IMNA multiplies by walking ``Mw x Ma`` bit pairs through a compare/write
LUT (cost O(M^2), Table I).  The TPU's MXU is a fixed 8-bit-or-wider
systolic array, so the faithful *algorithmic* analogue walks the weight's
bit planes and issues one int8 matmul per plane:

    y = x_q @ w_q = sum_{j < Mw} 2^j * (x_q @ plane_j)        (sign plane
      carries weight -2^(Mw-1), two's complement)

* ``n_planes`` is a **static** specialization (dispatch-cached in ops.py) —
  lowering a 4-bit layer issues 4 plane matmuls, a 2-bit layer 2: compute
  cost scales linearly with assigned weight bits, the MXU analogue of the
  AP's "MSBs deactivated" energy scaling.
* Activation bits are absorbed by the MXU's native 8-bit path; activation
  fluidity is dyadic requantization (core/bitfluid.requant_shift), applied
  before the kernel.

Tiling: grid (M/bm, N/bn, K/bk), K innermost; an int32 VMEM scratch
accumulates across K steps; plane extraction happens on the VMEM-resident
weight tile, so HBM traffic is the int8 container once — planes are never
materialized in HBM.  MXU-aligned blocks (multiples of 128 on M/N, 128+ on
K) are enforced by ops.py padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, n_planes: int, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                    # (bm, bk) int8
    if n_planes == 8:
        # container width: the 8-plane walk reassembles the int8 word
        # exactly, so it degenerates to the MXU's native int8 matmul —
        # one dot instead of eight (the traced-bits serve path lands
        # here after dyadic requantization).
        acc_ref[...] += jax.lax.dot_general(
            x, w_ref[...],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    else:
        w = w_ref[...].astype(jnp.int32)              # (bk, bn) int8 container
        field = w & ((1 << n_planes) - 1)             # low-Mw two's-compl field
        acc = acc_ref[...]
        for j in range(n_planes):                     # the bit-serial walk
            plane = ((field >> j) & 1).astype(jnp.int8)
            d = jax.lax.dot_general(
                x, plane,
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            weight = -(1 << (n_planes - 1)) if j == n_planes - 1 else (1 << j)
            acc = acc + weight * d
        acc_ref[...] = acc

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("n_planes", "bm", "bn", "bk",
                                             "interpret"))
def bitplane_matmul(x_q: jnp.ndarray, w_q: jnp.ndarray, *, n_planes: int = 8,
                    bm: int = 128, bn: int = 128, bk: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """(M, K) int8 @ (K, N) int8-container -> (M, N) int32, plane-serial.

    Shapes must be multiples of the block sizes (ops.py pads).
    """
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2, (x_q.shape, w_q.shape)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    assert 1 <= n_planes <= 8
    k_steps = K // bk

    grid = (M // bm, N // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_kernel, n_planes=n_planes, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q)
