"""Fused int8 GEMM + dequant (+bias, +activation) — the fixed-precision path.

One MXU int8 matmul per tile with the dequantization epilogue fused in VMEM:

    y = act( (x_q @ w_q) * scale[n] + bias[n] )

``scale`` folds the per-tensor activation scale into the per-channel weight
scale outside the kernel (ops.py), so the epilogue is one multiply.  This is
the throughput ceiling the bit-plane kernel is measured against: a b-bit
layer costs b/8 of this kernel's MXU work via the plane walk, and exactly
this kernel's work via the requant-shift path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_ACTS = {
    "none": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "silu": lambda x: x * jax.nn.sigmoid(x),
    "gelu": jax.nn.gelu,
}


def _kernel(x_ref, w_ref, s_ref, b_ref, o_ref, acc_ref, *,
            k_steps: int, act: str, out_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        y = acc_ref[...].astype(jnp.float32) * s_ref[...]
        y = y + b_ref[...]
        o_ref[...] = _ACTS[act](y).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("act", "out_dtype", "bm", "bn",
                                             "bk", "interpret"))
def quant_matmul(x_q: jnp.ndarray, w_q: jnp.ndarray, scale: jnp.ndarray,
                 bias: jnp.ndarray, *, act: str = "none",
                 out_dtype=jnp.float32, bm: int = 128, bn: int = 128,
                 bk: int = 128, interpret: bool = False) -> jnp.ndarray:
    """(M,K) int8 @ (K,N) int8 -> (M,N) out_dtype with fused epilogue.

    scale, bias: (1, N) float32 (broadcast rows), per output channel.
    """
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2 and scale.shape == (1, N) and bias.shape == (1, N)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    k_steps = K // bk

    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, act=act,
                          out_dtype=out_dtype),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, scale, bias)
