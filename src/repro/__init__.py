"""BF-IMNA reproduction: bit-fluid mixed-precision LMs on jax.

Subpackages: core (bit-fluid quantization + AP emulator), kernels,
apsim (analytic IMC cost model), dist (mesh/sharding substrate),
models, data, optim, train, serve, launch, configs.
"""
__version__ = "0.1.0"
