"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655; InternViT frontend is a STUB (precomputed patch embeddings
via input_specs, per the brief).  [arXiv:2404.16821]"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab_size=151655, head_dim=64, qkv_bias=True, rope_theta=1e6,
    mlp_type="swiglu", norm_type="rms", norm_eps=1e-6, tie_embeddings=True,
    frontend="vision", n_prefix_tokens=256,
)

SMOKE = FULL.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, head_dim=16, n_prefix_tokens=8, remat="none",
)
