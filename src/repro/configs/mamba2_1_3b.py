"""mamba2-1.3b [ssm] — 48L d_model=2048 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality).  [arXiv:2405.21060]"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, expand=2, d_conv=4, ssm_chunk=128,
    norm_type="rms", norm_eps=1e-5, tie_embeddings=True,
)

SMOKE = FULL.with_(
    n_layers=2, d_model=64, vocab_size=512, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=16, remat="none",
)
