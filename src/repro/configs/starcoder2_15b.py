"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152, GQA + RoPE, sliding-window 4096, LayerNorm + GELU MLP.
[arXiv:2402.19173]"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, d_ff=24576,
    vocab_size=49152, head_dim=128, qkv_bias=True, rope_theta=1e5,
    sliding_window=4096, mlp_type="gelu", norm_type="layer", norm_eps=1e-5,
)

SMOKE = FULL.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, head_dim=16, sliding_window=8, remat="none",
)
