"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
(per expert) vocab=163840, MoE 384 experts top-8 (+1 shared).  Trillion-
parameter paper-table config.  [arXiv:2501 Kimi K2]"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab_size=163840, head_dim=128, rope_theta=5e4,
    mlp_type="swiglu", norm_type="rms", norm_eps=1e-6,
    n_experts=384, experts_per_token=8, n_shared_experts=1,
    capacity_factor=1.25, accum_dtype="bfloat16",
)

SMOKE = FULL.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
    vocab_size=512, head_dim=16, n_experts=8, experts_per_token=2,
    n_shared_experts=1, remat="none",
)
