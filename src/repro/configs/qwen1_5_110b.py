"""qwen1.5-110b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5-110B family]"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=49152,
    vocab_size=152064, head_dim=128, qkv_bias=True, rope_theta=1e6,
    mlp_type="swiglu", norm_type="rms", norm_eps=1e-6,
)

SMOKE = FULL.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, head_dim=16, remat="none",
)
