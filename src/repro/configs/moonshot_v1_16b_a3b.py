"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H (GQA kv=16) d_ff=1408
(per expert) vocab=163840, MoE 64 experts top-6 (+2 shared).
[hf:moonshotai/Moonlight-16B-A3B family]"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=163840, head_dim=128, rope_theta=5e4,
    mlp_type="swiglu", norm_type="rms", norm_eps=1e-6,
    n_experts=64, experts_per_token=6, n_shared_experts=2,
    capacity_factor=1.25,
)

SMOKE = FULL.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
    vocab_size=512, head_dim=16, n_experts=8, experts_per_token=2,
    n_shared_experts=2, remat="none",
)
