"""qwen3-4b [dense] — 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936, qk_norm.  [hf:Qwen/Qwen3-4B family]"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, d_ff=9728,
    vocab_size=151936, head_dim=128, qk_norm=True, rope_theta=1e6,
    mlp_type="swiglu", norm_type="rms", norm_eps=1e-6, tie_embeddings=True,
)

SMOKE = FULL.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, head_dim=16, remat="none",
)
