"""seamless-m4t-medium [audio] — enc-dec, 12L(enc) + 12L(dec) d_model=1024
16H (kv=16) d_ff=4096 vocab=256206; the audio frontend is a STUB
(precomputed frame embeddings via input_specs).  [arXiv:2308.11596]"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64, rope_theta=1e4,
    mlp_type="gelu", norm_type="layer", norm_eps=1e-5,
    frontend="audio", frames_ratio=4,
)

SMOKE = FULL.with_(
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, head_dim=16, remat="none",
)
