"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64; Mamba2 backbone + one globally-shared attention
block invoked every 6 layers with per-site LoRA.  [arXiv:2411.15242]"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab_size=32000, head_dim=80, rope_theta=1e4,
    mlp_type="swiglu", norm_type="rms", norm_eps=1e-5,
    ssm_state=64, ssm_head_dim=64, expand=2, d_conv=4, ssm_chunk=128,
    attn_every=6, lora_rank=64,
)

SMOKE = FULL.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=512, head_dim=16, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=16, attn_every=1, lora_rank=4, remat="none",
)
