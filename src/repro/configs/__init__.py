"""Architecture registry: one module per assigned arch (+ paper CNNs).

Each module exports ``FULL`` (the exact assigned configuration) and
``SMOKE`` (a reduced same-family config for CPU tests).  Select with
``--arch <id>`` in the launchers; ``get(name)`` / ``get_smoke(name)`` here.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import ModelConfig, SHAPES, SHAPES_BY_NAME  # noqa: F401

ARCH_IDS = (
    "qwen1_5_110b",
    "starcoder2_15b",
    "stablelm_12b",
    "qwen3_4b",
    "mamba2_1_3b",
    "internvl2_1b",
    "kimi_k2_1t_a32b",
    "moonshot_v1_16b_a3b",
    "zamba2_2_7b",
    "seamless_m4t_medium",
)

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIASES.update({
    "qwen1.5-110b": "qwen1_5_110b",
    "mamba2-1.3b": "mamba2_1_3b",
    "zamba2-2.7b": "zamba2_2_7b",
})


def canonical(name: str) -> str:
    key = name.replace("-", "_").replace(".", "_")
    if key in ARCH_IDS:
        return key
    if name in _ALIASES:
        return _ALIASES[name]
    raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")


def _module(name: str):
    return importlib.import_module(f"repro.configs.{canonical(name)}")


def get(name: str) -> ModelConfig:
    return _module(name).FULL


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


def all_full() -> Dict[str, ModelConfig]:
    return {i: get(i) for i in ARCH_IDS}
