"""Sharding checker (DESIGN.md §12, pass 3 of 4): every ``dist/sharding``
pspec must divide the mesh for every config, at analysis time.

``dist.api.logical_to_mesh`` deliberately falls back to replication when
a dimension does not divide its logical axis — safe at run time, but it
means a bad spec (or a config whose shapes silently stopped dividing)
degrades to replicated execution with no error anywhere.  This pass
builds the FULL ten configs' parameter / quantized-parameter / cache /
bits / budgets / batch trees abstractly (``jax.eval_shape`` — no
allocation, the 1T-param config audits in milliseconds) and resolves
every leaf's spec against fake 1/2/4/8-device meshes — including the
placement-plan override families (``_logical_spec(..., plan=...)`` with
a fully-replicated and a partial 8-device ``PlacementPlan``) — checking
three things:

* **SH601** (fatal) — a *resolved* PartitionSpec that is arithmetically
  wrong: an axis not in the mesh, an axis consumed twice, or a sharded
  dimension whose size does not divide the product of its mesh axes.
  ``logical_to_mesh`` should make these impossible; this is the
  independent re-verification.
* **SH602** (fatal) — a leaf whose LOGICAL spec requests an axis that
  exists in the mesh (size > 1) but was dropped by the divisibility
  fallback: the config cannot actually shard the way ``sharding.py``
  says it should, named down to config × mesh × leaf path × dim.
* **SH603** (fatal) — the safety net: on the 2×2 mesh every config must
  end up with at least one parameter leaf on ``model``, one on
  ``data``, and one cache leaf on ``data`` — catching a refactor that
  quietly neuters the placement rules without breaking any arithmetic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.common import Finding

SHARDING_FILE = "src/repro/dist/sharding.py"

# fake meshes at 1/2/4/8 devices, covering pure-dp, pure-tp, and mixed
MESH_SHAPES: Tuple[Dict[str, int], ...] = (
    {"data": 1},
    {"data": 2}, {"model": 2},
    {"data": 4}, {"model": 4}, {"data": 2, "model": 2},
    {"data": 8}, {"model": 8}, {"data": 2, "model": 4},
    {"data": 4, "model": 2},
)

SAFETY_NET_MESH: Dict[str, int] = {"data": 2, "model": 2}

BATCH = 8            # divisible by every dp size above
CACHE_LEN = 64


@dataclasses.dataclass(frozen=True)
class FakeMesh:
    """Duck-types the two attributes ``dist.api``/``dist.sharding`` read
    (``.shape`` dict and ``.axis_names``) — no devices required."""
    axis_sizes: Tuple[Tuple[str, int], ...]

    @property
    def shape(self) -> Dict[str, int]:
        return dict(self.axis_sizes)

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.axis_sizes)


def mesh_label(mesh: FakeMesh) -> str:
    return "x".join(f"{n}{s}" for n, s in mesh.axis_sizes)


def _prod(vals: Iterable[int]) -> int:
    out = 1
    for v in vals:
        out *= v
    return out


def _path_str(path) -> str:
    return ".".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _leaves(tree) -> List[Tuple[str, Tuple[int, ...], Tuple[str, ...]]]:
    """(dotted path, shape, raw keys) for every array leaf."""
    import jax

    from repro.dist.sharding import _keys

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(_path_str(path), tuple(leaf.shape), _keys(path))
            for path, leaf in flat]


# ---------------------------------------------------------------------------
# Spec arithmetic (independent of dist.api's own implementation)
# ---------------------------------------------------------------------------

def check_resolved(spec, shape: Tuple[int, ...], mesh: FakeMesh,
                   where: str) -> List[Finding]:
    """SH601: re-verify one resolved PartitionSpec against the mesh."""
    out: List[Finding] = []
    entries = tuple(spec)
    if len(entries) > len(shape):
        out.append(Finding(
            rule="SH601", file=SHARDING_FILE, line=0, scope=where,
            message=f"spec {entries} has {len(entries)} entries for a "
                    f"rank-{len(shape)} leaf {shape}",
            hint="pspec builders must emit at most one entry per dim"))
        return out
    used: set = set()
    for dim, entry in enumerate(entries):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            if a not in mesh.shape:
                out.append(Finding(
                    rule="SH601", file=SHARDING_FILE, line=0, scope=where,
                    message=f"dim {dim} assigned axis {a!r} which is not "
                            f"in mesh {mesh.shape}",
                    hint="mesh_axes_for must filter to mesh.axis_names"))
            elif a in used:
                out.append(Finding(
                    rule="SH601", file=SHARDING_FILE, line=0, scope=where,
                    message=f"axis {a!r} consumed by two dims of {entries}",
                    hint="each mesh axis may shard at most one dim"))
            used.add(a)
        size = _prod(mesh.shape[a] for a in axes if a in mesh.shape)
        if size > 1 and shape[dim] % size != 0:
            out.append(Finding(
                rule="SH601", file=SHARDING_FILE, line=0, scope=where,
                message=f"dim {dim} of shape {shape} not divisible by "
                        f"{axes} (size {size}) in mesh {mesh.shape}",
                hint="logical_to_mesh must replicate non-dividing dims"))
    return out


def dropped_axes(mesh: FakeMesh, logical: Tuple[Optional[str], ...],
                 shape: Tuple[int, ...]) -> List[Tuple[int, str, int]]:
    """Dims whose requested logical axis exists in the mesh (size > 1)
    but was dropped by the divisibility fallback: mirrors
    ``logical_to_mesh``'s consumption loop, reporting what it silently
    replicated.  Returns (dim, logical name, axis size) triples."""
    from repro.dist.api import mesh_axes_for

    used: set = set()
    fell: List[Tuple[int, str, int]] = []
    for dim, name in enumerate(logical):
        if name is None or dim >= len(shape):
            continue
        if shape[dim] <= 1:
            continue        # replicating a singleton dim loses nothing
        axes = tuple(a for a in mesh_axes_for(mesh, name)
                     if a not in used)
        size = _prod(mesh.shape[a] for a in axes)
        if not axes or size <= 1:
            continue                       # axis absent/trivial: no request
        if shape[dim] % size != 0:
            fell.append((dim, name, size))
        else:
            used.update(axes)
    return fell


# ---------------------------------------------------------------------------
# Abstract per-config state
# ---------------------------------------------------------------------------

def _abstract_state(cfg):
    """(params, qparams, cache, bits, budgets, batch) as shape trees."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.retrace import abstract_cache, abstract_qparams
    from repro.models import lm

    params = jax.eval_shape(lambda k: lm.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    qparams = abstract_qparams(cfg)
    cache = abstract_cache(cfg, BATCH, CACHE_LEN)
    nb = lm.n_bit_slots(cfg)
    bits = jax.ShapeDtypeStruct((BATCH, nb), jnp.int32)
    budgets = jax.ShapeDtypeStruct((BATCH,), jnp.float32)
    batch = {"tokens": jax.ShapeDtypeStruct((BATCH, CACHE_LEN), jnp.int32)}
    return params, qparams, cache, bits, budgets, batch


def audit_config_sharding(name: str, meshes: Sequence[FakeMesh]
                          ) -> Tuple[List[Finding], Dict[str, int]]:
    """All pspec families for one FULL config across every mesh."""
    from repro import configs
    from repro.dist import api as dapi
    from repro.dist import sharding as dsh

    cfg = configs.get(name)
    params, qparams, cache, bits, budgets, batch = _abstract_state(cfg)
    findings: List[Finding] = []
    stats = {"leaves": 0, "sharded": 0}

    def logical_family(tag: str, leaves, pspec_of):
        for path, shape, _keys_ in leaves:
            for mesh in meshes:
                logical = pspec_of(path, shape, _keys_)
                resolved = dapi.logical_to_mesh(mesh, logical, shape)
                where = f"{name}/{tag}/{path}@{mesh_label(mesh)}"
                findings.extend(check_resolved(resolved, shape, mesh,
                                               where))
                for dim, lname, size in dropped_axes(mesh, logical,
                                                     shape):
                    findings.append(Finding(
                        rule="SH602", file=SHARDING_FILE, line=0,
                        scope=where,
                        message=f"logical axis {lname!r} requested on "
                                f"dim {dim} of {shape} but dropped: "
                                f"{shape[dim]} %% {size} != 0",
                        hint=f"config {name} cannot shard this leaf as "
                             f"specified; fix the shape or the rule"))
                stats["leaves"] += 1
                stats["sharded"] += int(any(e is not None
                                            for e in tuple(resolved)))

    class _L:                       # minimal .ndim carrier for pspec fns
        def __init__(self, shape):
            self.shape = tuple(shape)
            self.ndim = len(shape)

    logical_family("params", _leaves(params),
                   lambda p, s, k: dsh._logical_spec(k, len(s)))
    logical_family("qparams", _leaves(qparams),
                   lambda p, s, k: dsh._logical_spec(k, len(s)))

    # placement-plan overrides (dist/placement.py): the plan-aware pspec
    # path must resolve on every mesh too.  A fully-replicated 8-device
    # plan forces all-None on planned leaves (trivially divisible but
    # still arithmetic-checked); a partial plan must fall back to the
    # base rules UNCHANGED — both audited with the same SH601/SH602
    # machinery as the base families.
    from repro.dist import placement as dpl
    from repro.models import lm as lmod

    gd = lmod.layer_gemm_dims(cfg)
    rep = [8] * len(gd)
    plan_full = dpl.plan_placement(
        gd, rep, rep, n_devices=8, head=lmod.head_gemm_dims(cfg))
    plan_part = dpl.plan_placement(
        gd, rep, rep, n_devices=8, head=lmod.head_gemm_dims(cfg),
        memory_budget=1.5)
    logical_family(
        "qparams+plan_full", _leaves(qparams),
        lambda p, s, k: dsh._logical_spec(k, len(s), plan=plan_full))
    logical_family(
        "qparams+plan_partial", _leaves(qparams),
        lambda p, s, k: dsh._logical_spec(k, len(s), plan=plan_part))
    logical_family("bits", [("bits", tuple(bits.shape), ("bits",))],
                   lambda p, s, k: dsh.bits_pspec(_L(s)))
    logical_family("budgets",
                   [("budgets", tuple(budgets.shape), ("budgets",))],
                   lambda p, s, k: dsh.budgets_pspec(_L(s)))
    logical_family("batch", _leaves(batch),
                   lambda p, s, k: dsh.batch_pspec(_L(s)))

    # cache specs come back as concrete PartitionSpecs with their own
    # divisibility logic — arithmetic-check them directly
    for path, shape, keys in _leaves(cache):
        for mesh in meshes:
            resolved = dsh._cache_leaf_spec(mesh, keys, _L(shape))
            where = f"{name}/cache/{path}@{mesh_label(mesh)}"
            findings.extend(check_resolved(resolved, shape, mesh, where))
            stats["leaves"] += 1
            stats["sharded"] += int(any(e is not None
                                        for e in tuple(resolved)))

    # safety net: the 2x2 mesh must actually place both axes
    net = FakeMesh(tuple(sorted(SAFETY_NET_MESH.items())))

    def placed(tree_leaves, spec_of, axis: str) -> bool:
        for path, shape, keys in tree_leaves:
            entries = tuple(spec_of(shape, keys))
            for e in entries:
                axes = e if isinstance(e, tuple) else (e,)
                if axis in axes:
                    return True
        return False

    def param_spec(shape, keys):
        return dapi.logical_to_mesh(net, dsh._logical_spec(keys,
                                                           len(shape)),
                                    shape)

    def cache_spec(shape, keys):
        return dsh._cache_leaf_spec(net, keys, _L(shape))

    for axis in ("model", "data"):
        if not placed(_leaves(qparams), param_spec, axis):
            findings.append(Finding(
                rule="SH603", file=SHARDING_FILE, line=0,
                scope=f"{name}/qparams@{mesh_label(net)}",
                message=f"no quantized-param leaf sharded on {axis!r} "
                        f"on the 2x2 mesh — placement rules are inert "
                        f"for this config",
                hint="check _logical_spec's key patterns against this "
                     "config's param tree"))
    if not placed(_leaves(cache), cache_spec, "data"):
        findings.append(Finding(
            rule="SH603", file=SHARDING_FILE, line=0,
            scope=f"{name}/cache@{mesh_label(net)}",
            message="no cache leaf sharded on 'data' on the 2x2 mesh "
                    f"at B={BATCH}",
            hint="check _cache_leaf_spec's batch-dim placement"))
    return findings, stats


def run_sharding(arch_ids: Optional[Sequence[str]] = None
                 ) -> Tuple[List[Finding], Dict[str, Dict[str, int]]]:
    """Audit every FULL config against the mesh matrix."""
    from repro import configs

    meshes = [FakeMesh(tuple(sorted(m.items()))) for m in MESH_SHAPES]
    findings: List[Finding] = []
    summary: Dict[str, Dict[str, int]] = {}
    for name in (arch_ids if arch_ids is not None else configs.ARCH_IDS):
        f, stats = audit_config_sharding(name, meshes)
        findings.extend(f)
        summary[name] = stats
    return findings, summary
