"""Registries shared by the analysis passes (DESIGN.md §12).

Three kinds of project knowledge live here, OUT of the generic pass
machinery, so growing the codebase means editing data, not analyzers:

* **Hot scopes** — the per-tick / per-admission serving paths where a
  host sync is a real throughput bug.  One-time setup (``__init__``,
  pool construction) and cached host-side helpers (``_host_index``,
  ``host_bits``, ``_config_cost`` — the sanctioned per-admission
  mirrors) are deliberately NOT registered: syncing once at
  construction is fine, and the caching helpers exist precisely so the
  hot paths don't have to.
* **Taint vocabulary** — which callees produce device values, which
  produce host values, and which force a sync on whatever they're
  given.  The linter's dataflow is intraprocedural; these sets are its
  interprocedural knowledge.
* **Ledger waivers** — ``CostRecord`` fields written by the serve
  layer that ``accounting.aggregate()`` intentionally does not read,
  each naming its real consumer.
"""
from __future__ import annotations

import fnmatch
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Hot scopes for the host-sync rules (HS101/HS102/HS103)
# ---------------------------------------------------------------------------
# file pattern (repo-relative, fnmatch) -> qualname patterns.  "*" marks
# a whole module hot (kernels execute inside traces; any sync there is
# wrong at any time).
HOT_SCOPES: Dict[str, Tuple[str, ...]] = {
    "src/repro/serve/engine.py": (
        "ServeEngine._admit",
        "ServeEngine._step",
        "ServeEngine._decode_tick",
        "ServeEngine._spec_round",
        "ServeEngine._batch_bits",
        "ServeEngine._generate",
        "ServeEngine._finish",
    ),
    "src/repro/serve/runtime.py": (
        "ServeRuntime.admit_record",
        "ServeRuntime.plan_admissions",
        "ServeRuntime.charge",
        "ServeRuntime.new_record",
        "ServeRuntime.next_admission",
        "ServeRuntime.finish_record",
        "ServeRuntime.sched_tick",
        "ServeRuntime.age_queue",
    ),
    "src/repro/serve/cnn.py": (
        "CNNServeEngine.serve",
    ),
    "src/repro/kernels/*.py": ("*",),
}


def hot_patterns(relpath: str) -> Tuple[str, ...]:
    """Qualname patterns registered hot for one file ('' when none)."""
    out: Tuple[str, ...] = ()
    for pat, quals in HOT_SCOPES.items():
        if fnmatch.fnmatch(relpath, pat):
            out += quals
    return out


def is_hot(relpath: str, qualname: str) -> bool:
    for pat in hot_patterns(relpath):
        if pat == "*" or fnmatch.fnmatch(qualname, pat):
            return True
        # nested defs inherit their enclosing scope's hotness
        if qualname.startswith(pat + ".") or qualname.startswith(
                pat + ".<locals>."):
            return True
    return False


# ---------------------------------------------------------------------------
# Taint vocabulary for the host-sync dataflow
# ---------------------------------------------------------------------------

# method/attribute names whose call RETURNS device values (jax arrays):
# seeds of the taint.  Matched on the final attribute of the callee.
DEVICE_METHODS = frozenset({
    # controller / sharding
    "resolve", "shard_bits", "shard_budgets", "shard_batch", "device_put",
    # ServeEngine compiled programs + helpers
    "_prefill", "_prefill_row", "_decode_scan", "_decode_scan_sh",
    "_decode_one", "_draft", "_verify", "_sample_first", "_extend_row",
    "_bits", "_batch_bits", "_draft_bits", "_split_key",
    # CNN compiled program
    "_fwd",
})

# names whose call returns HOST values even when fed device state — the
# sanctioned cached per-admission helpers plus the coalesced transfer.
HOST_METHODS = frozenset({
    "host_bits", "_host_index", "_config_cost", "device_get",
    "block_until_ready",        # returns its (still-device) arg; callers
                                # using it as a barrier are not syncing data
})

# callees that force a host sync of their *arguments*: calling them on a
# device value is itself the finding (they np.asarray internally).
SYNC_ARG_METHODS = frozenset({
    "price_bits", "price", "price_verify", "price_matrix",
})

# jax.* callees that do NOT produce device values (abstract eval, host
# transfer, specs) — exempt from the jnp/jax taint seeding.
JAX_HOST_CALLS = frozenset({
    "jax.device_get", "jax.eval_shape", "jax.make_jaxpr",
    "jax.ShapeDtypeStruct", "jax.tree_util.tree_structure",
    "jax.block_until_ready",
})


# ---------------------------------------------------------------------------
# Closure-capture audit (STAT401)
# ---------------------------------------------------------------------------
# A captured local matching this predicate inside a jitted closure is a
# bit width baked in at trace time — the paper's §V.B invariant (one
# program across all precisions) dies exactly this way.
BIT_NAMES = frozenset({"wv", "av", "wb", "ab", "wmat", "amat"})


def is_bit_name(name: str) -> bool:
    return name in BIT_NAMES or "bit" in name.lower()


# ---------------------------------------------------------------------------
# Ledger waivers (ledger auditor)
# ---------------------------------------------------------------------------
# CostRecord fields written in serve/ that aggregate() intentionally
# does not consume, each naming the real consumer.  An aggregate()-side
# pickup makes the waiver STALE (the auditor flags it for removal).
LEDGER_WAIVED: Dict[str, str] = {
    "rid": "request identity joining the runtime queue, engine slots, "
           "and per-request report tables",
    "submitted_s": "latency_s property -> wall-clock latency reporting",
    "budget_s": "per-request SLO attainment in traffic.Collector and "
                "launch/serve.py's per-request table",
    "mean_wbits": "traffic.Collector bits-per-window series and the "
                  "launch CLIs' per-request tables",
    "cached_mean_wbits": "prefix-cache precision introspection in "
                         "launch/serve.py --prefix-cache ledger",
    "cached_cost": "hit repricing vs miss pricing in tests and the "
                   "prefix-cache benchmark",
    "cache_hit": "hit-kind split in benchmarks/prefix_cache.py and the "
                 "launch ledger",
    "planned_units": "axis_planned() admission charge, reconciled in "
                     "ServeRuntime.finish_record",
    "slot": "slot lifecycle bookkeeping in ServeEngine._admit/_finish",
    "submitted_tick": "queue-delay series in traffic.Collector",
    "admitted_tick": "queue-delay series in traffic.Collector",
    "finished_tick": "latency_ticks property -> traffic.Collector "
                     "tick-domain latency percentiles",
    "finished_s": "latency_s property -> wall-clock latency reporting",
    "spec_k": "per-request draft-depth reporting in "
              "benchmarks/spec_decode.py",
    "planned_spec_rounds": "axis_planned() speculative charge, "
                           "reconciled in finish_record",
    "planned_spec_tokens": "axis_planned() speculative charge, "
                           "reconciled in finish_record",
    # ImageStats-only fields (CNN serve writes them through the same
    # record type family)
    "index": "batch-position bookkeeping in CNNServeEngine.serve",
    "wbits": "per-image config introspection (tests, table7 benchmark)",
    "abits": "per-image config introspection (tests, table7 benchmark)",
}


def waiver_for(field: str) -> Optional[str]:
    return LEDGER_WAIVED.get(field)
