"""Retrace auditor (DESIGN.md §12, pass 2 of 4): the *static* proof of
the zero-retrace invariant.

``RuntimeStats.trace()`` counters prove at RUN time that one compiled
program serves every precision configuration (paper §V.B).  This pass
proves it at ANALYSIS time: every registered entrypoint — ragged
prefill, the scan-fused decode block, the SPEC_K_MAX draft scan, the
chunked verify, ``_extend_row``, and the CNN conv-GEMM forward — is
abstractly evaluated with :func:`jax.make_jaxpr` over real
:class:`~repro.serve.engine.ServeEngine` instances built on
``jax.eval_shape``'d parameters (no weight allocation, so the 1T-param
configs audit in milliseconds), across a variant matrix of budgets ×
draft depth k × (start, length).

Two failure modes, both fatal:

* **RT501** — an entrypoint yields more than one abstract signature
  (sha256 of input avals + jaxpr text) across its variants: some
  variant-dependent value reached the program as a static (weak-dtype
  drift, a Python scalar that shapes the jaxpr, a baked-in literal).
* **RT502** — an entrypoint fails to trace abstractly: budgets, bit
  vectors, and k/start/length enter the wrapper as TRACED inputs (the
  wrapper runs ``controller.resolve`` inside the trace), so any host
  conversion on the budget→bits→program path — ``int()`` on a bit
  width, ``np.asarray`` on a traced vector — raises
  ConcretizationTypeError right here instead of a retrace in
  production.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis.common import Finding

# budget values spanning the default controller's config table
BUDGETS = (0.0, 0.6, 0.8, 2.0)
BUDGET_MIXES = ((0.0, 2.0), (0.6, 0.8), (2.0, 2.0), (0.8, 0.0))

# audit-engine geometry (smoke configs: L=2, d=64, V=512)
N_SLOTS = 2
PREFILL_LEN = 8
MAX_LEN = 48
DECODE_BLOCK = 4

ENTRYPOINT_FILES: Dict[str, str] = {
    "prefill": "src/repro/models/lm.py",
    "decode_step": "src/repro/models/lm.py",
    "prefill_row": "src/repro/serve/engine.py",
    "decode_scan": "src/repro/serve/engine.py",
    "draft_scan": "src/repro/serve/engine.py",
    "verify_chunk": "src/repro/serve/engine.py",
    "extend_row": "src/repro/serve/engine.py",
    "sample_first": "src/repro/serve/engine.py",
    "cnn_forward": "src/repro/models/cnn.py",
}


@dataclasses.dataclass
class TraceReport:
    """One (config, entrypoint) audit: variant labels per signature."""
    config: str
    entrypoint: str
    signatures: Dict[str, List[str]]     # sig hash -> variant labels
    errors: Dict[str, str]               # variant label -> error text

    @property
    def ok(self) -> bool:
        return len(self.signatures) == 1 and not self.errors

    def findings(self) -> List[Finding]:
        file = ENTRYPOINT_FILES.get(self.entrypoint, "")
        out: List[Finding] = []
        if len(self.signatures) > 1:
            parts = "; ".join(
                f"{sig}: {', '.join(labels)}"
                for sig, labels in sorted(self.signatures.items()))
            out.append(Finding(
                rule="RT501", file=file, line=0,
                scope=f"{self.config}.{self.entrypoint}",
                message=f"{len(self.signatures)} abstract signatures "
                        f"across {sum(map(len, self.signatures.values()))} "
                        f"variants ({parts}) — each will compile "
                        f"separately in production",
                hint="a variant-dependent value is reaching the program "
                     "as a static; keep budgets/bits/k/start/length "
                     "traced (jnp.asarray) end to end"))
        for label, err in sorted(self.errors.items()):
            out.append(Finding(
                rule="RT502", file=file, line=0,
                scope=f"{self.config}.{self.entrypoint}",
                message=f"variant {label!r} failed abstract trace: {err}",
                hint="a host conversion (int()/float()/np.asarray) sits "
                     "on the budget->bits->program path; keep it traced"))
        return out


def signature(fn: Callable, *args) -> str:
    """sha256 of (input avals, jaxpr text) — the abstract identity of
    the program XLA would compile for these arguments."""
    import jax
    closed = jax.make_jaxpr(fn)(*args)
    avals = ", ".join(str(v.aval) for v in closed.jaxpr.invars)
    text = avals + "\n" + str(closed)
    return hashlib.sha256(text.encode()).hexdigest()[:16]


def audit_entrypoint(config: str, entrypoint: str,
                     variants: Sequence[Tuple[str, Callable[[], Tuple]]],
                     fn: Callable) -> TraceReport:
    """Trace ``fn`` once per variant (each thunk builds the arg tuple
    through the same construction code the engine uses) and bucket the
    resulting signatures."""
    sigs: Dict[str, List[str]] = {}
    errors: Dict[str, str] = {}
    for label, thunk in variants:
        try:
            sig = signature(fn, *thunk())
        except Exception as e:                  # noqa: BLE001 - reported
            errors[label] = f"{type(e).__name__}: {e}".splitlines()[0][:200]
            continue
        sigs.setdefault(sig, []).append(label)
    return TraceReport(config=config, entrypoint=entrypoint,
                       signatures=sigs, errors=errors)


# ---------------------------------------------------------------------------
# Abstract model state (no weight allocation)
# ---------------------------------------------------------------------------

def abstract_qparams(cfg):
    """ShapeDtypeStruct pytree of the serve-form quantized params."""
    import jax
    from repro.models import lm

    params = jax.eval_shape(lambda k: lm.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    return jax.eval_shape(lambda p: lm.quantize_params(p, cfg), params)


def abstract_cache(cfg, batch: int, max_len: int):
    import jax
    from repro.models import lm
    return jax.eval_shape(lambda: lm.empty_cache(cfg, batch, max_len))


def _default_controller(n: int):
    from repro.launch.serve import default_controller
    return default_controller(n)


# ---------------------------------------------------------------------------
# Per-config audits
# ---------------------------------------------------------------------------

def _build_engine(cfg):
    from repro import dist
    from repro.models import lm
    from repro.serve.engine import ServeEngine

    spec_ok = (cfg.family in lm.SPEC_CHUNK_FAMILIES
               and not cfg.sliding_window)
    controller = _default_controller(lm.n_bit_slots(cfg))
    # audit WITH placement enabled: an 8-device fully-replicated plan
    # attached to the engine must not change any compiled program's
    # signature (plans amortize host-side pricing; they never enter a
    # jaxpr)
    plan = dist.plan_for_controller(
        controller, lm.layer_gemm_dims(cfg), n_devices=8,
        head=lm.head_gemm_dims(cfg))
    return ServeEngine(
        cfg, abstract_qparams(cfg), max_len=MAX_LEN,
        controller=controller, plan=plan,
        n_slots=N_SLOTS, prefill_len=PREFILL_LEN,
        decode_block=DECODE_BLOCK,
        spec_k=2 if spec_ok else None,
        draft_budget_s=0.0 if spec_ok else None)


def _audit_engine(name: str, cfg) -> List[TraceReport]:
    """Engine-level audit for the continuous-batching families: the
    compiled programs, reached through the engine's own argument
    construction, with resolve() inside the trace."""
    import jax
    import jax.numpy as jnp
    from repro.models import lm
    from repro.serve.engine import SPEC_K_MAX

    eng = _build_engine(cfg)
    B, V = N_SLOTS, cfg.vocab_size
    cache = abstract_cache(cfg, B, MAX_LEN)
    row = abstract_cache(cfg, 1, MAX_LEN)
    q = eng.qparams
    npre = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
    reports: List[TraceReport] = []

    def keys(n: int):
        return jax.random.split(jax.random.PRNGKey(0), n)

    def slot_f32(vals):
        import numpy as np
        return jnp.asarray(np.asarray(vals, np.float64), jnp.float32)

    # ---- prefill_row: per-admission ragged prefill -------------------
    def prefill_row_fn(qp, budget, tokens, length, *prefix):
        wv, av = eng.controller.resolve(budget)
        return eng._prefill_row(qp, tokens, length, wv, av, *prefix)

    def prefill_row_args(budget: float, S: int):
        tokens = jnp.zeros((1, PREFILL_LEN), jnp.int32)
        extra = (() if npre == 0
                 else (jax.ShapeDtypeStruct((1, npre, cfg.d_model),
                                            jnp.float32),))
        return (q, jnp.asarray(budget, jnp.float32), tokens,
                jnp.asarray([S], jnp.int32)) + extra

    reports.append(audit_entrypoint(
        name, "prefill_row",
        [(f"budget={b}/S={s}",
          lambda b=b, s=s: prefill_row_args(b, s))
         for b in BUDGETS[:3] for s in (1, PREFILL_LEN)],
        prefill_row_fn))

    # ---- decode_scan: the per-tick scan-fused block ------------------
    def decode_fn(qp, budgets, tok, t, cache, temp, topk, ks):
        wv, av = eng.controller.resolve(budgets)
        return eng._decode_scan(qp, tok, t, cache, wv, av, temp, topk, ks)

    def decode_args(mix):
        return (q, slot_f32(mix),
                jnp.zeros((B, 1), jnp.int32),
                jnp.zeros((B,), jnp.int32), cache,
                jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.int32),
                keys(DECODE_BLOCK))

    reports.append(audit_entrypoint(
        name, "decode_scan",
        [(f"mix={mix}", lambda mix=mix: decode_args(mix))
         for mix in BUDGET_MIXES],
        decode_fn))

    # ---- sample_first: per-admission first-token sampling ------------
    reports.append(audit_entrypoint(
        name, "sample_first",
        [(f"temp={temp}", lambda temp=temp: (
            jax.ShapeDtypeStruct((1, 1, V), jnp.float32), keys(1)[0],
            jnp.asarray([temp], jnp.float32), jnp.asarray([0], jnp.int32)))
         for temp in (0.0, 0.7)],
        eng._sample_first))

    # ---- extend_row: partial prefix-cache hits -----------------------
    def extend_fn(qp, budget, tokens, row, start, r):
        wv, av = eng.controller.resolve(budget)
        return eng._extend_row(qp, tokens, row, start, r, wv, av)

    def extend_args(budget: float, start: int, r: int):
        return (q, jnp.asarray(budget, jnp.float32),
                jnp.zeros((1, PREFILL_LEN), jnp.int32), row,
                jnp.asarray(start, jnp.int32), jnp.asarray(r, jnp.int32))

    reports.append(audit_entrypoint(
        name, "extend_row",
        [(f"budget={b}/start={s}/r={r}",
          lambda b=b, s=s, r=r: extend_args(b, s, r))
         for b in BUDGETS[:2]
         for (s, r) in ((1, PREFILL_LEN - 1), (PREFILL_LEN - 1, 1))],
        extend_fn))

    if eng.spec_k is None:
        return reports

    # ---- draft_scan: SPEC_K_MAX low-bit self-draft -------------------
    def draft_fn(qp, tok, t, cache, temp, topk, ks):
        dwv, dav = eng._draft_bits()
        return eng._draft(qp, tok, t, cache, dwv, dav, temp, topk, ks)

    def draft_args(t0: int):
        return (q, jnp.zeros((B, 1), jnp.int32),
                jnp.full((B,), t0, jnp.int32), cache,
                jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.int32),
                keys(SPEC_K_MAX))

    reports.append(audit_entrypoint(
        name, "draft_scan",
        [(f"t={t0}", lambda t0=t0: draft_args(t0)) for t0 in (4, 9)],
        draft_fn))

    # ---- verify_chunk: one (SPEC_K_MAX + 1)-wide target-bit verify ---
    def verify_fn(qp, budgets, tok, dt, dp, t, cache, k_eff, temp, topk,
                  ku, ks_):
        wv, av = eng.controller.resolve(budgets)
        return eng._verify(qp, tok, dt, dp, t, cache, wv, av, k_eff,
                           temp, topk, ku, ks_)

    def verify_args(mix, k: int):
        import numpy as np
        return (q, slot_f32(mix), jnp.zeros((B, 1), jnp.int32),
                jnp.zeros((B, SPEC_K_MAX), jnp.int32),
                jax.ShapeDtypeStruct((B, SPEC_K_MAX, V), jnp.float32),
                jnp.zeros((B,), jnp.int32), cache,
                jnp.asarray(np.minimum(k, np.arange(1, B + 1)), jnp.int32),
                jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.int32),
                keys(1)[0], keys(2)[1])

    reports.append(audit_entrypoint(
        name, "verify_chunk",
        [(f"mix={mix}/k={k}",
          lambda mix=mix, k=k: verify_args(mix, k))
         for mix in BUDGET_MIXES[:2] for k in (0, 1, SPEC_K_MAX)],
        verify_fn))
    return reports


def _audit_model(name: str, cfg) -> List[TraceReport]:
    """Model-level audit for the whole-batch families (ssm/moe/hybrid/
    encdec): prefill + decode_step through generate()'s construction."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops as kops
    from repro.models import lm

    B, S = 2, PREFILL_LEN
    L = lm.n_bit_slots(cfg)
    ctrl = _default_controller(L)
    q = abstract_qparams(cfg)
    cache = abstract_cache(cfg, B, MAX_LEN)
    fams = tuple(sorted({4, 8}))
    reports: List[TraceReport] = []

    def prefill_fn(qp, budget, tokens, cache, *extra):
        wv, av = ctrl.resolve(budget)
        batch = {"tokens": tokens}
        if cfg.family == "encdec":
            batch["frames"] = extra[0]
        with kops.bit_families(fams):
            return lm.prefill(qp, batch, cfg, wv, av, cache)

    def prefill_args(budget: float):
        extra = ()
        if cfg.family == "encdec":
            F = max(MAX_LEN // cfg.frames_ratio, 1)
            extra = (jax.ShapeDtypeStruct((B, F, cfg.d_model),
                                          jnp.float32),)
        return (q, jnp.asarray(budget, jnp.float32),
                jnp.zeros((B, S), jnp.int32), cache) + extra

    reports.append(audit_entrypoint(
        name, "prefill",
        [(f"budget={b}", lambda b=b: prefill_args(b)) for b in BUDGETS],
        prefill_fn))

    def decode_fn(qp, budget, tok, t, cache):
        wv, av = ctrl.resolve(budget)
        with kops.bit_families(fams):
            return lm.decode_step(qp, tok, t, cache, cfg, wv, av)

    def decode_args(budget: float, t0: int):
        return (q, jnp.asarray(budget, jnp.float32),
                jnp.zeros((B, 1), jnp.int32),
                jnp.full((B,), t0, jnp.int32), cache)

    reports.append(audit_entrypoint(
        name, "decode_step",
        [(f"budget={b}/t={t0}", lambda b=b, t0=t0: decode_args(b, t0))
         for b in BUDGETS[:3] for t0 in (S,)],
        decode_fn))
    return reports


def _audit_cnn() -> List[TraceReport]:
    """CNN conv-GEMM path: one abstract signature across every HAWQ-v3
    ResNet18 configuration (the paper's headline config-switching claim,
    statically)."""
    import jax
    import jax.numpy as jnp
    from repro.apsim.workloads import HAWQV3_RESNET18, per_layer_bits
    from repro.kernels import ops as kops
    from repro.models import cnn

    image = 16
    box: Dict[str, object] = {}

    def build(k):
        params, layers = cnn.init_cnn("resnet18", k, image=image)
        box["layers"] = layers
        return params

    params = jax.eval_shape(build, jax.random.PRNGKey(0))
    layers = box["layers"]
    qp = jax.eval_shape(
        lambda p: cnn.quantize_cnn_params(p, layers), params)

    def fwd(qparams, x, wv, av):
        with kops.bit_families((4, 8)):
            return cnn.cnn_forward(qparams, x, layers, wv, av)

    def args(vec):
        bits = jnp.asarray(per_layer_bits(layers, vec), jnp.int32)
        return (qp, jax.ShapeDtypeStruct((2, image, image, 3),
                                         jnp.float32), bits, bits)

    return [audit_entrypoint(
        "resnet18_hawq", "cnn_forward",
        [(cfg_name, lambda vec=vec: args(vec))
         for cfg_name, vec in HAWQV3_RESNET18.items()],
        fwd)]


def audit_config(name: str) -> List[TraceReport]:
    from repro import configs
    from repro.models import lm

    cfg = configs.get_smoke(name)
    if cfg.family in lm.RAGGED_PREFILL_FAMILIES:
        return _audit_engine(name, cfg)
    return _audit_model(name, cfg)


def run_retrace(arch_ids: Optional[Sequence[str]] = None,
                include_cnn: bool = True
                ) -> Tuple[List[Finding], List[TraceReport]]:
    """Audit every config (default: all ten) + the CNN path.  Returns
    (findings, reports); an empty findings list IS the static
    zero-retrace proof."""
    from repro import configs

    reports: List[TraceReport] = []
    for name in (arch_ids if arch_ids is not None else configs.ARCH_IDS):
        reports.extend(audit_config(name))
    if include_cnn:
        reports.extend(_audit_cnn())
    findings = [f for r in reports for f in r.findings()]
    return findings, reports
