"""Ledger auditor (DESIGN.md §12, pass 4 of 4): no pricing field falls
out of the ledger silently.

The PR 7/8 reconciliation bugs were all one shape: the serve layer
writes a :class:`~repro.serve.accounting.CostRecord` field (a new cost
split, a speculative counter) and ``accounting.aggregate()`` keeps
summing without it — the ledger stays green while under-counting.  This
pass closes the loop symbolically:

* **writes** — every record field assigned anywhere under
  ``src/repro/serve/`` (attribute stores *and* ``RequestStats(...)`` /
  ``ImageStats(...)`` constructor keywords);
* **reads** — the transitive closure of attribute loads reachable from
  ``aggregate()``'s body through the record classes' properties and
  methods (``edp → ap_energy_j → _axis_total → ap_cost`` …);
* **LG701** (fatal) — a field written but neither consumed by
  ``aggregate()`` nor waived in
  :data:`repro.analysis.registry.LEDGER_WAIVED`;
* **LG702** (fatal) — a STALE waiver: the waived field is now consumed
  by ``aggregate()`` (the waiver hides nothing and must go) or is no
  longer written anywhere (the code it excused is gone).
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis import registry
from repro.analysis.common import (Finding, ParsedModule, iter_modules,
                                   parse_module, qualname_index, repo_root)

ACCOUNTING = "src/repro/serve/accounting.py"
RECORD_CLASSES = ("CostRecord", "RequestStats", "ImageStats")
AGGREGATE = "aggregate"


def _attr_loads(node: ast.AST, self_only: bool = False) -> Set[str]:
    """Names of every attribute LOAD in the subtree.

    ``self_only`` restricts to ``self.<attr>`` — used when expanding
    record property bodies, so a same-named attribute on some OTHER
    object (``self.ap_cost.latency_s`` is a ``BitVectorCost`` field,
    not the record's ``latency_s`` property) can't leak into the
    transitive consumption set."""
    out: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and isinstance(n.ctx, ast.Load):
            if self_only and not (isinstance(n.value, ast.Name)
                                  and n.value.id == "self"):
                continue
            out.add(n.attr)
    return out


def record_schema(mod: ParsedModule
                  ) -> Tuple[Set[str], Dict[str, Set[str]]]:
    """(dataclass field names, member name -> attr loads in its body)
    across the record class family in ``accounting.py``."""
    fields: Set[str] = set()
    members: Dict[str, Set[str]] = {}
    for node in mod.tree.body:
        if not (isinstance(node, ast.ClassDef)
                and node.name in RECORD_CLASSES):
            continue
        for stmt in node.body:
            if (isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)
                    and not stmt.target.id.startswith("_")):
                fields.add(stmt.target.id)
            elif isinstance(stmt, ast.FunctionDef):
                loads = _attr_loads(stmt, self_only=True)
                members[stmt.name] = members.get(stmt.name, set()) | loads
    return fields, members


def consumed_fields(mod: ParsedModule, fields: Set[str],
                    members: Dict[str, Set[str]]) -> Set[str]:
    """Transitive closure of attribute loads from ``aggregate()``."""
    agg = next((n for n in mod.tree.body
                if isinstance(n, ast.FunctionDef)
                and n.name == AGGREGATE), None)
    if agg is None:
        return set()
    reached = _attr_loads(agg)
    frontier = [m for m in reached if m in members]
    seen: Set[str] = set()
    while frontier:
        m = frontier.pop()
        if m in seen:
            continue
        seen.add(m)
        loads = members[m]
        new = loads - reached
        reached |= loads
        frontier.extend(x for x in new if x in members)
    return reached & fields


def written_fields(mods: Sequence[ParsedModule], fields: Set[str]
                   ) -> Dict[str, List[Tuple[str, int, str]]]:
    """field -> [(file, line, scope)] for every write in serve/."""
    out: Dict[str, List[Tuple[str, int, str]]] = {}

    def note(field: str, mod: ParsedModule, node: ast.AST,
             scope: str) -> None:
        out.setdefault(field, []).append(
            (mod.relpath, getattr(node, "lineno", 0), scope))

    for mod in mods:
        qnames = qualname_index(mod.tree)

        def scope_of(node: ast.AST) -> str:
            best = ""
            for fn, qn in qnames.items():
                if (hasattr(fn, "lineno") and hasattr(node, "lineno")
                        and fn.lineno <= node.lineno
                        <= getattr(fn, "end_lineno", fn.lineno)
                        and len(qn) > len(best)):
                    best = qn
            return best

        for n in ast.walk(mod.tree):
            targets: List[ast.expr] = []
            if isinstance(n, ast.Assign):
                targets = list(n.targets)
            elif isinstance(n, (ast.AugAssign, ast.AnnAssign)):
                targets = [n.target]
            for t in targets:
                if isinstance(t, ast.Tuple):
                    elts = list(t.elts)
                else:
                    elts = [t]
                for e in elts:
                    if (isinstance(e, ast.Attribute)
                            and e.attr in fields):
                        note(e.attr, mod, n, scope_of(n))
            if isinstance(n, ast.Call):
                callee = n.func
                cname = callee.attr if isinstance(callee, ast.Attribute) \
                    else getattr(callee, "id", None)
                if cname in RECORD_CLASSES:
                    for kw in n.keywords:
                        if kw.arg and kw.arg in fields:
                            note(kw.arg, mod, n, scope_of(n))
    return out


def run_ledger(root: Optional[str] = None
               ) -> Tuple[List[Finding], Dict[str, Set[str]]]:
    root = root or repo_root()
    acct = parse_module(os.path.join(root, ACCOUNTING), ACCOUNTING)
    fields, members = record_schema(acct)
    consumed = consumed_fields(acct, fields, members)
    serve_mods = [m for m in iter_modules(root, ("src/repro/serve",))
                  if m.relpath != ACCOUNTING]
    writes = written_fields(serve_mods, fields)

    findings: List[Finding] = []
    for field in sorted(writes):
        if field in consumed or registry.waiver_for(field):
            continue
        file, line, scope = writes[field][0]
        findings.append(Finding(
            rule="LG701", file=file, line=line, scope=scope,
            message=f"CostRecord field {field!r} is written here (and at "
                    f"{len(writes[field]) - 1} other site(s)) but "
                    f"aggregate() never consumes it",
            hint="sum it in accounting.aggregate() or add a justified "
                 "entry to registry.LEDGER_WAIVED naming the real "
                 "consumer"))
    for field, why in sorted(registry.LEDGER_WAIVED.items()):
        if field in consumed:
            findings.append(Finding(
                rule="LG702", file=ACCOUNTING, line=0, scope=AGGREGATE,
                message=f"stale waiver: {field!r} ({why.split(',')[0]}) "
                        f"IS consumed by aggregate() now",
                hint="delete the LEDGER_WAIVED entry"))
        elif field not in writes:
            findings.append(Finding(
                rule="LG702", file=ACCOUNTING, line=0, scope=AGGREGATE,
                message=f"stale waiver: {field!r} is never written "
                        f"under serve/ anymore",
                hint="delete the LEDGER_WAIVED entry"))
    detail = {"fields": fields, "consumed": consumed,
              "written": set(writes)}
    return findings, detail
