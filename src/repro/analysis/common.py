"""Shared vocabulary of the static-analysis suite (DESIGN.md §12).

Every pass — the AST linter, the retrace auditor, the sharding checker,
the ledger auditor — reports :class:`Finding` objects carrying a rule
ID, a location, and a fix hint, so one CLI (``repro.launch.analyze``)
renders and gates them uniformly.  Grandfathered findings live in a
checked-in :class:`Baseline` file next to this package; every entry
must carry a ``why`` (the CI gate is "empty or individually
justified").
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

BASELINE_PATH = os.path.join(os.path.dirname(__file__), "baseline.json")


@dataclasses.dataclass
class Finding:
    """One analysis finding: where, what, and how to fix it."""
    rule: str                    # rule ID, e.g. "HS102"
    file: str                    # repo-relative path
    line: int                    # 1-based source line (0 = file-level)
    scope: str                   # enclosing qualname ("" = module level)
    message: str                 # what is wrong, concretely
    hint: str = ""               # how to fix it
    snippet: str = ""            # offending source excerpt

    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.file, self.scope)

    def render(self) -> str:
        loc = f"{self.file}:{self.line}" if self.line else self.file
        scope = f" [{self.scope}]" if self.scope else ""
        out = f"{self.rule} {loc}{scope}: {self.message}"
        if self.snippet:
            out += f"\n      > {self.snippet.strip()}"
        if self.hint:
            out += f"\n      fix: {self.hint}"
        return out


class Baseline:
    """Checked-in grandfathered findings.

    Entries match on (rule, file, scope) plus a ``match`` substring of
    the offending snippet, so they survive line drift but die when the
    code they justify changes.  Every entry needs a ``why``.
    """

    def __init__(self, entries: Sequence[dict]) -> None:
        for e in entries:
            missing = {"rule", "file", "match", "why"} - set(e)
            if missing:
                raise ValueError(f"baseline entry {e} missing {missing}")
        self.entries = list(entries)
        self._used = [False] * len(self.entries)

    @classmethod
    def load(cls, path: Optional[str] = None) -> "Baseline":
        path = path or BASELINE_PATH
        if not os.path.exists(path):
            return cls([])
        with open(path) as f:
            data = json.load(f)
        return cls(data.get("entries", []))

    def suppresses(self, finding: Finding) -> bool:
        for i, e in enumerate(self.entries):
            if (e["rule"] == finding.rule and e["file"] == finding.file
                    and e.get("scope", finding.scope) == finding.scope
                    and e["match"] in (finding.snippet or finding.message)):
                self._used[i] = True
                return True
        return False

    def stale(self) -> List[dict]:
        """Entries that suppressed nothing — the code they justified is
        gone, so the grandfather clause should go too."""
        return [e for e, used in zip(self.entries, self._used) if not used]


def apply_baseline(findings: Iterable[Finding], baseline: Baseline
                   ) -> Tuple[List[Finding], List[Finding]]:
    """Split findings into (fresh, suppressed)."""
    fresh, suppressed = [], []
    for f in findings:
        (suppressed if baseline.suppresses(f) else fresh).append(f)
    return fresh, suppressed


# ---------------------------------------------------------------------------
# Source tree walking
# ---------------------------------------------------------------------------

def repo_root(start: Optional[str] = None) -> str:
    """The repository root: the directory holding ``src/repro``."""
    here = start or os.path.dirname(__file__)          # .../src/repro/analysis
    root = os.path.abspath(os.path.join(here, "..", "..", ".."))
    if not os.path.isdir(os.path.join(root, "src", "repro")):
        # installed package: fall back to cwd if it looks like the repo
        cwd = os.getcwd()
        if os.path.isdir(os.path.join(cwd, "src", "repro")):
            return cwd
    return root


@dataclasses.dataclass
class ParsedModule:
    """One parsed source file plus the qualname of every def."""
    relpath: str                 # repo-relative, '/'-separated
    source: str
    tree: ast.Module
    lines: List[str]

    def snippet(self, node: ast.AST) -> str:
        try:
            seg = ast.get_source_segment(self.source, node)
        except Exception:
            seg = None
        if seg:
            return seg.splitlines()[0][:120]
        ln = getattr(node, "lineno", 0)
        return self.lines[ln - 1].strip()[:120] if 0 < ln <= len(self.lines) \
            else ""


def parse_module(path: str, relpath: str) -> ParsedModule:
    with open(path) as f:
        source = f.read()
    return ParsedModule(relpath=relpath.replace(os.sep, "/"), source=source,
                        tree=ast.parse(source, filename=relpath),
                        lines=source.splitlines())


def iter_modules(root: str, subdirs: Sequence[str]) -> List[ParsedModule]:
    """Parse every ``.py`` file under ``root/<subdir>`` (sorted, stable)."""
    mods: List[ParsedModule] = []
    for sub in subdirs:
        base = os.path.join(root, sub)
        for dirpath, _dirnames, filenames in sorted(os.walk(base)):
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                full = os.path.join(dirpath, fn)
                mods.append(parse_module(full, os.path.relpath(full, root)))
    return mods


def qualname_index(tree: ast.Module) -> Dict[ast.AST, str]:
    """Map every FunctionDef/AsyncFunctionDef/ClassDef node to its
    dotted qualname (``Class.method``, ``outer.<locals>.inner``)."""
    out: Dict[ast.AST, str] = {}

    def walk(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                name = f"{prefix}{child.name}" if prefix else child.name
                out[child] = name
                sep = "." if isinstance(child, ast.ClassDef) else ".<locals>."
                walk(child, f"{name}{sep}")
            else:
                walk(child, prefix)

    walk(tree, "")
    return out


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None
