"""Static-analysis suite (DESIGN.md §12): four passes, one gate.

``run_suite`` executes the AST linter, the retrace auditor, the
sharding checker, and the ledger auditor, applies the checked-in
baseline, and reports a single ok/fail — the same entry the
``repro.launch.analyze`` CLI, the CI ``analysis`` job, and
``benchmarks/compare.py``'s baseline-update guard all use.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.analysis.common import (Baseline, Finding, apply_baseline,
                                   repo_root)

ALL_PASSES = ("lint", "retrace", "sharding", "ledger")


@dataclasses.dataclass
class PassResult:
    name: str
    fresh: List[Finding]
    suppressed: List[Finding]
    notes: List[str]

    @property
    def ok(self) -> bool:
        return not self.fresh


@dataclasses.dataclass
class SuiteResult:
    passes: List[PassResult]
    stale_baseline: List[dict]

    @property
    def ok(self) -> bool:
        return (all(p.ok for p in self.passes)
                and not self.stale_baseline)

    def to_dict(self) -> Dict:
        return {
            "ok": self.ok,
            "passes": {
                p.name: {
                    "ok": p.ok,
                    "fresh": [dataclasses.asdict(f) for f in p.fresh],
                    "suppressed": len(p.suppressed),
                    "notes": p.notes,
                } for p in self.passes
            },
            "stale_baseline": self.stale_baseline,
        }


def run_suite(passes: Sequence[str] = ALL_PASSES,
              arch_ids: Optional[Sequence[str]] = None,
              root: Optional[str] = None,
              baseline_path: Optional[str] = None) -> SuiteResult:
    """Run the requested passes against the repo at ``root``.

    Baseline staleness is only judged when every pass ran (a subset run
    cannot tell whether the other passes' entries still suppress)."""
    from repro.analysis import ledger, lint, retrace, sharding

    root = root or repo_root()
    bl = Baseline.load(baseline_path)
    results: List[PassResult] = []
    for name in passes:
        notes: List[str] = []
        if name == "lint":
            found = lint.run_lint(root)
        elif name == "retrace":
            found, reports = retrace.run_retrace(arch_ids)
            n_variants = sum(
                sum(len(v) for v in r.signatures.values())
                + len(r.errors) for r in reports)
            notes.append(f"{len(reports)} entrypoint audits, "
                         f"{n_variants} traced variants, "
                         f"{sum(1 for r in reports if r.ok)} single-"
                         f"signature")
        elif name == "sharding":
            found, summary = sharding.run_sharding(arch_ids)
            leaves = sum(s["leaves"] for s in summary.values())
            sharded = sum(s["sharded"] for s in summary.values())
            notes.append(f"{len(summary)} configs, {leaves} leaf×mesh "
                         f"specs checked, {sharded} sharded")
        elif name == "ledger":
            found, detail = ledger.run_ledger(root)
            notes.append(f"{len(detail['written'])} fields written, "
                         f"{len(detail['consumed'])} consumed by "
                         f"aggregate(), "
                         f"{len(detail['written']) - len(detail['consumed'] & detail['written'])}"
                         f" waived")
        else:
            raise ValueError(f"unknown analysis pass {name!r}")
        fresh, suppressed = apply_baseline(found, bl)
        results.append(PassResult(name=name, fresh=fresh,
                                  suppressed=suppressed, notes=notes))
    stale = bl.stale() if set(passes) >= set(ALL_PASSES) else []
    return SuiteResult(passes=results, stale_baseline=stale)


__all__ = ["ALL_PASSES", "Baseline", "Finding", "PassResult",
           "SuiteResult", "run_suite"]
