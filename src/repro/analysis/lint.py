"""JAX-specific AST linter (DESIGN.md §12, pass 1 of 4).

Rules (each carries an ID + fix-hint; grandfathered findings live in
``analysis/baseline.json``):

* **HS101** — ``.item()`` / ``.tolist()`` on a device value inside a
  registered hot scope (``registry.HOT_SCOPES``): a per-element host
  sync on the serving tick path.
* **HS102** — host conversion of a device value in a hot scope:
  ``float()`` / ``int()`` / ``bool()`` / ``np.asarray`` / ``np.*``, or
  passing a device value to a pricing call that converts internally
  (``registry.SYNC_ARG_METHODS``).  The fix is almost always ONE
  coalesced ``jax.device_get(...)`` per tick, or the cached host-side
  helpers (``host_bits`` / ``_host_index`` / ``_config_cost``).
* **HS103** — host control flow (``if`` / ``while`` / ``assert`` /
  ``for``) over a device value in a hot scope: an implicit ``bool()``
  sync, and a ConcretizationTypeError the moment the scope is traced.
* **ND201** — iteration over a set (``for x in {...}``, comprehension
  over ``set(...)``, ``tuple(<set>)``): hash-order nondeterminism in
  modules that feed jitted programs.  ``sorted(<set>)`` is the fix and
  is recognized as clean.
* **RNG301** — unseeded RNG: ``np.random.default_rng()`` with no seed,
  the legacy ``np.random.<fn>`` global generator, stdlib ``random.<fn>``.
* **STAT401** — a bit width captured statically where a traced value is
  expected: a jitted closure capturing a bit-named local from its
  enclosing scope, or ``jax.jit(..., static_argnums/static_argnames)``
  marking a bit-named parameter static.  This bakes one precision into
  the compiled program — the exact hazard class the zero-retrace design
  (paper §V.B) exists to prevent; the retrace auditor is the dynamic
  complement of this rule.

The host-sync dataflow is intraprocedural taint: device-ness seeds from
``jnp.*`` / ``jax.*`` calls and ``registry.DEVICE_METHODS``, clears
through ``registry.HOST_METHODS`` (``jax.device_get`` and the cached
helpers), and a flagged conversion yields a HOST result — downstream
use of the converted value is deliberately not re-flagged.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis import registry
from repro.analysis.common import (Finding, ParsedModule, dotted,
                                   iter_modules, qualname_index, repo_root)

LINT_SUBDIRS = ("src/repro",)
# the analyzers themselves and the host-side CLIs are not serving code
EXCLUDE_PREFIXES = ("src/repro/analysis/",)


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    title: str
    hint: str


RULES: Dict[str, Rule] = {r.id: r for r in [
    Rule("HS101", "per-element host sync (.item()/.tolist()) in hot scope",
         "batch the transfer: one jax.device_get((a, b, ...)) per tick"),
    Rule("HS102", "host conversion of device value in hot scope",
         "coalesce into one jax.device_get per tick, or use the cached "
         "host-side helpers (host_bits/_host_index/_config_cost)"),
    Rule("HS103", "host control flow on device value in hot scope",
         "device_get once, branch on the host copy (or move the branch "
         "into the traced program via jnp.where/lax.cond)"),
    Rule("ND201", "set iteration order is nondeterministic",
         "wrap in sorted(...): trace-feeding order must be stable "
         "across processes"),
    Rule("RNG301", "unseeded / global RNG construction",
         "np.random.default_rng(seed) with an explicit seed (derive "
         "from the experiment seed)"),
    Rule("STAT401", "bit width captured statically in compiled program",
         "pass bits as a traced argument (jnp.asarray) so one program "
         "serves every precision configuration"),
]}

_LEGACY_NP_RANDOM = frozenset({
    "rand", "randn", "randint", "random", "random_sample", "choice",
    "shuffle", "permutation", "normal", "uniform", "seed",
})
_STDLIB_RANDOM = frozenset({
    "random", "randint", "choice", "choices", "shuffle", "uniform",
    "sample", "randrange", "getrandbits", "seed", "gauss",
})
_CONVERTERS = frozenset({"float", "int", "bool", "complex"})


def _last_attr(func: ast.AST) -> Optional[str]:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    return False


# ---------------------------------------------------------------------------
# HS101/HS102/HS103 — intraprocedural device-taint in hot scopes
# ---------------------------------------------------------------------------

class _TaintVisitor:
    """Walks one hot function's statements in order, tracking which
    local (dotted) names hold device values."""

    def __init__(self, mod: ParsedModule, scope: str) -> None:
        self.mod = mod
        self.scope = scope
        self.tainted: Set[str] = set()
        self.findings: List[Finding] = []
        self._seen: Set[Tuple[str, int, str]] = set()

    # -- findings ---------------------------------------------------------

    def flag(self, rule: str, node: ast.AST, message: str) -> None:
        key = (rule, node.lineno, message)
        if key in self._seen:
            return
        self._seen.add(key)
        self.findings.append(Finding(
            rule=rule, file=self.mod.relpath, line=node.lineno,
            scope=self.scope, message=message, hint=RULES[rule].hint,
            snippet=self.mod.snippet(node)))

    # -- expression taint -------------------------------------------------

    def taint_of(self, node: ast.AST) -> bool:
        """True if evaluating ``node`` yields a device value.  Flags any
        sync the evaluation itself performs."""
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            d = dotted(node)
            return d in self.tainted if d else self.taint_of(node.value)
        if isinstance(node, ast.Call):
            return self._call_taint(node)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.taint_of(e) for e in node.elts)
        if isinstance(node, ast.Subscript):
            return self.taint_of(node.value)
        if isinstance(node, ast.BinOp):
            return self.taint_of(node.left) or self.taint_of(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.taint_of(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.taint_of(v) for v in node.values)
        if isinstance(node, ast.Compare):
            return (self.taint_of(node.left)
                    or any(self.taint_of(c) for c in node.comparators))
        if isinstance(node, ast.IfExp):
            return self.taint_of(node.body) or self.taint_of(node.orelse)
        if isinstance(node, ast.Starred):
            return self.taint_of(node.value)
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            return any(self.taint_of(g.iter) for g in node.generators) \
                or self.taint_of(node.elt)
        if isinstance(node, ast.JoinedStr):
            # f-string: formatting a device value is a sync
            for v in node.values:
                if isinstance(v, ast.FormattedValue) \
                        and self.taint_of(v.value):
                    self.flag("HS102", node,
                              "formatting a device value forces a host "
                              "sync")
            return False
        return False

    def _args_taint(self, node: ast.Call) -> bool:
        return (any(self.taint_of(a) for a in node.args)
                or any(self.taint_of(k.value) for k in node.keywords))

    def _call_taint(self, node: ast.Call) -> bool:
        func = node.func
        d = dotted(func) or ""
        name = _last_attr(func)

        # receiver.method() syncs
        if isinstance(func, ast.Attribute):
            recv_taint = self.taint_of(func.value)
            if name in ("item", "tolist") and recv_taint:
                self.flag("HS101", node,
                          f".{name}() on a device value is a per-call "
                          f"host sync")
                return False
        if d in registry.JAX_HOST_CALLS or name in registry.HOST_METHODS:
            # host-returning: evaluate args (nested syncs still flag)
            self._args_taint(node)
            return False
        if name in registry.SYNC_ARG_METHODS:
            if self._args_taint(node):
                self.flag("HS102", node,
                          f"{name}() converts its arguments to host "
                          f"numpy — passing device values syncs per "
                          f"call")
            return False
        if name in _CONVERTERS and isinstance(func, ast.Name):
            if self._args_taint(node):
                self.flag("HS102", node,
                          f"{name}() on a device value forces a host "
                          f"sync")
            return False
        if d.startswith("np.") or d.startswith("numpy."):
            if self._args_taint(node):
                self.flag("HS102", node,
                          f"{d.split('(')[0]} on a device value forces "
                          f"a device->host transfer")
            return False
        if d.startswith("jnp.") or d.startswith("jax.") \
                or name in registry.DEVICE_METHODS:
            self._args_taint(node)
            return True
        # unknown callee: conservative propagate
        return self._args_taint(node)

    # -- statements -------------------------------------------------------

    def assign_target(self, target: ast.AST, taint: bool) -> None:
        if isinstance(target, ast.Name):
            (self.tainted.add if taint
             else self.tainted.discard)(target.id)
        elif isinstance(target, ast.Attribute):
            d = dotted(target)
            if d:
                (self.tainted.add if taint else self.tainted.discard)(d)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self.assign_target(e, taint)
        elif isinstance(target, ast.Starred):
            self.assign_target(target.value, taint)
        # subscript stores don't bind a trackable name

    def run_body(self, body: Sequence[ast.stmt]) -> None:
        for stmt in body:
            self.run_stmt(stmt)

    def run_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            t = self.taint_of(stmt.value)
            for target in stmt.targets:
                self.assign_target(target, t)
        elif isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self.assign_target(stmt.target, self.taint_of(stmt.value))
        elif isinstance(stmt, ast.AugAssign):
            t = self.taint_of(stmt.value) or self.taint_of(stmt.target)
            self.assign_target(stmt.target, t)
        elif isinstance(stmt, (ast.If, ast.While)):
            if self.taint_of(stmt.test):
                self.flag("HS103", stmt.test,
                          "branching on a device value is an implicit "
                          "bool() host sync")
            self.run_body(stmt.body)
            self.run_body(stmt.orelse)
            if isinstance(stmt, ast.While):    # second pass: loop taint
                self.run_body(stmt.body)
        elif isinstance(stmt, ast.Assert):
            if self.taint_of(stmt.test):
                self.flag("HS103", stmt.test,
                          "asserting on a device value is an implicit "
                          "bool() host sync")
        elif isinstance(stmt, ast.For):
            if self.taint_of(stmt.iter):
                self.flag("HS103", stmt.iter,
                          "iterating a device value syncs per element")
                self.assign_target(stmt.target, False)
            else:
                self.assign_target(stmt.target, False)
            self.run_body(stmt.body)
            self.run_body(stmt.body)           # second pass: loop taint
            self.run_body(stmt.orelse)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self.taint_of(item.context_expr)
                if item.optional_vars is not None:
                    self.assign_target(item.optional_vars, False)
            self.run_body(stmt.body)
        elif isinstance(stmt, ast.Try):
            self.run_body(stmt.body)
            for h in stmt.handlers:
                self.run_body(h.body)
            self.run_body(stmt.orelse)
            self.run_body(stmt.finalbody)
        elif isinstance(stmt, (ast.Expr, ast.Return)):
            if stmt.value is not None:
                self.taint_of(stmt.value)
        elif isinstance(stmt, ast.Raise):
            if stmt.exc is not None:
                self.taint_of(stmt.exc)
        elif isinstance(stmt, ast.Delete):
            for t in stmt.targets:
                self.assign_target(t, False)
        # nested defs are visited when their own scope is analyzed


def _check_hot_scopes(mod: ParsedModule) -> List[Finding]:
    findings: List[Finding] = []
    for node, qual in qualname_index(mod.tree).items():
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not registry.is_hot(mod.relpath, qual):
            continue
        v = _TaintVisitor(mod, qual)
        v.run_body(node.body)
        findings.extend(v.findings)
    return findings


# ---------------------------------------------------------------------------
# ND201 — set-iteration nondeterminism
# ---------------------------------------------------------------------------

def _check_set_order(mod: ParsedModule) -> List[Finding]:
    findings: List[Finding] = []
    quals = qualname_index(mod.tree)
    scopes: Dict[int, str] = {}

    def scope_of(node: ast.AST, current: str) -> str:
        return quals.get(node, current)

    def flag(node: ast.AST, scope: str, what: str) -> None:
        findings.append(Finding(
            rule="ND201", file=mod.relpath, line=node.lineno, scope=scope,
            message=f"{what} iterates a set in hash order",
            hint=RULES["ND201"].hint, snippet=mod.snippet(node)))

    def walk(node: ast.AST, scope: str) -> None:
        scope = scope_of(node, scope)
        if isinstance(node, ast.For) and _is_set_expr(node.iter):
            flag(node.iter, scope, "for loop")
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            for g in node.generators:
                if _is_set_expr(g.iter):
                    # a set comprehension over a set re-hashes: order
                    # nondeterminism only escapes via ordered outputs
                    if not isinstance(node, (ast.SetComp, ast.DictComp)):
                        flag(g.iter, scope, "comprehension")
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("list", "tuple") and node.args \
                and _is_set_expr(node.args[0]):
            flag(node, scope, f"{node.func.id}(...)")
        for child in ast.iter_child_nodes(node):
            walk(child, scope)

    walk(mod.tree, "")
    return findings


# ---------------------------------------------------------------------------
# RNG301 — unseeded RNG construction
# ---------------------------------------------------------------------------

def _check_rng(mod: ParsedModule) -> List[Finding]:
    findings: List[Finding] = []
    quals = qualname_index(mod.tree)

    def flag(node: ast.AST, scope: str, message: str) -> None:
        findings.append(Finding(
            rule="RNG301", file=mod.relpath, line=node.lineno, scope=scope,
            message=message, hint=RULES["RNG301"].hint,
            snippet=mod.snippet(node)))

    def walk(node: ast.AST, scope: str) -> None:
        scope = quals.get(node, scope)
        if isinstance(node, ast.Call):
            d = dotted(node.func) or ""
            if d in ("np.random.default_rng", "numpy.random.default_rng") \
                    and not node.args and not node.keywords:
                flag(node, scope, "default_rng() without a seed draws "
                                  "from OS entropy — runs are not "
                                  "reproducible")
            parts = d.split(".")
            if len(parts) == 3 and parts[0] in ("np", "numpy") \
                    and parts[1] == "random" \
                    and parts[2] in _LEGACY_NP_RANDOM:
                flag(node, scope, f"{d}() uses the legacy GLOBAL numpy "
                                  f"generator (cross-module state)")
            if len(parts) == 2 and parts[0] == "random" \
                    and parts[1] in _STDLIB_RANDOM:
                flag(node, scope, f"{d}() uses the stdlib global "
                                  f"generator (cross-module state)")
        for child in ast.iter_child_nodes(node):
            walk(child, scope)

    walk(mod.tree, "")
    return findings


# ---------------------------------------------------------------------------
# STAT401 — static bit capture audit
# ---------------------------------------------------------------------------

def _local_bindings(fn: ast.AST) -> Set[str]:
    """Names bound in ``fn``'s own scope: params + stores (nested defs'
    internals excluded — their stores bind in the nested scope)."""
    out: Set[str] = set()
    args = fn.args
    for a in (args.posonlyargs + args.args + args.kwonlyargs
              + ([args.vararg] if args.vararg else [])
              + ([args.kwarg] if args.kwarg else [])):
        out.add(a.arg)

    def walk(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda, ast.ClassDef)):
                if not isinstance(child, ast.Lambda):
                    out.add(child.name)
                continue
            if isinstance(child, ast.Name) \
                    and isinstance(child.ctx, (ast.Store, ast.Del)):
                out.add(child.id)
            walk(child)

    walk(fn)
    return out


def _loads(fn: ast.AST) -> Set[str]:
    """Every Name load in ``fn``'s whole subtree (nested defs included:
    a name free in a nested def propagates outward)."""
    return {n.id for n in ast.walk(fn)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}


def _jit_call_of(call: ast.Call) -> bool:
    d = dotted(call.func) or ""
    return d in ("jax.jit", "jit", "pjit", "jax.pjit")


def _check_static_bits(mod: ParsedModule) -> List[Finding]:
    findings: List[Finding] = []
    quals = qualname_index(mod.tree)

    def flag(node: ast.AST, scope: str, message: str) -> None:
        findings.append(Finding(
            rule="STAT401", file=mod.relpath, line=node.lineno, scope=scope,
            message=message, hint=RULES["STAT401"].hint,
            snippet=mod.snippet(node)))

    def param_names(fn: ast.AST) -> List[str]:
        a = fn.args
        return [p.arg for p in a.posonlyargs + a.args]

    def check_static_marks(call: ast.Call, fn: Optional[ast.AST],
                           scope: str) -> None:
        for kw in call.keywords:
            if kw.arg == "static_argnums":
                if fn is None:
                    continue
                names = param_names(fn)
                nums = []
                v = kw.value
                elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) \
                    else [v]
                for e in elts:
                    if isinstance(e, ast.Constant) \
                            and isinstance(e.value, int):
                        nums.append(e.value)
                for i in nums:
                    if i < len(names) and registry.is_bit_name(names[i]):
                        flag(call, scope,
                             f"static_argnums marks bit parameter "
                             f"{names[i]!r} static — every distinct "
                             f"width recompiles")
            elif kw.arg == "static_argnames":
                v = kw.value
                elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) \
                    else [v]
                for e in elts:
                    if isinstance(e, ast.Constant) \
                            and isinstance(e.value, str) \
                            and registry.is_bit_name(e.value):
                        flag(call, scope,
                             f"static_argnames marks bit parameter "
                             f"{e.value!r} static — every distinct "
                             f"width recompiles")

    def check_outer(outer: ast.AST, scope: str) -> None:
        locals_ = _local_bindings(outer)
        nested: Dict[str, ast.AST] = {}
        for child in ast.walk(outer):
            if child is not outer and isinstance(
                    child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                nested[child.name] = child
        for call in ast.walk(outer):
            if not (isinstance(call, ast.Call) and _jit_call_of(call)):
                continue
            fn = None
            if call.args and isinstance(call.args[0], ast.Name):
                fn = nested.get(call.args[0].id)
            check_static_marks(call, fn, scope)
            if fn is None:
                continue
            free = _loads(fn) - _local_bindings(fn)
            captured = free & locals_
            for name in sorted(captured):
                if registry.is_bit_name(name):
                    flag(call, scope,
                         f"jitted closure {fn.name!r} captures "
                         f"bit-named local {name!r} from its enclosing "
                         f"scope — the width is baked in at trace time")

    for node, qual in quals.items():
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            check_outer(node, qual)
            # decorator form: @partial(jax.jit, static_argnames=...)
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call):
                    d = dotted(dec.func) or ""
                    if _jit_call_of(dec):
                        check_static_marks(dec, node, qual)
                    elif d in ("functools.partial", "partial") \
                            and dec.args and (dotted(dec.args[0]) or "") \
                            in ("jax.jit", "jit"):
                        check_static_marks(dec, node, qual)
    return findings


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

CHECKERS: List[Callable[[ParsedModule], List[Finding]]] = [
    _check_hot_scopes, _check_set_order, _check_rng, _check_static_bits,
]


def lint_modules(modules: Sequence[ParsedModule]) -> List[Finding]:
    findings: List[Finding] = []
    for mod in modules:
        if any(mod.relpath.startswith(p) for p in EXCLUDE_PREFIXES):
            continue
        for check in CHECKERS:
            findings.extend(check(mod))
    findings.sort(key=lambda f: (f.file, f.line, f.rule))
    return findings


def run_lint(root: Optional[str] = None) -> List[Finding]:
    """Lint the whole ``src/repro`` tree; returns raw findings (the CLI
    applies the baseline)."""
    return lint_modules(iter_modules(root or repo_root(), LINT_SUBDIRS))
