"""Mesh context + logical-axis resolution — the one sharding vocabulary.

Models speak LOGICAL axes ("dp" data-parallel, "tp" tensor-parallel,
"dp+tp" both combined, None replicated); this module maps them onto
whatever mesh is active:

  2-axis mesh ("data", "model")         dp -> "data",           tp -> "model"
  3-axis mesh ("pod", "data", "model")  dp -> ("pod", "data"),  tp -> "model"

``constrain``/``constrain_heads`` are no-ops when no mesh is active, so
every model file can sprinkle sharding annotations and still run unchanged
on CPU tests and single-host launches.

The active mesh is resolved from (in order):
  1. the explicit :func:`use_mesh` context stack (nestable, thread-local);
  2. jax's own ``with mesh:`` context manager (what launch/dryrun uses).

Divisibility fallback: a dimension whose size does not divide the product
of its mapped mesh axes is REPLICATED (per dimension, not per spec) —
oddball shapes degrade to replication instead of crashing the partitioner.
"""
from __future__ import annotations

import contextlib
import math
import threading
import warnings
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# Logical -> candidate mesh axes, in the order they combine.
_LOGICAL_AXES = {
    "dp": ("pod", "data"),
    "tp": ("model",),
}


# ---------------------------------------------------------------------------
# Mesh context stack
# ---------------------------------------------------------------------------

_local = threading.local()


def _stack() -> list:
    if not hasattr(_local, "meshes"):
        _local.meshes = []
    return _local.meshes


@contextlib.contextmanager
def use_mesh(mesh):
    """Push ``mesh`` as the active mesh for the enclosed block (nestable)."""
    _stack().append(mesh)
    try:
        yield mesh
    finally:
        _stack().pop()


def _jax_context_mesh():
    """The mesh of an enclosing ``with mesh:`` block, if any."""
    try:
        from jax.interpreters import pxla
        m = pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:       # noqa: BLE001 — internals moved; degrade to None
        pass
    return None


def active_mesh():
    """Innermost active mesh, or None (=> all dist ops are no-ops)."""
    stack = _stack()
    if stack:
        return stack[-1]
    return _jax_context_mesh()


@contextlib.contextmanager
def manual_mode():
    """Mark the enclosed trace as running INSIDE a ``shard_map`` body.

    ``with_sharding_constraint`` on a mesh axis is illegal under manual
    (per-device) execution — the axis is already consumed by the shard
    map — so :func:`constrain`/:func:`constrain_heads` become identity
    while this flag is up.  Engines wrap their shard-mapped program
    bodies in it (thread-local, trace-time: the flag is read while the
    body traces, never at run time)."""
    prev = getattr(_local, "manual", False)
    _local.manual = True
    try:
        yield
    finally:
        _local.manual = prev


def in_manual_mode() -> bool:
    return getattr(_local, "manual", False)


def dp_size(mesh=None) -> int:
    """Total data-parallel ways of the active (or given) mesh."""
    mesh = mesh if mesh is not None else active_mesh()
    if mesh is None:
        return 1
    return math.prod(mesh.shape[a] for a in _LOGICAL_AXES["dp"]
                     if a in mesh.shape)


def tp_size(mesh=None) -> int:
    """Tensor-parallel ways (size of the "model" axis), 1 without a mesh."""
    mesh = mesh if mesh is not None else active_mesh()
    if mesh is None:
        return 1
    return math.prod(mesh.shape[a] for a in _LOGICAL_AXES["tp"]
                     if a in mesh.shape)


# ---------------------------------------------------------------------------
# Logical -> mesh resolution
# ---------------------------------------------------------------------------

def mesh_axes_for(mesh, logical: Optional[str]) -> Tuple[str, ...]:
    """Mesh axes a logical name maps to on this mesh ("dp+tp" combines)."""
    if logical is None:
        return ()
    names = set(mesh.axis_names)
    out = []
    for part in logical.split("+"):
        try:
            candidates = _LOGICAL_AXES[part]
        except KeyError:
            raise ValueError(f"unknown logical axis {part!r}; "
                             f"known: {sorted(_LOGICAL_AXES)}") from None
        out.extend(a for a in candidates if a in names)
    return tuple(out)


# divisibility fallbacks already warned about (one-shot per distinct
# (logical axis, mesh axes, dim, shape) — a serving loop resolves the
# same specs every tick and must not spam)
_warned_fallbacks: set = set()


def logical_to_mesh(mesh, logical_axes: Sequence[Optional[str]],
                    shape: Sequence[int]) -> P:
    """Resolve per-dimension logical axes into a PartitionSpec.

    Per-dimension divisibility fallback: if the dim size does not divide
    the product of the mapped mesh-axis sizes, that dimension replicates
    — with a one-shot RuntimeWarning naming the axis and shape, so a
    half-sharded placement is visible instead of discovered via
    benchmarks.  A mesh axis is consumed at most once per spec (first
    dim wins).
    """
    assert len(logical_axes) == len(shape), (logical_axes, shape)
    used: set = set()
    entries = []
    for dim, logical in zip(shape, logical_axes):
        axes = tuple(a for a in mesh_axes_for(mesh, logical)
                     if a not in used)
        size = math.prod(mesh.shape[a] for a in axes) if axes else 0
        if not axes or size <= 1 or dim % size != 0:
            if axes and size > 1 and dim > 1:
                # a real sharding request fell back (absent/trivial axes
                # and singleton dims lose nothing — stay silent there)
                key = (logical, axes, int(dim), tuple(shape))
                if key not in _warned_fallbacks:
                    _warned_fallbacks.add(key)
                    warnings.warn(
                        f"logical axis {logical!r} -> mesh axes "
                        f"{axes} (size {size}) does not divide dim "
                        f"{dim} of shape {tuple(shape)}; replicating "
                        f"this dimension", RuntimeWarning, stacklevel=2)
            entries.append(None)
            continue
        used.update(axes)
        entries.append(axes[0] if len(axes) == 1 else axes)
    return P(*entries)


# ---------------------------------------------------------------------------
# Sharding constraints (no-ops without a mesh)
# ---------------------------------------------------------------------------

def shard_map_compat(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``shard_map`` across jax versions.

    The function moved out of ``jax.experimental`` and its replication-
    check kwarg was renamed ``check_rep`` -> ``check_vma`` along the way.
    """
    try:
        from jax import shard_map as sm
    except ImportError:                         # older jax
        from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check)
    except TypeError:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check)


def constrain(x, logical_axes: Sequence[Optional[str]]):
    """``with_sharding_constraint`` in logical axes; identity off-mesh
    and inside ``shard_map`` bodies (see :func:`manual_mode`)."""
    mesh = active_mesh()
    if mesh is None or in_manual_mode():
        return x
    spec = logical_to_mesh(mesh, logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_heads(x, head_dim: int, alt_dim: int, use_head: bool):
    """Shard dim 0 over dp and ONE of (head_dim | alt_dim) over tp.

    Attention uses this to keep q/k/v/cache consistently sharded: when the
    (KV-)head count divides tp, shard heads (Megatron); otherwise fall
    back to sharding the per-head feature dim (``alt_dim``).
    """
    axes: list = [None] * x.ndim
    axes[0] = "dp"
    axes[head_dim if use_head else alt_dim] = "tp"
    return constrain(x, tuple(axes))
