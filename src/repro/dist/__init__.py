"""Distributed substrate: logical-axis sharding over jax meshes.

``dist.constrain(x, ("dp", None, "tp"))`` is the whole model-side API —
logical axes resolve against whatever mesh is active (see
:mod:`repro.dist.api`) and every op is a no-op off-mesh, so the same model
code runs on a CPU test, a single host, or a multi-pod production mesh.
:mod:`repro.dist.sharding` holds the path-based parameter/optimizer/
batch/cache placement rules used by the launchers and the serving engine.
"""
from repro.dist import api, placement, sharding            # noqa: F401
from repro.dist.api import (active_mesh, constrain,        # noqa: F401
                            constrain_heads, dp_size, logical_to_mesh,
                            manual_mode, mesh_axes_for, shard_map_compat,
                            tp_size, use_mesh)
from repro.dist.placement import (PlacementPlan,           # noqa: F401
                                  plan_for_controller, plan_placement)
