"""Path-based sharding rules for every parameter/optimizer/batch/cache leaf.

The scheme is Megatron + FSDP expressed in logical axes (resolved by
:mod:`repro.dist.api`):

  * column-parallel linears (wq/wk/wv, wg/wu/wi, in_proj, router, head):
    output dim over "tp", input dim over "dp" (FSDP);
  * row-parallel linears (wo, wd, out_proj): input dim over "tp", output
    dim over "dp";
  * MoE expert stacks (..., E, d_in, d_out): experts over "tp" (expert
    parallelism) AND d_in over "dp" — sharded on both mesh axes;
  * embeddings/head: padded vocab over "tp", d_model over "dp";
  * Mamba2 conv kernels: channel dim over "tp"; scalar SSM params
    (A_log, D, dt_bias) and all norms replicate;
  * hybrid LoRA adapters: ``a`` FSDP-sharded on d_in, ``b`` on d_out/tp.

Every rule degrades to replication through the per-dimension divisibility
fallback in :func:`repro.dist.api.logical_to_mesh`, so one rule set covers
all ten configs (and their smoke variants) on any mesh.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import api
from repro.dist.api import logical_to_mesh      # noqa: F401  (re-export)

# Linear dicts whose INPUT dim is tensor-parallel (the reduction dim of
# the second GEMM in each pair — output resharded by one all-reduce).
_ROW_PARALLEL = ("wo", "wd", "out_proj")
# Leaf names that always replicate (norm scales, biases, SSM scalars).
_REPLICATED = frozenset(("scale", "bias", "b", "conv_b", "A_log", "D",
                         "dt_bias", "kpos", "step"))
_EXPERT_STACK = ("wg", "wu", "wd")
_LINEAR_LEAVES = ("w", "q", "q4", "s")


def _keys(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", p)) for p in path)


def _logical_spec(keys: Sequence[str], nd: int,
                  plan=None) -> Tuple[Optional[str], ...]:
    """Per-dimension logical axes for a parameter leaf at ``keys``.

    ``plan`` (a :class:`repro.dist.placement.PlacementPlan`) overrides
    the base Megatron/FSDP rule with replication for leaves whose priced
    entry the planner fully replicated — extra resident copies trade
    memory for amortized latency (LRMP-style).  Entries the plan left at
    one copy (or partially replicated — pspecs cannot express partial
    replica counts) keep the base rule.
    """
    if nd == 0:
        return ()
    if plan is not None and plan.replicates(keys):
        return (None,) * nd
    name = keys[-1]
    parent = keys[-2] if len(keys) >= 2 else ""
    if "lora" in keys and name in ("a", "b"):
        spec = [None] * nd
        spec[-2 if name == "a" else -1] = "dp" if name == "a" else "tp"
        return tuple(spec)
    if name in _REPLICATED or nd == 1:
        return (None,) * nd
    if name == "emb":
        return (None,) * (nd - 2) + ("tp", "dp")
    if "experts" in keys and (name in _EXPERT_STACK
                              or parent in _EXPERT_STACK):
        # (..., E, d_in, d_out) train form, or {"q", "s"} serve form whose
        # middle dim is 1 for scales (falls back to replication there).
        if nd >= 3:
            return (None,) * (nd - 3) + ("tp", "dp", None)
        return (None,) * nd
    if name == "conv_w":
        return (None,) * (nd - 1) + ("tp",)
    if name in _LINEAR_LEAVES and nd >= 2:
        if parent in _ROW_PARALLEL:
            return (None,) * (nd - 2) + ("tp", "dp")
        return (None,) * (nd - 2) + ("dp", "tp")
    return (None,) * nd


def param_pspec(path, leaf, plan=None) -> Tuple[Optional[str], ...]:
    """Logical per-dimension spec for one parameter leaf (len == ndim)."""
    return _logical_spec(_keys(path), leaf.ndim, plan=plan)


def param_shardings(params, mesh, plan=None):
    """NamedSharding pytree mirroring ``params`` (train or serve form).
    ``plan`` applies a placement planner's replication overrides."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, logical_to_mesh(mesh, param_pspec(path, leaf, plan),
                                  leaf.shape)),
        params)


# ---------------------------------------------------------------------------
# Optimizer state — moments mirror parameter sharding (FSDP shards Adam
# state too); the int8 / factored codecs reuse the base parameter's spec.
# ---------------------------------------------------------------------------

_CODEC_SUFFIXES = frozenset(("q", "s", "vr", "vc"))


def opt_pspec(path, leaf) -> Tuple[Optional[str], ...]:
    keys = _keys(path)
    if keys[0] == "step":
        return (None,) * leaf.ndim
    base = keys[1:]                       # drop the leading "m" / "v"
    name = base[-1] if base else ""
    if name in _CODEC_SUFFIXES:
        pkeys = base[:-1]
        if name in ("q", "s"):            # int8 codec: q = param shape,
            return _logical_spec(pkeys, leaf.ndim)   # s last dim 1 -> repl.
        full = _logical_spec(pkeys, leaf.ndim + 1)   # factored v drops a dim
        return full[:-1] if name == "vr" else full[:-2] + full[-1:]
    return _logical_spec(base, leaf.ndim)


def opt_shardings(opt, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, logical_to_mesh(mesh, opt_pspec(path, leaf), leaf.shape)),
        opt)


# ---------------------------------------------------------------------------
# Batches / activations
# ---------------------------------------------------------------------------

def batch_pspec(leaf) -> Tuple[Optional[str], ...]:
    """Inputs shard their leading (batch) dim over dp, rest replicated."""
    if leaf.ndim == 0:
        return ()
    return ("dp",) + (None,) * (leaf.ndim - 1)


def batch_shardings(batch, mesh):
    return jax.tree.map(
        lambda leaf: NamedSharding(
            mesh, logical_to_mesh(mesh, batch_pspec(leaf), leaf.shape)),
        batch)


def shard_batch(batch, mesh=None):
    """device_put a host batch onto the active mesh (identity off-mesh)."""
    mesh = mesh if mesh is not None else api.active_mesh()
    if mesh is None:
        return batch
    return jax.device_put(batch, batch_shardings(batch, mesh))


def bits_pspec(leaf) -> Tuple[Optional[str], ...]:
    """Per-layer bit tables: (L,) replicates; a per-request (B, L) bit
    matrix shards its batch dim over dp so each dp shard carries exactly
    the bit rows of the activation rows it owns."""
    if leaf.ndim == 2:
        return ("dp", None)
    return (None,) * leaf.ndim


def budgets_pspec(leaf) -> Tuple[Optional[str], ...]:
    """Per-request (B,) budget vectors — the serving runtime's batched
    admission state — shard over dp like the rows they gate, so the
    controller's select/gather lands its (B, L) bit matrix already
    dp-placed instead of resharding a replicated result."""
    if leaf.ndim >= 1:
        return ("dp",) + (None,) * (leaf.ndim - 1)
    return ()


def shard_budgets(budgets, mesh=None):
    """device_put a per-request budget vector onto the active mesh
    (identity off-mesh; replication fallback for non-dividing B)."""
    mesh = mesh if mesh is not None else api.active_mesh()
    if mesh is None:
        return budgets
    return jax.device_put(budgets, NamedSharding(
        mesh, logical_to_mesh(mesh, budgets_pspec(budgets), budgets.shape)))


def shard_bits(bits, mesh=None):
    """device_put a resolved bit table onto the active mesh (identity
    off-mesh); replication fallback covers non-dividing batch sizes."""
    mesh = mesh if mesh is not None else api.active_mesh()
    if mesh is None:
        return bits
    return jax.device_put(bits, NamedSharding(
        mesh, logical_to_mesh(mesh, bits_pspec(bits), bits.shape)))


# ---------------------------------------------------------------------------
# KV / SSM caches
# ---------------------------------------------------------------------------

def _axis_entry(axes: Tuple[str, ...]):
    return axes[0] if len(axes) == 1 else tuple(axes)


def _kv_cache_spec(mesh, shape) -> P:
    """(L, B, S, KV, hd) cache spec.

    dp goes on the batch dim when it divides; a B=1 long-context decode
    shards the SEQUENCE over dp instead (the ring buffer is per-slot, so
    sequence sharding is legal).  tp goes on KV heads when they divide,
    else on the per-head feature dim (small-GQA models).
    """
    L, B, S, KV, hd = shape
    entries: list = [None] * 5
    dp_axes = api.mesh_axes_for(mesh, "dp")
    dp_sz = api.dp_size(mesh)
    if dp_sz > 1:
        if B % dp_sz == 0:
            entries[1] = _axis_entry(dp_axes)
        elif S % dp_sz == 0:
            entries[2] = _axis_entry(dp_axes)
    tp_axes = api.mesh_axes_for(mesh, "tp")
    tp_sz = api.tp_size(mesh)
    if tp_sz > 1:
        if KV % tp_sz == 0:
            entries[3] = _axis_entry(tp_axes)
        elif hd % tp_sz == 0:
            entries[4] = _axis_entry(tp_axes)
    return P(*entries)


def _cache_leaf_spec(mesh, keys: Tuple[str, ...], leaf) -> P:
    name = keys[-1]
    shape = leaf.shape
    if name in ("k", "v") and leaf.ndim == 5:
        return _kv_cache_spec(mesh, shape)
    if name == "kpos" and leaf.ndim == 3:           # (L, B, Sc) per-row
        L, B, Sc = shape                            # positions follow the
        dp_sz = api.dp_size(mesh)                   # k/v batch placement
        if dp_sz > 1 and B % dp_sz == 0:
            return P(None, _axis_entry(api.mesh_axes_for(mesh, "dp")), None)
        return P(None, None, None)
    if name in ("ks", "vs") and leaf.ndim == 4:     # int8 cache scales:
        full = _kv_cache_spec(mesh, shape + (1,))   # (L, B, S, KV) = k/v
        return P(*tuple(full)[:4])                  # minus the head dim
    if name == "ssm" and leaf.ndim >= 3:            # (L, B, H, P, N)
        return logical_to_mesh(
            mesh, (None, "dp", "tp") + (None,) * (leaf.ndim - 3), shape)
    if name == "conv" and leaf.ndim >= 2:           # (L, B, K-1, C)
        spec = [None] * leaf.ndim
        spec[1] = "dp"
        spec[-1] = "tp"
        return logical_to_mesh(mesh, tuple(spec), shape)
    return P(*(None,) * leaf.ndim)                  # kpos etc.


def cache_shardings(cache, mesh, plan=None):
    """Cache shardings; ``plan`` is accepted for call-site symmetry with
    :func:`param_shardings` (a placement plan only moves WEIGHTS — the
    cache's dp-on-batch placement is already what row-parallel scale-out
    execution needs, so the base rules stand unchanged)."""
    del plan
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, _cache_leaf_spec(mesh, _keys(path), leaf)),
        cache)
