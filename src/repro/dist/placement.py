"""Cost-driven layer replication + data-parallel scale-out planning.

LRMP (arXiv:2312.03146) replicates the bottleneck layers of a
mixed-precision in-memory pipeline across crossbar tiles so the pipeline
clock is set by the *amortized* bottleneck, not the raw one.  Our serve
analogue: the per-layer AP cost breakdown every admission already pays
for (``apsim.metrics.price_bit_vector`` — per-slot cycles/energy, plus a
trailing logits-head entry) becomes a *placement* signal.

:func:`plan_placement` turns one representative priced bit vector plus a
device budget into a :class:`PlacementPlan`:

* **dp** — request rows shard across the mesh's data axis (the serving
  batch is embarrassingly row-parallel);
* **replicas** — per priced entry (bit slots, + the head when priced),
  extra resident copies for the entries whose latency/EDP share
  dominates, assigned greedily to the current bottleneck while the
  memory budget funds them (``r = n_devices`` = one full copy per
  device, ``r = 1`` = a single logical copy left to the base
  Megatron/FSDP rules of ``dist/sharding.py``).

The plan is consumed three ways, mirroring how it was produced:

* **pspec rules** — ``dist.sharding._logical_spec(keys, nd, plan=...)``
  overrides the base rule with replication for fully-replicated entries
  (``param_shardings(..., plan=...)`` threads it), so placement flows
  through the exact builders everything else uses.  Partial replica
  counts (1 < r < n_devices) are a *resource* statement — GSPMD pspecs
  can only express fully-replicated vs base-sharded, so they keep the
  base rule;
* **execution** — a fully-replicated plan unlocks ``shard_map`` row
  sharding in the engines (every device holds every weight, so manual
  per-device compute is exact);
* **pricing** — :meth:`PlacementPlan.price` amortizes each entry's
  latency over its replicas (energy is unchanged: the same work runs,
  spread wider), which is what ``CostRecord``/``aggregate()`` report and
  what a ``FluidController`` co-decides precision against
  (``BudgetController.adopt_plan``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

from repro.apsim import metrics as apm
from repro.apsim.workloads import Layer

# plan entries that resolve to these leaf names are the LM logits head
# family (the trailing priced entry); stacked transformer blocks live
# under "layers" (one leading L dim — per-layer pspec differentiation is
# impossible on a stacked leaf, so the stack replicates only when EVERY
# slot entry does)
_HEAD_LEAVES = frozenset(("head", "emb"))
_STACK_KEY = "layers"


@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    """One placement decision: dp ways + per-entry replica counts.

    ``replicas``/``shares`` align with the priced cost entries: one per
    bit slot, plus one trailing entry when the logits head was priced
    (``has_head``).  ``names`` (CNN) maps entries to the per-layer
    qparam dict keys; empty for LM (stacked params).
    """
    n_devices: int
    dp: int
    replicas: Tuple[int, ...]
    shares: Tuple[float, ...]
    axis: str = "edp"
    has_head: bool = False
    names: Tuple[str, ...] = ()

    def __post_init__(self):
        if self.n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {self.n_devices}")
        if not all(1 <= r <= self.n_devices for r in self.replicas):
            raise ValueError(f"replica counts {self.replicas} must lie in "
                             f"[1, {self.n_devices}]")
        if self.names and len(self.names) != len(self.replicas) - int(
                self.has_head):
            raise ValueError(
                f"{len(self.names)} entry names for "
                f"{len(self.replicas)} replica entries (has_head="
                f"{self.has_head})")

    # ---- introspection ----------------------------------------------

    @property
    def fully_replicated(self) -> bool:
        """Every priced entry holds one copy per device — the scale-out
        serving mode (unlocks exact ``shard_map`` row execution)."""
        return all(r == self.n_devices for r in self.replicas)

    @property
    def mean_replicas(self) -> float:
        return (sum(self.replicas) / len(self.replicas)
                if self.replicas else 1.0)

    @property
    def replicated_entries(self) -> Tuple[int, ...]:
        """Indices of entries with more than one resident copy."""
        return tuple(i for i, r in enumerate(self.replicas) if r > 1)

    def summary(self) -> Dict[str, object]:
        return {
            "n_devices": self.n_devices,
            "dp": self.dp,
            "axis": self.axis,
            "mean_replicas": round(self.mean_replicas, 4),
            "fully_replicated": self.fully_replicated,
            "replicated_entries": list(self.replicated_entries),
        }

    # ---- honest pricing ---------------------------------------------

    def price(self, cost: apm.BitVectorCost) -> apm.BitVectorCost:
        """Amortize a priced bit vector over this plan's replicas.

        Per entry, latency (cycles) divides by the replica count — r
        resident copies of a layer serve r concurrent token streams, so
        the per-stream occupancy of that stage is cycles/r at full
        replica occupancy (LRMP's pipeline-clock argument).  Energy is
        UNCHANGED: every stream's work still runs somewhere.  Accepts
        costs priced with or without the trailing head entry (slots
        align from the front either way)."""
        n = len(cost.per_layer_cycles)
        if n > len(self.replicas):
            raise ValueError(
                f"cost has {n} entries but the plan covers only "
                f"{len(self.replicas)}")
        cyc = tuple(c / self.replicas[i]
                    for i, c in enumerate(cost.per_layer_cycles))
        return apm.BitVectorCost(cyc, cost.per_layer_energy_j, cost.freq_hz)

    # ---- pspec integration ------------------------------------------

    def _name_index(self) -> Dict[str, int]:
        return {n: i for i, n in enumerate(self.names)}

    def replicates(self, keys: Sequence[str]) -> bool:
        """Whether the parameter leaf at ``keys`` should REPLICATE under
        this plan (override the base Megatron/FSDP rule with all-None).

        CNN leaves match by their per-layer dict key (``names``); LM
        logits-head leaves (emb/head) match the trailing head entry; the
        stacked ``layers`` subtree replicates only when every slot entry
        is fully replicated (one leading L dim — no per-layer specs)."""
        keys = tuple(keys)
        if not keys:
            return False
        if self.names:
            idx = self._name_index().get(keys[0])
            if idx is not None:
                return self.replicas[idx] == self.n_devices
            return False
        if keys[0] in _HEAD_LEAVES or keys[-1] in _HEAD_LEAVES:
            return self.has_head and self.replicas[-1] == self.n_devices
        if keys[0] == _STACK_KEY:
            slots = self.replicas[:-1] if self.has_head else self.replicas
            return bool(slots) and all(r == self.n_devices for r in slots)
        return False


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------

def _entry_weights(gemms: Sequence[Sequence],
                   head: Optional[Tuple[int, int]]) -> Tuple[float, ...]:
    """Weight elements resident per priced entry (replication's memory
    currency): sum of K*N over a slot's GEMV pairs, or i*j for a full
    conv/fc Layer."""
    out = []
    for dims in gemms:
        w = 0.0
        for item in dims:
            if isinstance(item, Layer):
                i, j, _ = item.gemm_dims()
                w += float(i) * float(j)
            else:
                K, N = item
                w += float(K) * float(N)
        out.append(w)
    if head is not None:
        out.append(float(head[0]) * float(head[1]))
    return tuple(out)


def _entry_shares(cost: apm.BitVectorCost, axis: str) -> Tuple[float, ...]:
    """Per-entry share of the total budget-axis cost (the dominance
    signal a replica chases)."""
    cyc = cost.per_layer_cycles
    en = cost.per_layer_energy_j
    if axis == "latency":
        raw = cyc
    elif axis == "energy":
        raw = en
    elif axis == "edp":
        raw = tuple(c * e for c, e in zip(cyc, en))
    else:
        raise ValueError(f"unknown budget axis {axis!r}")
    tot = sum(raw)
    if tot <= 0.0:
        return tuple(0.0 for _ in raw)
    return tuple(v / tot for v in raw)


def plan_placement(gemms: Sequence[Sequence],
                   wvec: Sequence[int], avec: Sequence[int], *,
                   n_devices: int,
                   head: Optional[Tuple[int, int]] = None,
                   axis: str = "edp",
                   memory_budget: Optional[float] = None,
                   names: Sequence[str] = ()) -> PlacementPlan:
    """Plan dp + replication from one representative priced bit vector.

    ``gemms``/``head`` are exactly ``price_bit_vector``'s descriptors
    (``lm.layer_gemm_dims`` / ``apm.network_gemms``); ``wvec``/``avec``
    the representative per-slot bits (a controller's most-accurate
    config — :func:`plan_for_controller`).  ``memory_budget`` is total
    weight capacity in units of one full model copy (default:
    ``n_devices`` — every device can hold a full copy, so the plan fully
    replicates); tighter budgets (e.g. 1.5) replicate only the dominant
    entries.  Deterministic: greedy bottleneck chase, ties break on the
    lowest entry index.
    """
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    cost = apm.price_bit_vector(gemms, list(wvec), list(avec), head=head)
    shares = _entry_shares(cost, axis)
    weights = _entry_weights(gemms, head)
    lat = cost.per_layer_cycles
    n = len(lat)
    replicas = [1] * n
    total_w = sum(weights)
    budget = float(n_devices if memory_budget is None else memory_budget)
    if budget < 1.0:
        raise ValueError(f"memory_budget must be >= 1 model copy, "
                         f"got {budget}")
    remaining = (budget - 1.0) * total_w
    tol = 1e-9 * max(total_w, 1.0)
    while n_devices > 1:
        # chase the current bottleneck: highest amortized latency first;
        # fall through to the next entry when the top one is maxed out
        # or unfundable (replicating a cheaper stage still helps)
        order = sorted(range(n), key=lambda i: (-lat[i] / replicas[i], i))
        bumped = False
        for i in order:
            if replicas[i] >= n_devices:
                continue
            if weights[i] <= remaining + tol:
                replicas[i] += 1
                remaining -= weights[i]
                bumped = True
                break
        if not bumped:
            break
    return PlacementPlan(
        n_devices=int(n_devices), dp=int(n_devices),
        replicas=tuple(replicas), shares=shares, axis=axis,
        has_head=head is not None, names=tuple(names))


def plan_for_controller(controller, gemms: Sequence[Sequence], *,
                        n_devices: int,
                        head: Optional[Tuple[int, int]] = None,
                        axis: Optional[str] = None,
                        memory_budget: Optional[float] = None,
                        names: Sequence[str] = ()) -> PlacementPlan:
    """Plan from a controller's bit families: the representative vector
    is the most-accurate registered configuration (the plan must stay
    honest for the heaviest bits the controller can resolve; cheaper
    configs only flatten the same dominance profile).  ``axis`` defaults
    to the controller's own budget axis."""
    import numpy as np

    wtab, atab = controller.stacked_tables()
    wv = np.asarray(wtab)[-1].tolist()
    av = np.asarray(atab)[-1].tolist()
    return plan_placement(
        gemms, wv, av, n_devices=n_devices, head=head,
        axis=axis if axis is not None else controller.budget_axis,
        memory_budget=memory_budget, names=names)


def mesh_device_count(mesh) -> int:
    """Total device count of a mesh (duck-typed: ``.shape`` dict)."""
    return int(math.prod(mesh.shape.values())) if mesh is not None else 1
