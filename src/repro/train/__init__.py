from repro.train.loop import TrainConfig, make_train_step  # noqa: F401
from repro.train.checkpoint import save_checkpoint, restore_checkpoint  # noqa: F401
from repro.train.watchdog import StragglerWatchdog  # noqa: F401
