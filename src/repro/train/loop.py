"""The pjit train step: microbatched grad accumulation + AdamW + metrics.

``make_train_step(cfg, model_cfg)`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with donated params/opt_state.  Gradient accumulation is a
``lax.scan`` over ``n_accum`` microbatches (activation memory / n_accum);
the accumulator dtype follows ``ModelConfig.accum_dtype`` (bf16 for the
1T-param config).  XLA overlaps the FSDP reduce-scatter/all-gather with
the backward automatically; §Perf iterates on the schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.optim.adamw import AdamWConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    n_accum: int = 1                    # microbatches per step
    wbits: Tuple[int, ...] = (8,)       # per-layer precision policy tables
    abits: Tuple[int, ...] = (8,)


def make_train_step(tcfg: TrainConfig, cfg, param_shardings=None):
    nbits = lm.n_bit_slots(cfg)
    wvec = jnp.asarray([tcfg.wbits[min(i, len(tcfg.wbits) - 1)]
                        for i in range(nbits)], jnp.int32)
    avec = jnp.asarray([tcfg.abits[min(i, len(tcfg.abits) - 1)]
                        for i in range(nbits)], jnp.int32)
    acc_dtype = jnp.dtype(cfg.accum_dtype)

    def pin(tree):
        """Pin gradient/accumulator leaves to the parameter sharding —
        the scan carry otherwise REPLICATES (a 1T-param model's grad
        accumulator replicated = 2 TB/device of temp; §Perf kimi iter 2)."""
        if param_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            param_shardings)

    def loss_fn(params, microbatch):
        return lm.train_loss(params, microbatch, cfg, wvec, avec)

    def train_step(params, opt_state, batch):
        n = tcfg.n_accum

        def split(x):
            return x.reshape(n, x.shape[0] // n, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def accum(carry, mb):
            g_acc, l_acc = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            g_acc = pin(jax.tree.map(
                lambda a, g: a + g.astype(acc_dtype) / n, g_acc,
                pin(grads)))
            return (g_acc, l_acc + loss / n), metrics

        g0 = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype),
                              params))
        (grads, loss), metrics = jax.lax.scan(
            accum, (g0, jnp.zeros((), jnp.float32)), micro)
        grads = pin(grads)

        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, opt_state, tcfg.optimizer)
        out = {"loss": loss, **{k: jnp.mean(v) for k, v in metrics.items()},
               **opt_metrics}
        return new_params, new_opt, out

    return train_step, (wvec, avec)
