"""Fault-tolerant checkpointing: atomic save, resharding restore (elastic).

Format: one ``.npz`` per host (this container: one) + a JSON manifest with
step, mesh topology, and the flattened key list.  Writes go to a temp dir
renamed into place (atomic on POSIX), so a crash mid-save never corrupts
the latest checkpoint; ``restore_checkpoint`` takes *target shardings* and
``device_put``s each leaf — a checkpoint written on mesh A restores onto
mesh B (elastic scaling: grow/shrink the pod between runs).

At 1000+ nodes the same layout shards the npz per host
(``process_index`` key in the manifest); the gather/scatter points are
marked below.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Atomic save of a pytree (params/opt state/data state)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    # gather point: multi-host would save only addressable shards here
    arrays = {}
    dtypes = {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        dtypes[k] = str(a.dtype)
        if a.dtype.kind not in "biufc":          # bf16 etc: store raw bits
            a = a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
        arrays[k] = a
    manifest = {
        "step": int(step),
        "keys": sorted(arrays.keys()),
        "dtypes": dtypes,
        "process_index": jax.process_index(),
        "process_count": jax.process_count(),
        "extra": extra or {},
    }
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _update_latest(ckpt_dir, step)
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def _update_latest(ckpt_dir: str, step: int) -> None:
    tmp = os.path.join(ckpt_dir, ".latest_tmp")
    with open(tmp, "w") as f:
        f.write(str(step))
    os.replace(tmp, os.path.join(ckpt_dir, "LATEST"))


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip())


def restore_checkpoint(ckpt_dir: str, target: Any,
                       shardings: Optional[Any] = None,
                       step: Optional[int] = None):
    """Restore into the structure of ``target``; reshard onto ``shardings``
    (a matching pytree of NamedSharding / None).  Returns (tree, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    dtypes = manifest.get("dtypes", {})
    flat_t = jax.tree_util.tree_flatten_with_path(target)
    flat_s = (jax.tree_util.tree_flatten(shardings)[0]
              if shardings is not None else [None] * len(flat_t[0]))
    leaves = []
    for (path, leaf), shd in zip(flat_t[0], flat_s):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(data[key])
        saved_dt = dtypes.get(key)
        if saved_dt and arr.dtype.kind in "u" and saved_dt not in (
                str(arr.dtype),):
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, saved_dt,
                                            saved_dt)))
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        # scatter point: reshard onto the (possibly different) target mesh
        leaves.append(jax.device_put(arr, shd) if shd is not None
                      else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(flat_t[1], leaves), step
