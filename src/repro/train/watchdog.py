"""Straggler mitigation: per-step timing watchdog + slow-host hook.

At multi-pod scale a single slow host gates every collective.  The
watchdog keeps a running mean/variance of step wall-times, flags z-score
outliers, and calls a pluggable ``on_straggler`` hook (production: report
the host for exclusion + trigger an elastic restart from the last
checkpoint — both substrates exist in this repo; locally: log).  The data
pipeline is stateless (step -> batch is pure), so re-issuing a straggler's
work after exclusion is deterministic.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional


class StragglerWatchdog:
    def __init__(self, z_threshold: float = 3.0, warmup: int = 5,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.z = z_threshold
        self.warmup = warmup
        self.on_straggler = on_straggler
        self.n = 0
        self.mean = 0.0
        self.m2 = 0.0
        self.events: List[dict] = []
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        assert self._t0 is not None, "start() not called"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self.n += 1
        delta = dt - self.mean
        self.mean += delta / self.n
        self.m2 += delta * (dt - self.mean)
        if self.n > self.warmup:
            std = (self.m2 / (self.n - 1)) ** 0.5
            if std > 0 and (dt - self.mean) / std > self.z:
                self.events.append({"step": step, "seconds": dt,
                                    "mean": self.mean, "std": std})
                if self.on_straggler:
                    self.on_straggler(step, dt)
        return dt
