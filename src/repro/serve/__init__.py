from repro.serve.accounting import (CostRecord, ImageStats,  # noqa: F401
                                    RequestStats, RuntimeStats, aggregate,
                                    predict_table)
from repro.serve.cnn import CNNServeEngine  # noqa: F401
from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.prefix_cache import (PrefixCache, PrefixEntry,  # noqa: F401
                                      PrefixHit)
from repro.serve.runtime import ServeRuntime, SlotTable  # noqa: F401
from repro.serve.traffic import (Trace, TraceReplayer,  # noqa: F401
                                 TraceRequest, summarize, synth_trace)
