from repro.serve.cnn import CNNServeEngine  # noqa: F401
from repro.serve.engine import ServeEngine  # noqa: F401
