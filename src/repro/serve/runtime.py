"""Workload-agnostic serving runtime (DESIGN.md §8).

Everything the LM and CNN engines used to duplicate lives here once:

  * the request queue + admission scheduler — EDP-aware (cheapest
    modeled EDP admits first, maximizing requests served inside a tight
    SLO window) with FIFO anti-starvation aging, deterministic;
  * the closed control loop — when the controller is a
    :class:`repro.core.policy.FluidController`, every admission's
    effective budget comes from the *remaining* SLO-window budget and
    its priced AP cost is charged back (paper §V.B's dynamic switching
    as a live loop; selection stays the pure-data gather, zero-retrace);
  * slot/batch lifecycle state (:class:`SlotTable` for slot-pool
    workloads, :meth:`ServeRuntime.plan_admissions` for batched ones);
  * trace-counting stats, the per-request cost records, and the cached
    AP pricer (``serve/accounting.py``);
  * the compute context: active mesh + the controller's static bit
    family set applied around every compiled call.

:class:`repro.serve.engine.ServeEngine` (prefill/decode) and
:class:`repro.serve.cnn.CNNServeEngine` (batched forward) are thin
workload adapters over this base.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro import dist
from repro.apsim import metrics as apm
from repro.core.policy import BudgetController, FluidController
from repro.kernels import ops as kops
from repro.serve.accounting import (BitVectorPricer, CostRecord,
                                    RuntimeStats, axis_cost)

# "no budget": fits every configuration on any axis (most accurate wins)
UNCONSTRAINED_BUDGET = 1e30


@dataclasses.dataclass
class _QueueEntry:
    """One queued admission: workload payload + scheduling metadata."""
    rid: int
    payload: object
    est_edp: float                      # modeled per-unit EDP (ordering)
    age: int = 0                        # scheduler ticks spent waiting


class SlotTable:
    """Host-side per-slot scheduler state for slot-pool workloads.

    The slot→request ownership array plus named numpy columns (decode
    position, sampling params, countdowns, ...).  The runtime owns the
    occupy/release lifecycle; workload adapters read and write columns.
    """

    def __init__(self, n_slots: int,
                 **columns: Tuple[type, float]) -> None:
        self.n_slots = n_slots
        self.rid = np.full((n_slots,), -1, np.int64)
        self._fill = {name: fill for name, (_, fill) in columns.items()}
        self.cols: Dict[str, np.ndarray] = {
            name: np.full((n_slots,), fill, dtype)
            for name, (dtype, fill) in columns.items()}

    def __getitem__(self, name: str) -> np.ndarray:
        return self.cols[name]

    @property
    def active(self) -> np.ndarray:
        return self.rid >= 0

    def occupy(self, slot: int, rid: int, **values) -> None:
        self.rid[slot] = rid
        for name, v in values.items():
            self.cols[name][slot] = v

    def release(self, slot: int) -> None:
        """Free a slot; columns reset to their fills (a freed row decodes
        masked garbage — its reset budget resolves the cheapest config)."""
        self.rid[slot] = -1
        for name, arr in self.cols.items():
            arr[slot] = self._fill[name]


class ServeRuntime:
    """Shared serving base: queue, scheduler, accounting, control loop."""

    def __init__(self, controller: BudgetController, n_layers: int, *,
                 gemms: Optional[Sequence[Sequence]] = None,
                 head: Optional[Tuple[int, int]] = None,
                 mesh=None, starvation_ticks: int = 8,
                 plan=None,
                 slot_desc: str = "bit-slot layers") -> None:
        if controller.n_layers != n_layers:
            raise ValueError(
                f"controller resolves {controller.n_layers} bit slots but "
                f"this workload has {n_layers} {slot_desc}")
        self.controller = controller
        # resolve the ambient mesh here so every adapter behaves the
        # same inside a `dist.use_mesh(...)` context (shard_budgets et
        # al. would otherwise fall back to it while the engine's guards
        # think there is no mesh — half-sharded inputs)
        self.mesh = mesh if mesh is not None else dist.active_mesh()
        self.n_layers = n_layers
        self.starvation_ticks = starvation_ticks
        # grouped per-row dispatch specializes one GEMM per *distinct*
        # weight bit-width the controller can emit (kernels/ops.py); the
        # family set is applied around every compiled call (trace-time)
        wtab, _ = controller.stacked_tables()
        self._families = tuple(sorted(
            {min(max(int(v), 1), 8) for v in np.asarray(wtab).ravel()}))
        self.pricer = (BitVectorPricer(gemms, head=head)
                       if gemms is not None else None)
        # placement plan (DESIGN.md §13): ``plan`` is a
        # dist.placement.PlacementPlan, or "auto" to plan one here from
        # the controller's bit families over this runtime's priced gemms
        # and the mesh's device count (None when either is missing — a
        # single device has nothing to replicate onto).  The plan
        # amortizes every priced cost (see :meth:`_planned`) and, for a
        # closed-loop controller, re-prices the prediction table so SLO
        # headroom co-decides precision against replication.
        if plan == "auto":
            nd = dist.placement.mesh_device_count(self.mesh)
            plan = (dist.placement.plan_for_controller(
                        controller, gemms, n_devices=nd, head=head)
                    if gemms is not None and nd > 1 else None)
        self.plan = plan
        # plan-amortized costs cached by base-object identity: the
        # pricer returns ONE shared BitVectorCost per distinct vector
        # (and keeps it alive in its own cache), so id() keys are stable
        self._plan_costs: Dict[int, apm.BitVectorCost] = {}
        if self.plan is not None and isinstance(controller, FluidController):
            if self.pricer is None:
                raise ValueError("a placement plan needs priced gemms "
                                 "(pass gemms=) to co-decide precision")
            controller.adopt_plan(self.plan, self.pricer)
        self.stats = RuntimeStats()
        self.requests: Dict[int, CostRecord] = {}
        self._next_rid = 0
        self._pending: List[_QueueEntry] = []
        self._config_costs: Optional[List[apm.BitVectorCost]] = None
        self._lats_np: Optional[np.ndarray] = None
        self._tabs_np: Optional[Tuple[np.ndarray, np.ndarray]] = None
        # scheduler clock + deferred (timestamped) arrivals: submit_at()
        # registers a submit thunk for a future tick; run() drains the
        # due thunks at the top of each tick (trace replay enqueues by
        # timestamp, never all-up-front)
        self._tick = 0
        self._arrivals: Dict[int, List[Callable[[], int]]] = {}

    # ------------------------------------------------------------------
    # Pricing / control loop
    # ------------------------------------------------------------------

    def _planned(self, cost: apm.BitVectorCost) -> apm.BitVectorCost:
        """Amortize a priced cost under the placement plan (identity
        pass-through without one).  Cached by base-object identity —
        callers rely on cost-object identity staying stable per distinct
        bit vector, and the pricer's own cache keeps the base objects
        (our id() keys) alive."""
        if self.plan is None:
            return cost
        hit = self._plan_costs.get(id(cost))
        if hit is None:
            hit = self.plan.price(cost)
            self._plan_costs[id(cost)] = hit
        return hit

    def price_bits(self, wv, av) -> apm.BitVectorCost:
        """AP cycles/energy of one resolved bit vector pair (cached;
        plan-amortized when a placement plan is installed)."""
        return self._planned(self.pricer.price(wv, av))

    def price_verify_bits(self, wv, av, u: int) -> apm.BitVectorCost:
        """Plan-amortized :meth:`BitVectorPricer.price_verify` — one
        u-token verify chunk at this bit vector."""
        return self._planned(self.pricer.price_verify(wv, av, u))

    def price_matrix_bits(self, wmat, amat) -> List[apm.BitVectorCost]:
        """Plan-amortized one-pass batch pricing (rows share cached
        cost objects, like :meth:`price_bits`)."""
        return [self._planned(c)
                for c in self.pricer.price_matrix(wmat, amat)]

    def _host_index(self, budget: float) -> int:
        """Host-side mirror of ``controller.select`` for one budget
        (prediction array cached as numpy — this runs per admission).
        Built from the controller's prediction DICT, never its device
        arrays: this helper must stay usable inside abstract traces
        (the retrace auditor calls ``_draft_bits`` under make_jaxpr,
        where any jnp constant becomes a tracer)."""
        if self._lats_np is None:
            self._lats_np = np.asarray(
                [self.controller.predicted_latency_s[k]
                 for k in self.controller.order()], np.float32)
        fits = np.nonzero(self._lats_np <= np.float32(budget))[0]
        return int(fits[-1]) if fits.size else 0

    def host_tables(self) -> Tuple[np.ndarray, np.ndarray]:
        """The controller's stacked (w, a) bit tables as cached host
        numpy — admission-path bookkeeping indexes these, never device
        arrays.  Expanded from the raw policy tuples (same
        last-entry-extends rule as ``PrecisionPolicy.vectors``) so the
        mirror never touches jnp — see :meth:`_host_index`."""
        if self._tabs_np is None:
            n = self.n_layers

            def expand(tab):
                return [int(tab[i]) if i < len(tab) else int(tab[-1])
                        for i in range(n)]

            ws, as_ = [], []
            for k in self.controller.order():
                p = self.controller.configs[k]
                ws.append(expand(p.weight_bits))
                as_.append(expand(p.act_bits))
            self._tabs_np = (np.asarray(ws, np.int32),
                             np.asarray(as_, np.int32))
        return self._tabs_np

    def host_bits(self, budget: float) -> Tuple[np.ndarray, np.ndarray]:
        """The (wbits, abits) vectors a budget resolves to, as host
        numpy (stacked tables cached) — the prefix-cache precision gate
        runs per admission and must not sync device arrays."""
        wtab, atab = self.host_tables()
        i = self._host_index(budget)
        return wtab[i], atab[i]

    def _config_cost(self, idx: int) -> apm.BitVectorCost:
        """Priced AP cost of the controller's idx-th stacked config."""
        if self._config_costs is None:
            wtab, atab = self.controller.stacked_tables()
            wtab, atab = np.asarray(wtab), np.asarray(atab)
            self._config_costs = [
                self._planned(self.pricer.price(wtab[i], atab[i]))
                for i in range(wtab.shape[0])]
        return self._config_costs[idx]

    def admission_budget(self, requested: Optional[float] = None,
                         pending: Optional[int] = None) -> float:
        """Effective budget for the next admission: closed-loop headroom
        under a FluidController, the request's own budget otherwise.
        ``pending`` (tick-windowed controllers) is how many admissions
        compete for the remaining window budget — defaults to this
        admission plus everything still queued."""
        if isinstance(self.controller, FluidController):
            if pending is None:
                pending = self.queued + 1
            return self.controller.admission_budget(requested,
                                                    pending=pending)
        return (float(requested) if requested is not None
                else UNCONSTRAINED_BUDGET)

    def charge(self, cost: apm.BitVectorCost, units: int = 1) -> None:
        """Feed one admission's priced cost back into the control loop."""
        if isinstance(self.controller, FluidController):
            self.controller.charge(
                axis_cost(cost, self.controller.budget_axis, units))

    def admit_record(self, record: CostRecord,
                     requested: Optional[float], units: int, *,
                     eff: Optional[float] = None,
                     charge_units: Optional[int] = None,
                     spec: Optional[Tuple] = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Resolve one admission end to end: effective budget → bit
        vectors (pure-data gather) → AP pricing → control-loop charge.
        ``units`` is the admission's *planned* AP unit count (LM: prompt
        + max new tokens; CNN: 1).  An engine that consulted the prefix
        cache passes the pre-computed ``eff`` (so the gate and the
        charge see the same headroom) and ``charge_units`` = the miss
        fraction — cache-served units are never charged against a
        FluidController's SLO window, and the avoided share is recorded
        on the controller for introspection.

        ``spec`` = (spec_k, draft_cost, verify_cost, planned_rounds,
        planned_tokens) installs a speculative-decoding plan on the
        record: the charge swaps the planned spec tokens' ap_cost for
        the planned rounds' draft + verify pricing
        (``CostRecord.axis_planned``); :meth:`finish_record` reconciles
        against the rounds that actually ran."""
        if eff is None:
            eff = self.admission_budget(requested)
        wv, av = self.controller.resolve(jnp.asarray(eff, jnp.float32))
        # price through the cached host mirrors (host_bits == resolve by
        # construction): the device vectors go straight to the compiled
        # programs without ever being pulled back for bookkeeping
        wv_h, av_h = self.host_bits(eff)
        cost = self.price_bits(wv_h, av_h)
        record.budget_s = eff
        record.ap_cost = cost
        record.mean_wbits = float(np.mean(np.asarray(wv_h, np.float64)))
        if self.plan is not None:
            record.plan_replicas = self.plan.mean_replicas
        record.planned_units = units if charge_units is None \
            else charge_units
        record.admitted_tick = self._tick
        if spec is not None:
            (record.spec_k, record.draft_cost, record.verify_cost,
             record.planned_spec_rounds, record.planned_spec_tokens) = spec
        if isinstance(self.controller, FluidController):
            self.controller.charge(
                record.axis_planned(self.controller.budget_axis))
        if (charge_units is not None and charge_units != units
                and isinstance(self.controller, FluidController)):
            axis = self.controller.budget_axis
            self.controller.record_saved(
                axis_cost(cost, axis, units)
                - axis_cost(cost, axis, charge_units))
        self.stats.admitted += 1
        return wv, av

    def plan_admissions(self, budgets: Sequence[Optional[float]],
                        units: int = 1) -> np.ndarray:
        """Batch admission planning (the batched-forward lifecycle):
        each admission is charged at its selected config's priced cost
        *before* the next one's headroom is computed, so a closed-loop
        controller adapts within the batch.  Open-loop budgets pass
        through unchanged.  Returns effective budgets — pure data for
        ``controller.resolve``."""
        fluid = isinstance(self.controller, FluidController)
        eff = np.empty((len(budgets),), np.float64)
        for i, b in enumerate(budgets):
            # the rest of this batch competes for the same window budget
            e = self.admission_budget(b, pending=len(budgets) - i)
            if fluid:
                self.charge(self._config_cost(self._host_index(e)), units)
            eff[i] = e
        return eff

    # ------------------------------------------------------------------
    # Queue + admission scheduler
    # ------------------------------------------------------------------

    def new_record(self, record: CostRecord, payload: object,
                   requested: Optional[float], *,
                   est_scale: float = 1.0) -> int:
        """Register a submitted request and enqueue it for admission.
        ``est_scale`` discounts the modeled EDP used for admission
        ordering — an engine with a prefix cache passes the predicted
        miss fraction, so predicted hits look cheaper and admit
        earlier (they really are cheaper: hits skip prefill)."""
        record.submitted_tick = self._tick
        self.requests[record.rid] = record
        est = 0.0
        if self.pricer is not None:
            open_budget = (float(requested) if requested is not None
                           else UNCONSTRAINED_BUDGET)
            est = (self._config_cost(self._host_index(open_budget)).edp
                   * float(est_scale))
        self._pending.append(_QueueEntry(record.rid, payload, est))
        return record.rid

    def submit_at(self, tick: int, submit: Callable[[], int]) -> None:
        """Register a deferred arrival: ``submit`` (a thunk that calls
        the adapter's ``submit(...)``) runs when the scheduler clock
        reaches ``tick`` inside :meth:`run` — the trace-replay entry
        point (arrivals enqueue by timestamp, not all-up-front)."""
        t = int(tick)
        if t < self._tick:
            raise ValueError(f"arrival tick {t} is in the past "
                             f"(scheduler clock is at {self._tick})")
        self._arrivals.setdefault(t, []).append(submit)

    def next_rid(self) -> int:
        rid = self._next_rid
        self._next_rid += 1
        return rid

    @property
    def queued(self) -> int:
        return len(self._pending)

    def age_queue(self) -> None:
        """One scheduler tick of waiting for everything still queued."""
        for e in self._pending:
            e.age += 1

    def next_admission(self) -> Optional[object]:
        """EDP-aware admission pick: the queued request with the lowest
        modeled per-unit EDP admits first (cheap requests maximize how
        many fit a tight SLO window), EXCEPT that any request that has
        waited ``starvation_ticks`` scheduler ticks is admitted FIFO
        first — the ordering never starves.  Deterministic (ties break
        by rid)."""
        if not self._pending:
            return None
        starved = [e for e in self._pending if e.age >= self.starvation_ticks]
        pick = (min(starved, key=lambda e: e.rid) if starved
                else min(self._pending, key=lambda e: (e.est_edp, e.rid)))
        self._pending.remove(pick)
        return pick.payload

    def finish_record(self, rid: int) -> CostRecord:
        record = self.requests[rid]
        record.done = True
        record.finished_s = time.time()
        record.finished_tick = self._tick
        self.stats.completed += 1
        # admissions were charged their PLANNED cost; a request that
        # terminated early (eos) — or whose speculative rounds diverged
        # from the plan (acceptance variance) — refunds/charges the
        # difference, so the SLO window tracks the stream's real spend
        if (isinstance(self.controller, FluidController)
                and record.ap_cost is not None):
            axis = self.controller.budget_axis
            actual = record.axis_actual(axis)
            planned = record.axis_planned(axis)
            if actual != planned:
                self.controller.reconcile(actual - planned)
        return record

    # ------------------------------------------------------------------
    # Scheduler loop (slot-pool workloads)
    # ------------------------------------------------------------------

    def step(self) -> List[int]:                # pragma: no cover - abstract
        raise NotImplementedError("workload adapter must implement step()")

    def _has_active(self) -> bool:              # pragma: no cover - abstract
        raise NotImplementedError

    def _active_count(self) -> int:
        """Occupied-slot count for the queue-depth instrumentation
        (adapters with a slot pool override)."""
        return 0

    def _can_admit(self) -> bool:
        return True

    def sched_tick(self) -> List[int]:
        """One instrumented scheduler tick: advance tick-windowed fluid
        controllers, run the adapter's :meth:`step`, record queue depth,
        and advance the scheduler clock.  Returns the rids that finished
        during the tick."""
        if isinstance(self.controller, FluidController):
            self.controller.tick()
        done = self.step()
        self.stats.record_tick(self.queued, self._active_count())
        self._tick += 1
        return done

    def run(self, max_ticks: int = 10_000, *,
            on_exhaust: str = "raise") -> Dict[int, CostRecord]:
        """Pump the scheduler until every submitted request — including
        deferred :meth:`submit_at` arrivals — completes; returns
        {rid: record}.

        If the queue cannot drain within ``max_ticks``, the leftover
        requests are counted in ``stats.unserved`` (their records stay
        ``done=False``) and the runtime raises — or, with
        ``on_exhaust="report"``, returns the partial result so callers
        (the traffic harness) can report rejections honestly instead of
        crashing mid-experiment."""
        if on_exhaust not in ("raise", "report"):
            raise ValueError(f"on_exhaust must be 'raise' or 'report', "
                             f"got {on_exhaust!r}")
        for _ in range(max_ticks):
            for submit in self._arrivals.pop(self._tick, ()):
                submit()
            if (not self._pending and not self._has_active()
                    and not self._arrivals):
                return dict(self.requests)
            if self._pending and not self._can_admit():
                raise RuntimeError("engine has no slots; requests can "
                                   "never be admitted")
            self.sched_tick()
        still = sorted(r.rid for r in self.requests.values() if not r.done)
        late = sum(len(v) for v in self._arrivals.values())
        self.stats.unserved = len(still) + late
        if self.stats.unserved and on_exhaust == "raise":
            raise RuntimeError(
                f"run() exhausted {max_ticks} ticks with {len(still)} "
                f"requests still pending ({late} arrivals never enqueued): "
                f"rids {still}")
        return dict(self.requests)

    # ------------------------------------------------------------------
    # Compute context
    # ------------------------------------------------------------------

    @contextlib.contextmanager
    def compute_ctx(self):
        """Mesh placement + the controller's static bit-family set (both
        trace-time properties of the engine's compiled programs)."""
        mesh_ctx = (dist.use_mesh(self.mesh) if self.mesh is not None
                    else contextlib.nullcontext())
        with mesh_ctx, kops.bit_families(self._families):
            yield
