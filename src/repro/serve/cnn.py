"""Batched CNN image serving: the batched-forward workload adapter.

The CNN analogue of :class:`repro.serve.engine.ServeEngine` (DESIGN.md
§7/§8): weights are quantized/prepacked ONCE at engine construction
(``cnn.quantize_cnn_params`` — int8 containers, packed int4 where the
controller's configurations make a layer eligible), and ONE compiled
forward serves every batch: each image's latency/EDP budget resolves
through a :class:`repro.core.policy.BudgetController` (or closed-loop
:class:`~repro.core.policy.FluidController`, charged image by image)
into a per-layer bit vector, the batch's ``(B, n_gemm)`` bit *matrix*
is an ordinary traced input executed via the bit-grouped batch dispatch
(``kernels/ops.py``), and the whole batch's resolved matrix is priced
in one pass through the paper's calibrated AP cost model
(``apsim.metrics.price_bit_matrix``) — per-request AP
latency/energy/EDP come back with the logits (Table VII, live per
image).  Queue/scheduler/stats/pricing plumbing lives in the shared
:class:`repro.serve.runtime.ServeRuntime`.

Batches pad to a fixed ``max_batch`` so batch-size churn never
retraces; ``stats.forward_traces`` proves the zero-retrace property.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import dist
from repro.apsim import metrics as apm
from repro.apsim.workloads import Layer, gemm_layers
from repro.core.policy import BudgetController, PrecisionPolicy, fixed
from repro.dist import sharding as shd
from repro.models import cnn
from repro.serve.accounting import ImageStats, RuntimeStats  # noqa: F401
from repro.serve.runtime import ServeRuntime


class CNNServeEngine(ServeRuntime):
    """Batched, bit-fluid CNN inference server.

    ``serve(images, budgets)`` runs one batch: ``images`` (B, H, W, C)
    with B <= ``max_batch`` (short batches right-pad; padded rows take
    the cheapest configuration and are dropped from the results), and
    ``budgets`` a scalar or ``(B,)`` per-image vector on the
    controller's budget axis (EDP by default — see
    ``policy.cnn_budget_controller``; ``None`` = unconstrained = most
    accurate configuration).  Returns ``(logits (B, num_classes),
    [ImageStats])``.
    """

    def __init__(self, params: dict, layers: Sequence[Layer], *,
                 controller: Optional[BudgetController] = None,
                 policy: Optional[PrecisionPolicy] = None,
                 max_batch: int = 8, container: str = "auto", mesh=None,
                 plan=None):
        self.layers = list(layers)
        gl = gemm_layers(self.layers)
        self.n_gemm = len(gl)
        if controller is None:
            pol = policy or fixed(8)
            controller = BudgetController({pol.name: pol}, {pol.name: 0.0},
                                          self.n_gemm)
        if plan == "auto":
            # resolve here rather than in the runtime: a CNN plan needs
            # the per-layer NAMES so replicates() can match the
            # per-layer-keyed qparams dicts (true LRMP-style per-layer
            # replication — LM stacks can't differentiate layers)
            m = mesh if mesh is not None else dist.active_mesh()
            nd = dist.placement.mesh_device_count(m)
            plan = (dist.placement.plan_for_controller(
                        controller, apm.network_gemms(self.layers),
                        n_devices=nd, names=tuple(l.name for l in gl))
                    if nd > 1 else None)
        super().__init__(controller, self.n_gemm,
                         gemms=apm.network_gemms(self.layers), mesh=mesh,
                         plan=plan, slot_desc="GEMM (conv/fc) layers")
        self.max_batch = max_batch
        wtab, _ = controller.stacked_tables()
        if container == "auto":
            int4_names = cnn.int4_eligible(self.layers, wtab)
            container = "int8"
        else:
            int4_names = ()
            wmax = int(np.max(np.asarray(wtab)))
            if container == "int4" and wmax > 4:
                raise ValueError(
                    f"container='int4' caps fidelity at 4 bits but the "
                    f"controller can resolve up to {wmax}-bit "
                    f"configurations — requests would be priced at a "
                    f"precision the container cannot honor (use "
                    f"container='auto' to pack int4 only where every "
                    f"configuration stays <= 4 bits)")
        self.int4_names = int4_names
        self.qparams = cnn.quantize_cnn_params(params, self.layers,
                                               container=container,
                                               int4_names=int4_names)
        if self.mesh is not None:       # place serve weights once — the
            # plan's fully-replicated layers override the base rules
            self.qparams = jax.device_put(
                self.qparams, shd.param_shardings(self.qparams, self.mesh,
                                                  plan=self.plan))
        # scale-out execution gate (mirrors ServeEngine): a fully-
        # replicated plan runs the batched forward under shard_map with
        # image ROWS split across dp — rows are independent, so the
        # per-device compute is exact
        self._dp_exec = None
        if (self.plan is not None and self.mesh is not None
                and self.plan.fully_replicated):
            dpx = dist.mesh_axes_for(self.mesh, "dp")
            dp = dist.dp_size(self.mesh)
            if dpx and dp > 1 and max_batch % dp == 0:
                self._dp_exec = dpx[0] if len(dpx) == 1 else tuple(dpx)

        def _fwd(qp, x, wmat, amat):
            self.stats.trace("forward")
            return cnn.cnn_forward(qp, x, self.layers, wmat, amat)

        if self._dp_exec is not None:
            from jax.sharding import PartitionSpec as P

            dpx = self._dp_exec

            def _fwd_manual(qp, x, wmat, amat):
                with dist.manual_mode():
                    return _fwd(qp, x, wmat, amat)

            self._fwd = jax.jit(dist.shard_map_compat(
                _fwd_manual, mesh=self.mesh,
                in_specs=(P(), P(dpx, None, None, None),
                          P(dpx, None), P(dpx, None)),
                out_specs=P(dpx, None)))
        else:
            self._fwd = jax.jit(_fwd)

    def serve(self, images, budgets=None
              ) -> Tuple[np.ndarray, List[ImageStats]]:
        """One batched inference; see class docstring."""
        images = jnp.asarray(images)
        B = images.shape[0]
        if not 1 <= B <= self.max_batch:
            raise ValueError(f"batch of {B} images exceeds max_batch="
                             f"{self.max_batch}")
        submitted = time.time()
        if budgets is None:
            req: List[Optional[float]] = [None] * B
        else:
            req = np.broadcast_to(np.asarray(budgets, np.float64),
                                  (B,)).tolist()
        # batch admission planning: closed-loop controllers are charged
        # image by image, so effective budgets tighten within the batch
        bud = self.plan_admissions(req)
        # pad to the fixed batch shape: padded rows take the cheapest
        # configuration (budget 0 fits nothing -> fastest) and are dropped
        pad = self.max_batch - B
        if pad:
            images = jnp.pad(images, ((0, pad),) + ((0, 0),) * 3)
            bud = np.concatenate([bud, np.zeros((pad,), np.float64)])
        budv = shd.shard_budgets(jnp.asarray(bud, jnp.float32), self.mesh)
        wmat, amat = self.controller.resolve(budv)
        if self.mesh is not None:
            images = shd.shard_batch({"x": images}, self.mesh)["x"]
            wmat = shd.shard_bits(wmat, self.mesh)
            amat = shd.shard_bits(amat, self.mesh)
        with self.compute_ctx():
            logits = self._fwd(self.qparams, images, wmat, amat)
        # ONE coalesced device->host transfer per batch
        wmat_h, amat_h, logits_h = jax.device_get((wmat, amat, logits))
        wmat_h = wmat_h.astype(np.int64)[:B]
        amat_h = amat_h.astype(np.int64)[:B]
        costs = self.price_matrix_bits(wmat_h, amat_h)     # one-pass batch
        replicas = (self.plan.mean_replicas if self.plan is not None
                    else 0.0)
        stats = []
        for i in range(B):
            rec = ImageStats(
                rid=self.next_rid(), budget_s=float(bud[i]), index=i,
                mean_wbits=float(np.mean(wmat_h[i])), ap_cost=costs[i],
                wbits=tuple(int(b) for b in wmat_h[i]),
                abits=tuple(int(b) for b in amat_h[i]),
                plan_replicas=replicas,
                submitted_s=submitted)
            self.requests[rec.rid] = rec
            self.finish_record(rec.rid)
            stats.append(rec)
        self.stats.admitted += B
        self.stats.batches += 1
        self.stats.images += B
        return logits_h[:B], stats


def hawq_fidelity_sweep(network: str = "resnet18", image: int = 32,
                        batch: int = 2, seed: int = 0
                        ) -> Tuple[Dict[str, float], int]:
    """Run every ``HAWQV3_RESNET18`` configuration through the serve-form
    kernels in ONE compiled program; returns ``({constraint:
    fidelity-vs-fp}, n_traces)``.

    Fidelity is softmax total-variation agreement with the fp
    (fake-quant-identity) reference — the functional accuracy axis of
    the Table VII accuracy-vs-EDP reproduction.  ``n_traces`` counts
    compiles across all five configuration switches; 1 is the
    zero-retrace claim (``benchmarks/table7_bitfluid.py`` gates on it,
    ``examples/mixed_precision_resnet18.py`` prints it).
    """
    from repro.apsim.workloads import HAWQV3_RESNET18, per_layer_bits

    key = jax.random.PRNGKey(seed)
    params, layers = cnn.init_cnn(network, key, image=image)
    qp = cnn.quantize_cnn_params(params, layers)
    x = jax.random.normal(key, (batch, image, image, 3), jnp.float32)
    ref = jax.nn.softmax(cnn.cnn_forward(params, x, layers), axis=-1)
    traces: List[int] = []

    def fwd(wv):
        traces.append(1)
        return cnn.cnn_forward(qp, x, layers, wv, wv)

    jfwd = jax.jit(fwd)
    fid = {}
    for name, vec in HAWQV3_RESNET18.items():
        bits = jnp.asarray(per_layer_bits(layers, vec), jnp.int32)
        out = jax.nn.softmax(jfwd(bits), axis=-1)
        fid[name] = float(1.0 - 0.5 * jnp.abs(out - ref).sum(-1).mean())
    assert all(np.isfinite(v) for v in fid.values())
    return fid, len(traces)
