"""Batched CNN image serving with per-request bit fluidity + EDP pricing.

The CNN analogue of :class:`repro.serve.engine.ServeEngine` (DESIGN.md
§7): weights are quantized/prepacked ONCE at engine construction
(``cnn.quantize_cnn_params`` — int8 containers, packed int4 where the
controller's configurations make a layer eligible), and ONE compiled
forward serves every batch: each image's latency/EDP budget resolves
through a :class:`repro.core.policy.BudgetController` into a per-layer
bit vector, the batch's ``(B, n_gemm)`` bit *matrix* is an ordinary
traced input executed via the bit-grouped batch dispatch
(``kernels/ops.py``), and each image's resolved vector is priced through
the paper's calibrated AP cost model (``apsim.metrics.price_bit_vector``
over the network's conv/fc GEMM dims) — so per-request AP
latency/energy/EDP come back with the logits (Table VII, live per
image).

Batches pad to a fixed ``max_batch`` so batch-size churn never retraces;
``CNNServeStats.forward_traces`` proves the zero-retrace property.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.apsim import metrics as apm
from repro.apsim.workloads import Layer, gemm_layers
from repro.core.policy import BudgetController, PrecisionPolicy, fixed
from repro.kernels import ops as kops
from repro.models import cnn


@dataclasses.dataclass
class CNNServeStats:
    """Engine-wide counters; ``forward_traces`` proves zero-retrace."""
    forward_traces: int = 0
    batches: int = 0
    images: int = 0


@dataclasses.dataclass(frozen=True)
class ImageStats:
    """Per-image serving record: the request's resolved precision and its
    modeled AP cost for ONE inference at that precision (per-layer
    breakdown on ``ap_cost``)."""
    index: int
    budget: float
    wbits: Tuple[int, ...]
    abits: Tuple[int, ...]
    ap_cost: apm.BitVectorCost

    @property
    def mean_wbits(self) -> float:
        return sum(self.wbits) / len(self.wbits)

    @property
    def ap_latency_s(self) -> float:
        return self.ap_cost.latency_s

    @property
    def ap_energy_j(self) -> float:
        return self.ap_cost.energy_j

    @property
    def edp(self) -> float:
        """Modeled AP energy-delay product (J*s) of this inference."""
        return self.ap_cost.edp


class CNNServeEngine:
    """Batched, bit-fluid CNN inference server.

    ``serve(images, budgets)`` runs one batch: ``images`` (B, H, W, C)
    with B <= ``max_batch`` (short batches right-pad; padded rows take
    the cheapest configuration and are dropped from the results), and
    ``budgets`` a scalar or ``(B,)`` per-image vector on the
    controller's budget axis (EDP by default — see
    ``policy.cnn_budget_controller``; ``None`` = unconstrained = most
    accurate configuration).  Returns ``(logits (B, num_classes),
    [ImageStats])``.
    """

    def __init__(self, params: dict, layers: Sequence[Layer], *,
                 controller: Optional[BudgetController] = None,
                 policy: Optional[PrecisionPolicy] = None,
                 max_batch: int = 8, container: str = "auto"):
        self.layers = list(layers)
        gl = gemm_layers(self.layers)
        self.n_gemm = len(gl)
        if controller is None:
            pol = policy or fixed(8)
            controller = BudgetController({pol.name: pol}, {pol.name: 0.0},
                                          self.n_gemm)
        if controller.n_layers != self.n_gemm:
            raise ValueError(
                f"controller resolves {controller.n_layers} bit slots but "
                f"the network has {self.n_gemm} GEMM (conv/fc) layers")
        self.controller = controller
        self.max_batch = max_batch
        wtab, _ = controller.stacked_tables()
        # grouped per-row dispatch specializes one GEMM per distinct
        # weight bit-width the controller can emit (kernels/ops.py)
        self._families = tuple(sorted(
            {min(max(int(v), 1), 8) for v in np.asarray(wtab).ravel()}))
        if container == "auto":
            int4_names = cnn.int4_eligible(self.layers, wtab)
            container = "int8"
        else:
            int4_names = ()
            wmax = int(np.max(np.asarray(wtab)))
            if container == "int4" and wmax > 4:
                raise ValueError(
                    f"container='int4' caps fidelity at 4 bits but the "
                    f"controller can resolve up to {wmax}-bit "
                    f"configurations — requests would be priced at a "
                    f"precision the container cannot honor (use "
                    f"container='auto' to pack int4 only where every "
                    f"configuration stays <= 4 bits)")
        self.int4_names = int4_names
        self.qparams = cnn.quantize_cnn_params(params, self.layers,
                                               container=container,
                                               int4_names=int4_names)
        self._gemms = apm.network_gemms(self.layers)
        self._price_cache: Dict[bytes, apm.BitVectorCost] = {}
        self.stats = CNNServeStats()

        def _fwd(qp, x, wmat, amat):
            self.stats.forward_traces += 1
            return cnn.cnn_forward(qp, x, self.layers, wmat, amat)

        self._fwd = jax.jit(_fwd)

    def price_bits(self, wv, av) -> apm.BitVectorCost:
        """AP cycles/energy of one resolved (n_gemm,) bit vector pair
        over the network's conv/fc GEMMs (cached — controllers emit a
        small static set of vectors)."""
        wv = np.asarray(wv, np.int64)
        av = np.asarray(av, np.int64)
        key = wv.tobytes() + b"|" + av.tobytes()
        hit = self._price_cache.get(key)
        if hit is None:
            hit = apm.price_bit_vector(self._gemms, wv.tolist(), av.tolist())
            self._price_cache[key] = hit
        return hit

    def serve(self, images, budgets=None
              ) -> Tuple[np.ndarray, List[ImageStats]]:
        """One batched inference; see class docstring."""
        images = jnp.asarray(images)
        B = images.shape[0]
        if not 1 <= B <= self.max_batch:
            raise ValueError(f"batch of {B} images exceeds max_batch="
                             f"{self.max_batch}")
        if budgets is None:
            bud = np.full((B,), 1e30, np.float64)      # unconstrained
        else:
            bud = np.broadcast_to(np.asarray(budgets, np.float64),
                                  (B,)).copy()
        # pad to the fixed batch shape: padded rows take the cheapest
        # configuration (budget 0 fits nothing -> fastest) and are dropped
        pad = self.max_batch - B
        if pad:
            images = jnp.pad(images, ((0, pad),) + ((0, 0),) * 3)
            bud = np.concatenate([bud, np.zeros((pad,), np.float64)])
        wmat, amat = self.controller.resolve(jnp.asarray(bud, jnp.float32))
        with kops.bit_families(self._families):
            logits = self._fwd(self.qparams, images, wmat, amat)
        wmat_h = np.asarray(wmat, np.int64)
        amat_h = np.asarray(amat, np.int64)
        stats = [
            ImageStats(index=i, budget=float(bud[i]),
                       wbits=tuple(int(b) for b in wmat_h[i]),
                       abits=tuple(int(b) for b in amat_h[i]),
                       ap_cost=self.price_bits(wmat_h[i], amat_h[i]))
            for i in range(B)
        ]
        self.stats.batches += 1
        self.stats.images += B
        return np.asarray(logits[:B]), stats


def hawq_fidelity_sweep(network: str = "resnet18", image: int = 32,
                        batch: int = 2, seed: int = 0
                        ) -> Tuple[Dict[str, float], int]:
    """Run every ``HAWQV3_RESNET18`` configuration through the serve-form
    kernels in ONE compiled program; returns ``({constraint:
    fidelity-vs-fp}, n_traces)``.

    Fidelity is softmax total-variation agreement with the fp
    (fake-quant-identity) reference — the functional accuracy axis of
    the Table VII accuracy-vs-EDP reproduction.  ``n_traces`` counts
    compiles across all five configuration switches; 1 is the
    zero-retrace claim (``benchmarks/table7_bitfluid.py`` gates on it,
    ``examples/mixed_precision_resnet18.py`` prints it).
    """
    from repro.apsim.workloads import HAWQV3_RESNET18, per_layer_bits

    key = jax.random.PRNGKey(seed)
    params, layers = cnn.init_cnn(network, key, image=image)
    qp = cnn.quantize_cnn_params(params, layers)
    x = jax.random.normal(key, (batch, image, image, 3), jnp.float32)
    ref = jax.nn.softmax(cnn.cnn_forward(params, x, layers), axis=-1)
    traces: List[int] = []

    def fwd(wv):
        traces.append(1)
        return cnn.cnn_forward(qp, x, layers, wv, wv)

    jfwd = jax.jit(fwd)
    fid = {}
    for name, vec in HAWQV3_RESNET18.items():
        bits = jnp.asarray(per_layer_bits(layers, vec), jnp.int32)
        out = jax.nn.softmax(jfwd(bits), axis=-1)
        fid[name] = float(1.0 - 0.5 * jnp.abs(out - ref).sum(-1).mean())
    assert all(np.isfinite(v) for v in fid.values())
    return fid, len(traces)
