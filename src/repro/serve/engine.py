"""Continuous-batching serving engine with per-request bit fluidity.

One compiled prefill + one compiled decode program serve every precision
configuration AND every mix of configurations across a batch: each
request carries its own latency budget, resolved by a
:class:`repro.core.policy.BudgetController` into a per-layer bit vector,
and the batch's ``(B, n_layers)`` bit *matrix* is an ordinary traced
input — the TPU realization of the paper's §V.B dynamic mixed-precision
claim ("switching between the three mixed-precision configurations
dynamically, as imposed by the changing run-time resource requirements"),
now at request granularity (cf. LRMP, arXiv:2312.03146).

Architecture (DESIGN.md §6):

  * ``submit()`` enqueues requests (prompt, latency budget, sampling
    params); a scheduler admits them into free slots of a persistent
    :class:`repro.models.lm.CachePool` as earlier requests complete
    (continuous batching — no batch barrier).
  * prefill runs per admitted request on a fixed ``(1, prefill_len)``
    shape (right-padded, EMPTY_POS-masked), its cache row installed into
    the pool by a traced-index write — slot churn never retraces.
  * decode is scan-fused: ``decode_block`` tokens per dispatch via
    ``lax.scan`` over (decode_step -> sample), with per-row positions,
    per-row bits, and per-row sampling (greedy / temperature / top-k).
  * ``ServeStats`` counts traces; tests assert both programs compile
    exactly once across budget changes, slot reuse, and admission churn.

The legacy whole-batch API (``set_budget``/``generate``) is kept — it now
accepts a per-request budget *vector* and runs the same scan-fused decode
(``fused=False`` preserves the old per-token Python loop for the
benchmark baseline in benchmarks/serve_throughput.py).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import dist
from repro.apsim import metrics as apm
from repro.core.policy import BudgetController, PrecisionPolicy
from repro.dist import sharding as shd
from repro.kernels import ops as kops
from repro.models import lm

TOPK_MAX = 64          # static top-k sort width; per-row k <= TOPK_MAX


@dataclasses.dataclass
class ServeStats:
    """Engine-wide counters; trace counts prove zero-retrace serving."""
    prefill_traces: int = 0
    decode_traces: int = 0
    tokens: int = 0
    admitted: int = 0
    completed: int = 0


@dataclasses.dataclass
class Request:
    """A queued generation request with its own budget + sampling params."""
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int
    budget_s: float
    temperature: float = 0.0
    top_k: int = 0
    prefix: Optional[np.ndarray] = None  # vlm: (n_prefix_tokens, d) stub


@dataclasses.dataclass
class RequestStats:
    """Per-request serving record (the per-request half of ServeStats).

    Besides wall-clock timing, each request carries its *priced* AP cost:
    at admission the resolved per-layer bit vector is pushed through
    ``apsim.metrics.price_bit_vector`` (the paper's calibrated cycle/energy
    model), so every request reports the latency/energy/EDP it would cost
    on the BF-IMNA hardware at its own precision — the Table 7
    accuracy-vs-EDP trade-off, live per request."""
    rid: int
    prompt_len: int
    budget_s: float
    mean_wbits: float                   # realized per-layer weight bits
    slot: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)
    submitted_s: float = 0.0
    finished_s: float = 0.0
    done: bool = False
    ap_cycles_per_token: float = 0.0
    ap_energy_per_token_j: float = 0.0
    ap_cost: Optional[apm.BitVectorCost] = None   # per-layer breakdown

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)

    @property
    def processed_tokens(self) -> int:
        """Tokens this request pushed through the model (prompt + new)."""
        return self.prompt_len + self.n_tokens

    @property
    def latency_s(self) -> float:
        """Wall-clock submit-to-finish latency (0.0 until done)."""
        return max(self.finished_s - self.submitted_s, 0.0) if self.done \
            else 0.0

    @property
    def ap_latency_s(self) -> float:
        """Modeled AP latency for every processed token at this request's
        precision configuration."""
        if self.ap_cost is None:
            return 0.0
        return (self.processed_tokens * self.ap_cycles_per_token
                / self.ap_cost.freq_hz)

    @property
    def ap_energy_j(self) -> float:
        return self.processed_tokens * self.ap_energy_per_token_j

    @property
    def edp(self) -> float:
        """Modeled AP energy-delay product (J·s) of the whole request."""
        return self.ap_energy_j * self.ap_latency_s


def _sample_tokens(logits: jnp.ndarray, key, temperature: jnp.ndarray,
                   top_k: jnp.ndarray) -> jnp.ndarray:
    """Per-row sampling: logits (B, V); temperature/top_k (B,).

    temperature == 0 -> greedy; top_k > 0 masks all but the row's k best
    logits (static TOPK_MAX sort width, per-row threshold gather)."""
    V = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    K = min(TOPK_MAX, V)
    vals, _ = jax.lax.top_k(logits, K)                       # (B, K)
    kth = jnp.take_along_axis(vals, jnp.clip(top_k, 1, K)[:, None] - 1,
                              axis=1)                        # (B, 1)
    masked = jnp.where((top_k[:, None] > 0) & (logits < kth),
                       -jnp.inf, logits)
    scaled = masked / jnp.maximum(temperature, 1e-6)[:, None]
    sampled = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy)


class ServeEngine:
    """Continuous-batching, bit-fluid serving engine.

    Two APIs share the compiled programs:

      * whole-batch: ``set_budget(scalar | (B,) vector)`` +
        ``generate(batch, steps)`` — one synchronous batch.
      * continuous: ``submit(prompt, budget_s=..., ...) -> rid`` +
        ``run()`` (or ``step()`` for manual pumping) — requests stream
        through a persistent slot pool, each at its own precision.
    """

    def __init__(self, cfg, qparams, *, max_len: int = 256,
                 controller: Optional[BudgetController] = None,
                 policy: Optional[PrecisionPolicy] = None,
                 mesh=None, n_slots: int = 4, prefill_len: int = 32,
                 decode_block: int = 8, eos_id: Optional[int] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.mesh = mesh if mesh is not None else dist.active_mesh()
        if self.mesh is not None:       # place serve weights once, sharded
            qparams = jax.device_put(
                qparams, shd.param_shardings(qparams, self.mesh))
        self.qparams = qparams
        self.max_len = max_len
        self.n_slots = n_slots
        self.prefill_len = prefill_len
        self.decode_block = decode_block
        self.eos_id = eos_id
        n = lm.n_bit_slots(cfg)
        self.n_layers = n
        if controller is not None:
            self.controller = controller
        else:
            pol = policy or _default_policy()
            self.controller = BudgetController(
                {pol.name: pol}, {pol.name: 0.0}, n)
        self.budget_s = jnp.asarray(1e9, jnp.float32)
        self.stats = ServeStats()
        self.row_bits = cfg.family in lm.PER_ROW_BIT_FAMILIES
        self._key = jax.random.PRNGKey(seed)
        # grouped per-row dispatch specializes one GEMM per *distinct*
        # weight bit-width the controller can emit (kernels/ops.py); the
        # family set is applied around every compiled call (trace-time)
        wtab, _ = self.controller.stacked_tables()
        self._families = tuple(sorted(
            {min(max(int(v), 1), 8) for v in np.asarray(wtab).ravel()}))
        # AP pricing of resolved bit vectors (per-request EDP accounting)
        self._gemms = lm.layer_gemm_dims(cfg)
        self._head_gemm = lm.head_gemm_dims(cfg)
        self._price_cache: Dict[bytes, apm.BitVectorCost] = {}

        # ---- continuous-batching state (pool built lazily on first submit)
        self.pool: Optional[lm.CachePool] = None
        self._queue: "collections.deque[Request]" = collections.deque()
        self._next_rid = 0
        self.requests: Dict[int, RequestStats] = {}
        self._slot_req = np.full((n_slots,), -1, np.int64)   # slot -> rid
        self._tok = np.zeros((n_slots,), np.int64)
        self._t = np.zeros((n_slots,), np.int64)
        self._budget = np.full((n_slots,), 1e9, np.float64)
        self._temp = np.zeros((n_slots,), np.float64)
        self._topk = np.zeros((n_slots,), np.int64)
        self._remaining = np.zeros((n_slots,), np.int64)
        self._just_finished: List[int] = []

        # ---- compiled programs (each traces exactly once per shape)
        def _prefill_batch(q, batch, cache, wv, av):
            self.stats.prefill_traces += 1
            return lm.prefill(q, batch, cfg, wv, av, cache)

        def _prefill_row(q, tokens, length, wv, av, *prefix):
            self.stats.prefill_traces += 1
            cache = lm.empty_cache(cfg, 1, max_len)
            batch = {"tokens": tokens}
            if prefix:                  # vlm: (1, n_prefix_tokens, d)
                batch["prefix"] = prefix[0]
            return lm.prefill(q, batch, cfg, wv, av, cache, lengths=length)

        def _decode_scan(q, tok, t, cache, wv, av, temp, topk, keys):
            self.stats.decode_traces += 1

            def step(carry, key):
                tok, t, cache = carry
                logits, cache = lm.decode_step(q, tok, t, cache, cfg, wv, av)
                nxt = _sample_tokens(logits[:, -1], key, temp, topk)
                return (nxt[:, None], t + 1, cache), nxt

            (tok, t, cache), toks = jax.lax.scan(step, (tok, t, cache), keys)
            return tok, t, cache, jnp.moveaxis(toks, 0, 1)   # (B, steps)

        def _decode_one(q, tok, t, cache, wv, av, temp, topk, key):
            # per-token baseline (benchmarks) — same math, no scan fusion
            self.stats.decode_traces += 1
            logits, cache = lm.decode_step(q, tok, t, cache, cfg, wv, av)
            nxt = _sample_tokens(logits[:, -1], key, temp, topk)
            return nxt[:, None], t + 1, cache, nxt

        def _sample_first(logits, key, temp, topk):
            return _sample_tokens(logits[:, -1], key, temp, topk)

        self._prefill = jax.jit(_prefill_batch, donate_argnums=(2,))
        self._prefill_row = jax.jit(_prefill_row)
        self._decode_scan = jax.jit(_decode_scan, donate_argnums=(3,))
        self._decode_one = jax.jit(_decode_one, donate_argnums=(3,))
        self._sample_first = jax.jit(_sample_first)

    # ------------------------------------------------------------------
    # Shared plumbing
    # ------------------------------------------------------------------

    def set_budget(self, seconds) -> None:
        """Runtime knob: a scalar batch budget, or a (B,) per-request
        budget vector — either way pure data, no recompilation."""
        self.budget_s = jnp.asarray(seconds, jnp.float32)

    def _bits(self):
        wv, av = self.controller.resolve(self.budget_s)
        if wv.ndim == 2 and not self.row_bits:
            raise NotImplementedError(
                f"per-request budgets need per-row bit support; family "
                f"{self.cfg.family!r} serves whole-batch budgets only "
                f"(supported: {lm.PER_ROW_BIT_FAMILIES})")
        return wv, av

    @contextlib.contextmanager
    def _compute_ctx(self):
        """Mesh placement + the controller's static bit-family set (both
        trace-time properties of the engine's compiled programs)."""
        mesh_ctx = (dist.use_mesh(self.mesh) if self.mesh is not None
                    else contextlib.nullcontext())
        with mesh_ctx, kops.bit_families(self._families):
            yield

    def price_bits(self, wv, av) -> apm.BitVectorCost:
        """AP cycles/energy of one resolved (n_layers,) bit vector pair
        (cached — the controller emits a small static set of vectors)."""
        wv = np.asarray(wv, np.int64)
        av = np.asarray(av, np.int64)
        key = wv.tobytes() + b"|" + av.tobytes()
        hit = self._price_cache.get(key)
        if hit is None:
            hit = apm.price_bit_vector(self._gemms, wv.tolist(), av.tolist(),
                                       head=self._head_gemm)
            self._price_cache[key] = hit
        return hit

    def price_budget(self, budget_s: float) -> apm.BitVectorCost:
        """Per-token AP cost of the configuration a scalar budget selects."""
        wv, av = self.controller.resolve(jnp.asarray(budget_s, jnp.float32))
        return self.price_bits(wv, av)

    def _split_key(self, num: int):
        keys = jax.random.split(self._key, num + 1)
        self._key = keys[0]
        return keys[1:]

    # ------------------------------------------------------------------
    # Whole-batch API (legacy-compatible, now scan-fused)
    # ------------------------------------------------------------------

    def generate(self, batch: Dict[str, jnp.ndarray], steps: int, *,
                 temperature=None, top_k=None, fused: bool = True
                 ) -> jnp.ndarray:
        """Generate ``steps`` tokens for one synchronous batch; returns
        (B, steps) ids.  Greedy unless per-row temperature/top_k given."""
        with self._compute_ctx():
            return self._generate(batch, steps, temperature, top_k, fused)

    def _generate(self, batch, steps, temperature, top_k, fused):
        B, S = batch["tokens"].shape
        prefix = self.cfg.n_prefix_tokens if self.cfg.family == "vlm" else 0
        temp = jnp.zeros((B,), jnp.float32) if temperature is None else \
            jnp.broadcast_to(jnp.asarray(temperature, jnp.float32), (B,))
        if top_k is not None and int(np.max(np.asarray(top_k))) > TOPK_MAX:
            raise ValueError(f"top_k exceeds TOPK_MAX={TOPK_MAX}")
        topk = jnp.zeros((B,), jnp.int32) if top_k is None else \
            jnp.broadcast_to(jnp.asarray(top_k, jnp.int32), (B,))
        wv, av = self._bits()
        if self.mesh is not None:
            wv, av = shd.shard_bits(wv, self.mesh), shd.shard_bits(av,
                                                                   self.mesh)
        batch = shd.shard_batch(batch, self.mesh)
        cache = lm.empty_cache(self.cfg, B, self.max_len)
        if self.mesh is not None:
            cache = jax.device_put(cache, shd.cache_shardings(cache,
                                                              self.mesh))
        logits, cache = self._prefill(self.qparams, batch, cache, wv, av)
        keys = self._split_key(steps)
        tok = self._sample_first(logits, keys[0], temp, topk)[:, None]
        t = jnp.full((B,), S + prefix, jnp.int32)
        if fused:
            _, _, cache, toks = self._decode_scan(
                self.qparams, tok, t, cache, wv, av, temp, topk,
                keys[1:steps])
            out = jnp.concatenate([tok, toks], axis=1)
        else:
            out = [tok]
            for i in range(steps - 1):
                tok, t, cache, _ = self._decode_one(
                    self.qparams, tok, t, cache, wv, av, temp, topk,
                    keys[1 + i])
                out.append(tok)
            out = jnp.concatenate(out, axis=1)
        self.stats.tokens += B * steps
        return out

    # ------------------------------------------------------------------
    # Continuous-batching API
    # ------------------------------------------------------------------

    def submit(self, prompt, *, max_new_tokens: int = 16,
               budget_s: Optional[float] = None, temperature: float = 0.0,
               top_k: int = 0, prefix=None) -> int:
        """Enqueue a request; returns its id.  ``budget_s`` picks this
        request's precision configuration (None = loosest/most accurate).
        vlm models require ``prefix`` (n_prefix_tokens, d_model)."""
        if self.cfg.family not in lm.RAGGED_PREFILL_FAMILIES:
            raise NotImplementedError(
                f"the continuous-batching API needs ragged prefill; family "
                f"{self.cfg.family!r} serves via generate() only "
                f"(supported: {lm.RAGGED_PREFILL_FAMILIES})")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if not 1 <= prompt.shape[0] <= self.prefill_len:
            raise ValueError(f"prompt length {prompt.shape[0]} not in "
                             f"[1, {self.prefill_len}]")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens={max_new_tokens} must be >= 1")
        prefix_len = (self.cfg.n_prefix_tokens
                      if self.cfg.family == "vlm" else 0)
        if (prefix_len + self.prefill_len + max_new_tokens > self.max_len
                and not self.cfg.sliding_window):
            raise ValueError("prefix + prefill_len + max_new_tokens "
                             "exceeds max_len (KV ring would wrap)")
        if top_k > TOPK_MAX:
            raise ValueError(f"top_k={top_k} exceeds TOPK_MAX={TOPK_MAX}")
        if self.cfg.family == "vlm":
            if prefix is None:
                raise ValueError("vlm requests need a prefix "
                                 "(n_prefix_tokens, d_model)")
            prefix = np.asarray(prefix, np.float32)
            if prefix.shape != (self.cfg.n_prefix_tokens, self.cfg.d_model):
                raise ValueError(f"prefix shape {prefix.shape} != "
                                 f"({self.cfg.n_prefix_tokens}, "
                                 f"{self.cfg.d_model})")
        budget = float(budget_s) if budget_s is not None else 1e9
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, prompt, max_new_tokens, budget,
                                   float(temperature), int(top_k),
                                   prefix=prefix))
        self.requests[rid] = RequestStats(
            rid=rid, prompt_len=int(prompt.shape[0]), budget_s=budget,
            mean_wbits=0.0,             # realized at admission (_admit)
            submitted_s=time.time())
        return rid

    def _ensure_pool(self) -> lm.CachePool:
        if self.pool is None:
            shardings = None
            if self.mesh is not None:
                proto = lm.empty_cache(self.cfg, self.n_slots, self.max_len)
                shardings = shd.cache_shardings(proto, self.mesh)
            self.pool = lm.CachePool(self.cfg, self.n_slots, self.max_len,
                                     shardings=shardings)
        return self.pool

    def _admit(self) -> List[int]:
        """Move queued requests into free pool slots (prefill + install)."""
        pool = self._ensure_pool()
        admitted = []
        while self._queue and pool.free_slots:
            req = self._queue.popleft()
            slot = pool.alloc()
            S = req.prompt.shape[0]
            tokens = np.zeros((1, self.prefill_len), np.int32)
            tokens[0, :S] = req.prompt
            wv, av = self.controller.resolve(
                jnp.asarray(req.budget_s, jnp.float32))
            extra = (() if req.prefix is None
                     else (jnp.asarray(req.prefix[None]),))
            logits, row_cache = self._prefill_row(
                self.qparams, jnp.asarray(tokens),
                jnp.asarray([S], jnp.int32), wv, av, *extra)
            prefix_len = (self.cfg.n_prefix_tokens
                          if self.cfg.family == "vlm" else 0)
            pool.write_row(row_cache, slot, S + prefix_len)
            key = self._split_key(1)[0]
            first = self._sample_first(
                logits, key, jnp.asarray([req.temperature], jnp.float32),
                jnp.asarray([req.top_k], jnp.int32))
            st = self.requests[req.rid]
            st.slot = slot
            st.mean_wbits = float(jnp.mean(wv.astype(jnp.float32)))
            cost = self.price_bits(wv, av)      # AP pricing of this mix
            st.ap_cost = cost
            st.ap_cycles_per_token = cost.cycles
            st.ap_energy_per_token_j = cost.energy_j
            st.tokens.append(int(first[0]))
            self.stats.tokens += 1
            self.stats.admitted += 1
            self._slot_req[slot] = req.rid
            self._tok[slot] = int(first[0])
            self._t[slot] = S + prefix_len
            self._budget[slot] = req.budget_s
            self._temp[slot] = req.temperature
            self._topk[slot] = req.top_k
            self._remaining[slot] = req.max_new_tokens - 1
            admitted.append(req.rid)
            if self._remaining[slot] <= 0 or (
                    self.eos_id is not None
                    and int(first[0]) == self.eos_id):
                self._finish(slot)
        return admitted

    def _finish(self, slot: int) -> None:
        rid = int(self._slot_req[slot])
        st = self.requests[rid]
        st.done = True
        st.finished_s = time.time()
        self.stats.completed += 1
        self._slot_req[slot] = -1
        self._remaining[slot] = 0
        self.pool.free(slot)
        self._just_finished.append(rid)

    def step(self) -> List[int]:
        """One scheduler tick: admit into free slots, decode one block,
        harvest tokens, retire finished requests.  Returns the rids that
        completed during this tick."""
        with self._compute_ctx():
            return self._step()

    def _step(self) -> List[int]:
        self._admit()
        pool = self.pool
        active = self._slot_req >= 0
        if not active.any():
            done = self._just_finished
            self._just_finished = []
            return done
        # submit() guarantees a RAGGED_PREFILL_FAMILIES family, all of
        # which support per-row bits — so budgets are always per-slot
        budgets = jnp.asarray(self._budget, jnp.float32)          # (B,)
        wv, av = self.controller.resolve(budgets)
        if self.mesh is not None:
            wv, av = shd.shard_bits(wv, self.mesh), shd.shard_bits(av,
                                                                   self.mesh)
        keys = self._split_key(self.decode_block)
        tok = jnp.asarray(self._tok[:, None], jnp.int32)
        t = jnp.asarray(self._t, jnp.int32)
        temp = jnp.asarray(self._temp, jnp.float32)
        topk = jnp.asarray(self._topk, jnp.int32)
        tok, t, pool.cache, toks = self._decode_scan(
            self.qparams, tok, t, pool.cache, wv, av, temp, topk, keys)
        toks_h = np.asarray(toks)
        self._tok = np.asarray(tok)[:, 0].astype(np.int64)
        self._t += self.decode_block
        for slot in np.nonzero(active)[0]:
            rid = int(self._slot_req[slot])
            st = self.requests[rid]
            take = int(min(self._remaining[slot], self.decode_block))
            new = toks_h[slot, :take].tolist()
            if self.eos_id is not None and self.eos_id in new:
                new = new[:new.index(self.eos_id) + 1]
            st.tokens.extend(int(x) for x in new)
            self.stats.tokens += len(new)
            self._remaining[slot] -= take
            hit_eos = (self.eos_id is not None and new
                       and new[-1] == self.eos_id)
            if self._remaining[slot] <= 0 or hit_eos:
                self._finish(slot)
        done = self._just_finished
        self._just_finished = []
        return done

    def run(self, max_ticks: int = 10_000) -> Dict[int, RequestStats]:
        """Pump the scheduler until every submitted request completes;
        returns {rid: RequestStats}.  Raises if the queue cannot drain
        (no slots, or max_ticks exhausted) rather than silently returning
        incomplete results."""
        for _ in range(max_ticks):
            if not self._queue and not (self._slot_req >= 0).any():
                return dict(self.requests)
            if self._queue and self.n_slots < 1:
                raise RuntimeError("engine has no slots; requests can "
                                   "never be admitted")
            self.step()
        pending = [r.rid for r in self.requests.values() if not r.done]
        if pending:
            raise RuntimeError(f"run() exhausted {max_ticks} ticks with "
                               f"requests still pending: {pending}")
        return dict(self.requests)


def _default_policy() -> PrecisionPolicy:
    from repro.core import policy as pol
    return pol.fixed(8)
