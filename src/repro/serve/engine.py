"""Continuous-batching LM serving: the prefill/decode workload adapter.

One compiled prefill + one compiled decode program serve every precision
configuration AND every mix of configurations across a batch: each
request carries its own latency budget, resolved by a
:class:`repro.core.policy.BudgetController` (or closed-loop
:class:`~repro.core.policy.FluidController`) into a per-layer bit
vector, and the batch's ``(B, n_layers)`` bit *matrix* is an ordinary
traced input — the TPU realization of the paper's §V.B dynamic
mixed-precision claim, at request granularity (cf. LRMP,
arXiv:2312.03146).

The queue, EDP-aware admission scheduler, slot lifecycle, closed
control loop, pricing, and stats all live in the workload-agnostic
:class:`repro.serve.runtime.ServeRuntime` (DESIGN.md §8); this module
owns only what is LM-shaped — ragged prefill, the scan-fused decode
block, per-row sampling, and the KV cache pool.

  * prefill runs per admitted request on a fixed ``(1, prefill_len)``
    shape (right-padded, EMPTY_POS-masked), its cache row installed into
    a persistent :class:`repro.models.lm.CachePool` by a traced-index
    write — slot churn never retraces.
  * decode is scan-fused: ``decode_block`` tokens per dispatch via
    ``lax.scan`` over (decode_step -> sample), with per-row positions,
    per-row bits, and per-row sampling (greedy / temperature / top-k).
  * ``stats`` counts traces; tests assert both programs compile exactly
    once across budget churn, slot reuse, and closed-loop switches.

The legacy whole-batch API (``set_budget``/``generate``) is kept — it
accepts a per-request budget *vector* and runs the same scan-fused
decode (``fused=False`` preserves the per-token Python loop for the
benchmark baseline in benchmarks/serve_throughput.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import dist
from repro.core.policy import (BudgetController, FluidController,
                               PrecisionPolicy)
from repro.dist import sharding as shd
from repro.models import lm
from repro.models.transformer import EMPTY_POS
from repro.serve.accounting import RequestStats, RuntimeStats  # noqa: F401
from repro.serve.prefix_cache import PrefixCache
from repro.serve.runtime import (ServeRuntime, SlotTable,
                                 UNCONSTRAINED_BUDGET)

TOPK_MAX = 64          # static top-k sort width; per-row k <= TOPK_MAX
SPEC_K_MAX = 8         # static draft depth ceiling: every speculative
                       # round drafts SPEC_K_MAX tokens and verifies one
                       # (SPEC_K_MAX + 1)-wide chunk, so ONE compiled
                       # draft program and ONE verify program cover every
                       # (slot, k, accept-length) combination


@dataclasses.dataclass
class Request:
    """A queued generation request with its own budget + sampling params."""
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int
    budget_s: Optional[float]
    temperature: float = 0.0
    top_k: int = 0
    prefix: Optional[np.ndarray] = None  # vlm: (n_prefix_tokens, d) stub
    rep_key: Optional[int] = None       # traffic repetition key (the
                                        # prefix-cache count signal)
    draft_k: Optional[int] = None       # speculative draft depth override
                                        # (None: engine/controller decides)


def _scaled_logits(logits: jnp.ndarray, temperature: jnp.ndarray,
                   top_k: jnp.ndarray) -> jnp.ndarray:
    """Per-row masked + temperature-scaled logits: logits (B, V);
    temperature/top_k (B,).  top_k > 0 masks all but the row's k best
    logits (static TOPK_MAX sort width, per-row threshold gather).  The
    single definition of the sampling distribution — sampling draws from
    softmax of this, and speculative rejection-accept tests drafts
    against the same densities."""
    V = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    K = min(TOPK_MAX, V)
    vals, _ = jax.lax.top_k(logits, K)                       # (B, K)
    kth = jnp.take_along_axis(vals, jnp.clip(top_k, 1, K)[:, None] - 1,
                              axis=1)                        # (B, 1)
    masked = jnp.where((top_k[:, None] > 0) & (logits < kth),
                       -jnp.inf, logits)
    return masked / jnp.maximum(temperature, 1e-6)[:, None]


def _sample_tokens(logits: jnp.ndarray, key, temperature: jnp.ndarray,
                   top_k: jnp.ndarray) -> jnp.ndarray:
    """Per-row sampling: logits (B, V); temperature/top_k (B,).
    temperature == 0 -> greedy."""
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = _scaled_logits(logits, temperature, top_k)
    sampled = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy)


class ServeEngine(ServeRuntime):
    """Continuous-batching, bit-fluid LM serving engine.

    Two APIs share the compiled programs:

      * whole-batch: ``set_budget(scalar | (B,) vector)`` +
        ``generate(batch, steps)`` — one synchronous batch.
      * continuous: ``submit(prompt, budget_s=..., ...) -> rid`` +
        ``run()`` (or ``step()`` for manual pumping) — requests stream
        through a persistent slot pool, each at its own precision.
    """

    def __init__(self, cfg, qparams, *, max_len: int = 256,
                 controller: Optional[BudgetController] = None,
                 policy: Optional[PrecisionPolicy] = None,
                 mesh=None, n_slots: int = 4, prefill_len: int = 32,
                 decode_block: int = 8, eos_id: Optional[int] = None,
                 seed: int = 0, prefix_cache: Optional[PrefixCache] = None,
                 spec_k: Optional[int] = None,
                 draft_budget_s: Optional[float] = None,
                 plan=None):
        self.cfg = cfg
        # ---- speculative decoding (DESIGN.md §11): spec_k=None disables
        # entirely; an int enables self-drafting with that default depth
        # (a FluidController overrides per admission via draft_depth()).
        # draft_budget_s picks the DRAFT bit configuration through the
        # same controller tables (None -> 0.0 -> the cheapest config).
        if spec_k is not None:
            if not 0 <= spec_k <= SPEC_K_MAX:
                raise ValueError(
                    f"spec_k={spec_k} not in [0, {SPEC_K_MAX}]")
            if cfg.sliding_window:
                raise ValueError(
                    "speculative decoding needs a non-wrapping KV ring; "
                    "sliding_window models must serve with spec_k=None")
            if cfg.family not in lm.SPEC_CHUNK_FAMILIES:
                raise ValueError(
                    f"speculative decoding needs the chunked verify path; "
                    f"family {cfg.family!r} is unsupported "
                    f"(supported: {lm.SPEC_CHUNK_FAMILIES})")
        self.spec_k = spec_k
        self._draft_budget_f = (0.0 if draft_budget_s is None
                                else float(draft_budget_s))
        self._draft_bits_c = None
        self._draft_price = None
        self._draft_idx = -1            # config index the draft caches hold
        self._draft_price_idx = -1
        self._draft_wbits_f = 0.0       # mean weight bits of that config
        mesh = mesh if mesh is not None else dist.active_mesh()
        self.qparams = qparams
        self.max_len = max_len
        self.n_slots = n_slots
        self.prefill_len = prefill_len
        self.decode_block = decode_block
        self.eos_id = eos_id
        n = lm.n_bit_slots(cfg)
        if controller is None:
            pol = policy or _default_policy()
            controller = BudgetController({pol.name: pol}, {pol.name: 0.0}, n)
        if (controller.budget_axis != "latency"
                and not isinstance(controller, FluidController)):
            # a FluidController may run its SLO loop on the energy/EDP
            # axis (AP latency is nearly flat across precisions — Table
            # VII — so only energy-family budgets can discriminate);
            # request budgets then live on that axis too.  An OPEN-loop
            # controller on a non-latency axis is a wiring bug: LM
            # budgets are seconds, so they would always- or never-fit.
            raise ValueError(
                f"ServeEngine budgets are LATENCY budgets (seconds) but the "
                f"controller's prediction table lives on the "
                f"{controller.budget_axis!r} axis — its budgets would "
                f"always- or never-fit; build the controller with "
                f"latency predictions (cnn_budget_controller's "
                f"energy/EDP axes are for CNNServeEngine, or use a "
                f"FluidController for an energy/EDP SLO loop)")
        super().__init__(controller, n, gemms=lm.layer_gemm_dims(cfg),
                         head=lm.head_gemm_dims(cfg), mesh=mesh, plan=plan)
        if self.mesh is not None:       # place serve weights once, sharded
            # (after super().__init__ so an "auto" plan is resolved —
            # fully-replicated plan entries override the FSDP/tp rules)
            self.qparams = jax.device_put(
                self.qparams, shd.param_shardings(self.qparams, self.mesh,
                                                  plan=self.plan))
        # scale-out execution gate (DESIGN.md §13): a fully-replicated
        # plan makes every device hold every weight, so the decode block
        # can run under shard_map with request ROWS split across the dp
        # axis — manual per-device compute is exact (rows are
        # independent; greedy sampling is bit-identical).  Partial plans
        # and plan-less meshes keep the GSPMD path.
        self._dp_exec = None
        if (self.plan is not None and self.mesh is not None
                and self.plan.fully_replicated):
            dpx = dist.mesh_axes_for(self.mesh, "dp")
            dp = dist.dp_size(self.mesh)
            if dpx and dp > 1 and n_slots % dp == 0:
                self._dp_exec = dpx[0] if len(dpx) == 1 else tuple(dpx)
        self.budget_s = jnp.asarray(1e9, jnp.float32)
        self.row_bits = cfg.family in lm.PER_ROW_BIT_FAMILIES
        self._key = jax.random.PRNGKey(seed)
        # cross-request prefix/KV-cache tier (DESIGN.md §10): only
        # prompts that fit the cache ring entirely are cacheable (a
        # wrapped prefix would install an incomplete row), and vlm
        # requests bypass (their prefix embeddings aren't content-keyed)
        self.prefix_cache = prefix_cache
        self._cache_sc = (min(max_len, cfg.sliding_window)
                          if cfg.sliding_window else max_len)

        # ---- continuous-batching state (pool built lazily on first submit)
        self.pool: Optional[lm.CachePool] = None
        self.slots = SlotTable(
            n_slots,
            tok=(np.int64, 0), t=(np.int64, 0),
            budget=(np.float64, 0.0),           # freed rows: cheapest bits
            temp=(np.float64, 0.0), topk=(np.int64, 0),
            remaining=(np.int64, 0),
            k=(np.int64, 0))                    # speculative draft depth
        self._just_finished: List[int] = []

        # ---- compiled programs (each traces exactly once per shape)
        def _prefill_batch(q, batch, cache, wv, av):
            self.stats.trace("prefill")
            return lm.prefill(q, batch, cfg, wv, av, cache)

        def _prefill_row(q, tokens, length, wv, av, *prefix):
            self.stats.trace("prefill")
            cache = lm.empty_cache(cfg, 1, max_len)
            batch = {"tokens": tokens}
            if prefix:                  # vlm: (1, n_prefix_tokens, d)
                batch["prefix"] = prefix[0]
            return lm.prefill(q, batch, cfg, wv, av, cache, lengths=length)

        def _decode_scan(q, tok, t, cache, wv, av, temp, topk, keys):
            self.stats.trace("decode")

            def step(carry, key):
                tok, t, cache = carry
                logits, cache = lm.decode_step(q, tok, t, cache, cfg, wv, av)
                nxt = _sample_tokens(logits[:, -1], key, temp, topk)
                return (nxt[:, None], t + 1, cache), nxt

            (tok, t, cache), toks = jax.lax.scan(step, (tok, t, cache), keys)
            return tok, t, cache, jnp.moveaxis(toks, 0, 1)   # (B, steps)

        def _decode_one(q, tok, t, cache, wv, av, temp, topk, key):
            # per-token baseline (benchmarks) — same math, no scan fusion
            self.stats.trace("decode")
            logits, cache = lm.decode_step(q, tok, t, cache, cfg, wv, av)
            nxt = _sample_tokens(logits[:, -1], key, temp, topk)
            return nxt[:, None], t + 1, cache, nxt

        def _draft_scan(q, tok, t, cache, wv, av, temp, topk, keys):
            # speculative self-draft: SPEC_K_MAX scan-fused decode steps
            # at the engine's LOW draft bits (one program for every k —
            # rows with shallower depth simply ignore the tail).  Also
            # returns each draft's sampling density q_i: the rejection
            # verify tests p_i/q_i against the same distributions the
            # tokens were drawn from.
            self.stats.trace("draft")

            def step(carry, key):
                tok, t, cache = carry
                logits, cache = lm.decode_step(q, tok, t, cache, cfg, wv, av)
                flat = logits[:, -1].astype(jnp.float32)
                nxt = _sample_tokens(flat, key, temp, topk)
                probs = jax.nn.softmax(
                    _scaled_logits(flat, temp, topk), axis=-1)
                return (nxt[:, None], t + 1, cache), (nxt, probs)

            (_, _, cache), (toks, probs) = jax.lax.scan(
                step, (tok, t, cache), keys)
            return (jnp.moveaxis(toks, 0, 1),        # (B, SPEC_K_MAX)
                    jnp.moveaxis(probs, 0, 1),       # (B, SPEC_K_MAX, V)
                    cache)

        def _spec_verify(q, tok, draft_toks, draft_probs, t, cache,
                         wv, av, k_eff, temp, topk, key_u, key_s):
            # batched high-bit verify: ONE (SPEC_K_MAX + 1)-wide chunk
            # scores the current token + every draft at each row's own
            # TARGET bits, overwriting the draft-precision cache entries
            # in place.  Greedy rows accept the longest exact-argmax
            # prefix; sampled rows run rejection resampling against the
            # draft densities (accept u < p/q, resample the first
            # rejection from normalize(max(p - q, 0)), bonus draw from p
            # on full accept) — both paths emit a + 1 tokens.  k_eff is
            # the per-row accept clamp (min(spec_k, remaining - 1)), so
            # one compiled program covers every (slot, k, accept-length)
            # combination.
            self.stats.trace("verify")
            B = tok.shape[0]
            U = SPEC_K_MAX + 1
            toks = jnp.concatenate([tok, draft_toks], axis=1)     # (B, U)
            logits, cache = lm.decode_chunk(q, toks, t, cache, cfg, wv, av)
            logits = logits.astype(jnp.float32)
            ver = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, U)
            flat = logits.reshape(B * U, -1)
            p = jax.nn.softmax(
                _scaled_logits(flat, jnp.repeat(temp, U),
                               jnp.repeat(topk, U)), axis=-1
            ).reshape(B, U, -1)                   # per-position target dists
            p_g = jnp.take_along_axis(p[:, :SPEC_K_MAX],
                                      draft_toks[..., None],
                                      axis=-1)[..., 0]            # (B, K)
            q_g = jnp.take_along_axis(draft_probs, draft_toks[..., None],
                                      axis=-1)[..., 0]
            u = jax.random.uniform(key_u, draft_toks.shape)
            ok = jnp.where(temp[:, None] > 0,
                           u * jnp.maximum(q_g, 1e-20) < p_g,     # u < p/q
                           draft_toks == ver[:, :SPEC_K_MAX])
            ok &= jnp.arange(SPEC_K_MAX)[None] < k_eff[:, None]
            a = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1),
                        axis=1)                  # accepted prefix length
            # the (a+1)-th emitted token: greedy rows take the verify
            # argmax at position a; sampled rows draw from the residual
            # (or from p itself at a == k_eff, the full-accept bonus)
            p_a = jnp.take_along_axis(p, a[:, None, None], axis=1)[:, 0]
            q_pad = jnp.concatenate(
                [draft_probs, jnp.zeros_like(draft_probs[:, :1])], axis=1)
            q_a = jnp.take_along_axis(q_pad, a[:, None, None], axis=1)[:, 0]
            resid = jnp.where((a < k_eff)[:, None],
                              jnp.maximum(p_a - q_a, 0.0), p_a)
            tot = jnp.sum(resid, axis=-1, keepdims=True)
            rdist = jnp.where(tot > 0, resid / jnp.maximum(tot, 1e-30), p_a)
            extra = jnp.where(
                temp > 0,
                jax.random.categorical(
                    key_s, jnp.log(rdist + 1e-30),
                    axis=-1).astype(jnp.int32),
                jnp.take_along_axis(ver, a[:, None], axis=1)[:, 0])
            emitted = jnp.where(
                jnp.arange(U)[None] < a[:, None],
                jnp.concatenate([draft_toks, draft_toks[:, -1:]], axis=1),
                extra[:, None])                                   # (B, U)
            # extra is the round's LAST delivered token = next round's
            # input; keep = t + a is the rollback watermark (entries past
            # it were computed from rejected drafts)
            return extra, t + a + 1, emitted, a + 1, t + a, cache

        def _sample_first(logits, key, temp, topk):
            return _sample_tokens(logits[:, -1], key, temp, topk)

        def _extend_row(q, tokens, row, start, r, wv, av):
            # partial prefix-cache hit: the entry's row holds a longer
            # (or equal) prompt — mask it down to its first ``start``
            # tokens, then push the remaining ``r`` prompt tokens
            # through the decode path at positions start..start+r-1.
            # Fixed scan length (prefill_len) with a clamped step index
            # keeps the shape static: start/r are traced scalars, so
            # every partial hit shares ONE compiled program; the
            # clamped tail steps recompute the final token with
            # identical inputs (idempotent cache writes).  The entry's
            # pytree is never donated — the cache keeps its rows.
            self.stats.trace("extend")

            def mask(path, p):
                if path and path[-1] == "kpos":
                    return jnp.where(p >= start, EMPTY_POS, p)
                return p

            row = jax.tree_util.tree_map_with_path(
                lambda path, p: mask(tuple(
                    str(getattr(k, "key", k)) for k in path), p), row)

            def step(cache, s):
                s_eff = jnp.minimum(s, r - 1)
                tok = jax.lax.dynamic_slice(tokens, (0, start + s_eff),
                                            (1, 1))
                logits, cache = lm.decode_step(q, tok, start + s_eff,
                                               cache, cfg, wv, av)
                return cache, logits

            row, ys = jax.lax.scan(
                step, row, jnp.arange(prefill_len, dtype=jnp.int32))
            return ys[-1], row          # final-token logits (1, 1, V)

        self._prefill = jax.jit(_prefill_batch, donate_argnums=(2,))
        self._prefill_row = jax.jit(_prefill_row)
        self._decode_scan = jax.jit(_decode_scan, donate_argnums=(3,))
        self._decode_scan_sh = None
        if self._dp_exec is not None:
            from jax.sharding import PartitionSpec as P

            dpx = self._dp_exec

            def _decode_scan_manual(*args):
                # trace-time flag: constrain() inside the body must
                # no-op (mesh axes are consumed by the shard_map)
                with dist.manual_mode():
                    return _decode_scan(*args)

            # (q, tok, t, cache, wv, av, temp, topk, keys): weights
            # replicated (the plan's point), every per-request operand
            # split on its batch dim — cache leaves all carry batch at
            # dim 1, so one prefix spec covers the whole pytree
            self._decode_scan_sh = jax.jit(
                dist.shard_map_compat(
                    _decode_scan_manual, mesh=self.mesh,
                    in_specs=(P(), P(dpx, None), P(dpx), P(None, dpx),
                              P(dpx, None), P(dpx, None), P(dpx),
                              P(dpx), P()),
                    out_specs=(P(dpx, None), P(dpx), P(None, dpx),
                               P(dpx, None))),
                donate_argnums=(3,))
        self._decode_one = jax.jit(_decode_one, donate_argnums=(3,))
        self._draft = jax.jit(_draft_scan, donate_argnums=(3,))
        self._verify = jax.jit(_spec_verify, donate_argnums=(5,))
        self._sample_first = jax.jit(_sample_first)
        self._extend_row = jax.jit(_extend_row)

    # ------------------------------------------------------------------
    # Shared plumbing
    # ------------------------------------------------------------------

    def set_budget(self, seconds) -> None:
        """Runtime knob: a scalar batch budget, or a (B,) per-request
        budget vector — either way pure data, no recompilation."""
        self.budget_s = jnp.asarray(seconds, jnp.float32)

    def _bits(self):
        wv, av = self.controller.resolve(self.budget_s)
        if wv.ndim == 2 and not self.row_bits:
            raise NotImplementedError(
                f"per-request budgets need per-row bit support; family "
                f"{self.cfg.family!r} serves whole-batch budgets only "
                f"(supported: {lm.PER_ROW_BIT_FAMILIES})")
        return wv, av

    def price_budget(self, budget_s: float):
        """Per-token AP cost of the configuration a scalar budget selects."""
        return self.price_bits(
            *self.controller.resolve(jnp.asarray(budget_s, jnp.float32)))

    def _draft_index(self) -> int:
        """Stacked-config index the drafts run at: the draft budget's
        base config, offset by a FluidController's autotuner shift
        (``observe_accept`` — accept-rate EMA moves draft bits up or
        down), clamped into the config range."""
        base = self._host_index(self._draft_budget_f)
        shift = int(getattr(self.controller, "draft_shift", 0) or 0)
        n = self.host_tables()[0].shape[0]
        return min(max(base + shift, 0), n - 1)

    def _draft_bits(self):
        """Device-side draft bit matrix (n_slots, L): the current draft
        config broadcast across rows.  Cached per config index — the
        shapes never change, so an autotuner shift swaps pure data
        without retracing."""
        idx = self._draft_index()
        if self._draft_bits_c is None or idx != self._draft_idx:
            wtab, atab = self.controller.stacked_tables()
            wv, av = wtab[idx], atab[idx]
            wv = jnp.broadcast_to(wv, (self.n_slots,) + wv.shape)
            av = jnp.broadcast_to(av, (self.n_slots,) + av.shape)
            if self.mesh is not None:
                wv = shd.shard_bits(wv, self.mesh)
                av = shd.shard_bits(av, self.mesh)
            self._draft_bits_c = (wv, av)
            self._draft_idx = idx
            self._draft_wbits_f = float(np.mean(self.host_tables()[0][idx]))
            self._draft_price = None
        return self._draft_bits_c

    def _draft_pricing(self):
        """Per-token AP cost of one draft step at the current draft bits
        (cached per config index; plan-amortized when a placement plan
        is installed)."""
        idx = self._draft_index()
        if self._draft_price is None or idx != self._draft_price_idx:
            wtab, atab = self.host_tables()
            self._draft_price = self.price_bits(wtab[idx], atab[idx])
            self._draft_price_idx = idx
            self._draft_wbits_f = float(np.mean(wtab[idx]))
        return self._draft_price

    def _resolve_draft_k(self, req: Request) -> int:
        """Draft depth for one admission: the request's explicit
        ``draft_k``, else the FluidController's headroom-scaled depth,
        else the engine default (spec_k=None disables)."""
        if req.draft_k is not None:
            return int(req.draft_k)
        if self.spec_k is None:
            return 0
        if isinstance(self.controller, FluidController):
            return min(self.controller.draft_depth(), SPEC_K_MAX)
        return self.spec_k

    def _split_key(self, num: int):
        keys = jax.random.split(self._key, num + 1)
        self._key = keys[0]
        return keys[1:]

    # ------------------------------------------------------------------
    # Whole-batch API (legacy-compatible, now scan-fused)
    # ------------------------------------------------------------------

    def generate(self, batch: Dict[str, jnp.ndarray], steps: int, *,
                 temperature=None, top_k=None, fused: bool = True
                 ) -> jnp.ndarray:
        """Generate ``steps`` tokens for one synchronous batch; returns
        (B, steps) ids.  Greedy unless per-row temperature/top_k given."""
        if isinstance(self.controller, FluidController):
            # the whole-batch path has no admissions to charge — it would
            # silently run the fluid controller open-loop
            raise ValueError(
                "the whole-batch generate() API is open-loop; a "
                "FluidController's SLO window is only charged by the "
                "continuous scheduler — use submit()/run()")
        with self.compute_ctx():
            return self._generate(batch, steps, temperature, top_k, fused)

    def _generate(self, batch, steps, temperature, top_k, fused):
        B, S = batch["tokens"].shape
        prefix = self.cfg.n_prefix_tokens if self.cfg.family == "vlm" else 0
        temp = jnp.zeros((B,), jnp.float32) if temperature is None else \
            jnp.broadcast_to(jnp.asarray(temperature, jnp.float32), (B,))
        if top_k is not None and int(np.max(np.asarray(top_k))) > TOPK_MAX:
            raise ValueError(f"top_k exceeds TOPK_MAX={TOPK_MAX}")
        topk = jnp.zeros((B,), jnp.int32) if top_k is None else \
            jnp.broadcast_to(jnp.asarray(top_k, jnp.int32), (B,))
        wv, av = self._bits()
        if self.mesh is not None:
            wv, av = shd.shard_bits(wv, self.mesh), shd.shard_bits(av,
                                                                   self.mesh)
        batch = shd.shard_batch(batch, self.mesh)
        cache = lm.empty_cache(self.cfg, B, self.max_len)
        if self.mesh is not None:
            cache = jax.device_put(cache, shd.cache_shardings(cache,
                                                              self.mesh))
        logits, cache = self._prefill(self.qparams, batch, cache, wv, av)
        keys = self._split_key(steps)
        tok = self._sample_first(logits, keys[0], temp, topk)[:, None]
        t = jnp.full((B,), S + prefix, jnp.int32)
        if fused:
            _, _, cache, toks = self._decode_scan(
                self.qparams, tok, t, cache, wv, av, temp, topk,
                keys[1:steps])
            out = jnp.concatenate([tok, toks], axis=1)
        else:
            out = [tok]
            for i in range(steps - 1):
                tok, t, cache, _ = self._decode_one(
                    self.qparams, tok, t, cache, wv, av, temp, topk,
                    keys[1 + i])
                out.append(tok)
            out = jnp.concatenate(out, axis=1)
        self.stats.tokens += B * steps
        return out

    # ------------------------------------------------------------------
    # Continuous-batching API
    # ------------------------------------------------------------------

    def submit(self, prompt, *, max_new_tokens: int = 16,
               budget_s: Optional[float] = None, temperature: float = 0.0,
               top_k: int = 0, prefix=None,
               rep_key: Optional[int] = None,
               draft_k: Optional[int] = None) -> int:
        """Enqueue a request; returns its id.  ``budget_s`` caps this
        request's precision configuration (None = loosest/most accurate;
        under a FluidController the closed loop may tighten it further).
        vlm models require ``prefix`` (n_prefix_tokens, d_model).
        ``rep_key`` threads a traffic repetition key to the prefix-cache
        tier (hits are content-keyed either way; the key feeds the
        repetition-aware eviction value).  ``draft_k`` overrides the
        speculative draft depth for this request (0 = vanilla decode;
        None = the engine/controller decides)."""
        if self.cfg.family not in lm.RAGGED_PREFILL_FAMILIES:
            raise NotImplementedError(
                f"the continuous-batching API needs ragged prefill; family "
                f"{self.cfg.family!r} serves via generate() only "
                f"(supported: {lm.RAGGED_PREFILL_FAMILIES})")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if not 1 <= prompt.shape[0] <= self.prefill_len:
            raise ValueError(f"prompt length {prompt.shape[0]} not in "
                             f"[1, {self.prefill_len}]")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens={max_new_tokens} must be >= 1")
        prefix_len = (self.cfg.n_prefix_tokens
                      if self.cfg.family == "vlm" else 0)
        if (prefix_len + self.prefill_len + max_new_tokens > self.max_len
                and not self.cfg.sliding_window):
            raise ValueError("prefix + prefill_len + max_new_tokens "
                             "exceeds max_len (KV ring would wrap)")
        if top_k > TOPK_MAX:
            raise ValueError(f"top_k={top_k} exceeds TOPK_MAX={TOPK_MAX}")
        if draft_k is not None and not 0 <= draft_k <= SPEC_K_MAX:
            raise ValueError(f"draft_k={draft_k} not in [0, {SPEC_K_MAX}]")
        # speculative rounds write up to SPEC_K_MAX positions past the
        # accepted point before rollback — the KV ring must never wrap
        # under them (wrapped slots would expose stale-lap entries to
        # the chunked verify).  Enforced whenever this request COULD
        # draft: an explicit draft_k > 0, or a spec-enabled engine whose
        # controller may pick k > 0 at admission time.
        spec_possible = (draft_k or 0) > 0 or (
            draft_k is None and self.spec_k is not None
            and (self.spec_k > 0
                 or isinstance(self.controller, FluidController)))
        if spec_possible:
            if self.cfg.sliding_window:
                raise ValueError(
                    "speculative decoding needs a non-wrapping KV ring; "
                    "sliding_window requests must submit draft_k=0")
            if self.cfg.family not in lm.SPEC_CHUNK_FAMILIES:
                raise ValueError(
                    f"speculative decoding unsupported for family "
                    f"{self.cfg.family!r} "
                    f"(supported: {lm.SPEC_CHUNK_FAMILIES})")
            if (prefix_len + self.prefill_len + max_new_tokens
                    + SPEC_K_MAX > self.max_len):
                raise ValueError(
                    "prefix + prefill_len + max_new_tokens + SPEC_K_MAX "
                    "exceeds max_len (a speculative round could wrap the "
                    "KV ring); submit draft_k=0 or shrink the request")
        if self.cfg.family == "vlm":
            if prefix is None:
                raise ValueError("vlm requests need a prefix "
                                 "(n_prefix_tokens, d_model)")
            prefix = np.asarray(prefix, np.float32)
            if prefix.shape != (self.cfg.n_prefix_tokens, self.cfg.d_model):
                raise ValueError(f"prefix shape {prefix.shape} != "
                                 f"({self.cfg.n_prefix_tokens}, "
                                 f"{self.cfg.d_model})")
        rid = self.next_rid()
        req = Request(rid, prompt, max_new_tokens,
                      None if budget_s is None else float(budget_s),
                      float(temperature), int(top_k), prefix=prefix,
                      rep_key=rep_key, draft_k=draft_k)
        record = RequestStats(
            rid=rid,
            budget_s=(float(budget_s) if budget_s is not None
                      else UNCONSTRAINED_BUDGET),
            prompt_len=int(prompt.shape[0]), submitted_s=time.time())
        est_scale = 1.0
        if self._cacheable(req):
            # admission planner sees the predicted hit: the modeled EDP
            # is discounted by the predicted cached fraction, so likely
            # hits admit earlier — they really are cheaper to serve
            total = prompt.shape[0] + max_new_tokens
            est_scale = max(total - self.prefix_cache.peek(prompt),
                            1) / total
        return self.new_record(record, req, budget_s, est_scale=est_scale)

    def _ensure_pool(self) -> lm.CachePool:
        if self.pool is None:
            shardings = None
            if self.mesh is not None:
                proto = lm.empty_cache(self.cfg, self.n_slots, self.max_len)
                shardings = shd.cache_shardings(proto, self.mesh)
            self.pool = lm.CachePool(self.cfg, self.n_slots, self.max_len,
                                     shardings=shardings)
        return self.pool

    def _cacheable(self, req: Request) -> bool:
        return (self.prefix_cache is not None and req.prefix is None
                and req.prompt.shape[0] <= self._cache_sc)

    def _admit(self) -> List[int]:
        """Move queued requests into free pool slots, in the runtime's
        EDP-aware, starvation-free admission order.  With a prefix
        cache, each admission consults the tier before prefilling: a
        full hit installs the cached row and reuses its stored logits
        (prefill skipped entirely), a partial hit installs the shared
        prefix and extends the remainder through the decode path, and a
        miss prefills fresh and stores/refreshes the entry.  Only the
        miss fraction is charged against a FluidController's window."""
        pool = self._ensure_pool()
        admitted = []
        while self.queued and pool.free_slots:
            req: Request = self.next_admission()
            slot = pool.alloc()
            S = req.prompt.shape[0]
            record = self.requests[req.rid]
            planned = S + req.max_new_tokens
            hit = wv_np = av_np = None
            # resolve the effective budget HOST-side first: the prefix
            # cache's precision gate and the speculative plan's pricing
            # both need the bits before any charging
            eff = self.admission_budget(req.budget_s)
            if self._cacheable(req):
                wv_np, av_np = self.host_bits(eff)
                hit = self.prefix_cache.lookup(
                    req.prompt, wv_np, av_np, rep_key=req.rep_key)
            cached = hit.keep if hit is not None else 0
            # speculative plan: charge draft + verify pricing for the
            # planned rounds at admission (full-accept plan; the honest
            # per-round actuals reconcile at finish)
            k_req = self._resolve_draft_k(req)
            spec = None
            if k_req > 0 and req.max_new_tokens > 1:
                swv, sav = self.host_bits(eff)
                spec = (k_req, self._draft_pricing(),
                        self.price_verify_bits(swv, sav, k_req + 1),
                        -(-(req.max_new_tokens - 1) // (k_req + 1)),
                        req.max_new_tokens - 1)
            else:
                k_req = 0
            wv, av = self.admit_record(record, req.budget_s, planned,
                                       eff=eff,
                                       charge_units=planned - cached,
                                       spec=spec)
            if hit is not None:
                record.cached_units = cached
                record.cache_hit = "full" if hit.full else "partial"
                record.cached_cost = self.price_bits(hit.entry.wbits,
                                                     hit.entry.abits)
                record.cached_mean_wbits = float(
                    np.mean(hit.entry.wbits))
                self.prefix_cache.ledger.prefill_edp_saved_js += \
                    record.prefill_edp_saved_js
            tokens = np.zeros((1, self.prefill_len), np.int32)
            tokens[0, :S] = req.prompt
            if hit is not None and hit.full:
                # full hit: the cached row IS the prefill output at the
                # entry's bits — install it and reuse its stored logits
                pool.install_prefix(hit.entry.row_cache, slot, S)
                logits = hit.entry.logits
                prefix_len = 0
            elif hit is not None:
                # partial hit: install the shared prefix, extend the
                # rest through the compiled decode-extension program
                logits, row_cache = self._extend_row(
                    self.qparams, jnp.asarray(tokens),
                    hit.entry.row_cache, jnp.asarray(cached, jnp.int32),
                    jnp.asarray(S - cached, jnp.int32), wv, av)
                pool.write_row(row_cache, slot, S)
                prefix_len = 0
                # refresh only when precision-pure: the extended row
                # mixes entry bits (prefix) with resolved bits (tail)
                # unless they match
                if (np.array_equal(hit.entry.wbits, wv_np)
                        and np.array_equal(hit.entry.abits, av_np)):
                    self.prefix_cache.store(
                        req.prompt, row_cache, logits, wv_np, av_np,
                        record.ap_cost, rep_key=req.rep_key)
            else:
                extra = (() if req.prefix is None
                         else (jnp.asarray(req.prefix[None]),))
                logits, row_cache = self._prefill_row(
                    self.qparams, jnp.asarray(tokens),
                    jnp.asarray([S], jnp.int32), wv, av, *extra)
                prefix_len = (self.cfg.n_prefix_tokens
                              if self.cfg.family == "vlm" else 0)
                pool.write_row(row_cache, slot, S + prefix_len)
                if wv_np is not None:   # cacheable miss: store/refresh
                    self.prefix_cache.store(
                        req.prompt, row_cache, logits, wv_np, av_np,
                        record.ap_cost, rep_key=req.rep_key)
            key = self._split_key(1)[0]
            first = self._sample_first(
                logits, key, jnp.asarray([req.temperature], jnp.float32),
                jnp.asarray([req.top_k], jnp.int32))
            # the unavoidable per-admission sync (eos/stream bookkeeping
            # needs the sampled token on the host) — exactly one transfer
            first0 = int(jax.device_get(first)[0])
            record.slot = slot
            record.tokens.append(first0)
            self.stats.tokens += 1
            self.slots.occupy(slot, req.rid, tok=first0,
                              t=S + prefix_len, budget=record.budget_s,
                              temp=req.temperature, topk=req.top_k,
                              remaining=req.max_new_tokens - 1, k=k_req)
            admitted.append(req.rid)
            if self.slots["remaining"][slot] <= 0 or (
                    self.eos_id is not None
                    and first0 == self.eos_id):
                self._finish(slot)
        return admitted

    def _finish(self, slot: int) -> None:
        rid = int(self.slots.rid[slot])
        self.finish_record(rid)
        self.slots.release(slot)
        self.pool.free(slot)
        self._just_finished.append(rid)

    def _has_active(self) -> bool:
        return bool(self.slots.active.any())

    def _active_count(self) -> int:
        return int(self.slots.active.sum())

    def _can_admit(self) -> bool:
        return self.n_slots >= 1

    def step(self) -> List[int]:
        """One scheduler tick: admit into free slots, decode one block,
        harvest tokens, retire finished requests.  Returns the rids that
        completed during this tick."""
        with self.compute_ctx():
            return self._step()

    def _step(self) -> List[int]:
        self.age_queue()
        self._admit()
        slots = self.slots
        active = slots.active
        if active.any():
            # a round can accept at most remaining - 1 drafts (the +1
            # verified token must not overshoot max_new_tokens), so a
            # batch whose every row is clamped to 0 takes the vanilla
            # scan-fused block — speculation degrades to today's path
            k_eff = np.where(
                active, np.minimum(slots["k"], slots["remaining"] - 1),
                0).astype(np.int64)
            if k_eff.max() > 0:
                self._spec_round(active, k_eff)
            else:
                self._decode_tick(active)
        done = self._just_finished
        self._just_finished = []
        return done

    def _batch_bits(self):
        # submit() guarantees a RAGGED_PREFILL_FAMILIES family, all of
        # which support per-row bits — so budgets are always per-slot
        # (effective budgets were frozen at admission: a request's
        # configuration is stable for its lifetime even under the
        # closed-loop controller)
        budgets = shd.shard_budgets(
            jnp.asarray(self.slots["budget"], jnp.float32), self.mesh)  # (B,)
        wv, av = self.controller.resolve(budgets)
        if self.mesh is not None:
            wv, av = shd.shard_bits(wv, self.mesh), shd.shard_bits(av,
                                                                   self.mesh)
        return wv, av

    def _decode_tick(self, active) -> None:
        """Vanilla tick: one scan-fused decode block, per-row bits."""
        pool = self.pool
        slots = self.slots
        wv, av = self._batch_bits()
        keys = self._split_key(self.decode_block)
        tok = jnp.asarray(slots["tok"][:, None], jnp.int32)
        t = jnp.asarray(slots["t"], jnp.int32)
        temp = jnp.asarray(slots["temp"], jnp.float32)
        topk = jnp.asarray(slots["topk"], jnp.int32)
        decode = (self._decode_scan_sh if self._decode_scan_sh is not None
                  else self._decode_scan)
        tok, t, pool.cache, toks = decode(
            self.qparams, tok, t, pool.cache, wv, av, temp, topk, keys)
        # ONE coalesced device->host transfer per tick
        tok_h, toks_h = jax.device_get((tok, toks))
        slots["tok"][:] = tok_h[:, 0].astype(np.int64)
        slots["t"][:] += self.decode_block
        for slot in np.nonzero(active)[0]:
            rid = int(slots.rid[slot])
            st = self.requests[rid]
            take = int(min(slots["remaining"][slot], self.decode_block))
            new = toks_h[slot, :take].tolist()
            if self.eos_id is not None and self.eos_id in new:
                new = new[:new.index(self.eos_id) + 1]
            st.tokens.extend(int(x) for x in new)
            self.stats.tokens += len(new)
            slots["remaining"][slot] -= take
            hit_eos = (self.eos_id is not None and new
                       and new[-1] == self.eos_id)
            if slots["remaining"][slot] <= 0 or hit_eos:
                self._finish(slot)

    def _spec_round(self, active, k_eff_h) -> None:
        """One speculative round for the whole batch: draft SPEC_K_MAX
        tokens per row at the LOW draft bits, verify the current token +
        all drafts in ONE (SPEC_K_MAX + 1)-wide chunked pass at each
        row's own target bits, deliver the longest accepted prefix + 1
        tokens, and mask the rejected KV entries
        (:meth:`repro.models.lm.CachePool.rollback`).  Rows with
        k_eff == 0 ride along and deliver exactly their one verified
        (target-bits) token — greedy output is bit-identical to the
        vanilla path either way."""
        pool = self.pool
        slots = self.slots
        wv, av = self._batch_bits()
        dwv, dav = self._draft_bits()
        keys = self._split_key(SPEC_K_MAX + 2)
        tok = jnp.asarray(slots["tok"][:, None], jnp.int32)
        t = jnp.asarray(slots["t"], jnp.int32)
        temp = jnp.asarray(slots["temp"], jnp.float32)
        topk = jnp.asarray(slots["topk"], jnp.int32)
        k_eff = jnp.asarray(k_eff_h, jnp.int32)
        draft_toks, draft_probs, pool.cache = self._draft(
            self.qparams, tok, t, pool.cache, dwv, dav, temp, topk,
            keys[:SPEC_K_MAX])
        nxt, t_next, emitted, count, keep, pool.cache = self._verify(
            self.qparams, tok, draft_toks, draft_probs, t, pool.cache,
            wv, av, k_eff, temp, topk, keys[SPEC_K_MAX],
            keys[SPEC_K_MAX + 1])
        pool.rollback(keep)
        # ONE coalesced device->host transfer per round
        nxt_h, t_next_h, emitted_h, count_h = jax.device_get(
            (nxt, t_next, emitted, count))
        slots["tok"][:] = nxt_h.astype(np.int64)
        slots["t"][:] = t_next_h.astype(np.int64)
        for slot in np.nonzero(active)[0]:
            rid = int(slots.rid[slot])
            st = self.requests[rid]
            take = int(count_h[slot])           # a + 1 <= remaining
            new = emitted_h[slot, :take].tolist()
            if self.eos_id is not None and self.eos_id in new:
                new = new[:new.index(self.eos_id) + 1]
            st.tokens.extend(int(x) for x in new)
            self.stats.tokens += len(new)
            slots["remaining"][slot] -= take
            k_req = int(slots["k"][slot])
            if k_req > 0:
                # honest per-round actuals at the REQUEST's chosen depth
                # (clamped tail rounds still run/charge the full-width
                # chunk; acceptance just can't use the tail)
                st.spec_rounds += 1
                st.draft_units += k_req
                st.verify_units += k_req + 1
                st.accepted_units += take - 1
                st.spec_tokens += len(new)
                st.draft_wbits = self._draft_wbits_f
                if isinstance(self.controller, FluidController):
                    # close the draft-bit loop: this round's accept rate
                    # (accepted drafts over drafted) feeds the EMA that
                    # may shift the NEXT round's draft config
                    self.controller.observe_accept((take - 1) / k_req)
            hit_eos = (self.eos_id is not None and new
                       and new[-1] == self.eos_id)
            if slots["remaining"][slot] <= 0 or hit_eos:
                self._finish(slot)


def _default_policy() -> PrecisionPolicy:
    from repro.core import policy as pol
    return pol.fixed(8)
