"""Batched serving engine with runtime bit fluidity.

One compiled prefill + one compiled decode program serve every precision
configuration: the per-layer bit vectors are *inputs*, selected per batch
by a :class:`repro.core.policy.BudgetController` from a latency budget —
the TPU realization of the paper's §V.B dynamic mixed-precision claim
("switching between the three mixed-precision configurations dynamically,
as imposed by the changing run-time resource requirements").

The engine is deliberately simple (static batch, greedy sampling): the
interesting part is that ``set_budget()`` between batches changes cost/
accuracy *without touching compiled code* — tests assert zero retraces.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro import dist
from repro.core.policy import BudgetController, PrecisionPolicy
from repro.dist import sharding as shd
from repro.models import lm


@dataclasses.dataclass
class ServeStats:
    prefill_traces: int = 0
    decode_traces: int = 0
    tokens: int = 0


class ServeEngine:
    def __init__(self, cfg, qparams, *, max_len: int = 256,
                 controller: Optional[BudgetController] = None,
                 policy: Optional[PrecisionPolicy] = None,
                 mesh=None):
        self.cfg = cfg
        self.mesh = mesh if mesh is not None else dist.active_mesh()
        if self.mesh is not None:       # place serve weights once, sharded
            qparams = jax.device_put(
                qparams, shd.param_shardings(qparams, self.mesh))
        self.qparams = qparams
        self.max_len = max_len
        n = lm.n_bit_slots(cfg)
        if controller is not None:
            self.controller = controller
        else:
            pol = policy or _default_policy()
            self.controller = BudgetController(
                {pol.name: pol}, {pol.name: 0.0}, n)
        self.budget_s = jnp.asarray(1e9, jnp.float32)
        self.stats = ServeStats()

        def _prefill(q, batch, cache, wv, av):
            self.stats.prefill_traces += 1
            return lm.prefill(q, batch, cfg, wv, av, cache)

        def _decode(q, tok, t, cache, wv, av):
            self.stats.decode_traces += 1
            return lm.decode_step(q, tok, t, cache, cfg, wv, av)

        self._prefill = jax.jit(_prefill, donate_argnums=(2,))
        self._decode = jax.jit(_decode, donate_argnums=(3,))

    def set_budget(self, seconds: float) -> None:
        """Runtime knob: tightens/loosens the per-batch latency budget.
        Changes which precision config the controller resolves — pure
        data, no recompilation."""
        self.budget_s = jnp.asarray(seconds, jnp.float32)

    def _bits(self):
        return self.controller.resolve(self.budget_s)

    def _mesh_ctx(self):
        return (dist.use_mesh(self.mesh) if self.mesh is not None
                else contextlib.nullcontext())

    def generate(self, batch: Dict[str, jnp.ndarray], steps: int
                 ) -> jnp.ndarray:
        """Greedy generation; returns (B, steps) generated ids."""
        with self._mesh_ctx():
            return self._generate(batch, steps)

    def _generate(self, batch: Dict[str, jnp.ndarray], steps: int
                  ) -> jnp.ndarray:
        B, S = batch["tokens"].shape
        prefix = self.cfg.n_prefix_tokens if self.cfg.family == "vlm" else 0
        wv, av = self._bits()
        batch = shd.shard_batch(batch, self.mesh)
        cache = lm.empty_cache(self.cfg, B, self.max_len)
        if self.mesh is not None:
            cache = jax.device_put(cache, shd.cache_shardings(cache,
                                                              self.mesh))
        logits, cache = self._prefill(self.qparams, batch, cache, wv, av)
        out = []
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        t = S + prefix
        for i in range(steps):
            out.append(tok)
            wv, av = self._bits()
            logits, cache = self._decode(self.qparams, tok,
                                         jnp.asarray(t + i), cache, wv, av)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            self.stats.tokens += B
        return jnp.concatenate(out, axis=1)


def _default_policy() -> PrecisionPolicy:
    from repro.core import policy as pol
    return pol.fixed(8)
