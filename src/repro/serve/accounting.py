"""Workload-agnostic serving accounting (DESIGN.md §8).

One cost vocabulary for every serve workload: :class:`RuntimeStats`
counts compiled-program traces (the zero-retrace proof) and engine-wide
totals; :class:`CostRecord` is the single per-request record both the LM
engine (:class:`RequestStats`) and the CNN engine (:class:`ImageStats`)
specialize — each request carries its resolved precision and the AP cost
of that precision priced through the paper's calibrated model, so
latency/energy/EDP read identically across workloads and aggregate with
:func:`aggregate`; :class:`BitVectorPricer` is the shared cached pricer
(vector and one-pass matrix forms) whose charges also drive the
closed-loop :class:`repro.core.policy.FluidController`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.apsim import metrics as apm


class RuntimeStats:
    """Engine-wide serving counters; trace counts prove zero-retrace.

    Compiled programs are counted generically: an engine calls
    ``stats.trace("prefill")`` inside the traced function, and readers
    use the derived ``stats.prefill_traces`` / ``decode_traces`` /
    ``forward_traces`` attributes — any ``<program>_traces`` name reads
    the counter for ``<program>`` (0 if it never traced).
    """

    def __init__(self) -> None:
        self.traces: Dict[str, int] = {}
        self.tokens = 0                 # LM: tokens sampled
        self.admitted = 0               # LM: requests admitted into slots
        self.completed = 0              # LM: requests retired
        self.batches = 0                # CNN: serve() calls
        self.images = 0                 # CNN: real (unpadded) images served
        self.unserved = 0               # requests left pending at run() exit
        self.ticks = 0                  # scheduler ticks recorded
        self.queue_depth: List[int] = []   # queued requests after each tick
        self.active_depth: List[int] = []  # occupied slots after each tick

    def trace(self, program: str) -> None:
        self.traces[program] = self.traces.get(program, 0) + 1

    def record_tick(self, queued: int, active: int) -> None:
        """One scheduler tick's queue instrumentation (the traffic
        harness's queue-depth-over-time series reads these)."""
        self.ticks += 1
        self.queue_depth.append(int(queued))
        self.active_depth.append(int(active))

    def __getattr__(self, name: str) -> int:
        if name.endswith("_traces"):
            return self.__dict__.get("traces", {}).get(name[:-7], 0)
        raise AttributeError(name)

    def __repr__(self) -> str:          # pragma: no cover - debug aid
        return (f"RuntimeStats(traces={self.traces}, tokens={self.tokens}, "
                f"admitted={self.admitted}, completed={self.completed}, "
                f"batches={self.batches}, images={self.images})")


@dataclasses.dataclass
class CostRecord:
    """Per-request serving record shared by every workload.

    Besides wall-clock timing, each request carries its *priced* AP
    cost: at admission the resolved per-layer bit vector is pushed
    through ``apsim.metrics`` (the paper's calibrated cycle/energy
    model), so every request reports the latency/energy/EDP it would
    cost on the BF-IMNA hardware at its own precision — the Table VII
    accuracy-vs-EDP trade-off, live per request.  ``ap_cost`` prices ONE
    :meth:`ap_units` unit (LM: one token; CNN: one inference); derived
    totals scale by the units the request actually processed.
    """
    rid: int
    budget_s: float                     # effective budget (axis units)
    mean_wbits: float = 0.0             # realized per-layer weight bits
    ap_cost: Optional[apm.BitVectorCost] = None   # per-layer breakdown
    submitted_s: float = 0.0
    finished_s: float = 0.0
    done: bool = False
    planned_units: int = 1              # units charged at admission (the
                                        # runtime reconciles vs ap_units
                                        # when the request finishes)
    # prefix-cache hit/miss split (DESIGN.md §10): units served from the
    # cross-request cache are NOT recomputed, so they drop out of
    # ap_units (and hence energy/EDP) — the counterfactual saving reads
    # from prefill_edp_saved_js.  Under the ``repriced`` hit policy the
    # cached precision/cost is recorded alongside, keeping the ledger
    # honest about which bits actually produced the cached rows.
    cached_units: int = 0               # prompt units served from cache
    cache_hit: str = ""                 # "" | "full" | "partial"
    cached_cost: Optional[apm.BitVectorCost] = None
    cached_mean_wbits: float = 0.0
    # scheduler-tick timing (deterministic, unlike wall clock): set by the
    # runtime when requests arrive/admit/finish inside a ticked run()/replay
    submitted_tick: int = -1
    admitted_tick: int = -1
    finished_tick: int = -1
    # speculative decoding (DESIGN.md §11): draft tokens run at the
    # request's DRAFT bits (``draft_cost`` prices one), verify rounds run
    # one (spec_k+1)-token chunk at its target bits (``verify_cost``
    # prices one round).  Tokens delivered by spec rounds (spec_tokens)
    # are NOT charged at ap_cost — their compute is the drafts plus the
    # chunks, priced honestly below in ap_latency_s / ap_energy_j.
    spec_k: int = 0                     # draft depth chosen at admission
    draft_cost: Optional[apm.BitVectorCost] = None   # one draft token
    verify_cost: Optional[apm.BitVectorCost] = None  # one verify round
    draft_units: int = 0                # draft tokens generated
    verify_units: int = 0               # token positions verified
    accepted_units: int = 0             # draft tokens accepted by verify
    spec_rounds: int = 0                # draft+verify rounds run
    spec_tokens: int = 0                # tokens delivered by spec rounds
    planned_spec_rounds: int = 0        # rounds charged at admission
    planned_spec_tokens: int = 0        # tokens those rounds were planned
                                        # to deliver (full acceptance)
    # placement (DESIGN.md §13): mean replica count of the plan this
    # request's costs were amortized under (0 = no plan — costs are the
    # base single-copy pricing); draft_wbits is the mean weight bits of
    # the DRAFT config the autotuner had selected when this request's
    # rounds ran (0 when it never drafted)
    plan_replicas: float = 0.0
    draft_wbits: float = 0.0

    @property
    def ap_units(self) -> int:
        """How many ``ap_cost`` units this request processed."""
        return 1

    @property
    def latency_s(self) -> float:
        """Wall-clock submit-to-finish latency (0.0 until done)."""
        return max(self.finished_s - self.submitted_s, 0.0) if self.done \
            else 0.0

    @property
    def latency_ticks(self) -> int:
        """Submit-to-finish latency in scheduler ticks (-1 until done or
        outside a ticked run — the traffic harness's deterministic
        latency axis)."""
        if not self.done or self.submitted_tick < 0 or self.finished_tick < 0:
            return -1
        return self.finished_tick - self.submitted_tick

    def _axis_total(self, axis: str, base_units: float, draft_units: int,
                    rounds: int) -> float:
        """Budget-axis cost of ``base_units`` at ap_cost plus a
        speculative component (``draft_units`` draft tokens +
        ``rounds`` verify chunks).  With zero spec terms this is exactly
        :func:`axis_cost` — same float summation order, so non-spec
        charging is bit-identical to the historical path."""
        lat = base_units * self.ap_cost.latency_s
        en = base_units * self.ap_cost.energy_j
        if self.draft_cost is not None and draft_units:
            lat += draft_units * self.draft_cost.latency_s
            en += draft_units * self.draft_cost.energy_j
        if self.verify_cost is not None and rounds:
            lat += rounds * self.verify_cost.latency_s
            en += rounds * self.verify_cost.energy_j
        if axis == "latency":
            return lat
        if axis == "energy":
            return en
        if axis == "edp":
            return en * lat
        raise ValueError(f"unknown budget axis {axis!r}")

    def axis_planned(self, axis: str) -> float:
        """Budget-axis cost charged at admission: planned units at
        ap_cost, with the decode tokens a spec plan covers re-priced as
        planned draft+verify rounds (full acceptance)."""
        if self.ap_cost is None:
            return 0.0
        return self._axis_total(axis,
                                self.planned_units - self.planned_spec_tokens,
                                self.planned_spec_rounds * self.spec_k,
                                self.planned_spec_rounds)

    def axis_actual(self, axis: str) -> float:
        """Budget-axis cost of what this request actually ran: non-spec
        units at ap_cost plus the real draft/verify round counts —
        the reconciliation side of the ledger."""
        if self.ap_cost is None:
            return 0.0
        return self._axis_total(axis, self.ap_units - self.spec_tokens,
                                self.draft_units, self.spec_rounds)

    @property
    def ap_latency_s(self) -> float:
        """Modeled AP latency of every processed unit at this request's
        precision configuration (spec-round units priced as their drafts
        + verify chunks)."""
        if self.ap_cost is None:
            return 0.0
        return self._axis_total("latency", self.ap_units - self.spec_tokens,
                                self.draft_units, self.spec_rounds)

    @property
    def ap_energy_j(self) -> float:
        if self.ap_cost is None:
            return 0.0
        return self._axis_total("energy", self.ap_units - self.spec_tokens,
                                self.draft_units, self.spec_rounds)

    @property
    def edp(self) -> float:
        """Modeled AP energy-delay product (J·s) of the whole request."""
        return self.ap_energy_j * self.ap_latency_s

    @property
    def prefill_edp_js(self) -> float:
        """Modeled EDP actually spent on prefill (LM records override)."""
        return 0.0

    @property
    def prefill_edp_saved_js(self) -> float:
        """Counterfactual prefill EDP avoided by cache hits (LM records
        override; 0 for workloads without a prefill phase)."""
        return 0.0


@dataclasses.dataclass
class RequestStats(CostRecord):
    """LM request record: token stream + per-token AP pricing."""
    prompt_len: int = 0
    slot: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)

    @property
    def processed_tokens(self) -> int:
        """Tokens this request pushed through the model (prompt + new)."""
        return self.prompt_len + self.n_tokens

    @property
    def ap_units(self) -> int:
        """Units the AP actually computed: cached prompt tokens were
        installed from the prefix cache, never recomputed."""
        return self.processed_tokens - self.cached_units

    @property
    def prefill_edp_js(self) -> float:
        """Modeled EDP of the prompt tokens this request re-prefilled
        (prompt minus cache-served tokens, at its own resolved cost)."""
        if self.ap_cost is None:
            return 0.0
        u = self.prompt_len - self.cached_units
        return (u * self.ap_cost.energy_j) * (u * self.ap_cost.latency_s)

    @property
    def prefill_edp_saved_js(self) -> float:
        """Counterfactual: the prefill EDP a cache-less serve of the
        full prompt would have cost, minus what this request spent."""
        if self.ap_cost is None or not self.cached_units:
            return 0.0
        s = self.prompt_len
        full = (s * self.ap_cost.energy_j) * (s * self.ap_cost.latency_s)
        return full - self.prefill_edp_js

    @property
    def ap_cycles_per_token(self) -> float:
        return 0.0 if self.ap_cost is None else self.ap_cost.cycles

    @property
    def ap_energy_per_token_j(self) -> float:
        return 0.0 if self.ap_cost is None else self.ap_cost.energy_j


@dataclasses.dataclass
class ImageStats(CostRecord):
    """CNN image record: resolved bit vectors + one-inference pricing."""
    index: int = -1                     # row inside the batch that served it
    wbits: Tuple[int, ...] = ()
    abits: Tuple[int, ...] = ()

    @property
    def budget(self) -> float:
        return self.budget_s


def axis_cost(cost: apm.BitVectorCost, axis: str, units: int = 1) -> float:
    """One admission's cost on a controller's budget axis (the closed
    loop's feedback signal): modeled AP latency (s), energy (J), or EDP
    (J·s) of ``units`` priced units."""
    lat = units * cost.latency_s
    if axis == "latency":
        return lat
    en = units * cost.energy_j
    if axis == "energy":
        return en
    if axis == "edp":
        return en * lat
    raise ValueError(f"unknown budget axis {axis!r}")


def aggregate(records: Iterable[CostRecord]) -> Dict[str, float]:
    """System-level accounting: sums of the per-request records.

    Workload-agnostic (LM and CNN records mix freely), so a deployment
    serving both reads one ledger; tests pin the invariant that engine
    stats totals equal these per-request sums.
    """
    recs = list(records)
    hits = sum(1 for r in recs if r.cached_units > 0)
    draft = sum(r.draft_units for r in recs)
    accepted = sum(r.accepted_units for r in recs)
    spec_tokens = sum(r.spec_tokens for r in recs)
    planned = sum(1 for r in recs if r.plan_replicas > 0)
    edp_total = sum(r.edp for r in recs)
    units = sum(r.ap_units for r in recs)
    return {
        "requests": len(recs),
        "completed": sum(1 for r in recs if r.done),
        "ap_units": units,
        "ap_latency_s": sum(r.ap_latency_s for r in recs),
        "ap_energy_j": sum(r.ap_energy_j for r in recs),
        "edp": edp_total,
        # prefix-cache tier split (0 / 0.0 when no tier is configured)
        "prefix_hits": hits,
        "prefix_hit_rate": round(hits / len(recs), 4) if recs else 0.0,
        "cached_units": sum(r.cached_units for r in recs),
        "prefill_edp_saved_js": sum(r.prefill_edp_saved_js for r in recs),
        # speculative-decoding split (all 0 when no request drafted):
        # accept_rate is accepted drafts over drafts, the net-EDP view is
        # total modeled EDP over units actually delivered — drafting
        # only wins this ledger when the extra draft energy is outrun by
        # the latency the accepted tokens skip (DESIGN.md §11)
        "spec_draft_units": draft,
        "spec_accepted_units": accepted,
        "spec_verify_units": sum(r.verify_units for r in recs),
        "spec_rounds": sum(r.spec_rounds for r in recs),
        "spec_tokens": spec_tokens,
        "spec_accept_rate": round(accepted / draft, 4) if draft else 0.0,
        # draft-bit autotuning: draft-unit-weighted mean weight bits of
        # the draft configs actually used (0.0 when nothing drafted or
        # the engine predates the autotuner)
        "spec_draft_mean_wbits": round(
            sum(r.draft_wbits * r.draft_units for r in recs) / draft, 4)
        if draft else 0.0,
        # placement-plan split: how many requests were priced under a
        # replication plan, and the mean replica count they saw
        "plan_requests": planned,
        "plan_mean_replicas": round(
            sum(r.plan_replicas for r in recs if r.plan_replicas > 0)
            / planned, 4) if planned else 0.0,
        "edp_per_unit_js": edp_total / units if units else 0.0,
    }


def predict_table(gemms: Sequence[Sequence], configs, *, axis: str = "edp",
                  units: int = 1,
                  head: Optional[Tuple[int, int]] = None,
                  optimism: float = 1.0) -> Dict[str, float]:
    """Build a controller prediction table by PRICING each config.

    Each registered :class:`~repro.core.policy.PrecisionPolicy` is
    expanded over the workload's bit slots, priced through the AP model,
    and converted with the exact :func:`axis_cost` math the runtime
    charges at admission — so predictions and charges cannot drift.
    ``units`` is the planned AP units per request (LM: prompt + max new
    tokens); ``optimism`` scales the table (< 1 = optimistic — the
    closed-loop demos use 0.5 to show the loop correcting for it).
    """
    pricer = BitVectorPricer(gemms, head=head)
    table = {}
    for name, p in configs.items():
        wv, av = p.vectors(len(gemms))
        table[name] = optimism * axis_cost(pricer.price(wv, av), axis,
                                           units)
    return table


class BitVectorPricer:
    """Cached AP pricing of resolved bit vectors and matrices.

    Controllers emit a small static set of vectors, so pricing caches by
    the clamped vector bytes and returns ONE shared
    :class:`~repro.apsim.metrics.BitVectorCost` object per distinct
    vector (callers rely on identity).  Batch admissions go through the
    one-pass :func:`repro.apsim.metrics.price_bit_matrix`.
    """

    def __init__(self, gemms: Sequence[Sequence], *,
                 head: Optional[Tuple[int, int]] = None) -> None:
        self.gemms = tuple(gemms)
        self.head = head
        self._cache: Dict[bytes, apm.BitVectorCost] = {}

    @staticmethod
    def _key(wv: np.ndarray, av: np.ndarray) -> bytes:
        # clamp exactly like the pricing itself, so clamp-equivalent
        # vectors share one cached cost object
        wv = np.clip(wv, 1, 16)
        av = np.clip(av, 1, 16)
        return wv.tobytes() + b"|" + av.tobytes()

    def price(self, wv, av) -> apm.BitVectorCost:
        """AP cycles/energy of one resolved (n_slots,) bit vector pair."""
        wv = np.asarray(wv, np.int64)
        av = np.asarray(av, np.int64)
        key = self._key(wv, av)
        hit = self._cache.get(key)
        if hit is None:
            hit = apm.price_bit_vector(self.gemms, wv.tolist(), av.tolist(),
                                       head=self.head)
            self._cache[key] = hit
        return hit

    def price_verify(self, wv, av, u: int) -> apm.BitVectorCost:
        """AP cost of ONE u-token verify chunk at this bit vector: every
        serve GEMV batches over u token rows (the ``(B·(k+1), K)``
        grouped GEMM), priced through the chunked serve mapping
        (``apsim.metrics.serve_gemv_cost``).  Cached per (vector, u)."""
        if u < 1:
            raise ValueError(f"verify chunk width must be >= 1, got {u}")
        wv = np.asarray(wv, np.int64)
        av = np.asarray(av, np.int64)
        key = self._key(wv, av) + b"|u" + str(int(u)).encode()
        hit = self._cache.get(key)
        if hit is None:
            hit = apm.price_bit_vector(self.gemms, wv.tolist(), av.tolist(),
                                       head=self.head, units=int(u))
            self._cache[key] = hit
        return hit

    def price_matrix(self, wmat, amat) -> List[apm.BitVectorCost]:
        """Price a (B, n_slots) bit matrix; rows share cached objects."""
        wmat = np.asarray(wmat, np.int64)
        amat = np.asarray(amat, np.int64)
        keys = [self._key(wmat[i], amat[i]) for i in range(wmat.shape[0])]
        miss = [i for i, k in enumerate(keys) if k not in self._cache]
        if miss:
            costs = apm.price_bit_matrix(self.gemms, wmat[miss], amat[miss],
                                         head=self.head)
            for i, c in zip(miss, costs):
                self._cache.setdefault(keys[i], c)
        return [self._cache[k] for k in keys]
