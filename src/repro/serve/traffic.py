"""Trace-driven traffic: generator, replay driver, metrics collector
(DESIGN.md §9).

The serving runtime's closed control loop (DESIGN.md §8) had only ever
been exercised on hand-built request lists submitted all-up-front.  This
module is the scenario-diversity backbone the elasticity experiments run
on:

  * :func:`synth_trace` — a SEEDED workload generator.  A pattern
    (Poisson steady-state, diurnal sinusoid, systematic spike) shapes a
    per-tick arrival-rate series; arrivals are Poisson draws against it;
    each arrival is a :class:`TraceRequest` with a workload kind
    (LM/CNN), an architecture drawn from the registered mix, a
    repetition *key* (same key == same payload — the repetition
    coefficient controls the unique-vs-repeated mix, with a
    rich-get-richer key draw so repeats skew Zipf-like), and per-request
    budget/SLO metadata.  Same seed, same trace — bit for bit.
  * :class:`TraceReplayer` — feeds engines from the schedule: arrivals
    enqueue at their timestamped tick (never all-up-front), every engine
    advances lock-step one ``sched_tick`` per tick, CNN arrivals batch
    per tick (spill past ``max_batch`` queues to the next tick), and
    tick-windowed :class:`~repro.core.policy.FluidController` loops are
    advanced once per tick.  Deterministic end to end: latency is
    measured in scheduler ticks, EDP through the analytic AP model.
  * :func:`summarize` — the metrics collector: SLO attainment, p50/p99
    latency (ticks) and EDP, queue depth over time, unserved/starvation
    counts, and mean resolved bits per arrival window.

``benchmarks/traffic_elasticity.py`` drives the spike-response and
hourly-elasticity experiments on top; ``launch/serve.py --trace`` replays
a pattern through one LM engine via ``ServeRuntime.submit_at``/``run``.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.policy import FluidController
from repro.serve.runtime import UNCONSTRAINED_BUDGET

__all__ = [
    "TraceRequest", "Trace", "TraceReplayer", "TrafficResult",
    "pattern_rates", "synth_trace", "dump_trace", "load_trace",
    "payload_tokens", "payload_image", "result_from_runtime", "summarize",
]


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One timestamped arrival in a synthesized trace."""
    t: int                              # arrival tick
    workload: str                       # "lm" | "cnn"
    arch: str                           # config (lm) / network (cnn) name
    key: int                            # repetition group: same key ==
                                        # same deterministic payload
    prompt_len: int = 0                 # lm payload shape
    max_new_tokens: int = 0
    budget: Optional[float] = None      # per-request budget (axis units);
                                        # closed-loop replays may ignore it
    slo_edp: Optional[float] = None     # per-request EDP SLO (attainment
                                        # metadata, J*s)


@dataclasses.dataclass(frozen=True)
class Trace:
    """A seeded, timestamped arrival schedule."""
    pattern: str
    seed: int
    ticks: int
    rates: Tuple[float, ...]            # expected arrivals per tick
    requests: Tuple[TraceRequest, ...]

    @property
    def n_requests(self) -> int:
        return len(self.requests)

    def counts(self) -> np.ndarray:
        """Realized arrivals per tick, (ticks,) int64."""
        c = np.zeros((self.ticks,), np.int64)
        for r in self.requests:
            c[r.t] += 1
        return c

    def arrivals_by_tick(self) -> Dict[int, List[TraceRequest]]:
        by: Dict[int, List[TraceRequest]] = {}
        for r in self.requests:
            by.setdefault(r.t, []).append(r)
        return by


def pattern_rates(pattern: str, ticks: int, rate: float, *,
                  burst_mag: float = 10.0, burst_at: Optional[int] = None,
                  burst_len: int = 4, period: Optional[int] = None,
                  depth: float = 0.9, mmpp_up: float = 0.08,
                  mmpp_down: float = 0.25, seed: int = 0) -> np.ndarray:
    """Expected-arrivals-per-tick series for a traffic pattern.

      * ``poisson`` — flat ``rate``.
      * ``diurnal`` — ``rate * (1 + depth*sin(2*pi*t/period))``: one
        sinusoid cycle per ``period`` ticks (default: one cycle over the
        whole trace), peak at period/4, trough at 3*period/4.
      * ``spike``  — flat ``rate`` except a systematic burst of
        ``burst_mag * rate`` for ``burst_len`` ticks starting at
        ``burst_at`` (default: one third in).
      * ``mmpp``   — two-state Markov-modulated Poisson process: a
        hidden state chain switches between a calm state (rate
        ``rate``) and a bursty state (``burst_mag * rate``) with
        per-tick transition probabilities ``mmpp_up`` (calm→bursty) and
        ``mmpp_down`` (bursty→calm) — bursts arrive at random times and
        last a geometric number of ticks (mean ``1/mmpp_down``), unlike
        ``spike``'s one systematic burst.  The chain draws from its own
        seeded stream, so the series is byte-deterministic per seed and
        independent of the arrival draws layered on top.
    """
    t = np.arange(ticks, dtype=np.float64)
    if pattern == "poisson":
        return np.full((ticks,), float(rate))
    if pattern == "diurnal":
        p = float(period if period is not None else ticks)
        return rate * (1.0 + depth * np.sin(2.0 * math.pi * t / p))
    if pattern == "spike":
        at = ticks // 3 if burst_at is None else int(burst_at)
        r = np.full((ticks,), float(rate))
        r[at:at + burst_len] = rate * burst_mag
        return r
    if pattern == "mmpp":
        rng = np.random.default_rng([int(seed), 0x33])
        flips = rng.random(ticks)       # one draw per tick, state-agnostic
        r = np.empty((ticks,), np.float64)
        state = 0
        for i in range(ticks):
            r[i] = rate * (burst_mag if state else 1.0)
            if state == 0:
                state = 1 if flips[i] < mmpp_up else 0
            else:
                state = 0 if flips[i] < mmpp_down else 1
        return r
    raise ValueError(f"unknown traffic pattern {pattern!r} "
                     f"(poisson | diurnal | spike | mmpp | file)")


def synth_trace(pattern: str = "poisson", *, ticks: int = 64,
                rate: float = 1.0, seed: int = 0, repetition: float = 0.0,
                burst_mag: float = 10.0, burst_at: Optional[int] = None,
                burst_len: int = 4, period: Optional[int] = None,
                depth: float = 0.9, mmpp_up: float = 0.08,
                mmpp_down: float = 0.25, cnn_frac: float = 0.0,
                lm_archs: Sequence[str] = ("qwen3_4b",),
                cnn_archs: Sequence[str] = ("resnet18",),
                prompt_len: int = 8, max_new_tokens: int = 8,
                budget: Optional[Sequence[float]] = None,
                slo_edp: Optional[float] = None,
                path: Optional[str] = None) -> Trace:
    """Synthesize a seeded, timestamped arrival schedule.

    Arrivals per tick are Poisson draws against the pattern's rate
    series; ``repetition`` in [0, 1) is the probability that an arrival
    reuses a previously seen key instead of minting a new one (keys are
    drawn from the *occurrence* history, so popular keys get more
    popular — a Zipf-ish repeated mix); ``cnn_frac`` is the probability
    an arrival is a CNN inference instead of an LM generation; per-kind
    architectures draw uniformly from ``lm_archs`` / ``cnn_archs``.
    ``budget`` (cycled over arrivals) and ``slo_edp`` attach per-request
    budget/SLO metadata.  Same arguments + same seed → identical trace.

    ``pattern="file"`` imports a JSONL trace instead (see
    :func:`load_trace`); ``path`` names the file and the synthesis
    knobs are ignored — payloads stay seeded off (seed, key), so a
    replay of an imported trace is just as byte-deterministic.
    """
    if pattern == "file":
        if path is None:
            raise ValueError('synth_trace(pattern="file") needs path=')
        return load_trace(path, ticks=ticks or None, seed=seed)
    if not 0.0 <= repetition < 1.0:
        raise ValueError(f"repetition must be in [0, 1), got {repetition}")
    rates = pattern_rates(pattern, ticks, rate, burst_mag=burst_mag,
                          burst_at=burst_at, burst_len=burst_len,
                          period=period, depth=depth, mmpp_up=mmpp_up,
                          mmpp_down=mmpp_down, seed=seed)
    rng = np.random.default_rng([int(seed), 0xBF])
    counts = rng.poisson(np.maximum(rates, 0.0))
    occurrences: List[int] = []         # every key occurrence (repeat pool)
    next_key = 0
    requests: List[TraceRequest] = []
    i = 0
    for t, c in enumerate(counts):
        for _ in range(int(c)):
            if occurrences and rng.random() < repetition:
                key = occurrences[int(rng.integers(len(occurrences)))]
            else:
                key = next_key
                next_key += 1
            occurrences.append(key)
            is_cnn = cnn_frac > 0.0 and rng.random() < cnn_frac
            archs = cnn_archs if is_cnn else lm_archs
            arch = archs[int(rng.integers(len(archs)))]
            b = None if budget is None else float(budget[i % len(budget)])
            requests.append(TraceRequest(
                t=t, workload="cnn" if is_cnn else "lm", arch=arch, key=key,
                prompt_len=0 if is_cnn else prompt_len,
                max_new_tokens=0 if is_cnn else max_new_tokens,
                budget=b, slo_edp=slo_edp))
            i += 1
    return Trace(pattern=pattern, seed=int(seed), ticks=int(ticks),
                 rates=tuple(float(r) for r in rates),
                 requests=tuple(requests))


def dump_trace(trace: Trace, path: str) -> None:
    """Write a trace as JSONL: one ``{"meta": ...}`` header line with
    the trace-level fields, then one JSON object per arrival.  The
    format round-trips through :func:`load_trace` bit for bit."""
    with open(path, "w") as f:
        f.write(json.dumps({"meta": {
            "pattern": trace.pattern, "seed": trace.seed,
            "ticks": trace.ticks}}) + "\n")
        for r in trace.requests:
            f.write(json.dumps(dataclasses.asdict(r)) + "\n")


def load_trace(path: str, *, ticks: Optional[int] = None,
               seed: int = 0) -> Trace:
    """Import a JSONL trace file (``synth_trace(pattern="file")``).

    One JSON object per line; blank lines and ``#`` comments are
    skipped.  Each arrival needs at least ``t`` (its tick); the other
    :class:`TraceRequest` fields default like :func:`synth_trace`'s
    (workload "lm", arch "qwen3_4b", prompt_len/max_new_tokens 8) and
    ``key`` defaults to a fresh key per line — so a hand-written trace
    of bare ``{"t": ...}`` lines replays.  An optional ``{"meta": ...}``
    header (written by :func:`dump_trace`) restores pattern/seed/ticks.
    Payload bytes stay a pure function of (seed, key), so an imported
    trace replays byte-identically: same file + same seed → same
    prompts, same schedule.  ``ticks`` is a floor on the trace span
    (reporting windows); the realized per-tick arrival counts stand in
    for the rate series."""
    meta: Dict[str, object] = {}
    requests: List[TraceRequest] = []
    next_key = 0
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            obj = json.loads(line)
            if "meta" in obj and "t" not in obj:
                meta = dict(obj["meta"])
                continue
            if "t" not in obj:
                raise ValueError(f"{path}:{ln}: arrival needs a tick "
                                 f"field 't'")
            t = int(obj["t"])
            if t < 0:
                raise ValueError(f"{path}:{ln}: negative tick {t}")
            key = int(obj.get("key", next_key))
            next_key = max(next_key, key) + 1
            workload = str(obj.get("workload", "lm"))
            requests.append(TraceRequest(
                t=t, workload=workload,
                arch=str(obj.get("arch", "qwen3_4b" if workload == "lm"
                                 else "resnet18")),
                key=key,
                prompt_len=int(obj.get("prompt_len",
                                       0 if workload == "cnn" else 8)),
                max_new_tokens=int(obj.get(
                    "max_new_tokens", 0 if workload == "cnn" else 8)),
                budget=(None if obj.get("budget") is None
                        else float(obj["budget"])),
                slo_edp=(None if obj.get("slo_edp") is None
                         else float(obj["slo_edp"]))))
    requests.sort(key=lambda r: r.t)
    span = max((r.t for r in requests), default=-1) + 1
    n_ticks = max(int(meta.get("ticks", 0)), span, int(ticks or 0))
    counts = np.zeros((max(n_ticks, 1),), np.float64)
    for r in requests:
        counts[r.t] += 1.0
    return Trace(pattern=str(meta.get("pattern", "file")),
                 seed=int(meta.get("seed", seed)),
                 ticks=int(max(n_ticks, 1)),
                 rates=tuple(float(c) for c in counts),
                 requests=tuple(requests))


def payload_tokens(trace: Trace, req: TraceRequest,
                   vocab_size: int) -> np.ndarray:
    """Deterministic prompt for an LM request: a function of (trace
    seed, key) only, so repeated keys replay byte-identical prompts
    (the repetition-aware cache tier's future hit signal).  Length draws
    from [max(1, prompt_len//2), prompt_len], also per key."""
    rng = np.random.default_rng([trace.seed, 0x7A, req.key])
    n = int(rng.integers(max(1, req.prompt_len // 2), req.prompt_len + 1))
    return rng.integers(0, vocab_size, (n,), dtype=np.int32)


def payload_image(trace: Trace, req: TraceRequest,
                  shape: Tuple[int, int, int]) -> np.ndarray:
    """Deterministic (H, W, C) image for a CNN request, keyed like
    :func:`payload_tokens`."""
    rng = np.random.default_rng([trace.seed, 0x1C, req.key])
    return rng.standard_normal(shape).astype(np.float32)


@dataclasses.dataclass
class TrafficResult:
    """One replay's outcome: per-request entries + tick series."""
    entries: List[dict]                 # per-request accounting rows
    queue_depth: List[int]              # summed over engines, per tick
    active_depth: List[int]
    ticks: int
    unserved: int

    def report(self, *, window: int = 8) -> dict:
        return summarize(self, window=window)


class TraceReplayer:
    """Replay a :class:`Trace` against serving engines, lock-step.

    ``engines`` maps LM arch names to
    :class:`~repro.serve.engine.ServeEngine` instances; ``cnn_engines``
    maps CNN arch names to
    :class:`~repro.serve.cnn.CNNServeEngine` instances (with
    ``image_hw`` giving each network's input height/width).  Every tick:
    due arrivals enqueue (LM requests into the engine's admission queue,
    CNN requests into a per-engine pending list), every LM engine runs
    one ``sched_tick``, and every CNN engine serves up to ``max_batch``
    pending images in one batched forward (the spill queues on).  Replay
    ends when the schedule and every queue drain, or after ``max_ticks``
    — leftovers are reported as unserved, never silently dropped.

    ``use_budgets=False`` ignores per-request budget metadata (closed-
    loop runs: the SLO window picks precision, not requests).
    """

    def __init__(self, trace: Trace, engines: Optional[Dict[str, object]],
                 *, cnn_engines: Optional[Dict[str, object]] = None,
                 image_hw: int = 8, use_budgets: bool = True,
                 max_ticks: int = 10_000) -> None:
        self.trace = trace
        self.engines = dict(engines or {})
        self.cnn_engines = dict(cnn_engines or {})
        self.image_hw = image_hw
        self.use_budgets = use_budgets
        self.max_ticks = max_ticks
        need_lm = {r.arch for r in trace.requests if r.workload == "lm"}
        need_cnn = {r.arch for r in trace.requests if r.workload == "cnn"}
        if need_lm - set(self.engines):
            raise ValueError(f"trace draws LM archs {sorted(need_lm)} but "
                             f"engines only cover {sorted(self.engines)}")
        if need_cnn - set(self.cnn_engines):
            raise ValueError(f"trace draws CNN archs {sorted(need_cnn)} "
                             f"but cnn_engines only cover "
                             f"{sorted(self.cnn_engines)}")

    def _image_shape(self, eng) -> Tuple[int, int, int]:
        first = next(l for l in eng.layers if l.kind == "conv")
        return (self.image_hw, self.image_hw, first.cin)

    def replay(self) -> TrafficResult:
        by_tick = self.trace.arrivals_by_tick()
        last_arrival = max(by_tick) if by_tick else -1
        lm_meta: Dict[Tuple[str, int], TraceRequest] = {}
        cnn_pending: Dict[str, List[TraceRequest]] = {
            a: [] for a in self.cnn_engines}
        entries: List[dict] = []
        queue_depth: List[int] = []
        active_depth: List[int] = []
        t = 0
        while t < self.max_ticks:
            for req in by_tick.get(t, ()):
                if req.workload == "lm":
                    eng = self.engines[req.arch]
                    rid = eng.submit(
                        payload_tokens(self.trace, req, eng.cfg.vocab_size),
                        max_new_tokens=req.max_new_tokens,
                        budget_s=(req.budget if self.use_budgets else None),
                        rep_key=req.key)
                    lm_meta[(req.arch, rid)] = req
                else:
                    cnn_pending[req.arch].append(req)
            q = a = 0
            for arch, eng in self.engines.items():
                eng.sched_tick()
                q += eng.queued
                a += eng._active_count()
            for arch, eng in self.cnn_engines.items():
                entries.extend(self._serve_cnn_tick(arch, eng,
                                                    cnn_pending[arch], t))
                q += len(cnn_pending[arch])
            queue_depth.append(q)
            active_depth.append(a)
            t += 1
            drained = (t > last_arrival
                       and all(not e.queued and not e._has_active()
                               for e in self.engines.values())
                       and all(not p for p in cnn_pending.values()))
            if drained:
                break
        unserved = 0
        for (arch, rid), req in lm_meta.items():
            eng = self.engines[arch]
            entries.append(self._entry(eng.requests[rid], req, arch,
                                       eng.starvation_ticks))
            unserved += 0 if eng.requests[rid].done else 1
        # arrivals the max_ticks cutoff never even enqueued, plus CNN
        # spill still pending — reported, never silently dropped
        never = [r for tick, reqs in by_tick.items() if tick >= t
                 for r in reqs]
        for req in never + [r for p in cnn_pending.values() for r in p]:
            unserved += 1
            entries.append({
                "rid": -1, "workload": req.workload, "arch": req.arch,
                "key": req.key, "done": False, "submitted_tick": req.t,
                "latency_ticks": -1, "wait_ticks": 0, "edp": 0.0,
                "energy_j": 0.0, "mean_wbits": 0.0, "slo_edp": req.slo_edp,
                "attained": False, "starved": False})
        for arch, pend in cnn_pending.items():
            self.cnn_engines[arch].stats.unserved += len(pend)
        entries.sort(key=lambda e: (e["submitted_tick"], e["workload"],
                                    e["arch"], e["rid"]))
        return TrafficResult(entries=entries, queue_depth=queue_depth,
                             active_depth=active_depth, ticks=t,
                             unserved=unserved)

    def _serve_cnn_tick(self, arch: str, eng,
                        pending: List[TraceRequest], t: int) -> List[dict]:
        if isinstance(eng.controller, FluidController):
            eng.controller.tick()
        if not pending:
            eng.stats.record_tick(0, 0)
            return []
        batch = pending[:eng.max_batch]
        del pending[:len(batch)]
        shape = self._image_shape(eng)
        images = np.stack([payload_image(self.trace, r, shape)
                           for r in batch])
        budgets = ([UNCONSTRAINED_BUDGET if r.budget is None else r.budget
                    for r in batch] if self.use_budgets else None)
        eng._tick = t                   # stamp finished_tick = serve tick
        _, stats = eng.serve(images, budgets)
        eng.stats.record_tick(len(pending), 0)
        out = []
        for req, rec in zip(batch, stats):
            rec.submitted_tick = req.t  # arrival, not serve, tick
            out.append(self._entry(rec, req, arch))
        return out

    @staticmethod
    def _entry(rec, req: TraceRequest, arch: str,
               starvation_ticks: Optional[int] = None) -> dict:
        attained = (rec.done and req.slo_edp is not None
                    and rec.edp <= req.slo_edp)
        wait = (rec.admitted_tick - rec.submitted_tick
                if rec.admitted_tick >= 0 and rec.submitted_tick >= 0
                else 0)
        return {
            "rid": rec.rid, "workload": req.workload, "arch": arch,
            "key": req.key, "done": bool(rec.done),
            "submitted_tick": rec.submitted_tick,
            "latency_ticks": rec.latency_ticks,
            "wait_ticks": wait,
            "edp": rec.edp, "energy_j": rec.ap_energy_j,
            "mean_wbits": rec.mean_wbits, "slo_edp": req.slo_edp,
            "attained": bool(attained),
            "starved": bool(starvation_ticks is not None
                            and wait >= starvation_ticks)}


def result_from_runtime(runtime,
                        meta: Dict[int, TraceRequest]) -> TrafficResult:
    """Collect a :class:`TrafficResult` from ONE runtime after a
    ``submit_at``-driven ``run()`` (the single-engine replay path —
    ``launch/serve.py --trace``).  ``meta`` maps each submitted rid to
    its originating :class:`TraceRequest`; arrivals ``run()`` never
    enqueued are already counted in ``runtime.stats.unserved``."""
    entries = [TraceReplayer._entry(runtime.requests[rid], req, req.arch,
                                    runtime.starvation_ticks)
               for rid, req in meta.items()]
    entries.sort(key=lambda e: (e["submitted_tick"], e["rid"]))
    return TrafficResult(entries=entries,
                         queue_depth=list(runtime.stats.queue_depth),
                         active_depth=list(runtime.stats.active_depth),
                         ticks=int(runtime.stats.ticks),
                         unserved=int(runtime.stats.unserved))


def summarize(result: TrafficResult, *, window: int = 8,
              starvation_ticks: Optional[int] = None) -> dict:
    """The metrics collector: one JSON-ready report per replay.

    Reports SLO attainment (fraction of finished requests whose modeled
    EDP met their per-request ``slo_edp`` metadata — ``None`` when the
    trace carried none), p50/p99 latency in scheduler ticks, p50/p99 and
    total EDP, queue-depth-over-time (series + peak + mean),
    unserved/starvation counts, the mean resolved weight bits per
    ``window``-tick arrival window (the bits-degradation time series the
    elasticity experiments plot), and per-key repetition stats
    (distinct keys, top-key share, theoretical max hit rate) — the
    yardstick the prefix-cache tier's achieved hit rate is judged
    against."""
    entries = result.entries
    fin = [e for e in entries if e["done"]]
    lat = np.asarray([e["latency_ticks"] for e in fin], np.float64)
    edp = np.asarray([e["edp"] for e in fin], np.float64)
    with_slo = [e for e in fin if e["slo_edp"] is not None]
    if starvation_ticks is not None:
        starved = sum(1 for e in fin
                      if e.get("wait_ticks", 0) >= starvation_ticks)
    else:
        starved = sum(1 for e in fin if e.get("starved"))
    n_windows = (result.ticks + window - 1) // window if result.ticks else 0
    bits_w: List[List[float]] = [[] for _ in range(n_windows)]
    arrivals_w = [0] * n_windows
    for e in entries:
        w = min(max(e["submitted_tick"], 0) // window,
                max(n_windows - 1, 0))
        if n_windows:
            arrivals_w[w] += 1
            if e["done"]:
                bits_w[w].append(e["mean_wbits"])
    qd = np.asarray(result.queue_depth, np.float64) \
        if result.queue_depth else np.zeros((0,))
    pct = (lambda a, p: float(np.percentile(a, p)) if a.size else 0.0)
    # per-key repetition stats: sanity-check a trace's repeated mix
    # against the prefix-cache tier's achieved hit rate — a repeat of
    # an already-seen key is the theoretical best case for a hit, so
    # max_hit_rate = (arrivals - distinct keys) / arrivals
    keys = [e["key"] for e in entries if e.get("key") is not None]
    key_counts: Dict[int, int] = {}
    for k in keys:
        key_counts[k] = key_counts.get(k, 0) + 1
    n_keys = len(keys)
    repetition = {
        "arrivals": n_keys,
        "distinct_keys": len(key_counts),
        "top_key_share": (round(max(key_counts.values()) / n_keys, 4)
                          if n_keys else 0.0),
        "max_hit_rate": (round((n_keys - len(key_counts)) / n_keys, 4)
                         if n_keys else 0.0),
    }
    return {
        "requests": len(entries),
        "completed": len(fin),
        "unserved": int(result.unserved),
        "starved": int(starved),
        "ticks": int(result.ticks),
        "window_ticks": int(window),
        "slo_attainment": (round(sum(e["attained"] for e in with_slo)
                                 / len(with_slo), 4) if with_slo else None),
        "p50_latency_ticks": pct(lat, 50),
        "p99_latency_ticks": pct(lat, 99),
        "p50_edp_js": pct(edp, 50),
        "p99_edp_js": pct(edp, 99),
        "total_edp_js": float(edp.sum()),
        "total_energy_j": float(sum(e["energy_j"] for e in fin)),
        "mean_wbits": (round(float(np.mean([e["mean_wbits"]
                                            for e in fin])), 4)
                       if fin else 0.0),
        "queue_depth": {
            "series": [int(x) for x in result.queue_depth],
            "peak": int(qd.max()) if qd.size else 0,
            "mean": round(float(qd.mean()), 3) if qd.size else 0.0,
        },
        "arrivals_per_window": arrivals_w,
        "mean_wbits_per_window": [
            round(float(np.mean(b)), 3) if b else None for b in bits_w],
        "repetition": repetition,
    }
