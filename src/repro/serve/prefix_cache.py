"""Cross-request prefix/KV-cache tier (DESIGN.md §10).

Production traffic is dominated by repeated work — shared system
prompts, re-asked queries — and the traffic generator stamps every
arrival with a repetition key for exactly this reason.  This tier sits
between the admission scheduler and ragged prefill: prompts are keyed
by TOKEN CONTENT (so a hit is decided by what the model would actually
see, not by who sent it), and a hit installs the cached single-row KV
pytree into the requester's :class:`repro.models.lm.CachePool` slot via
the traced-index ``install_prefix`` path instead of re-prefilling.

Keying is **chunked**: besides the full-prompt key, every
``chunk``-aligned prefix of a stored prompt registers a lookup key
pointing at the same entry, so a new prompt that merely *shares a
prefix* with a cached one still hits partially — the engine installs
the shared ``keep`` tokens and extends the remainder through the
decode path (one extra compiled program, traced once).

Hits are **precision-aware** (the bit-fluid wrinkle): each entry
records the per-layer bit vectors it was prefilled at, and
``hit_policy`` (``exact | at_least | repriced``,
``repro.cache.policy``) decides whether those bits may serve the
requester's resolved budget; a gated lookup is a miss that refreshes
the entry at the new precision.  Admission/eviction is delegated to a
:class:`repro.cache.RepetitionAwarePolicy`: value = modeled recompute
EDP (AP pricing of the entry's bits over its tokens) x observed
repetition count, lowest value evicted.

The tier never touches device state itself — it holds prefilled
single-row cache pytrees (which ``CachePool.write_row``/
``install_prefix`` copy, never donate) plus host-side numpy metadata,
and the :class:`~repro.serve.engine.ServeEngine` owns all installs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, List, Optional, Tuple

import numpy as np

from repro.cache.policy import (HIT_POLICIES, CacheLedger,
                                RepetitionAwarePolicy, hit_allowed)

__all__ = ["PrefixCache", "PrefixEntry", "PrefixHit"]


@dataclasses.dataclass
class PrefixEntry:
    """One cached prompt: its KV row plus the precision that made it."""
    key: bytes                          # full-prompt content key
    tokens: np.ndarray                  # (length,) int32 prompt
    length: int
    row_cache: object                   # (L, 1, Sc, ...) prefilled pytree
    logits: object                      # last-token prefill logits (1,1,V)
    wbits: np.ndarray                   # (n_layers,) resolved weight bits
    abits: np.ndarray
    cost: object                        # per-token BitVectorCost at bits
    recompute_edp: float                # modeled EDP of re-prefilling
    count_key: Hashable                 # repetition-count key (policy)
    seq: int                            # insertion sequence (tie-break)
    prefix_keys: List[bytes] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class PrefixHit:
    """A lookup outcome: serve ``keep`` tokens of the prompt from
    ``entry``; ``full`` hits also reuse the entry's stored logits."""
    entry: PrefixEntry
    keep: int
    full: bool


class PrefixCache:
    """Content-keyed, chunked, precision-aware prefix/KV cache."""

    def __init__(self, *, chunk: int = 8, capacity: int = 32,
                 hit_policy: str = "at_least",
                 policy: Optional[RepetitionAwarePolicy] = None) -> None:
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        if hit_policy not in HIT_POLICIES:
            raise ValueError(f"hit_policy must be one of {HIT_POLICIES}, "
                             f"got {hit_policy!r}")
        self.chunk = chunk
        self.hit_policy = hit_policy
        self.policy = policy or RepetitionAwarePolicy(capacity=capacity)
        self.entries: Dict[bytes, PrefixEntry] = {}
        # chunk-aligned prefix key -> (owning entry key, keep length);
        # first registration wins (deterministic), cleaned on eviction
        self._by_prefix: Dict[bytes, Tuple[bytes, int]] = {}
        self.ledger = CacheLedger()
        self._seq = 0

    @staticmethod
    def content_key(tokens) -> bytes:
        return np.ascontiguousarray(tokens, np.int32).tobytes()

    def __len__(self) -> int:
        return len(self.entries)

    # ------------------------------------------------------------------
    # Candidate search (shared by peek/lookup)
    # ------------------------------------------------------------------

    def _candidates(self, tokens: np.ndarray):
        """Yield (entry, keep, full) matches, longest keep first."""
        S = int(tokens.shape[0])
        hit = self._by_prefix.get(self.content_key(tokens))
        if hit is not None:
            entry = self.entries[hit[0]]
            if entry.length == S:
                yield entry, S, True
            elif S > 1:
                # the prompt is a strict prefix of a longer cached one:
                # its KV rows are all cached but the last-token logits
                # are not — recompute just that token via the extend path
                yield entry, S - 1, False
        top = ((S - 1) // self.chunk) * self.chunk
        for keep in range(top, 0, -self.chunk):
            hit = self._by_prefix.get(self.content_key(tokens[:keep]))
            if hit is not None and hit[1] == keep:
                yield self.entries[hit[0]], keep, False

    def peek(self, tokens) -> int:
        """Predicted cached-prefix length for a prompt (0 = miss) —
        no precision gate, no repetition-count side effects.  The
        admission planner uses this to scale a request's modeled EDP."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        for _, keep, _ in self._candidates(tokens):
            return keep
        return 0

    # ------------------------------------------------------------------
    # Lookup / store
    # ------------------------------------------------------------------

    def lookup(self, tokens, want_w, want_a, *,
               rep_key: Optional[Hashable] = None) -> Optional[PrefixHit]:
        """Resolve a prompt against the cache under the requester's
        resolved bits.  Counts the repetition key, takes the longest
        candidate whose precision passes ``hit_policy``, and keeps the
        ledger: every call is exactly one hit, partial hit, or miss."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        S = int(tokens.shape[0])
        self.policy.observe(self._count_key(tokens, rep_key))
        for entry, keep, full in self._candidates(tokens):
            if not hit_allowed(self.hit_policy, entry.wbits, entry.abits,
                               want_w, want_a):
                continue
            if full:
                self.ledger.hits += 1
            else:
                self.ledger.partial_hits += 1
            self.ledger.hit_tokens += keep
            self.ledger.computed_tokens += S - keep
            return PrefixHit(entry=entry, keep=keep, full=full)
        self.ledger.misses += 1
        self.ledger.computed_tokens += S
        return None

    def store(self, tokens, row_cache, logits, wbits, abits, cost, *,
              rep_key: Optional[Hashable] = None) -> bool:
        """Install/refresh the entry for a freshly prefilled prompt.
        ``cost`` is the per-token AP cost at (wbits, abits); the entry's
        cache value is its modeled recompute EDP x repetition count.
        Returns True when the entry is resident afterwards."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        S = int(tokens.shape[0])
        key = self.content_key(tokens)
        recompute_edp = (S * cost.energy_j) * (S * cost.latency_s)
        count_key = self._count_key(tokens, rep_key)
        old = self.entries.get(key)
        if old is None:
            admit, victim = self.policy.plan(
                self.policy.value(count_key, recompute_edp),
                {k: (self.policy.value(e.count_key, e.recompute_edp),
                     e.seq) for k, e in self.entries.items()})
            if not admit:
                self.ledger.rejected += 1
                return False
            if victim is not None:
                self._evict(victim)
        else:
            self.ledger.refreshes += 1
        entry = PrefixEntry(
            key=key, tokens=tokens, length=S, row_cache=row_cache,
            logits=logits, wbits=np.asarray(wbits, np.int64).copy(),
            abits=np.asarray(abits, np.int64).copy(), cost=cost,
            recompute_edp=float(recompute_edp), count_key=count_key,
            seq=(old.seq if old is not None else self._seq))
        if old is None:
            self._seq += 1
        self.entries[key] = entry
        self._by_prefix[key] = (key, S)
        for keep in range(self.chunk, S, self.chunk):
            pk = self.content_key(tokens[:keep])
            if pk not in self._by_prefix:
                self._by_prefix[pk] = (key, keep)
                entry.prefix_keys.append(pk)
        if old is not None:
            entry.prefix_keys = old.prefix_keys
        return True

    def _count_key(self, tokens: np.ndarray,
                   rep_key: Optional[Hashable]) -> Hashable:
        return rep_key if rep_key is not None else self.content_key(tokens)

    def _evict(self, key: bytes) -> None:
        entry = self.entries.pop(key)
        self._by_prefix.pop(key, None)
        for pk in entry.prefix_keys:
            if self._by_prefix.get(pk, (None,))[0] == key:
                del self._by_prefix[pk]
        self.ledger.evictions += 1
