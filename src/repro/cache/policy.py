"""Repetition-aware, AP-cost-priced cache policy (DESIGN.md §10).

Two orthogonal decisions live here, both deliberately free of any KV
plumbing so the prefix-cache tier can swap them independently:

* **Precision gating** (:func:`hit_allowed`): a cached KV entry was
  prefilled at *some* resolved per-layer bit vector, so a hit must
  respect the requester's resolved bit budget.  Three modes:

    - ``exact``    — serve only when the cached bits equal the
      requester's resolved bits (bit-exact replay of what fresh prefill
      would produce).
    - ``at_least`` — serve when the cached bits dominate elementwise
      (cached precision >= requested everywhere: the requester gets at
      least the fidelity it paid for; the ledger still charges the
      requester's own configuration for the miss fraction).
    - ``repriced`` — always serve on a key match; the engine records
      the *cached* precision/cost on the ``CostRecord`` so the ledger
      stays honest about which bits actually produced the KV rows.

* **Admission/eviction** (:class:`RepetitionAwarePolicy`): cache value
  is *modeled recompute EDP x observed repetition count* — the EDP the
  AP model (``apsim.metrics.price_bit_vector``) says re-prefilling the
  entry's tokens at its bits would cost, weighted by how often the
  key has been seen.  The lowest-value resident entry is evicted, and
  a new entry is admitted into a full cache only when its value meets
  the victim's (repetition counts persist across rejections, so a key
  that keeps arriving eventually earns its slot).  Ties break by
  insertion sequence (oldest first) — fully deterministic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, Optional, Tuple

import numpy as np

HIT_POLICIES = ("exact", "at_least", "repriced")


def hit_allowed(policy: str, cached_w: np.ndarray, cached_a: np.ndarray,
                want_w: np.ndarray, want_a: np.ndarray) -> bool:
    """May an entry prefilled at (cached_w, cached_a) serve a request
    that resolved to (want_w, want_a)?  See module docstring."""
    if policy == "repriced":
        return True
    cw, ca = np.asarray(cached_w), np.asarray(cached_a)
    ww, wa = np.asarray(want_w), np.asarray(want_a)
    if policy == "exact":
        return bool(np.array_equal(cw, ww) and np.array_equal(ca, wa))
    if policy == "at_least":
        return bool((cw >= ww).all() and (ca >= wa).all())
    raise ValueError(f"unknown hit policy {policy!r} "
                     f"(choose from {HIT_POLICIES})")


@dataclasses.dataclass
class CacheLedger:
    """The tier's hit/miss ledger.  Invariant (tested): every cacheable
    admission is exactly one lookup, and every lookup is exactly one of
    hit / partial hit / miss — ``hits + partial_hits + misses ==
    lookups == cacheable admissions``."""
    hits: int = 0                   # full-prompt hits (prefill skipped)
    partial_hits: int = 0           # chunk-aligned prefix hits (extended)
    misses: int = 0                 # includes precision-gated refreshes
    refreshes: int = 0              # misses that re-prefilled an existing
                                    # key at a new precision
    evictions: int = 0
    rejected: int = 0               # admissions the value policy declined
    hit_tokens: int = 0             # prompt tokens served from cache
    computed_tokens: int = 0        # prompt tokens actually prefilled
    prefill_edp_saved_js: float = 0.0

    @property
    def lookups(self) -> int:
        return self.hits + self.partial_hits + self.misses

    @property
    def hit_rate(self) -> float:
        n = self.lookups
        return (self.hits + self.partial_hits) / n if n else 0.0

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["lookups"] = self.lookups
        d["hit_rate"] = round(self.hit_rate, 4)
        d["prefill_edp_saved_js"] = float(self.prefill_edp_saved_js)
        return d


class RepetitionAwarePolicy:
    """AP-cost-priced, repetition-aware admission/eviction.

    ``observe(key)`` counts every arrival of a repetition key (threaded
    from the traffic trace, or derived from prompt content); an entry's
    value is ``recompute_edp * count``.  ``plan(...)`` decides whether
    a new entry enters a full cache and which resident entry makes room.
    """

    def __init__(self, capacity: int = 32) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.counts: Dict[Hashable, int] = {}

    def observe(self, key: Hashable) -> int:
        """Count one arrival of ``key``; returns the running count."""
        c = self.counts.get(key, 0) + 1
        self.counts[key] = c
        return c

    def count(self, key: Hashable) -> int:
        return self.counts.get(key, 0)

    def value(self, key: Hashable, recompute_edp: float) -> float:
        """Cache value of an entry: modeled recompute EDP (J*s, from
        the AP pricing of the entry's bits over its tokens) x observed
        repetition count."""
        return float(recompute_edp) * max(self.count(key), 1)

    def plan(self, new_value: float,
             resident: Dict[Hashable, Tuple[float, int]]
             ) -> Tuple[bool, Optional[Hashable]]:
        """Admission decision for a new entry against the resident set
        (``{entry_key: (value, insertion_seq)}`` with values from
        :meth:`value`).  Returns ``(admit, victim_key)``: room left →
        admit outright; full → admit only when the new value meets the
        lowest resident value (that victim is evicted), deterministic
        tie-break by insertion seq (oldest first)."""
        if len(resident) < self.capacity:
            return True, None
        victim = min(resident,
                     key=lambda k: (resident[k][0], resident[k][1]))
        if float(new_value) >= resident[victim][0]:
            return True, victim
        return False, None
