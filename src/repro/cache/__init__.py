"""Cross-request caching policies (DESIGN.md §10).

The serving tier's prefix/KV cache (``repro.serve.prefix_cache``) is
deliberately split from its *policy*: this package owns the questions
"is a cached entry allowed to serve this request?" (precision gating,
:data:`HIT_POLICIES`) and "which entry is worth keeping?"
(:class:`RepetitionAwarePolicy` — admission/eviction priced in AP-cost
terms), so alternative policies can be swapped without touching the KV
plumbing.
"""
from repro.cache.policy import (HIT_POLICIES, CacheLedger,  # noqa: F401
                                RepetitionAwarePolicy, hit_allowed)
