"""The paper's CNN workloads in JAX: conv-as-GEMM (im2col) through the
bit-fluid linear — AlexNet / VGG16 / ResNet18 / ResNet50.

Faithful to BF-IMNA's mapping (§II.C): every convolution lowers to
``im2col`` patches x kernel matrix, executed by the same quantized linear
as the LM stacks, so HAWQ-V3's per-layer bit vectors drive these networks
identically (Table VII reproduction runs ResNet18 through this path).

Shapes are NHWC; reduced image sizes are fine (examples use CIFAR-sized
inputs) — layer structure, not ImageNet resolution, is what the paper's
study needs on CPU.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.apsim.workloads import Layer, NETWORKS
from repro.models import common as cm


def im2col(x: jnp.ndarray, hk: int, wk: int, stride: int, pad: int
           ) -> jnp.ndarray:
    """NHWC -> (N, Ho, Wo, hk*wk*C) patches (the paper's P matrix rows)."""
    patches = jax.lax.conv_general_dilated_patches(
        x, (hk, wk), (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    # conv_general_dilated_patches yields channel-major (C*hk*wk) features;
    # reorder to (hk*wk, C) so weights reshape naturally
    N, Ho, Wo, F = patches.shape
    C = x.shape[-1]
    p = patches.reshape(N, Ho, Wo, C, hk * wk)
    return jnp.moveaxis(p, 3, 4).reshape(N, Ho, Wo, hk * wk * C)


def conv_gemm(p: dict, x: jnp.ndarray, layer: Layer, wbits=8, abits=8
              ) -> jnp.ndarray:
    """x: (N, H, W, Cin) -> (N, Ho, Wo, Cout) via patches @ W."""
    g = layer.groups
    cols = im2col(x, layer.hk, layer.wk, layer.stride, layer.pad)
    if g == 1:
        y = cm.apply_linear(p, cols, wbits, abits)
    else:
        N, Ho, Wo, F = cols.shape
        cin_g = x.shape[-1] // g
        fk = layer.hk * layer.wk * cin_g
        cols_g = cols.reshape(N, Ho, Wo, g, fk)
        w = p["w"].reshape(fk, g, layer.cout // g)
        ys = [cm.apply_linear({"w": w[:, i]}, cols_g[:, :, :, i], wbits, abits)
              for i in range(g)]
        y = jnp.concatenate(ys, axis=-1)
        if "b" in p:
            y = y + p["b"]
    if layer.relu:
        y = jax.nn.relu(y.astype(jnp.float32)).astype(cm.DTYPE)
    return y


def pool2d(x: jnp.ndarray, layer: Layer) -> jnp.ndarray:
    k, s = layer.hk, layer.stride
    if layer.kind == "maxpool":
        return jax.lax.reduce_window(
            x, -jnp.inf if x.dtype == jnp.float32 else jnp.finfo(x.dtype).min,
            jax.lax.max, (1, k, k, 1), (1, s, s, 1), "VALID")
    summed = jax.lax.reduce_window(
        x.astype(jnp.float32), 0.0, jax.lax.add, (1, k, k, 1),
        (1, s, s, 1), "VALID")
    return (summed / (k * k)).astype(x.dtype)


def init_cnn(network: str, key, num_classes: int = 1000,
             image: int = 0) -> Tuple[dict, List[Layer]]:
    """Build params for a paper workload table (optionally rescaled to a
    smaller input image; FC input dims follow the actual spatial size)."""
    layers = NETWORKS[network]()
    if image:
        scale = image / layers[0].hin
        layers = _rescale(layers, image)
    params: dict = {}
    keys = jax.random.split(key, len(layers))
    x_hw, x_c = layers[0].hin, layers[0].cin
    for i, l in enumerate(layers):
        if l.kind == "conv":
            fk = l.hk * l.wk * (l.cin // l.groups)
            # grouped convs store w as (fk, cout) and reshape (fk, g,
            # cout/g) at apply time; bias is always full (cout,)
            params[l.name] = cm.dense_init(keys[i], fk, l.cout, bias=True)
        elif l.kind == "fc":
            params[l.name] = cm.dense_init(keys[i], l.cin, l.cout, bias=True)
    return params, layers


def _rescale(layers: List[Layer], image: int) -> List[Layer]:
    """Shrink spatial dims; keeps channel structure (for CPU examples).

    Residual ``*_down`` convs read the BLOCK input (the height at the
    previous ``add``), not the main path's current height."""
    import dataclasses as dc
    out = []
    h = image
    h_block = image
    for l in layers:
        if l.kind == "conv" and l.name.endswith("_down"):
            hk = min(l.hk, h_block)
            out.append(dc.replace(l, hin=h_block, win=h_block, hk=hk, wk=hk))
        elif l.kind in ("conv", "maxpool", "avgpool"):
            hk = min(l.hk, h)
            nl = dc.replace(l, hin=h, win=h, hk=hk, wk=hk,
                            window=hk * hk if l.kind != "conv" else l.window)
            h = nl.hout
            out.append(nl)
            if l.kind != "conv":
                h_block = h
        elif l.kind == "add":
            out.append(dc.replace(l, hin=h, win=h))
            h_block = h
        elif l.kind == "fc" and out and out[-1].kind in ("conv", "maxpool",
                                                         "avgpool", "add"):
            prev_c = _last_channels(out)
            nl = dc.replace(l, cin=prev_c * h * h)
            out.append(nl)
            h = 1
        else:
            out.append(l)
    return out


def _last_channels(layers: List[Layer]) -> int:
    for l in reversed(layers):
        if l.kind == "conv":
            return l.cout
        if l.kind in ("maxpool", "avgpool", "add"):
            return l.cin
    raise ValueError


def cnn_forward(params: dict, x: jnp.ndarray, layers: List[Layer],
                wvec=None, avec=None) -> jnp.ndarray:
    """End-to-end inference; wvec/avec: per-GEMM-layer bit arrays (the
    HAWQ-V3 Table VII vectors) or None for fp."""
    gi = 0
    residual: Optional[jnp.ndarray] = None
    block_in: Optional[jnp.ndarray] = None
    x = x.astype(cm.DTYPE)
    for l in layers:
        wb = int(wvec[min(gi, len(wvec) - 1)]) if wvec is not None else 16
        ab = int(avec[min(gi, len(avec) - 1)]) if avec is not None else 16
        if l.kind == "conv":
            if block_in is None:
                block_in = x
            if l.name.endswith("_down"):
                residual = conv_gemm(params[l.name], block_in, l, wb, ab)
                gi += 1
                continue
            x = conv_gemm(params[l.name], x, l, wb, ab)
            gi += 1
        elif l.kind in ("maxpool", "avgpool"):
            x = pool2d(x, l)
        elif l.kind == "add":
            skip = residual if residual is not None else block_in
            if skip is not None and skip.shape == x.shape:
                x = x + skip
            x = jax.nn.relu(x.astype(jnp.float32)).astype(cm.DTYPE)
            residual, block_in = None, None
        elif l.kind == "fc":
            if x.ndim == 4:
                x = x.reshape(x.shape[0], -1)
            x = cm.apply_linear(params[l.name], x, wb, ab)
            if l.relu:
                x = jax.nn.relu(x.astype(jnp.float32)).astype(cm.DTYPE)
            gi += 1
    return x.astype(jnp.float32)
