"""The paper's CNN workloads in JAX: conv-as-GEMM (im2col) through the
bit-fluid linear — AlexNet / VGG16 / ResNet18 / ResNet50.

Faithful to BF-IMNA's mapping (§II.C): every convolution lowers to
``im2col`` patches x kernel matrix, executed by the same quantized linear
as the LM stacks, so HAWQ-V3's per-layer bit vectors drive these networks
identically (Table VII reproduction runs ResNet18 through this path).

Two parameter forms, mirroring ``models/common.py`` (DESIGN.md §7):

* **train form** (``init_cnn``): ``{"w", "b"}`` per conv/fc layer —
  ``cnn_forward`` runs fake-quant float math (the QAT / accuracy-proxy
  path, retained as the fidelity oracle).
* **serve form** (``quantize_cnn_params``): each weight matrix is
  quantized ONCE into an int8 container (packed int4 where a serving
  policy makes it eligible — see ``int4_eligible``), and every GEMM
  reaches the kernel dispatch layer through ``ops.serve_linear``.
  Per-layer bits arrive as **traced** ``(n_gemm,)`` vectors — any
  HAWQ-V3 / fixed / per-layer configuration runs in one compiled
  program with zero retrace — or as ``(B, n_gemm)`` per-request
  matrices routed through the bit-grouped batch dispatch.

Grouped convolutions stack per-group containers ``(g, fk, cout/g)`` and
execute as a single batched GEMM (``ops.serve_linear_stacked``; the
fake-quant path vmaps the same stack) instead of a per-group Python loop.

Shapes are NHWC; reduced image sizes are fine (examples use CIFAR-sized
inputs) — layer structure, not ImageNet resolution, is what the paper's
study needs on CPU.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.apsim.workloads import Layer, NETWORKS, gemm_layers
from repro.kernels import ops as kops
from repro.models import common as cm


def im2col(x: jnp.ndarray, hk: int, wk: int, stride: int, pad: int
           ) -> jnp.ndarray:
    """NHWC -> (N, Ho, Wo, hk*wk*C) patches (the paper's P matrix rows)."""
    patches = jax.lax.conv_general_dilated_patches(
        x, (hk, wk), (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    # conv_general_dilated_patches yields channel-major (C*hk*wk) features;
    # reorder to (hk*wk, C) so weights reshape naturally
    N, Ho, Wo, F = patches.shape
    C = x.shape[-1]
    p = patches.reshape(N, Ho, Wo, C, hk * wk)
    return jnp.moveaxis(p, 3, 4).reshape(N, Ho, Wo, hk * wk * C)


def grouped_cols(cols: jnp.ndarray, g: int, taps: int) -> jnp.ndarray:
    """(N, Ho, Wo, taps*C) im2col patches -> (N, Ho, Wo, g, taps*(C/g)).

    im2col features are tap-major / channel-minor; group ``i`` owns the
    channel slice [i*C/g, (i+1)*C/g) of EVERY tap (true grouped-conv
    semantics — ``jax.lax.conv`` with ``feature_group_count``), so the
    split must slice the channel axis, not take contiguous feature runs.
    """
    N, Ho, Wo, F = cols.shape
    cg = F // (taps * g)
    p = cols.reshape(N, Ho, Wo, taps, g, cg)
    return jnp.moveaxis(p, 4, 3).reshape(N, Ho, Wo, g, taps * cg)


def stack_grouped_weight(w: jnp.ndarray, g: int, cout: int) -> jnp.ndarray:
    """Flat (fk, cout) grouped-conv weight -> (g, fk, cout/g) group stack
    (group ``i`` produces the contiguous output-channel run [i*cout/g, ...))."""
    return jnp.moveaxis(w.reshape(w.shape[0], g, cout // g), 1, 0)


def conv_gemm(p: dict, x: jnp.ndarray, layer: Layer, wbits=8, abits=8
              ) -> jnp.ndarray:
    """x: (N, H, W, Cin) -> (N, Ho, Wo, Cout) via patches @ W.

    Dispatches on the parameter form: ``{"w"}`` fake-quant float,
    ``{"q"/"q4", "s"}`` through the kernel layer.  Grouped convs run one
    batched GEMM over the (g, fk, cout/g) stack for both forms.
    """
    g = layer.groups
    cols = im2col(x, layer.hk, layer.wk, layer.stride, layer.pad)
    if g == 1:
        y = cm.apply_linear(p, cols, wbits, abits)
    else:
        N, Ho, Wo, F = cols.shape
        xg = jnp.moveaxis(grouped_cols(cols, g, layer.hk * layer.wk), 3, 0)
        if "w" in p:
            w3 = stack_grouped_weight(p["w"], g, layer.cout)
            y = jax.vmap(lambda w, xr: cm.apply_linear({"w": w}, xr,
                                                       wbits, abits))(w3, xg)
        else:
            y = kops.serve_linear_stacked({"q": p["q"], "s": p["s"]}, xg,
                                          wbits, abits)
        y = jnp.moveaxis(y, 0, 3).reshape(N, Ho, Wo, layer.cout)
        if "b" in p:
            y = (y.astype(jnp.float32) + p["b"].astype(jnp.float32))
        y = y.astype(cm.DTYPE)
    if layer.relu:
        y = jax.nn.relu(y.astype(jnp.float32)).astype(cm.DTYPE)
    return y


def pool2d(x: jnp.ndarray, layer: Layer) -> jnp.ndarray:
    k, s = layer.hk, layer.stride
    if layer.kind == "maxpool":
        if jnp.issubdtype(x.dtype, jnp.integer):
            init = jnp.iinfo(x.dtype).min        # int8 serve activations
        else:
            init = -jnp.inf if x.dtype == jnp.float32 else \
                jnp.finfo(x.dtype).min
        return jax.lax.reduce_window(
            x, jnp.asarray(init, x.dtype), jax.lax.max,
            (1, k, k, 1), (1, s, s, 1), "VALID")
    summed = jax.lax.reduce_window(
        x.astype(jnp.float32), 0.0, jax.lax.add, (1, k, k, 1),
        (1, s, s, 1), "VALID")
    return (summed / (k * k)).astype(x.dtype)


def init_cnn(network: str, key, num_classes: int = 1000,
             image: int = 0) -> Tuple[dict, List[Layer]]:
    """Build params for a paper workload table (optionally rescaled to a
    smaller input image; FC input dims follow the actual spatial size)."""
    layers = NETWORKS[network]()
    if image:
        layers = _rescale(layers, image)
    params: dict = {}
    keys = jax.random.split(key, len(layers))
    for i, l in enumerate(layers):
        if l.kind == "conv":
            fk = l.hk * l.wk * (l.cin // l.groups)
            # grouped convs store w as (fk, cout) and stack to (g, fk,
            # cout/g) at apply/quantize time; bias is always full (cout,)
            params[l.name] = cm.dense_init(keys[i], fk, l.cout, bias=True)
        elif l.kind == "fc":
            params[l.name] = cm.dense_init(keys[i], l.cin, l.cout, bias=True)
    return params, layers


def _shrink_conv_kernel(l: Layer, h: int) -> Tuple[int, int]:
    """(hk, pad) for a conv squeezed to an ``h``-pixel input: kernels
    larger than the image shrink, staying ODD so stride-1 same-padded
    convs keep their spatial size (an even kernel with the original pad
    GROWS the map — the main path then cannot meet its ``_down``
    projection at the residual add)."""
    hk = min(l.hk, h)
    if hk < l.hk and hk % 2 == 0:
        hk = max(hk - 1, 1)
    return hk, min(l.pad, hk // 2)


def _rescale(layers: List[Layer], image: int) -> List[Layer]:
    """Shrink spatial dims; keeps channel structure (for CPU examples).

    Residual ``*_down`` convs read the BLOCK input (the height at the
    previous ``add``), not the main path's current height."""
    import dataclasses as dc
    out = []
    h = image
    h_block = image
    for l in layers:
        if l.kind == "conv" and l.name.endswith("_down"):
            hk, pad = _shrink_conv_kernel(l, h_block)
            out.append(dc.replace(l, hin=h_block, win=h_block, hk=hk, wk=hk,
                                  pad=pad))
        elif l.kind == "conv":
            hk, pad = _shrink_conv_kernel(l, h)
            nl = dc.replace(l, hin=h, win=h, hk=hk, wk=hk, pad=pad)
            h = nl.hout
            out.append(nl)
        elif l.kind in ("maxpool", "avgpool"):
            hk = min(l.hk, h)
            nl = dc.replace(l, hin=h, win=h, hk=hk, wk=hk, window=hk * hk)
            h = nl.hout
            out.append(nl)
            h_block = h
        elif l.kind == "add":
            out.append(dc.replace(l, hin=h, win=h))
            h_block = h
        elif l.kind == "fc" and out and out[-1].kind in ("conv", "maxpool",
                                                         "avgpool", "add"):
            prev_c = _last_channels(out)
            nl = dc.replace(l, cin=prev_c * h * h)
            out.append(nl)
            h = 1
        else:
            out.append(l)
    return out


def _last_channels(layers: List[Layer]) -> int:
    for l in reversed(layers):
        if l.kind == "conv":
            return l.cout
        if l.kind in ("maxpool", "avgpool", "add"):
            return l.cin
    raise ValueError


# ---------------------------------------------------------------------------
# Serve-form parameters
# ---------------------------------------------------------------------------

def quantize_cnn_params(params: dict, layers: Sequence[Layer], *,
                        container: str = "int8",
                        int4_names: Sequence[str] = ()) -> dict:
    """Train-form CNN params -> serve-form containers, once at init.

    Each conv/fc weight becomes ``{"q" int8 (K, N), "s" (1, N) [, "b"]}``
    — or ``{"q4" packed uint8 (K, N/2), ...}`` for layers named in
    ``int4_names`` (see :func:`int4_eligible`).  Grouped convs stack
    per-group containers ``(g, fk, cout/g)`` with per-group scales
    (int8 only — their GEMMs run via ``ops.serve_linear_stacked``).
    """
    qp: dict = {}
    for l in gemm_layers(list(layers)):
        p = params[l.name]
        if l.kind == "conv" and l.groups > 1:
            w3 = stack_grouped_weight(p["w"].astype(jnp.float32),
                                      l.groups, l.cout)
            q = cm.quantize_linear({"w": w3}, "int8")
            if "b" in p:
                q["b"] = p["b"]
            qp[l.name] = q
        else:
            cont = "int4" if l.name in tuple(int4_names) else container
            qp[l.name] = cm.quantize_linear(p, cont)
    return qp


def int4_eligible(layers: Sequence[Layer], wtab) -> Tuple[str, ...]:
    """GEMM-layer names a serving policy set makes packed-int4 eligible.

    ``wtab``: (n_configs, n_gemm) stacked weight-bit tables (e.g.
    ``BudgetController.stacked_tables()[0]``).  A layer may live in an
    int4 container only if EVERY registered configuration runs it at
    <= 4 bits (the container is the fidelity ceiling), it is ungrouped,
    and its output width packs into nibble pairs.
    """
    import numpy as np
    gl = gemm_layers(list(layers))
    wmax = np.max(np.asarray(wtab, np.int64).reshape(-1, len(gl)), axis=0)
    return tuple(l.name for i, l in enumerate(gl)
                 if wmax[i] <= 4 and l.groups == 1 and l.cout % 2 == 0)


def _is_serve_form(params: dict, layers: Sequence[Layer]) -> bool:
    for l in layers:
        if l.kind in ("conv", "fc"):
            return "q" in params[l.name] or "q4" in params[l.name]
    return False


def _check_bits(vec, n_gemm: int, which: str):
    if vec is None:
        return None
    v = jnp.asarray(vec)
    if v.ndim not in (1, 2) or v.shape[-1] != n_gemm:
        raise ValueError(
            f"{which} bit vector has shape {tuple(v.shape)} but the network "
            f"has {n_gemm} GEMM (conv/fc) layers; expand short policy "
            f"tables first (workloads.per_layer_bits or "
            f"PrecisionPolicy.vectors({n_gemm})) — silent clamping would "
            f"misassign per-layer precisions")
    return v


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def cnn_forward(params: dict, x: jnp.ndarray, layers: List[Layer],
                wvec=None, avec=None) -> jnp.ndarray:
    """End-to-end inference; wvec/avec: per-GEMM-layer bit arrays (the
    HAWQ-V3 Table VII vectors) or None for fp.

    Bit vectors must cover the network's GEMM layers exactly:
    ``(n_gemm,)`` shared across the batch, or ``(B, n_gemm)`` per-request
    rows (serve form only — routed through the bit-grouped dispatch).
    With serve-form ``params`` the vectors may be traced arrays: every
    configuration runs in ONE compiled program (zero retrace); bits clamp
    to the int8 container width.  ``None`` means fp (fake-quant identity)
    in train form and container-width execution in serve form.
    """
    n_gemm = sum(1 for l in layers if l.kind in ("conv", "fc"))
    wvec = _check_bits(wvec, n_gemm, "weight")
    avec = _check_bits(avec, n_gemm, "activation")
    serve = _is_serve_form(params, layers)
    if serve:
        # the container holds at most 8 bit planes; >=16 is the fp
        # sentinel, which a quantized container cannot honor
        wvec = jnp.minimum(wvec, 8) if wvec is not None else None
        avec = jnp.minimum(avec, 8) if avec is not None else None
    default = 8 if serve else 16
    gi = 0
    residual: Optional[jnp.ndarray] = None
    block_in: Optional[jnp.ndarray] = None
    x = x.astype(cm.DTYPE)
    for l in layers:
        wb = wvec[..., gi] if wvec is not None else default
        ab = avec[..., gi] if avec is not None else default
        if l.kind == "conv":
            if block_in is None:
                block_in = x
            if l.name.endswith("_down"):
                residual = conv_gemm(params[l.name], block_in, l, wb, ab)
                gi += 1
                continue
            x = conv_gemm(params[l.name], x, l, wb, ab)
            gi += 1
        elif l.kind in ("maxpool", "avgpool"):
            x = pool2d(x, l)
            # a pool ends the residual block: the next conv starts a new
            # block from the POOLED map (a stale block_in would hand the
            # first residual add a pre-pool skip of the wrong shape)
            block_in = None
        elif l.kind == "add":
            skip = residual if residual is not None else block_in
            if skip is None or skip.shape != x.shape:
                raise ValueError(
                    f"residual add {l.name!r}: main path {tuple(x.shape)} "
                    f"vs skip "
                    f"{None if skip is None else tuple(skip.shape)} — "
                    f"block wiring is broken (missing/inconsistent "
                    f"downsample projection)")
            x = x + skip
            x = jax.nn.relu(x.astype(jnp.float32)).astype(cm.DTYPE)
            residual, block_in = None, None
        elif l.kind == "fc":
            if x.ndim == 4:
                x = x.reshape(x.shape[0], -1)
            x = cm.apply_linear(params[l.name], x, wb, ab)
            if l.relu:
                x = jax.nn.relu(x.astype(jnp.float32)).astype(cm.DTYPE)
            gi += 1
    return x.astype(jnp.float32)
