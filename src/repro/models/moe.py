"""Mixture-of-Experts FFN (kimi-k2, moonshot) with expert parallelism.

Capacity-based top-k routing designed to stay memory-sane at 1M-token
global batches: position-in-expert is computed per *choice* (k small
one-hot cumsums of (T, E)), never materializing (T*k, E); dispatch/combine
are scatter-add / gather on an (E, C, d) buffer that shards E over the
``model`` (expert-parallel) axis and C over ``data`` — the sharded
scatter is where XLA emits the token-routing all-to-all.

Per-EXPERT precision: the paper's per-layer granularity maps naturally to
per-expert here (DESIGN.md §4) — ``wbits`` may be a scalar or an (E,)
vector; expert e's GEMMs run at wbits[e].
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro import dist
from repro.core import bitfluid as bf
from repro.kernels import ops as kops
from repro.models import common as cm


def moe_init(key, cfg) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    s = d ** -0.5

    def w(k, shape, sc):
        return (jax.random.normal(k, shape, jnp.float32) * sc).astype(cm.DTYPE)

    p = {
        "router": {"w": w(ks[0], (d, E), s)},
        "experts": {
            "wg": w(ks[1], (E, d, f), s),
            "wu": w(ks[2], (E, d, f), s),
            "wd": w(ks[3], (E, f, d), f ** -0.5),
        },
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared"] = {"wg": cm.dense_init(ks[4], d, fs),
                       "wu": cm.dense_init(jax.random.fold_in(ks[4], 1), d, fs),
                       "wd": cm.dense_init(jax.random.fold_in(ks[4], 2), fs, d,
                                           scale=fs ** -0.5)}
    return p


def _expert_ffn(pe, xin, wbits, abits):
    """xin: (E, C, d); per-expert SwiGLU, expert e at wbits[e]."""
    if not isinstance(pe["wg"], dict):                  # train form
        wb = jnp.broadcast_to(jnp.asarray(wbits), (pe["wg"].shape[0],))

        def per_expert(w3, x, b):
            wq = bf.fake_quant(w3.astype(jnp.float32), b, axis=0)
            return (bf.fake_quant(x.astype(jnp.float32), abits) @ wq
                    ).astype(cm.DTYPE)

        g = jax.vmap(per_expert, in_axes=(0, 0, 0))(pe["wg"], xin, wb)
        u = jax.vmap(per_expert, in_axes=(0, 0, 0))(pe["wu"], xin, wb)
        h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
             ).astype(cm.DTYPE)
        return jax.vmap(per_expert, in_axes=(0, 0, 0))(pe["wd"], h, wb)
    # serve form: {"q": (E,d,f) int8, "s": (E,1,f)} — expert stacks run as
    # one batched GEMM through the kernel layer, expert e at wbits[e]
    # (ops.serve_linear_stacked with stack_bits: per-expert weights
    # differ, so the per-expert requant is NOT redundant, unlike per-row
    # bits over shared weights).
    def stacked(pq, x):
        return kops.serve_linear_stacked(
            {"q": pq["q"], "s": pq["s"]}, x, wbits, abits,
            stack_bits=True).astype(cm.DTYPE)

    g = stacked(pe["wg"], xin)
    u = stacked(pe["wu"], xin)
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
         ).astype(cm.DTYPE)
    return stacked(pe["wd"], h)


def _route(p, xf, cfg):
    """Router top-k + load-balance aux.  xf: (T, d)."""
    E, k = cfg.n_experts, cfg.experts_per_token
    logits = cm.apply_linear(p["router"], xf, 16, 16).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    topv, topi = jax.lax.top_k(probs, k)                        # (T, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return topi, topv, aux


def _positions(topi, E, C):
    """Position-in-expert per choice: k cumsums of (T, E) — never (T*k, E).
    Returns (eid, pos, keep) flattened (T*k,)."""
    T, k = topi.shape
    counts = jnp.zeros((E,), jnp.int32)
    pos_list, keep_list = [], []
    for j in range(k):
        oh = jax.nn.one_hot(topi[:, j], E, dtype=jnp.int32)     # (T, E)
        pos_j = jnp.cumsum(oh, axis=0) - 1 + counts[None, :]
        pos_sel = jnp.sum(oh * pos_j, axis=-1)                  # (T,)
        counts = counts + jnp.sum(oh, axis=0)
        pos_list.append(pos_sel)
        keep_list.append(pos_sel < C)
    pos = jnp.stack(pos_list, 1).reshape(-1)                    # (T*k,)
    keep = jnp.stack(keep_list, 1).reshape(-1)
    return topi.reshape(-1), pos, keep


def _dispatch_compute_combine(xf, topi, topv, experts, cfg, wbits, abits, C):
    """Single-device dispatch -> expert FFN -> combine.  xf: (T, d)."""
    T, d = xf.shape
    E, k = experts_E(experts), cfg.experts_per_token
    eid, pos, keep = _positions(topi, E, C)
    gate = (topv.reshape(-1) * keep).astype(jnp.float32)
    xr = jnp.repeat(xf, k, axis=0)                              # (T*k, d)
    xr = dist.constrain(xr, ("dp", None))
    pos_c = jnp.where(keep, pos, 0)
    buf = jnp.zeros((E, C, d), xf.dtype)
    buf = buf.at[eid, pos_c].add(
        jnp.where(keep[:, None], xr, 0), mode="drop")
    buf = dist.constrain(buf, ("tp", "dp", None))
    out_buf = _expert_ffn(experts, buf, wbits, abits)           # (E, C, d)
    yk = out_buf[eid, pos_c] * gate[:, None]                    # (T*k, d)
    return jnp.sum(yk.reshape(T, k, d), axis=1).astype(cm.DTYPE)


def experts_E(experts) -> int:
    wg = experts["wg"]
    return (wg["q"] if isinstance(wg, dict) else wg).shape[0]


def _apply_moe_shard_map(p, xf, topi, topv, cfg, wbits, abits, mesh, C_shard):
    """Expert-parallel dispatch under shard_map (DESIGN.md §5):

    tokens shard over dp; experts shard over `model`; each device routes
    its LOCAL tokens to its LOCAL experts (pure local scatter — no sharded
    scatter for SPMD to mangle), FSDP-gathers its expert weights over
    `data`, runs the FFN, and a single psum over `model` combines each
    token's k expert contributions.  Collectives per layer: one (E_loc,
    d, f) all-gather + one (T_loc, d) all-reduce — vs the auto-partitioned
    scatter's full-buffer all-reduces (the kimi 84 TB/device baseline).

    Works for both train-form (bare (E,d,f) arrays) and serve-form
    ({"q": int8, "s": scales}) expert stacks: every 3-D leaf with a real
    middle axis is FSDP-sharded there (wg/wu on d, wd on f), scales
    (E,1,f) ride along replicated over dp."""
    from jax.sharding import PartitionSpec as P

    names = set(mesh.axis_names)
    dp_ax = tuple(a for a in ("pod", "data") if a in names)
    E, k = cfg.n_experts, cfg.experts_per_token
    tp = mesh.shape["model"]
    E_loc = E // tp
    ex = p["experts"]

    def _is_big(leaf) -> bool:
        return leaf.ndim == 3 and leaf.shape[1] > 1

    def local(xf_b, topi_b, topv_b, ex_b):
        rank = jax.lax.axis_index("model")
        if dp_ax:
            ex_b = jax.tree.map(
                lambda l: (jax.lax.all_gather(l, dp_ax, axis=1, tiled=True)
                           if _is_big(l) else l), ex_b)
        # re-index global expert ids onto this rank's slot [0, E_loc)
        local_i = topi_b - rank * E_loc
        mine = (local_i >= 0) & (local_i < E_loc)
        li = jnp.where(mine, local_i, E_loc)     # E_loc = dummy overflow slot
        eid, pos, keep = _positions(li, E_loc + 1, C_shard)
        keep &= mine.reshape(-1)
        gate = (topv_b.reshape(-1) * keep).astype(jnp.float32)
        T_loc, d = xf_b.shape
        xr = jnp.repeat(xf_b, k, axis=0)
        pos_c = jnp.where(keep, pos, 0)
        eid_c = jnp.where(keep, eid, 0)
        buf = jnp.zeros((E_loc, C_shard, d), xf_b.dtype)
        buf = buf.at[eid_c, pos_c].add(
            jnp.where(keep[:, None], xr, 0), mode="drop")
        out_buf = _expert_ffn(ex_b, buf, wbits, abits)
        yk = out_buf[eid_c, pos_c] * gate[:, None]
        y = jnp.sum(yk.reshape(T_loc, k, d), axis=1)
        return jax.lax.psum(y, "model").astype(cm.DTYPE)

    dp = dp_ax if len(dp_ax) > 1 else (dp_ax[0] if dp_ax else None)
    ex_specs = jax.tree.map(
        lambda l: P("model", dp, None) if _is_big(l)
        else P("model", None, None), ex)
    return dist.api.shard_map_compat(
        local, mesh=mesh,
        in_specs=(P(dp, None), P(dp, None), P(dp, None), ex_specs),
        out_specs=P(dp, None),
        check=False,
    )(xf, topi, topv, ex)


def apply_moe(p, x, cfg, wbits=8, abits=8) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss).  Top-k capacity routing.

    Under an active mesh with E % tp == 0 (train form), dispatch runs the
    explicit shard_map expert-parallel path; otherwise the single-device
    path (CPU tests, serving with few devices)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    T = B * S
    xf = x.reshape(T, d)
    topi, topv, aux = _route(p, xf, cfg)

    mesh = dist.api.active_mesh()
    use_sm = (mesh is not None and "model" in mesh.shape
              and E % mesh.shape["model"] == 0)
    if use_sm:
        names = set(mesh.axis_names)
        dp_sz = 1
        for a in ("pod", "data"):
            if a in names:
                dp_sz *= mesh.shape[a]
        use_sm = (T % dp_sz == 0 and d % dp_sz == 0
                  and cfg.d_ff % dp_sz == 0)
        if use_sm:
            T_loc = T // dp_sz
            C_shard = max(int(T_loc * k / E * cfg.capacity_factor), 4)
            C_shard = -(-C_shard // 8) * 8
            y = _apply_moe_shard_map(p, xf, topi, topv, cfg, wbits, abits,
                                     mesh, C_shard)
    if not use_sm:
        C = max(int(T * k / E * cfg.capacity_factor), 1)
        C = -(-C // 512) * 512 if T >= 4096 else C
        y = _dispatch_compute_combine(xf, topi, topv, p["experts"], cfg,
                                      wbits, abits, C)

    if "shared" in p:
        # shared expert runs at the max of the per-expert bits (scalar)
        wb_s = wbits if jnp.ndim(wbits) == 0 else jnp.max(wbits)
        g = cm.apply_linear(p["shared"]["wg"], xf, wb_s, abits)
        u = cm.apply_linear(p["shared"]["wu"], xf, wb_s, abits)
        h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
             ).astype(cm.DTYPE)
        y = y + cm.apply_linear(p["shared"]["wd"], h, wb_s, abits)
    return y.reshape(B, S, d), aux
