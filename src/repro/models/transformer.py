"""Dense transformer blocks: GQA attention (RoPE, qk_norm, QKV bias, sliding
window), SwiGLU/GELU MLPs, KV-cache prefill/decode.

Everything is a pure function over parameter pytrees.  All GEMMs go through
``common.apply_linear`` so the per-layer (wbits, abits) runtime scalars give
bit-fluid mixed precision in both train (fake-quant STE) and serve (integer
container) modes.

Cache convention (per layer):
  {"k": (B, Sc, KV, hd), "v": (B, Sc, KV, hd), "kpos": (B, Sc) int32}
``Sc`` is the cache capacity — ``min(max_len, window)`` for sliding-window
models, so a 500k-token starcoder2 decode keeps a 4k ring buffer.  Slot
``t % Sc`` is overwritten at step t; ``kpos`` records, *per batch row*,
the absolute position held by each slot (``EMPTY_POS`` = +2^30 = empty /
padded — never visible, since visibility is ``kpos <= t``) and drives the
visibility mask, which makes full-window, ring-buffer, and per-row
continuous-batching attention the same code path.  ``t`` may be a scalar
(lock-step batch) or a ``(B,)`` vector (per-row decode positions for the
slot pool in serve/engine.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import dist
from repro.kernels import ops as kops
from repro.models import common as cm

EMPTY_POS = 2 ** 30          # "no token here": fails kpos <= t forever


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def attn_init(key, cfg) -> dict:
    ks = jax.random.split(key, 4)
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": cm.dense_init(ks[0], d, H * hd, bias=cfg.qkv_bias),
        "wk": cm.dense_init(ks[1], d, KV * hd, bias=cfg.qkv_bias),
        "wv": cm.dense_init(ks[2], d, KV * hd, bias=cfg.qkv_bias),
        "wo": cm.dense_init(ks[3], H * hd, d,
                            scale=(H * hd) ** -0.5 / max(cfg.n_layers, 1) ** 0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((hd,), cm.DTYPE)}
        p["k_norm"] = {"scale": jnp.ones((hd,), cm.DTYPE)}
    return p


def mlp_init(key, cfg, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {"wg": cm.dense_init(ks[0], d, f),
                "wu": cm.dense_init(ks[1], d, f),
                "wd": cm.dense_init(ks[2], f, d, scale=f ** -0.5)}
    return {"wi": cm.dense_init(ks[0], d, f, bias=cfg.norm_type == "layer"),
            "wd": cm.dense_init(ks[1], f, d, bias=cfg.norm_type == "layer",
                                scale=f ** -0.5)}


def block_init(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": cm.norm_init(cfg.d_model, cfg.norm_type),
        "attn": attn_init(k1, cfg),
        "ln2": cm.norm_init(cfg.d_model, cfg.norm_type),
        "mlp": mlp_init(k2, cfg),
    }


def empty_cache(cfg, batch: int, max_len: int, n_layers: Optional[int] = None,
                dtype=cm.DTYPE) -> dict:
    """Stacked (n_layers, ...) cache pytree for the decode scan.

    kv_cache_bits == 8 stores int8 keys/values with per-(token, head)
    scales — half the HBM traffic per decoded token, and the QK/PV dots
    run on the int8 MXU path (2x peak)."""
    L = n_layers if n_layers is not None else cfg.n_layers
    Sc = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    kv = (L, batch, Sc, cfg.n_kv_heads, cfg.head_dim)
    out = {"kpos": jnp.full((L, batch, Sc), EMPTY_POS, jnp.int32)}
    if cfg.kv_cache_bits == 8:
        out.update({
            "k": jnp.zeros(kv, jnp.int8),
            "v": jnp.zeros(kv, jnp.int8),
            "ks": jnp.zeros(kv[:-1], cm.DTYPE),
            "vs": jnp.zeros(kv[:-1], cm.DTYPE),
        })
    else:
        out.update({"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)})
    return out


def _quant_heads(x: jnp.ndarray):
    """(B, S, KV, hd) -> int8 values + per-(token, head) scale."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    s = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127
                 ).astype(jnp.int8)
    return q, s[..., 0].astype(cm.DTYPE)


def _row_insert(buf: jnp.ndarray, new: jnp.ndarray, slot: jnp.ndarray
                ) -> jnp.ndarray:
    """Write ``new`` (B, 1, ...) into ``buf`` (B, Sc, ...) at per-row ring
    slot ``slot`` (B,) — the continuous-batching cache insert, where each
    row sits at its own decode position."""
    return jax.vmap(lambda b, n, s: jax.lax.dynamic_update_slice(
        b, n, (s,) + (0,) * (b.ndim - 1)))(buf, new, slot)


def _sdpa_int8(q, kq, ks, vq, vs, bias, cfg):
    """Decode attention on the int8 cache: scores = (q_q . k_q) sq ks.

    q: (B,1,H,hd) bf16; kq/vq: (B,Sc,KV,hd) int8; ks/vs: (B,Sc,KV)."""
    B, Sq, H, hd = q.shape
    KV = kq.shape[2]
    G = H // KV
    qq, qs = _quant_heads(q)
    qg = qq.reshape(B, Sq, KV, G, hd)
    acc = jnp.einsum("bqkgd,bskd->bkgqs", qg, kq,
                     preferred_element_type=jnp.int32)
    qs_g = qs.reshape(B, Sq, KV, G).transpose(0, 2, 3, 1)[..., None]
    scores = (acc.astype(jnp.float32) * qs_g.astype(jnp.float32)
              * ks.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, None, :])
    scores = scores * (hd ** -0.5) + bias[:, None, None]
    probs = jax.nn.softmax(scores, axis=-1)
    # fold v scales into probs, quantize probs to int8 (p in [0,1])
    pv = probs * vs.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, None, :]
    pmax = jnp.max(pv, axis=-1, keepdims=True) + 1e-9
    p_q = jnp.clip(jnp.round(pv / pmax * 127.0), 0, 127).astype(jnp.int8)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p_q, vq,
                     preferred_element_type=jnp.int32)
    out = out.astype(jnp.float32) * (pmax.transpose(0, 3, 1, 2, 4) / 127.0)
    return out.reshape(B, Sq, H * hd).astype(cm.DTYPE)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _qkv(p, x, cfg, wbits, abits):
    B, S = x.shape[:2]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = cm.apply_linear(p["wq"], x, wbits, abits).reshape(B, S, H, hd)
    k = cm.apply_linear(p["wk"], x, wbits, abits).reshape(B, S, KV, hd)
    v = cm.apply_linear(p["wv"], x, wbits, abits).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = cm.rms_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
        k = cm.rms_norm(k, p["k_norm"]["scale"], cfg.norm_eps)
    return q, k, v


def _sdpa(q, k, v, bias, cfg):
    """q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd); bias: (Sq,Sk) or (B,Sq,Sk).

    Grouped-query einsum; used for decode (Sq==1) and short sequences,
    where the scores tensor is small.  Long sequences take _flash (the
    kernel-layer flash dispatcher)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (hd ** -0.5)
    if bias.ndim == 2:
        scores = scores + bias[None, None, None]
    else:
        scores = scores + bias[:, None, None]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(k.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H * hd).astype(cm.DTYPE)


FLASH_THRESHOLD = 2048


def _flash(q, k, v, cfg, causal: bool):
    """Long-sequence attention via the kernel-layer flash dispatcher.

    q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd) — KV heads are expanded to H flat
    heads so the `model` axis shards the head dim of every intermediate
    (Megatron semantics), then heads flatten into the batch dim of
    ``ops.flash_attention`` (Pallas kernel on TPU, blockwise online-softmax
    ref elsewhere — the scores tensor never materializes beyond one tile).
    Positions are lock-step 0..S-1 by construction on this path (ragged
    prefill is capped at FLASH_THRESHOLD upstream); the sliding-window
    band applies only to causal self-attention.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    if G > 1:                                 # expand GQA to flat heads
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    k = dist.constrain(k, ("dp", None, "tp", None))
    v = dist.constrain(v, ("dp", None, "tp", None))
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd).astype(cm.DTYPE)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, Sk, hd).astype(cm.DTYPE)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, Sk, hd).astype(cm.DTYPE)
    window = cfg.sliding_window if causal else 0
    out = kops.flash_attention(qf, kf, vf, causal=causal, window=window)
    out = out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
    return out.reshape(B, Sq, H * hd).astype(cm.DTYPE)


def attention(p, x, cfg, wbits=8, abits=8, *, positions, causal: bool = True,
              kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              cache: Optional[dict] = None, t=None):
    """Self- or cross-attention with optional cache update.

    positions: (B, S) absolute positions of x's tokens (for RoPE + mask).
    kv:        precomputed (k, v) for cross-attention (RoPE skipped).
    cache/t:   decode path — insert this step's k/v at slot t % Sc.
    Returns (out, new_cache).
    """
    q, k_new, v_new = _qkv(p, x, cfg, wbits, abits)
    if cfg.rope_theta > 0 and kv is None:
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k_new = cm.apply_rope(k_new, positions, cfg.rope_theta)

    new_cache = None
    out = None
    if kv is not None:                                   # cross-attention
        k, v = kv
        if q.shape[1] * k.shape[1] > FLASH_THRESHOLD ** 2:
            out = _flash(q, k, v, cfg, causal=False)
        else:
            bias = jnp.zeros((q.shape[1], k.shape[1]), jnp.float32)
            out = _sdpa(q, k, v, bias, cfg)
    elif cache is not None and x.shape[1] == 1:          # decode (S == 1)
        # consistent head/hd sharding across q, k/v inserts, and the cache:
        # the KV head count decides the axis for *all* of q/k/v
        use_head = k_new.shape[2] % dist.api.tp_size() == 0
        q = dist.constrain_heads(q, 2, 3, use_head)
        k_new = dist.constrain_heads(k_new, 2, 3, use_head)
        v_new = dist.constrain_heads(v_new, 2, 3, use_head)
        B = x.shape[0]
        Sc = cache["k"].shape[1]
        t_b = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))
        slot = (t_b % Sc).astype(jnp.int32)
        kpos = jax.vmap(lambda kp, tv, sl: jax.lax.dynamic_update_slice(
            kp, tv[None], (sl,)))(cache["kpos"], t_b, slot)
        visible = kpos <= positions[:, -1:]              # (B, Sc)
        if cfg.sliding_window:
            visible &= kpos > positions[:, -1:] - cfg.sliding_window
        bias = jnp.where(visible, 0.0, -jnp.inf)[:, None, :].astype(jnp.float32)
        bias = bias.reshape(B, 1, Sc)                    # (B, Sq=1, Sc)
        if "ks" in cache:                                # int8 cache path
            kq_n, ks_n = _quant_heads(k_new)
            vq_n, vs_n = _quant_heads(v_new)
            k = _row_insert(cache["k"], kq_n, slot)
            v = _row_insert(cache["v"], vq_n, slot)
            ks = _row_insert(cache["ks"], ks_n, slot)
            vs = _row_insert(cache["vs"], vs_n, slot)
            new_cache = {"k": k, "v": v, "ks": ks, "vs": vs, "kpos": kpos}
            out = _sdpa_int8(q, k, ks, v, vs, bias, cfg)
        else:
            k = _row_insert(cache["k"], k_new, slot)
            v = _row_insert(cache["v"], v_new, slot)
            new_cache = {"k": k, "v": v, "kpos": kpos}
            out = _sdpa(q, k, v, bias, cfg)
    elif cache is not None and t is not None:            # chunked decode
        # Speculative verify: U consecutive token positions per row in ONE
        # forward.  Writes land in the same ring slots sequential decode
        # would use; each query position sees exactly the kpos <= pos
        # prefix, so the chunk is bit-identical to U single-token steps
        # (the draft's stale entries past each query are masked, and
        # rejected slots are rolled back to EMPTY_POS by the caller).
        use_head = k_new.shape[2] % dist.api.tp_size() == 0
        q = dist.constrain_heads(q, 2, 3, use_head)
        k_new = dist.constrain_heads(k_new, 2, 3, use_head)
        v_new = dist.constrain_heads(v_new, 2, 3, use_head)
        B, U = x.shape[:2]
        Sc = cache["k"].shape[1]
        pos = positions.astype(jnp.int32)                # (B, U)
        slots = (pos % Sc).astype(jnp.int32)             # (B, U)
        scatter = jax.vmap(lambda b, n, s: b.at[s].set(n))
        kpos = scatter(cache["kpos"], pos, slots)
        visible = kpos[:, None, :] <= pos[:, :, None]    # (B, U, Sc)
        if cfg.sliding_window:
            visible &= kpos[:, None, :] > pos[:, :, None] - cfg.sliding_window
        bias = jnp.where(visible, 0.0, -jnp.inf).astype(jnp.float32)
        if "ks" in cache:                                # int8 cache path
            kq_n, ks_n = _quant_heads(k_new)
            vq_n, vs_n = _quant_heads(v_new)
            k = scatter(cache["k"], kq_n, slots)
            v = scatter(cache["v"], vq_n, slots)
            ks = scatter(cache["ks"], ks_n, slots)
            vs = scatter(cache["vs"], vs_n, slots)
            new_cache = {"k": k, "v": v, "ks": ks, "vs": vs, "kpos": kpos}
            out = _sdpa_int8(q, k, ks, v, vs, bias, cfg)
        else:
            k = scatter(cache["k"], k_new, slots)
            v = scatter(cache["v"], v_new, slots)
            new_cache = {"k": k, "v": v, "kpos": kpos}
            out = _sdpa(q, k, v, bias, cfg)
    else:                                                # full sequence
        pos1 = positions[0]
        k, v = k_new, v_new
        if x.shape[1] > FLASH_THRESHOLD:
            out = _flash(q, k, v, cfg, causal=causal)
        elif causal and cache is not None and positions.shape[0] > 1:
            # ragged serving prefill: rows carry different valid lengths
            # (padded positions == EMPTY_POS), so the mask is per-row;
            # lock-step prefill passes (1, S) positions and keeps the
            # shared (S, S) mask below
            bias = cm.causal_mask_bias_batched(positions, positions,
                                               cfg.sliding_window)
            out = _sdpa(q, k, v, bias, cfg)
        else:
            bias = (cm.causal_mask_bias(pos1, pos1, cfg.sliding_window)
                    if causal
                    else jnp.zeros((x.shape[1], x.shape[1]), jnp.float32))
            out = _sdpa(q, k, v, bias, cfg)
        if cache is not None:                            # prefill: fill cache
            new_cache = prefill_cache_insert(cache, k_new, v_new, positions)

    y = cm.apply_linear(p["wo"], out, wbits, abits)
    return y, new_cache


def prefill_cache_insert(cache_layer: dict, k: jnp.ndarray, v: jnp.ndarray,
                         positions: jnp.ndarray) -> dict:
    """Write a full prefill's k/v (B,S,KV,hd) into a fresh layer cache.

    ``positions`` (B, S) or (1, S) may differ per row: padded tokens at
    EMPTY_POS land in the cache as EMPTY_POS slots, which the decode
    visibility mask (kpos <= t) never exposes — padding is masked, not
    special-cased.  When the prompt buffer exceeds the ring capacity,
    each row keeps its own last ``Sc`` *valid* tokens (a per-row gather —
    a uniform tail slice would keep only padding for short rows)."""
    Sc = cache_layer["k"].shape[1]
    B, S = k.shape[0], k.shape[1]
    keep = min(S, Sc)
    positions = jnp.broadcast_to(positions, (B, S)).astype(jnp.int32)
    if keep == S:                                        # whole buffer fits
        kpos_new, k_keep, v_keep = positions, k, v
    else:
        n_valid = jnp.sum(positions < EMPTY_POS, axis=1)        # (B,)
        shift = jnp.maximum(n_valid - keep, 0)                  # (B,)
        idx = jnp.minimum(shift[:, None] + jnp.arange(keep)[None], S - 1)
        kpos_new = jnp.take_along_axis(positions, idx, axis=1)
        k_keep = jnp.take_along_axis(k, idx[..., None, None], axis=1)
        v_keep = jnp.take_along_axis(v, idx[..., None, None], axis=1)
    kpos = jax.lax.dynamic_update_slice(cache_layer["kpos"], kpos_new,
                                        (0, 0))
    if "ks" in cache_layer:                              # int8 cache
        kq, ks = _quant_heads(k_keep)
        vq, vs = _quant_heads(v_keep)
        return {
            "k": jax.lax.dynamic_update_slice(cache_layer["k"], kq,
                                              (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache_layer["v"], vq,
                                              (0, 0, 0, 0)),
            "ks": jax.lax.dynamic_update_slice(cache_layer["ks"], ks,
                                               (0, 0, 0)),
            "vs": jax.lax.dynamic_update_slice(cache_layer["vs"], vs,
                                               (0, 0, 0)),
            "kpos": kpos,
        }
    ck = jax.lax.dynamic_update_slice(
        cache_layer["k"], k_keep, (0, 0, 0, 0))
    cv = jax.lax.dynamic_update_slice(
        cache_layer["v"], v_keep, (0, 0, 0, 0))
    return {"k": ck, "v": cv, "kpos": kpos}


# ---------------------------------------------------------------------------
# MLP + block
# ---------------------------------------------------------------------------

def mlp(p, x, cfg, wbits=8, abits=8):
    if cfg.mlp_type == "swiglu":
        g = cm.apply_linear(p["wg"], x, wbits, abits)
        u = cm.apply_linear(p["wu"], x, wbits, abits)
        h = jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
        return cm.apply_linear(p["wd"], h.astype(cm.DTYPE), wbits, abits)
    h = cm.apply_linear(p["wi"], x, wbits, abits)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(cm.DTYPE)
    return cm.apply_linear(p["wd"], h, wbits, abits)


def block(p, x, cfg, wbits=8, abits=8, *, positions, causal=True,
          cache=None, t=None, mlp_fn=None):
    """Pre-norm residual block.  Returns (x, new_cache, aux)."""
    h, new_cache = attention(p["attn"], cm.apply_norm(p["ln1"], x, cfg.norm_type,
                                                      cfg.norm_eps),
                             cfg, wbits, abits, positions=positions,
                             causal=causal, cache=cache, t=t)
    x = x + h
    fn = mlp_fn if mlp_fn is not None else mlp
    out = fn(p["mlp"], cm.apply_norm(p["ln2"], x, cfg.norm_type, cfg.norm_eps),
             cfg, wbits, abits)
    if isinstance(out, tuple):                    # MoE returns (y, aux)
        y, aux = out
    else:
        y, aux = out, jnp.zeros((), jnp.float32)
    return x + y, new_cache, aux
