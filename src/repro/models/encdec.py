"""Encoder-decoder (seamless-m4t backbone): bidirectional encoder over
precomputed audio-frame embeddings (modality frontend is a stub per the
brief), causal decoder with per-layer cross-attention.

Decode caches: self-attention KV ring caches (transformer.empty_cache) plus
per-layer cross K/V projected once from the encoder output at prefill.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import transformer as tf


def dec_block_init(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    p = tf.block_init(k1, cfg)
    p["lnx"] = cm.norm_init(cfg.d_model, cfg.norm_type)
    p["xattn"] = tf.attn_init(k2, cfg)
    return p


def encdec_init(key, cfg) -> dict:
    ke, kd = jax.random.split(key)
    enc = jax.vmap(lambda k: tf.block_init(k, cfg))(
        jax.random.split(ke, cfg.n_enc_layers))
    dec = jax.vmap(lambda k: dec_block_init(k, cfg))(
        jax.random.split(kd, cfg.n_layers))
    return {"enc": enc, "enc_ln_f": cm.norm_init(cfg.d_model, cfg.norm_type),
            "dec": dec}


def encode(p, frames: jnp.ndarray, cfg, wvec, avec) -> jnp.ndarray:
    """frames: (B, F, d) stub embeddings -> encoder output (B, F, d)."""
    B, F, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(F)[None], (B, F))

    def body(x, scanned):
        lp, wb, ab = scanned
        x, _, _ = tf.block(lp, x, cfg, wb, ab, positions=positions,
                           causal=False)
        return x, ()

    body = jax.checkpoint(body) if cfg.remat == "full" else body
    n_enc = cfg.n_enc_layers
    x, _ = jax.lax.scan(body, frames, (p["enc"], wvec[:n_enc], avec[:n_enc]))
    return cm.apply_norm(p["enc_ln_f"], x, cfg.norm_type, cfg.norm_eps)


def cross_kv(p_dec, enc_out: jnp.ndarray, cfg, wvec, avec) -> dict:
    """Project encoder output to per-decoder-layer cross K/V (prefill)."""
    B, F, _ = enc_out.shape
    KV, hd = cfg.n_kv_heads, cfg.head_dim

    def one(xp, wb, ab):
        k = cm.apply_linear(xp["wk"], enc_out, wb, ab).reshape(B, F, KV, hd)
        v = cm.apply_linear(xp["wv"], enc_out, wb, ab).reshape(B, F, KV, hd)
        return k, v

    ks, vs = jax.lax.map(lambda args: one(*args),
                         (p_dec["xattn"], wvec, avec))
    return {"k": ks, "v": vs}                    # (L, B, F, KV, hd)


def decoder_block(p, x, cfg, wb, ab, *, positions, enc_kv,
                  cache: Optional[dict] = None, t=None):
    """Self-attn + cross-attn + MLP.  enc_kv: (k, v) for this layer."""
    h, new_cache = tf.attention(
        p["attn"], cm.apply_norm(p["ln1"], x, cfg.norm_type, cfg.norm_eps),
        cfg, wb, ab, positions=positions, causal=True, cache=cache, t=t)
    x = x + h
    hx, _ = tf.attention(
        p["xattn"], cm.apply_norm(p["lnx"], x, cfg.norm_type, cfg.norm_eps),
        cfg, wb, ab, positions=positions, kv=enc_kv)
    x = x + hx
    y = tf.mlp(p["mlp"], cm.apply_norm(p["ln2"], x, cfg.norm_type, cfg.norm_eps),
               cfg, wb, ab)
    return x + y, new_cache


def decoder_forward(p, x, cfg, wvec, avec, *, positions,
                    enc_kv: dict, cache: Optional[dict] = None, t=None
                    ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: (B, S, d) decoder-side embeddings; enc_kv stacked (L, ...)."""
    def body(carry, scanned):
        x = carry
        if cache is not None:
            lp, wb, ab, ek, ev, cl = scanned
        else:
            lp, wb, ab, ek, ev = scanned
            cl = None
        x, new_cl = decoder_block(lp, x, cfg, wb, ab, positions=positions,
                                  enc_kv=(ek, ev), cache=cl, t=t)
        return x, (new_cl if cache is not None else ())

    n_dec = cfg.n_layers
    wd, ad = wvec[-n_dec:], avec[-n_dec:]
    xs = (p["dec"], wd, ad, enc_kv["k"], enc_kv["v"])
    if cache is not None:
        xs = xs + (cache,)
    body = (jax.checkpoint(body) if cfg.remat == "full" and cache is None
            else body)
    x, ys = jax.lax.scan(body, x, xs)
    return x, (ys if cache is not None else None)
