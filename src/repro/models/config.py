"""ModelConfig — one dataclass describing every supported architecture."""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    vocab_size: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    head_dim: int = 0            # 0 -> d_model // n_heads
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4      # 0 disables RoPE
    sliding_window: int = 0      # 0 = full attention
    mlp_type: str = "swiglu"     # swiglu | gelu
    norm_type: str = "rms"       # rms | layer
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    ssm_chunk: int = 128
    # hybrid (zamba2): one shared attn+mlp block every `attn_every` layers
    attn_every: int = 0
    lora_rank: int = 0
    # enc-dec (seamless)
    n_enc_layers: int = 0
    frames_ratio: int = 4        # decoder tokens per encoder frame (stub)
    # modality frontend stub: prepended precomputed embeddings
    frontend: str = ""           # "" | "vision" | "audio"
    n_prefix_tokens: int = 0
    # training details
    remat: str = "full"          # none | full
    accum_dtype: str = "float32"  # gradient accumulation dtype
    # serving details
    kv_cache_bits: int = 0       # 0 = bf16 cache; 8 = int8 cache with
    #                              int8 QK/PV attention (beyond-paper:
    #                              the bit-fluid insight applied to the
    #                              decode bandwidth bottleneck)

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Embedding/head rows padded to a 512 multiple so the vocab dim
        shards over any mesh axis (oddball vocabs — 50280, 151655, 256206
        — would otherwise replicate the logits tensor).  Logits at padded
        ids are masked to -inf in logits_fn; real vocab ids are unchanged.
        """
        if self.vocab_size % 512 == 0:
            return self.vocab_size
        return -(-self.vocab_size // 512) * 512

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (bounded decode state)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}
