"""Mamba2 — SSD (state-space duality) blocks, chunked scan + O(1) decode.

Implements the Mamba2 layer of arXiv:2405.21060 in pure JAX:

  in_proj -> (z, x, B, C, dt);  depthwise causal conv(4) over (x, B, C);
  SSD core: chunked dual form — intra-chunk "attention-like" quadratic term
  plus an inter-chunk recurrence on the (H, P, N) state, lax.scan over
  chunks; gated RMSNorm; out_proj.

Bit fluidity applies to the in/out projections (the GEMM mass of the
layer); the associative scan itself is floating point — the paper's
bit-serial LUT walk has no analogue inside a recurrence (DESIGN.md §4).

Decode carries {"conv": (B, K-1, Cch), "ssm": (B, H, P, N)} — constant-size
state, which is why long_500k runs on this family.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm


def dims(cfg):
    d_inner = cfg.expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    return d_inner, H, cfg.ssm_state, cfg.ssm_head_dim


def mamba_init(key, cfg, prefix_dim: Optional[int] = None) -> dict:
    d = prefix_dim or cfg.d_model
    d_inner, H, N, P = dims(cfg)
    conv_ch = d_inner + 2 * N                       # x, B, C share the conv
    d_proj = 2 * d_inner + 2 * N + H                # z, x, B, C, dt
    ks = jax.random.split(key, 4)
    return {
        "ln": cm.norm_init(d, "rms"),
        "in_proj": cm.dense_init(ks[0], d, d_proj),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, conv_ch), jnp.float32)
                   * (cfg.d_conv ** -0.5)).astype(cm.DTYPE),
        "conv_b": jnp.zeros((conv_ch,), cm.DTYPE),
        "A_log": jnp.zeros((H,), jnp.float32),      # a = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "gn": cm.norm_init(d_inner, "rms"),
        "out_proj": cm.dense_init(ks[2], d_inner, d, scale=d_inner ** -0.5),
    }


def empty_state(cfg, batch: int, n_layers: int) -> dict:
    d_inner, H, N, P = dims(cfg)
    conv_ch = d_inner + 2 * N
    return {
        "conv": jnp.zeros((n_layers, batch, cfg.d_conv - 1, conv_ch), cm.DTYPE),
        "ssm": jnp.zeros((n_layers, batch, H, P, N), jnp.float32),
    }


def _split(p, xz, cfg):
    d_inner, H, N, P = dims(cfg)
    z, xBC, dt = jnp.split(xz, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xBC, dt


def _causal_conv(w, b, xBC):
    """Depthwise causal conv, window K, via K shifted adds. xBC: (B,S,C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    S = xBC.shape[1]
    y = jnp.zeros_like(xBC, dtype=jnp.float32)
    for i in range(K):
        y = y + pad[:, i:i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(y + b.astype(jnp.float32)).astype(xBC.dtype)


def ssd_chunked(xh, Bm, Cm, dt, a, h0, chunk: int):
    """SSD dual form.  xh (B,S,H,P); Bm/Cm (B,S,N); dt (B,S,H); a (H,)<0.
    h0: (B,H,P,N) initial state.  Returns (y (B,S,H,P), h_final)."""
    Bsz, S, H, Pd = xh.shape
    Sp = -(-S // chunk) * chunk
    if Sp != S:
        # zero-pad: dt=0 -> decay 1 and no input; B=C=0 -> no contribution
        pad = lambda t: jnp.pad(t, [(0, 0), (0, Sp - S)]
                                + [(0, 0)] * (t.ndim - 2))
        xh, Bm, Cm, dt = pad(xh), pad(Bm), pad(Cm), pad(dt)
    nc = Sp // chunk
    r = lambda t: t.reshape(Bsz, nc, chunk, *t.shape[2:])
    xh, Bm, Cm, dt = r(xh), r(Bm), r(Cm), r(dt)

    dA = a[None, None, None, :] * dt                       # (B,nc,Q,H) <= 0
    cs = jnp.cumsum(dA, axis=2)                            # within-chunk

    def chunk_step(h, inp):
        xh_c, B_c, C_c, dt_c, cs_c = inp                   # (B,Q,...) per chunk
        # intra-chunk: M[q,k] = exp(cs_q - cs_k) * (C_q.B_k) * dt_k  (q >= k)
        seg = cs_c[:, :, None, :] - cs_c[:, None, :, :]    # (B,Q,Q,H)
        iota = jnp.arange(cs_c.shape[1])
        causal = (iota[:, None] >= iota[None, :])[None, :, :, None]
        G = jnp.where(causal, jnp.exp(seg), 0.0)
        CB = jnp.einsum("bqn,bkn->bqk", C_c.astype(jnp.float32),
                        B_c.astype(jnp.float32))
        M = G * CB[:, :, :, None] * dt_c[:, None, :, :]    # (B,Q,Q,H)
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", M, xh_c.astype(jnp.float32))
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bqn,bhpn->bqhp", C_c.astype(jnp.float32), h) \
            * jnp.exp(cs_c)[..., None]
        # state update
        decay_to_end = jnp.exp(cs_c[:, -1:, :] - cs_c)     # (B,Q,H)
        contrib = jnp.einsum("bqh,bqn,bqhp->bhpn",
                             decay_to_end * dt_c, B_c.astype(jnp.float32),
                             xh_c.astype(jnp.float32))
        h_new = h * jnp.exp(cs_c[:, -1])[:, :, None, None] + contrib
        return h_new, y_intra + y_inter

    to_scan = tuple(jnp.moveaxis(t, 1, 0) for t in (xh, Bm, Cm, dt, cs))
    h_final, ys = jax.lax.scan(chunk_step, h0, to_scan)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, Sp, H, Pd)[:, :S]
    return y, h_final


def mamba_block(p, x, cfg, wbits=8, abits=8, *, state: Optional[dict] = None
                ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: (B, S, d).

    * state=None, S>=1 .... chunked full-sequence (train); no state out.
    * state given, S>1 .... chunked prefill seeded by state; state out.
    * state given, S==1 ... single-step decode; state out.
    """
    d_inner, H, N, P = dims(cfg)
    res = x
    xz = cm.apply_linear(p["in_proj"],
                         cm.rms_norm(x, p["ln"]["scale"], cfg.norm_eps),
                         wbits, abits)
    z, xBC, dt_raw = _split(p, xz, cfg)
    a = -jnp.exp(p["A_log"])                                # (H,)

    if state is None or x.shape[1] > 1:
        xBC_raw = xBC
        xBC = _causal_conv(p["conv_w"], p["conv_b"], xBC)
        xh = xBC[..., :d_inner].reshape(*x.shape[:2], H, P)
        Bm = xBC[..., d_inner:d_inner + N]
        Cm = xBC[..., d_inner + N:]
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + p["dt_bias"][None, None])
        h0 = (state["ssm"] if state is not None
              else jnp.zeros((x.shape[0], H, P, N), jnp.float32))
        y, h_fin = ssd_chunked(xh, Bm, Cm, dt, a, h0, cfg.ssm_chunk)
        new_state = None
        if state is not None:
            K = cfg.d_conv
            new_state = {"conv": xBC_raw[:, x.shape[1] - (K - 1):, :],
                         "ssm": h_fin}
    else:
        # decode: roll conv window, single SSM step
        conv_in = jnp.concatenate([state["conv"], xBC], axis=1)  # (B,K,C)
        xBC1 = jax.nn.silu(
            jnp.einsum("bkc,kc->bc", conv_in.astype(jnp.float32),
                       p["conv_w"].astype(jnp.float32))
            + p["conv_b"].astype(jnp.float32))[:, None]          # (B,1,C)
        xh = xBC1[..., :d_inner].reshape(x.shape[0], 1, H, P)
        Bm = xBC1[..., d_inner:d_inner + N]
        Cm = xBC1[..., d_inner + N:]
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + p["dt_bias"][None, None])         # (B,1,H)
        dA = jnp.exp(a[None, :] * dt[:, 0])                      # (B,H)
        h = state["ssm"] * dA[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, 0], Bm[:, 0].astype(jnp.float32),
            xh[:, 0].astype(jnp.float32))
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h)[:, None]
        new_state = {"conv": conv_in[:, 1:], "ssm": h}

    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = cm.rms_norm(y.astype(cm.DTYPE), p["gn"]["scale"], cfg.norm_eps)
    out = cm.apply_linear(p["out_proj"], y, wbits, abits)
    return res + out, new_state
