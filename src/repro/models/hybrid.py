"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block.

Structure (arXiv:2411.15242, adapted): the layer stack is grouped into
``n_layers / attn_every`` super-blocks; each super-block first runs the
globally-shared attention+MLP block (one weight set reused at every site,
specialized per site by a LoRA adapter pair), then ``attn_every`` Mamba2
layers.  The outer scan carries hidden state; Mamba params are stacked
(n_super, attn_every, ...), LoRA params (n_super, ...).

Because the shared block's base weights are one tensor reused everywhere,
its precision assignment is global — the per-layer bit vectors index
super-blocks for the LoRA/Mamba params, while the shared base uses
``wbits[0]`` (constraint recorded in DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import mamba2, transformer


def n_super(cfg) -> int:
    assert cfg.n_layers % cfg.attn_every == 0
    return cfg.n_layers // cfg.attn_every


def lora_init(key, cfg) -> dict:
    """Per-site LoRA on the shared block's four attention projections."""
    d, H, hd, r = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.lora_rank
    ks = jax.random.split(key, 4)

    def pair(k, d_in, d_out):
        a = (jax.random.normal(k, (d_in, r), jnp.float32) * d_in ** -0.5
             ).astype(cm.DTYPE)
        b = jnp.zeros((r, d_out), cm.DTYPE)
        return {"a": a, "b": b}

    return {"wq": pair(ks[0], d, H * hd), "wk": pair(ks[1], d, cfg.n_kv_heads * hd),
            "wv": pair(ks[2], d, cfg.n_kv_heads * hd), "wo": pair(ks[3], H * hd, d)}


def hybrid_init(key, cfg) -> dict:
    ns = n_super(cfg)
    k_shared, k_mamba, k_lora = jax.random.split(key, 3)
    shared = transformer.block_init(k_shared, cfg)
    mamba_keys = jax.random.split(k_mamba, ns * cfg.attn_every)
    mamba_keys = mamba_keys.reshape(ns, cfg.attn_every, *mamba_keys.shape[1:])
    stack = jax.vmap(jax.vmap(lambda k: mamba2.mamba_init(k, cfg)))(mamba_keys)
    lora = jax.vmap(lambda k: lora_init(k, cfg))(jax.random.split(k_lora, ns))
    return {"shared": shared, "mamba": stack, "lora": lora}


def _lora_attn_params(shared_attn: dict, lora: dict) -> dict:
    """Materialize site-specific attention weights: W + A @ B (train form),
    or attach the LoRA delta additively around the quantized base."""
    out = dict(shared_attn)
    for name in ("wq", "wk", "wv", "wo"):
        base = shared_attn[name]
        delta = (lora[name]["a"].astype(jnp.float32)
                 @ lora[name]["b"].astype(jnp.float32))
        if "w" in base:
            out[name] = dict(base, w=(base["w"].astype(jnp.float32) + delta
                                      ).astype(base["w"].dtype))
        else:   # serve form: keep int base, add fp delta via side branch
            out[name] = dict(base, lora_delta=delta.astype(cm.DTYPE))
    return out


def hybrid_forward(p, x, cfg, wbits, abits, *, positions,
                   cache: Optional[dict] = None, t=None
                   ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: (B, S, d).  wbits/abits: (n_super,) vectors (or scalars).
    cache: {"kv": transformer cache stacked (n_super,...),
            "ssm"/"conv": mamba states stacked (n_super*attn_every,...)}."""
    ns = n_super(cfg)
    wb = jnp.broadcast_to(jnp.asarray(wbits), (ns,))
    ab = jnp.broadcast_to(jnp.asarray(abits), (ns,))
    shared = p["shared"]
    decode = cache is not None

    def super_block(carry, scanned):
        x = carry
        if decode:
            (mp, lora, wb_i, ab_i, kv_c, m_c) = scanned
        else:
            (mp, lora, wb_i, ab_i) = scanned
            kv_c, m_c = None, None
        attn_p = {"ln1": shared["ln1"], "ln2": shared["ln2"],
                  "mlp": shared["mlp"],
                  "attn": _lora_attn_params(shared["attn"], lora)}
        x, new_kv, _ = transformer.block(
            attn_p, x, cfg, wb[0], ab[0], positions=positions,
            cache=kv_c, t=t)

        def mamba_layer(xc, inner):
            if decode:
                mp_i, conv_i, ssm_i = inner
                st = {"conv": conv_i, "ssm": ssm_i}
            else:
                (mp_i,) = inner
                st = None
            xc, new_st = mamba2.mamba_block(mp_i, xc, cfg, wb_i, ab_i, state=st)
            ys = (new_st["conv"], new_st["ssm"]) if decode else ()
            return xc, ys

        inner_xs = (mp, m_c["conv"], m_c["ssm"]) if decode else (mp,)
        x, m_out = jax.lax.scan(mamba_layer, x, inner_xs)
        ys = ((new_kv, {"conv": m_out[0], "ssm": m_out[1]}) if decode else ())
        return x, ys

    if decode:
        ssm = jax.tree.map(
            lambda a: a.reshape(ns, cfg.attn_every, *a.shape[1:]),
            {"conv": cache["conv"], "ssm": cache["ssm"]})
        xs = (p["mamba"], p["lora"], wb, ab, cache["kv"], ssm)
    else:
        xs = (p["mamba"], p["lora"], wb, ab)
    body = jax.checkpoint(super_block) if cfg.remat == "full" else super_block
    x, ys = jax.lax.scan(body, x, xs)
    new_cache = None
    if decode:
        kv_new, m_new = ys
        new_cache = {
            "kv": kv_new,
            "conv": m_new["conv"].reshape(cfg.n_layers, *m_new["conv"].shape[2:]),
            "ssm": m_new["ssm"].reshape(cfg.n_layers, *m_new["ssm"].shape[2:]),
        }
    return x, new_cache


def empty_hybrid_cache(cfg, batch: int, max_len: int) -> dict:
    ns = n_super(cfg)
    kv = transformer.empty_cache(cfg, batch, max_len, n_layers=ns)
    ms = mamba2.empty_state(cfg, batch, cfg.n_layers)
    return {"kv": kv, "conv": ms["conv"], "ssm": ms["ssm"]}
