"""LM wiring: embeddings, per-family stacks, loss, prefill/decode, and the
train/serve parameter forms.

Public API (all pure functions):
  init_params(cfg, key)                      -> train-form pytree (bf16)
  quantize_params(params, cfg, container)    -> serve-form (int8/int4 + scales)
  train_loss(params, batch, cfg, wvec, avec) -> (loss, metrics)
  prefill(params, batch, cfg, wvec, avec, cache) -> (last_logits, cache)
  decode_step(params, tok, t, cache, cfg, wvec, avec) -> (logits, cache)
  empty_cache(cfg, batch, max_len)           -> family-specific cache pytree

``wvec``/``avec`` are per-layer bit vectors (runtime tensors — core/policy);
per-family semantics documented in DESIGN.md §4.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import dist
from repro.models import common as cm
from repro.models import encdec, hybrid, mamba2, moe, transformer as tf
from repro.models.config import ModelConfig

MOE_AUX_COEF = 0.01


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def n_bit_slots(cfg: ModelConfig) -> int:
    """Length of the per-layer bit vectors for this family."""
    if cfg.family == "encdec":
        return cfg.n_enc_layers + cfg.n_layers
    if cfg.family == "hybrid":
        return hybrid.n_super(cfg)
    return cfg.n_layers


def init_params(cfg: ModelConfig, key) -> dict:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    p = {"emb": (jax.random.normal(k_emb, (cfg.padded_vocab, cfg.d_model),
                                   jnp.float32) * 0.02).astype(cm.DTYPE),
         "ln_f": cm.norm_init(cfg.d_model, cfg.norm_type)}
    if cfg.family in ("dense", "vlm"):
        p["layers"] = jax.vmap(lambda k: tf.block_init(k, cfg))(
            jax.random.split(k_layers, cfg.n_layers))
    elif cfg.family == "moe":
        def one(k):
            k1, k2 = jax.random.split(k)
            blk = tf.block_init(k1, cfg)
            del blk["mlp"]
            blk["mlp"] = moe.moe_init(k2, cfg)
            return blk
        p["layers"] = jax.vmap(one)(jax.random.split(k_layers, cfg.n_layers))
    elif cfg.family == "ssm":
        p["layers"] = jax.vmap(lambda k: mamba2.mamba_init(k, cfg))(
            jax.random.split(k_layers, cfg.n_layers))
    elif cfg.family == "hybrid":
        p["layers"] = hybrid.hybrid_init(k_layers, cfg)
    elif cfg.family == "encdec":
        p["layers"] = encdec.encdec_init(k_layers, cfg)
    else:
        raise ValueError(cfg.family)
    if not cfg.tie_embeddings:
        p["head"] = cm.dense_init(k_head, cfg.d_model, cfg.padded_vocab,
                                  scale=cfg.d_model ** -0.5)
    return p


# ---------------------------------------------------------------------------
# Serve-form quantization (rule-based traversal)
# ---------------------------------------------------------------------------

_EXPERT_KEYS = ("wg", "wu", "wd")
_FP_SUBTREES = ("router", "lora")        # precision-sensitive: keep bf16
_SKIP_ARRAYS = ("emb",)                  # gather tables stay bf16


def quantize_params(params: dict, cfg: ModelConfig,
                    container: str = "int8") -> dict:
    """Train-form -> serve-form.  Every linear {"w": (..., K, N)} becomes
    {"q"/"q4", "s"} (per-out-channel scales, stacked dims preserved);
    MoE expert stacks (E, d, f) quantize per expert."""
    import repro.core.bitfluid as bf

    def q_linear(p: dict) -> dict:
        w = p["w"].astype(jnp.float32)
        out = {}
        if container == "int4":
            s = bf.symmetric_scale(w, 4, axis=-2)
            out["q4"] = bf.pack_int4_halves(bf.quantize(w, s, 4))
        else:
            s = bf.symmetric_scale(w, 8, axis=-2)
            out["q"] = bf.quantize(w, s, 8)
        out["s"] = s
        if "b" in p:
            out["b"] = p["b"]
        return out

    def q_expert(w: jnp.ndarray) -> dict:
        w = w.astype(jnp.float32)
        s = bf.symmetric_scale(w, 8, axis=-2)
        return {"q": bf.quantize(w, s, 8), "s": s}

    def rec(node, path):
        if isinstance(node, dict):
            if "w" in node and path[-1] not in _FP_SUBTREES:
                return q_linear(node)
            out = {}
            for k, v in node.items():
                if k in _FP_SUBTREES:
                    out[k] = v
                elif (k in _EXPERT_KEYS and not isinstance(v, dict)
                        and getattr(v, "ndim", 0) == 3):
                    out[k] = q_expert(v)
                else:
                    out[k] = rec(v, path + (k,))
            return out
        return node

    return rec(params, ("",))


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------

def _dense_stack(layers, x, cfg, wvec, avec, positions, cache=None, t=None,
                 mlp_fn=None):
    def body(carry, scanned):
        x = carry
        if cache is not None:
            lp, wb, ab, cl = scanned
        else:
            lp, wb, ab = scanned
            cl = None
        x, new_cl, aux = tf.block(lp, x, cfg, wb, ab, positions=positions,
                                  cache=cl, t=t, mlp_fn=mlp_fn)
        x = dist.constrain(x, ("dp", None, None))
        return x, ((new_cl, aux) if cache is not None else aux)

    if cfg.remat == "full" and cache is None:
        body = jax.checkpoint(body)
    xs = (layers, wvec, avec) + ((cache,) if cache is not None else ())
    x, ys = jax.lax.scan(body, x, xs)
    if cache is not None:
        new_cache, aux = ys
        return x, new_cache, jnp.mean(aux)
    return x, None, jnp.mean(ys)


def _ssm_stack(layers, x, cfg, wvec, avec, cache=None):
    def body(carry, scanned):
        x = carry
        if cache is not None:
            lp, wb, ab, conv, ssm = scanned
            st = {"conv": conv, "ssm": ssm}
        else:
            lp, wb, ab = scanned
            st = None
        x, new_st = mamba2.mamba_block(lp, x, cfg, wb, ab, state=st)
        x = dist.constrain(x, ("dp", None, None))
        return x, ((new_st["conv"], new_st["ssm"]) if cache is not None else ())

    if cfg.remat == "full" and cache is None:
        body = jax.checkpoint(body)
    xs = (layers, wvec, avec)
    if cache is not None:
        xs = xs + (cache["conv"], cache["ssm"])
    x, ys = jax.lax.scan(body, x, xs)
    if cache is not None:
        return x, {"conv": ys[0], "ssm": ys[1]}, jnp.zeros((), jnp.float32)
    return x, None, jnp.zeros((), jnp.float32)


def forward_hidden(params, x, cfg: ModelConfig, wvec, avec, *, positions,
                   cache=None, t=None, enc_out=None):
    """Embedded inputs -> final hidden states.  Returns (h, cache, aux)."""
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return _dense_stack(params["layers"], x, cfg, wvec, avec, positions,
                            cache, t)
    if fam == "moe":
        return _dense_stack(params["layers"], x, cfg, wvec, avec, positions,
                            cache, t, mlp_fn=moe.apply_moe)
    if fam == "ssm":
        return _ssm_stack(params["layers"], x, cfg, wvec, avec, cache)
    if fam == "hybrid":
        h, new_cache = hybrid.hybrid_forward(
            params["layers"], x, cfg, wvec, avec, positions=positions,
            cache=cache, t=t)
        return h, new_cache, jnp.zeros((), jnp.float32)
    if fam == "encdec":
        kv_cache = cache["self"] if cache is not None else None
        if cache is not None and "cross" in cache:
            xkv = cache["cross"]
        else:
            xkv = encdec.cross_kv(params["layers"]["dec"], enc_out, cfg,
                                  wvec[-cfg.n_layers:], avec[-cfg.n_layers:])
        h, new_self = encdec.decoder_forward(
            params["layers"], x, cfg, wvec, avec, positions=positions,
            enc_kv=xkv, cache=kv_cache, t=t)
        new_cache = ({"self": new_self, "cross": xkv}
                     if cache is not None else None)
        return h, new_cache, jnp.zeros((), jnp.float32)
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Embedding / logits / loss
# ---------------------------------------------------------------------------

def embed(params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["emb"], tokens, axis=0)


def logits_fn(params, h: jnp.ndarray, cfg: ModelConfig, wb=8, ab=8):
    h = cm.apply_norm(params["ln_f"], h, cfg.norm_type, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", h.astype(jnp.float32),
                            params["emb"].astype(jnp.float32))
    else:
        logits = cm.apply_linear(params["head"], h, wb, ab
                                 ).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:       # mask padding ids
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


def _xent(logits: jnp.ndarray, targets: jnp.ndarray, mask: jnp.ndarray):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    zloss = jnp.sum((logz * mask) ** 2) / denom
    return jnp.sum(nll) / denom, zloss


def train_loss(params, batch: dict, cfg: ModelConfig, wvec, avec
               ) -> Tuple[jnp.ndarray, dict]:
    tokens = batch["tokens"]
    B, S = tokens.shape
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    mask = jnp.asarray(batch.get("loss_mask", jnp.ones_like(tgt)),
                       jnp.float32)

    x = embed(params, inp)
    enc_out = None
    if cfg.family == "vlm":
        prefix = batch["prefix"].astype(cm.DTYPE)       # (B, P, d) stub
        x = jnp.concatenate([prefix, x], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros((B, prefix.shape[1]), jnp.float32), mask], axis=1)
        tgt = jnp.concatenate(
            [jnp.zeros((B, prefix.shape[1]), tgt.dtype), tgt], axis=1)
    elif cfg.family == "encdec":
        enc_out = encdec.encode(params["layers"], batch["frames"].astype(cm.DTYPE),
                                cfg, wvec, avec)
    x = dist.constrain(x, ("dp", None, None))
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                 (B, x.shape[1]))
    h, _, aux = forward_hidden(params, x, cfg, wvec, avec,
                               positions=positions, enc_out=enc_out)
    logits = logits_fn(params, h, cfg, wvec[-1], avec[-1])
    logits = dist.constrain(logits, ("dp", None, "tp"))
    loss, zloss = _xent(logits, tgt, mask)
    total = loss + 1e-4 * zloss + MOE_AUX_COEF * aux
    return total, {"loss": loss, "zloss": zloss, "moe_aux": aux}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def empty_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    if cfg.family in ("dense", "vlm", "moe"):
        return tf.empty_cache(cfg, batch, max_len)
    if cfg.family == "ssm":
        return mamba2.empty_state(cfg, batch, cfg.n_layers)
    if cfg.family == "hybrid":
        return hybrid.empty_hybrid_cache(cfg, batch, max_len)
    if cfg.family == "encdec":
        frames = max(max_len // cfg.frames_ratio, 1)
        return {
            "self": tf.empty_cache(cfg, batch, max_len),
            "cross": {
                "k": jnp.zeros((cfg.n_layers, batch, frames, cfg.n_kv_heads,
                                cfg.head_dim), cm.DTYPE),
                "v": jnp.zeros((cfg.n_layers, batch, frames, cfg.n_kv_heads,
                                cfg.head_dim), cm.DTYPE),
            },
        }
    raise ValueError(cfg.family)


def prefill(params, batch: dict, cfg: ModelConfig, wvec, avec, cache: dict
            ) -> Tuple[jnp.ndarray, dict]:
    """Full-context forward filling ``cache``; returns last-token logits."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params, tokens)
    enc_out = None
    if cfg.family == "vlm":
        x = jnp.concatenate([batch["prefix"].astype(cm.DTYPE), x], axis=1)
    elif cfg.family == "encdec":
        enc_out = encdec.encode(params["layers"], batch["frames"].astype(cm.DTYPE),
                                cfg, wvec, avec)
        cache = {"self": cache["self"]}        # cross is rebuilt from enc_out
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], (B, x.shape[1]))
    h, new_cache, _ = forward_hidden(params, x, cfg, wvec, avec,
                                     positions=positions, cache=cache,
                                     enc_out=enc_out)
    return logits_fn(params, h[:, -1:], cfg, wvec[-1], avec[-1]), new_cache


def decode_step(params, tok: jnp.ndarray, t, cache: dict, cfg: ModelConfig,
                wvec, avec) -> Tuple[jnp.ndarray, dict]:
    """One decode step: tok (B, 1) int32, t scalar position. Returns
    (logits (B, 1, V), new_cache)."""
    B = tok.shape[0]
    x = embed(params, tok)
    t = jnp.asarray(t, jnp.int32)
    positions = jnp.broadcast_to(t[None, None], (B, 1))
    h, new_cache, _ = forward_hidden(params, x, cfg, wvec, avec,
                                     positions=positions, cache=cache, t=t)
    return logits_fn(params, h, cfg, wvec[-1], avec[-1]), new_cache
