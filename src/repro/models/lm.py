"""LM wiring: embeddings, per-family stacks, loss, prefill/decode, and the
train/serve parameter forms.

Public API (all pure functions, plus the stateful CachePool):
  init_params(cfg, key)                      -> train-form pytree (bf16)
  quantize_params(params, cfg, container)    -> serve-form (int8/int4 + scales)
  train_loss(params, batch, cfg, wvec, avec) -> (loss, metrics)
  prefill(params, batch, cfg, wvec, avec, cache, lengths=None)
                                             -> (last_logits, cache)
  decode_step(params, tok, t, cache, cfg, wvec, avec) -> (logits, cache)
  empty_cache(cfg, batch, max_len)           -> family-specific cache pytree
  CachePool(cfg, n_slots, max_len)           -> slot-based persistent cache
                                                (alloc / free / reset_slot)

``wvec``/``avec`` are per-layer bit vectors (runtime tensors — core/policy):
``(n_layers,)`` shared across the batch, or ``(B, n_layers)`` matrices for
per-request precision (families in PER_ROW_BIT_FAMILIES only); per-family
semantics documented in DESIGN.md §4, serving semantics in §6.
``t`` in decode_step is a scalar (lock-step batch) or ``(B,)`` vector
(per-row positions — continuous batching).  ``lengths`` in prefill marks
per-row valid prompt lengths; padded positions are masked via EMPTY_POS.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import dist
from repro.kernels import ops as kops
from repro.models import common as cm
from repro.models import encdec, hybrid, mamba2, moe, transformer as tf
from repro.models.config import ModelConfig

MOE_AUX_COEF = 0.01

# Families whose layer stacks accept (B, n_layers) per-request bit
# matrices.  MoE resolves a *per-expert* axis instead (DESIGN.md §4);
# hybrid shares one attention block batch-wide; encdec shares the encoder.
PER_ROW_BIT_FAMILIES = ("dense", "vlm", "ssm")
# Families whose prefill supports ragged per-row prompt lengths (attention
# masks padding out; SSM recurrences would consume the pad tokens).
RAGGED_PREFILL_FAMILIES = ("dense", "vlm")


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def n_bit_slots(cfg: ModelConfig) -> int:
    """Length of the per-layer bit vectors for this family."""
    if cfg.family == "encdec":
        return cfg.n_enc_layers + cfg.n_layers
    if cfg.family == "hybrid":
        return hybrid.n_super(cfg)
    return cfg.n_layers


def layer_gemm_dims(cfg: ModelConfig):
    """Per-bit-slot serve GEMV dims: one tuple of (K, N) pairs per slot.

    Each pair is a serve-form linear a single token flows through at that
    slot's precision; ``apsim.metrics.price_bit_vector`` turns these plus
    a resolved (wbits, abits) vector into AP cycles/energy — the serve
    engine's per-request EDP accounting (paper Table 7, live).  Hybrid /
    enc-dec entries are first-order: the shared attention block and the
    cross-attention projections are charged at their slot's bits.
    """
    d = cfg.d_model
    attn = ((d, cfg.n_heads * cfg.head_dim),
            (d, cfg.n_kv_heads * cfg.head_dim),
            (d, cfg.n_kv_heads * cfg.head_dim),
            (cfg.n_heads * cfg.head_dim, d))

    def mlp(f):
        if cfg.mlp_type == "swiglu":
            return ((d, f), (d, f), (f, d))
        return ((d, f), (f, d))

    if cfg.family in ("dense", "vlm"):
        return (attn + mlp(cfg.d_ff),) * cfg.n_layers
    if cfg.family == "moe":
        per = attn + cfg.experts_per_token * mlp(cfg.d_ff)
        if cfg.n_shared_experts:
            per = per + mlp(cfg.d_ff * cfg.n_shared_experts)
        return (per,) * cfg.n_layers
    d_inner, H, N, _ = mamba2.dims(cfg)
    mam = ((d, 2 * d_inner + 2 * N + H), (d_inner, d))    # in/out proj
    if cfg.family == "ssm":
        return (mam,) * cfg.n_layers
    if cfg.family == "hybrid":
        per = attn + mlp(cfg.d_ff) + mam * cfg.attn_every
        return (per,) * hybrid.n_super(cfg)
    if cfg.family == "encdec":
        enc = attn + mlp(cfg.d_ff)
        dec = attn + attn + mlp(cfg.d_ff)                 # self + cross
        return (enc,) * cfg.n_enc_layers + (dec,) * cfg.n_layers
    raise ValueError(cfg.family)


def head_gemm_dims(cfg: ModelConfig):
    """(K, N) of the per-token logits GEMM (priced at the last slot's
    bits, mirroring logits_fn's _last_layer_bits rule)."""
    return (cfg.d_model, cfg.padded_vocab)


def init_params(cfg: ModelConfig, key) -> dict:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    p = {"emb": (jax.random.normal(k_emb, (cfg.padded_vocab, cfg.d_model),
                                   jnp.float32) * 0.02).astype(cm.DTYPE),
         "ln_f": cm.norm_init(cfg.d_model, cfg.norm_type)}
    if cfg.family in ("dense", "vlm"):
        p["layers"] = jax.vmap(lambda k: tf.block_init(k, cfg))(
            jax.random.split(k_layers, cfg.n_layers))
    elif cfg.family == "moe":
        def one(k):
            k1, k2 = jax.random.split(k)
            blk = tf.block_init(k1, cfg)
            del blk["mlp"]
            blk["mlp"] = moe.moe_init(k2, cfg)
            return blk
        p["layers"] = jax.vmap(one)(jax.random.split(k_layers, cfg.n_layers))
    elif cfg.family == "ssm":
        p["layers"] = jax.vmap(lambda k: mamba2.mamba_init(k, cfg))(
            jax.random.split(k_layers, cfg.n_layers))
    elif cfg.family == "hybrid":
        p["layers"] = hybrid.hybrid_init(k_layers, cfg)
    elif cfg.family == "encdec":
        p["layers"] = encdec.encdec_init(k_layers, cfg)
    else:
        raise ValueError(cfg.family)
    if not cfg.tie_embeddings:
        p["head"] = cm.dense_init(k_head, cfg.d_model, cfg.padded_vocab,
                                  scale=cfg.d_model ** -0.5)
    return p


# ---------------------------------------------------------------------------
# Serve-form quantization (rule-based traversal)
# ---------------------------------------------------------------------------

_EXPERT_KEYS = ("wg", "wu", "wd")
_FP_SUBTREES = ("router", "lora")        # precision-sensitive: keep bf16
_SKIP_ARRAYS = ("emb",)                  # gather tables stay bf16


def quantize_params(params: dict, cfg: ModelConfig,
                    container: str = "int8") -> dict:
    """Train-form -> serve-form.  Every linear {"w": (..., K, N)} becomes
    {"q"/"q4", "s"} (per-out-channel scales, stacked dims preserved);
    MoE expert stacks (E, d, f) quantize per expert."""
    import repro.core.bitfluid as bf

    def q_linear(p: dict) -> dict:
        w = p["w"].astype(jnp.float32)
        out = {}
        if container == "int4":
            s = bf.symmetric_scale(w, 4, axis=-2)
            out["q4"] = bf.pack_int4_halves(bf.quantize(w, s, 4))
        else:
            s = bf.symmetric_scale(w, 8, axis=-2)
            out["q"] = bf.quantize(w, s, 8)
        out["s"] = s
        if "b" in p:
            out["b"] = p["b"]
        return out

    def q_expert(w: jnp.ndarray) -> dict:
        w = w.astype(jnp.float32)
        s = bf.symmetric_scale(w, 8, axis=-2)
        return {"q": bf.quantize(w, s, 8), "s": s}

    def rec(node, path):
        if isinstance(node, dict):
            if "w" in node and path[-1] not in _FP_SUBTREES:
                return q_linear(node)
            out = {}
            for k, v in node.items():
                if k in _FP_SUBTREES:
                    out[k] = v
                elif (k in _EXPERT_KEYS and not isinstance(v, dict)
                        and getattr(v, "ndim", 0) == 3):
                    out[k] = q_expert(v)
                else:
                    out[k] = rec(v, path + (k,))
            return out
        return node

    return rec(params, ("",))


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------

def _dense_stack(layers, x, cfg, wvec, avec, positions, cache=None, t=None,
                 mlp_fn=None):
    def body(carry, scanned):
        x = carry
        if cache is not None:
            lp, wb, ab, cl = scanned
        else:
            lp, wb, ab = scanned
            cl = None
        x, new_cl, aux = tf.block(lp, x, cfg, wb, ab, positions=positions,
                                  cache=cl, t=t, mlp_fn=mlp_fn)
        x = dist.constrain(x, ("dp", None, None))
        return x, ((new_cl, aux) if cache is not None else aux)

    if cfg.remat == "full" and cache is None:
        body = jax.checkpoint(body)
    xs = (layers, wvec, avec) + ((cache,) if cache is not None else ())
    x, ys = jax.lax.scan(body, x, xs)
    if cache is not None:
        new_cache, aux = ys
        return x, new_cache, jnp.mean(aux)
    return x, None, jnp.mean(ys)


def _ssm_stack(layers, x, cfg, wvec, avec, cache=None):
    def body(carry, scanned):
        x = carry
        if cache is not None:
            lp, wb, ab, conv, ssm = scanned
            st = {"conv": conv, "ssm": ssm}
        else:
            lp, wb, ab = scanned
            st = None
        x, new_st = mamba2.mamba_block(lp, x, cfg, wb, ab, state=st)
        x = dist.constrain(x, ("dp", None, None))
        return x, ((new_st["conv"], new_st["ssm"]) if cache is not None else ())

    if cfg.remat == "full" and cache is None:
        body = jax.checkpoint(body)
    xs = (layers, wvec, avec)
    if cache is not None:
        xs = xs + (cache["conv"], cache["ssm"])
    x, ys = jax.lax.scan(body, x, xs)
    if cache is not None:
        return x, {"conv": ys[0], "ssm": ys[1]}, jnp.zeros((), jnp.float32)
    return x, None, jnp.zeros((), jnp.float32)


def _layer_major(vec, family: str):
    """Normalize a bit table for the layer scan: (L,) stays; a per-request
    (B, L) matrix transposes to (L, B) so each scanned layer sees a (B,)
    per-row bit vector (the apply_linear vmap path)."""
    v = jnp.asarray(vec)
    if v.ndim == 2:
        if family not in PER_ROW_BIT_FAMILIES:
            raise NotImplementedError(
                f"per-request (B, n_layers) bit matrices are not supported "
                f"for family {family!r} (see DESIGN.md §4)")
        return v.T
    return v


def forward_hidden(params, x, cfg: ModelConfig, wvec, avec, *, positions,
                   cache=None, t=None, enc_out=None):
    """Embedded inputs -> final hidden states.  Returns (h, cache, aux)."""
    fam = cfg.family
    wvec = _layer_major(wvec, fam)
    avec = _layer_major(avec, fam)
    if fam in ("dense", "vlm"):
        return _dense_stack(params["layers"], x, cfg, wvec, avec, positions,
                            cache, t)
    if fam == "moe":
        return _dense_stack(params["layers"], x, cfg, wvec, avec, positions,
                            cache, t, mlp_fn=moe.apply_moe)
    if fam == "ssm":
        return _ssm_stack(params["layers"], x, cfg, wvec, avec, cache)
    if fam == "hybrid":
        h, new_cache = hybrid.hybrid_forward(
            params["layers"], x, cfg, wvec, avec, positions=positions,
            cache=cache, t=t)
        return h, new_cache, jnp.zeros((), jnp.float32)
    if fam == "encdec":
        kv_cache = cache["self"] if cache is not None else None
        if cache is not None and "cross" in cache:
            xkv = cache["cross"]
        else:
            xkv = encdec.cross_kv(params["layers"]["dec"], enc_out, cfg,
                                  wvec[-cfg.n_layers:], avec[-cfg.n_layers:])
        h, new_self = encdec.decoder_forward(
            params["layers"], x, cfg, wvec, avec, positions=positions,
            enc_kv=xkv, cache=kv_cache, t=t)
        new_cache = ({"self": new_self, "cross": xkv}
                     if cache is not None else None)
        return h, new_cache, jnp.zeros((), jnp.float32)
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Embedding / logits / loss
# ---------------------------------------------------------------------------

def embed(params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["emb"], tokens, axis=0)


def logits_fn(params, h: jnp.ndarray, cfg: ModelConfig, wb=8, ab=8):
    h = cm.apply_norm(params["ln_f"], h, cfg.norm_type, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", h.astype(jnp.float32),
                            params["emb"].astype(jnp.float32))
    else:
        logits = cm.apply_linear(params["head"], h, wb, ab
                                 ).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:       # mask padding ids
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


def _xent(logits: jnp.ndarray, targets: jnp.ndarray, mask: jnp.ndarray):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    zloss = jnp.sum((logz * mask) ** 2) / denom
    return jnp.sum(nll) / denom, zloss


def train_loss(params, batch: dict, cfg: ModelConfig, wvec, avec
               ) -> Tuple[jnp.ndarray, dict]:
    tokens = batch["tokens"]
    B, S = tokens.shape
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    mask = jnp.asarray(batch.get("loss_mask", jnp.ones_like(tgt)),
                       jnp.float32)

    x = embed(params, inp)
    enc_out = None
    if cfg.family == "vlm":
        prefix = batch["prefix"].astype(cm.DTYPE)       # (B, P, d) stub
        x = jnp.concatenate([prefix, x], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros((B, prefix.shape[1]), jnp.float32), mask], axis=1)
        tgt = jnp.concatenate(
            [jnp.zeros((B, prefix.shape[1]), tgt.dtype), tgt], axis=1)
    elif cfg.family == "encdec":
        enc_out = encdec.encode(params["layers"], batch["frames"].astype(cm.DTYPE),
                                cfg, wvec, avec)
    x = dist.constrain(x, ("dp", None, None))
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                 (B, x.shape[1]))
    h, _, aux = forward_hidden(params, x, cfg, wvec, avec,
                               positions=positions, enc_out=enc_out)
    logits = logits_fn(params, h, cfg, _last_layer_bits(wvec),
                       _last_layer_bits(avec))
    logits = dist.constrain(logits, ("dp", None, "tp"))
    loss, zloss = _xent(logits, tgt, mask)
    total = loss + 1e-4 * zloss + MOE_AUX_COEF * aux
    return total, {"loss": loss, "zloss": zloss, "moe_aux": aux}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def empty_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    if cfg.family in ("dense", "vlm", "moe"):
        return tf.empty_cache(cfg, batch, max_len)
    if cfg.family == "ssm":
        return mamba2.empty_state(cfg, batch, cfg.n_layers)
    if cfg.family == "hybrid":
        return hybrid.empty_hybrid_cache(cfg, batch, max_len)
    if cfg.family == "encdec":
        frames = max(max_len // cfg.frames_ratio, 1)
        return {
            "self": tf.empty_cache(cfg, batch, max_len),
            "cross": {
                "k": jnp.zeros((cfg.n_layers, batch, frames, cfg.n_kv_heads,
                                cfg.head_dim), cm.DTYPE),
                "v": jnp.zeros((cfg.n_layers, batch, frames, cfg.n_kv_heads,
                                cfg.head_dim), cm.DTYPE),
            },
        }
    raise ValueError(cfg.family)


def _last_layer_bits(vec):
    """Bits for the head GEMM: scalar for (L,) tables, (B,) for (B, L)."""
    return jnp.asarray(vec)[..., -1]


def prefill(params, batch: dict, cfg: ModelConfig, wvec, avec, cache: dict,
            lengths=None) -> Tuple[jnp.ndarray, dict]:
    """Full-context forward filling ``cache``; returns last-token logits.

    ``lengths`` (B,) marks per-row valid prompt lengths for right-padded
    batches (continuous batching): padded positions take EMPTY_POS (never
    visible to real queries, never visible in the cache), and the returned
    logits are gathered at each row's own last real token."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params, tokens)
    enc_out = None
    prefix_len = 0
    if cfg.family == "vlm":
        prefix_len = batch["prefix"].shape[1]
        x = jnp.concatenate([batch["prefix"].astype(cm.DTYPE), x], axis=1)
    elif cfg.family == "encdec":
        enc_out = encdec.encode(params["layers"], batch["frames"].astype(cm.DTYPE),
                                cfg, wvec, avec)
        cache = {"self": cache["self"]}        # cross is rebuilt from enc_out
    Sx = x.shape[1]
    if lengths is None:
        # (1, Sx): rows share positions, so attention keeps its shared
        # (S, S) mask instead of materializing a (B, S, S) batched one
        positions = jnp.arange(Sx, dtype=jnp.int32)[None]
    else:
        if cfg.family not in RAGGED_PREFILL_FAMILIES:
            raise NotImplementedError(
                f"ragged (per-row lengths) prefill is not supported for "
                f"family {cfg.family!r} (see DESIGN.md §6)")
        if Sx > tf.FLASH_THRESHOLD:
            raise NotImplementedError(
                "ragged prefill uses the masked-SDPA path; keep the padded "
                f"prompt length <= {tf.FLASH_THRESHOLD}")
        lens = jnp.asarray(lengths, jnp.int32).reshape(B) + prefix_len
        pos = jnp.arange(Sx, dtype=jnp.int32)[None]
        valid = pos < lens[:, None]                       # (B, Sx)
        positions = jnp.where(valid, pos, tf.EMPTY_POS)
        # zero pad embeddings so per-row dynamic activation scales see only
        # real tokens (keeps ragged rows numerically close to standalone)
        x = jnp.where(valid[..., None], x, 0).astype(x.dtype)
    h, new_cache, _ = forward_hidden(params, x, cfg, wvec, avec,
                                     positions=positions, cache=cache,
                                     enc_out=enc_out)
    if lengths is None:
        h_last = h[:, -1:]
    else:
        idx = jnp.maximum(lens - 1, 0)[:, None, None]
        h_last = jnp.take_along_axis(h, idx, axis=1)
    return (logits_fn(params, h_last, cfg, _last_layer_bits(wvec),
                      _last_layer_bits(avec)), new_cache)


def decode_step(params, tok: jnp.ndarray, t, cache: dict, cfg: ModelConfig,
                wvec, avec) -> Tuple[jnp.ndarray, dict]:
    """One decode step: tok (B, 1) int32, t scalar or (B,) positions.
    Returns (logits (B, 1, V), new_cache)."""
    B = tok.shape[0]
    x = embed(params, tok)
    t = jnp.asarray(t, jnp.int32)
    positions = jnp.broadcast_to(t, (B,))[:, None]        # (B, 1)
    h, new_cache, _ = forward_hidden(params, x, cfg, wvec, avec,
                                     positions=positions, cache=cache, t=t)
    return (logits_fn(params, h, cfg, _last_layer_bits(wvec),
                      _last_layer_bits(avec)), new_cache)


# Families whose decode supports chunked (multi-position) steps — the
# speculative-verify forward.  Attention masks future positions exactly;
# SSM/hybrid recurrences have no per-position rollback.
SPEC_CHUNK_FAMILIES = ("dense", "vlm")


def decode_chunk(params, toks: jnp.ndarray, t, cache: dict, cfg: ModelConfig,
                 wvec, avec) -> Tuple[jnp.ndarray, dict]:
    """Decode U consecutive positions per row in ONE forward.

    ``toks`` (B, U) int32 with ``toks[:, i]`` at position ``t + i``
    (``t`` scalar or (B,)).  This is the speculative-verify step: the
    chunked attention branch writes the same ring slots sequential decode
    would, each query sees exactly its ``kpos <= pos`` prefix, and
    activations quantize under per-token scales (``kops.token_scale_mode``)
    — so on the per-row bit-matrix path the returned logits are
    bit-identical to U sequential :func:`decode_step` calls (the verify
    invariant; DESIGN.md §11).  Returns (logits (B, U, V), new_cache).
    """
    if cfg.family not in SPEC_CHUNK_FAMILIES:
        raise NotImplementedError(
            f"chunked decode is implemented for the attention families "
            f"{SPEC_CHUNK_FAMILIES}, not {cfg.family!r}")
    B, U = toks.shape
    x = embed(params, toks)
    t = jnp.asarray(t, jnp.int32)
    positions = (jnp.broadcast_to(t, (B,))[:, None]
                 + jnp.arange(U, dtype=jnp.int32)[None])   # (B, U)
    with kops.token_scale_mode():
        h, new_cache, _ = forward_hidden(params, x, cfg, wvec, avec,
                                         positions=positions, cache=cache,
                                         t=t)
        logits = logits_fn(params, h, cfg, _last_layer_bits(wvec),
                           _last_layer_bits(avec))
    return logits, new_cache


# ---------------------------------------------------------------------------
# Slot-based persistent cache pool (continuous batching)
# ---------------------------------------------------------------------------

class CachePool:
    """A persistent, slot-based KV/SSM cache for continuous batching.

    The pool owns ONE device cache pytree of batch capacity ``n_slots``
    that lives across requests: ``alloc()`` hands out a free slot,
    ``write_row`` installs a freshly prefilled single-row cache into it
    (a traced-index dynamic_update_slice — slot churn never retraces),
    ``free``/``reset_slot`` recycle it.  Per-slot valid lengths live
    host-side in ``lengths``; visibility inside attention is carried by
    the per-row ``kpos`` columns, so a reset slot is invisible by
    construction (EMPTY_POS) rather than by zeroing data.
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int,
                 shardings=None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = empty_cache(cfg, n_slots, max_len)
        if shardings is not None:
            self.cache = jax.device_put(self.cache, shardings)
        self.lengths = np.zeros((n_slots,), np.int64)
        self._free = list(range(n_slots - 1, -1, -1))

        def write_row(pool, row, slot):
            return jax.tree.map(
                lambda p, r: jax.lax.dynamic_update_slice(
                    p, r.astype(p.dtype),
                    (0, slot) + (0,) * (p.ndim - 2)),
                pool, row)

        def install_row(pool, row, slot, keep):
            # write_row + a visibility clamp: positions >= keep in the
            # incoming row are masked EMPTY, so a cached row installs as
            # exactly its first ``keep`` tokens (partial-prefix hits)
            def leaf(path, p, r):
                r = r.astype(p.dtype)
                if path and path[-1] == "kpos":
                    r = jnp.where(r >= keep, tf.EMPTY_POS, r)
                return jax.lax.dynamic_update_slice(
                    p, r, (0, slot) + (0,) * (p.ndim - 2))
            return jax.tree_util.tree_map_with_path(
                lambda path, p, r: leaf(tuple(
                    str(getattr(k, "key", k)) for k in path), p, r),
                pool, row)

        def copy_row(pool, src, dst):
            def leaf(p):
                row = jax.lax.dynamic_slice(
                    p, (0, src) + (0,) * (p.ndim - 2),
                    (p.shape[0], 1) + p.shape[2:])
                return jax.lax.dynamic_update_slice(
                    p, row, (0, dst) + (0,) * (p.ndim - 2))
            return jax.tree.map(leaf, pool)

        def reset_row(pool, slot):
            def leaf(p, path):
                if path and path[-1] == "kpos":
                    empty = jnp.full((p.shape[0], 1) + p.shape[2:],
                                     tf.EMPTY_POS, p.dtype)
                    return jax.lax.dynamic_update_slice(
                        p, empty, (0, slot) + (0,) * (p.ndim - 2))
                return p
            return jax.tree_util.tree_map_with_path(
                lambda path, p: leaf(p, tuple(
                    str(getattr(k, "key", k)) for k in path)), pool)

        def rollback_rows(pool, keeps):
            # speculative-decode rejection: entries past keeps[slot] go
            # invisible (kpos -> EMPTY_POS); K/V payloads stay in place,
            # masked by kpos exactly like reset_slot.  kpos leaves are
            # (L, n_slots, Sc); rows outside the spec round pass
            # keep >= EMPTY_POS and are untouched.
            def leaf(path, p):
                if path and path[-1] == "kpos":
                    return jnp.where(p > keeps[None, :, None],
                                     tf.EMPTY_POS, p)
                return p
            return jax.tree_util.tree_map_with_path(
                lambda path, p: leaf(tuple(
                    str(getattr(k, "key", k)) for k in path), p), pool)

        self._write = jax.jit(write_row, donate_argnums=(0,))
        self._install = jax.jit(install_row, donate_argnums=(0,))
        self._copy = jax.jit(copy_row, donate_argnums=(0,))
        self._reset = jax.jit(reset_row, donate_argnums=(0,))
        self._rollback = jax.jit(rollback_rows, donate_argnums=(0,))

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def alloc(self) -> Optional[int]:
        """Claim a free slot (None when the pool is full)."""
        return self._free.pop() if self._free else None

    def free(self, slot: int) -> None:
        """Return a slot to the pool and mask its cache row."""
        if slot in self._free:
            raise ValueError(f"slot {slot} double-freed")
        self.reset_slot(slot)
        self._free.append(slot)

    def reset_slot(self, slot: int) -> None:
        """Mask a slot's cache row (kpos -> EMPTY_POS) and zero its length."""
        self.lengths[slot] = 0
        self.cache = self._reset(self.cache, jnp.asarray(slot, jnp.int32))

    def _check_install(self, slot: int, length: int) -> None:
        """Guard every row install: silent corruption otherwise (an
        out-of-range length poisons the host-side length table, and a
        write into an unallocated slot is clobbered by the next
        ``alloc`` — double-free is caught, so double-install must be
        too)."""
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range "
                             f"[0, {self.n_slots})")
        if slot in self._free:
            raise ValueError(f"slot {slot} is free — alloc() it before "
                             f"installing a row")
        if not 0 <= length <= self.max_len:
            raise ValueError(f"row length {length} not in "
                             f"[0, max_len={self.max_len}]")

    def write_row(self, row_cache, slot: int, length: int) -> None:
        """Install a prefilled single-row cache into ``slot``."""
        self._check_install(slot, length)
        self.lengths[slot] = length
        self.cache = self._write(self.cache, row_cache,
                                 jnp.asarray(slot, jnp.int32))

    def install_prefix(self, row_cache, slot: int, keep: int) -> None:
        """Install the first ``keep`` tokens of a cached single-row
        cache into ``slot`` (the prefix-cache hit path): positions
        >= ``keep`` are masked EMPTY on the way in, and the source row
        is copied, never donated — the cache tier keeps its entry."""
        self._check_install(slot, keep)
        self.lengths[slot] = keep
        self.cache = self._install(self.cache, row_cache,
                                   jnp.asarray(slot, jnp.int32),
                                   jnp.asarray(keep, jnp.int32))

    def rollback(self, keeps) -> None:
        """Mask every cache entry past ``keeps[slot]`` per slot (the
        speculative-decode rejection path): ``kpos > keep`` becomes
        EMPTY_POS across all layers.  ``keeps`` is an ``(n_slots,)``
        int32 vector of last-kept absolute positions; slots not in a
        speculative round pass any value >= EMPTY_POS (no-op).  Runs as
        one jitted donate-in-place masking — no retrace across rounds."""
        self.cache = self._rollback(self.cache,
                                    jnp.asarray(keeps, jnp.int32))

    def copy_row(self, src: int, dst: int,
                 length: Optional[int] = None) -> None:
        """Duplicate one resident row into another allocated slot
        (traced-index gather + write — no retrace, no host copy)."""
        if src in self._free:
            raise ValueError(f"source slot {src} is free — nothing to "
                             f"copy")
        self._check_install(dst, int(self.lengths[src]
                                     if length is None else length))
        self.lengths[dst] = (self.lengths[src] if length is None
                             else length)
        self.cache = self._copy(self.cache, jnp.asarray(src, jnp.int32),
                                jnp.asarray(dst, jnp.int32))
