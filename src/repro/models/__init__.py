"""Model zoo: every assigned architecture family + the paper's CNNs.

All GEMMs route through the bit-fluid linear (models/common.apply_linear):
training uses fake-quant STE at per-layer runtime bits; serving uses int8/
int4 containers with dyadic runtime requantization (core/bitfluid).
"""
