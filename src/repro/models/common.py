"""Shared building blocks: norms, RoPE, the bit-fluid linear, init helpers.

Parameter conventions
---------------------
Every linear is a dict ``{"w": (K, N) [, "b": (N,)]}`` in training form, or
``{"q": int8 (K, N), "s": f32 (1, N) [, "b"]}`` (int8 container) /
``{"q4": uint8 (K, N/2), "s": ...}`` (packed int4 container) in serving
form.  :func:`apply_linear` dispatches on the keys, so every model runs
both modes through one code path, and per-layer ``wbits`` / ``abits`` may
be traced scalars (bit fluidity as data — see core/bitfluid).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import bitfluid as bf
from repro.kernels import ops as kops

DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               scale: Optional[float] = None, dtype=DTYPE):
    w_scale = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
               * w_scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def quantize_linear(p: dict, container: str = "int8") -> dict:
    """Training-form linear -> serving-form (int8 or packed-int4 container).

    ``w`` may be ``(K, N)`` or a stack ``(G, K, N)`` (grouped-conv /
    expert stacks): scales are per-out-channel along the reduction axis
    (``axis=-2``), so every stacked slice quantizes independently."""
    w = p["w"].astype(jnp.float32)
    out = {}
    if container == "int4":
        s = bf.symmetric_scale(w, 4, axis=-2)
        q = bf.quantize(w, s, 4)
        out["q4"] = bf.pack_int4_halves(q)
        out["s"] = s
    else:
        s = bf.symmetric_scale(w, 8, axis=-2)
        out["q"] = bf.quantize(w, s, 8)
        out["s"] = s
    if "b" in p:
        out["b"] = p["b"]
    return out


# ---------------------------------------------------------------------------
# The bit-fluid linear
# ---------------------------------------------------------------------------

def apply_linear(p: dict, x: jnp.ndarray, wbits=8, abits=8) -> jnp.ndarray:
    """y = x @ W (+b) at runtime precisions; dispatches train/serve forms.

    ``wbits``/``abits`` are scalars (shared precision — the fast path) or
    ``(B,)`` vectors matching ``x``'s leading axis (per-request precision:
    serving batches whose rows carry different latency budgets).

    Serve-form containers ({"q"/"q4", "s"}) dispatch wholesale through the
    kernel layer (:func:`repro.kernels.ops.serve_linear`): scalar bits take
    the container path, per-row bits the bit-grouped batch path — one
    weight requantization and one GEMM per distinct bit family instead of
    per row, with rows numerically independent of their batch-mates
    (DESIGN.md §3/§6).  Train form stays here: fake-quant STE is float
    math, not a quantized kernel.
    """
    per_row = (getattr(wbits, "ndim", 0) >= 1
               or getattr(abits, "ndim", 0) >= 1)
    if "w" in p:                                     # train: fake-quant STE
        if per_row:
            B = x.shape[0]
            wb = jnp.broadcast_to(jnp.asarray(wbits, jnp.int32), (B,))
            ab = jnp.broadcast_to(jnp.asarray(abits, jnp.int32), (B,))
            return jax.vmap(lambda xr, w, a: _train_linear(p, xr, w, a))(
                x, wb, ab)
        return _train_linear(p, x, wbits, abits)
    return kops.serve_linear(p, x, wbits, abits).astype(DTYPE)


def _train_linear(p: dict, x: jnp.ndarray, wbits, abits) -> jnp.ndarray:
    """Scalar-bits fake-quant (STE) linear — the QAT path."""
    # stay bf16 END-TO-END around the dot (fake_quant rounds in f32
    # internally but preserves input dtype): both the forward TP
    # partial sums AND the backward dx cotangant reductions then move
    # bf16 — the dominant train all-reduces were f32 activation-shaped
    # cotangents from an f32 round-trip here (§Perf iter 6)
    w = bf.fake_quant(p["w"], wbits, axis=0)
    xq = bf.fake_quant(x.astype(DTYPE), abits)
    y = jnp.einsum("...k,kn->...n", xq, w,
                   preferred_element_type=DTYPE).astype(jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(DTYPE)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
            ).astype(x.dtype)


def apply_norm(p: dict, x: jnp.ndarray, kind: str, eps: float = 1e-5):
    if kind == "layer":
        return layer_norm(x, p["scale"], p["bias"], eps)
    return rms_norm(x, p["scale"], eps)


def norm_init(d: int, kind: str, dtype=DTYPE) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layer":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : hd // 2], x32[..., hd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Masks (iota-based: cheap to constant-fold, never materialized at scale)
# ---------------------------------------------------------------------------

def causal_mask_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                     window: int = 0) -> jnp.ndarray:
    """Additive attention bias (Sq, Sk): 0 where visible, -inf elsewhere.

    ``window`` > 0 adds the sliding-window band (starcoder2)."""
    visible = k_pos[None, :] <= q_pos[:, None]
    if window:
        visible &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(visible, 0.0, -jnp.inf).astype(jnp.float32)


def causal_mask_bias_batched(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                             window: int = 0) -> jnp.ndarray:
    """Per-row additive bias (B, Sq, Sk) from per-row positions (B, S).

    Used when rows carry different valid lengths (continuous-batching
    prefill): padded tokens sit at ``EMPTY_POS`` (a huge positive
    sentinel), so real queries never see them, while padded queries still
    see the padded keys — their softmax stays finite and their outputs
    are discarded by the length-indexed logits gather."""
    visible = k_pos[:, None, :] <= q_pos[:, :, None]
    if window:
        visible &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    return jnp.where(visible, 0.0, -jnp.inf).astype(jnp.float32)
