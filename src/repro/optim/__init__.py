from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from repro.optim.compress import compress_psum  # noqa: F401
