"""AdamW with large-scale memory options, as pure pytree transforms.

Moment storage is configurable per the 1000-node posture (DESIGN.md §5):

  m_dtype:  float32 | bfloat16 | int8   (int8 = block-quantized 8-bit Adam
            with per-slice scales, Dettmers-style — 4x smaller than f32)
  v_mode:   full | factored              (factored = Adafactor row/col rank-1
            second moment: O(K+N) instead of O(K*N) — the only way a 1T-param
            model's optimizer state approaches a 512-chip pod)

State leaves mirror parameter sharding, so FSDP shards moments too.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    m_dtype: str = "float32"          # float32 | bfloat16 | int8
    v_mode: str = "full"              # full | factored
    int8_block: int = 256


# ---------------------------------------------------------------------------
# int8 moment codec — SHAPE-PRESERVING (per-last-axis-row absmax scale).
#
# A flatten-to-(nb, block) codec is slightly more accurate but its reshape
# is inexpressible to the SPMD partitioner, so every optimizer temp (m_f,
# v_hat, update) materializes REPLICATED — on the 1T-param config that was
# 7.8 TB/device of temp (§Perf kimi iteration log).  Keeping q the exact
# parameter shape lets all Adam intermediates inherit parameter sharding.
# ---------------------------------------------------------------------------

def _enc_i8(x: jnp.ndarray, block: int = 0) -> dict:
    s = (jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
         ).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    return {"q": q, "s": s}


def _dec_i8(enc: dict, shape=None, block: int = 0) -> jnp.ndarray:
    return enc["q"].astype(jnp.float32) * enc["s"]


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def _is_codec(x) -> bool:
    return isinstance(x, dict) and set(x.keys()) == {"q", "s"}


def _is_fact(x) -> bool:
    return isinstance(x, dict) and set(x.keys()) == {"vr", "vc"}


# ---------------------------------------------------------------------------

def adamw_init(params, cfg: AdamWConfig) -> dict:
    def init_m(p):
        if cfg.m_dtype == "int8":
            return _enc_i8(jnp.zeros(p.shape, jnp.float32))
        return jnp.zeros(p.shape, jnp.dtype(cfg.m_dtype))

    def init_v(p):
        if cfg.v_mode == "factored" and _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(init_m, params),
        "v": jax.tree.map(init_v, params),
    }


def global_norm(grads) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def adamw_update(params, grads, state: dict, cfg: AdamWConfig
                 ) -> Tuple[dict, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    treedef = jax.tree.structure(params)
    p_l = jax.tree.leaves(params)
    g_l = jax.tree.leaves(grads)
    m_l = jax.tree.leaves(state["m"], is_leaf=_is_codec)
    v_l = jax.tree.leaves(state["v"], is_leaf=_is_fact)

    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(p_l, g_l, m_l, v_l):
        g = g.astype(jnp.float32) * clip
        m_f = _dec_i8(m) if isinstance(m, dict) else m.astype(jnp.float32)
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        if isinstance(v, dict):                      # factored second moment
            g2 = jnp.square(g) + 1e-30
            vr = cfg.b2 * v["vr"] + (1 - cfg.b2) * jnp.mean(g2, axis=-1)
            vc = cfg.b2 * v["vc"] + (1 - cfg.b2) * jnp.mean(g2, axis=-2)
            v_hat = (vr[..., None] * vc[..., None, :]
                     / (jnp.mean(vr, axis=-1, keepdims=True)[..., None] + 1e-30))
            new_v.append({"vr": vr, "vc": vc})
        else:
            v_hat = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
            new_v.append(v_hat)
        upd = (m_f / bc1) / (jnp.sqrt(v_hat / bc2) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - cfg.lr * upd).astype(p.dtype))
        if cfg.m_dtype == "int8":
            new_m.append(_enc_i8(m_f))
        else:
            new_m.append(m_f.astype(jnp.dtype(cfg.m_dtype)))

    mk = lambda leaves: jax.tree.unflatten(treedef, leaves)
    new_state = {"step": step, "m": mk(new_m), "v": mk(new_v)}
    return mk(new_p), new_state, {"grad_norm": gnorm,
                                  "clip": clip}
