"""int8 gradient compression with error feedback for cross-pod all-reduce.

At 1000+ nodes the pod-level (DCI) all-reduce is the scarcest bandwidth.
``compress_psum`` quantizes each gradient leaf to int8 with a per-leaf
scale before ``psum`` over the given axis and keeps the quantization
residual in an error-feedback buffer (added back next step), which keeps
SGD/Adam convergence unbiased in expectation — a standard 1-bit/8-bit Adam
style trick.

Usable only inside shard_map/pmap (named-axis collectives); the pjit train
path instead relies on XLA's sharding-propagated all-reduces, with the
compressed variant exposed for the explicit-collective launcher.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress_psum(grads, err, axis_name: str) -> Tuple[dict, dict]:
    """Returns (averaged_grads, new_err).

    The quantization scale is the GLOBAL absmax (one scalar pmax) so the
    int32 sum dequantizes exactly — per-shard scales would corrupt the sum
    by the scale spread (a measured ~2.5% bias before this fix)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        s = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_name) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(g32 / s), -127, 127).astype(jnp.int8)
        new_e = g32 - q.astype(jnp.float32) * s         # error feedback
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (q_sum.astype(jnp.float32) * s / n).astype(g.dtype), new_e

    out = jax.tree.map(one, grads, err)
    avg = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return avg, new_err
