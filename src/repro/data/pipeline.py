"""Deterministic synthetic data pipeline with per-host sharding + prefetch.

``batch = f(seed, step)`` is a *pure function* — restarting after a crash
or re-issuing a straggler's shard replays identical data with no iterator
state to checkpoint (only the step number, which lives in the optimizer
state).  Each host materializes only its slice (``host_slice``); a
background thread keeps a small prefetch queue ahead of the training loop.

The synthetic stream is a mixture of Zipf-distributed tokens and short
repeated motifs, so models show a real (falling) loss curve in the
examples without any dataset dependency.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import sharding as dist_sharding


def make_batch(seed: int, step: int, batch: int, seq_len: int,
               vocab: int, cfg=None) -> dict:
    """Pure (seed, step) -> batch.  Adds modality-stub inputs per family."""
    rng = np.random.default_rng(np.uint64(seed) + np.uint64(step) * 1000003)
    # Zipf body + motif repetitions (gives n-gram structure to learn)
    body = rng.zipf(1.3, size=(batch, seq_len)).astype(np.int64) % vocab
    motif_len = 16
    motif = rng.integers(0, vocab, (batch, motif_len))
    reps = seq_len // (4 * motif_len)
    for r in range(reps):
        at = (r * 4 + 1) * motif_len
        body[:, at:at + motif_len] = motif
    out = {"tokens": jnp.asarray(body, jnp.int32)}
    if cfg is not None and cfg.family == "vlm":
        out["prefix"] = jnp.asarray(
            rng.standard_normal((batch, cfg.n_prefix_tokens, cfg.d_model)),
            jnp.bfloat16)
    if cfg is not None and cfg.family == "encdec":
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, max(seq_len // cfg.frames_ratio, 1),
                                 cfg.d_model)), jnp.bfloat16)
    return out


def host_slice(global_batch: int) -> slice:
    """This host's batch rows (data-parallel across processes)."""
    per = global_batch // max(jax.process_count(), 1)
    i = jax.process_index()
    return slice(i * per, (i + 1) * per)


class SyntheticLM:
    """Prefetching iterator over make_batch(seed, step).

    When a dist mesh is active at iteration time, batches are device_put
    with their dp-sharded placement (``dist.sharding.shard_batch``) so the
    train step never re-lays-out its inputs; off-mesh this is an identity.
    """

    def __init__(self, seed: int, batch: int, seq_len: int, vocab: int,
                 cfg=None, start_step: int = 0, prefetch: int = 2,
                 shard: bool = True):
        self.seed, self.batch, self.seq_len, self.vocab = seed, batch, seq_len, vocab
        self.cfg = cfg
        self.shard = shard
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self) -> None:
        s = self.step
        while not self._stop.is_set():
            b = make_batch(self.seed, s, self.batch, self.seq_len,
                           self.vocab, self.cfg)
            try:
                self._q.put((s, b), timeout=1.0)
                s += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        s, b = self._q.get()
        self.step = s + 1
        if self.shard:
            b = dist_sharding.shard_batch(b)
        return s, b

    def close(self) -> None:
        self._stop.set()
