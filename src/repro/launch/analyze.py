"""Static-analysis CLI (DESIGN.md §12): the four-pass auditor suite.

    PYTHONPATH=src python -m repro.launch.analyze --all

runs the AST linter (host-sync / nondeterminism / RNG / static-bit
rules over the registered hot paths), the retrace auditor (one abstract
signature per compiled entrypoint across every config × budget × k ×
(start, length) variant), the sharding checker (every pspec divides
every 1/2/4/8-device mesh for all ten FULL configs), and the ledger
auditor (every ``CostRecord`` field written in ``serve/`` is consumed
by ``aggregate()`` or waived).  Exit status 0 iff no fresh findings and
no stale baseline entries — the blocking CI ``analysis`` job and
``benchmarks/compare.py``'s baseline-update guard both ride on it.

``--json PATH`` writes the machine-readable result (compare.py reads
it to stamp analysis status into the step summary without re-running
the suite).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro import analysis


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.launch.analyze",
        description="static retrace/host-sync/sharding/ledger auditors")
    p.add_argument("--all", action="store_true",
                   help="run every pass (same as naming all four)")
    for name in analysis.ALL_PASSES:
        p.add_argument(f"--{name}", action="store_true",
                       help=f"run the {name} pass")
    p.add_argument("--configs", nargs="*", default=None, metavar="ARCH",
                   help="restrict retrace/sharding to these arch ids")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="write the machine-readable suite result here")
    p.add_argument("--baseline", default=None, metavar="PATH",
                   help="override the checked-in baseline file")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    passes = [n for n in analysis.ALL_PASSES if getattr(args, n)]
    if args.all or not passes:
        passes = list(analysis.ALL_PASSES)

    t0 = time.time()
    res = analysis.run_suite(passes, arch_ids=args.configs,
                             baseline_path=args.baseline)
    dt = time.time() - t0

    for pr in res.passes:
        status = "ok" if pr.ok else f"{len(pr.fresh)} finding(s)"
        extra = f" ({pr.notes[0]})" if pr.notes else ""
        print(f"[{pr.name}] {status}{extra}")
        for f in pr.fresh:
            print("  " + f.render().replace("\n", "\n  "))
        if pr.suppressed:
            print(f"  {len(pr.suppressed)} finding(s) suppressed by "
                  f"baseline")
    for e in res.stale_baseline:
        print(f"[baseline] STALE entry {e['rule']} {e['file']} "
              f"(match: {e['match']!r}): suppressed nothing — remove it")

    verdict = "PASS" if res.ok else "FAIL"
    print(f"analysis: {verdict} "
          f"({', '.join(p.name for p in res.passes)}; {dt:.1f}s)")

    if args.json:
        payload = res.to_dict()
        payload["elapsed_s"] = round(dt, 2)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")
    return 0 if res.ok else 1


if __name__ == "__main__":
    sys.exit(main())
