"""Production training driver: data pipeline -> pjit train step ->
checkpoint/restart -> straggler watchdog.

Single-host usage (CPU or one TPU VM):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_4b --smoke \\
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a pod: run under the TPU launcher (one process per host); the data
pipeline shards by process, the mesh comes from make_production_mesh(),
and restarts resume from the latest atomic checkpoint — kill any host and
relaunch to see the fault-tolerance path.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import time

import jax

from repro import configs, dist
from repro.data.pipeline import SyntheticLM
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.loop import TrainConfig, make_train_step
from repro.train.watchdog import StragglerWatchdog


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--wbits", type=int, nargs="+", default=[8])
    ap.add_argument("--abits", type=int, nargs="+", default=[8])
    ap.add_argument("--tp", type=int, default=1,
                    help="model-parallel ways of the host mesh")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=args.lr),
                       n_accum=args.accum,
                       wbits=tuple(args.wbits), abits=tuple(args.abits))

    # Mesh over whatever devices exist; with one device everything below
    # (constraints, placements, batch sharding) degrades to the identity.
    mesh = None
    if args.tp > 1 or len(jax.devices()) > 1:
        mesh = make_host_mesh(model=args.tp)
        print(f"[train] mesh {dict(mesh.shape)}")
    mesh_ctx = dist.use_mesh(mesh) if mesh is not None \
        else contextlib.nullcontext()

    with mesh_ctx:
        _run(args, cfg, tcfg, mesh)


def _run(args, cfg, tcfg, mesh) -> None:
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    opt = adamw_init(params, tcfg.optimizer)
    p_shd = o_shd = None
    if mesh is not None:
        p_shd = shd.param_shardings(params, mesh)
        o_shd = shd.opt_shardings(opt, mesh)
        params = jax.device_put(params, p_shd)
        opt = jax.device_put(opt, o_shd)
    step_fn, (wvec, avec) = make_train_step(tcfg, cfg, param_shardings=p_shd)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        target = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            {"params": params, "opt": opt})
        shardings = None
        if mesh is not None:
            shardings = {"params": p_shd, "opt": o_shd}
        restored, start = restore_checkpoint(args.ckpt_dir, target, shardings)
        params, opt = restored["params"], restored["opt"]
        print(f"[train] resumed from step {start}")

    data = SyntheticLM(seed=0, batch=args.batch, seq_len=args.seq + 1,
                       vocab=cfg.vocab_size, cfg=cfg, start_step=start)
    wd = StragglerWatchdog()
    t_start = time.time()
    step, metrics = start - 1, {"loss": float("nan")}
    for _ in range(args.steps):
        step, batch = next(data)
        wd.start()
        params, opt, metrics = step_fn(params, opt, batch)
        dt = wd.stop(step)
        if step % args.log_every == 0 or step == start:
            print(f"[train] step={step} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt:.2f}s")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1,
                            {"params": params, "opt": opt})
            print(f"[train] checkpoint @ {step + 1}")
    data.close()
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, step + 1,
                        {"params": params, "opt": opt})
    print(f"[train] done: {args.steps} steps in {time.time() - t_start:.1f}s;"
          f" stragglers flagged: {len(wd.events)}")
    print(json.dumps({"final_loss": float(metrics["loss"]),
                      "steps": args.steps}))


if __name__ == "__main__":
    main()
