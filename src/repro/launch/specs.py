"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns the batch pytree for the given
(architecture x input-shape) cell; ``abstract_params`` / ``abstract_opt`` /
``abstract_cache`` eval_shape the parameter/optimizer/cache pytrees.  These
drive both the dry-run lowering and the roofline accounting.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim.adamw import AdamWConfig, adamw_init

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        toks = SDS((B, S + 1), jnp.int32)      # model trains on exactly S
        out = {"tokens": toks}
        if cfg.family == "vlm":
            out["tokens"] = SDS((B, S + 1 - cfg.n_prefix_tokens), jnp.int32)
            out["prefix"] = SDS((B, cfg.n_prefix_tokens, cfg.d_model),
                                jnp.bfloat16)
        elif cfg.family == "encdec":
            out["frames"] = SDS((B, S // cfg.frames_ratio, cfg.d_model),
                                jnp.bfloat16)
        return out
    if shape.kind == "prefill":
        out = {"tokens": SDS((B, S), jnp.int32)}
        if cfg.family == "vlm":
            out["tokens"] = SDS((B, S - cfg.n_prefix_tokens), jnp.int32)
            out["prefix"] = SDS((B, cfg.n_prefix_tokens, cfg.d_model),
                                jnp.bfloat16)
        elif cfg.family == "encdec":
            out["frames"] = SDS((B, S // cfg.frames_ratio, cfg.d_model),
                                jnp.bfloat16)
        return out
    # decode: one new token against an S-deep cache
    return {"tok": SDS((B, 1), jnp.int32), "t": SDS((), jnp.int32)}


def abstract_params(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: lm.init_params(cfg, k), key)


def abstract_qparams(cfg: ModelConfig, container: str = "int8"):
    p = abstract_params(cfg)
    return jax.eval_shape(lambda q: lm.quantize_params(q, cfg, container), p)


def abstract_opt(cfg: ModelConfig, ocfg: AdamWConfig):
    p = abstract_params(cfg)
    return jax.eval_shape(lambda q: adamw_init(q, ocfg), p)


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: lm.empty_cache(cfg, shape.global_batch, shape.seq_len))


def bit_vectors(cfg: ModelConfig, bits: int = 8):
    n = lm.n_bit_slots(cfg)
    v = jnp.full((n,), bits, jnp.int32)
    return v, v


def optimizer_for(cfg: ModelConfig) -> AdamWConfig:
    """Memory posture scales with model size (DESIGN.md §5): the 1T MoE
    uses int8 first moments + factored second moments."""
    if cfg.n_experts >= 256 or cfg.d_model >= 8192:
        return AdamWConfig(m_dtype="int8", v_mode="factored")
    return AdamWConfig(m_dtype="float32", v_mode="full")
