"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is an
outer data-parallel axis whose gradient reduction crosses the inter-pod
DCI — kept to one (optionally int8-compressed) all-reduce per step.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0, \
        f"model={model} must divide the {n} visible devices"
    return jax.make_mesh((n // model, model), ("data", "model"))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # per chip
PEAK_FLOPS_INT8 = 394e12        # per chip (int8 MXU path)
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~3 links usable / chip)
HBM_PER_CHIP = 16 * 2 ** 30     # 16 GiB
