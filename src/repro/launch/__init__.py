"""Launchers: production meshes, shape specs, dry-run lowering, train/serve
drivers.  Deliberately empty — ``launch.dryrun`` must set XLA_FLAGS before
any jax initialization, so nothing here may import jax at package-import
time.
"""
