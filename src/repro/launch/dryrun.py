"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract roofline inputs from the compiled artifact.

MUST set the fake-device flag before ANY jax import (jax locks the device
count on first init):
"""
import os
import re
# Drop any inherited device-count flag (CI exports =8 for the mesh tests;
# whichever flag comes LAST wins inside XLA) before forcing 512.
_inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                    os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = (_inherited.strip()
                           + " --xla_force_host_platform_device_count=512"
                           ).strip()

import argparse           # noqa: E402
import json               # noqa: E402
import subprocess         # noqa: E402
import sys                # noqa: E402
import time               # noqa: E402

import jax                # noqa: E402
import jax.numpy as jnp   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                                   # noqa: E402
from repro.dist import sharding as shd                      # noqa: E402
from repro.launch import hloparse                           # noqa: E402
from repro.launch import specs as sp                        # noqa: E402
from repro.launch.mesh import (HBM_BW, HBM_PER_CHIP, ICI_BW,  # noqa: E402
                               PEAK_FLOPS_BF16, make_production_mesh)
from repro.models import lm                                 # noqa: E402
from repro.models.config import SHAPES_BY_NAME              # noqa: E402
from repro.train.loop import TrainConfig, make_train_step   # noqa: E402

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "f16": 2, "bf16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 0.5, "u4": 0.5}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


# ---------------------------------------------------------------------------
# Cell planning
# ---------------------------------------------------------------------------

def planned_cells():
    """All (arch, shape) cells; long_500k only for sub-quadratic archs
    (skip recorded in DESIGN.md §4)."""
    cells = []
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if s == "long_500k" and not cfg.subquadratic:
                continue
            cells.append((arch, s))
    return cells


def accum_for(cfg, shape) -> int:
    """Microbatch count (the §Perf accumulation knob).

    Measured on qwen1.5-110b (§Perf iter 3): accum 8 -> 2 cut collective
    only 25.5 -> 20.3 s (XLA already hoists the gradient all-reduce out of
    the microbatch scan, so only the FSDP weight regather scales) while
    activation peak blew 36 -> 127 GiB.  REFUTED trade — 8 stays."""
    if shape.kind != "train":
        return 1
    return 8


# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for m in re.finditer(r"([a-z0-9]+)\[([\d,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo: str) -> dict:
    """Per-device bytes moved per collective kind, from result shapes.

    Approximation (documented in EXPERIMENTS.md §Roofline): traffic factor
    2x for all-reduce (ring reduce+broadcast), 1x otherwise; '-start'
    variants counted, '-done' skipped."""
    out = {}
    for m in re.finditer(
            r"=\s+((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\][^\s]*))\s+"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(-start|-done)?\(", hlo):
        type_str, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue
        b = _shape_bytes(type_str)
        d = out.setdefault(kind, {"count": 0, "bytes": 0.0})
        d["count"] += 1
        d["bytes"] += b
    return out


def collective_traffic_bytes(stats: dict) -> float:
    t = 0.0
    for kind, d in stats.items():
        factor = 2.0 if kind == "all-reduce" else 1.0
        t += factor * d["bytes"]
    return t


# ---------------------------------------------------------------------------
# Analytic FLOPs (roofline numerator sanity check)
# ---------------------------------------------------------------------------

def param_counts(cfg) -> dict:
    p = sp.abstract_params(cfg)
    flat = jax.tree_util.tree_flatten_with_path(p)[0]
    total = emb = expert = 0
    for path, leaf in flat:
        n = 1
        for d in leaf.shape:
            n *= d
        keys = tuple(str(getattr(q, "key", q)) for q in path)
        total += n
        if keys[-1] == "emb" or "head" in keys:
            emb += n
        if "experts" in keys:
            expert += n
    active = total - expert
    if cfg.n_experts:
        active += expert * cfg.experts_per_token / cfg.n_experts
    return {"total": total, "embedding": emb, "active": active}


def model_flops(cfg, shape, counts) -> float:
    """6*N_active*D train; 2*N_active*D forward (prefill/decode)."""
    n = counts["active"] - counts["embedding"]
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch            # decode: one token


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               container: str = "int8", kv_bits: int = 0):
    cfg = configs.get(arch)
    shape = SHAPES_BY_NAME[shape_name]
    if kv_bits and shape.kind != "train":
        cfg = cfg.with_(kv_cache_bits=kv_bits)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            ocfg = sp.optimizer_for(cfg)
            tcfg = TrainConfig(optimizer=ocfg, n_accum=accum_for(cfg, shape))
            params = sp.abstract_params(cfg)
            opt = sp.abstract_opt(cfg, ocfg)
            batch = sp.input_specs(cfg, shape)
            p_shd = shd.param_shardings(params, mesh)
            o_shd = shd.opt_shardings(opt, mesh)
            b_shd = shd.batch_shardings(batch, mesh)
            step_fn, _ = make_train_step(tcfg, cfg, param_shardings=p_shd)
            lowered = jax.jit(
                step_fn,
                in_shardings=(p_shd, o_shd, b_shd),
                out_shardings=(p_shd, o_shd, None),
                donate_argnums=(0, 1),
            ).lower(params, opt, batch)
        else:
            qparams = sp.abstract_qparams(cfg, container)
            cache = sp.abstract_cache(cfg, shape)
            q_shd = shd.param_shardings(qparams, mesh)
            c_shd = shd.cache_shardings(cache, mesh)
            wvec, avec = sp.bit_vectors(cfg)
            rep = NamedSharding(mesh, P())
            if shape.kind == "prefill":
                batch = sp.input_specs(cfg, shape)
                b_shd = shd.batch_shardings(batch, mesh)

                def prefill_fn(q, b, c, wv, av):
                    return lm.prefill(q, b, cfg, wv, av, c)

                lowered = jax.jit(
                    prefill_fn,
                    in_shardings=(q_shd, b_shd, c_shd, rep, rep),
                    donate_argnums=(2,),
                ).lower(qparams, batch, cache, wvec, avec)
            else:
                toks = sp.input_specs(cfg, shape)
                tok_shd = NamedSharding(
                    mesh, shd.logical_to_mesh(
                        mesh, ("dp", None), toks["tok"].shape))

                def decode_fn(q, tok, t, c, wv, av):
                    return lm.decode_step(q, tok, t, c, cfg, wv, av)

                lowered = jax.jit(
                    decode_fn,
                    in_shardings=(q_shd, tok_shd, rep, c_shd, rep, rep),
                    donate_argnums=(3,),
                ).lower(qparams, toks["tok"], toks["t"], cache, wvec, avec)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = hloparse.cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    walk = hloparse.summarize(hlo)          # trip-count-exact per-device cost
    colls = walk["collectives"]
    counts = param_counts(cfg)
    chips = 512 if multi_pod else 256

    flops_dev = walk["flops"]
    flops_i8_dev = walk["flops_int8"]
    bytes_dev = walk["bytes_opt"]       # ideal-fusion HBM floor (memory term)
    bytes_hlo = walk["bytes"]           # CPU-fused upper bound (reported)
    coll_dev = walk["collective_bytes"]
    mf = model_flops(cfg, shape, counts)

    peak_bytes = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                  - ma.alias_size_in_bytes + ma.temp_size_in_bytes)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "kind": shape.kind,
        "time_lower_s": round(t_lower, 2),
        "time_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "peak_bytes_per_device": int(peak_bytes),
            "fits_hbm_16g": bool(peak_bytes <= HBM_PER_CHIP),
        },
        "cost": {"flops_per_device": flops_dev,
                 "flops_int8_per_device": flops_i8_dev,
                 "bytes_per_device": bytes_dev,
                 "bytes_hlo_upper_bound": bytes_hlo,
                 "raw_cost_analysis_flops": float(ca.get("flops", 0.0)),
                 "raw_cost_analysis_bytes": float(
                     ca.get("bytes accessed", 0.0))},
        "collectives": colls,
        "collective_bytes_per_device": coll_dev,
        "model_flops_global": mf,
        "params": counts,
        "roofline": {
            "compute_s": (flops_dev / PEAK_FLOPS_BF16
                          + flops_i8_dev / (2 * PEAK_FLOPS_BF16)),
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": coll_dev / (3 * ICI_BW),
            "model_flops_ratio": ((mf / chips)
                                  / max(flops_dev + flops_i8_dev, 1.0)),
        },
    }
    terms = result["roofline"]
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    result["roofline"]["dominant"] = dom.replace("_s", "")
    return result


# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every planned cell in subprocesses")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--container", default="int8", choices=("int8", "int4"))
    ap.add_argument("--kv-bits", type=int, default=0, choices=(0, 8),
                    help="int8 KV cache for serve cells (§Perf)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.all:
        cells = planned_cells()
        meshes = [False, True] if args.both_meshes else [bool(args.multi_pod)]
        failures = []
        for arch, shape in cells:
            for mp in meshes:
                tag = f"{arch}.{shape}.{'2x16x16' if mp else '16x16'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip] {tag} (exists)")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", args.out,
                       "--container", args.container]
                if mp:
                    cmd.append("--multi-pod")
                print(f"[run ] {tag}", flush=True)
                r = subprocess.run(cmd, capture_output=True, text=True)
                if r.returncode != 0:
                    failures.append(tag)
                    print(f"[FAIL] {tag}\n{r.stdout[-2000:]}\n{r.stderr[-4000:]}")
                else:
                    print(r.stdout.strip().splitlines()[-1])
        print(f"\n{len(cells) * len(meshes) - len(failures)} ok, "
              f"{len(failures)} failed: {failures}")
        sys.exit(1 if failures else 0)

    res = lower_cell(args.arch, args.shape, args.multi_pod, args.container,
                     args.kv_bits)
    tag = f"{args.arch}.{args.shape}.{res['mesh']}"
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(res, f, indent=1)
    r = res["roofline"]
    print(f"[ok  ] {tag}: compile={res['time_compile_s']}s "
          f"peak={res['memory']['peak_bytes_per_device'] / 2**30:.2f}GiB "
          f"fits={res['memory']['fits_hbm_16g']} "
          f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
          f"collective={r['collective_s']:.4f}s dom={r['dominant']}")


if __name__ == "__main__":
    main()
