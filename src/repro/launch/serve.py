"""Serving driver: load/initialize a model, quantize, and serve requests
with runtime latency budgets (dynamic bit fluidity).

Two modes:

  * ``--continuous`` (default): the continuous-batching engine — every
    request carries its OWN budget (cycled from ``--budgets``) and streams
    through a persistent slot pool; one compiled prefill + one compiled
    decode serve all precision mixes.

      PYTHONPATH=src python -m repro.launch.serve --arch qwen3_4b --smoke \\
          --requests 8 --steps 16 --budgets 2.0 0.75 0.5

  * ``--batch``: the legacy whole-batch path (one budget per batch);
    kept for A/B comparison and the paper's §V.B batch-switch story.

``--slo-edp <J*s>`` (continuous mode) swaps the open-loop controller
for a closed-loop :class:`repro.core.policy.FluidController`: every
admission's priced AP cost is charged against the system-level EDP SLO
window and later requests resolve from the REMAINING budget — the
paper's dynamic switching as a live control loop (DESIGN.md §8).

With ``--ckpt-dir`` it restores trained weights (from launch/train.py)
before quantizing — train -> checkpoint -> quantized bit-fluid serving is
the full production path.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import policy as pol
from repro.data.pipeline import make_batch
from repro.models import lm
from repro.serve import aggregate, predict_table
from repro.serve.engine import ServeEngine
from repro.train.checkpoint import latest_step, restore_checkpoint


def default_controller(n: int) -> pol.BudgetController:
    return pol.BudgetController(
        {"int4": pol.fixed(4), "mixed": pol.per_layer([8, 4], name="mixed"),
         "int8": pol.fixed(8)},
        {"int4": 0.5, "mixed": 0.75, "int8": 1.0}, n)


def fluid_controller(cfg, n: int, args) -> pol.FluidController:
    """Closed-loop controller for --slo-edp: the same three configs, but
    predicted at their PRICED per-request AP EDP, charged against a
    system-level SLO window the size of the request stream."""
    base = default_controller(n)
    preds = predict_table(
        lm.layer_gemm_dims(cfg), base.configs, axis="edp",
        units=args.prompt_len + args.steps,     # planned tokens/request
        head=lm.head_gemm_dims(cfg))
    return pol.FluidController(base.configs, preds, n, budget_axis="edp",
                               slo=args.slo_edp, window=args.requests)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching mode (the default)")
    ap.add_argument("--batch", action="store_true",
                    help="legacy whole-batch mode (one budget per batch)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--decode-block", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--budgets", type=float, nargs="+", default=None,
                    help="per-request latency budgets, cycled over the "
                         "stream (default: 2.0 0.5)")
    ap.add_argument("--slo-edp", type=float, default=0.0,
                    help="closed-loop mode: total modeled AP EDP budget "
                         "(J*s) for the whole request stream (0 = open "
                         "loop; continuous mode only)")
    ap.add_argument("--kv-bits", type=int, default=0, choices=(0, 8))
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()
    if args.continuous and args.batch:
        ap.error("--continuous and --batch are mutually exclusive")
    if args.slo_edp and args.batch:
        ap.error("--slo-edp needs the continuous scheduler")
    if args.slo_edp and args.budgets is not None:
        ap.error("--budgets are latency budgets; with --slo-edp the EDP "
                 "SLO window drives precision — omit --budgets")
    if args.budgets is None:
        args.budgets = [2.0, 0.5]

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    if args.kv_bits:
        cfg = cfg.with_(kv_cache_bits=args.kv_bits)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        target = {"params": jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)}
        restored, step = restore_checkpoint(args.ckpt_dir, target)
        params = restored["params"]
        print(f"[serve] restored weights from step {step}")
    qparams = lm.quantize_params(params, cfg)

    n = lm.n_bit_slots(cfg)
    if args.batch:
        _serve_batches(cfg, qparams, default_controller(n), args)
    elif args.slo_edp:
        _serve_continuous(cfg, qparams, fluid_controller(cfg, n, args), args)
    else:
        _serve_continuous(cfg, qparams, default_controller(n), args)


def _serve_continuous(cfg, qparams, ctrl, args) -> None:
    closed = isinstance(ctrl, pol.FluidController)
    eng = ServeEngine(cfg, qparams, max_len=args.max_len, controller=ctrl,
                      n_slots=args.n_slots, prefill_len=args.prompt_len,
                      decode_block=args.decode_block)
    t0 = time.time()
    rids = []
    for i in range(args.requests):
        prompt = make_batch(7, i, 1, args.prompt_len,
                            cfg.vocab_size)["tokens"][0]
        rids.append(eng.submit(
            np.asarray(prompt), max_new_tokens=args.steps,
            # closed loop: the SLO window picks precision, not requests
            budget_s=(None if closed
                      else args.budgets[i % len(args.budgets)]),
            temperature=args.temperature, top_k=args.top_k))
    res = eng.run()
    dt = time.time() - t0
    for rid in rids:
        st = res[rid]
        print(f"[serve] req{rid}: budget={st.budget_s:.3g} -> "
              f"{st.mean_wbits:.1f} mean wbits, {st.n_tokens} tokens "
              f"(slot {st.slot}, {st.finished_s - st.submitted_s:.2f}s, "
              f"AP {st.ap_latency_s * 1e3:.2f}ms / "
              f"{st.ap_energy_j * 1e3:.2f}mJ, EDP {st.edp:.3e} J·s)")
    print(f"[serve] {eng.stats.tokens} tokens in {dt:.2f}s "
          f"({eng.stats.tokens / dt:.1f} tok/s) across "
          f"{args.requests} requests on {args.n_slots} slots")
    if closed:
        agg = aggregate(res.values())
        print(f"[serve] closed loop: spent {agg['edp']:.3e} of "
              f"{ctrl.slo:.3e} J·s EDP SLO ({agg['edp'] / ctrl.slo:.2f}x) "
              f"over {agg['requests']} admissions")
    print(f"[serve] compiled programs: prefill={eng.stats.prefill_traces} "
          f"decode={eng.stats.decode_traces} (fluid across "
          f"{1 if closed else len(set(args.budgets))} budget levels, "
          f"{eng.stats.admitted} admissions)")


def _serve_batches(cfg, qparams, ctrl, args) -> None:
    eng = ServeEngine(cfg, qparams, max_len=args.max_len, controller=ctrl)
    for bi, budget in enumerate(args.budgets):
        eng.set_budget(budget)
        batch = {"tokens": make_batch(7, bi, args.requests, args.prompt_len,
                                      cfg.vocab_size)["tokens"]}
        t0 = time.time()
        eng.generate(batch, steps=args.steps)
        dt = time.time() - t0
        wv, _ = ctrl.resolve(jnp.asarray(budget))
        cost = eng.price_budget(budget)
        print(f"[serve] budget={budget}: mean_bits="
              f"{float(np.mean(np.asarray(wv))):.1f} "
              f"{args.requests * args.steps} tokens in {dt:.2f}s "
              f"({args.requests * args.steps / dt:.1f} tok/s; AP "
              f"{cost.cycles:.0f} cy/tok, {cost.energy_j * 1e3:.3f} mJ/tok)")
    print(f"[serve] compiled programs: prefill={eng.stats.prefill_traces} "
          f"decode={eng.stats.decode_traces} (fluid across "
          f"{len(args.budgets)} budgets)")


if __name__ == "__main__":
    main()
