"""Serving driver: load/initialize a model, quantize, serve batched
requests with runtime latency budgets (dynamic bit fluidity).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_4b --smoke \\
      --requests 4 --steps 16 --budgets 2.0 0.5

With ``--ckpt-dir`` it restores trained weights (from launch/train.py)
before quantizing — train -> checkpoint -> quantized bit-fluid serving is
the full production path.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import policy as pol
from repro.data.pipeline import make_batch
from repro.models import lm
from repro.serve.engine import ServeEngine
from repro.train.checkpoint import latest_step, restore_checkpoint


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--budgets", type=float, nargs="+", default=[2.0, 0.5])
    ap.add_argument("--kv-bits", type=int, default=0, choices=(0, 8))
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    if args.kv_bits:
        cfg = cfg.with_(kv_cache_bits=args.kv_bits)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        target = {"params": jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)}
        restored, step = restore_checkpoint(args.ckpt_dir, target)
        params = restored["params"]
        print(f"[serve] restored weights from step {step}")
    qparams = lm.quantize_params(params, cfg)

    n = lm.n_bit_slots(cfg)
    ctrl = pol.BudgetController(
        {"int4": pol.fixed(4), "mixed": pol.per_layer([8, 4], name="mixed"),
         "int8": pol.fixed(8)},
        {"int4": 0.5, "mixed": 0.75, "int8": 1.0}, n)
    eng = ServeEngine(cfg, qparams, max_len=args.max_len, controller=ctrl)

    for bi, budget in enumerate(args.budgets):
        eng.set_budget(budget)
        batch = {"tokens": make_batch(7, bi, args.requests, args.prompt_len,
                                      cfg.vocab_size)["tokens"]}
        t0 = time.time()
        out = eng.generate(batch, steps=args.steps)
        dt = time.time() - t0
        wv, _ = ctrl.resolve(jnp.asarray(budget))
        import numpy as np
        print(f"[serve] budget={budget}: mean_bits="
              f"{float(np.mean(np.asarray(wv))):.1f} "
              f"{args.requests * args.steps} tokens in {dt:.2f}s "
              f"({args.requests * args.steps / dt:.1f} tok/s)")
    print(f"[serve] compiled programs: prefill={eng.stats.prefill_traces} "
          f"decode={eng.stats.decode_traces} (fluid across "
          f"{len(args.budgets)} budgets)")


if __name__ == "__main__":
    main()
