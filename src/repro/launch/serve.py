"""Serving driver: load/initialize a model, quantize, and serve requests
with runtime latency budgets (dynamic bit fluidity).

Two modes:

  * ``--continuous`` (default): the continuous-batching engine — every
    request carries its OWN budget (cycled from ``--budgets``) and streams
    through a persistent slot pool; one compiled prefill + one compiled
    decode serve all precision mixes.

      PYTHONPATH=src python -m repro.launch.serve --arch qwen3_4b --smoke \\
          --requests 8 --steps 16 --budgets 2.0 0.75 0.5

  * ``--batch``: the legacy whole-batch path (one budget per batch);
    kept for A/B comparison and the paper's §V.B batch-switch story.

With ``--ckpt-dir`` it restores trained weights (from launch/train.py)
before quantizing — train -> checkpoint -> quantized bit-fluid serving is
the full production path.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import policy as pol
from repro.data.pipeline import make_batch
from repro.models import lm
from repro.serve.engine import ServeEngine
from repro.train.checkpoint import latest_step, restore_checkpoint


def default_controller(n: int) -> pol.BudgetController:
    return pol.BudgetController(
        {"int4": pol.fixed(4), "mixed": pol.per_layer([8, 4], name="mixed"),
         "int8": pol.fixed(8)},
        {"int4": 0.5, "mixed": 0.75, "int8": 1.0}, n)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching mode (the default)")
    ap.add_argument("--batch", action="store_true",
                    help="legacy whole-batch mode (one budget per batch)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--decode-block", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--budgets", type=float, nargs="+", default=[2.0, 0.5])
    ap.add_argument("--kv-bits", type=int, default=0, choices=(0, 8))
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()
    if args.continuous and args.batch:
        ap.error("--continuous and --batch are mutually exclusive")

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    if args.kv_bits:
        cfg = cfg.with_(kv_cache_bits=args.kv_bits)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        target = {"params": jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)}
        restored, step = restore_checkpoint(args.ckpt_dir, target)
        params = restored["params"]
        print(f"[serve] restored weights from step {step}")
    qparams = lm.quantize_params(params, cfg)

    ctrl = default_controller(lm.n_bit_slots(cfg))
    if args.batch:
        _serve_batches(cfg, qparams, ctrl, args)
    else:
        _serve_continuous(cfg, qparams, ctrl, args)


def _serve_continuous(cfg, qparams, ctrl, args) -> None:
    eng = ServeEngine(cfg, qparams, max_len=args.max_len, controller=ctrl,
                      n_slots=args.n_slots, prefill_len=args.prompt_len,
                      decode_block=args.decode_block)
    t0 = time.time()
    rids = []
    for i in range(args.requests):
        prompt = make_batch(7, i, 1, args.prompt_len,
                            cfg.vocab_size)["tokens"][0]
        rids.append(eng.submit(np.asarray(prompt),
                               max_new_tokens=args.steps,
                               budget_s=args.budgets[i % len(args.budgets)],
                               temperature=args.temperature,
                               top_k=args.top_k))
    res = eng.run()
    dt = time.time() - t0
    for rid in rids:
        st = res[rid]
        print(f"[serve] req{rid}: budget={st.budget_s:g}s -> "
              f"{st.mean_wbits:.1f} mean wbits, {st.n_tokens} tokens "
              f"(slot {st.slot}, {st.finished_s - st.submitted_s:.2f}s, "
              f"AP {st.ap_latency_s * 1e3:.2f}ms / "
              f"{st.ap_energy_j * 1e3:.2f}mJ, EDP {st.edp:.3e} J·s)")
    print(f"[serve] {eng.stats.tokens} tokens in {dt:.2f}s "
          f"({eng.stats.tokens / dt:.1f} tok/s) across "
          f"{args.requests} requests on {args.n_slots} slots")
    print(f"[serve] compiled programs: prefill={eng.stats.prefill_traces} "
          f"decode={eng.stats.decode_traces} (fluid across "
          f"{len(set(args.budgets))} budget levels, "
          f"{eng.stats.admitted} admissions)")


def _serve_batches(cfg, qparams, ctrl, args) -> None:
    eng = ServeEngine(cfg, qparams, max_len=args.max_len, controller=ctrl)
    for bi, budget in enumerate(args.budgets):
        eng.set_budget(budget)
        batch = {"tokens": make_batch(7, bi, args.requests, args.prompt_len,
                                      cfg.vocab_size)["tokens"]}
        t0 = time.time()
        eng.generate(batch, steps=args.steps)
        dt = time.time() - t0
        wv, _ = ctrl.resolve(jnp.asarray(budget))
        cost = eng.price_budget(budget)
        print(f"[serve] budget={budget}: mean_bits="
              f"{float(np.mean(np.asarray(wv))):.1f} "
              f"{args.requests * args.steps} tokens in {dt:.2f}s "
              f"({args.requests * args.steps / dt:.1f} tok/s; AP "
              f"{cost.cycles:.0f} cy/tok, {cost.energy_j * 1e3:.3f} mJ/tok)")
    print(f"[serve] compiled programs: prefill={eng.stats.prefill_traces} "
          f"decode={eng.stats.decode_traces} (fluid across "
          f"{len(args.budgets)} budgets)")


if __name__ == "__main__":
    main()
