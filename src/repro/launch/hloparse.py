"""Trip-count-aware cost extraction from compiled (scheduled) HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE (validated
in tests/test_hloparse.py), which under-counts scanned-layer programs by
~n_layers x n_accum.  This walker reconstructs exact per-device costs:

  * splits the module into computations;
  * per instruction: dot FLOPs (2 * |result| * |contracted dims|, bucketed
    by operand dtype so int8 MXU work is separated), bytes accessed
    (operands + result, at fusion granularity — matching HloCostAnalysis
    semantics on the post-fusion module), collective bytes by kind;
  * multiplies while bodies by ``backend_config.known_trip_count`` and
    recurses through call/fusion/conditional (max over branches).

The result is the roofline numerator set: flops (bf16/int8), HBM bytes,
and per-kind collective bytes — all per device, loop-exact.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "f16": 2, "bf16": 2, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[\d,]*\]\S*)\s+([\w\-]+)\(")
_CALLED_RE = re.compile(r"(?:calls=|to_apply=|body=)%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

_NO_BYTES_OPS = {"parameter", "tuple", "get-tuple-element", "bitcast",
                 "constant", "after-all", "custom-call"}


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions (older
    jaxlibs return a one-element list of dicts, newer return the dict)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def _operand_segment(line: str, op: str) -> str:
    """The balanced-paren operand list of ``op`` on this line.

    Operands are printed WITH their types (``dot(f32[64,256]{1,0} %a, …)``)
    and tuple types nest parens, so a greedy regex won't do.
    """
    i = line.find(" " + op + "(")
    if i < 0:
        return ""
    start = line.index("(", i)
    depth = 0
    for j in range(start, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                return line[start + 1:j]
    return line[start + 1:]


def _shape_info(type_str: str) -> List[Tuple[str, int]]:
    """[(dtype, numel), ...] for a possibly-tuple type string."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dt, n))
    return out


def _bytes_of(type_str: str) -> float:
    return sum(n * _DTYPE_BYTES[dt] for dt, n in _shape_info(type_str))


class Cost(dict):
    KEYS = ("flops", "flops_int8", "bytes", "bytes_dot", "coll_bytes",
            "transcendentals")

    def __init__(self):
        super().__init__({k: 0.0 for k in self.KEYS})
        self["coll"] = {}

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        for k in self.KEYS:
            self[k] += other[k] * mult
        for kind, d in other["coll"].items():
            mine = self["coll"].setdefault(kind, {"count": 0.0, "bytes": 0.0})
            mine["count"] += d["count"] * mult
            mine["bytes"] += d["bytes"] * mult


def _parse_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and "{" in line and "(" in line:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    comps["__entry__"] = comps[cur]
                continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _dot_flops(line: str, result_type: str) -> Tuple[float, bool]:
    """(flops, is_int8). flops = 2 * |result| * prod(contracted lhs dims)."""
    info = _shape_info(result_type)
    if not info:
        return 0.0, False
    result_n = info[0][1]
    seg = _operand_segment(line, "dot")
    contract = 1
    lhs_dt = None
    m = _SHAPE_RE.search(seg)               # lhs type is inline in operands
    if m:
        lhs_dt = m.group(1)
        lhs_dims = [int(d) for d in m.group(2).split(",") if d]
        cm = _CONTRACT_RE.search(line)
        if cm and cm.group(1):
            for i in (int(x) for x in cm.group(1).split(",")):
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
    # Integer dots are the int8-container path (quantized serving / int8
    # KV attention).  On TPU the operands stay s8; the CPU backend widens
    # them to s32 inside a fusion before the dot, so classify by "any
    # integer accumulate" rather than chasing converts through fusions.
    is_int8 = lhs_dt in ("s8", "u8", "s16", "u16", "s32", "u32")
    return 2.0 * result_n * contract, is_int8


def analyze(hlo: str) -> Cost:
    comps = _parse_computations(hlo)
    cache: Dict[str, Cost] = {}

    def comp_cost(name: str) -> Cost:
        if name in cache:
            return cache[name]
        cost = Cost()
        cache[name] = cost                       # cycle guard
        lines = comps.get(name, [])
        for line in lines:
            d = _DEF_RE.match(line)
            if not d:
                continue
            _, result_type, op = d.groups()
            if op == "while":
                body = _CALLED_RE.search(line)
                trip = _TRIP_RE.search(line)
                n = float(trip.group(1)) if trip else 1.0
                if body:
                    cost.add(comp_cost(body.group(1)), n)
                continue
            if op == "conditional":
                br = _BRANCHES_RE.search(line)
                if br:
                    branch_costs = [comp_cost(b.strip().lstrip("%"))
                                    for b in br.group(1).split(",")]
                    best = max(branch_costs,
                               key=lambda c: c["flops"] + c["bytes"])
                    cost.add(best)
                continue
            if op in ("fusion", "call"):
                callee = _CALLED_RE.search(line)
                if callee:
                    inner = comp_cost(callee.group(1))
                    # dots/collectives inside count; bytes at fusion boundary
                    part = Cost()
                    part.add(inner)
                    part["bytes"] = 0.0
                    cost.add(part)
                cost["bytes"] += _bytes_of(result_type) + _operand_bytes(
                    line, op)
                continue
            if op == "dot":
                fl, is8 = _dot_flops(line, result_type)
                cost["flops_int8" if is8 else "flops"] += fl
                b = _bytes_of(result_type) + _operand_bytes(line, op)
                cost["bytes"] += b
                cost["bytes_dot"] += b
                continue
            for kind in COLLECTIVES:
                if op == kind or op == kind + "-start":
                    b = _bytes_of(result_type)
                    dd = cost["coll"].setdefault(
                        kind, {"count": 0.0, "bytes": 0.0})
                    dd["count"] += 1
                    dd["bytes"] += b
                    cost["coll_bytes"] += b * (2.0 if kind == "all-reduce"
                                               else 1.0)
                    break
            if op in _NO_BYTES_OPS or op.endswith("-done"):
                continue
            cost["bytes"] += _bytes_of(result_type) + _operand_bytes(
                line, op)
        return cost

    def _operand_bytes(line: str, op: str) -> float:
        # Operand types are printed inline in scheduled HLO; sum them
        # directly rather than resolving names through the symbol table.
        return _bytes_of(_operand_segment(line, op))

    return comp_cost("__entry__" if "__entry__" in comps
                     else next(iter(comps)))


def summarize(hlo: str) -> dict:
    """bytes      — HloCostAnalysis semantics on the *CPU-fused* module
                    (pessimistic: the CPU backend fuses less than TPU, so
                    elementwise chains over-count HBM traffic);
       bytes_opt  — ideal-fusion floor: dot operands/results + collective
                    traffic (everything between dots fuses into them).
    The true TPU memory term lies between; §Roofline reports both."""
    c = analyze(hlo)
    return {
        "flops": c["flops"],
        "flops_int8": c["flops_int8"],
        "bytes": c["bytes"],
        "bytes_opt": c["bytes_dot"] + c["coll_bytes"],
        "collective_bytes": c["coll_bytes"],
        "collectives": {k: {"count": v["count"], "bytes": v["bytes"]}
                        for k, v in c["coll"].items()},
    }
