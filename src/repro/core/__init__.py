"""repro.core — the paper's contribution as composable JAX modules.

bitfluid   quant/dequant, bit planes, dyadic runtime requantization
policy     per-layer precision policies (fixed / mixed / HAWQ-V3 / dynamic)
emulator   functional AP (compare/write LUT passes, bit-exact validation)
"""
from repro.core import bitfluid, emulator, policy  # noqa: F401
