"""Functional AP emulator: word-parallel compare/write LUT passes on bits.

The paper's §IV validates its runtime models with a Python emulation of
the AP executing micro/macro/CNN functions.  This module is that
emulation: data lives as {0,1} bit planes (two's-complement columns), and
every operation is a sequence of *compare* (pattern match -> tag) and
*write* (masked update of tagged rows) passes following the operation's
LUT — the same mechanism as the hardware, so results are bit-exact by
construction and the pass counts cross-validate Table I's cycle models
(tests/test_emulator.py).

LUTs implemented:
  * in-place addition (4 passes/bit + carry column; Yantir [50] ordering
    chosen so written patterns never re-match later passes)
  * out-of-place multiplication (bit-serial shift-add: Mw x Ma pass walk)
  * ReLU (Table III: one pass/bit against the sign flag)
  * max (Table IV flags F1/F2: MSB-first winner resolution)
  * reduction / average pooling (vertical-mode pairwise adds)
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PassCounter:
    compares: int = 0
    writes: int = 0
    reads: int = 0

    def cycles(self) -> int:
        return self.compares + self.writes + self.reads


def to_bits(x: np.ndarray, M: int) -> np.ndarray:
    """(L,) ints -> (L, M) two's-complement bit matrix, LSB first."""
    x = np.asarray(x, np.int64)
    u = x & ((1 << M) - 1)
    return ((u[:, None] >> np.arange(M)[None, :]) & 1).astype(np.uint8)


def from_bits(b: np.ndarray, signed: bool = True) -> np.ndarray:
    M = b.shape[1]
    v = (b.astype(np.int64) * (1 << np.arange(M))[None, :]).sum(1)
    if signed:
        v = np.where(b[:, -1] == 1, v - (1 << M), v)
    return v


# ---------------------------------------------------------------------------
# Compare / write primitives (word-parallel across rows)
# ---------------------------------------------------------------------------

def _compare(cols, pattern, counter: PassCounter, select=None) -> np.ndarray:
    """Tag rows whose selected column bits equal `pattern`."""
    counter.compares += 1
    tag = np.ones(cols[0].shape[0], bool)
    for c, p in zip(cols, pattern):
        tag &= c == p
    if select is not None:
        tag &= select
    return tag


def _write(cols, values, tag, counter: PassCounter) -> None:
    counter.writes += 1
    for c, v in zip(cols, values):
        c[tag] = v


# ---------------------------------------------------------------------------
# Addition LUT (in-place A + B -> B, carry column Cr)
# Pass order guarantees no written row re-matches a later pass.
# ---------------------------------------------------------------------------

_ADD_LUT = (  # (A, B, Cr) pattern  ->  (B', Cr')
    ((0, 0, 1), (1, 0)),
    ((0, 1, 1), (0, 1)),
    ((1, 1, 0), (0, 1)),
    ((1, 0, 0), (1, 0)),
)


def add_inplace(A: np.ndarray, B: np.ndarray, counter: PassCounter,
                select=None) -> np.ndarray:
    """B := A + B, bit-serial LSB->MSB.  A: (L, Ma), B: (L, Mb >= Ma+1)."""
    L, Ma = A.shape
    Cr = np.zeros(L, np.uint8)
    for i in range(B.shape[1]):
        a_col = A[:, i] if i < Ma else np.zeros(L, np.uint8)
        b_col = B[:, i]
        for pattern, (b_new, c_new) in _ADD_LUT:
            tag = _compare((a_col, b_col, Cr), pattern, counter, select)
            _write((b_col, Cr), (b_new, c_new), tag, counter)
        B[:, i] = b_col
    return B


def multiply(A: np.ndarray, B: np.ndarray, counter: PassCounter
             ) -> np.ndarray:
    """C := A * B (unsigned), out of place; (L,Ma) x (L,Mb) -> (L,Ma+Mb).

    Bit-serial shift-add: for each multiplier bit j, rows with B_j == 1
    add (A << j) into C — the Mw x Ma LUT walk of Eq. 2."""
    L, Ma = A.shape
    Mb = B.shape[1]
    C = np.zeros((L, Ma + Mb), np.uint8)
    for j in range(Mb):
        sel = _compare((B[:, j],), (1,), counter)
        window = C[:, j:]
        add_inplace(A, window, counter, select=sel)
        C[:, j:] = window
    return C


def relu(V: np.ndarray, counter: PassCounter) -> np.ndarray:
    """Table III: stash MSB in flag, reset it, zero bits where flag set.

    Pass accounting (cross-checked in tests/test_emulator.py): the flag
    stash is one read, the MSB reset is one write (counted by ``_write``
    itself), and each of the M-1 remaining bits is one compare + one
    write — 2M passes total, matching Table I's 4M+1 ReLU cycles minus
    the 2M populate and 1 read-out I/O passes."""
    L, M = V.shape
    F = V[:, -1].copy()
    counter.reads += 1
    _write((V[:, -1],), (0,), np.ones(L, bool), counter)
    for i in range(M - 1):
        col = V[:, i]
        tag = _compare((col, F), (1, 1), counter)
        _write((col,), (0,), tag, counter)
        V[:, i] = col
    return V


def maximum_inplace(A: np.ndarray, B: np.ndarray, counter: PassCounter
                    ) -> np.ndarray:
    """B := max(A, B) (unsigned), MSB-first with Table IV's F1/F2 flags.

    F2 = comparison decided; F1 = B is the winner.  Per bit (4 LUT
    passes): undecided rows resolve on the first differing bit; rows
    decided for A copy A's remaining bits into B."""
    L, M = A.shape
    F1 = np.zeros(L, np.uint8)              # decided, B wins
    F2 = np.zeros(L, np.uint8)              # decided
    for i in range(M - 1, -1, -1):
        a_col, b_col = A[:, i], B[:, i].copy()
        # 1st pass: A=1,B=0, undecided -> A wins, copy bit
        tag = _compare((a_col, b_col, F2), (1, 0, 0), counter)
        _write((b_col, F2), (1, 0), tag, counter)
        decided_a = tag
        # 2nd pass: A=0,B=1, undecided -> B wins
        tag = _compare((a_col, b_col, F2), (0, 1, 0), counter)
        _write((F1, F2), (1, 1), tag, counter)
        # mark rows decided for A (F2=1, F1=0) — done after pass 2 so the
        # pass-2 compare can't see them
        F2[decided_a] = 1
        # 3rd/4th passes: decided-for-A rows copy A's bit into B
        sel = (F2 == 1) & (F1 == 0)
        tag = _compare((a_col,), (1,), counter, select=sel & ~decided_a)
        _write((b_col,), (1,), tag, counter)
        tag = _compare((a_col,), (0,), counter, select=sel & ~decided_a)
        _write((b_col,), (0,), tag, counter)
        B[:, i] = b_col
    return B


def reduce_sum(A: np.ndarray, M_out: int, counter: PassCounter) -> int:
    """Vertical-mode reduction: pairwise in-place adds (Eq. 4 structure)."""
    vals = [A[i:i + 1] for i in range(A.shape[0])]
    while len(vals) > 1:
        nxt = []
        for i in range(0, len(vals) - 1, 2):
            a = np.pad(vals[i], ((0, 0), (0, M_out - vals[i].shape[1])))
            b = np.pad(vals[i + 1], ((0, 0), (0, M_out - vals[i + 1].shape[1])))
            nxt.append(add_inplace(a, b, counter))
        if len(vals) % 2:
            nxt.append(np.pad(vals[-1],
                              ((0, 0), (0, M_out - vals[-1].shape[1]))))
        vals = nxt
    counter.reads += 1
    return int(from_bits(vals[0], signed=False)[0])


# ---------------------------------------------------------------------------
# Word-level convenience wrappers (the emulator's public API)
# ---------------------------------------------------------------------------

def ap_add(a: np.ndarray, b: np.ndarray, M: int):
    """Returns (a + b mod 2^(M+1), PassCounter)."""
    c = PassCounter()
    A = to_bits(a, M)
    B = np.pad(to_bits(b, M), ((0, 0), (0, 1)))
    out = add_inplace(A, B, c)
    return from_bits(out, signed=False), c


def ap_multiply(a: np.ndarray, b: np.ndarray, M: int):
    c = PassCounter()
    out = multiply(to_bits(a, M), to_bits(b, M), c)
    return from_bits(out, signed=False), c


def ap_relu(v: np.ndarray, M: int):
    c = PassCounter()
    out = relu(to_bits(v, M), c)
    return from_bits(out, signed=False), c


def ap_max(a: np.ndarray, b: np.ndarray, M: int):
    c = PassCounter()
    out = maximum_inplace(to_bits(a, M), to_bits(b, M), c)
    return from_bits(out, signed=False), c


def ap_reduce(a: np.ndarray, M: int):
    c = PassCounter()
    L = len(a)
    M_out = M + max(int(np.ceil(np.log2(max(L, 2)))), 1)
    return reduce_sum(to_bits(a, M), M_out, c), c


def ap_matmul(X: np.ndarray, W: np.ndarray, M: int):
    """Full GEMM on the emulator: X (i,j) @ W (j,u), unsigned M-bit inputs."""
    c = PassCounter()
    i, j = X.shape
    _, u = W.shape
    out = np.zeros((i, u), np.int64)
    for r in range(i):
        for col in range(u):
            prod = multiply(to_bits(X[r], M), to_bits(W[:, col], M), c)
            M_out = 2 * M + max(int(np.ceil(np.log2(max(j, 2)))), 1)
            out[r, col] = reduce_sum(prod, M_out, c)
    return out, c
