"""Bit-fluid quantization — the paper's contribution as composable JAX ops.

BF-IMNA's core insight: on bit-serial hardware, *precision is a runtime
axis* — a layer assigned ``b`` bits simply executes fewer bit passes, with
no hardware reconfiguration.  We map that insight onto TPU as follows
(DESIGN.md §2):

* Weights are stored once at the **container precision** (int8, or packed
  int4 nibbles).  An int8 word *is* its 8 bit planes; the bit-plane GEMM
  kernel (kernels/bitplane_matmul.py) walks planes exactly like the AP's
  bit-serial LUT walk, and masking planes = deactivating MSBs.
* Runtime precision switching uses **dyadic requantization**: a right shift
  ``q_b = round_half_even(q_8 / 2^(8-b))`` re-expresses the stored 8-bit
  value on a b-bit grid of the same scale family.  This matches HAWQ-V3's
  dyadic-arithmetic constraint [53] and makes the per-layer precision
  configuration an ordinary *runtime tensor* — one compiled program serves
  any static or dynamic mixed-precision configuration (the TPU analogue of
  "no reconfiguration overhead at run-time").
* Training uses fake-quant with a straight-through estimator so the same
  per-layer bit vector drives quantization-aware training.

All functions are pure and jit/vmap/scan-compatible; ``bits`` arguments may
be Python ints *or* traced scalars (bit fluidity as data).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INT_DTYPE = jnp.int8
ACC_DTYPE = jnp.int32


# ---------------------------------------------------------------------------
# Scales / quantize / dequantize (symmetric, mid-rise, power-of-two friendly)
# ---------------------------------------------------------------------------

def qmax(bits) -> jnp.ndarray:
    """Largest magnitude representable at ``bits``: 2^(b-1) - 1."""
    return (2.0 ** (jnp.asarray(bits, jnp.float32) - 1.0)) - 1.0


def symmetric_scale(x: jnp.ndarray, bits, axis=None, eps: float = 1e-8):
    """Per-tensor (axis=None) or per-channel symmetric scale."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(amax, eps).astype(jnp.float32) / qmax(bits)


def quantize(x: jnp.ndarray, scale: jnp.ndarray, bits) -> jnp.ndarray:
    """Symmetric quantization to a signed ``bits``-bit grid, stored as int8.

    Values occupy the low ``bits`` bits (two's complement); for bits < 8 the
    upper bit planes of the int8 container are sign extension — exactly the
    paper's "MSBs are deactivated" storage picture.
    """
    q = jnp.round(x / scale)
    lim = qmax(bits)
    return jnp.clip(q, -lim, lim).astype(INT_DTYPE)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# Runtime-fluid dyadic requantization (the bit-fluid switch)
# ---------------------------------------------------------------------------

def requant_shift(q: jnp.ndarray, to_bits, from_bits: int = 8) -> jnp.ndarray:
    """Re-express an int ``from_bits`` value on a ``to_bits`` grid (dyadic).

    q_b = round(q / 2^(from-to)), then the caller's effective scale becomes
    ``scale * 2^(from-to)``.  ``to_bits`` may be a traced scalar — this is
    the zero-recompilation precision switch.  Rounding is round-half-away
    implemented with integer ops only (AP-friendly: shifts and adds).
    """
    to_bits = jnp.asarray(to_bits, ACC_DTYPE)
    shift = jnp.maximum(jnp.asarray(from_bits, ACC_DTYPE) - to_bits, 0)
    qi = q.astype(ACC_DTYPE)
    half = jnp.where(shift > 0, (1 << jnp.maximum(shift - 1, 0)), 0)
    rounded = jnp.where(qi >= 0, (qi + half) >> shift, -((-qi + half) >> shift))
    lim = (2 ** (to_bits - 1) - 1).astype(ACC_DTYPE)
    return jnp.clip(rounded, -lim, lim).astype(INT_DTYPE)


def effective_scale(scale: jnp.ndarray, to_bits, from_bits: int = 8):
    shift = jnp.maximum(from_bits - jnp.asarray(to_bits, jnp.float32), 0.0)
    return scale * (2.0 ** shift)


# ---------------------------------------------------------------------------
# Bit planes (two's complement) — the AP's native data layout
# ---------------------------------------------------------------------------

def bitplanes(q: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Decompose int8 ``q`` into ``bits`` {0,1} planes, LSB first.

    Plane weights are 2^j for j < bits-1 and -2^(bits-1) for the sign plane
    (two's complement), so  q == sum_j w_j * plane_j  exactly.
    """
    js = jnp.arange(bits, dtype=jnp.int32)
    u = q.astype(jnp.int32) & ((1 << bits) - 1)          # low `bits` field
    return ((u[None] >> js.reshape((bits,) + (1,) * q.ndim)) & 1).astype(INT_DTYPE)


def plane_weights(bits: int) -> jnp.ndarray:
    w = 2.0 ** jnp.arange(bits, dtype=jnp.float32)
    return w.at[bits - 1].set(-(2.0 ** (bits - 1)))


def from_bitplanes(planes: jnp.ndarray, bits: int) -> jnp.ndarray:
    w = plane_weights(bits).reshape((bits,) + (1,) * (planes.ndim - 1))
    return jnp.sum(planes.astype(jnp.float32) * w, axis=0).astype(INT_DTYPE)


# ---------------------------------------------------------------------------
# int4 packing (two nibbles per int8 byte) — decode-bandwidth container
# ---------------------------------------------------------------------------

def pack_int4(q: jnp.ndarray) -> jnp.ndarray:
    """Pack int4 values (last axis even) into uint8 nibbles, low nibble first."""
    if q.shape[-1] % 2:
        raise ValueError("last axis must be even to pack nibbles")
    u = (q.astype(jnp.int32) & 0xF).astype(jnp.uint8)
    lo, hi = u[..., 0::2], u[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """Unpack uint8 nibbles back to signed int8 in [-8, 7]."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    both = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[:-1] + (-1,))
    return jnp.where(both >= 8, both - 16, both).astype(INT_DTYPE)


def pack_int4_halves(q: jnp.ndarray) -> jnp.ndarray:
    """Half-split nibble layout: columns [0, N/2) in the low nibble, columns
    [N/2, N) in the high nibble.  Unpacking is a nibble select — no
    interleave — which keeps the Pallas int4 kernel's in-VMEM unpack a pure
    elementwise op (TPU-layout friendly; see kernels/int4_matmul.py)."""
    if q.shape[-1] % 2:
        raise ValueError("last axis must be even to pack nibbles")
    half = q.shape[-1] // 2
    lo = (q[..., :half].astype(jnp.int32) & 0xF).astype(jnp.uint8)
    hi = (q[..., half:].astype(jnp.int32) & 0xF).astype(jnp.uint8)
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4_halves(packed: jnp.ndarray) -> jnp.ndarray:
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    both = jnp.concatenate([lo, hi], axis=-1)
    return jnp.where(both >= 8, both - 16, both).astype(INT_DTYPE)


# ---------------------------------------------------------------------------
# Fake quantization with straight-through estimator (QAT / mixed-prec train)
# ---------------------------------------------------------------------------

def fake_quant(x: jnp.ndarray, bits, axis=None) -> jnp.ndarray:
    """Differentiable b-bit quantization: forward quantizes, grad passes through.

    ``bits`` may be a traced scalar (per-layer bit vectors flow through scan).
    bits >= 16 acts as identity (the "fp path" sentinel).
    """
    scale = symmetric_scale(jax.lax.stop_gradient(x), bits, axis=axis)
    lim = qmax(bits)
    q = jnp.clip(jnp.round(x / scale), -lim, lim) * scale
    q = jnp.where(jnp.asarray(bits) >= 16, x, q.astype(x.dtype))
    return x + jax.lax.stop_gradient(q - x)


# ---------------------------------------------------------------------------
# Fluid integer matmul — XLA serving path (Pallas kernel mirrors this; see
# kernels/bitplane_matmul.py for the MXU bit-plane walk)
# ---------------------------------------------------------------------------

def fluid_int8_matmul(x: jnp.ndarray, qw: jnp.ndarray, w_scale: jnp.ndarray,
                      wbits=8, abits=8) -> jnp.ndarray:
    """y = x @ dequant(qw) at runtime precisions (wbits, abits).

    x        (..., K) float; dynamically quantized per-tensor to ``abits``.
    qw       (K, N) int8 container (8-bit grid), per-channel ``w_scale`` (N,).
    wbits    runtime scalar or python int — dyadic shift to the b-bit grid.

    Cost on TPU is one int8 MXU matmul regardless of bits (the MXU is a
    fixed 8-bit engine); *bandwidth* scales with the container (int4 packs
    exist for that — see int4 path), and numerics scale with (wbits, abits)
    exactly as on the AP.
    """
    w_q = requant_shift(qw, wbits)
    w_s = effective_scale(w_scale, wbits)
    x_scale = symmetric_scale(x, abits)
    x_q = quantize(x, x_scale, abits)
    acc = jax.lax.dot_general(
        x_q, w_q,
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=ACC_DTYPE)
    return acc.astype(jnp.float32) * x_scale * w_s


def bitplane_matmul_ref(x_q: jnp.ndarray, qw: jnp.ndarray, wbits: int) -> jnp.ndarray:
    """Plane-walk reference:  sum_j w_j * (x_q @ plane_j)  ==  x_q @ q_w.

    This is the mathematically-exact identity the Pallas kernel exploits;
    kept here (jnp-only) as the oracle for kernels/ref.py and tests.
    """
    planes = bitplanes(qw, wbits)                       # (wbits, K, N)
    w = plane_weights(wbits)
    acc = jnp.zeros(x_q.shape[:-1] + (qw.shape[-1],), jnp.float32)
    for j in range(wbits):
        d = jax.lax.dot_general(
            x_q, planes[j],
            dimension_numbers=(((x_q.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=ACC_DTYPE)
        acc = acc + w[j] * d.astype(jnp.float32)
    return acc
