"""Precision policies — who gets how many bits, statically or at runtime.

A :class:`PrecisionPolicy` resolves to two per-layer integer vectors
(weight bits, activation bits) that flow through the model as *data*
(scan xs), so switching configurations never recompiles — the TPU analogue
of BF-IMNA's zero-overhead dynamic mixed-precision.

Built-ins:
  * ``fixed(b)``                      — the paper's fixed-precision baseline.
  * ``per_layer([...])``              — arbitrary static mixed-precision.
  * ``hawq_v3(constraint)``           — the paper's Table VII ResNet18 study
                                        (INT4/INT8 mixes for low/medium/high
                                        latency budgets, from HAWQ-V3 [53]).
  * ``BudgetController``              — dynamic: picks among registered
                                        configurations at runtime from a
                                        latency/EDP budget signal (paper §V.B
                                        "switching between the three
                                        mixed-precision configurations
                                        dynamically").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.apsim.workloads import HAWQV3_RESNET18, HAWQV3_METADATA  # noqa: F401

FP_BITS = 16  # sentinel: >=16 means "leave in bf16/f32" (fake_quant identity)


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Per-layer (weight, activation) bit assignment for an n_layers stack."""
    name: str
    weight_bits: Tuple[int, ...]
    act_bits: Tuple[int, ...]

    def vectors(self, n_layers: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Materialize (wbits, abits) int32 vectors of length n_layers.

        Shorter tables extend with their last entry (paper Table VII rule).
        """
        def expand(tab: Sequence[int]) -> jnp.ndarray:
            vals = [tab[i] if i < len(tab) else tab[-1] for i in range(n_layers)]
            return jnp.asarray(vals, jnp.int32)
        return expand(self.weight_bits), expand(self.act_bits)

    @property
    def avg_bits(self) -> float:
        return sum(self.weight_bits) / len(self.weight_bits)


def fixed(bits: int, name: Optional[str] = None) -> PrecisionPolicy:
    return PrecisionPolicy(name or f"int{bits}", (bits,), (bits,))


def full_precision() -> PrecisionPolicy:
    return PrecisionPolicy("fp", (FP_BITS,), (FP_BITS,))


def per_layer(weight_bits: Sequence[int],
              act_bits: Optional[Sequence[int]] = None,
              name: str = "mixed") -> PrecisionPolicy:
    ab = tuple(act_bits) if act_bits is not None else tuple(weight_bits)
    return PrecisionPolicy(name, tuple(weight_bits), ab)


def hawq_v3(constraint: str) -> PrecisionPolicy:
    """Paper Table VII: HAWQ-V3 ResNet18 mixes; constraint in
    {int4, low, medium, high, int8} (weight and activation share bits)."""
    tab = HAWQV3_RESNET18[constraint]
    return per_layer(tab, name=f"hawqv3-{constraint}")


def cnn_budget_controller(network: str = "resnet18",
                          constraints: Sequence[str] = ("int4", "low",
                                                        "medium", "high",
                                                        "int8"),
                          *, layers=None,
                          configs: Optional[Dict[str, PrecisionPolicy]] = None,
                          metric: str = "edp") -> "BudgetController":
    """A :class:`BudgetController` for a CNN workload, with predicted
    per-image costs from the calibrated AP model
    (``apsim.mapper.simulate_network``).

    ``configs`` defaults to the paper's Table VII HAWQ-V3 ResNet18
    mixes (``constraints`` picks which) — those per-layer vectors only
    fit ResNet-shaped networks, so for AlexNet/VGG16 pass explicit
    policies (e.g. ``{"int4": fixed(4), "int8": fixed(8)}``).  Every
    policy table is validated against the network's GEMM-layer count
    and priced on its fully-expanded vector.

    On the AP, latency is nearly FLAT across precisions (Table VII:
    <1% spread — bit-serial columns), so a latency budget cannot
    discriminate configurations; energy, and hence EDP, is the axis a
    CNN budget meaningfully constrains.  ``metric`` therefore defaults
    to ``"edp"``: the controller's prediction table holds modeled
    per-image EDP (J*s) and a request's ``budget_s`` is an EDP budget
    (``"energy"`` (J) and ``"latency"`` (s) also accepted); the chosen
    axis is recorded on ``BudgetController.budget_axis``.  Selection
    semantics are unchanged: the most accurate configuration whose
    predicted cost fits the budget, else the cheapest.
    """
    import numpy as np

    from repro.apsim.energy import SRAM
    from repro.apsim.mapper import LR_CONFIG, simulate_network
    from repro.apsim.workloads import NETWORKS, gemm_layers

    lay = list(layers) if layers is not None else NETWORKS[network]()
    n = len(gemm_layers(lay))
    if metric not in ("edp", "energy", "latency"):
        raise ValueError(f"metric must be edp/energy/latency, got {metric!r}")
    if configs is None:
        configs = {}
        for c in constraints:
            p = hawq_v3(c)
            configs[p.name] = p
    pred = {}
    for name, p in configs.items():
        if len(p.weight_bits) > n:
            raise ValueError(
                f"policy {p.name!r} assigns {len(p.weight_bits)} layers "
                f"but {network!r} has {n} GEMM (conv/fc) layers — the "
                f"HAWQ-V3 defaults are ResNet18 vectors; pass explicit "
                f"``configs`` for this network")
        wv, av = p.vectors(n)
        rep = simulate_network(lay, LR_CONFIG, SRAM,
                               bits=[int(b) for b in np.asarray(wv)],
                               act_bits=[int(b) for b in np.asarray(av)],
                               network=network)
        pred[name] = {"edp": rep.edp, "energy": rep.energy_j,
                      "latency": rep.latency_s}[metric]
    return BudgetController(configs, pred, n, budget_axis=metric)


# ---------------------------------------------------------------------------
# Dynamic switching (run-time bit fluidity)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BudgetController:
    """Chooses a registered precision configuration from a runtime budget.

    The chosen config is returned as *arrays*, so the switch is pure data —
    a serving binary compiled once switches per request batch.  Selection
    rule (paper §V.B): tightest-latency config whose predicted latency fits
    the budget; if none fit, the fastest config wins.
    """
    configs: Dict[str, PrecisionPolicy]
    predicted_latency_s: Dict[str, float]
    n_layers: int
    # which axis the prediction table (and hence request budgets) lives
    # on: "latency" (seconds, the LM engines), or "energy" (J) / "edp"
    # (J*s) for CNN controllers (see cnn_budget_controller) — selection
    # semantics are identical, but budgets on the wrong axis always- or
    # never-fit, so the axis is recorded on the controller itself.
    budget_axis: str = "latency"

    def order(self):
        return sorted(self.configs, key=lambda k: self.predicted_latency_s[k])

    def stacked_tables(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(n_configs, n_layers) bit tables, fastest config first."""
        ws, as_ = [], []
        for k in self.order():
            w, a = self.configs[k].vectors(self.n_layers)
            ws.append(w)
            as_.append(a)
        return jnp.stack(ws), jnp.stack(as_)

    def select(self, budget_s) -> jnp.ndarray:
        """Runtime index into stacked_tables() given a latency budget.

        ``budget_s`` may be a scalar (whole-batch budget) or a ``(B,)``
        vector (per-request budgets); the result matches its shape.  Pure
        jnp — budgets are *data*, so per-request precision never retraces.
        """
        lats = jnp.asarray([self.predicted_latency_s[k] for k in self.order()],
                           jnp.float32)
        b = jnp.asarray(budget_s, jnp.float32)
        fits = lats <= b[..., None]                  # (..., n_configs)
        # last (slowest/most accurate) fitting config, else index 0 (fastest)
        best = jnp.max(jnp.where(fits, jnp.arange(lats.shape[0]), -1), axis=-1)
        return jnp.maximum(best, 0).astype(jnp.int32)

    def resolve(self, budget_s) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(wbits, abits) tables for ``budget_s``: ``(n_layers,)`` for a
        scalar budget, ``(B, n_layers)`` for a ``(B,)`` budget vector.
        The gather is the whole "switch" — zero-retrace by construction."""
        wtab, atab = self.stacked_tables()
        idx = self.select(budget_s)
        return wtab[idx], atab[idx]
