"""Precision policies — who gets how many bits, statically or at runtime.

A :class:`PrecisionPolicy` resolves to two per-layer integer vectors
(weight bits, activation bits) that flow through the model as *data*
(scan xs), so switching configurations never recompiles — the TPU analogue
of BF-IMNA's zero-overhead dynamic mixed-precision.

Built-ins:
  * ``fixed(b)``                      — the paper's fixed-precision baseline.
  * ``per_layer([...])``              — arbitrary static mixed-precision.
  * ``hawq_v3(constraint)``           — the paper's Table VII ResNet18 study
                                        (INT4/INT8 mixes for low/medium/high
                                        latency budgets, from HAWQ-V3 [53]).
  * ``BudgetController``              — dynamic: picks among registered
                                        configurations at runtime from a
                                        latency/EDP budget signal (paper §V.B
                                        "switching between the three
                                        mixed-precision configurations
                                        dynamically").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.apsim.workloads import HAWQV3_RESNET18, HAWQV3_METADATA  # noqa: F401

FP_BITS = 16  # sentinel: >=16 means "leave in bf16/f32" (fake_quant identity)


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Per-layer (weight, activation) bit assignment for an n_layers stack."""
    name: str
    weight_bits: Tuple[int, ...]
    act_bits: Tuple[int, ...]

    def vectors(self, n_layers: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Materialize (wbits, abits) int32 vectors of length n_layers.

        Shorter tables extend with their last entry (paper Table VII rule).
        """
        def expand(tab: Sequence[int]) -> jnp.ndarray:
            vals = [tab[i] if i < len(tab) else tab[-1] for i in range(n_layers)]
            return jnp.asarray(vals, jnp.int32)
        return expand(self.weight_bits), expand(self.act_bits)

    @property
    def avg_bits(self) -> float:
        return sum(self.weight_bits) / len(self.weight_bits)


def fixed(bits: int, name: Optional[str] = None) -> PrecisionPolicy:
    return PrecisionPolicy(name or f"int{bits}", (bits,), (bits,))


def full_precision() -> PrecisionPolicy:
    return PrecisionPolicy("fp", (FP_BITS,), (FP_BITS,))


def per_layer(weight_bits: Sequence[int],
              act_bits: Optional[Sequence[int]] = None,
              name: str = "mixed") -> PrecisionPolicy:
    ab = tuple(act_bits) if act_bits is not None else tuple(weight_bits)
    return PrecisionPolicy(name, tuple(weight_bits), ab)


def hawq_v3(constraint: str) -> PrecisionPolicy:
    """Paper Table VII: HAWQ-V3 ResNet18 mixes; constraint in
    {int4, low, medium, high, int8} (weight and activation share bits)."""
    tab = HAWQV3_RESNET18[constraint]
    return per_layer(tab, name=f"hawqv3-{constraint}")


# ---------------------------------------------------------------------------
# Dynamic switching (run-time bit fluidity)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BudgetController:
    """Chooses a registered precision configuration from a runtime budget.

    The chosen config is returned as *arrays*, so the switch is pure data —
    a serving binary compiled once switches per request batch.  Selection
    rule (paper §V.B): tightest-latency config whose predicted latency fits
    the budget; if none fit, the fastest config wins.
    """
    configs: Dict[str, PrecisionPolicy]
    predicted_latency_s: Dict[str, float]
    n_layers: int

    def order(self):
        return sorted(self.configs, key=lambda k: self.predicted_latency_s[k])

    def stacked_tables(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(n_configs, n_layers) bit tables, fastest config first."""
        ws, as_ = [], []
        for k in self.order():
            w, a = self.configs[k].vectors(self.n_layers)
            ws.append(w)
            as_.append(a)
        return jnp.stack(ws), jnp.stack(as_)

    def select(self, budget_s) -> jnp.ndarray:
        """Runtime index into stacked_tables() given a latency budget.

        ``budget_s`` may be a scalar (whole-batch budget) or a ``(B,)``
        vector (per-request budgets); the result matches its shape.  Pure
        jnp — budgets are *data*, so per-request precision never retraces.
        """
        lats = jnp.asarray([self.predicted_latency_s[k] for k in self.order()],
                           jnp.float32)
        b = jnp.asarray(budget_s, jnp.float32)
        fits = lats <= b[..., None]                  # (..., n_configs)
        # last (slowest/most accurate) fitting config, else index 0 (fastest)
        best = jnp.max(jnp.where(fits, jnp.arange(lats.shape[0]), -1), axis=-1)
        return jnp.maximum(best, 0).astype(jnp.int32)

    def resolve(self, budget_s) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(wbits, abits) tables for ``budget_s``: ``(n_layers,)`` for a
        scalar budget, ``(B, n_layers)`` for a ``(B,)`` budget vector.
        The gather is the whole "switch" — zero-retrace by construction."""
        wtab, atab = self.stacked_tables()
        idx = self.select(budget_s)
        return wtab[idx], atab[idx]
