"""Precision policies — who gets how many bits, statically or at runtime.

A :class:`PrecisionPolicy` resolves to two per-layer integer vectors
(weight bits, activation bits) that flow through the model as *data*
(scan xs), so switching configurations never recompiles — the TPU analogue
of BF-IMNA's zero-overhead dynamic mixed-precision.

Built-ins:
  * ``fixed(b)``                      — the paper's fixed-precision baseline.
  * ``per_layer([...])``              — arbitrary static mixed-precision.
  * ``hawq_v3(constraint)``           — the paper's Table VII ResNet18 study
                                        (INT4/INT8 mixes for low/medium/high
                                        latency budgets, from HAWQ-V3 [53]).
  * ``BudgetController``              — dynamic, open-loop: picks among
                                        registered configurations at runtime
                                        from a latency/EDP budget signal
                                        (paper §V.B "switching between the
                                        three mixed-precision configurations
                                        dynamically").
  * ``FluidController``               — dynamic, closed-loop: charges each
                                        admission's priced AP cost against a
                                        system-level SLO window and resolves
                                        precision from the REMAINING budget
                                        (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.apsim.workloads import HAWQV3_RESNET18, HAWQV3_METADATA  # noqa: F401

FP_BITS = 16  # sentinel: >=16 means "leave in bf16/f32" (fake_quant identity)


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Per-layer (weight, activation) bit assignment for an n_layers stack."""
    name: str
    weight_bits: Tuple[int, ...]
    act_bits: Tuple[int, ...]

    def vectors(self, n_layers: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Materialize (wbits, abits) int32 vectors of length n_layers.

        Shorter tables extend with their last entry (paper Table VII rule).
        """
        def expand(tab: Sequence[int]) -> jnp.ndarray:
            vals = [tab[i] if i < len(tab) else tab[-1] for i in range(n_layers)]
            return jnp.asarray(vals, jnp.int32)
        return expand(self.weight_bits), expand(self.act_bits)

    @property
    def avg_bits(self) -> float:
        return sum(self.weight_bits) / len(self.weight_bits)


def fixed(bits: int, name: Optional[str] = None) -> PrecisionPolicy:
    return PrecisionPolicy(name or f"int{bits}", (bits,), (bits,))


def full_precision() -> PrecisionPolicy:
    return PrecisionPolicy("fp", (FP_BITS,), (FP_BITS,))


def per_layer(weight_bits: Sequence[int],
              act_bits: Optional[Sequence[int]] = None,
              name: str = "mixed") -> PrecisionPolicy:
    ab = tuple(act_bits) if act_bits is not None else tuple(weight_bits)
    return PrecisionPolicy(name, tuple(weight_bits), ab)


def hawq_v3(constraint: str) -> PrecisionPolicy:
    """Paper Table VII: HAWQ-V3 ResNet18 mixes; constraint in
    {int4, low, medium, high, int8} (weight and activation share bits)."""
    tab = HAWQV3_RESNET18[constraint]
    return per_layer(tab, name=f"hawqv3-{constraint}")


def cnn_budget_controller(network: str = "resnet18",
                          constraints: Sequence[str] = ("int4", "low",
                                                        "medium", "high",
                                                        "int8"),
                          *, layers=None,
                          configs: Optional[Dict[str, PrecisionPolicy]] = None,
                          metric: str = "edp") -> "BudgetController":
    """A :class:`BudgetController` for a CNN workload, with predicted
    per-image costs from the calibrated AP model
    (``apsim.mapper.simulate_network``).

    ``configs`` defaults to the paper's Table VII HAWQ-V3 ResNet18
    mixes (``constraints`` picks which) — those per-layer vectors only
    fit ResNet-shaped networks, so for AlexNet/VGG16 pass explicit
    policies (e.g. ``{"int4": fixed(4), "int8": fixed(8)}``).  Every
    policy table is validated against the network's GEMM-layer count
    and priced on its fully-expanded vector.

    On the AP, latency is nearly FLAT across precisions (Table VII:
    <1% spread — bit-serial columns), so a latency budget cannot
    discriminate configurations; energy, and hence EDP, is the axis a
    CNN budget meaningfully constrains.  ``metric`` therefore defaults
    to ``"edp"``: the controller's prediction table holds modeled
    per-image EDP (J*s) and a request's ``budget_s`` is an EDP budget
    (``"energy"`` (J) and ``"latency"`` (s) also accepted); the chosen
    axis is recorded on ``BudgetController.budget_axis``.  Selection
    semantics are unchanged: the most accurate configuration whose
    predicted cost fits the budget, else the cheapest.
    """
    import numpy as np

    from repro.apsim.energy import SRAM
    from repro.apsim.mapper import LR_CONFIG, simulate_network
    from repro.apsim.workloads import NETWORKS, gemm_layers

    lay = list(layers) if layers is not None else NETWORKS[network]()
    n = len(gemm_layers(lay))
    if metric not in ("edp", "energy", "latency"):
        raise ValueError(f"metric must be edp/energy/latency, got {metric!r}")
    if configs is None:
        configs = {}
        for c in constraints:
            p = hawq_v3(c)
            configs[p.name] = p
    pred = {}
    for name, p in configs.items():
        if len(p.weight_bits) > n:
            raise ValueError(
                f"policy {p.name!r} assigns {len(p.weight_bits)} layers "
                f"but {network!r} has {n} GEMM (conv/fc) layers — the "
                f"HAWQ-V3 defaults are ResNet18 vectors; pass explicit "
                f"``configs`` for this network")
        wv, av = p.vectors(n)
        rep = simulate_network(lay, LR_CONFIG, SRAM,
                               bits=[int(b) for b in np.asarray(wv)],
                               act_bits=[int(b) for b in np.asarray(av)],
                               network=network)
        pred[name] = {"edp": rep.edp, "energy": rep.energy_j,
                      "latency": rep.latency_s}[metric]
    return BudgetController(configs, pred, n, budget_axis=metric)


# ---------------------------------------------------------------------------
# Dynamic switching (run-time bit fluidity)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BudgetController:
    """Chooses a registered precision configuration from a runtime budget.

    The chosen config is returned as *arrays*, so the switch is pure data —
    a serving binary compiled once switches per request batch.  Selection
    rule (paper §V.B): tightest-latency config whose predicted latency fits
    the budget; if none fit, the fastest config wins.
    """
    configs: Dict[str, PrecisionPolicy]
    predicted_latency_s: Dict[str, float]
    n_layers: int
    # which axis the prediction table (and hence request budgets) lives
    # on: "latency" (seconds, the LM engines), or "energy" (J) / "edp"
    # (J*s) for CNN controllers (see cnn_budget_controller) — selection
    # semantics are identical, but budgets on the wrong axis always- or
    # never-fit, so the axis is recorded on the controller itself.
    budget_axis: str = "latency"
    # admission-hot-path caches (configs/predictions are fixed after
    # construction; engines resolve on EVERY admission and decode tick,
    # so the tables must not be rebuilt from Python dicts each time)
    _order: Optional[Tuple[str, ...]] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    _tables: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    _lats: Optional[jnp.ndarray] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    # placement co-decision state (adopt_plan): the adopted plan plus
    # the per-config prediction scale it applied
    _plan: Optional[object] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    plan_gain: Optional[Dict[str, float]] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    def adopt_plan(self, plan, pricer) -> None:
        """Re-price the prediction table under a placement plan — the
        precision-vs-replication co-decision (DESIGN.md §13).

        Each registered config's predicted budget-axis cost is scaled by
        the ratio its PLAN-amortized priced cost bears to its base cost
        (``PlacementPlan.price`` divides per-entry latency by replicas;
        energy is unchanged).  Replication makes every config look —
        honestly — cheaper on latency/EDP axes, so the same budget or
        SLO headroom now resolves HIGHER bits: the plan trades its
        replica memory for precision.  ``pricer`` is the runtime's
        cached :class:`~repro.serve.accounting.BitVectorPricer` (same
        gemms/head the predictions were built from, so predictions and
        admission charges stay in lockstep)."""
        if self._plan is plan:
            return                      # idempotent re-adoption
        if self._plan is not None:
            raise ValueError("controller already adopted a different "
                             "placement plan; build a fresh controller "
                             "to re-plan")
        import numpy as np

        def _axis_val(cost) -> float:
            if self.budget_axis == "latency":
                return cost.latency_s
            if self.budget_axis == "energy":
                return cost.energy_j
            return cost.energy_j * cost.latency_s

        gain: Dict[str, float] = {}
        for name, p in self.configs.items():
            wv, av = p.vectors(self.n_layers)
            base = pricer.price(np.asarray(wv), np.asarray(av))
            planned = plan.price(base)
            b = _axis_val(base)
            ratio = _axis_val(planned) / b if b > 0 else 1.0
            gain[name] = ratio
            self.predicted_latency_s[name] *= ratio
        self._plan = plan
        self.plan_gain = gain
        # prediction values moved: drop the admission-path caches (the
        # config order is re-derived from the scaled table)
        self._order = None
        self._tables = None
        self._lats = None

    def order(self) -> list:
        if self._order is None:
            self._order = tuple(sorted(
                self.configs, key=lambda k: self.predicted_latency_s[k]))
        return list(self._order)

    def stacked_tables(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(n_configs, n_layers) bit tables, fastest config first (cached
        on the controller — the hot admission path gathers from them)."""
        if self._tables is None:
            ws, as_ = [], []
            for k in self.order():
                w, a = self.configs[k].vectors(self.n_layers)
                ws.append(w)
                as_.append(a)
            self._tables = (jnp.stack(ws), jnp.stack(as_))
        return self._tables

    def latency_array(self) -> jnp.ndarray:
        """Predicted budget-axis costs, fastest config first (cached)."""
        if self._lats is None:
            self._lats = jnp.asarray(
                [self.predicted_latency_s[k] for k in self.order()],
                jnp.float32)
        return self._lats

    def select(self, budget_s) -> jnp.ndarray:
        """Runtime index into stacked_tables() given a latency budget.

        ``budget_s`` may be a scalar (whole-batch budget) or a ``(B,)``
        vector (per-request budgets); the result matches its shape.  Pure
        jnp — budgets are *data*, so per-request precision never retraces.
        """
        lats = self.latency_array()
        b = jnp.asarray(budget_s, jnp.float32)
        fits = lats <= b[..., None]                  # (..., n_configs)
        # last (slowest/most accurate) fitting config, else index 0 (fastest)
        best = jnp.max(jnp.where(fits, jnp.arange(lats.shape[0]), -1), axis=-1)
        return jnp.maximum(best, 0).astype(jnp.int32)

    def resolve(self, budget_s) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(wbits, abits) tables for ``budget_s``: ``(n_layers,)`` for a
        scalar budget, ``(B, n_layers)`` for a ``(B,)`` budget vector.
        The gather is the whole "switch" — zero-retrace by construction."""
        wtab, atab = self.stacked_tables()
        idx = self.select(budget_s)
        return wtab[idx], atab[idx]


@dataclasses.dataclass
class FluidController(BudgetController):
    """Closed-loop bit fluidity: precision from the REMAINING budget.

    :class:`BudgetController` is open-loop — a static prediction table
    maps each request's own budget to a configuration once, with no
    feedback from what the system has actually spent.  The fluid
    controller closes the loop the way the paper's §V.B run describes
    ("switching between the three mixed-precision configurations
    dynamically, as imposed by the changing run-time resource
    requirements"): the serving runtime charges every admission's
    *priced* AP cost (``serve/accounting.py``) against a system-level
    SLO window of ``slo`` budget-axis units per ``window`` admissions,
    and each new admission's effective budget is its share of whatever
    budget remains — so over-spending early requests push later ones
    into cheaper (lower-bit) configurations and under-spending relaxes
    them, Table VII's latency-budget sweep run as a live control loop
    (cf. LRMP's runtime precision re-allocation, arXiv:2312.03146).

    The loop lives entirely host-side: ``admission_budget()`` returns an
    ordinary float, selection stays the inherited pure-data gather, so
    closed-loop config switches never retrace.  Window rollover expires
    unused credit but carries debt, keeping the long-run average at the
    SLO.

    Two window shapes (the rollover semantics under bursty arrivals):

      * admission-count (``window_ticks == 0``, the default): ``slo``
        units per ``window`` admissions.  Load-independent — a 10x
        burst spends the window 10x faster and later admissions tighten,
        but an idle hour and a busy hour get the same budget per
        request.
      * tick-based (``window_ticks > 0``): ``slo`` units per
        ``window_ticks`` *scheduler ticks* — a rate SLO.  The serving
        runtime calls :meth:`tick` once per scheduler tick; headroom
        splits the remaining window budget over the admissions known to
        be waiting (``pending``), so a burst that deepens the queue
        tightens every admission's share immediately while a trough
        (empty queue) relaxes back to full precision.  This is the
        window shape the traffic harness's diurnal/spike experiments
        drive (``serve/traffic.py``).
    """
    slo: float = float("inf")      # budget-axis units per window
    window: int = 32               # admissions per SLO window
    window_ticks: int = 0          # >0: roll on scheduler ticks instead
    spent: float = 0.0             # charged so far in this window
    served: int = 0                # admissions charged in this window
    ticks: int = 0                 # scheduler ticks elapsed in this window
    saved: float = 0.0             # cumulative budget-axis cost avoided by
                                   # the prefix-cache tier (hits charge only
                                   # their miss fraction; this tracks the
                                   # difference — introspection, not spend)
    # ---- draft-bit autotuning (DESIGN.md §11 stretch): the closed loop
    # watches an EMA of the speculative accept rate and shifts the DRAFT
    # configuration index — low acceptance means the cheap drafts are
    # being rejected (wasted draft+verify spend), so drafting moves to a
    # higher-bit config; high acceptance means the drafts are already
    # good enough and a cheaper config would do.  Off by default (the
    # PR 8 spec-decode baselines stay byte-stable).
    draft_autotune: bool = False
    draft_ema_alpha: float = 0.2   # EMA smoothing of per-round accept rates
    draft_accept_low: float = 0.45     # EMA below this: raise draft bits
    draft_accept_high: float = 0.85    # EMA above this: lower draft bits
    draft_accept_ema: float = -1.0     # -1 = no observation yet (reset
                                       # after each shift: hysteresis)
    draft_shift: int = 0           # config-index offset applied to the
                                   # engine's base draft configuration

    def headroom(self, pending: int = 1) -> float:
        """Per-admission share of the remaining window budget.

        ``pending`` (tick-based windows only) is how many admissions are
        known to be competing for the remainder — the runtime passes its
        queue depth; admission-count windows split over the window's
        remaining admission slots instead."""
        if self.window_ticks:
            left = max(pending, 1)
        else:
            left = max(self.window - self.served, 1)
        return max(self.slo - self.spent, 0.0) / left

    def admission_budget(self, requested: Optional[float] = None,
                         pending: int = 1) -> float:
        """Effective budget for the next admission: the closed-loop
        headroom, tightened by the request's own budget when it has one."""
        h = self.headroom(pending)
        return h if requested is None else min(float(requested), h)

    def charge(self, amount: float) -> None:
        """Record one admission's actual (priced) budget-axis cost."""
        self.spent += float(amount)
        self.served += 1
        if not self.window_ticks and self.served >= self.window:
            self._roll()

    def tick(self) -> None:
        """One scheduler tick (tick-based windows; no-op otherwise)."""
        if not self.window_ticks:
            return
        self.ticks += 1
        if self.ticks >= self.window_ticks:
            self._roll()

    def _roll(self) -> None:
        # roll the window: unused credit expires, debt carries over
        self.spent = max(self.spent - self.slo, 0.0)
        self.served = 0
        self.ticks = 0

    # Draft depths the closed loop can hand out, slowest-headroom first.
    DRAFT_DEPTHS = (0, 2, 4, 8)

    def draft_depth(self) -> int:
        """Speculative draft depth for the next admission, from SLO
        headroom.  Drafting spends extra budget-axis units now (k draft
        tokens + a (k+1)-wide verify per round) to buy latency later, so
        depth scales with the *fraction* of the window budget this
        admission's share represents: a window with plenty of slack
        drafts deep (k=8), a tight one shallow, and a window in debt
        falls back to k=0 — exactly today's non-speculative path, so the
        closed loop degrades gracefully under pressure (DESIGN.md §11).
        """
        if self.slo == float("inf"):
            return self.DRAFT_DEPTHS[-1]
        if self.slo <= 0:
            return 0
        frac = max(self.slo - self.spent, 0.0) / self.slo
        if frac >= 0.5:
            return 8
        if frac >= 0.25:
            return 4
        if frac >= 0.10:
            return 2
        return 0

    def observe_accept(self, rate: float) -> None:
        """Feed one speculative round's accept rate (accepted/drafted)
        into the draft-bit autotuner.  EMA-smoothed; when the average
        leaves the [low, high] deadband the draft config index shifts by
        one (up = more bits on low acceptance, down = fewer on high) and
        the EMA resets so the next decision waits for fresh evidence
        under the new bits (hysteresis).  The engine clamps the final
        index into its config range, so the shift itself only needs a
        loose clamp here."""
        if not self.draft_autotune:
            return
        r = min(max(float(rate), 0.0), 1.0)
        a = self.draft_ema_alpha
        if self.draft_accept_ema < 0.0:
            self.draft_accept_ema = r
        else:
            self.draft_accept_ema = (1.0 - a) * self.draft_accept_ema + a * r
        if self.draft_accept_ema < self.draft_accept_low:
            self.draft_shift = min(self.draft_shift + 1, 8)
            self.draft_accept_ema = -1.0
        elif self.draft_accept_ema > self.draft_accept_high:
            self.draft_shift = max(self.draft_shift - 1, -8)
            self.draft_accept_ema = -1.0

    def record_saved(self, amount: float) -> None:
        """Track budget-axis cost a cache hit avoided charging.  The
        SLO window itself only ever sees the miss fraction (that's the
        point: hits free budget for higher-precision admissions); this
        running total is the controller's own view of how much the
        cache tier is subsidizing the window."""
        self.saved += float(amount)

    def reconcile(self, delta: float) -> None:
        """Adjust the ledger after a request finishes: admissions are
        charged their PLANNED unit count up front (so headroom reacts
        immediately), and an early-terminating request (eos) refunds the
        difference here — the window's spend tracks reality, not plans."""
        self.spent = max(self.spent + float(delta), 0.0)

    @classmethod
    def from_open_loop(cls, ctrl: BudgetController, *, slo: float,
                       window: int = 32,
                       window_ticks: int = 0) -> "FluidController":
        """Wrap an existing controller's configs/predictions in a
        closed-loop SLO window (axis carried over)."""
        return cls(dict(ctrl.configs), dict(ctrl.predicted_latency_s),
                   ctrl.n_layers, budget_axis=ctrl.budget_axis,
                   slo=slo, window=window, window_ticks=window_ticks)
