"""Derived metrics + the Table VIII peak-performance model.

Peak throughput: all 19.66M CAP rows hold one MAC each; the bit-serial
multiply + amortized vertical add complete in ``3M^2 + 11M`` cycles at 1 GHz
(counting 2 ops per MAC).  This cycle polynomial reproduces the paper's
published peaks EXACTLY for all three precisions:

    M=1 : 14 cy   -> 2,808,686 GOPS   (paper: 2,808,686)
    M=8 : 280 cy  ->   140,434 GOPS   (paper:   140,434)
    M=16: 944 cy  ->    41,654 GOPS   (paper:    41,654)

i.e. the paper's peak model is cycles(M) = 3M^2 + 11M — consistent with a
LUT walk of 3 compare-dominated passes per bit pair plus ~11 linear-cost
populate/readout passes per bit.  (Reverse-engineered; noted in
EXPERIMENTS.md.)

Peak power uses the same cell-energy accounting as the end-to-end simulator
(multiply-phase compares dominate), so peak GOPS/W is a *prediction* — the
paper does not state its power basis; deltas are reported.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.apsim import costmodel as cmod
from repro.apsim.energy import TechParams, SRAM
from repro.apsim.mapper import BFIMNAConfig, LR_CONFIG, _gemm_layer
from repro.apsim.workloads import Layer, fc, gemm_layers


def peak_cycles(M: int) -> float:
    return 3.0 * M * M + 11.0 * M


def peak_gops(M: int, cfg: BFIMNAConfig = LR_CONFIG) -> float:
    ops = 2.0 * cfg.total_rows
    return ops / peak_cycles(M) * (cfg.freq_hz / 1e9)


def peak_energy_per_mac_j(M: int, tech: TechParams = SRAM) -> float:
    """Paper peak-power basis: ONE compare-energy per bit-pair pass per
    row — e_mac(M) = E_compare * (M^2 + M).

    Reverse-engineered by fitting the paper's three published GOPS/W
    points (22879@1b, 641@8b, 170@16b): the quadratic coefficient of the
    fit, 4.31e-14 J, matches our independently Fig.6/7-calibrated
    E_COMPARE_J = 4.59e-14 J within 6% — i.e. the paper's peak model
    charges the multiply's M^2 bit-pair walk plus an M-linear add at one
    compare-energy each, per resident MAC.  (The end-to-end simulator
    keeps the full cell-level accounting; this basis is used only for the
    Table VIII peaks, like the paper's 'peak values [40]'.)"""
    cell_ops = float(M * M + M)
    return cell_ops * tech.e_compare_j + 2.0 * M * tech.e_write_j


def peak_gops_per_w(M: int, tech: TechParams = SRAM,
                    cfg: BFIMNAConfig = LR_CONFIG) -> float:
    ops_per_j = 2.0 / peak_energy_per_mac_j(M, tech)
    return ops_per_j / 1e9


# ---------------------------------------------------------------------------
# Bit-vector pricing — the serve engine's per-request latency/EDP accounting.
#
# A language model's serve path is, per token, a fixed list of GEMVs whose
# dims come from the model config (lm.layer_gemm_dims); a request's resolved
# per-layer (wbits, abits) vector prices each slot's GEMVs on the AP via the
# same calibrated mapping the paper benchmarks use (mapper._gemm_layer on an
# FC layer — (1, K) @ (K, N) is exactly the paper's FC case).  This is the
# Table 7 accuracy-vs-EDP trade-off made live: every admitted request gets
# AP cycles/energy per token, and RequestStats reports latency/EDP.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BitVectorCost:
    """Per-token AP cost of one resolved per-layer bit vector.

    ``per_layer_*`` align with the bit-slot axis (plus one trailing entry
    for the logits head when it was priced); totals derive from them."""
    per_layer_cycles: Tuple[float, ...]
    per_layer_energy_j: Tuple[float, ...]
    freq_hz: float = 1e9

    @property
    def cycles(self) -> float:
        return sum(self.per_layer_cycles)

    @property
    def energy_j(self) -> float:
        return sum(self.per_layer_energy_j)

    @property
    def latency_s(self) -> float:
        return self.cycles / self.freq_hz

    @property
    def edp(self) -> float:
        """Per-token energy-delay product (J·s)."""
        return self.energy_j * self.latency_s


def _clamp_bits(b) -> int:
    return int(min(max(int(b), 1), 16))


@functools.lru_cache(maxsize=4096)
def gemv_cost(K: int, N: int, Mw: int, Ma: int, *,
              cfg: BFIMNAConfig = LR_CONFIG,
              tech: TechParams = SRAM) -> Tuple[float, float]:
    """(cycles, energy_j) of one serve GEMV (1, K) @ (K, N) at (Mw, Ma),
    under the paper's batch-size-1 CNN mapping (``mapper._gemm_layer``).

    Cached: uniform bit vectors price every layer to the same (K, N, Mw,
    Ma) tuples, so per-request admission pays the analytic mapping once
    per distinct shape/bits pair, not once per layer."""
    rep = _gemm_layer(cfg, tech, fc(f"gemv_{K}x{N}", K, N, relu=False),
                      Mw, Ma)
    return rep.cycles, rep.energy_j


@functools.lru_cache(maxsize=8192)
def serve_gemv_cost(K: int, N: int, Mw: int, Ma: int, u: int = 1, *,
                    cfg: BFIMNAConfig = LR_CONFIG,
                    tech: TechParams = SRAM) -> Tuple[float, float]:
    """(cycles, energy_j) of a serve GEMM (u, K) @ (K, N) at (Mw, Ma)
    under the latency-optimal *decode* mapping.

    The paper mapping (:func:`gemv_cost`) packs ``opc`` output blocks per
    CAP and charges their reductions sequentially — correct when a layer's
    blocks fill every CAP (the Table V-VII CNN regime), but a serve GEMV
    has only N·u output blocks for 4096 CAPs, so almost every CAP is idle
    and each holds a single block.  Two refinements, both only meaningful
    in that underutilized regime (at full occupancy they reduce to the
    paper mapping, which keeps the calibrated CNN tables byte-identical):

    * **occupancy-aware reduction**: a CAP only reduces the blocks it
      actually holds — ``min(opc, ceil(blocks / n_caps))``, not ``opc``;
    * **latency-optimal fold**: with idle CAPs available the mapper may
      split one block's K products over ``f`` CAPs (the existing
      ``j_fold`` mechanism), shrinking the in-CAP chain to ``ceil(K/f)-1``
      adds at the cost of ``ceil(log2 f)`` cross-CAP partial-sum merge
      rounds (charged per round, unlike the paper path's single round,
      i.e. strictly *more* conservative per fold) and ``f``× activation
      streaming energy.  The fold is chosen by exhaustive argmin over
      modeled cycles; energy is reported at the chosen fold.

    Under this mapping decode latency is genuinely bit-dependent (the
    4·Mw·Ma multiply passes dominate once the chain is short) and a
    ``u``-token verify chunk amortizes the pass over u tokens — the two
    properties bit-fluid speculative decoding prices against.
    """
    i, j = N, K
    best: Optional[Tuple[float, float]] = None
    max_f = min(j, 256)
    for f in range(1, max_f + 1):
        j_sub = math.ceil(j / f)
        if j_sub > cfg.cap_rows - 1:
            continue
        opc = max(1, (cfg.cap_rows - 1) // j_sub)
        total_blocks = i * u * f
        steps = math.ceil(total_blocks / (cfg.n_caps * opc))
        occ = min(opc, math.ceil(total_blocks / cfg.n_caps))
        per_step = cmod.Cost()
        per_step.writes += Ma                        # stream activations
        passes = 4 * Mw * Ma                         # bit-serial multiply
        per_step.compares += passes
        per_step.writes += passes
        seq_adds = occ * max(j_sub - 1, 0)           # resident blocks only
        per_step.compares += 4 * seq_adds
        per_step.writes += 4 * seq_adds
        per_step.word_ops += occ
        cycles = steps * per_step.cycles(tech) + Mw * tech.write_cycles
        width = Mw + Ma + math.log2(max(j, 2))
        if f > 1:                                    # cross-CAP merges,
            merge_rounds = math.ceil(math.log2(f))   # charged per round
            cycles += steps * merge_rounds * 8 * width * tech.write_cycles * 0.5
        out_bits_elem = Mw + Ma + math.ceil(math.log2(max(j, 2)))
        out_bits = i * u * out_bits_elem
        cycles += cfg.mesh.transfer_latency_s(out_bits) * cfg.freq_hz
        # ---- energy at this fold (same accounting as _gemm_layer) ------
        comp = cmod.rt_matmat(i, j, u, Mw, Ma, mode="2d",
                              parallel_blocks=cfg.n_caps * opc)
        energy = comp.energy_j(tech)
        in_bits = j * u * Ma * f
        w_bits = i * j * Mw
        move_bits = in_bits + w_bits + out_bits
        energy += cfg.mesh.transfer_energy_j(move_bits)
        energy += 2.0 * i * u * out_bits_elem * (tech.e_write_j
                                                 + tech.e_read_j) / 2.0
        if f > 1:                                    # partial-sum merge adds
            energy += (f - 1) * i * u * cmod.rt_add(
                math.ceil(width), 2, populate=False, readout=False
            ).energy_j(tech)
        if best is None or cycles < best[0]:
            best = (cycles, energy)
    assert best is not None
    return best


@functools.lru_cache(maxsize=4096)
def layer_gemm_cost(layer: Layer, Mw: int, Ma: int, *,
                    cfg: BFIMNAConfig = LR_CONFIG,
                    tech: TechParams = SRAM) -> Tuple[float, float]:
    """(cycles, energy_j) of one full conv/fc GEMM layer at (Mw, Ma) —
    the CNN serve path's per-image pricing unit: the layer's (i, j, u)
    GEMM through the same calibrated mapping the paper benchmarks use
    (``mapper._gemm_layer``, paper batch size 1).  Cached per distinct
    (layer, bits) pair, like :func:`gemv_cost`."""
    rep = _gemm_layer(cfg, tech, layer, Mw, Ma)
    return rep.cycles, rep.energy_j


def network_gemms(layers: Sequence[Layer]) -> Tuple[Tuple[Layer, ...], ...]:
    """Per-bit-slot pricing entries for a CNN workload: one conv/fc
    :class:`Layer` per slot — ``price_bit_vector`` prices Layer items
    through :func:`layer_gemm_cost` (full conv-as-GEMM cost) alongside
    plain (K, N) GEMV pairs (the LM serve path)."""
    return tuple((l,) for l in gemm_layers(list(layers)))


def price_bit_vector(gemms: Sequence[Sequence],
                     wvec: Sequence[int], avec: Sequence[int], *,
                     head: Optional[Tuple[int, int]] = None,
                     units: int = 1,
                     cfg: BFIMNAConfig = LR_CONFIG,
                     tech: TechParams = SRAM) -> BitVectorCost:
    """Price a resolved per-layer bit vector against its model's GEMMs.

    ``gemms``: one sequence of GEMM descriptors per bit slot — (K, N)
    pairs for serve GEMVs (see ``lm.layer_gemm_dims``), priced under the
    latency-optimal decode mapping (:func:`serve_gemv_cost`), or workload
    :class:`Layer` records for full conv/fc GEMMs (see
    :func:`network_gemms`), priced under the paper mapping; ``head``,
    when given, is priced at the last slot's bits (the logits-GEMM rule)
    and appended as a trailing entry.  Bits clamp into [1, 16] (>= 16 is
    the fp sentinel).  ``units`` batches every (K, N) GEMV over u tokens
    (the speculative verify chunk) — Layer items reject units != 1.
    """
    if len(wvec) != len(gemms) or len(avec) != len(gemms):
        raise ValueError(
            f"bit vectors (len {len(wvec)}/{len(avec)}) do not match the "
            f"model's {len(gemms)} bit slots")
    cyc, en = [], []
    for dims, w, a in zip(gemms, wvec, avec):
        c, e = _slot_cost(dims, _clamp_bits(w), _clamp_bits(a), cfg, tech,
                          units)
        cyc.append(c)
        en.append(e)
    if head is not None:
        ci, ei = serve_gemv_cost(head[0], head[1], _clamp_bits(wvec[-1]),
                                 _clamp_bits(avec[-1]), units,
                                 cfg=cfg, tech=tech)
        cyc.append(ci)
        en.append(ei)
    return BitVectorCost(tuple(cyc), tuple(en), cfg.freq_hz)


def _slot_cost(dims: Sequence, Mw: int, Ma: int, cfg: BFIMNAConfig,
               tech: TechParams, units: int = 1) -> Tuple[float, float]:
    """(cycles, energy_j) of one bit slot's GEMM descriptors at (Mw, Ma).

    Single accumulation point for both the per-vector and per-matrix
    pricers, so the two are bit-identical (same item order, same float
    summation order)."""
    c = e = 0.0
    for item in dims:
        if isinstance(item, Layer):
            if units != 1:
                raise ValueError(
                    "chunked pricing (units != 1) only applies to serve "
                    "GEMV slots, not full conv/fc Layer slots")
            ci, ei = layer_gemm_cost(item, Mw, Ma, cfg=cfg, tech=tech)
        else:
            K, N = item
            ci, ei = serve_gemv_cost(K, N, Mw, Ma, units, cfg=cfg,
                                     tech=tech)
        c += ci
        e += ei
    return c, e


def price_bit_matrix(gemms: Sequence[Sequence], wmat, amat, *,
                     head: Optional[Tuple[int, int]] = None,
                     cfg: BFIMNAConfig = LR_CONFIG,
                     tech: TechParams = SRAM) -> List[BitVectorCost]:
    """Price a whole ``(B, n_slots)`` bit matrix in one pass.

    The serving runtime admits batches, not vectors: every admission
    round resolves a ``(B, n_slots)`` bit matrix, and pricing it row by
    row through :func:`price_bit_vector` costs ``B * n_slots`` Python
    loop iterations even when the controller only ever emits a handful
    of distinct configurations.  Here the analytic mapping runs once per
    *distinct clamped (wbits, abits) pair per slot* — the matrix then
    gathers its per-slot costs with numpy, so a B=32 batch over a
    5-config controller pays ~``n_pairs * n_slots`` mapping lookups
    (all LRU-cached) plus one vectorized gather.  Rows with identical
    bit vectors share ONE :class:`BitVectorCost` object (callers rely on
    identity for their own caches).  Row semantics are exactly
    :func:`price_bit_vector`'s, bit-identical per row.
    """
    wmat = np.asarray(wmat, np.int64)
    amat = np.asarray(amat, np.int64)
    if wmat.ndim == 1:
        wmat, amat = wmat[None], amat[None]
    if wmat.shape != amat.shape or wmat.ndim != 2:
        raise ValueError(f"bit matrices must share a (B, n_slots) shape, "
                         f"got {wmat.shape} / {amat.shape}")
    B, L = wmat.shape
    if L != len(gemms):
        raise ValueError(f"bit matrices (n_slots {L}) do not match the "
                         f"model's {len(gemms)} bit slots")
    wc = np.clip(wmat, 1, 16)
    ac = np.clip(amat, 1, 16)
    pairs = np.stack([wc, ac], axis=-1).reshape(-1, 2)
    uniq, inv = np.unique(pairs, axis=0, return_inverse=True)
    inv = inv.reshape(B, L)
    cyc_tab = np.empty((uniq.shape[0], L))
    en_tab = np.empty((uniq.shape[0], L))
    head_tab = np.empty((uniq.shape[0], 2))
    for pi, (Mw, Ma) in enumerate(uniq):
        for s, dims in enumerate(gemms):
            cyc_tab[pi, s], en_tab[pi, s] = _slot_cost(
                dims, int(Mw), int(Ma), cfg, tech)
        if head is not None:
            head_tab[pi] = serve_gemv_cost(head[0], head[1], int(Mw),
                                           int(Ma), cfg=cfg, tech=tech)
    cyc = cyc_tab[inv, np.arange(L)[None, :]]            # (B, L) gathers
    en = en_tab[inv, np.arange(L)[None, :]]
    out: List[BitVectorCost] = []
    shared: Dict[bytes, BitVectorCost] = {}
    for i in range(B):
        key = wc[i].tobytes() + b"|" + ac[i].tobytes()
        hit = shared.get(key)
        if hit is None:
            pc = tuple(float(v) for v in cyc[i])
            pe = tuple(float(v) for v in en[i])
            if head is not None:
                hc, he = head_tab[inv[i, -1]]
                pc, pe = pc + (float(hc),), pe + (float(he),)
            hit = BitVectorCost(pc, pe, cfg.freq_hz)
            shared[key] = hit
        out.append(hit)
    return out


PAPER_TABLE8 = {
    # framework: (tech node, freq GHz, precision, GOPS, GOPS/W)
    "H100 GPU": ("TSMC 4N", 1.83, 8, 1_979_000, 2827),
    "TPUv4": ("7nm", 1.05, 8, 275_000, 1432),
    "Valavi [43]": ("65nm", 0.1, 1, 18_876, 866_000),
    "Sim [37]": ("65nm", 0.125, 16, 64, 1422),
    "DaDianNao": ("32nm", 0.606, 16, 5584, 278),
    "ISAAC": ("32nm-memristive", 1.2, 16, 40_907, 622),
    "PipeLayer": ("50nm-memristive", None, 16, 122_706, 143),
    "IMCA": ("65nm", 1.0, 8, 3, 4630),
    "PUMA": ("32nm-memristive", 1.0, 16, 52_310, 840),
    "BF-IMNA_1b (paper)": ("16nm", 1.0, 1, 2_808_686, 22_879),
    "BF-IMNA_8b (paper)": ("16nm", 1.0, 8, 140_434, 641),
    "BF-IMNA_16b (paper)": ("16nm", 1.0, 16, 41_654, 170),
}
