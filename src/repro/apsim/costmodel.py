"""AP runtime/cost models — Eqs. 1-15 and Tables I & II of the BF-IMNA paper.

Every AP operation is a sequence of *compare* / *write* / *read* passes.
Table I counts passes; latency multiplies pass counts by per-pass cycle
costs (technology dependent -- ReRAM writes are slower), and energy
multiplies *cell-level* op counts (how many CAM cells each pass touches)
by per-cell energies from ``energy.TechParams``.

Conventions (paper section III.B):
  * ``M``     operand bitwidth.  Mixed precision multiply uses ``Mw * Ma``.
  * ``L``     number of words stored in the AP (2 words per row).
  * a *pass* = one compare phase + (on average) one write phase applied to a
    pair of columns (horizontal mode) or a pair of rows (vertical mode);
    the LUTs of add/multiply have 4 passes per bit position.
  * bit-sequential column write/read touches all L rows of one column;
    word-sequential read/write of one word costs 2 cycles (paper: "two-cycle
    requirement per writing a row/column").

All ``rt_*`` functions return a :class:`Cost` whose ``ops`` drive latency
and whose ``cells`` drive energy.  ``mode`` selects the AP flavour of
Table I: ``"1d"``, ``"2d"`` (no segmentation -- the BF-IMNA design point),
or ``"2dseg"``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

MODES = ("1d", "2d", "2dseg")


@dataclasses.dataclass
class Cost:
    """Pass-level op counts (latency) + cell-level op counts (energy)."""

    # op-level counts (each op = one array-wide pass)
    compares: float = 0.0
    writes: float = 0.0          # LUT / populate column writes
    reads: float = 0.0           # bit-sequential column reads
    word_ops: float = 0.0        # word-sequential read/write ops (2 cycles each)
    # cell-level counts (for energy).  Data writes (populate / transfers)
    # always pay full write energy; LUT-pass writes mostly re-write the value
    # already stored, so in ReRAM only a *toggle fraction* pays the 21.7 pJ
    # SET/RESET cost (state-dependent write energy).
    cell_compares: float = 0.0
    cell_writes: float = 0.0     # data writes: populate, transfers, reshape
    cell_writes_lut: float = 0.0  # LUT-pass result writes (toggle-weighted)
    cell_reads: float = 0.0

    def __add__(self, other: "Cost") -> "Cost":
        return Cost(*(getattr(self, f.name) + getattr(other, f.name)
                      for f in dataclasses.fields(Cost)))

    def scale(self, k: float) -> "Cost":
        return Cost(*(getattr(self, f.name) * k for f in dataclasses.fields(Cost)))

    # ---- latency ---------------------------------------------------------
    def cycles(self, tech) -> float:
        """Latency in AP cycles for technology ``tech`` (TechParams).

        Word-sequential ops count 1 cycle each, matching Table I's literal
        "+ (L-1)" transfer terms (the two-cycle write of §II.B is absorbed
        into the table's constants)."""
        return (self.compares * tech.compare_cycles
                + self.writes * tech.write_cycles
                + self.reads * tech.read_cycles
                + self.word_ops * tech.write_cycles)

    # ---- energy ----------------------------------------------------------
    def energy_j(self, tech) -> float:
        """Energy in Joules for technology ``tech``."""
        return (self.cell_compares * tech.e_compare_j
                + self.cell_writes * tech.e_write_j
                + self.cell_writes_lut * tech.lut_toggle_frac * tech.e_write_j
                + self.cell_reads * tech.e_read_j)

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def _check(mode: str) -> None:
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")


def _log2(x: float) -> float:
    return math.log2(max(x, 1.0))


# ---------------------------------------------------------------------------
# Micro functions
# ---------------------------------------------------------------------------

def rt_add(M: int, L: int, mode: str = "2d", *, populate: bool = True,
           readout: bool = True) -> Cost:
    """In-place vector addition  A + B -> B  (Eq. 1): 2M + 8M + M + 1.

    Identical on 1D and 2D APs (horizontal mode only).  ``L`` words are
    stored two per row (L/2 rows active).
    """
    _check(mode)
    c = Cost()
    rows = L / 2.0
    if populate:                      # 2M bit-sequential column writes
        c.writes += 2 * M
        c.cell_writes += 2 * M * rows
    # LUT: 4 passes per column pair, M column pairs -> 4M compares + 4M writes
    c.compares += 4 * M
    c.cell_compares += 4 * M * rows * 2            # each compare senses 2 cols x rows
    c.writes += 4 * M
    c.cell_writes_lut += 4 * M * rows * 0.5            # ~half the rows match & get written
    if readout:                       # M+1 column reads (result has carry bit)
        c.reads += M + 1
        c.cell_reads += (M + 1) * rows
    return c


def rt_multiply(Mw: int, Ma: int, L: int, mode: str = "2d", *,
                populate: bool = True, readout: bool = True) -> Cost:
    """Out-of-place multiply A*B -> C (Eq. 2): 2M + 8M^2 + 2M.

    Mixed precision: the LUT walks ``Mw * Ma`` bit pairs (this is the
    bit-serial O(M^2) the paper exploits for bit fluidity).
    """
    _check(mode)
    c = Cost()
    rows = L / 2.0
    if populate:
        c.writes += Mw + Ma
        c.cell_writes += (Mw + Ma) * rows
    passes = 4 * Mw * Ma
    c.compares += passes
    c.cell_compares += passes * rows * 2
    c.writes += passes
    c.cell_writes_lut += passes * rows * 0.5
    if readout:                       # product is Mw+Ma bits wide
        c.reads += Mw + Ma
        c.cell_reads += (Mw + Ma) * rows
    return c


def rt_reduce(M: int, L: int, mode: str = "2d", *, populate: bool = True,
              readout: bool = True) -> Cost:
    """Vector reduction sum(A) (Eqs. 3-5).

    1D:    2M + sum_q 8(M+q-1) over log2(L) rounds + (L-1) word transfers + 1
    2D:    2M + 8M + 8(L/2 - 1) + 1        (vertical row-pair adds, sequential)
    2Dseg: 2M + 8M + 8 log2(L/2) + 1       (row pairs in parallel)
    """
    _check(mode)
    c = Cost()
    rows = L / 2.0
    if populate:
        c.writes += 2 * M
        c.cell_writes += 2 * M * rows
    if mode == "1d":
        for q in range(1, int(_log2(L)) + 1):
            width = M + q - 1
            c.compares += 4 * width
            c.cell_compares += 4 * width * rows * 2
            c.writes += 4 * width
            c.cell_writes_lut += 4 * width * rows * 0.5
        transfers = L / 2.0 - 1
        c.word_ops += 2 * transfers          # each transfer = 1 read + 1 write
        c.cell_reads += transfers * (M + _log2(L))
        c.cell_writes += transfers * (M + _log2(L))
    else:
        # one horizontal in-place add first (pairs within rows)
        c.compares += 4 * M
        c.cell_compares += 4 * M * rows * 2
        c.writes += 4 * M
        c.cell_writes_lut += 4 * M * rows * 0.5
        n_vert = (L / 2.0 - 1) if mode == "2d" else _log2(L / 2.0)
        # a vertical add completes in 4 passes (Eq. 4) regardless of width, so
        # each pass touches a constant ~2x2 cell window (2 rows x carry/flag
        # columns) — ASSUMPTION consistent with the 8-cycles-per-add latency.
        c.compares += 4 * n_vert
        c.cell_compares += 4 * n_vert * 4
        c.writes += 4 * n_vert
        c.cell_writes_lut += 4 * n_vert * 2 * 0.5
    if readout:
        c.word_ops += 1                      # final word-sequential read
        c.cell_reads += M + _log2(L)
    return c


# ---------------------------------------------------------------------------
# Macro functions
# ---------------------------------------------------------------------------

def rt_matmat(i: int, j: int, u: int, Mw: int, Ma: int, mode: str = "2d", *,
              populate: bool = True, readout: bool = True,
              parallel_blocks: int = 1) -> Cost:
    """Matrix-matrix multiply (i x j) @ (j x u)  (Eqs. 6-8).

    The AP stores one product per row: ``i*j*u`` rows (+carry).  After the
    bit-serial multiply (word-parallel over all rows), each of the ``i*u``
    output blocks reduces its ``j`` products with vertical row-pair adds:
      2D no-seg: (i*u)(j-1) sequential adds of 8 cycles (Eq. 7)
      2Dseg    : log2(j) rounds (Eq. 8)
      1D       : log2(j) add rounds + (i*u)(j-1) word transfers (Eq. 6)

    ``parallel_blocks`` models BF-IMNA's spatial parallelism: output blocks
    spread over that many independent APs reduce concurrently, dividing the
    *sequential* reduction count (latency) but not the energy.
    """
    _check(mode)
    c = Cost()
    L = i * j * u                            # one product per row-word
    rows = float(L)
    if populate:
        c.writes += Mw + Ma
        c.cell_writes += (Mw + Ma) * rows
    # multiply phase, all rows word-parallel
    passes = 4 * Mw * Ma
    c.compares += passes
    c.cell_compares += passes * rows * 2
    c.writes += passes
    c.cell_writes_lut += passes * rows * 0.5
    # reduction phase
    width = Mw + Ma + _log2(j)
    n_blocks = i * u
    total_adds = n_blocks * max(j - 1, 0)
    if mode == "1d":
        for q in range(1, int(_log2(j)) + 1):
            w = 2 * max(Mw, Ma) + q - 1
            c.compares += 4 * w
            c.cell_compares += 4 * w * rows * 2
            c.writes += 4 * w
            c.cell_writes_lut += 4 * w * rows * 0.5
        c.word_ops += 2 * total_adds         # transfers
        c.cell_reads += total_adds * width
        c.cell_writes += total_adds * width
    elif mode == "2d":
        seq_adds = total_adds / max(parallel_blocks, 1)
        c.compares += 4 * seq_adds
        c.writes += 4 * seq_adds
        # constant-cell vertical passes (see rt_reduce note)
        c.cell_compares += 4 * total_adds * 4
        c.cell_writes_lut += 4 * total_adds * 2 * 0.5
    else:  # 2dseg: reductions across row pairs in parallel
        n_rounds = _log2(j)
        c.compares += 4 * n_rounds
        c.writes += 4 * n_rounds
        c.cell_compares += 4 * total_adds * 4
        c.cell_writes_lut += 4 * total_adds * 2 * 0.5
    if readout:
        c.reads += Mw + Ma + _log2(j)
        c.cell_reads += (Mw + Ma + _log2(j)) * n_blocks
    return c


# ---------------------------------------------------------------------------
# CNN functions
# ---------------------------------------------------------------------------

def rt_relu(M: int, L: int, mode: str = "2d", *, populate: bool = True,
            readout: bool = True) -> Cost:
    """ReLU via the Table III LUT (Eq. 15): M + 3 + (M-1)*2 + M.

    Words stored vertically; identical for all AP flavours.
    """
    _check(mode)
    c = Cost()
    if populate:
        c.writes += M
        c.cell_writes += M * L
    # stash MSB in flag, reset MSB: 2 writes + 1 read
    c.writes += 2
    c.cell_writes += 2 * L
    c.reads += 1
    c.cell_reads += L
    # LUT pass over remaining M-1 bit/flag pairs
    c.compares += M - 1
    c.cell_compares += (M - 1) * L * 2
    c.writes += M - 1
    c.cell_writes_lut += (M - 1) * L * 0.5
    if readout:
        c.reads += M
        c.cell_reads += M * L
    return c


def rt_maxpool(M: int, S: int, K: int, mode: str = "2d", *, populate: bool = True,
               readout: bool = True, parallel_blocks: int = 1) -> Cost:
    """Max pooling, window S, K windows (Eqs. 12-14) via the Table IV LUT."""
    _check(mode)
    c = Cost()
    L = S * K
    rows = L / 2.0
    if populate:
        c.writes += 2 * M
        c.cell_writes += 2 * M * rows
    # first horizontal max pass: 4M compares/writes + 2 flag-reset writes
    c.compares += 4 * M
    c.cell_compares += 4 * M * rows * 2
    c.writes += 4 * M + 2
    c.cell_writes_lut += 4 * M * rows * 0.5 + 2 * rows
    if mode == "1d":
        n_rounds = max(_log2(S) - 1, 0)
        c.compares += n_rounds * 4 * M
        c.cell_compares += n_rounds * 4 * M * rows * 2
        c.writes += n_rounds * (4 * M + 2)
        c.cell_writes_lut += n_rounds * (4 * M * rows * 0.5 + 2 * rows)
        transfers = K * (S / 2.0 - 1)
        c.word_ops += 2 * transfers
        c.cell_reads += transfers * M
        c.cell_writes += transfers * M
    elif mode == "2d":
        total_vert = K * (S / 2.0 - 1)
        seq_vert = total_vert / max(parallel_blocks, 1)
        c.compares += 4 * seq_vert
        c.writes += (4 + 2) * seq_vert       # Eq. 13: 10K(S/2-1) total ops
        c.cell_compares += 4 * total_vert * M * 2
        c.cell_writes_lut += (4 * 0.5 + 2) * total_vert * M
    else:
        n_rounds = _log2(S / 2.0)
        c.compares += 4 * n_rounds
        c.writes += (4 + 2 * K) * n_rounds
        total_vert = K * (S / 2.0 - 1)
        c.cell_compares += 4 * total_vert * M * 2
        c.cell_writes_lut += (4 * 0.5 + 2) * total_vert * M
    if readout:
        c.reads += M
        c.cell_reads += M * K
    return c


def rt_avgpool(M: int, S: int, K: int, mode: str = "2d", *, populate: bool = True,
               readout: bool = True, parallel_blocks: int = 1) -> Cost:
    """Average pooling, window S, K windows (Eqs. 9-11).

    Division by the window size is a free shifted read (S power of two).
    """
    _check(mode)
    c = Cost()
    L = S * K
    rows = L / 2.0
    if populate:
        c.writes += 2 * M
        c.cell_writes += 2 * M * rows
    if mode == "1d":
        for q in range(1, int(_log2(S)) + 1):
            w = M + q - 1
            c.compares += 4 * w
            c.cell_compares += 4 * w * rows * 2
            c.writes += 4 * w
            c.cell_writes_lut += 4 * w * rows * 0.5
        transfers = K * (S / 2.0 - 1)
        c.word_ops += 2 * transfers
        c.cell_reads += transfers * M
        c.cell_writes += transfers * M
    else:
        c.compares += 4 * M
        c.cell_compares += 4 * M * rows * 2
        c.writes += 4 * M
        c.cell_writes_lut += 4 * M * rows * 0.5
        if mode == "2d":
            total_vert = K * (S / 2.0 - 1)
            seq_vert = total_vert / max(parallel_blocks, 1)
            c.compares += 4 * seq_vert
            c.writes += 4 * seq_vert
        else:
            n_rounds = _log2(S / 2.0)
            c.compares += 4 * n_rounds
            c.writes += 4 * n_rounds
            total_vert = K * (S / 2.0 - 1)
        c.cell_compares += 4 * total_vert * 4
        c.cell_writes_lut += 4 * total_vert * 2 * 0.5
    if readout:
        c.reads += M                          # shifted bit-sequential read
        c.cell_reads += M * K
    return c


# ---------------------------------------------------------------------------
# Table I closed forms (cycle counts, SRAM units) -- used by tests to verify
# the Cost-based accounting matches the paper's published expressions.
# ---------------------------------------------------------------------------

def table1_cycles(fn: str, mode: str, **kw) -> float:
    """Literal Table I expressions (compare=write=read=1 cycle)."""
    M = kw.get("M")
    L = kw.get("L")
    S = kw.get("S")
    K = kw.get("K")
    i, j, u = kw.get("i"), kw.get("j"), kw.get("u")
    if fn == "add":
        return 2 * M + 8 * M + M + 1
    if fn == "multiply":
        return 2 * M + 8 * M * M + 2 * M
    if fn == "reduce":
        if mode == "1d":
            return (2 * M + sum(8 * (M + q - 1) for q in range(1, int(_log2(L)) + 1))
                    + 2 * (L / 2 - 1) + 2)
        if mode == "2d":
            return 2 * M + 8 * M + 8 * (L / 2 - 1) + 2
        return 2 * M + 8 * M + 8 * _log2(L / 2) + 2
    if fn == "matmat":
        M2 = 2 * M
        if mode == "1d":
            return (2 * M + 8 * M * M
                    + sum(8 * (M2 + q - 1) for q in range(1, int(_log2(j)) + 1))
                    + 2 * (i * u) * (j - 1) + M2 + _log2(j))
        if mode == "2d":
            return 2 * M + 8 * M * M + 8 * (i * u) * (j - 1) + M2 + _log2(j)
        return 2 * M + 8 * M * M + 8 * _log2(j) + M2 + _log2(j)
    if fn == "relu":
        return 4 * M + 1
    if fn == "maxpool":
        if mode == "1d":
            return 2 * M + (8 * M + 2) * _log2(S) + 2 * K * (S / 2 - 1) + M
        if mode == "2d":
            return 2 * M + (8 * M + 2) + 10 * K * (S / 2 - 1) + M
        return 2 * M + (8 * M + 2) + (8 + 2 * K) * _log2(S / 2) + M
    if fn == "avgpool":
        if mode == "1d":
            return (2 * M + 2 * K * (S / 2 - 1)
                    + sum(8 * (M + q - 1) for q in range(1, int(_log2(S)) + 1)) + M)
        if mode == "2d":
            return 2 * M + 8 * M + 8 * K * (S / 2 - 1) + M
        return 2 * M + 8 * M + 8 * _log2(S / 2) + M
    raise ValueError(fn)
