"""CNN workload tables for the paper's benchmarks.

AlexNet / VGG16 / ResNet50 (paper §IV) + ResNet18 (Table VII bit-fluidity
study).  Each network is a list of :class:`Layer` records; convolutions are
described by their im2col GEMM dimensions (paper §II.C):

    P (input-patch)  : (Hk*Wk*Ci) x (Ho*Wo)
    K (kernel-patch) : Ck x (Hk*Wk*Ci)
    O = K @ P        : Ck x (Ho*Wo)       i.e. GEMM dims i=Ck, j=Hk*Wk*Ci/g,
                                          u=Ho*Wo  (g = groups)

MAC counts match the common references (AlexNet 0.72G with grouped convs as
the paper cites; VGG16 15.5G).  NOTE: the paper quotes "4.14G MACs" for
ResNet50, which is its FLOP count (2 ops/MAC); our table yields ~2.07 GMACs
— the trend comparisons (VGG16 > ResNet50 > AlexNet) are unaffected and the
delta is recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class Layer:
    name: str
    kind: str                    # conv | fc | maxpool | avgpool | add | relu
    # conv/fc geometry
    hin: int = 0
    win: int = 0
    cin: int = 0
    hk: int = 0
    wk: int = 0
    cout: int = 0
    stride: int = 1
    pad: int = 0
    groups: int = 1
    # pooling geometry
    window: int = 0              # S = hk*wk for pools
    relu: bool = False           # fused activation after conv/fc

    @property
    def hout(self) -> int:
        if self.kind in ("conv", "maxpool", "avgpool"):
            return (self.hin - self.hk + 2 * self.pad) // self.stride + 1
        return 1

    @property
    def wout(self) -> int:
        if self.kind in ("conv", "maxpool", "avgpool"):
            return (self.win - self.wk + 2 * self.pad) // self.stride + 1
        return 1

    def gemm_dims(self) -> Tuple[int, int, int]:
        """(i, j, u) such that the layer is O[i,u] = K[i,j] @ P[j,u]."""
        if self.kind == "conv":
            i = self.cout // self.groups
            j = self.hk * self.wk * (self.cin // self.groups)
            u = self.hout * self.wout
            return i, j, u
        if self.kind == "fc":
            return self.cout, self.cin, 1
        raise ValueError(f"{self.kind} has no GEMM dims")

    @property
    def macs(self) -> int:
        if self.kind == "conv":
            i, j, u = self.gemm_dims()
            return i * j * u * self.groups
        if self.kind == "fc":
            return self.cout * self.cin
        return 0

    @property
    def pool_elems(self) -> int:
        """(#windows K, window size S) product = elements pooled."""
        if self.kind in ("maxpool", "avgpool"):
            return self.hout * self.wout * self.cin * self.hk * self.wk
        return 0


def conv(name, hin, cin, k, cout, stride=1, pad=None, groups=1, relu=True) -> Layer:
    if pad is None:
        pad = k // 2
    return Layer(name, "conv", hin, hin, cin, k, k, cout,
                 stride=stride, pad=pad, groups=groups, relu=relu)


def pool(name, kind, hin, cin, k, stride) -> Layer:
    return Layer(name, kind, hin, hin, cin, k, k, cin, stride=stride, pad=0,
                 window=k * k)


def fc(name, cin, cout, relu=True) -> Layer:
    return Layer(name, "fc", cin=cin, cout=cout, relu=relu)


def add(name, hin, cin) -> Layer:
    return Layer(name, "add", hin=hin, win=hin, cin=cin)


# ---------------------------------------------------------------------------
def alexnet() -> List[Layer]:
    return [
        conv("conv1", 227, 3, 11, 96, stride=4, pad=0),
        pool("pool1", "maxpool", 55, 96, 3, 2),
        conv("conv2", 27, 96, 5, 256, groups=2),
        pool("pool2", "maxpool", 27, 256, 3, 2),
        conv("conv3", 13, 256, 3, 384),
        conv("conv4", 13, 384, 3, 384, groups=2),
        conv("conv5", 13, 384, 3, 256, groups=2),
        pool("pool5", "maxpool", 13, 256, 3, 2),
        fc("fc6", 256 * 6 * 6, 4096),
        fc("fc7", 4096, 4096),
        fc("fc8", 4096, 1000, relu=False),
    ]


def vgg16() -> List[Layer]:
    layers: List[Layer] = []
    cfg = [(224, 3, 64, 2), (112, 64, 128, 2), (56, 128, 256, 3),
           (28, 256, 512, 3), (14, 512, 512, 3)]
    for bi, (h, cin, cout, n) in enumerate(cfg, 1):
        for li in range(n):
            layers.append(conv(f"conv{bi}_{li+1}", h, cin if li == 0 else cout,
                               3, cout))
        layers.append(pool(f"pool{bi}", "maxpool", h, cout, 2, 2))
    layers += [fc("fc6", 512 * 7 * 7, 4096), fc("fc7", 4096, 4096),
               fc("fc8", 4096, 1000, relu=False)]
    return layers


def _resnet(block_cfg, bottleneck: bool) -> List[Layer]:
    layers: List[Layer] = [
        conv("conv1", 224, 3, 7, 64, stride=2, pad=3),
        pool("pool1", "maxpool", 112, 64, 3, 2),
    ]
    h, cin = 56, 64
    for si, (cmid, n_blocks) in enumerate(block_cfg, 2):
        cout = cmid * 4 if bottleneck else cmid
        for b in range(n_blocks):
            stride = 2 if (b == 0 and si > 2) else 1
            pfx = f"s{si}b{b+1}"
            if bottleneck:
                layers += [
                    conv(f"{pfx}_c1", h, cin, 1, cmid, stride=stride, pad=0),
                    conv(f"{pfx}_c2", h // stride, cmid, 3, cmid),
                    conv(f"{pfx}_c3", h // stride, cmid, 1, cout, pad=0,
                         relu=False),
                ]
            else:
                layers += [
                    conv(f"{pfx}_c1", h, cin, 3, cmid, stride=stride),
                    conv(f"{pfx}_c2", h // stride, cmid, 3, cout, relu=False),
                ]
            if b == 0 and cin != cout:
                layers.append(conv(f"{pfx}_down", h, cin, 1, cout,
                                   stride=stride, pad=0, relu=False))
            h //= stride
            cin = cout
            layers.append(add(f"{pfx}_add", h, cout))
    layers.append(pool("gap", "avgpool", h, cin, h, 1))
    layers.append(fc("fc", cin, 1000, relu=False))
    return layers


def resnet50() -> List[Layer]:
    return _resnet([(64, 3), (128, 4), (256, 6), (512, 3)], bottleneck=True)


def resnet18() -> List[Layer]:
    return _resnet([(64, 2), (128, 2), (256, 2), (512, 2)], bottleneck=False)


NETWORKS = {
    "alexnet": alexnet,
    "vgg16": vgg16,
    "resnet50": resnet50,
    "resnet18": resnet18,
}
WORKLOADS = NETWORKS  # alias


def total_macs(layers: List[Layer]) -> int:
    return sum(l.macs for l in layers)


def gemm_layers(layers: List[Layer]) -> List[Layer]:
    return [l for l in layers if l.kind in ("conv", "fc")]


# ---------------------------------------------------------------------------
# HAWQ-V3 per-layer bitwidths for ResNet18 (paper Table VII).  Vectors are
# transcribed from the table; they apply to the quantized GEMM layers in
# order, and any remaining layers take the final entry.
# ---------------------------------------------------------------------------
HAWQV3_RESNET18 = {
    "int8": [8],
    "high": [8, 8, 8, 8, 8, 8, 8, 8, 4, 8, 8, 8, 4, 8, 4, 8, 4, 8, 4, 8],
    "medium": [8, 8, 8, 8, 8, 4, 8, 8, 4, 8, 8, 4, 4, 8, 4, 8, 4, 4],
    "low": [8, 8, 8, 4, 8, 4, 8, 4, 4, 4, 4, 4, 4, 4, 4, 4, 4, 4, 4, 4],
    "int4": [4],
}
# Accuracy / model size are adopted from HAWQ-V3 [53] (Table VII) — they are
# *inputs* to the EDP-accuracy trade-off, not simulator outputs.
HAWQV3_METADATA = {
    "int4": dict(size_mb=5.6, top1=68.45),
    "low": dict(size_mb=6.1, top1=68.56),
    "medium": dict(size_mb=7.2, top1=70.34),
    "high": dict(size_mb=8.7, top1=70.40),
    "int8": dict(size_mb=11.2, top1=71.56),
}


def per_layer_bits(layers: List[Layer], vec: List[int]) -> List[int]:
    """Expand a Table-VII bit vector over the network's GEMM layers.

    Short vectors extend with their last entry (the paper's rule); a
    vector LONGER than the network's GEMM-layer count is a config/network
    mismatch and raises instead of silently dropping its tail."""
    gl = gemm_layers(layers)
    if len(vec) > len(gl):
        raise ValueError(
            f"bit vector of length {len(vec)} exceeds the network's "
            f"{len(gl)} GEMM (conv/fc) layers — wrong network for this "
            f"configuration?")
    out = []
    for idx in range(len(gl)):
        out.append(vec[idx] if idx < len(vec) else vec[-1])
    return out
