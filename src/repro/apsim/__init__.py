"""repro.apsim — faithful reimplementation of BF-IMNA's in-house simulator.

The paper (Rakka et al., "BF-IMNA", 2024) models Associative-Processor (AP)
compute as sequences of compare/write passes (Tables I & II, Eqs. 1-15) and
estimates end-to-end CNN inference latency / energy / area on two hardware
configurations (IR = infinite resources, LR = limited resources, Table V)
for SRAM and ReRAM CAM cells (Table VI).

Modules
-------
costmodel   Eqs. 1-15 runtime models + cell-level op accounting
energy      Table VI technology parameters, voltage scaling
mapper      im2col GEMM dims, IR/LR mapping with time folding, mesh comm
workloads   AlexNet / VGG16 / ResNet50 / ResNet18 layer tables
metrics     GOPS, GOPS/W, GOPS/W/mm^2, EDP, Table VIII peak model
"""
from repro.apsim.costmodel import (  # noqa: F401
    Cost,
    rt_add,
    rt_multiply,
    rt_reduce,
    rt_matmat,
    rt_relu,
    rt_maxpool,
    rt_avgpool,
)
from repro.apsim.energy import TechParams, SRAM, RERAM  # noqa: F401
from repro.apsim.mapper import BFIMNAConfig, LR_CONFIG, IR_CONFIG, simulate_network  # noqa: F401
from repro.apsim.workloads import WORKLOADS, NETWORKS  # noqa: F401
