"""Technology parameters (Table VI, 16nm PTM) and energy accounting.

Paper-given constants are used verbatim.  Constants the paper does *not*
publish (per-cell compare/read energy, ReRAM compare-cycle slowdown) are
CALIBRATED once against the paper's own reported ratios (Fig. 6) and then
frozen — everything downstream (Fig. 7, Tables VII/VIII) is predicted.

Calibration targets (paper §V.A):
  * ReRAM/SRAM end-to-end VGG16 energy ratio falls 80.9x -> 63.1x as the
    fixed precision rises 2 -> 8 bits.
  * ReRAM/SRAM latency ratio stays ~1.85x across precisions.
  * Voltage scaling 1.0V -> 0.5V drops SRAM write energy 0.24fJ -> 0.06fJ
    (error prob 0 -> 0.021) with <0.1% end-to-end energy impact.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TechParams:
    name: str
    # --- energies, Joules per cell-op ---
    e_write_j: float           # Table VI: SRAM 0.24fJ, ReRAM 21.7pJ
    e_compare_j: float         # CALIBRATED (paper: "similar in both")
    e_read_j: float            # sensing ~= compare
    # --- cycle costs per pass ---
    compare_cycles: float      # ReRAM sense RC (R_LRS*C_in=0.25ns) is slower
    write_cycles: float        # paper: SRAM writes in half the ReRAM cycles
    read_cycles: float
    # --- cell area (um^2), for Table V / ReRAM 4.4x area saving ---
    cell_area_um2: float
    # --- LUT-pass writes re-write mostly-unchanged result bits; only the
    #     toggled fraction pays the full write energy (state-dependent) ---
    lut_toggle_frac: float = 1.0
    # --- voltage-scaling error probability (paper §V.A) ---
    write_error_prob: float = 0.0


# Per-cell compare energy: CALIBRATED (single fit, benchmarks/calibrate.py)
# against (a) Fig. 6 ReRAM/SRAM VGG16 energy ratios 80.9x@2b..63.1x@8b and
# (b) the paper's absolute LR/SRAM ResNet50 energies 0.009J@2b / 0.095J@8b.
# Result: ratios within +/-8%, absolute energies within 4%.
# The paper states compare energy is technology-independent.
E_COMPARE_J = 4.594e-14   # 0.046 pJ  [CALIBRATED]
E_READ_J = E_COMPARE_J    # a bit-sequential read is a search (paper §II.B)
LUT_TOGGLE_FRAC_RERAM = 0.386  # [CALIBRATED] fraction of LUT result writes
#                                that toggle the ReRAM cell state

# 6T SRAM cell @16nm ~0.05 um^2; ReRAM 4.4x denser (paper §V.A)
_SRAM_CELL_AREA = 0.050
_RERAM_CELL_AREA = _SRAM_CELL_AREA / 4.4

SRAM = TechParams(
    name="sram",
    e_write_j=0.24e-15,          # Table VI
    e_compare_j=E_COMPARE_J,
    e_read_j=E_READ_J,
    compare_cycles=1.0,
    write_cycles=1.0,
    read_cycles=1.0,
    cell_area_um2=_SRAM_CELL_AREA,
)

RERAM = TechParams(
    name="reram",
    e_write_j=21.7e-12,          # Table VI
    e_compare_j=E_COMPARE_J,
    e_read_j=E_READ_J,
    compare_cycles=1.7,          # CALIBRATED: R_LRS*C_in RC sense slowdown
    write_cycles=2.0,            # paper: SRAM needs half the write cycles
    read_cycles=1.7,
    cell_area_um2=_RERAM_CELL_AREA,
    lut_toggle_frac=LUT_TOGGLE_FRAC_RERAM,
)

SRAM_05V = dataclasses.replace(
    SRAM, name="sram@0.5V", e_write_j=0.06e-15, write_error_prob=0.021,
)

# --- extension technologies (paper §V.A: "very easy to extend our
# framework" to PCM [49] and FeFET [29] cells).  Write energies/cycles
# from the cited surveys; compare energy is sense-side and shared. ------
PCM = dataclasses.replace(
    RERAM, name="pcm",
    e_write_j=30e-12,            # SET/RESET ~10-100 pJ (Wong [49])
    write_cycles=4.0,            # ~100 ns programming vs 1 GHz clock scale
    cell_area_um2=_SRAM_CELL_AREA / 4.0,
)

FEFET = dataclasses.replace(
    RERAM, name="fefet",
    e_write_j=1e-15,             # field-effect write, ~fJ (Müller [29])
    write_cycles=2.0,
    compare_cycles=1.3, read_cycles=1.3,
    cell_area_um2=_SRAM_CELL_AREA / 2.0,
)

TECHNOLOGIES = {t.name: t for t in (SRAM, RERAM, SRAM_05V, PCM, FEFET)}


def voltage_scaled(tech: TechParams, vdd: float) -> TechParams:
    """Interpolate write energy between the paper's two published points.

    1.0V -> 0.24fJ (err 0.0);  0.5V -> 0.06fJ (err 0.021).  E ~ V^2.
    Only published for SRAM; other technologies are returned unchanged.
    """
    if tech.name != "sram":
        return tech
    vdd = max(0.5, min(1.0, vdd))
    scale = (vdd / 1.0) ** 2
    err = 0.021 * (1.0 - vdd) / 0.5
    return dataclasses.replace(
        tech, name=f"sram@{vdd:.2f}V",
        e_write_j=0.24e-15 * scale, write_error_prob=err)


# --- interconnect (paper Table V + ref [6]) --------------------------------
@dataclasses.dataclass(frozen=True)
class MeshParams:
    bits_per_transfer: int = 1024
    freq_hz: float = 500e6              # half of the 1 GHz AP clock
    avg_hops: float = 3.815             # Table V
    e_per_bit_per_mm_j: float = 0.05e-12  # ~0.05 pJ/bit/mm @16nm (Dally [6])
    hop_mm: float = 1.47                # sqrt(137.45mm^2 / 64 clusters)

    def transfer_latency_s(self, bits: float) -> float:
        transfers = -(-bits // self.bits_per_transfer) if bits else 0
        return transfers * self.avg_hops / self.freq_hz

    def transfer_energy_j(self, bits: float) -> float:
        return bits * self.e_per_bit_per_mm_j * self.hop_mm * self.avg_hops


MESH = MeshParams()
