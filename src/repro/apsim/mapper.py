"""BF-IMNA architecture mapping + end-to-end inference simulation (paper §III-IV).

Two hardware configurations (paper §III.A):

* **IR** (infinite resources / maximum parallelism): one giant cluster with
  enough CAPs to compute the largest layer in one shot; each output block
  (the j products of one output element) lives in its own CAP region, so
  block reductions run fully in parallel.

* **LR** (limited resources, Table V): 8x8 clusters of 8x8 CAPs, each CAP
  4800 rows x 16 columns (two 8-bit words / row).  Weight-stationary GEMM,
  time-folded: each cluster holds a copy of the layer's kernel matrix and
  computes different output columns; output blocks packed into a CAP reduce
  *sequentially* (2D AP without segmentation — the paper's design point).

Mapping assumptions not pinned down by the paper text are marked ASSUMPTION
and reported against the paper's published ratios in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from repro.apsim import costmodel as cm
from repro.apsim.energy import MESH, SRAM, MeshParams, TechParams
from repro.apsim.workloads import Layer, gemm_layers, per_layer_bits


@dataclasses.dataclass(frozen=True)
class BFIMNAConfig:
    """Hardware configuration (Table V)."""
    name: str
    clusters: int = 64                # 8 x 8
    caps_per_cluster: int = 64        # 8 x 8
    cap_rows: int = 4800
    cap_cols: int = 16                # 2 words x 8 bits
    freq_hz: float = 1e9
    infinite: bool = False            # IR config
    mesh: MeshParams = MESH
    periphery_factor: float = 1.94    # CALIBRATED: area -> Table V 137.45mm^2

    @property
    def n_caps(self) -> int:
        return self.clusters * self.caps_per_cluster

    @property
    def total_rows(self) -> int:
        return self.n_caps * self.cap_rows


LR_CONFIG = BFIMNAConfig(name="LR")
IR_CONFIG = BFIMNAConfig(name="IR", infinite=True)


@dataclasses.dataclass
class LayerReport:
    name: str
    kind: str
    bits: int
    steps: int
    cycles: float
    compute_energy_j: float
    move_energy_j: float
    move_cycles: float
    macs: int

    @property
    def latency_s(self) -> float:
        return self.cycles / 1e9

    @property
    def energy_j(self) -> float:
        return self.compute_energy_j + self.move_energy_j


@dataclasses.dataclass
class NetworkReport:
    network: str
    config: str
    tech: str
    layers: List[LayerReport]
    area_mm2: float

    @property
    def latency_s(self) -> float:
        return sum(l.cycles for l in self.layers) / 1e9

    @property
    def energy_j(self) -> float:
        return sum(l.energy_j for l in self.layers)

    @property
    def macs(self) -> int:
        return sum(l.macs for l in self.layers)

    @property
    def gops(self) -> float:
        return 2.0 * self.macs / self.latency_s / 1e9

    @property
    def gops_per_w(self) -> float:
        return 2.0 * self.macs / self.energy_j / 1e9

    @property
    def gops_per_w_per_mm2(self) -> float:
        return self.gops_per_w / self.area_mm2

    @property
    def edp(self) -> float:
        return self.energy_j * self.latency_s

    def breakdown(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for l in self.layers:
            kind = {"conv": "gemm", "fc": "gemm"}.get(l.kind, l.kind)
            d = out.setdefault(kind, dict(energy_j=0.0, cycles=0.0))
            d["energy_j"] += l.energy_j
            d["cycles"] += l.cycles
        return out


# ---------------------------------------------------------------------------
# GEMM mapping
# ---------------------------------------------------------------------------

def _gemm_mapping(cfg: BFIMNAConfig, i: int, j: int, u: int):
    """Returns (j_fold, j_sub, outputs_per_cap, steps)."""
    # a block (j products of one output) must fit in one CAP (+1 carry row)
    j_fold = max(1, math.ceil(j / (cfg.cap_rows - 1)))
    j_sub = math.ceil(j / j_fold)
    opc = max(1, (cfg.cap_rows - 1) // max(j_sub, 1))   # outputs per CAP
    total_blocks = i * u * j_fold
    if cfg.infinite:
        # IR: enough CAPs for every block of the layer at once
        return j_fold, j_sub, 1, 1
    slots = cfg.n_caps * opc
    steps = math.ceil(total_blocks / slots)
    return j_fold, j_sub, opc, steps


def _gemm_layer(cfg: BFIMNAConfig, tech: TechParams, layer: Layer,
                Mw: int, Ma: int) -> LayerReport:
    i, j, u = layer.gemm_dims()
    groups = layer.groups
    j_fold, j_sub, opc, steps = _gemm_mapping(cfg, i, j, u * groups)

    # ---- energy: whole-GEMM cell accounting (mapping independent) --------
    comp = cm.rt_matmat(i, j, u * groups, Mw, Ma, mode="2d",
                        parallel_blocks=cfg.n_caps * opc)
    compute_energy = comp.energy_j(tech)

    # ---- latency: per-step cost x steps (ASSUMPTION: 3-stage Read/Compute/
    # Write pipeline hides streaming; see paper "latency ... hidden") -------
    per_step = cm.Cost()
    per_step.writes += Ma                                # stream activations
    passes = 4 * Mw * Ma
    per_step.compares += passes
    per_step.writes += passes
    seq_adds = opc * max(j_sub - 1, 0)                   # sequential in-CAP
    per_step.compares += 4 * seq_adds
    per_step.writes += 4 * seq_adds
    per_step.word_ops += opc                             # word-seq readout
    cycles = steps * per_step.cycles(tech)
    # one-time weight load per layer (stationary) + partial-sum combines
    cycles += Mw * tech.write_cycles
    if j_fold > 1:
        width = Mw + Ma + math.log2(max(j, 2))
        cycles += steps * 8 * width * tech.write_cycles * 0.5

    # ---- data movement ----------------------------------------------------
    out_bits_elem = Mw + Ma + math.ceil(math.log2(max(j, 2)))
    in_bits = j * u * groups * Ma * j_fold               # stream P columns
    w_bits = i * j * groups * Mw                         # weights, once
    out_bits = i * u * groups * out_bits_elem            # reshape to MAP
    move_bits = in_bits + w_bits + out_bits
    move_energy = cfg.mesh.transfer_energy_j(move_bits)
    # MAP word-seq write/read energy for the reshape
    map_cells = 2.0 * i * u * groups * out_bits_elem
    move_energy += map_cells * (tech.e_write_j + tech.e_read_j) / 2.0
    move_cycles = cfg.mesh.transfer_latency_s(out_bits) * cfg.freq_hz
    # reshape is NOT hidden (paper: "All reshaping overheads are factored in")
    cycles += move_cycles

    return LayerReport(layer.name, layer.kind, max(Mw, Ma), steps, cycles,
                       compute_energy, move_energy, move_cycles, layer.macs)


def _pool_layer(cfg: BFIMNAConfig, tech: TechParams, layer: Layer,
                M: int) -> LayerReport:
    S = layer.window
    K = layer.hout * layer.wout * layer.cin
    fn = cm.rt_maxpool if layer.kind == "maxpool" else cm.rt_avgpool
    opc = max(1, cfg.cap_rows // max(S, 1))
    steps = 1 if cfg.infinite else math.ceil(K / (cfg.n_caps * opc))
    comp = fn(M, S, K, mode="2d", parallel_blocks=cfg.n_caps * opc)
    energy = comp.energy_j(tech)
    per_step = fn(M, S, min(K, opc), mode="2d", parallel_blocks=1)
    cycles = steps * per_step.cycles(tech)
    move_bits = K * S * M
    move_energy = cfg.mesh.transfer_energy_j(move_bits)
    return LayerReport(layer.name, layer.kind, M, steps, cycles, energy,
                       move_energy, 0.0, 0)


def _relu_layer(cfg: BFIMNAConfig, tech: TechParams, n_elems: int,
                M: int, name: str) -> LayerReport:
    per_cap = cfg.cap_cols * max(1, cfg.cap_rows // (M + 1))
    steps = 1 if cfg.infinite else math.ceil(n_elems / (cfg.n_caps * per_cap))
    comp = cm.rt_relu(M, n_elems, mode="2d")
    energy = comp.energy_j(tech)
    per_step = cm.rt_relu(M, min(n_elems, per_cap), mode="2d")
    cycles = steps * per_step.cycles(tech)
    return LayerReport(name, "relu", M, steps, cycles, energy, 0.0, 0.0, 0)


def _add_layer(cfg: BFIMNAConfig, tech: TechParams, layer: Layer,
               M: int) -> LayerReport:
    n = layer.hin * layer.win * layer.cin          # elementwise residual add
    steps = 1 if cfg.infinite else math.ceil(n / cfg.total_rows)
    comp = cm.rt_add(M, 2 * n, mode="2d")
    energy = comp.energy_j(tech)
    per_step = cm.rt_add(M, min(2 * n, 2 * cfg.cap_rows), mode="2d")
    cycles = steps * per_step.cycles(tech)
    return LayerReport(layer.name, "add", M, steps, cycles, energy, 0.0, 0.0, 0)


# ---------------------------------------------------------------------------

def area_mm2(cfg: BFIMNAConfig, tech: TechParams, weight_bits: float) -> float:
    """Die area: CAP cells + MAP storage for all weights + periphery.

    CALIBRATED: periphery_factor chosen once so the LR/SRAM/VGG16@8b point
    reproduces Table V's 137.45 mm^2.
    """
    cap_cells = cfg.n_caps * cfg.cap_rows * cfg.cap_cols
    map_cells = weight_bits
    return ((cap_cells + map_cells) * tech.cell_area_um2 * 1e-6
            * cfg.periphery_factor)


def simulate_network(layers: Sequence[Layer], cfg: BFIMNAConfig = LR_CONFIG,
                     tech: TechParams = SRAM,
                     bits: "int | Sequence[int]" = 8,
                     act_bits: Optional["int | Sequence[int]"] = None,
                     network: str = "net") -> NetworkReport:
    """End-to-end single-image inference simulation (paper batch size 1).

    ``bits`` — scalar fixed precision, or a per-GEMM-layer vector (bit
    fluidity: the vector is the run-time mixed-precision configuration; no
    hardware parameter changes between configurations).
    """
    gl = gemm_layers(list(layers))
    if isinstance(bits, int):
        wvec = [bits] * len(gl)
    else:
        wvec = per_layer_bits(list(layers), list(bits))
    if act_bits is None:
        avec = list(wvec)
    elif isinstance(act_bits, int):
        avec = [act_bits] * len(gl)
    else:
        avec = per_layer_bits(list(layers), list(act_bits))

    reports: List[LayerReport] = []
    gi = 0
    cur_bits = wvec[0] if wvec else 8
    for layer in layers:
        if layer.kind in ("conv", "fc"):
            Mw, Ma = wvec[gi], avec[gi]
            cur_bits = Ma
            reports.append(_gemm_layer(cfg, tech, layer, Mw, Ma))
            if layer.relu:
                n = layer.cout * layer.hout * layer.wout
                reports.append(_relu_layer(cfg, tech, n, Mw + Ma,
                                           layer.name + "_relu"))
            gi += 1
        elif layer.kind in ("maxpool", "avgpool"):
            reports.append(_pool_layer(cfg, tech, layer, cur_bits))
        elif layer.kind == "add":
            reports.append(_add_layer(cfg, tech, layer, cur_bits))
        else:
            raise ValueError(layer.kind)

    weight_bits = sum(l.macs // max(l.hout * l.wout, 1) if l.kind == "conv"
                      else (l.cin * l.cout if l.kind == "fc" else 0)
                      for l in layers) * (max(wvec) if wvec else 8)
    cfg_for_area = cfg
    if cfg.infinite:
        # IR area: enough rows for the largest layer's products at once
        need = max((l.macs for l in gl), default=1)
        scale = max(1.0, need / cfg.total_rows)
        cfg_for_area = dataclasses.replace(cfg, clusters=int(cfg.clusters * scale))
    return NetworkReport(network, cfg.name, tech.name, reports,
                         area_mm2(cfg_for_area, tech, weight_bits))
