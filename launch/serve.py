"""Replay a synthesized traffic trace through one bit-fluid LM server.

The CLI front end of the trace-driven traffic harness (DESIGN.md §9):
synthesize a seeded arrival schedule (``--trace poisson | diurnal |
spike | mmpp``, or ``--trace file --trace-file arrivals.jsonl`` to
import one), register every arrival with ``ServeRuntime.submit_at`` (the
runtime enqueues it when its scheduler clock reaches the arrival tick —
never all-up-front), pump ``run()``, and print the collector's report:
SLO attainment, p50/p99 latency (scheduler ticks) and EDP, queue depth
over time, unserved counts, and mean resolved bits per window.

By default the engine runs the closed loop: a FluidController with
deliberately optimistic predictions (``--optimism 0.5``) under a tight
whole-stream EDP SLO (``--slo-x`` times the predicted int8 cost), so a
spike trace visibly degrades bits mid-burst.  ``--open`` serves the same
trace open-loop for comparison; ``--window-ticks N`` switches to a rate
SLO (budget per N scheduler ticks — the diurnal experiment's shape).

  PYTHONPATH=src python launch/serve.py --trace spike --ticks 24 --rate 0.8
  PYTHONPATH=src python launch/serve.py --trace diurnal --window-ticks 6
  PYTHONPATH=src python launch/serve.py --trace mmpp --ticks 48 --rate 0.5
  PYTHONPATH=src python launch/serve.py --trace file --trace-file t.jsonl
  PYTHONPATH=src python launch/serve.py --trace poisson --open --out rep.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro import configs
from repro.core import policy as pol
from repro.models import lm
from repro.serve import predict_table
from repro.serve import traffic as tf
from repro.serve.engine import ServeEngine
from repro.serve.prefix_cache import PrefixCache


def build_engine(cfg, qparams, n, *, slo, window, window_ticks, optimism,
                 open_loop, prompt_len, max_new, slots, prefix_cache=None):
    cfgs = {"int4": pol.fixed(4), "int8": pol.fixed(8)}
    preds = predict_table(lm.layer_gemm_dims(cfg), cfgs, axis="edp",
                          units=prompt_len + max_new,
                          head=lm.head_gemm_dims(cfg), optimism=optimism)
    # open loop = an unconstrained fluid controller (slo=inf): same code
    # path and trace shape, but no feedback — it trusts the table blindly
    ctrl = pol.FluidController(
        cfgs, preds, n, budget_axis="edp",
        slo=float("inf") if open_loop else slo(preds), window=window,
        window_ticks=0 if open_loop else window_ticks)
    return ServeEngine(cfg, qparams, max_len=64, controller=ctrl,
                       n_slots=slots, prefill_len=prompt_len,
                       decode_block=max_new,
                       prefix_cache=prefix_cache), preds


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default="spike",
                    choices=("poisson", "diurnal", "spike", "mmpp",
                             "file"))
    ap.add_argument("--trace-file", default=None,
                    help="JSONL arrival schedule for --trace file "
                         "(one {'t': tick, ...} object per line)")
    ap.add_argument("--mmpp-up", type=float, default=0.08,
                    help="mmpp calm→bursty transition probability")
    ap.add_argument("--mmpp-down", type=float, default=0.25,
                    help="mmpp bursty→calm transition probability")
    ap.add_argument("--ticks", type=int, default=24)
    ap.add_argument("--rate", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repetition", type=float, default=0.0,
                    help="unique-vs-repeated request mix in [0, 1)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="serve through the cross-request prefix/KV-"
                         "cache tier and print its hit/miss ledger")
    ap.add_argument("--cache-capacity", type=int, default=32,
                    help="prefix-cache entries (repetition-aware "
                         "eviction past this)")
    ap.add_argument("--cache-chunk", type=int, default=4,
                    help="prefix-cache chunk alignment for partial hits")
    ap.add_argument("--hit-policy", default="at_least",
                    choices=("exact", "at_least", "repriced"),
                    help="precision gate for cache hits")
    ap.add_argument("--burst-mag", type=float, default=10.0)
    ap.add_argument("--burst-len", type=int, default=3)
    ap.add_argument("--depth", type=float, default=0.9,
                    help="diurnal modulation depth")
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--open", action="store_true",
                    help="open-loop baseline instead of the closed loop")
    ap.add_argument("--slo-x", type=float, default=1.2,
                    help="EDP SLO as a multiple of the predicted int8 "
                         "cost of the whole stream (or of one window "
                         "under --window-ticks)")
    ap.add_argument("--window-ticks", type=int, default=0,
                    help=">0: rate SLO per this many scheduler ticks")
    ap.add_argument("--optimism", type=float, default=0.5,
                    help="prediction-table scale (<1 = optimistic: the "
                         "closed loop must correct for it)")
    ap.add_argument("--max-ticks", type=int, default=10_000)
    ap.add_argument("--report-window", type=int, default=6,
                    help="ticks per bits/arrivals reporting window")
    ap.add_argument("--out", default=None, help="also write the report "
                                                "as JSON")
    args = ap.parse_args(argv)

    trace = tf.synth_trace(
        args.trace, ticks=args.ticks, rate=args.rate, seed=args.seed,
        repetition=args.repetition, burst_mag=args.burst_mag,
        burst_len=args.burst_len, depth=args.depth,
        mmpp_up=args.mmpp_up, mmpp_down=args.mmpp_down,
        lm_archs=(args.arch,), prompt_len=args.prompt_len,
        max_new_tokens=args.max_new, path=args.trace_file)
    print(f"trace: {args.trace}, {trace.n_requests} requests over "
          f"{trace.ticks} ticks (seed {args.seed})")

    cfg = configs.get_smoke(args.arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    qparams = lm.quantize_params(params, cfg)

    def slo(preds):
        if args.window_ticks:
            return args.window_ticks * args.rate * preds["int8"] * args.slo_x
        return trace.n_requests * preds["int8"] * args.slo_x

    cache = (PrefixCache(chunk=args.cache_chunk,
                         capacity=args.cache_capacity,
                         hit_policy=args.hit_policy)
             if args.prefix_cache else None)
    eng, _ = build_engine(
        cfg, qparams, lm.n_bit_slots(cfg), slo=slo, window=trace.n_requests,
        window_ticks=args.window_ticks, optimism=args.optimism,
        open_loop=args.open, prompt_len=args.prompt_len,
        max_new=args.max_new, slots=args.slots, prefix_cache=cache)

    meta = {}

    def arrival(req):
        def submit():
            rid = eng.submit(
                tf.payload_tokens(trace, req, cfg.vocab_size),
                max_new_tokens=req.max_new_tokens, rep_key=req.key)
            meta[rid] = req
            return rid
        return submit

    for req in trace.requests:
        eng.submit_at(req.t, arrival(req))
    t0 = time.time()
    eng.run(args.max_ticks, on_exhaust="report")
    rep = tf.result_from_runtime(eng, meta).report(
        window=args.report_window)

    mode = "open loop" if args.open else (
        f"closed loop (rate SLO per {args.window_ticks} ticks)"
        if args.window_ticks else "closed loop (whole-stream SLO)")
    print(f"{mode}: {rep['completed']}/{rep['requests']} served, "
          f"{rep['unserved']} unserved, mean_wbits={rep['mean_wbits']}, "
          f"p50/p99 latency {rep['p50_latency_ticks']:.0f}/"
          f"{rep['p99_latency_ticks']:.0f} ticks, "
          f"total EDP {rep['total_edp_js']:.3e} J*s, "
          f"queue peak {rep['queue_depth']['peak']}")
    print(f"bits/window    : {rep['mean_wbits_per_window']}")
    print(f"arrivals/window: {rep['arrivals_per_window']}")
    kr = rep["repetition"]
    print(f"repetition     : {kr['distinct_keys']} distinct keys / "
          f"{kr['arrivals']} arrivals, top-key share "
          f"{kr['top_key_share']:.2f}, max hit-rate {kr['max_hit_rate']:.2f}")
    if cache is not None:
        led = cache.ledger
        print(f"prefix cache   : {led.hits} full + {led.partial_hits} "
              f"partial hits / {led.lookups} lookups "
              f"(rate {led.hit_rate:.2f}), {led.misses} misses "
              f"({led.refreshes} refreshes), {led.evictions} evictions, "
              f"{led.rejected} rejected, {led.hit_tokens} tokens served "
              f"from cache, prefill EDP saved "
              f"{led.prefill_edp_saved_js:.3e} J*s")
        rep["prefix_cache"] = led.as_dict()
    print(f"compiled once: prefill x{eng.stats.prefill_traces}, "
          f"decode x{eng.stats.decode_traces}, "
          f"extend x{eng.stats.extend_traces} ({time.time() - t0:.1f}s "
          f"wall)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rep, f, indent=1)
        print(f"wrote {args.out}")
    return 0 if rep["unserved"] == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
